"""Fused serving step + int8 quantization invariants.

The fused path's correctness rests on one algebraic fact: the block
encoder adds no positional encoding to the context stream, so attention
over M context rows containing duplicates equals weighted attention over
the unique rows with the multiplicities as exponentiated-score weights.
``forward_cached_fused`` (dedup + weighted attention + precomputed cross
K/V) must therefore match ``forward_cached`` up to fp reassociation
(gated ≤1e-3; measured ~1e-6), and the Pallas kernel must match its XLA
twin.  int8 is a storage rung: per-channel weight fake-quantization with
fp32 compute, relative-error bounded.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.core import predictor, quant
from repro.core import standardize as std_mod
from repro.core.engine import BatchedPredictor, SimulationEngine
from repro.core.engine_config import PRECISIONS, EngineConfig
from repro.core.rt_cache import PAD_ROW_ID, RTCache
from repro.core.standardize import build_vocab, dedup_bucket, \
    dedupe_context_tokens
from repro.kernels.fused_serving import ops as wa_ops
from repro.isa import progen

VOCAB = build_vocab()
SMALL_CFG = get_config("capsim").replace(
    d_model=32, head_dim=8, d_ff=64, dtype="float32")
MIX = ["503.bwaves", "541.leela", "525.x264"]
SIM_KW = dict(interval_size=1_500, warmup=200, max_checkpoints=2,
              l_min=32, l_clip=32, l_token=16, batch_size=16,
              with_oracle=False)


@pytest.fixture(scope="module")
def params():
    return predictor.init_params(SMALL_CFG, jax.random.PRNGKey(0))


# --------------------------------------------------------------------- #
# context dedup
# --------------------------------------------------------------------- #

def test_dedup_bucket_ladder():
    assert [dedup_bucket(n, 360) for n in (1, 32, 33, 48, 49, 64, 65,
                                           96, 97, 128, 129)] == \
        [32, 32, 48, 48, 64, 64, 96, 96, 128, 128, 192]
    assert dedup_bucket(300, 360) == 360            # capped at M


def test_dedupe_context_tokens_preserves_multiset():
    rng = np.random.RandomState(0)
    ctx = rng.randint(0, 40, (16, 360)).astype(np.int32)
    uniq, counts = dedupe_context_tokens(ctx)
    assert uniq.shape == counts.shape
    assert uniq.dtype == np.int32 and counts.dtype == np.float32
    np.testing.assert_array_equal(counts.sum(1), 360.0)
    for i in range(ctx.shape[0]):
        got = {int(u): int(c) for u, c in zip(uniq[i], counts[i]) if c}
        want = dict(zip(*np.unique(ctx[i], return_counts=True)))
        assert got == {int(k): int(v) for k, v in want.items()}
    # unused slots carry id 0 / count 0
    assert (uniq[counts == 0] == 0).all()


def test_dedupe_explicit_bucket_too_small_raises():
    ctx = np.arange(64, dtype=np.int32)[None, :]
    with pytest.raises(ValueError, match="unique tokens > bucket"):
        dedupe_context_tokens(ctx, bucket=32)
    uniq, counts = dedupe_context_tokens(ctx, bucket=96)
    assert uniq.shape == (1, 96) and counts[0].sum() == 64


# --------------------------------------------------------------------- #
# weighted attention kernel
# --------------------------------------------------------------------- #

def _qkvw(rng, B=3, Sq=16, Skv=24, H=4, D=8):
    q = jnp.asarray(rng.standard_normal((B, Sq, H, D)), jnp.float32)
    k = jnp.asarray(rng.standard_normal((B, Skv, H, D)), jnp.float32)
    v = jnp.asarray(rng.standard_normal((B, Skv, H, D)), jnp.float32)
    w = jnp.asarray(rng.integers(0, 5, (B, Skv)), jnp.float32)
    w = w.at[:, 0].set(1.0)                     # at least one live key
    return q, k, v, w


def test_weighted_attention_replicates_duplicates():
    """weight-c attention over unique keys == plain attention over the
    physically duplicated keys: the dedup identity itself."""
    rng = np.random.default_rng(1)
    q, k, v, w = _qkvw(rng, Skv=8)
    # one multiplicity pattern for the whole batch so the duplicated
    # key/value tensors stack to a common Skv
    w = jnp.tile(w[:1], (w.shape[0], 1))
    reps = np.asarray(w, np.int32)
    k_dup = jnp.stack([jnp.repeat(k[b], reps[b], axis=0)
                       for b in range(k.shape[0])])
    v_dup = jnp.stack([jnp.repeat(v[b], reps[b], axis=0)
                       for b in range(v.shape[0])])
    ones = jnp.ones(k_dup.shape[:2], jnp.float32)
    out_u = wa_ops.weighted_attention_xla(q, k, v, w)
    out_d = wa_ops.weighted_attention_xla(q, k_dup, v_dup, ones)
    np.testing.assert_allclose(np.asarray(out_u), np.asarray(out_d),
                               rtol=2e-5, atol=2e-5)


def test_pallas_kernel_matches_xla_twin():
    rng = np.random.default_rng(2)
    q, k, v, w = _qkvw(rng, B=2, Sq=33, Skv=47)     # ragged, forces pad
    ref = wa_ops.weighted_attention_xla(q, k, v, w)
    out = wa_ops.weighted_attention(q, k, v, w, impl="pallas",
                                    interpret=True)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=2e-5, atol=2e-5)


def test_weighted_attention_zero_weight_keys_ignored():
    """Zero-weight (padding) keys must not contribute, in both impls:
    equivalent to slicing them away."""
    rng = np.random.default_rng(3)
    q, k, v, w = _qkvw(rng, B=2, Skv=24)
    w = w.at[:, 16:].set(0.0)
    ref = wa_ops.weighted_attention_xla(q, k[:, :16], v[:, :16],
                                        w[:, :16])
    for impl, kw in (("chunked", {}), ("pallas", {"interpret": True})):
        out = wa_ops.weighted_attention(q, k, v, w, impl=impl, **kw)
        np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                                   rtol=2e-5, atol=2e-5)


# --------------------------------------------------------------------- #
# fused forward vs the unfused RT path
# --------------------------------------------------------------------- #

def _fused_batch(params, rng, B=6, L=12):
    cprog = progen.build_benchmark("505.mcf").compiled()
    table = cprog.token_table(VOCAB, 16)
    cache = RTCache(params, SMALL_CFG, 16)
    ids = cache.ensure_rows(table, keys=cprog.token_row_keys(VOCAB, 16))
    pc = rng.integers(0, table.shape[0], (B, L)).astype(np.int32)
    mask = (rng.uniform(size=(B, L)) < 0.8).astype(np.float32)
    mask[:, 0] = 1.0
    rt_idx = np.where(mask > 0, ids[pc], PAD_ROW_ID).astype(np.int32)
    # realistic skew: few distinct ids, heavy duplication (the M=360
    # context row in deployment has ~64-128 uniques)
    ctx = rng.integers(1, 50, (B, SMALL_CFG.context_tokens)).astype(
        np.int32)
    return cache, rt_idx, ctx, mask


def test_forward_cached_fused_matches_forward_cached(params):
    rng = np.random.default_rng(4)
    cache, rt_idx, ctx, mask = _fused_batch(params, rng)
    ref = predictor.forward_cached(
        params, cache.table, {"rt_idx": jnp.asarray(rt_idx),
                              "context_tokens": jnp.asarray(ctx),
                              "clip_mask": jnp.asarray(mask)}, SMALL_CFG)
    uniq, counts = dedupe_context_tokens(ctx)
    plan = predictor.serving_plan(params, cache.table, SMALL_CFG)
    out = predictor.forward_cached_fused(
        params, plan, {"rt_idx": jnp.asarray(rt_idx),
                       "ctx_uniq": jnp.asarray(uniq),
                       "ctx_count": jnp.asarray(counts),
                       "clip_mask": jnp.asarray(mask)}, SMALL_CFG)
    rel = np.abs(np.asarray(out) - np.asarray(ref)) / np.maximum(
        np.abs(np.asarray(ref)), 1e-9)
    assert rel.max() < 1e-3                     # measured ~1e-6
    # bucket choice must not change the math, only the padding
    uniq2, counts2 = dedupe_context_tokens(
        ctx, bucket=dedup_bucket(SMALL_CFG.context_tokens,
                                 SMALL_CFG.context_tokens))
    out2 = predictor.forward_cached_fused(
        params, plan, {"rt_idx": jnp.asarray(rt_idx),
                       "ctx_uniq": jnp.asarray(uniq2),
                       "ctx_count": jnp.asarray(counts2),
                       "clip_mask": jnp.asarray(mask)}, SMALL_CFG)
    np.testing.assert_allclose(np.asarray(out2), np.asarray(out),
                               rtol=1e-5, atol=1e-5)


def test_engine_fused_matches_unfused_within_tolerance(params):
    runs = {}
    for fused in (False, True):
        eng = SimulationEngine.from_config(
            params, SMALL_CFG, VOCAB,
            EngineConfig(rt_cache=True, fused_serving=fused, **SIM_KW))
        eng.submit_names(MIX)
        runs[fused] = eng.run()
    for a, b in zip(runs[False], runs[True]):
        assert a.name == b.name and a.n_clips == b.n_clips
        rel = abs(b.predicted_cycles - a.predicted_cycles) / max(
            abs(a.predicted_cycles), 1e-9)
        assert rel < 1e-3, (a.name, rel)


def test_fused_without_rt_cache_rejected(params):
    with pytest.raises(ValueError, match="fused_serving requires"):
        EngineConfig(rt_cache=False, fused_serving=True)
    with pytest.raises(ValueError, match="fused_serving requires"):
        EngineConfig(use_context=False, fused_serving=True)
    with pytest.raises(ValueError, match="requires an RTCache"):
        BatchedPredictor(params, SMALL_CFG,
                         config=EngineConfig(fused_serving=True,
                                             batch_size=16))


# --------------------------------------------------------------------- #
# int8 quantization
# --------------------------------------------------------------------- #

def test_quantize_dequant_properties():
    rng = np.random.default_rng(5)
    w = jnp.asarray(rng.standard_normal((24, 16)), jnp.float32)
    qd = quant.quantize_dequant(w)
    # per-channel bound: |w - qd| <= absmax_channel / (2 * 127)
    bound = np.abs(np.asarray(w)).max(axis=0) / (2 * quant.Q_MAX)
    assert (np.abs(np.asarray(qd - w)) <= bound + 1e-7).all()
    # idempotent: already-on-grid values survive a second pass exactly
    np.testing.assert_array_equal(np.asarray(quant.quantize_dequant(qd)),
                                  np.asarray(qd))
    # 1-D leaves (biases, norm scales) pass through untouched
    b = jnp.asarray(rng.standard_normal(16), jnp.float32)
    np.testing.assert_array_equal(np.asarray(quant.quantize_dequant(b)),
                                  np.asarray(b))
    # all-zero channels stay exactly zero (no 0/0)
    z = jnp.zeros((8, 4), jnp.float32)
    np.testing.assert_array_equal(np.asarray(quant.quantize_dequant(z)),
                                  np.asarray(z))


def test_precision_ladder_names_in_sync():
    """EngineConfig's accepted precisions and the predictor's dtype map
    must name the same ladder."""
    assert set(p for p in PRECISIONS if p is not None) == \
        set(predictor.PRECISION_DTYPES)


def test_engine_int8_within_tolerance_and_composes_with_fused(params):
    base = EngineConfig(rt_cache=True, **SIM_KW)
    ref_eng = SimulationEngine.from_config(params, SMALL_CFG, VOCAB, base)
    ref_eng.submit_names(MIX)
    ref = ref_eng.run()
    # the quantization error bound is width-dependent: ~0.7% at the
    # full-scale d_model=128, a few % at this test's d_model=32
    for overrides in ({"precision": "int8"},
                      {"precision": "int8", "fused_serving": True}):
        eng = SimulationEngine.from_config(
            params, SMALL_CFG, VOCAB, base.replace(**overrides))
        eng.submit_names(MIX)
        for a, b in zip(ref, eng.run()):
            rel = abs(b.predicted_cycles - a.predicted_cycles) / max(
                abs(a.predicted_cycles), 1e-9)
            assert rel < 0.05, (a.name, overrides, rel)


def test_std_module_exports_dedupe():
    """serving path imports dedupe through the std_mod alias used by the
    engine dispatcher — keep the names wired."""
    assert std_mod.dedupe_context_tokens is dedupe_context_tokens
