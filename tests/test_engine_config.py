"""EngineConfig: the unified construction surface (PR 6 satellite).

Covers: validation + JSON round trip (including the nested
``SamplingConfig``), the retired PR-6 kwarg shims on all four entry
points (legacy keywords must raise ``TypeError`` pointing at
``EngineConfig``), and ``from_config`` equivalence.
"""
import warnings

import jax
import numpy as np
import pytest

from repro.configs import get_config
from repro.core import predictor
from repro.core import standardize as std_mod
from repro.core.engine import BatchedPredictor, SimulationEngine
from repro.core.engine_config import (EngineConfig, SamplingConfig,
                                      reject_legacy_kwargs)
from repro.core.simulate import capsim_simulate, capsim_simulate_multicore
from repro.isa import multicore, progen
from repro.serving.engine import PredictorEngine, Request

SMALL_CFG = get_config("capsim").replace(d_model=32, head_dim=8, d_ff=64,
                                         dtype="float32")
EC = EngineConfig(interval_size=1_000, warmup=100, max_checkpoints=1,
                  batch_size=16)


@pytest.fixture(scope="module")
def vocab():
    return std_mod.build_vocab()


@pytest.fixture(scope="module")
def params():
    return predictor.init_params(SMALL_CFG, jax.random.PRNGKey(0))


# ------------------------------ the dataclass ------------------------------ #

def test_defaults_unsharded():
    ec = EngineConfig()
    assert ec.mesh_shape == ()
    assert ec.n_shards == 0
    assert ec.rt_cache and ec.use_context and ec.with_oracle
    assert ec.sampling is None


def test_mesh_shape_normalization():
    assert EngineConfig(mesh_shape=4).mesh_shape == (4,)
    assert EngineConfig(mesh_shape=[2, 2]).mesh_shape == (2, 2)
    assert EngineConfig(mesh_shape=[2, 2]).n_shards == 4
    assert EngineConfig(mesh_shape=(1,)).n_shards == 1


def test_frozen():
    ec = EngineConfig()
    with pytest.raises(Exception):
        ec.batch_size = 8


@pytest.mark.parametrize("bad", [
    dict(mesh_shape=(0,)),
    dict(mesh_shape=(-2,)),
    dict(precision="fp16"),
    dict(batch_size=0),
    dict(batch_size=10, mesh_shape=(4,)),   # not divisible
    dict(multicore=-1),
    dict(peer_channels=True),               # needs multicore >= 1
    dict(quantum=0),
    dict(sampling=42),                      # not a SamplingConfig
])
def test_validate_rejects(bad):
    with pytest.raises(ValueError):
        EngineConfig(**bad)


def test_json_round_trip():
    ec = EngineConfig(mesh_shape=(8,), precision="bf16", batch_size=64,
                      multicore=2, quantum=32, peer_channels=True)
    assert EngineConfig.from_json(ec.to_json()) == ec
    # mesh_shape serializes as a list but round-trips to a tuple
    assert isinstance(ec.to_dict()["mesh_shape"], list)


# ------------------------------ SamplingConfig ------------------------------ #

def test_sampling_defaults_and_validation():
    sc = SamplingConfig()
    assert 0.0 < sc.fraction <= 1.0
    assert sc.strata >= 1 and sc.min_clips_per_stratum >= 1
    for bad in (dict(fraction=0.0), dict(fraction=1.5),
                dict(fraction=-0.1), dict(strata=0),
                dict(min_clips_per_stratum=0),
                dict(bootstrap_resamples=-1)):
        with pytest.raises(ValueError):
            SamplingConfig(**bad)


def test_sampling_json_round_trip():
    ec = EngineConfig(sampling=SamplingConfig(fraction=0.25, strata=3,
                                              seed=7, bootstrap_resamples=9))
    rt = EngineConfig.from_json(ec.to_json())
    assert rt == ec
    assert isinstance(rt.sampling, SamplingConfig)
    # sampling=None round-trips as None
    assert EngineConfig.from_json(EngineConfig().to_json()).sampling is None


def test_sampling_from_dict_rejects_unknown():
    with pytest.raises(ValueError, match="unknown SamplingConfig fields"):
        SamplingConfig.from_dict({"fractions": 0.1})
    with pytest.raises(ValueError):
        EngineConfig.from_dict(
            {"sampling": {"fraction": 0.1, "bogus": 1}})


def test_sampling_dict_normalizes_in_engine_config():
    ec = EngineConfig(sampling={"fraction": 0.5, "strata": 2})
    assert isinstance(ec.sampling, SamplingConfig)
    assert ec.sampling.fraction == 0.5 and ec.sampling.strata == 2


def test_from_dict_rejects_unknown():
    with pytest.raises(ValueError, match="unknown EngineConfig fields"):
        EngineConfig.from_dict({"batch_sized": 4})


# ------------------------------ retired shims ------------------------------ #

def test_reject_legacy_unknown_name_is_type_error():
    with pytest.raises(TypeError, match="unexpected keyword"):
        reject_legacy_kwargs({"batch_sized": 4}, "X")


def test_reject_legacy_known_field_points_at_config():
    with pytest.raises(TypeError, match="EngineConfig\\(batch_size=\\.\\.\\."):
        reject_legacy_kwargs({"batch_size": 8}, "X")
    reject_legacy_kwargs({}, "X")           # no kwargs -> no-op


def test_capsim_simulate_legacy_kwargs_raise(params, vocab):
    bench = progen.build_benchmark("505.mcf")
    with pytest.raises(TypeError, match="EngineConfig"):
        capsim_simulate(bench, params, SMALL_CFG, vocab,
                        interval_size=1_000, batch_size=16)


def test_capsim_simulate_multicore_legacy_kwargs_raise(params, vocab):
    mb = multicore.build_multicore_benchmark(
        list(multicore.MULTICORE_NAMES)[0], 2)
    with pytest.raises(TypeError, match="EngineConfig"):
        capsim_simulate_multicore(mb, params, SMALL_CFG, vocab,
                                  interval_size=1_000, batch_size=16)


def test_simulation_engine_legacy_kwargs_raise(params, vocab):
    with pytest.raises(TypeError, match="EngineConfig"):
        SimulationEngine(params, SMALL_CFG, vocab, batch_size=16)
    with pytest.raises(TypeError):
        SimulationEngine(params, SMALL_CFG, vocab, batch_sized=4)


def test_engine_construction_does_not_warn(params, vocab):
    bench = progen.build_benchmark("541.leela")
    with warnings.catch_warnings():
        warnings.simplefilter("error", DeprecationWarning)
        SimulationEngine.from_config(params, SMALL_CFG, vocab,
                                     EC).run([bench])


def test_batched_predictor_legacy_kwargs_raise(params, vocab):
    with pytest.raises(TypeError, match="EngineConfig"):
        BatchedPredictor(params, SMALL_CFG, batch_size=16)


def test_predictor_engine_legacy_kwargs_raise(params, vocab):
    with pytest.raises(TypeError, match="EngineConfig"):
        PredictorEngine(params, SMALL_CFG, batch_size=8)
    # the config path still serves
    rng = np.random.RandomState(1)
    tok = rng.randint(0, vocab.size, (4, 128, SMALL_CFG.clip_tokens)
                      ).astype(np.int32)
    ctx = rng.randint(0, vocab.size, (4, SMALL_CFG.context_tokens)
                      ).astype(np.int32)
    req = Request(0, tok, ctx, np.ones((4, 128), np.float32))
    eng = PredictorEngine.from_config(params, SMALL_CFG,
                                      EngineConfig(batch_size=8))
    eng.submit(req)
    res = eng.flush()[0]
    assert res.n_clips == 4 and res.clips_predicted == 4
    assert res.clips_extrapolated == 0 and res.cycles_ci is None


# ------------------------------ entry points ------------------------------ #

def test_peer_channels_reserved(params, vocab):
    ec = EC.replace(multicore=2, peer_channels=True)
    engine = SimulationEngine.from_config(params, SMALL_CFG, vocab, ec)
    mb = multicore.build_multicore_benchmark(
        list(multicore.MULTICORE_NAMES)[0], 2)
    with pytest.raises(NotImplementedError, match="peer_channels"):
        engine.run_multicore([mb])


def test_quantum_flows_from_config(params, vocab):
    mb = multicore.build_multicore_benchmark(
        list(multicore.MULTICORE_NAMES)[0], 2)
    ref = SimulationEngine.from_config(
        params, SMALL_CFG, vocab, EC).run_multicore(
            [mb], quantum=32)[0]
    via_cfg = SimulationEngine.from_config(
        params, SMALL_CFG, vocab,
        EC.replace(quantum=32)).run_multicore([mb])[0]
    assert via_cfg.predicted_cycles == ref.predicted_cycles
