"""EngineConfig: the unified construction surface (PR 6 satellite).

Covers: validation + JSON round trip, the deprecated kwarg shims on all
four entry points (warn AND produce the same engine behavior as the
config path), and ``from_config`` equivalence.
"""
import warnings

import jax
import numpy as np
import pytest

from repro.configs import get_config
from repro.core import predictor
from repro.core import standardize as std_mod
from repro.core.engine import BatchedPredictor, SimulationEngine
from repro.core.engine_config import EngineConfig, legacy_engine_config
from repro.core.simulate import capsim_simulate, capsim_simulate_multicore
from repro.isa import multicore, progen
from repro.serving.engine import PredictorEngine, Request

SMALL_CFG = get_config("capsim").replace(d_model=32, head_dim=8, d_ff=64,
                                         dtype="float32")
EC = EngineConfig(interval_size=1_000, warmup=100, max_checkpoints=1,
                  batch_size=16)


@pytest.fixture(scope="module")
def vocab():
    return std_mod.build_vocab()


@pytest.fixture(scope="module")
def params():
    return predictor.init_params(SMALL_CFG, jax.random.PRNGKey(0))


# ------------------------------ the dataclass ------------------------------ #

def test_defaults_unsharded():
    ec = EngineConfig()
    assert ec.mesh_shape == ()
    assert ec.n_shards == 0
    assert ec.rt_cache and ec.use_context and ec.with_oracle


def test_mesh_shape_normalization():
    assert EngineConfig(mesh_shape=4).mesh_shape == (4,)
    assert EngineConfig(mesh_shape=[2, 2]).mesh_shape == (2, 2)
    assert EngineConfig(mesh_shape=[2, 2]).n_shards == 4
    assert EngineConfig(mesh_shape=(1,)).n_shards == 1


def test_frozen():
    ec = EngineConfig()
    with pytest.raises(Exception):
        ec.batch_size = 8


@pytest.mark.parametrize("bad", [
    dict(mesh_shape=(0,)),
    dict(mesh_shape=(-2,)),
    dict(precision="fp16"),
    dict(batch_size=0),
    dict(batch_size=10, mesh_shape=(4,)),   # not divisible
    dict(multicore=-1),
    dict(peer_channels=True),               # needs multicore >= 1
    dict(quantum=0),
])
def test_validate_rejects(bad):
    with pytest.raises(ValueError):
        EngineConfig(**bad)


def test_json_round_trip():
    ec = EngineConfig(mesh_shape=(8,), precision="bf16", batch_size=64,
                      multicore=2, quantum=32, peer_channels=True)
    assert EngineConfig.from_json(ec.to_json()) == ec
    # mesh_shape serializes as a list but round-trips to a tuple
    assert isinstance(ec.to_dict()["mesh_shape"], list)


def test_from_dict_rejects_unknown():
    with pytest.raises(ValueError, match="unknown EngineConfig fields"):
        EngineConfig.from_dict({"batch_sized": 4})


def test_legacy_helper_unknown_name_is_type_error():
    with pytest.raises(TypeError, match="unexpected keyword"):
        legacy_engine_config(None, {"batch_sized": 4}, "X")


def test_legacy_helper_folds_and_warns():
    with pytest.warns(DeprecationWarning, match="EngineConfig"):
        ec = legacy_engine_config(EngineConfig(l_min=50),
                                  {"batch_size": 8}, "X")
    assert ec.batch_size == 8 and ec.l_min == 50


# ------------------------------ entry points ------------------------------ #

def test_capsim_simulate_shim_equivalent(params, vocab):
    bench = progen.build_benchmark("505.mcf")
    ref = capsim_simulate(bench, params, SMALL_CFG, vocab, EC)
    with pytest.warns(DeprecationWarning):
        shim = capsim_simulate(bench, params, SMALL_CFG, vocab,
                               interval_size=1_000, warmup=100,
                               max_checkpoints=1, batch_size=16)
    assert shim.predicted_cycles == ref.predicted_cycles
    assert shim.oracle_cycles == ref.oracle_cycles


def test_capsim_simulate_multicore_shim_equivalent(params, vocab):
    mb = multicore.build_multicore_benchmark(
        list(multicore.MULTICORE_NAMES)[0], 2)
    ref = capsim_simulate_multicore(mb, params, SMALL_CFG, vocab, EC)
    with pytest.warns(DeprecationWarning):
        shim = capsim_simulate_multicore(
            mb, params, SMALL_CFG, vocab, interval_size=1_000,
            warmup=100, max_checkpoints=1, batch_size=16)
    assert shim.predicted_cycles == ref.predicted_cycles
    assert [c.predicted_cycles for c in shim.cores] == \
        [c.predicted_cycles for c in ref.cores]


def test_simulation_engine_shim_and_from_config(params, vocab):
    bench = progen.build_benchmark("541.leela")
    ref = SimulationEngine.from_config(params, SMALL_CFG, vocab, EC)
    r_ref = ref.run([bench])[0]
    with pytest.warns(DeprecationWarning):
        shim = SimulationEngine(params, SMALL_CFG, vocab,
                                interval_size=1_000, warmup=100,
                                max_checkpoints=1, batch_size=16)
    assert shim.config == EC
    assert shim.run([bench])[0].predicted_cycles == r_ref.predicted_cycles
    # engine-internal BatchedPredictor construction must not warn
    with warnings.catch_warnings():
        warnings.simplefilter("error", DeprecationWarning)
        SimulationEngine.from_config(params, SMALL_CFG, vocab,
                                     EC).run([bench])


def test_simulation_engine_unknown_kwarg_raises(params, vocab):
    with pytest.raises(TypeError):
        SimulationEngine(params, SMALL_CFG, vocab, batch_sized=4)


def test_batched_predictor_shim(params, vocab):
    rng = np.random.RandomState(0)
    tok = rng.randint(0, vocab.size, (5, 128, SMALL_CFG.clip_tokens)
                      ).astype(np.int32)
    ctx = rng.randint(0, vocab.size, (5, SMALL_CFG.context_tokens)
                      ).astype(np.int32)
    mask = np.ones((5, 128), np.float32)
    ref = BatchedPredictor(params, SMALL_CFG,
                           config=EngineConfig(batch_size=16))
    ref.add(tok, ctx, mask)
    with pytest.warns(DeprecationWarning):
        shim = BatchedPredictor(params, SMALL_CFG, batch_size=16)
    shim.add(tok, ctx, mask)
    assert np.array_equal(shim.drain(), ref.drain())


def test_predictor_engine_shim(params, vocab):
    rng = np.random.RandomState(1)
    tok = rng.randint(0, vocab.size, (4, 128, SMALL_CFG.clip_tokens)
                      ).astype(np.int32)
    ctx = rng.randint(0, vocab.size, (4, SMALL_CFG.context_tokens)
                      ).astype(np.int32)
    req = Request(0, tok, ctx, np.ones((4, 128), np.float32))
    ref = PredictorEngine.from_config(params, SMALL_CFG,
                                      EngineConfig(batch_size=8))
    ref.submit(req)
    r_ref = ref.flush()[0]
    with pytest.warns(DeprecationWarning):
        shim = PredictorEngine(params, SMALL_CFG, batch_size=8)
    shim.submit(req)
    assert shim.flush()[0].total_cycles == r_ref.total_cycles


def test_peer_channels_reserved(params, vocab):
    ec = EC.replace(multicore=2, peer_channels=True)
    engine = SimulationEngine.from_config(params, SMALL_CFG, vocab, ec)
    mb = multicore.build_multicore_benchmark(
        list(multicore.MULTICORE_NAMES)[0], 2)
    with pytest.raises(NotImplementedError, match="peer_channels"):
        engine.run_multicore([mb])


def test_quantum_flows_from_config(params, vocab):
    mb = multicore.build_multicore_benchmark(
        list(multicore.MULTICORE_NAMES)[0], 2)
    ref = SimulationEngine.from_config(
        params, SMALL_CFG, vocab, EC).run_multicore(
            [mb], quantum=32)[0]
    via_cfg = SimulationEngine.from_config(
        params, SMALL_CFG, vocab,
        EC.replace(quantum=32)).run_multicore([mb])[0]
    assert via_cfg.predicted_cycles == ref.predicted_cycles
