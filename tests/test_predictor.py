"""CAPSim predictor + LSTM baseline model invariants."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.core import lstm_baseline, predictor

CFG = get_config("capsim").replace(
    d_model=32, head_dim=8, d_ff=64, dtype="float32",
    clip_tokens=16, context_tokens=36)


def _batch(B=4, L=8, rng=None):
    rng = rng or np.random.RandomState(0)
    return {
        "clip_tokens": jnp.asarray(
            rng.randint(1, CFG.vocab_size, (B, L, CFG.clip_tokens)),
            jnp.int32),
        "context_tokens": jnp.asarray(
            rng.randint(1, CFG.vocab_size, (B, CFG.context_tokens)),
            jnp.int32),
        "clip_mask": jnp.ones((B, L), jnp.float32),
        "time": jnp.asarray(rng.uniform(50, 400, (B,)), jnp.float32),
    }


def test_shapes_and_positivity():
    params = predictor.init_params(CFG, jax.random.PRNGKey(0))
    b = _batch()
    pred = predictor.predict_step(params, b, CFG)
    assert pred.shape == (4,)
    assert bool(jnp.all(pred > 0))          # softplus(CPI) * len > 0


def test_grads_finite_both_models():
    b = _batch()
    for mod in (predictor, lstm_baseline):
        params = mod.init_params(CFG, jax.random.PRNGKey(0))
        (loss, _), grads = jax.value_and_grad(
            lambda p: mod.mape_loss(p, b, CFG), has_aux=True)(params)
        assert np.isfinite(float(loss))
        assert all(np.isfinite(np.asarray(g)).all()
                   for g in jax.tree_util.tree_leaves(grads))


def test_clip_padding_is_ignored():
    """Appending masked-out instruction slots must not change predictions
    (cross-attention kv-mask + length normalization)."""
    params = predictor.init_params(CFG, jax.random.PRNGKey(0))
    rng = np.random.RandomState(1)
    b = _batch(B=2, L=6, rng=rng)
    padded = {
        "clip_tokens": jnp.concatenate(
            [b["clip_tokens"],
             jnp.zeros((2, 4, CFG.clip_tokens), jnp.int32)], axis=1),
        "context_tokens": b["context_tokens"],
        "clip_mask": jnp.concatenate(
            [b["clip_mask"], jnp.zeros((2, 4), jnp.float32)], axis=1),
    }
    p1 = predictor.predict_step(params, b, CFG)
    p2 = predictor.predict_step(params, padded, CFG)
    np.testing.assert_allclose(np.asarray(p1), np.asarray(p2), rtol=2e-4)


def test_instruction_order_matters():
    """Positional encoding: permuting the clip's instructions must change
    the prediction (execution order matters, §II-B)."""
    params = predictor.init_params(CFG, jax.random.PRNGKey(0))
    b = _batch(B=1, L=8)
    flipped = dict(b)
    flipped["clip_tokens"] = b["clip_tokens"][:, ::-1]
    p1 = float(predictor.predict_step(params, b, CFG)[0])
    p2 = float(predictor.predict_step(params, flipped, CFG)[0])
    assert abs(p1 - p2) > 1e-6


def test_context_changes_prediction():
    params = predictor.init_params(CFG, jax.random.PRNGKey(0))
    b = _batch(B=2, L=6)
    b2 = dict(b)
    b2["context_tokens"] = (b["context_tokens"] + 7) % CFG.vocab_size
    p1 = predictor.predict_step(params, b, CFG)
    p2 = predictor.predict_step(params, b2, CFG)
    assert float(jnp.max(jnp.abs(p1 - p2))) > 1e-6
    # and the no-context ablation is invariant to it
    a1 = predictor.predict_step(params, b, CFG, use_context=False)
    a2 = predictor.predict_step(params, b2, CFG, use_context=False)
    np.testing.assert_allclose(np.asarray(a1), np.asarray(a2))


def test_pallas_attention_path_matches_xla():
    params = predictor.init_params(CFG, jax.random.PRNGKey(0))
    b = _batch(B=2, L=8)
    px = predictor.predict_step(params, b, CFG)
    pp = predictor.predict_step(params, b, CFG.replace(attn_impl="pallas"))
    np.testing.assert_allclose(np.asarray(px), np.asarray(pp),
                               rtol=2e-4, atol=2e-4)


def test_mape_loss_zero_when_exact():
    b = _batch(B=2, L=4)
    params = predictor.init_params(CFG, jax.random.PRNGKey(0))
    pred = predictor.predict_step(params, b, CFG)
    b_exact = dict(b)
    b_exact["time"] = pred
    loss, aux = predictor.mape_loss(params, b_exact, CFG)
    assert float(loss) < 1e-5
