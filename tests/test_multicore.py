"""Multi-core trace simulation subsystem invariants.

The tentpole contracts:

  * ``run_multicore`` is deterministic, and at N=1 degenerates bitwise
    to plain ``run_compiled`` (trace AND snapshots);
  * sharded (disjoint-memory) cores are invariant under core count and
    scheduling order, while shared-memory writes ARE visible across
    cores under the deterministic interleave;
  * ``timing.simulate_multicore`` at N=1 is bitwise equal to
    ``simulate_columnar`` (the shared LLC / bus penalties key on
    cross-core interference only);
  * the engine's (benchmark, core) shards through the pooled predictor
    demux to per-core cycles bitwise equal to the per-core sequential
    path, and the RT cache is shared across cores of one program.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.core import context as ctx_mod
from repro.core import predictor
from repro.core import standardize as std_mod
from repro.core.engine import SimulationEngine
from repro.core.engine_config import EngineConfig
from repro.core.standardize import CORE, build_vocab
from repro.isa import funcsim, multicore, progen, timing
from repro.isa.compiled import IREG_SLOT, compile_program
from repro.isa.funcsim import CompiledState
from repro.isa.isa import Instruction

I = Instruction
VOCAB = build_vocab()
SMALL_CFG = get_config("capsim").replace(
    d_model=32, head_dim=8, d_ff=64, dtype="float32")
SIM_KW = dict(interval_size=1_200, warmup=150, max_checkpoints=2,
              l_min=32, l_clip=32, l_token=16, batch_size=16,
              with_oracle=True)
SIM_EC = EngineConfig(**SIM_KW)


@pytest.fixture(scope="module")
def params():
    return predictor.init_params(SMALL_CFG, jax.random.PRNGKey(0))


def _traces_equal(a, b):
    return (np.array_equal(a.pc, b.pc) and np.array_equal(a.ea, b.ea)
            and np.array_equal(a.taken, b.taken)
            and np.array_equal(a.snapshots, b.snapshots))


# --------------------------------------------------------------------------- #
# run_multicore: determinism, N=1 anchor, permutation invariance
# --------------------------------------------------------------------------- #

def test_n1_bitwise_equals_run_compiled():
    """One core through the quantum scheduler == one plain run_compiled
    call: pc/ea/taken columns and snapshot rows, bit for bit — across
    resumed quanta and a non-dividing snapshot stride."""
    for kind in multicore.MULTICORE_NAMES:
        mb = multicore.build_multicore_benchmark(kind, 1)
        mt = multicore.run_multicore(mb.compiled(), 2_000,
                                     mb.fresh_states(),
                                     snapshot_every=100, quantum=64)
        ref, _ = funcsim.run_compiled(
            multicore.build_multicore_benchmark(kind, 1).compiled()[0],
            2_000, mb.fresh_states()[0], snapshot_every=100)
        assert _traces_equal(mt.cores[0], ref), kind
        assert sum(n for _, n in mt.schedule) == len(ref)


def test_interleave_deterministic_across_runs():
    """Same inputs -> identical per-core traces and schedule, including
    the contention kernel whose loads see other cores' stores."""
    mb = multicore.build_multicore_benchmark("mt.counter", 4)
    a = multicore.run_multicore(mb.compiled(), 1_500, mb.fresh_states(),
                                snapshot_every=50)
    b = multicore.run_multicore(mb.compiled(), 1_500, mb.fresh_states(),
                                snapshot_every=50)
    assert a.schedule == b.schedule
    for ta, tb in zip(a.cores, b.cores):
        assert _traces_equal(ta, tb)


def test_sharded_traces_invariant_under_core_count_and_order():
    """Sharded stream/chase cores touch disjoint memory, so core i's
    trace must not change when (a) more cores join or (b) the round-robin
    visit order is permuted."""
    for kind in ("mt.stream", "mt.chase"):
        mb2 = multicore.build_multicore_benchmark(kind, 2)
        mb4 = multicore.build_multicore_benchmark(kind, 4)
        t2 = multicore.run_multicore(mb2.compiled(), 1_200,
                                     mb2.fresh_states(),
                                     snapshot_every=50)
        t4 = multicore.run_multicore(mb4.compiled(), 1_200,
                                     mb4.fresh_states(),
                                     snapshot_every=50)
        for c in range(2):
            assert _traces_equal(t2.cores[c], t4.cores[c]), (kind, c)
        perm = multicore.run_multicore(mb4.compiled(), 1_200,
                                       mb4.fresh_states(),
                                       snapshot_every=50,
                                       core_order=[3, 1, 0, 2])
        for c in range(4):
            assert _traces_equal(t4.cores[c], perm.cores[c]), (kind, c)


def test_shared_memory_conflict_visibility():
    """A store committed in core 0's quantum is architecturally visible
    to core 1's load in the SAME round (order [0, 1]), and to core 0 in
    the NEXT round when the order is reversed."""
    addr = 0x9000
    writer = compile_program([
        I("addi", dsts=("R3",), imm=addr),
        I("addi", dsts=("R4",), imm=42),
        I("std", srcs=("R4",), mem_base="R3", mem_offset=0),
        I("nop"),
        I("b", target=3),                  # spin
    ])
    reader = compile_program([
        I("addi", dsts=("R3",), imm=addr),
        I("ld", dsts=("R5",), mem_base="R3", mem_offset=0),
        I("b", target=1),                  # keep re-loading
    ])
    mem = {}
    states = [CompiledState(iregs=[0] * 40, fregs=[0.0] * 32, mem=mem)
              for _ in range(2)]
    multicore.run_multicore([writer, reader], 8, states, quantum=4)
    # writer ran its quantum first: reader's very first ld sees the store
    assert states[1].iregs[IREG_SLOT["R5"]] == 42
    assert mem[addr >> 3] == 42

    # reversed order: the reader's first quantum predates the store,
    # its later quanta observe it — visibility is by commit interleave
    mem2 = {}
    states2 = [CompiledState(iregs=[0] * 40, fregs=[0.0] * 32, mem=mem2)
               for _ in range(2)]
    mt = multicore.run_multicore([writer, reader], 8, states2, quantum=4,
                                 core_order=[1, 0])
    reader_tr = mt.cores[1]
    assert states2[1].iregs[IREG_SLOT["R5"]] == 42
    assert len(reader_tr) == 8


def test_shared_counter_increments_accumulate():
    """All cores hammer MT_COUNTER_EA: the final counter must exceed
    anything a single core could have produced alone (cross-core writes
    visible), yet stay <= the total increments committed."""
    mb = multicore.build_multicore_benchmark("mt.counter", 4)
    states = mb.fresh_states()
    multicore.run_multicore(mb.compiled(), 2_000, states)
    counter = states[0].mem[progen.MT_COUNTER_EA >> 3]
    mb1 = multicore.build_multicore_benchmark("mt.counter", 1)
    states1 = mb1.fresh_states()
    multicore.run_multicore(mb1.compiled(), 2_000, states1)
    solo = states1[0].mem[progen.MT_COUNTER_EA >> 3]
    assert counter > solo
    assert counter <= 4 * 2_000


# --------------------------------------------------------------------------- #
# Multicore timing oracle
# --------------------------------------------------------------------------- #

def test_oracle_n1_bitwise_equals_simulate_columnar():
    """The shared LLC / bus penalties key on OTHER cores, so at N=1 the
    multicore oracle must reproduce simulate_columnar bit for bit."""
    for kind in multicore.MULTICORE_NAMES:
        mb = multicore.build_multicore_benchmark(kind, 1)
        mt = multicore.run_multicore(mb.compiled(), 2_000,
                                     mb.fresh_states(), quantum=48)
        ref, _ = funcsim.run_compiled(
            multicore.build_multicore_benchmark(kind, 1).compiled()[0],
            2_000, mb.fresh_states()[0])
        got = timing.simulate_multicore(mt.cores, mt.schedule)[0]
        want = timing.simulate_columnar(ref)
        np.testing.assert_array_equal(got, want, err_msg=kind)


def test_oracle_cross_core_contention_slows_cores():
    """Chase cores at N=4 share LLC slots and the bus: at least one core
    must commit strictly later than the same core running alone."""
    mb4 = multicore.build_multicore_benchmark("mt.chase", 4)
    mt4 = multicore.run_multicore(mb4.compiled(), 1_500,
                                  mb4.fresh_states())
    tot4 = timing.total_cycles_multicore(mt4.cores, mt4.schedule)
    mb1 = multicore.build_multicore_benchmark("mt.chase", 1)
    mt1 = multicore.run_multicore(mb1.compiled(), 1_500,
                                  mb1.fresh_states())
    tot1 = timing.total_cycles_multicore(mt1.cores, mt1.schedule)
    assert max(tot4) > tot1[0]


def test_oracle_rejects_overrunning_schedule():
    mb = multicore.build_multicore_benchmark("mt.stream", 2)
    mt = multicore.run_multicore(mb.compiled(), 500, mb.fresh_states())
    bad = mt.schedule + [(0, 1)]
    with pytest.raises(AssertionError):
        timing.simulate_multicore(mt.cores, bad)


# --------------------------------------------------------------------------- #
# Core-id context channel
# --------------------------------------------------------------------------- #

def test_core_id_context_channel():
    snaps = np.arange(8 * 40, dtype=np.uint64).reshape(8, 40)
    base = ctx_mod.context_tokens_from_matrix(snaps, VOCAB)
    assert base.shape == (8, ctx_mod.CONTEXT_LEN)
    tagged = ctx_mod.context_tokens_from_matrix(snaps, VOCAB, core_id=3)
    assert tagged.shape == (8, ctx_mod.MULTICORE_CONTEXT_LEN)
    # prefix unchanged bit for bit; channel = <CORE> + big-endian bytes
    np.testing.assert_array_equal(tagged[:, :ctx_mod.CONTEXT_LEN], base)
    chan = tagged[0, ctx_mod.CONTEXT_LEN:]
    assert chan[0] == VOCAB[CORE]
    np.testing.assert_array_equal(
        chan, ctx_mod.core_id_tokens(3, VOCAB))
    assert chan[-1] == VOCAB[std_mod.BYTE_TOKENS[3]]
    # different cores -> different contexts (only the channel differs)
    other = ctx_mod.context_tokens_from_matrix(snaps, VOCAB, core_id=1)
    assert not np.array_equal(tagged, other)
    np.testing.assert_array_equal(other[:, :ctx_mod.CONTEXT_LEN], base)


# --------------------------------------------------------------------------- #
# Engine: (benchmark, core) shard demux + RT-cache sharing
# --------------------------------------------------------------------------- #

def _sequential_core_reference(mb, params, *, interval_size,
                               max_checkpoints, l_min, l_clip, l_token,
                               batch_size, warmup, with_oracle):
    """Per-(core, checkpoint) monolithic predict loops over the same
    interleaved front-end — the engine demux's bitwise reference."""
    predict = jax.jit(
        lambda p, b: predictor.predict_step(p, b, SMALL_CFG))
    cprogs = mb.compiled()
    tables = [cp.token_table(VOCAB, l_token) for cp in cprogs]
    states = mb.fresh_states()
    if warmup:
        multicore.run_multicore(cprogs, warmup, states)
    totals = [0.0] * mb.n_cores
    clips = [0] * mb.n_cores
    for _ in range(min(mb.ckp_num, max_checkpoints)):
        mtrace = multicore.run_multicore(
            cprogs, interval_size, states, snapshot_every=l_min)
        if len(mtrace) == 0:
            break
        for c, trace in enumerate(mtrace.cores):
            if not len(trace):
                continue
            tok, mask = std_mod.encode_fixed_clips(
                tables[c], trace.pc, l_min, l_clip)
            ctx_all = ctx_mod.context_tokens_from_matrix(
                trace.snapshots, VOCAB, core_id=c)
            rows = np.minimum(np.arange(tok.shape[0]), len(ctx_all) - 1)
            ctx = ctx_all[rows]
            k = tok.shape[0]
            pad = (-k) % batch_size
            if pad:
                tok = np.concatenate(
                    [tok, np.zeros((pad,) + tok.shape[1:], tok.dtype)])
                ctx = np.concatenate(
                    [ctx, np.zeros((pad,) + ctx.shape[1:], ctx.dtype)])
                mask = np.concatenate(
                    [mask, np.zeros((pad,) + mask.shape[1:],
                                    mask.dtype)])
            preds = []
            for lo in range(0, tok.shape[0], batch_size):
                batch = {
                    "clip_tokens": jnp.asarray(tok[lo:lo + batch_size]),
                    "context_tokens":
                        jnp.asarray(ctx[lo:lo + batch_size]),
                    "clip_mask": jnp.asarray(mask[lo:lo + batch_size])}
                preds.append(np.asarray(predict(params, batch)))
            totals[c] += float(np.concatenate(preds)[:k].sum())
            clips[c] += k
    return totals, clips


@pytest.fixture(scope="module")
def mc_engine_results(params):
    mbenches = [multicore.build_multicore_benchmark("mt.mix", 2),
                multicore.build_multicore_benchmark("mt.chase", 3)]
    engine = SimulationEngine(params, SMALL_CFG, VOCAB, SIM_EC)
    return mbenches, engine.run_multicore(mbenches), engine


def test_engine_demux_bitwise_equals_sequential(params, mc_engine_results):
    """(benchmark, core) shards pooled into shared (remainder-padded)
    device batches demux back to per-core and summed cycles bitwise equal
    to the per-core sequential loops."""
    mbenches, results, engine = mc_engine_results
    assert engine.last_stats.n_pad > 0        # remainder padding exercised
    for mb, r in zip(mbenches, results):
        ref, ref_clips = _sequential_core_reference(
            mb, params, **SIM_KW)
        assert r.n_cores == mb.n_cores == len(r.cores)
        for c, cr in enumerate(r.cores):
            assert cr.n_clips == ref_clips[c]
            assert cr.predicted_cycles == ref[c], (cr.name, c)
        summed = 0.0
        for v in ref:
            summed += v
        assert r.predicted_cycles == summed


def test_engine_clip_conservation(mc_engine_results):
    mbenches, results, engine = mc_engine_results
    total = sum(cr.n_clips for r in results for cr in r.cores)
    assert engine.last_stats.n_clips == total
    assert engine.last_stats.n_predicted == total
    for r in results:
        assert r.n_clips == sum(cr.n_clips for cr in r.cores)
        assert r.oracle_cycles == sum(cr.oracle_cycles for cr in r.cores)
        for cr in r.cores:
            assert cr.oracle_cycles > 0


def test_rt_cache_shared_across_cores(params):
    """All cores of one multi-threaded program share a token table
    (immediates collapse to <CONST>), so adding cores must not add RT
    rows — and a 4-core run encodes exactly what a 1-core run does."""
    ec = SIM_EC.replace(with_oracle=False)
    e1 = SimulationEngine(params, SMALL_CFG, VOCAB, ec)
    e1.run_multicore([multicore.build_multicore_benchmark("mt.mix", 1)])
    rows1 = e1.last_rt_stats.n_rows_encoded
    e4 = SimulationEngine(params, SMALL_CFG, VOCAB, ec)
    e4.run_multicore([multicore.build_multicore_benchmark("mt.mix", 4)])
    rows4 = e4.last_rt_stats.n_rows_encoded
    assert rows1 == rows4
    assert e4.last_rt_stats.n_rows_served > \
        e1.last_rt_stats.n_rows_served


def test_multicore_benchmark_shared_state():
    mb = multicore.build_multicore_benchmark("mt.mix", 3)
    states = mb.fresh_states()
    assert len(states) == 3
    assert all(st.mem is states[0].mem for st in states)
    assert states[0].mem[progen.MT_COUNTER_EA >> 3] == 0
    with pytest.raises(ValueError):
        multicore.build_multicore_benchmark("mt.nope", 2)
    with pytest.raises(ValueError):
        multicore.build_multicore_benchmark("mt.mix", 0)
