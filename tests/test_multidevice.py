"""Multi-device NUMERICAL validation of the shard_map paths.

The main pytest process is locked to 1 CPU device (jax fixes the device
count at first init), so this file launches a subprocess with
``--xla_force_host_platform_device_count=8`` and compares, on a real
(2, 4) = (data, model) mesh:

  - MoE expert-parallel dispatch (shard_map) vs the meshless reference,
  - flash-decoding (sequence-sharded cache psum merge) vs full attention,
  - sequence-parallel prefill attention vs the single-device chunked path.

These are the distribution paths the dry-run exercises only structurally;
here they must agree numerically across 8 shards.
"""
import subprocess
import sys

PROGRAM = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import numpy as np
import jax
import jax.numpy as jnp

from repro.configs import get_smoke_config
from repro.distributed.sharding import (LOGICAL_RULES_DECODE,
                                        LOGICAL_RULES_TRAIN,
                                        use_mesh_and_rules)
from repro.models import moe as moe_mod
from repro.models.attention import (_causal_attention_chunked, flash_decode,
                                    sp_prefill_attention)
from repro.models.layers import init_from_specs

assert len(jax.devices()) == 8, jax.devices()
from repro.launch.mesh import make_mesh_compat
mesh = make_mesh_compat((2, 4), ("data", "model"))
rng = np.random.RandomState(0)

# ---------------- MoE: shard_map EP vs meshless reference ----------------
cfg = get_smoke_config("llama4-maverick-400b-a17b").replace(
    num_experts=8, experts_per_token=1, capacity_factor=4.0)
params = init_from_specs(moe_mod.moe_specs(cfg), jax.random.PRNGKey(1),
                         "float32")
x = jnp.asarray(rng.randn(4, 8, cfg.d_model).astype(np.float32))
with use_mesh_and_rules(mesh, LOGICAL_RULES_TRAIN), mesh:
    y_mesh, lb_m, z_m = jax.jit(
        lambda p, a: moe_mod.moe_forward(p, a, cfg))(params, x)
with use_mesh_and_rules(None, None):
    y_ref, lb_r, z_r = moe_mod.moe_forward(params, x, cfg)
np.testing.assert_allclose(np.asarray(y_mesh), np.asarray(y_ref),
                           rtol=2e-4, atol=2e-5)
np.testing.assert_allclose(float(lb_m), float(lb_r), rtol=1e-4)
np.testing.assert_allclose(float(z_m), float(z_r), rtol=1e-4)
print("moe EP OK")

# ------------- flash decode: seq-sharded cache vs full attention ----------
acfg = get_smoke_config("qwen3-4b")
B, S = 4, 64
H, KV, Dh = acfg.num_heads, acfg.num_kv_heads, acfg.head_dim
q = jnp.asarray(rng.randn(B, 1, H, Dh).astype(np.float32))
kc = jnp.asarray(rng.randn(B, S, KV, Dh).astype(np.float32))
vc = jnp.asarray(rng.randn(B, S, KV, Dh).astype(np.float32))
pos = jnp.int32(37)
with use_mesh_and_rules(mesh, LOGICAL_RULES_DECODE), mesh:
    o_mesh = jax.jit(lambda *a: flash_decode(*a, acfg))(q, kc, vc, pos)
with use_mesh_and_rules(None, None):
    o_ref = flash_decode(q, kc, vc, pos, acfg)   # unsharded fallback path
np.testing.assert_allclose(np.asarray(o_mesh), np.asarray(o_ref),
                           rtol=2e-4, atol=2e-5)
print("flash decode OK")

# ------------- SP prefill attention vs single-device chunked --------------
from repro.distributed.sharding import LOGICAL_RULES_PREFILL_SP
B2, S2, H2, D2 = 2, 32, 4, 16
qq = jnp.asarray(rng.randn(B2, S2, H2, D2).astype(np.float32))
kk = jnp.asarray(rng.randn(B2, S2, 2, D2).astype(np.float32))
vv = jnp.asarray(rng.randn(B2, S2, 2, D2).astype(np.float32))
scfg = acfg.replace(num_heads=H2, num_kv_heads=2, head_dim=D2,
                    attn_chunk=8)
with use_mesh_and_rules(mesh, LOGICAL_RULES_PREFILL_SP), mesh:
    o_sp = jax.jit(lambda *a: sp_prefill_attention(*a, scfg))(qq, kk, vv)
kb = jnp.repeat(kk, H2 // 2, axis=2)
vb = jnp.repeat(vv, H2 // 2, axis=2)
o_full = _causal_attention_chunked(qq, kb, vb, 8)
np.testing.assert_allclose(np.asarray(o_sp), np.asarray(o_full),
                           rtol=2e-4, atol=2e-5)
print("sp prefill OK")
print("ALL MULTIDEVICE CHECKS PASSED")
"""


def test_multidevice_numerics():
    r = subprocess.run([sys.executable, "-c", PROGRAM], capture_output=True,
                       text=True, timeout=500,
                       env={**__import__("os").environ,
                            "PYTHONPATH": "src"})
    assert "ALL MULTIDEVICE CHECKS PASSED" in r.stdout, \
        f"stdout:\n{r.stdout}\nstderr:\n{r.stderr[-3000:]}"
