"""Train-step factory: microbatching, clipping, compression, schedules,
checkpoint roundtrip + crash-restart."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint.ckpt import CheckpointManager, latest_step, restore, \
    save
from repro.distributed.compression import compress_decompress, \
    init_error_feedback
from repro.distributed.fault_tolerance import ResilientTrainer, \
    StragglerMonitor
from repro.training.train_loop import (TrainConfig, init_train_state,
                                       make_train_step)


def _quadratic_loss(params, batch):
    pred = batch["x"] @ params["w"] + params["b"]
    loss = jnp.mean((pred - batch["y"]) ** 2)
    return loss, {"ce": loss, "lb": jnp.zeros(()), "z": jnp.zeros(())}


def _setup(optimizer="sgdm", **kw):
    tcfg = TrainConfig(optimizer=optimizer, base_lr=0.05, warmup_steps=0,
                       total_steps=100, **kw)
    params = {"w": jnp.zeros((3, 1)), "b": jnp.zeros((1,))}
    state = init_train_state(params, tcfg)
    step = make_train_step(_quadratic_loss, tcfg)
    rng = np.random.RandomState(0)
    x = rng.randn(8, 3).astype(np.float32)
    w_true = np.array([[1.0], [-2.0], [0.5]], np.float32)
    y = x @ w_true + 0.3
    batch = {"x": jnp.asarray(x), "y": jnp.asarray(y)}
    return tcfg, state, jax.jit(step), batch


def test_sgd_converges():
    _, state, step, batch = _setup()
    for _ in range(150):
        state, m = step(state, batch)
    assert float(m["loss"]) < 1e-2


def test_adamw_state_and_convergence():
    _, state, step, batch = _setup("adamw")
    assert "nu" in state["opt"]
    for _ in range(150):
        state, m = step(state, batch)
    assert float(m["loss"]) < 5e-2


def test_microbatch_equivalence():
    """Gradient accumulation over 4 microbatches == single big batch."""
    tcfg1, s1, step1, batch = _setup()
    tcfg4 = TrainConfig(optimizer="sgdm", base_lr=0.05, warmup_steps=0,
                        total_steps=100, microbatches=4)
    s4 = init_train_state({"w": jnp.zeros((3, 1)), "b": jnp.zeros((1,))},
                          tcfg4)
    step4 = jax.jit(make_train_step(_quadratic_loss, tcfg4))
    s1b, m1 = step1(s1, batch)
    s4b, m4 = step4(s4, batch)
    np.testing.assert_allclose(np.asarray(s1b["params"]["w"]),
                               np.asarray(s4b["params"]["w"]),
                               rtol=1e-5, atol=1e-6)
    np.testing.assert_allclose(float(m1["loss"]), float(m4["loss"]),
                               rtol=1e-5)


def test_grad_clipping_bounds_update():
    tcfg = TrainConfig(optimizer="sgdm", base_lr=1.0, grad_clip=1e-3,
                       warmup_steps=0, total_steps=10)
    params = {"w": jnp.zeros((3, 1)), "b": jnp.zeros((1,))}
    state = init_train_state(params, tcfg)
    step = jax.jit(make_train_step(_quadratic_loss, tcfg))
    batch = {"x": jnp.ones((4, 3)) * 100, "y": jnp.ones((4, 1)) * 1e6}
    state, m = step(state, batch)
    upd = float(jnp.max(jnp.abs(state["params"]["w"])))
    assert upd <= 1.1e-3 * tcfg.base_lr * 10  # clipped global norm


def test_compression_error_feedback():
    """int8 quantization with error feedback: deq + residual == g exactly,
    residual bounded by half a quantization step, and the residual is
    consumed on the next step."""
    g = {"w": jnp.asarray(np.linspace(-1, 1, 64).reshape(8, 8),
                          jnp.float32)}
    err = init_error_feedback(g)
    cg, new_err = compress_decompress(g, err)
    np.testing.assert_allclose(np.asarray(cg["w"]) + np.asarray(new_err["w"]),
                               np.asarray(g["w"]), rtol=0, atol=1e-6)
    scale = float(jnp.max(jnp.abs(g["w"]))) / 127.0
    assert float(jnp.max(jnp.abs(new_err["w"]))) <= scale / 2 + 1e-6
    # second step folds the residual in: error never accumulates unboundedly
    cg2, err2 = compress_decompress(g, new_err)
    np.testing.assert_allclose(
        np.asarray(cg2["w"]) + np.asarray(err2["w"]),
        np.asarray(g["w"]) + np.asarray(new_err["w"]), rtol=0, atol=1e-6)


def test_checkpoint_roundtrip(tmp_path):
    _, state, step, batch = _setup()
    state, _ = step(state, batch)
    save(state, 1, str(tmp_path))
    assert latest_step(str(tmp_path)) == 1
    like = jax.tree_util.tree_map(
        lambda a: jax.ShapeDtypeStruct(a.shape, a.dtype), state)
    restored = restore(like, 1, str(tmp_path))
    for a, b in zip(jax.tree_util.tree_leaves(state),
                    jax.tree_util.tree_leaves(restored)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_resilient_trainer_resumes(tmp_path):
    tcfg, state, step, batch = _setup()

    def make_trainer():
        return ResilientTrainer(
            step_fn=step, ckpt=CheckpointManager(str(tmp_path), keep=2,
                                                 async_save=False),
            save_every=5)

    def batches(n):
        for _ in range(n):
            yield batch

    # first run: 7 steps -> checkpoints at 5 and (drain) 7
    s1, n1 = make_trainer().run(state, batches(7), total_steps=7)
    assert n1 == 7 and latest_step(str(tmp_path)) == 7
    # second run resumes from 7 and continues to 12
    s2, n2 = make_trainer().run(state, batches(50), total_steps=12,
                                state_like=state)
    assert n2 == 12
    # loss keeps improving across the restart
    _, m1 = step(s1, batch)
    _, m2 = step(s2, batch)
    assert float(m2["loss"]) <= float(m1["loss"])


def test_checkpoint_gc_keeps_k(tmp_path):
    _, state, step, batch = _setup()
    mgr = CheckpointManager(str(tmp_path), keep=2, async_save=False)
    for s in (1, 2, 3, 4):
        mgr.save(state, s)
    steps = sorted(int(d.name[5:]) for d in tmp_path.iterdir()
                   if d.name.startswith("step_"))
    assert steps == [3, 4]


def test_straggler_monitor():
    mon = StragglerMonitor(n_hosts=4)
    for _ in range(10):
        for h, t in enumerate([1.0, 1.05, 0.95, 2.5]):
            mon.record(h, t)
    assert mon.stragglers() == [3]
    w = mon.rebalance()
    assert w[3] < 0.6 and abs(float(w.sum()) - 4.0) < 1e-6


def test_signal_handlers_chain_and_restore(tmp_path):
    import signal

    _, state, step, batch = _setup()
    trainer = ResilientTrainer(
        step_fn=step, ckpt=CheckpointManager(str(tmp_path), keep=2,
                                             async_save=False))
    seen = []
    prev_term = signal.getsignal(signal.SIGTERM)
    prev_int = signal.getsignal(signal.SIGINT)
    signal.signal(signal.SIGTERM, lambda s, f: seen.append(s))
    try:
        trainer.install_signal_handler()
        trainer.install_signal_handler()          # idempotent
        # SIGTERM: preemption flagged AND the launcher's hook still ran
        signal.raise_signal(signal.SIGTERM)
        assert trainer._preempted and seen == [signal.SIGTERM]
        # SIGINT is preemption too — graceful drain, NOT KeyboardInterrupt
        trainer._preempted = False
        signal.raise_signal(signal.SIGINT)
        assert trainer._preempted
        trainer.uninstall_signal_handler()
        # pre-install handlers are back (ours for TERM, python's for INT)
        signal.raise_signal(signal.SIGTERM)
        assert seen == [signal.SIGTERM, signal.SIGTERM]
        assert signal.getsignal(signal.SIGINT) is prev_int
    finally:
        signal.signal(signal.SIGTERM, prev_term)
        signal.signal(signal.SIGINT, prev_int)


def test_preemption_drains_and_run_restores_handlers(tmp_path):
    import signal

    _, state, step, batch = _setup()
    trainer = ResilientTrainer(
        step_fn=step, ckpt=CheckpointManager(str(tmp_path), keep=2,
                                             async_save=False),
        save_every=1000)                          # only the drain saves
    prev_int = signal.getsignal(signal.SIGINT)

    def batches():
        yield batch
        yield batch
        signal.raise_signal(signal.SIGINT)        # preempt mid-run
        yield batch
        yield batch

    _, n = trainer.run(state, batches(), total_steps=100)
    # the third step saw the flag: loop broke, drain checkpoint landed
    assert n == 2
    assert latest_step(str(tmp_path)) == 2
    # run() uninstalled its handlers on the way out
    assert signal.getsignal(signal.SIGINT) is prev_int
    assert not trainer._prev_handlers
