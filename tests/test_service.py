"""SimulationService: typed results, admission, watchdog, degradation.

The service contract: every submitted request ends in exactly one typed
terminal state (ok / degraded / overloaded / deadline_exceeded / failed
/ cancelled) — never a hang — and a degraded answer is still within the
rung's rel-err gate vs the monolithic reference.
"""
import time

import jax
import numpy as np
import pytest

from repro.configs import get_config
from repro.core import predictor
from repro.core.engine_config import EngineConfig
from repro.core.standardize import build_vocab
from repro.serving.engine import PredictorEngine, Request
from repro.serving.faults import FaultInjector
from repro.serving.service import (STATUSES, DegradationController,
                                   ServiceSLA, SimulationService,
                                   build_ladder)

VOCAB = build_vocab()
SMALL_CFG = get_config("capsim").replace(
    d_model=32, head_dim=8, d_ff=64, dtype="float32")
BASE = EngineConfig(batch_size=8)


@pytest.fixture(scope="module")
def params():
    return predictor.init_params(SMALL_CFG, jax.random.PRNGKey(0))


def _req(i, n=4, seed=None):
    rng = np.random.RandomState(i if seed is None else seed)
    tok = rng.randint(0, VOCAB.size, (n, 128, SMALL_CFG.clip_tokens)
                      ).astype(np.int32)
    ctx = rng.randint(0, VOCAB.size, (n, SMALL_CFG.context_tokens)
                      ).astype(np.int32)
    return Request(i, tok, ctx, np.ones((n, 128), np.float32))


def _sla(**kw):
    kw.setdefault("watchdog_s", 120.0)       # compile-safe on slow CI
    kw.setdefault("promote_after", 1)
    return ServiceSLA(**kw)


# --------------------------------------------------------------------------- #
# Ladder + controller units
# --------------------------------------------------------------------------- #

def test_build_ladder_respects_structural_axes():
    assert [n for n, _ in build_ladder(BASE)] == [
        "fused_int8", "fused", "rt", "monolithic"]
    assert [n for n, _ in build_ladder(BASE.replace(rt_cache=False))] == [
        "monolithic"]
    assert [n for n, _ in build_ladder(BASE.replace(use_context=False))
            ] == ["rt", "monolithic"]
    for name, cfg in build_ladder(BASE):
        cfg.validate()                        # every rung is launchable
    mono = dict(build_ladder(BASE))["monolithic"]
    assert not mono.rt_cache and mono.rt_store_dir is None


def test_degradation_controller_backoff():
    ctrl = DegradationController(4, ServiceSLA(promote_after=2,
                                               backoff_max=8))
    assert ctrl.on_trip() == 1                # demote, backoff 2 -> 4
    assert ctrl.on_trip() == 2                # backoff 4 -> 8
    assert ctrl.backoff == 8
    # climbing back needs a full backoff streak per rung
    for _ in range(7):
        assert ctrl.on_healthy() is None
    assert ctrl.on_healthy() == 1
    for _ in range(7):
        assert ctrl.on_healthy() is None
    assert ctrl.on_healthy() == 0
    # stable at the top for promote_after more -> backoff forgiven
    ctrl.on_healthy()
    ctrl.on_healthy()
    assert ctrl.backoff == 2
    # at the floor a trip demotes nowhere but still backs off
    ctrl.idx = 3
    assert ctrl.on_trip() is None


# --------------------------------------------------------------------------- #
# Request validation + persistent engine backend
# --------------------------------------------------------------------------- #

def test_submit_validates_shapes_and_dtypes(params):
    eng = PredictorEngine(params, SMALL_CFG, BASE)
    good = _req(0)
    eng.submit(good)
    bad_rank = Request(1, good.clip_tokens[:, 0], good.context_tokens,
                       good.clip_mask)
    with pytest.raises(ValueError, match="clip_tokens"):
        eng.submit(bad_rank)
    with pytest.raises(ValueError, match="l_clip"):
        eng.submit(Request(2, good.clip_tokens[:, :7], good.context_tokens,
                           good.clip_mask[:, :7]))
    with pytest.raises(ValueError, match="dtype"):
        eng.submit(Request(3, good.clip_tokens.astype(np.float32),
                           good.context_tokens, good.clip_mask))
    with pytest.raises(ValueError, match="context_tokens"):
        eng.submit(Request(4, good.clip_tokens,
                           good.context_tokens[:2], good.clip_mask))
    with pytest.raises(ValueError, match="clip_mask"):
        eng.submit(Request(5, good.clip_tokens, good.context_tokens,
                           good.clip_mask.astype(np.int32)))
    with pytest.raises(ValueError, match="context"):
        eng.submit(Request(6, good.clip_tokens,
                           good.context_tokens[:, :13], good.clip_mask))


def test_engine_backend_persists_across_flushes(params):
    eng = PredictorEngine(params, SMALL_CFG, BASE)
    eng.submit(_req(0))
    r1 = eng.flush()[0]
    backend = eng.backend()
    eng.submit(_req(1, n=3))
    eng.submit(_req(0))
    r2 = eng.flush()
    assert eng.backend() is backend           # ONE backend, reused
    assert [r.n_clips for r in r2] == [3, 4]
    assert r2[1].total_cycles == r1.total_cycles   # replay is bitwise
    # the RT table persisted across flushes: replay encoded nothing new
    assert eng.rt_stats.n_rows_encoded > 0


# --------------------------------------------------------------------------- #
# Service behavior
# --------------------------------------------------------------------------- #

def test_service_healthy_top_tier(params):
    with SimulationService(params, SMALL_CFG, BASE, sla=_sla()) as svc:
        tickets = [svc.submit(_req(i)) for i in range(3)]
        results = [t.result(timeout=300) for t in tickets]
    assert all(r.status == "ok" and r.ok for r in results)
    assert all(r.tier == "fused_int8" for r in results)
    assert all(r.total_cycles and np.isfinite(r.total_cycles)
               for r in results)
    # against the plain engine at the same rung: identical numbers
    eng = PredictorEngine(params, SMALL_CFG, BASE.replace(
        fused_serving=True, precision="int8"))
    eng.submit(_req(0))
    assert eng.flush()[0].total_cycles == pytest.approx(
        results[0].total_cycles, rel=1e-6)


def test_service_sheds_when_queue_full(params):
    sla = _sla(queue_limit=1)
    svc = SimulationService(params, SMALL_CFG, BASE, sla=sla)
    # not started: the worker never drains, so the 2nd+ submissions see
    # a full queue and must be shed IMMEDIATELY with a typed result
    svc._running = True
    t1 = svc.submit(_req(0))
    t2 = svc.submit(_req(1))
    assert not t1.done()
    assert t2.done() and t2.result().status == "overloaded"
    assert "queue full" in t2.result().error
    svc.stop(drain=False)
    assert t1.result(timeout=5).status == "cancelled"


def test_service_rejects_after_stop_and_validates(params):
    svc = SimulationService(params, SMALL_CFG, BASE, sla=_sla())
    t = svc.submit(_req(0))
    assert t.result().status == "overloaded"   # never started
    with pytest.raises(ValueError):
        svc.submit(Request(1, np.zeros((2, 3), np.int32),
                           np.zeros((2, 4), np.int32),
                           np.zeros((2, 3), np.float32)))


def test_service_deadline_exceeded_is_typed(params):
    with SimulationService(params, SMALL_CFG, BASE, sla=_sla()) as svc:
        # a deadline that already passed: the window collector resolves
        # it typed without burning a flush
        t = svc.submit(_req(0), deadline_s=-1.0)
        res = t.result(timeout=60)
    assert res.status == "deadline_exceeded"
    assert res.total_cycles is None and not res.ok


def test_service_nan_demotes_then_repromotes(params):
    inj = FaultInjector({"nan_output": 1.0})
    sla = _sla(check_every=0, backoff_max=2)
    with SimulationService(params, SMALL_CFG, BASE, sla=sla,
                           fault_injector=inj) as svc:
        top = svc.tier_stats[0].name
        # int8 tier returns NaN -> guard demotes; every tier is equally
        # poisoned, so the ladder exhausts into a typed failure
        res = svc.submit(_req(0)).result(timeout=600)
        assert res.status == "failed"
        assert "non-finite" in res.error or "tiers failed" in res.error
        assert svc.current_tier != top
        assert sum(t.nan_trips for t in svc.tier_stats) > 0
        demoted_to = svc.current_tier

        # faults stop -> healthy traffic climbs the ladder back
        inj.set_enabled(False)
        for i in range(1, 12):
            r = svc.submit(_req(i)).result(timeout=600)
            assert r.ok
            if svc.current_tier == top:
                break
        assert svc.current_tier == top
        assert svc.current_tier != demoted_to
        assert sum(t.promotions for t in svc.tier_stats) > 0
        stats = svc.stats()
    assert stats["statuses"]["failed"] == 1
    assert set(stats["statuses"]) == set(STATUSES)


def test_service_watchdog_aborts_stuck_flush(params):
    inj = FaultInjector({"slow_flush": 1.0}, slow_seconds=30.0)
    sla = _sla(watchdog_s=0.5, check_every=0)
    t0 = time.time()
    with SimulationService(params, SMALL_CFG, BASE, sla=sla,
                           fault_injector=inj) as svc:
        res = svc.submit(_req(0)).result(timeout=120)
        # stuck on EVERY rung -> typed failure, and the watchdog cut
        # each attempt at ~0.5s instead of 30s
        assert res.status == "failed"
        assert "watchdog" in res.error
        assert sum(t.watchdog_trips for t in svc.tier_stats) > 0
        assert time.time() - t0 < 30.0
        # faults stop: the service recovers without a restart (backends
        # were rebuilt after the abandoned flushes)
        inj.set_enabled(False)
        assert svc.submit(_req(1)).result(timeout=600).ok


def test_service_degraded_results_stay_gated(params):
    # poison ONLY the top tier via the spot check: int8's own rel err is
    # within gate, so serving continues at the top; a non-finite check
    # (nan fault) must demote.  Served-degraded answers then match the
    # monolithic reference exactly (rt tier is bitwise).
    inj = FaultInjector({"nan_output": 0.6}, seed=3)
    sla = _sla(check_every=0)
    with SimulationService(params, SMALL_CFG, BASE, sla=sla,
                           fault_injector=inj) as svc:
        results = [svc.submit(_req(i)).result(timeout=600)
                   for i in range(6)]
    ref = PredictorEngine(params, SMALL_CFG, BASE.replace(rt_cache=False))
    for i, r in enumerate(results):
        assert r.status in ("ok", "degraded", "failed")
        if not r.ok:
            continue
        ref.submit(_req(i))
        want = ref.flush()[0].total_cycles
        tol = 0.05 if r.tier == "fused_int8" else 1e-3
        assert abs(r.total_cycles - want) / abs(want) <= tol


def test_service_stats_shape(params):
    with SimulationService(params, SMALL_CFG, BASE, sla=_sla()) as svc:
        svc.submit(_req(0)).result(timeout=300)
        st = svc.stats()
    assert st["submitted"] == 1 and st["statuses"]["ok"] == 1
    assert list(st["tiers"]) == ["fused_int8", "fused", "rt",
                                 "monolithic"]
    assert st["tiers"]["fused_int8"]["clips"] == 4
    assert st["current_tier"] == "fused_int8"
