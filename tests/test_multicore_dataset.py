"""Multicore training subsystem invariants.

The tentpole contracts:

  * ``slice_multicore_columnar`` is per-core Algorithm 1: the default
    mode is bitwise ``slice_trace_columnar`` per core; tail-inclusive
    mode covers each core's whole trace, keeps every non-tail clip at
    ``l_min`` or longer, and its clip times sum to the oracle's per-core
    total cycles;
  * the N=1 multicore build is bitwise identical to the single-core
    ``build_dataset`` pipeline over the same program (tensors AND
    provenance) — the anchor that keeps the 360-token path unchanged;
  * builds are deterministic, and the context layouts (core-tagged /
    peer-channel) derive from ``context.context_len`` with the
    single-core prefix bitwise intact;
  * the replay scheduler's ``snapshot_at``/``peer_snapshots`` honor the
    per-trace-position contract.
"""
import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st
except ImportError:                    # container without the test extras
    from _hypothesis_compat import given, settings, strategies as st

from repro.core import context as ctx_mod
from repro.core import slicer as slicer_mod
from repro.core.standardize import build_vocab
from repro.data.dataset import BuildConfig, BuildStats, build_bench_clips
from repro.data.multicore_dataset import (MulticoreBuildConfig,
                                          build_multicore_bench_clips,
                                          build_multicore_dataset)
from repro.isa import multicore, timing

VOCAB = build_vocab()
KW = dict(interval_size=1_200, warmup=150, max_checkpoints=2, l_min=32,
          l_clip=40, l_token=16, threshold=20, coef=0.2)


def _commit_column(seed: int, n: int) -> np.ndarray:
    """Random monotone commit-cycle column (width-8 commit groups)."""
    rng = np.random.RandomState(seed)
    return np.cumsum(rng.randint(0, 3, size=n)) + rng.randint(0, 5)


# --------------------------------------------------------------------------- #
# slice_multicore_columnar
# --------------------------------------------------------------------------- #

@settings(max_examples=25, deadline=None)
@given(st.integers(0, 10_000), st.integers(0, 400), st.integers(4, 64))
def test_slice_default_matches_single_core_slicer(seed, n, l_min):
    cols = [_commit_column(seed, n), _commit_column(seed + 1, n // 2)]
    got = slicer_mod.slice_multicore_columnar(cols, l_min)
    for c, (bounds, times) in enumerate(got):
        ref_b, ref_t = slicer_mod.slice_trace_columnar(cols[c], l_min)
        np.testing.assert_array_equal(bounds, ref_b)
        np.testing.assert_array_equal(times, ref_t)


@settings(max_examples=25, deadline=None)
@given(st.integers(0, 10_000), st.integers(1, 400), st.integers(4, 64))
def test_slice_tail_mode_covers_and_sums(seed, n, l_min):
    """Tail-inclusive slicing: bounds partition [0, n), all non-tail
    clips respect l_min, and clip times telescope to commit[-1] — the
    oracle's total cycles for the core."""
    cols = [_commit_column(seed, n)]
    (bounds, times), = slicer_mod.slice_multicore_columnar(
        cols, l_min, include_tail=True)
    assert bounds.shape[0] >= 1
    assert bounds[0, 0] == 0 and bounds[-1, 1] == n
    np.testing.assert_array_equal(bounds[1:, 0], bounds[:-1, 1])
    lens = slicer_mod.clip_lengths(bounds)     # clip 0 counts its dup lead
    assert (lens[:-1] >= l_min).all()          # only the tail may be short
    assert times.sum() == pytest.approx(float(cols[0][-1]))
    # the tail clip is the default slicing plus at most one extra close
    ref_b, _ = slicer_mod.slice_trace_columnar(cols[0], l_min)
    assert bounds.shape[0] - ref_b.shape[0] in (0, 1)


def test_slice_tail_sums_to_multicore_oracle_totals():
    """On a real contended run: per-core clip time deltas sum to the
    shared-resource oracle's per-core total cycles."""
    mb = multicore.build_multicore_benchmark("mt.mix", 2)
    mt = multicore.run_multicore(mb.compiled(), 1_500, mb.fresh_states())
    commits = timing.simulate_multicore(mt.cores, mt.schedule)
    totals = timing.total_cycles_multicore(mt.cores, mt.schedule)
    sliced = slicer_mod.slice_multicore_columnar(commits, 32,
                                                 include_tail=True)
    for c, (bounds, times) in enumerate(sliced):
        assert bounds[-1, 1] == len(mt.cores[c])
        assert times.sum() == pytest.approx(float(totals[c]))


def test_slice_empty_columns():
    out = slicer_mod.slice_multicore_columnar(
        [np.zeros(0), np.zeros(0)], 8, include_tail=True)
    for bounds, times in out:
        assert bounds.shape == (0, 2) and times.shape == (0,)


# --------------------------------------------------------------------------- #
# N=1 bitwise anchor + determinism
# --------------------------------------------------------------------------- #

def _datasets_equal(a, b) -> bool:
    return (np.array_equal(a.clip_tokens, b.clip_tokens)
            and np.array_equal(a.context_tokens, b.context_tokens)
            and np.array_equal(a.clip_mask, b.clip_mask)
            and np.array_equal(a.time, b.time)
            and a.bench_names == b.bench_names)


def test_n1_build_bitwise_identical_to_single_core():
    """peer_channels off + N=1: the multicore build must reproduce the
    existing single-core ``build_dataset`` pipeline bit for bit — same
    Algorithm-1 bounds, same sampler keys, same 360-token contexts."""
    for kind in ("mt.stream", "mt.counter"):
        mb = multicore.build_multicore_benchmark(kind, 1)
        got = build_multicore_bench_clips(
            mb, MulticoreBuildConfig(n_cores=1, **KW), VOCAB)
        ref = build_bench_clips(multicore.single_core_benchmark(kind),
                                BuildConfig(**KW), VOCAB)
        assert len(got) > 0, kind
        assert got.context_len == ctx_mod.CONTEXT_LEN
        assert _datasets_equal(got, ref), kind
    # peer_channels at N=1 is a no-op (no peers), not a width change
    peer = build_multicore_dataset(
        ["mt.stream"],
        MulticoreBuildConfig(n_cores=1, peer_channels=True, **KW), VOCAB)
    assert peer.context_len == ctx_mod.CONTEXT_LEN


def test_build_deterministic_across_runs():
    bcfg = MulticoreBuildConfig(n_cores=2, **KW)
    a = build_multicore_dataset(["mt.counter"], bcfg, VOCAB)
    b = build_multicore_dataset(["mt.counter"], bcfg, VOCAB)
    assert len(a) > 0
    assert _datasets_equal(a, b)


def test_build_stats_accounting():
    stats = BuildStats()
    ds = build_multicore_dataset(["mt.stream"],
                                 MulticoreBuildConfig(n_cores=2, **KW),
                                 VOCAB, stats=stats)
    assert stats.n_clips == len(ds)
    assert stats.n_sliced >= stats.n_clips
    assert stats.n_instructions == 2 * KW["interval_size"] \
        * KW["max_checkpoints"]
    assert stats.build_seconds > 0


# --------------------------------------------------------------------------- #
# Context layouts
# --------------------------------------------------------------------------- #

def test_context_len_derivation_and_validation():
    assert ctx_mod.context_len() == ctx_mod.CONTEXT_LEN
    assert ctx_mod.context_len(4) == ctx_mod.MULTICORE_CONTEXT_LEN
    assert ctx_mod.context_len(3, peer_channels=True) \
        == 3 * ctx_mod.MULTICORE_CONTEXT_LEN
    # no peers to mix at N=1: the flag must not change the layout
    assert ctx_mod.context_len(1, peer_channels=True) \
        == ctx_mod.CONTEXT_LEN
    ctx_mod.validate_context_width(ctx_mod.CONTEXT_LEN, "t")
    ctx_mod.validate_context_width(ctx_mod.MULTICORE_CONTEXT_LEN, "t")
    ctx_mod.validate_context_width(4 * ctx_mod.MULTICORE_CONTEXT_LEN, "t")
    for bad in (0, 1, ctx_mod.CONTEXT_LEN - 1, ctx_mod.CONTEXT_LEN + 1,
                2 * ctx_mod.CONTEXT_LEN):
        with pytest.raises(ValueError):
            ctx_mod.validate_context_width(bad, "t")


def test_peer_context_layout():
    """Peer-channel context = own core-tagged block first (bitwise), then
    one <CORE>-tagged block per peer in ascending core order."""
    rng = np.random.RandomState(0)
    n_cores, b = 3, 5
    own = rng.randint(0, 1 << 40, (b, 40)).astype(np.uint64)
    peers = rng.randint(0, 1 << 40, (b, n_cores, 40)).astype(np.uint64)
    out = ctx_mod.peer_context_tokens(own, peers, core_id=1, vocab=VOCAB)
    m = ctx_mod.MULTICORE_CONTEXT_LEN
    assert out.shape == (b, n_cores * m)
    np.testing.assert_array_equal(
        out[:, :m],
        ctx_mod.context_tokens_from_matrix(own, VOCAB, core_id=1))
    for slot, peer in enumerate([0, 2]):
        blk = out[:, (1 + slot) * m: (2 + slot) * m]
        np.testing.assert_array_equal(
            blk, ctx_mod.context_tokens_from_matrix(
                peers[:, peer], VOCAB, core_id=peer))


def test_peer_channel_build_prefix_bitwise():
    """Turning peer mixing on must not change the clips, times, or the
    own-core context prefix — it only appends peer blocks."""
    base = build_multicore_bench_clips(
        multicore.build_multicore_benchmark("mt.mix", 2),
        MulticoreBuildConfig(n_cores=2, **KW), VOCAB)
    peer = build_multicore_bench_clips(
        multicore.build_multicore_benchmark("mt.mix", 2),
        MulticoreBuildConfig(n_cores=2, peer_channels=True, **KW), VOCAB)
    m = ctx_mod.MULTICORE_CONTEXT_LEN
    assert base.context_len == m
    assert peer.context_len == 2 * m
    np.testing.assert_array_equal(peer.clip_tokens, base.clip_tokens)
    np.testing.assert_array_equal(peer.time, base.time)
    np.testing.assert_array_equal(peer.context_tokens[:, :m],
                                  base.context_tokens)
    assert peer.bench_names == base.bench_names


# --------------------------------------------------------------------------- #
# Replay scheduler: snapshot_at + peer_snapshots
# --------------------------------------------------------------------------- #

def test_run_multicore_snapshot_at_matches_snapshot_every():
    mb = multicore.build_multicore_benchmark("mt.counter", 2)
    every = multicore.run_multicore(mb.compiled(), 1_000,
                                    mb.fresh_states(), snapshot_every=64)
    at = multicore.run_multicore(
        mb.compiled(), 1_000, mb.fresh_states(),
        snapshot_at=[list(range(0, len(t), 64)) for t in every.cores])
    for c in range(2):
        np.testing.assert_array_equal(at.cores[c].snapshots,
                                      every.cores[c].snapshots)


def test_peer_snapshots_n1_quantum_aligned():
    """At N=1 with snapshot positions on quantum starts, the quantum-
    start peer capture IS the core's own precise snapshot."""
    mb = multicore.build_multicore_benchmark("mt.stream", 1)
    q = 64
    mt = multicore.run_multicore(
        mb.compiled(), 1_000, mb.fresh_states(), quantum=q,
        snapshot_at=[list(range(0, 1_000, q))], peer_snapshots=True)
    ps = mt.peer_snapshots[0]
    assert ps.shape == (mt.cores[0].snapshots.shape[0], 1, 40)
    np.testing.assert_array_equal(ps[:, 0], mt.cores[0].snapshots)


def test_clone_states_shares_one_memory():
    mb = multicore.build_multicore_benchmark("mt.counter", 3)
    states = mb.fresh_states()
    clones = multicore.clone_states(states)
    assert all(c.mem is clones[0].mem for c in clones)
    assert clones[0].mem is not states[0].mem
    clones[0].mem[0xDEAD] = 1
    assert 0xDEAD not in states[0].mem
