"""Logical-axis rule resolution + vocab padding."""
import jax
import numpy as np
from jax.sharding import Mesh, PartitionSpec as P

from repro.configs import get_config, get_smoke_config
from repro.distributed.sharding import (
    LOGICAL_RULES_DECODE, LOGICAL_RULES_DECODE_LONG,
    LOGICAL_RULES_PREDICTOR, LOGICAL_RULES_TRAIN, axis_rules)
from repro.models.transformer import model_specs, padded_vocab


def _mesh(names):
    devs = np.array(jax.devices()[:1]).reshape((1,) * len(names))
    return Mesh(devs, names)


MESH2 = _mesh(("data", "model"))
MESH3 = _mesh(("pod", "data", "model"))


def test_train_rules_mapping():
    spec = axis_rules(("batch", "act_seq", "act_embed"),
                      rules=LOGICAL_RULES_TRAIN, mesh=MESH3)
    assert spec == P(("pod", "data"), None, None)
    spec = axis_rules(("embed", "mlp"), rules=LOGICAL_RULES_TRAIN,
                      mesh=MESH3)
    assert spec == P("data", "model")


def test_missing_mesh_axis_dropped():
    # 'pod' absent on the single-pod mesh.  Single-axis entries normalize
    # to the bare name (old jax compares P(("data",)) != P("data")).
    spec = axis_rules(("batch",), rules=LOGICAL_RULES_TRAIN, mesh=MESH2)
    assert spec == P("data")


def test_axis_used_once_per_spec():
    # both logical axes map to 'model': the second must be dropped
    spec = axis_rules(("qkv", "mlp"), rules=LOGICAL_RULES_TRAIN, mesh=MESH2)
    assert spec == P("model", None)


def test_decode_rules_shard_cache_seq():
    spec = axis_rules(("cache_batch", "cache_seq"),
                      rules=LOGICAL_RULES_DECODE, mesh=MESH3)
    assert spec == P(("pod", "data"), "model")
    # long-context: whole mesh on the sequence, batch unsharded
    spec = axis_rules(("cache_batch", "cache_seq"),
                      rules=LOGICAL_RULES_DECODE_LONG, mesh=MESH3)
    assert spec == P(None, ("pod", "data", "model"))


def test_predictor_rules_pure_dp():
    spec = axis_rules(("batch", None, None),
                      rules=LOGICAL_RULES_PREDICTOR, mesh=MESH3)
    assert spec == P(("pod", "data", "model"), None, None)
    spec = axis_rules(("embed", "qkv"), rules=LOGICAL_RULES_PREDICTOR,
                      mesh=MESH3)
    assert spec == P(None, None)           # weights replicate


def test_vocab_padding_only_when_needed():
    mamba = get_config("mamba2-780m")
    assert mamba.vocab_size == 50280                    # assigned value
    assert padded_vocab(mamba) == 50288                 # 16-divisible
    qwen = get_config("qwen3-4b")
    assert padded_vocab(qwen) == qwen.vocab_size        # untouched
    specs = model_specs(mamba)
    assert specs["embed"].shape[0] == 50288
    assert specs["unembed"].shape[1] == 50288


def test_padded_logits_masked():
    import jax.numpy as jnp
    from repro.models import transformer as tfm
    cfg = get_smoke_config("mamba2-780m").replace(vocab_size=250)  # pad->256
    params = tfm.init_params(cfg, jax.random.PRNGKey(0))
    batch = {"tokens": jnp.zeros((2, 8), jnp.int32)}
    logits, _, _, _ = tfm.forward(params, batch, cfg, "train")
    assert logits.shape[-1] == 256
    pad_cols = np.asarray(logits[..., 250:], np.float32)
    assert (pad_cols <= -1e29).all()
