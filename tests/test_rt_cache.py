"""Static-instruction RT cache + split predictor forward invariants.

The tentpole contract: serving clips through the RT-table gather
(``forward_cached`` — block encoder + head only) is *bitwise* identical
in fp32 to the monolithic ``forward`` that re-encodes every dynamic row,
because RT_i depends only on the static standardized tokens and rows
encode independently.  bf16 precision mode is relative-error bounded,
and the Pallas kernel's kv_mask plumbing must hold on padded remainder
batches (interpret mode on CPU).
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.core import predictor
from repro.core.engine import BatchedPredictor, SimulationEngine
from repro.core.engine_config import EngineConfig
from repro.core.rt_cache import PAD_ROW_ID, RTCache, encode_bucket
from repro.core.standardize import build_vocab, encode_fixed_clips, \
    fixed_clip_indices
from repro.data.dataset import BuildConfig, build_bench_clips, indexed_clips
from repro.isa import progen

VOCAB = build_vocab()
SMALL_CFG = get_config("capsim").replace(
    d_model=32, head_dim=8, d_ff=64, dtype="float32")
MIX = ["503.bwaves", "541.leela", "525.x264"]
SIM_EC = EngineConfig(interval_size=1_500, warmup=200, max_checkpoints=3,
                      l_min=32, l_clip=32, l_token=16, batch_size=16,
                      with_oracle=False)


@pytest.fixture(scope="module")
def params():
    return predictor.init_params(SMALL_CFG, jax.random.PRNGKey(0))


def _table_batch(params, rng, B=4, L=12):
    """Random clips drawn from a real program's token table, as both a
    token batch (monolithic forward) and an rt_idx batch (cached)."""
    cprog = progen.build_benchmark("505.mcf").compiled()
    table = cprog.token_table(VOCAB, 16)
    cache = RTCache(params, SMALL_CFG, 16)
    ids = cache.ensure_rows(table, keys=cprog.token_row_keys(VOCAB, 16))
    pc = rng.randint(0, table.shape[0], (B, L)).astype(np.int32)
    mask = (rng.uniform(size=(B, L)) < 0.8).astype(np.float32)
    mask[:, 0] = 1.0
    tok = table[pc] * mask[..., None].astype(np.int32)   # masked slots PAD
    rt_idx = np.where(mask > 0, ids[pc], PAD_ROW_ID).astype(np.int32)
    ctx = rng.randint(1, SMALL_CFG.vocab_size,
                      (B, SMALL_CFG.context_tokens)).astype(np.int32)
    return cache, tok, rt_idx, ctx, mask


def test_forward_cached_bitwise_equals_forward(params):
    """Gathering RT rows from the cache table == re-encoding the same
    token rows inside the clip batch, bit for bit (fp32)."""
    cache, tok, rt_idx, ctx, mask = _table_batch(
        params, np.random.RandomState(0))
    mono = predictor.forward(
        params, {"clip_tokens": jnp.asarray(tok),
                 "context_tokens": jnp.asarray(ctx),
                 "clip_mask": jnp.asarray(mask)}, SMALL_CFG)
    cached = predictor.forward_cached(
        params, cache.table, {"rt_idx": jnp.asarray(rt_idx),
                              "context_tokens": jnp.asarray(ctx),
                              "clip_mask": jnp.asarray(mask)}, SMALL_CFG)
    np.testing.assert_array_equal(np.asarray(mono), np.asarray(cached))


def test_engine_rt_cache_bitwise_per_benchmark(params):
    """SimulationEngine with the RT cache == monolithic engine, bitwise,
    per benchmark — the CI gate's unit-scale twin."""
    runs = {}
    for rt in (True, False):
        eng = SimulationEngine(params, SMALL_CFG, VOCAB,
                               SIM_EC.replace(rt_cache=rt))
        eng.submit_names(MIX)
        runs[rt] = eng.run()
        if rt:
            st = eng.last_rt_stats
            assert st.n_rows_encoded < st.n_rows_served
            assert st.build_seconds > 0.0
            assert eng.last_stats.n_clips > 0
        else:
            assert eng.last_rt_stats is None
    for a, b in zip(runs[True], runs[False]):
        assert a.name == b.name and a.n_clips == b.n_clips
        assert a.predicted_cycles == b.predicted_cycles     # bitwise


def test_batched_predictor_token_path_through_cache(params):
    """Serving-style ``add`` of raw tokenized clips dedupes through the
    cache and still matches the monolithic backend bitwise, across a
    bucketed remainder (zero-row padding)."""
    rng = np.random.RandomState(3)
    cache, tok, rt_idx, ctx, mask = _table_batch(params, rng, B=23, L=32)
    mono = BatchedPredictor(params, SMALL_CFG,
                            config=EngineConfig(batch_size=16))
    mono.add(tok, ctx, mask)
    ref = mono.drain()

    cached = BatchedPredictor(params, SMALL_CFG,
                              config=EngineConfig(batch_size=16),
                              rt_cache=cache)
    for lo, hi in ((0, 5), (5, 17), (17, 23)):
        cached.add(tok[lo:hi], ctx[lo:hi], mask[lo:hi])
    out = cached.drain()
    assert ref.shape == out.shape == (23,)
    np.testing.assert_array_equal(ref, out)
    assert cached.stats.n_predicted == 23 and cached.stats.n_pad == 1


def test_rt_cache_dedupe_and_pad_row(params):
    cache = RTCache(params, SMALL_CFG, 16)
    rows = np.zeros((3, 16), np.int32)
    rows[1, :4] = (1, 3, 2, 2)
    rows[2, :4] = (1, 3, 2, 2)                   # dup of row 1
    ids = cache.ensure_rows(rows)
    assert ids[0] == PAD_ROW_ID                  # all-<PAD> -> pad slot
    assert ids[1] == ids[2] != PAD_ROW_ID
    n0 = cache.stats.n_rows_encoded
    assert n0 == 2                               # pad row + one unique
    again = cache.ensure_rows(rows)
    np.testing.assert_array_equal(ids, again)
    assert cache.stats.n_rows_encoded == n0      # pure cache hits
    assert cache.stats.n_encode_passes == 1


def test_encode_bucket():
    # floor 32 = the shape-stable kernel class (see ENCODE_STABLE_MIN)
    assert encode_bucket(1) == 32 and encode_bucket(32) == 32
    assert encode_bucket(33) == 64 and encode_bucket(500) == 512


def test_fixed_clip_indices_matches_encode_fixed_clips():
    """Index building is the gather-free twin of token tokenization:
    same mask, and table[idx] == the token tensors."""
    cprog = progen.build_benchmark("505.mcf").compiled()
    table = cprog.token_table(VOCAB, 16)
    rng = np.random.RandomState(1)
    pcs = rng.randint(0, table.shape[0], 137).astype(np.int32)
    tok, mask = encode_fixed_clips(table, pcs, 32, 40)
    # local ids == pc, pad row appended at index n_static
    ext = np.concatenate([table, np.zeros((1, 16), np.int32)])
    idx, mask_i = fixed_clip_indices(
        np.arange(table.shape[0], dtype=np.int32), pcs, 32, 40,
        pad_id=table.shape[0])
    np.testing.assert_array_equal(mask, mask_i)
    np.testing.assert_array_equal(tok, ext[idx])


def test_bf16_precision_within_relative_error(params):
    """Opt-in bf16 inference: fp32 params cast at dispatch, fp32
    softmax/accumulation — per-benchmark predictions within 1%."""
    results = {}
    for prec in (None, "bf16"):
        eng = SimulationEngine(params, SMALL_CFG, VOCAB,
                               SIM_EC.replace(precision=prec))
        eng.submit_names(MIX)
        results[prec] = eng.run()
    for a, b in zip(results[None], results["bf16"]):
        rel = abs(b.predicted_cycles - a.predicted_cycles) \
            / abs(a.predicted_cycles)
        assert rel < 0.01, (a.name, rel)


def test_inference_config_precision_knob():
    resolved = predictor.inference_config(SMALL_CFG, None)
    if jax.default_backend() == "tpu":       # Pallas-by-default on TPU
        assert resolved.attn_impl == "pallas"
        assert resolved.replace(attn_impl="chunked") == SMALL_CFG
    else:
        assert resolved == SMALL_CFG         # identity off-TPU
    assert predictor.inference_config(SMALL_CFG, "fp32").dtype == "float32"
    assert predictor.inference_config(SMALL_CFG, "bf16").dtype == "bfloat16"
    # int8 is a storage/accuracy rung, not a compute dtype: weights are
    # fake-quantized at engine build and the step computes in fp32
    assert predictor.inference_config(SMALL_CFG, "int8").dtype == "float32"
    with pytest.raises(ValueError):
        predictor.inference_config(SMALL_CFG, "fp8")


def test_pallas_kv_mask_on_padded_remainder(params):
    """The Pallas flash path (interpret mode on CPU) must honor kv_mask on
    a drain-style batch: fully-masked zero remainder rows and partially
    masked clips, matching the XLA path and ignoring pad content."""
    rng = np.random.RandomState(5)
    cache, tok, rt_idx, ctx, mask = _table_batch(params, rng, B=6, L=16)
    # drain-style remainder: last two rows fully masked zero rows
    tok[4:] = 0
    rt_idx[4:] = PAD_ROW_ID
    ctx[4:] = 0
    mask[4:] = 0.0
    pcfg = SMALL_CFG.replace(attn_impl="pallas")
    batch = {"clip_tokens": jnp.asarray(tok),
             "context_tokens": jnp.asarray(ctx),
             "clip_mask": jnp.asarray(mask)}
    ref = np.asarray(predictor.forward(params, batch, SMALL_CFG))
    out = np.asarray(predictor.forward(params, batch, pcfg))
    np.testing.assert_allclose(out[:4], ref[:4], rtol=2e-4, atol=2e-4)
    cached = np.asarray(predictor.forward_cached(
        params, cache.table, {"rt_idx": jnp.asarray(rt_idx),
                              "context_tokens": jnp.asarray(ctx),
                              "clip_mask": jnp.asarray(mask)}, pcfg))
    np.testing.assert_allclose(cached[:4], ref[:4], rtol=2e-4, atol=2e-4)
    assert np.isfinite(out).all() and np.isfinite(cached).all()


def test_dataset_indexed_clips_round_trip():
    bcfg = BuildConfig(interval_size=1_200, warmup=100, max_checkpoints=1,
                       l_min=25, l_clip=32, l_token=16, sample=False)
    ds = build_bench_clips(progen.build_benchmark("541.leela"), bcfg, VOCAB)
    assert len(ds) > 0
    rows, idx = indexed_clips(ds)
    assert rows.shape[0] < ds.clip_tokens.shape[0] * ds.clip_tokens.shape[1]
    np.testing.assert_array_equal(rows[idx], ds.clip_tokens)
    # masked slots exist -> the all-<PAD> row sorts to local id 0
    if (ds.clip_mask == 0).any():
        assert not rows[0].any()
