"""Roofline machinery: HLO collective parsing, wire models, extrapolation."""

from repro.launch.dryrun import extrapolate_costs
from repro.launch.roofline import (model_flops, param_counts,
                                   parse_collectives, roofline_terms)

HLO = """
ENTRY %main {
  %ag = bf16[256,4096,128]{2,1,0} all-gather(%x), replica_groups=[16,16]<=[256], dimensions={2}
  %ar = f32[1024,1024]{1,0} all-reduce(%y), replica_groups={{0,1,2,3}}, to_apply=%add
  %rs = bf16[64,512]{1,0} reduce-scatter(%z), replica_groups=[32,8]<=[256], dimensions={0}
  %cp = bf16[8,128]{1,0} collective-permute(%w), source_target_pairs={{0,1}}
  %ags = (bf16[2,2]{1,0}, u32[]) all-gather-start(%v), replica_groups=[1,2]<=[2]
  %agd = bf16[2,2]{1,0} all-gather-done(%ags)
}
"""


def test_parse_collectives_counts_and_wire():
    colls = parse_collectives(HLO)
    assert colls["all-gather"]["count"] == 2          # plain + -start
    ag_bytes = 256 * 4096 * 128 * 2
    # ring wire for g=16: bytes*(g-1)/g  (+ the tiny -start op)
    assert abs(colls["all-gather"]["wire_bytes"]
               - (ag_bytes * 15 / 16 + 8 * 1 / 2)) < 16
    ar_bytes = 1024 * 1024 * 4
    assert colls["all-reduce"]["wire_bytes"] == 2 * ar_bytes * 3 / 4
    rs_bytes = 64 * 512 * 2
    assert colls["reduce-scatter"]["wire_bytes"] == rs_bytes * 7
    assert colls["collective-permute"]["wire_bytes"] == 8 * 128 * 2
    # -done ops are not double counted
    assert colls["all-gather"]["count"] == 2


def test_roofline_terms_dominance():
    t = roofline_terms(197e12, 819e9 * 2, 50e9 * 0.5)
    assert abs(t["compute_s"] - 1.0) < 1e-9
    assert abs(t["memory_s"] - 2.0) < 1e-9
    assert t["dominant"] == "memory_s"


def test_extrapolate_costs_linear():
    def cell(flops, b, ag):
        return {"cost": {"flops": flops, "bytes_accessed": b},
                "collectives": {"all-gather": {
                    "count": 1, "bytes": ag, "wire_bytes": ag * 0.9}}}
    # cost(R) = 10 + 5R
    out = extrapolate_costs(cell(15, 150, 1.0), cell(20, 200, 2.0), 48)
    assert out["flops"] == 10 + 5 * 48
    assert out["bytes_accessed"] == 100 + 50 * 48
    assert abs(out["collectives"]["all-gather"]["wire_bytes"]
               - (0.0 + 0.9 * 48)) < 1e-9


def test_model_flops_yardsticks():
    from repro.configs import CAPSIM_SHAPES, LM_SHAPES, get_config
    cfg = get_config("olmo-1b")
    total, active = param_counts(cfg)
    assert total == active                       # dense: no expert discount
    f_train = model_flops(cfg, LM_SHAPES["train_4k"], "train")
    f_pre = model_flops(cfg, LM_SHAPES["prefill_32k"], "prefill")
    assert abs(f_train / (6 * active * 256 * 4096) - 1) < 1e-9
    assert abs(f_pre / (2 * active * 32 * 32768) - 1) < 1e-9
    # MoE: active < total
    moe = get_config("kimi-k2-1t-a32b")
    t2, a2 = param_counts(moe)
    assert a2 < t2 / 5                           # 384 experts, top-8
    # predictor has its own token accounting
    cap = get_config("capsim")
    f = model_flops(cap, CAPSIM_SHAPES["train_clips"], "train")
    assert f > 0
