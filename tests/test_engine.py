"""Batched multi-benchmark SimulationEngine invariants.

The engine's contract: pooling clips from many programs into shared
device batches changes *throughput only* — per-benchmark predicted
cycles are bitwise identical to the sequential single-benchmark path,
and the bucketed batcher neither drops nor double-counts clips.
"""
import jax
import numpy as np
import pytest

from repro.configs import get_config
from repro.core import context as ctx_mod
from repro.core import predictor
from repro.core.engine import (BatchedPredictor, SimulationEngine,
                               bucket_sizes, predict_fn)
from repro.core.engine_config import EngineConfig
from repro.core.simulate import capsim_simulate
from repro.core.standardize import ClipEncoder, build_vocab, encode_clip
from repro.isa import progen

VOCAB = build_vocab()
SMALL_CFG = get_config("capsim").replace(
    d_model=32, head_dim=8, d_ff=64, dtype="float32")

# three mixed-size benchmarks: different ckp_num caps and interval sizes
# exercise full batches, bucketed remainders, and cross-bench boundaries
MIX = ["503.bwaves", "541.leela", "525.x264"]
SIM_EC = EngineConfig(interval_size=1_500, warmup=200, max_checkpoints=3,
                      l_min=32, l_clip=32, l_token=16, batch_size=16,
                      with_oracle=False)


@pytest.fixture(scope="module")
def params():
    return predictor.init_params(SMALL_CFG, jax.random.PRNGKey(0))


@pytest.fixture(scope="module")
def engine_results(params):
    engine = SimulationEngine(params, SMALL_CFG, VOCAB, SIM_EC)
    engine.submit_names(MIX)
    return engine.run(), engine.last_stats


def test_engine_matches_capsim_simulate_bitwise(params, engine_results):
    """(a) pooled multi-benchmark run == per-benchmark sequential wrapper,
    bit for bit, on a fixed seed."""
    results, _ = engine_results
    for name, r in zip(MIX, results):
        solo = capsim_simulate(progen.build_benchmark(name), params,
                               SMALL_CFG, VOCAB, SIM_EC)
        assert r.name == solo.name == name
        assert r.n_clips == solo.n_clips
        assert r.n_instructions == solo.n_instructions
        assert r.predicted_cycles == solo.predicted_cycles  # bitwise


def test_bucketing_conserves_clips(engine_results):
    """(b) across 3 mixed-size benchmarks, every clip is predicted exactly
    once: pool totals, per-benchmark demux spans, and dispatched batch
    shapes all agree."""
    results, stats = engine_results
    per_bench = sum(r.n_clips for r in results)
    assert per_bench == stats.n_clips == stats.n_predicted
    # dispatched rows = real clips + padding, in bucket-shaped batches only
    dispatched = sum(shape * n for shape, n in stats.batch_shapes.items())
    assert dispatched == stats.n_clips + stats.n_pad
    assert set(stats.batch_shapes) <= set(bucket_sizes(16))
    # the mix is deliberately not batch-aligned
    assert stats.n_clips % 16 != 0 and stats.n_pad > 0


def test_batched_predictor_order_and_remainder(params):
    """Predictions come back in submission order with padding stripped,
    regardless of how adds straddle batch boundaries."""
    rng = np.random.RandomState(7)
    n = 23                                       # 16 + bucketed remainder
    tok = rng.randint(1, VOCAB.size, (n, 32, 16)).astype(np.int32)
    ctx = rng.randint(1, VOCAB.size,
                      (n, ctx_mod.CONTEXT_LEN)).astype(np.int32)
    mask = np.ones((n, 32), np.float32)

    whole = BatchedPredictor(params, SMALL_CFG,
                             config=EngineConfig(batch_size=16))
    whole.add(tok, ctx, mask)
    ref = whole.drain()

    split = BatchedPredictor(params, SMALL_CFG,
                             config=EngineConfig(batch_size=16))
    for lo, hi in ((0, 5), (5, 17), (17, 23)):
        split.add(tok[lo:hi], ctx[lo:hi], mask[lo:hi])
    out = split.drain()

    assert ref.shape == out.shape == (n,)
    np.testing.assert_array_equal(ref, out)
    assert split.stats.n_predicted == n
    assert split.stats.n_pad == 8 - 7            # remainder 7 -> bucket 8


def test_bucket_sizes():
    assert bucket_sizes(256) == (256, 128, 64, 32, 16, 8)
    assert bucket_sizes(8) == (8,)
    assert bucket_sizes(12) == (12, 8)


def test_predict_fn_cached():
    assert predict_fn(SMALL_CFG, True) is predict_fn(SMALL_CFG, True)
    assert predict_fn(SMALL_CFG, True) is not predict_fn(SMALL_CFG, False)


def test_encode_clips_matches_encode_clip():
    bench = progen.build_benchmark("505.mcf")
    insts = bench.program[:90]
    clips = [insts[0:30], insts[30:55], insts[55:90]]
    enc = ClipEncoder(VOCAB, 32, 16)
    toks, mask = enc.encode(clips)
    assert toks.shape == (3, 32, 16) and mask.shape == (3, 32)
    for i, c in enumerate(clips):
        t_ref, m_ref = encode_clip(c, VOCAB, 32, 16)
        np.testing.assert_array_equal(toks[i], t_ref)
        np.testing.assert_array_equal(mask[i], m_ref)
    # memo hit rate: loopy traces collapse onto few standardized shapes
    assert len(enc._memo) < sum(len(c) for c in clips)
