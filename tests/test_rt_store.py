"""Persistent content-addressed RT-cache store invariants.

The store contract: a fresh cache constructed under the same content key
(params bytes + model config + l_token + vocab signature) adopts the
persisted (rows -> RT vectors) table byte for byte with ZERO re-encode;
ANY key ingredient changing silently invalidates (clean rebuild, no
warning); a store that matches the key but is corrupt warns and falls
back to cold encoding instead of crashing or serving bad vectors.
"""
import glob
import os
import warnings

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.core import predictor
from repro.core.engine import SimulationEngine
from repro.core.engine_config import EngineConfig
from repro.core.rt_cache import RT_STORE_VERSION, RTCache, rt_store_key
from repro.core.standardize import build_vocab
from repro.isa import progen

VOCAB = build_vocab()
SMALL_CFG = get_config("capsim").replace(
    d_model=32, head_dim=8, d_ff=64, dtype="float32")
MIX = ["503.bwaves", "541.leela"]
ENGINE_KW = dict(interval_size=1_500, warmup=200, max_checkpoints=2,
                 l_min=32, l_clip=32, l_token=16, batch_size=16,
                 with_oracle=False)


@pytest.fixture(scope="module")
def params():
    return predictor.init_params(SMALL_CFG, jax.random.PRNGKey(0))


@pytest.fixture(scope="module")
def table():
    cprog = progen.build_benchmark("505.mcf").compiled()
    return cprog.token_table(VOCAB, 16)


def _cache(params, store_dir, **kw):
    kw.setdefault("store_extra", VOCAB.signature())
    return RTCache(params, SMALL_CFG, 16, store_dir=str(store_dir), **kw)


def test_store_round_trip_byte_identical(params, table, tmp_path):
    c1 = _cache(params, tmp_path)
    ids1 = c1.ensure_rows(table)
    assert c1.stats.n_rows_loaded == 0          # nothing persisted yet
    assert c1.persist() is not None
    assert c1.persist() is None                 # no growth -> no-op

    c2 = _cache(params, tmp_path)
    assert c2.stats.n_rows_loaded == c1.n_rows
    assert c2.stats.store_load_seconds > 0.0
    # the loaded table is the persisted table, byte for byte
    np.testing.assert_array_equal(
        np.asarray(c1.table[:c1.n_rows]), np.asarray(c2.table[:c2.n_rows]))
    # serving the same rows is pure lookup: zero encodes, zero passes
    ids2 = c2.ensure_rows(table)
    np.testing.assert_array_equal(ids1, ids2)
    assert c2.stats.n_rows_encoded == 0
    assert c2.stats.n_encode_passes == 0


def test_store_growth_persists_incrementally(params, table, tmp_path):
    c1 = _cache(params, tmp_path)
    c1.ensure_rows(table[: table.shape[0] // 2])
    c1.persist()
    c2 = _cache(params, tmp_path)
    loaded = c2.stats.n_rows_loaded
    assert loaded == c1.n_rows
    c2.ensure_rows(table)                       # second half is new
    assert c2.n_rows > loaded
    assert c2.persist() is not None             # growth -> re-persist
    c3 = _cache(params, tmp_path)
    assert c3.stats.n_rows_loaded == c2.n_rows
    c3.ensure_rows(table)
    assert c3.stats.n_rows_encoded == 0


def test_params_mismatch_invalidates_silently(params, table, tmp_path):
    c1 = _cache(params, tmp_path)
    c1.ensure_rows(table)
    c1.persist()
    other = predictor.init_params(SMALL_CFG, jax.random.PRNGKey(7))
    assert rt_store_key(other, SMALL_CFG, 16) != \
        rt_store_key(params, SMALL_CFG, 16)
    with warnings.catch_warnings():
        warnings.simplefilter("error")          # silent = no warning
        c2 = _cache(other, tmp_path)
    assert c2.stats.n_rows_loaded == 0
    c2.ensure_rows(table)                       # clean rebuild works
    assert c2.stats.n_rows_encoded > 0
    # the two stores coexist under different keys in one directory
    c1b = _cache(params, tmp_path)
    assert c1b.stats.n_rows_loaded == c1.n_rows


def test_vocab_signature_mismatch_invalidates(params, table, tmp_path):
    c1 = _cache(params, tmp_path)
    c1.ensure_rows(table)
    c1.persist()
    c2 = _cache(params, tmp_path, store_extra="some-other-vocab")
    assert c2.stats.n_rows_loaded == 0


def test_corrupt_store_warns_and_cold_encodes(params, table, tmp_path):
    c1 = _cache(params, tmp_path)
    ids1 = c1.ensure_rows(table)
    c1.persist()
    # truncate every persisted array file under the store key
    arrs = glob.glob(str(tmp_path / "*" / "step_*" / "arr_*.npy"))
    assert arrs
    for p in arrs:
        with open(p, "r+b") as fh:
            fh.truncate(os.path.getsize(p) // 2)
    with pytest.warns(UserWarning, match="falling back to cold encode"):
        c2 = _cache(params, tmp_path)
    assert c2.stats.n_rows_loaded == 0 and c2.n_rows == 0
    ids2 = c2.ensure_rows(table)                # cold path still correct
    np.testing.assert_array_equal(ids1, ids2)
    np.testing.assert_array_equal(
        np.asarray(c1.table[:c1.n_rows]), np.asarray(c2.table[:c2.n_rows]))


def test_corrupt_manifest_warns_and_cold_encodes(params, table, tmp_path):
    c1 = _cache(params, tmp_path)
    c1.ensure_rows(table)
    c1.persist()
    for p in glob.glob(str(tmp_path / "*" / "step_*" / "manifest.*.json")):
        with open(p, "w") as fh:
            fh.write("{ not json")
    with pytest.warns(UserWarning, match="falling back to cold encode"):
        c2 = _cache(params, tmp_path)
    assert c2.stats.n_rows_loaded == 0


def test_tampered_table_values_rejected(params, table, tmp_path):
    """A key-matching store whose table fails validation (non-finite
    values) must not be adopted — warn + cold encode."""
    c1 = _cache(params, tmp_path)
    c1.ensure_rows(table)
    c1.persist()
    arrs = sorted(glob.glob(str(tmp_path / "*" / "step_*" / "arr_*.npy")))
    poisoned = False
    for p in arrs:
        a = np.load(p)
        if a.dtype == np.float32:               # the table leaf
            a[0, 0] = np.nan
            np.save(p, a)
            poisoned = True
    assert poisoned
    with pytest.warns(UserWarning, match="falling back to cold encode"):
        c2 = _cache(params, tmp_path)
    assert c2.stats.n_rows_loaded == 0


def test_store_version_mismatch_invalidates(params, table, tmp_path,
                                            monkeypatch):
    c1 = _cache(params, tmp_path)
    c1.ensure_rows(table)
    c1.persist()
    monkeypatch.setattr("repro.core.rt_cache.RT_STORE_VERSION",
                        RT_STORE_VERSION + 1)
    with warnings.catch_warnings():
        warnings.simplefilter("error")          # silent clean rebuild
        c2 = _cache(params, tmp_path)
    assert c2.stats.n_rows_loaded == 0


def test_engine_restart_bitwise_with_store(params, tmp_path):
    """SimulationEngine round trip through rt_store_dir: run 2 loads the
    persisted table, encodes nothing, and reproduces run 1 bitwise."""
    ec = EngineConfig(rt_cache=True, rt_store_dir=str(tmp_path),
                      **ENGINE_KW)
    eng1 = SimulationEngine.from_config(params, SMALL_CFG, VOCAB, ec)
    eng1.submit_names(MIX)
    res1 = eng1.run()
    assert eng1.last_rt_stats.n_rows_encoded > 0

    eng2 = SimulationEngine.from_config(params, SMALL_CFG, VOCAB, ec)
    eng2.submit_names(MIX)
    res2 = eng2.run()
    st = eng2.last_rt_stats
    assert st.n_rows_loaded == eng1.last_rt_stats.n_rows_encoded
    assert st.n_rows_encoded == 0               # pure store service
    for a, b in zip(res1, res2):
        assert a.name == b.name
        assert a.predicted_cycles == b.predicted_cycles     # bitwise


@pytest.mark.skipif(len(jax.devices()) < 8,
                    reason="needs 8 devices (CI mesh leg sets "
                           "xla_force_host_platform_device_count=8)")
def test_store_composes_with_mesh_sharded_encode(params, tmp_path):
    """A table built by the 8-way mesh-sharded encode persists and is
    adopted by an unsharded cache (and vice versa): the store key ignores
    the mesh because sharded encodes are byte-identical to unsharded."""
    cprog = progen.build_benchmark("505.mcf").compiled()
    table = cprog.token_table(VOCAB, 16)
    mesh_cache = RTCache(params, SMALL_CFG, 16, n_shards=8,
                         store_dir=str(tmp_path),
                         store_extra=VOCAB.signature())
    mesh_cache.ensure_rows(table)
    mesh_cache.persist()

    plain = _cache(params, tmp_path)
    assert plain.stats.n_rows_loaded == mesh_cache.n_rows
    np.testing.assert_array_equal(
        np.asarray(mesh_cache.table[:mesh_cache.n_rows]),
        np.asarray(plain.table[:plain.n_rows]))

    mesh2 = RTCache(params, SMALL_CFG, 16, n_shards=8,
                    store_dir=str(tmp_path),
                    store_extra=VOCAB.signature())
    assert mesh2.stats.n_rows_loaded == mesh_cache.n_rows
    mesh2.ensure_rows(table)
    assert mesh2.stats.n_rows_encoded == 0


def test_store_key_sensitivity(params):
    base = rt_store_key(params, SMALL_CFG, 16, extra="v")
    assert base == rt_store_key(params, SMALL_CFG, 16, extra="v")
    assert base != rt_store_key(params, SMALL_CFG, 32, extra="v")
    assert base != rt_store_key(params, SMALL_CFG, 16, extra="w")
    assert base != rt_store_key(
        params, SMALL_CFG.replace(dtype="bfloat16"), 16, extra="v")
    bumped = jax.tree_util.tree_map(lambda a: a, params)
    bumped["embed"] = jnp.asarray(np.asarray(bumped["embed"]) + 1e-3)
    assert base != rt_store_key(bumped, SMALL_CFG, 16, extra="v")


# --------------------------------------------------------------------------- #
# Concurrent persistence: many writers, one store directory
# --------------------------------------------------------------------------- #

def test_concurrent_persist_last_writer_wins(params, table, tmp_path):
    """Two caches under the SAME content key race grow+persist rounds
    against one store dir.  Writer-unique tmp names + the retrying
    atomic publish mean: no crash on the rename collision, and the
    published store is always ONE writer's complete table."""
    import threading

    n = table.shape[0]
    rows_a, rows_b = table[: 2 * n // 3], table[n // 3:]
    gate = threading.Barrier(2)
    caches, errs = {}, []

    def run(name, rows):
        try:
            c = _cache(params, tmp_path)
            caches[name] = c
            m = rows.shape[0]
            for k in range(1, 6):
                c.ensure_rows(rows[: max(1, k * m // 5)])
                gate.wait(timeout=60)       # maximize publish overlap
                c.persist()
        except Exception as exc:            # pragma: no cover
            errs.append(exc)

    threads = [threading.Thread(target=run, args=("a", rows_a)),
               threading.Thread(target=run, args=("b", rows_b))]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    assert not errs

    # a fresh process adopts one (whole) writer's table, not a blend
    c3 = _cache(params, tmp_path)
    assert c3.stats.n_rows_loaded in {caches["a"].n_rows,
                                      caches["b"].n_rows}
    assert np.isfinite(np.asarray(c3.table[: c3.n_rows])).all()
    # and serving the full row set from it is bitwise-equal to a cold
    # cache: the store can accelerate, never corrupt
    ids3 = c3.ensure_rows(table)
    cold = RTCache(params, SMALL_CFG, 16)
    ids_cold = cold.ensure_rows(table)
    np.testing.assert_array_equal(
        np.asarray(c3.table)[ids3], np.asarray(cold.table)[ids_cold])


def test_two_engines_share_store_dir(params, tmp_path):
    """Two serving engines flush (and persist) concurrently into one
    rt_store_dir; a third engine then loads whatever generation won and
    still serves bitwise-correct results."""
    import threading

    from repro.serving.engine import PredictorEngine, Request

    ec = EngineConfig(l_clip=32, l_token=16, batch_size=16,
                      rt_store_dir=str(tmp_path))
    rng = np.random.RandomState(0)

    def mk_req(i, seed):
        r = np.random.RandomState(seed)
        tok = r.randint(0, VOCAB.size, (6, 32, 16)).astype(np.int32)
        ctx = r.randint(0, VOCAB.size,
                        (6, SMALL_CFG.context_tokens)).astype(np.int32)
        return Request(i, tok, ctx, np.ones((6, 32), np.float32))

    results, errs = {}, []

    def serve(name, seed):
        try:
            eng = PredictorEngine(params, SMALL_CFG, ec)
            for rnd in range(3):            # each flush persists
                eng.submit(mk_req(rnd, seed + rnd))
                results[(name, rnd)] = eng.flush()[0].total_cycles
        except Exception as exc:            # pragma: no cover
            errs.append(exc)

    threads = [threading.Thread(target=serve, args=("e1", 100)),
               threading.Thread(target=serve, args=("e2", 200))]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    assert not errs and len(results) == 6

    eng3 = PredictorEngine(params, SMALL_CFG, ec)
    assert eng3.rt_stats is not None
    eng3.submit(mk_req(0, 100))
    eng3.submit(mk_req(0, 200))
    got = eng3.flush()
    assert eng3.rt_stats.n_rows_loaded > 0      # adopted a winner
    assert got[0].total_cycles == results[("e1", 0)]
    assert got[1].total_cycles == results[("e2", 0)]
