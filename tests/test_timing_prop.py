"""Property tests for the O3 timing oracle (isa/timing).

Invariants on random programs: commit cycles are monotone non-decreasing,
at most ``commit_width`` instructions commit per cycle, and the columnar
oracle (``simulate_columnar`` over the trace IR) is bitwise equal to the
object oracle (``simulate`` over ``TraceEntry`` lists).
"""
from collections import Counter

import numpy as np

try:
    from hypothesis import given, settings, strategies as st
except ImportError:                    # container without the test extras
    from _hypothesis_compat import given, settings, strategies as st

from repro.isa import funcsim, timing
from repro.isa.compiled import compile_program
from repro.isa.isa import Instruction

I = Instruction
MAX_STEPS = 500


def random_program(seed: int, n: int):
    """Random but well-formed mini-Power program: ALU/mul/div chains,
    loads/stores, float ops, compares, and data-dependent branches with
    in-range targets (loops are fine — execution is step-capped)."""
    rng = np.random.RandomState(seed)

    def gr():
        return f"R{int(rng.randint(0, 32))}"

    def fr():
        return f"F{int(rng.randint(0, 32))}"

    prog = []
    for _ in range(n):
        r = rng.rand()
        if r < 0.22:
            prog.append(I("addi", dsts=(gr(),), srcs=(gr(),),
                          imm=int(rng.randint(-100, 100))))
        elif r < 0.34:
            prog.append(I("add", dsts=(gr(),), srcs=(gr(), gr())))
        elif r < 0.42:
            prog.append(I("mulld", dsts=(gr(),), srcs=(gr(), gr())))
        elif r < 0.46:
            prog.append(I("divd", dsts=(gr(),), srcs=(gr(), gr())))
        elif r < 0.58:
            prog.append(I("ld", dsts=(gr(),), mem_base=gr(),
                          mem_offset=8 * int(rng.randint(0, 64))))
        elif r < 0.68:
            prog.append(I("std", srcs=(gr(),), mem_base=gr(),
                          mem_offset=8 * int(rng.randint(0, 64))))
        elif r < 0.76:
            prog.append(I("fmadd", dsts=(fr(),), srcs=(fr(), fr(), fr())))
        elif r < 0.84:
            prog.append(I("cmpi", srcs=(gr(),),
                          imm=int(rng.randint(-20, 50))))
        elif r < 0.94:
            prog.append(I("bc", imm=int(rng.randint(0, 4)),
                          target=int(rng.randint(0, n))))
        else:
            prog.append(I("b", target=int(rng.randint(0, n))))
    return prog


def _object_trace(prog):
    trace, _, _ = funcsim.run_reference(prog, MAX_STEPS)
    return trace


@settings(max_examples=40, deadline=None)
@given(st.integers(min_value=0, max_value=10_000),
       st.integers(min_value=8, max_value=64))
def test_commit_cycles_monotone(seed, n):
    trace = _object_trace(random_program(seed, n))
    commits = timing.simulate(trace)
    assert all(b >= a for a, b in zip(commits, commits[1:]))


@settings(max_examples=30, deadline=None)
@given(st.integers(min_value=0, max_value=10_000),
       st.integers(min_value=8, max_value=64),
       st.integers(min_value=1, max_value=8))
def test_commit_width_respected(seed, n, width):
    trace = _object_trace(random_program(seed, n))
    params = timing.TimingParams(commit_width=width)
    commits = timing.simulate(trace, params)
    if commits:
        assert max(Counter(commits).values()) <= width


@settings(max_examples=30, deadline=None)
@given(st.integers(min_value=0, max_value=10_000),
       st.integers(min_value=8, max_value=64),
       st.integers(min_value=1, max_value=8))
def test_columnar_oracle_bitwise_equals_object(seed, n, width):
    """simulate_columnar(Trace) == simulate(List[TraceEntry]) bit for bit
    on random traces, across commit widths."""
    prog = random_program(seed, n)
    trace_ref = _object_trace(prog)
    cprog = compile_program(prog)
    trace_col, _ = funcsim.run_compiled(cprog, MAX_STEPS)
    assert trace_col.pc.tolist() == [e.pc for e in trace_ref]
    params = timing.TimingParams(commit_width=width)
    np.testing.assert_array_equal(
        timing.simulate_columnar(trace_col, params),
        np.asarray(timing.simulate(trace_ref, params), np.int64))


def test_columnar_oracle_on_benchmarks():
    """Full-parameter bitwise equality on real benchmark traces."""
    from repro.isa import progen
    for name in ("505.mcf", "531.deepsjeng", "503.bwaves"):
        bench = progen.build_benchmark(name)
        ref, _, _ = funcsim.run_reference(bench.program, 2_000,
                                          state=progen.fresh_state(bench))
        col, _ = funcsim.run_compiled(bench.compiled(), 2_000,
                                      progen.fresh_compiled_state(bench))
        np.testing.assert_array_equal(
            timing.simulate_columnar(col),
            np.asarray(timing.simulate(ref), np.int64))
        assert timing.total_cycles_columnar(col) == \
            timing.total_cycles(ref)
