"""Analytical-ML fusion path: sampler, features, estimator, engine.

The ROADMAP item 4 contracts, as property tests:

  * the stratified sample is deterministic under a seed and covers
    every non-empty stratum with >= min_clips_per_stratum clips,
  * ``fraction=1.0`` is bitwise-equal to the unsampled engine,
  * the bootstrap CI contains the full-prediction estimate on
    synthetic data at the configured level,
  * analytical features are invariant to clip order.
"""
try:
    from hypothesis import given, settings, strategies as st
except ImportError:                    # container without the test extras
    from _hypothesis_compat import given, settings, strategies as st

import jax
import numpy as np
import pytest

from repro.configs import get_config
from repro.core import analytical, predictor
from repro.core import standardize as std_mod
from repro.core.engine import SimulationEngine
from repro.core.engine_config import EngineConfig, SamplingConfig
from repro.core.sampler import stratified_sample
from repro.isa import funcsim, progen

SMALL_CFG = get_config("capsim").replace(d_model=32, head_dim=8, d_ff=64,
                                         dtype="float32")
EC = EngineConfig(interval_size=1_000, warmup=100, max_checkpoints=2,
                  batch_size=16)


@pytest.fixture(scope="module")
def vocab():
    return std_mod.build_vocab()


@pytest.fixture(scope="module")
def params():
    return predictor.init_params(SMALL_CFG, jax.random.PRNGKey(0))


# --------------------------- stratified sampler --------------------------- #

@given(st.integers(1, 200), st.integers(1, 6),
       st.floats(0.05, 1.0), st.integers(1, 3),
       st.integers(0, 2 ** 31), st.integers(0, 32))
@settings(max_examples=40, deadline=None)
def test_stratified_sample_deterministic_and_covering(
        n, n_strata, fraction, min_per, seed, key):
    rng = np.random.default_rng(n)
    strata = rng.integers(0, n_strata, n).astype(np.int32)
    idx1, stats = stratified_sample(strata, fraction, min_per, seed, key)
    idx2, _ = stratified_sample(strata, fraction, min_per, seed, key)
    # deterministic under (seed, key)
    assert np.array_equal(idx1, idx2)
    # sorted, unique, in range
    assert np.all(np.diff(idx1) > 0) if idx1.size > 1 else True
    assert idx1.size == 0 or (idx1.min() >= 0 and idx1.max() < n)
    # every non-empty stratum covered with >= min(min_per, its size)
    taken = strata[idx1]
    for label in np.unique(strata):
        size = int((strata == label).sum())
        got = int((taken == label).sum())
        assert got >= min(min_per, size)
        assert got <= size
    assert stats.n_out == idx1.size and stats.n_in == n


def test_stratified_sample_fraction_one_is_identity():
    strata = np.repeat(np.arange(5), 7)
    idx, stats = stratified_sample(strata, 1.0, 1, seed=3, key=9)
    assert np.array_equal(idx, np.arange(strata.size))
    assert stats.reduction == 1.0


def test_stratified_sample_distinct_keys_draw_independently():
    strata = np.zeros(100, np.int32)
    a, _ = stratified_sample(strata, 0.2, 1, seed=0, key=0)
    b, _ = stratified_sample(strata, 0.2, 1, seed=0, key=1)
    assert not np.array_equal(a, b)


# --------------------------- analytical features --------------------------- #

def test_clip_features_invariant_to_clip_order():
    bench = progen.build_benchmark("505.mcf")
    cprog = bench.compiled()
    st_ = progen.fresh_compiled_state(bench)
    _, st_ = funcsim.run_compiled(cprog, 100, st_)
    trace, _ = funcsim.run_compiled(cprog, 1_000, st_, snapshot_every=100)
    feats = analytical.clip_features(trace, 100)
    assert feats.shape == (len(trace) // 100 + (1 if len(trace) % 100
                                                else 0),
                           analytical.N_FEATURES)
    # each row is a pure function of its own window: recomputing after
    # dropping the FIRST window must reproduce the later full windows
    l_min = 100
    n = len(trace)
    k_full = n // l_min

    import dataclasses as dc
    sub = dc.replace(trace, pc=trace.pc[l_min:], ea=trace.ea[l_min:],
                     taken=trace.taken[l_min:],
                     snapshots=trace.snapshots[1:])
    feats_sub = analytical.clip_features(sub, l_min)
    assert np.array_equal(feats_sub[:k_full - 1], feats[1:k_full])
    # analytical cycles are positive for real windows
    assert (feats[:, -1] > 0).all()


def test_stratify_order_invariance_and_labels():
    rng = np.random.default_rng(0)
    feats = rng.uniform(1, 100, (64, analytical.N_FEATURES))
    s = analytical.stratify(feats, 4)
    assert s.shape == (64,) and s.min() >= 0 and s.max() <= 3
    perm = rng.permutation(64)
    s_perm = analytical.stratify(feats[perm], 4)
    # quantile bins are order statistics: permuting rows permutes labels
    assert np.array_equal(s_perm, s[perm])
    assert analytical.stratify(feats, 1).max() == 0


# ----------------------------- fused estimator ----------------------------- #

def _synthetic(n, seed, noise=0.05):
    """Features + a target that is a noisy affine function of them —
    the regime the ridge residual fit is built for."""
    rng = np.random.default_rng(seed)
    feats = rng.uniform(0.5, 50.0, (n, analytical.N_FEATURES))
    w = rng.uniform(0.1, 1.0, analytical.N_FEATURES)
    y = feats @ w + 5.0 + rng.normal(0, noise * 10, n)
    return feats, np.maximum(y, 0.1)


def test_bootstrap_ci_contains_full_estimate():
    """On synthetic data the 95% CI must contain the full-prediction
    total well above the nominal level (the interval is conservative:
    it is expanded to contain the point estimate)."""
    hits = 0
    trials = 20
    for t in range(trials):
        feats, y = _synthetic(120, seed=t)
        strata = analytical.stratify(feats, 4)
        sampled, _ = stratified_sample(strata, 0.25, 2, seed=t, key=0)
        rep = analytical.fuse_predictions(
            feats, strata, sampled, y[sampled],
            bootstrap_resamples=200, seed=t, key=0)
        lo, hi = rep.cycles_ci
        assert lo <= rep.total_cycles <= hi
        if lo <= float(y.sum()) <= hi:
            hits += 1
    assert hits / trials >= 0.8, f"CI covered only {hits}/{trials}"


def test_fuse_report_accounting():
    feats, y = _synthetic(50, seed=1)
    strata = analytical.stratify(feats, 3)
    sampled, _ = stratified_sample(strata, 0.3, 2, seed=1, key=0)
    rep = analytical.fuse_predictions(feats, strata, sampled, y[sampled],
                                      bootstrap_resamples=25, seed=1)
    assert rep.clips_predicted == sampled.size
    assert rep.clips_extrapolated == 50 - sampled.size
    assert rep.n_clips == 50
    assert rep.clip_provenance.sum() == sampled.size
    assert rep.times.shape == (50,)
    # sampled positions carry the model predictions verbatim
    assert np.array_equal(rep.times[sampled], y[sampled])
    assert rep.ci_width >= 0.0
    # total = sampled sum + extrapolated sum
    expect = float(y[sampled].sum()) + float(
        rep.times[~rep.clip_provenance].sum())
    assert rep.total_cycles == pytest.approx(expect)


def test_fuse_all_sampled_is_exact_sum():
    feats, y = _synthetic(30, seed=2)
    strata = analytical.stratify(feats, 2)
    sampled = np.arange(30, dtype=np.int64)
    y32 = y.astype(np.float32)
    rep = analytical.fuse_predictions(feats, strata, sampled, y32,
                                      bootstrap_resamples=100, seed=2)
    assert rep.total_cycles == float(y32.sum())   # dtype-exact
    assert rep.cycles_ci == (rep.total_cycles, rep.total_cycles)
    assert rep.clips_extrapolated == 0


# ------------------------------ engine wiring ------------------------------ #

def test_fraction_one_bitwise_equal_to_unsampled(params, vocab):
    names = list(progen.TABLE_II)[:2]

    def run(ec):
        eng = SimulationEngine.from_config(params, SMALL_CFG, vocab, ec)
        eng.submit_names(names)
        return eng.run()

    full = run(EC)
    f1 = run(EC.replace(sampling=SamplingConfig(fraction=1.0)))
    for a, b in zip(full, f1):
        assert b.predicted_cycles == a.predicted_cycles   # bitwise
        assert b.n_clips == a.n_clips
        assert b.clips_predicted == a.n_clips
        assert b.clips_extrapolated == 0
        assert b.cycles_ci == (b.predicted_cycles, b.predicted_cycles)
    # sampling=None keeps the report fields at their full-path defaults
    assert full[0].cycles_ci is None
    assert full[0].clips_predicted == full[0].n_clips


def test_engine_subsample_reduces_clips_and_reports(params, vocab):
    names = list(progen.TABLE_II)[:2]
    eng = SimulationEngine.from_config(
        params, SMALL_CFG, vocab,
        EC.replace(sampling=SamplingConfig(fraction=0.25, strata=3,
                                           bootstrap_resamples=30)))
    eng.submit_names(names)
    results = eng.run()
    ref = SimulationEngine.from_config(params, SMALL_CFG, vocab, EC)
    ref.submit_names(names)
    full = ref.run()
    for r, f in zip(results, full):
        assert r.n_clips == f.n_clips
        assert 0 < r.clips_predicted < r.n_clips
        assert r.clips_predicted + r.clips_extrapolated == r.n_clips
        lo, hi = r.cycles_ci
        assert lo <= r.predicted_cycles <= hi
        assert r.clip_provenance.sum() == r.clips_predicted
        # the fused estimate stays in the right ballpark even with a
        # tiny random-init model (sanity, not the full-scale gate)
        assert abs(r.predicted_cycles - f.predicted_cycles) \
            / f.predicted_cycles < 0.5
        # sampled clips fewer: that is the point
        assert eng.last_stats.n_predicted < ref.last_stats.n_predicted
    rep = results[0].prediction_report
    assert rep.total_cycles == results[0].predicted_cycles
    assert rep.n_clips == results[0].n_clips


def test_engine_subsample_deterministic_under_seed(params, vocab):
    ec = EC.replace(sampling=SamplingConfig(fraction=0.3, strata=3,
                                            seed=5, bootstrap_resamples=10))

    def run():
        eng = SimulationEngine.from_config(params, SMALL_CFG, vocab, ec)
        eng.submit_names(list(progen.TABLE_II)[:1])
        return eng.run()[0]

    a, b = run(), run()
    assert a.predicted_cycles == b.predicted_cycles
    assert a.cycles_ci == b.cycles_ci
    assert np.array_equal(a.clip_provenance, b.clip_provenance)
