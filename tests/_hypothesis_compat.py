"""Minimal stand-in for the slice of the hypothesis API these tests use.

CI installs real hypothesis via ``pip install -e .[test]`` and this module
is never imported.  In hermetic containers without the test extras, the
property tests fall back to this shim: deterministic pseudo-random example
generation with the same ``@given``/``@settings``/``strategies`` surface
(no shrinking, no database — just honest example sweeps).
"""
from __future__ import annotations

import random
from types import SimpleNamespace

_SEED = 0xCA951


class _Strategy:
    def __init__(self, draw):
        self.draw = draw


def _integers(min_value: int, max_value: int) -> _Strategy:
    return _Strategy(lambda rng: rng.randint(min_value, max_value))


def _lists(elem: _Strategy, min_size: int = 0,
           max_size: int = 10) -> _Strategy:
    def draw(rng):
        n = rng.randint(min_size, max_size)
        return [elem.draw(rng) for _ in range(n)]
    return _Strategy(draw)


def _floats(min_value: float, max_value: float) -> _Strategy:
    return _Strategy(lambda rng: rng.uniform(min_value, max_value))


strategies = SimpleNamespace(integers=_integers, lists=_lists,
                             floats=_floats)


def settings(max_examples: int = 20, deadline=None, **_kw):
    def deco(fn):
        fn._max_examples = max_examples
        return fn
    return deco


def given(*strats: _Strategy):
    def deco(fn):
        def wrapper():
            n = getattr(wrapper, "_max_examples", 20)
            rng = random.Random(_SEED)
            for _ in range(n):
                vals = [s.draw(rng) for s in strats]
                try:
                    fn(*vals)
                except AssertionError as e:
                    raise AssertionError(
                        f"{e}\nFalsifying example "
                        f"(no-hypothesis fallback): {vals!r}") from e
        # NOT functools.wraps: pytest must see a zero-arg signature, or it
        # would treat the wrapped function's parameters as fixtures.
        wrapper.__name__ = fn.__name__
        wrapper.__doc__ = fn.__doc__
        wrapper._max_examples = getattr(fn, "_max_examples", 20)
        return wrapper
    return deco
