"""Per-architecture smoke tests: reduced config, one forward/train step on
CPU, asserting output shapes and finiteness (no NaNs)."""
import jax
import jax.numpy as jnp
import pytest

from repro.configs import ASSIGNED_ARCH_NAMES, ShapeConfig, get_smoke_config
from repro.distributed.sharding import (
    LOGICAL_RULES_DECODE, LOGICAL_RULES_TRAIN, use_mesh_and_rules)
from repro.launch.mesh import make_test_mesh
from repro.launch.specs import random_batch
from repro.models import transformer as tfm

SMOKE_TRAIN = ShapeConfig("smoke_train", seq_len=64, global_batch=4,
                          kind="train")
SMOKE_DECODE = ShapeConfig("smoke_decode", seq_len=64, global_batch=4,
                           kind="decode")


def _smoke_cfg(name):
    cfg = get_smoke_config(name)
    if cfg.frontend != "none":
        # keep total seq = 64 with a small frontend
        cfg = cfg.replace(frontend_len=min(cfg.frontend_len, 8))
    return cfg


@pytest.mark.parametrize("name", ASSIGNED_ARCH_NAMES)
def test_train_step_smoke(name):
    cfg = _smoke_cfg(name)
    mesh = make_test_mesh()
    with use_mesh_and_rules(mesh, LOGICAL_RULES_TRAIN):
        params = tfm.init_params(cfg, jax.random.PRNGKey(0))
        batch = random_batch(cfg, SMOKE_TRAIN, "train")
        loss_fn = lambda p, b: tfm.loss_fn(p, b, cfg)
        (loss, aux), grads = jax.jit(
            jax.value_and_grad(loss_fn, has_aux=True))(params, batch)
        assert jnp.isfinite(loss), f"{name}: loss not finite"
        assert loss.shape == ()
        gleaves = jax.tree_util.tree_leaves(grads)
        assert all(jnp.all(jnp.isfinite(g)) for g in gleaves), \
            f"{name}: non-finite grads"


@pytest.mark.parametrize("name", ASSIGNED_ARCH_NAMES)
def test_prefill_and_decode_smoke(name):
    cfg = _smoke_cfg(name)
    mesh = make_test_mesh()
    S = SMOKE_DECODE.seq_len
    B = SMOKE_DECODE.global_batch
    with use_mesh_and_rules(mesh, LOGICAL_RULES_DECODE):
        params = tfm.init_params(cfg, jax.random.PRNGKey(0))
        # prefill over S-1 tokens, then decode 1 token at position S-1
        pre_shape = ShapeConfig("pre", S - 1, B, "prefill")
        batch = random_batch(cfg, pre_shape, "prefill")
        logits, _ = jax.jit(lambda p, b: tfm.prefill_step(p, b, cfg))(
            params, batch)
        V = cfg.vocab_size
        exp = (B, S - 1, cfg.num_codebooks, V) if cfg.num_codebooks > 1 \
            else (B, S - 1, V)
        assert logits.shape == exp, f"{name}: prefill logits {logits.shape}"
        assert jnp.all(jnp.isfinite(logits.astype(jnp.float32)))

        caches = tfm.init_cache(cfg, B, S)
        dec_batch = random_batch(cfg, SMOKE_DECODE, "decode")
        step = jax.jit(
            lambda p, b, c, pos: tfm.decode_step(p, b, cfg, c, pos))
        logits2, new_caches = step(params, dec_batch, caches,
                                   jnp.int32(S - 1))
        exp2 = (B, 1, cfg.num_codebooks, V) if cfg.num_codebooks > 1 \
            else (B, 1, V)
        assert logits2.shape == exp2
        assert jnp.all(jnp.isfinite(logits2.astype(jnp.float32)))
        assert new_caches is not None


@pytest.mark.parametrize("name", ["jamba-1.5-large-398b", "mamba2-780m",
                                  "kimi-k2-1t-a32b"])
def test_bf16_dtype_stability(name):
    """Regression: bf16 activations must survive the scanned layer stack
    (an f32 leak through the SSD carry broke jamba/mamba2 cells in the
    dry-run; scan requires carry dtype stability)."""
    cfg = _smoke_cfg(name).replace(dtype="bfloat16",
                                   param_dtype="bfloat16")
    mesh = make_test_mesh()
    with use_mesh_and_rules(mesh, LOGICAL_RULES_TRAIN):
        params = tfm.init_params(cfg, jax.random.PRNGKey(0))
        batch = random_batch(cfg, SMOKE_TRAIN, "train")
        loss, _ = tfm.loss_fn(params, batch, cfg)
        assert jnp.isfinite(loss.astype(jnp.float32))
