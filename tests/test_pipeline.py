"""Dataset pipeline, intervals, serving engine, end-to-end CAPSim."""
import jax
import numpy as np
import pytest

from repro.configs import get_config
from repro.core import context as ctx_mod
from repro.core import predictor
from repro.core.engine_config import EngineConfig
from repro.core.intervals import basic_block_leaders, pick_intervals
from repro.core.simulate import capsim_simulate
from repro.core.standardize import build_vocab
from repro.data.dataset import (BuildConfig, batches, build_dataset,
                                shard_range, split_dataset)
from repro.isa import progen
from repro.isa.isa import Instruction
from repro.serving.engine import PredictorEngine, Request

VOCAB = build_vocab()
TINY_BCFG = BuildConfig(interval_size=2_000, warmup=200, max_checkpoints=2,
                        l_min=16, l_clip=32, l_token=16, threshold=20,
                        coef=0.2)
SMALL_CFG = get_config("capsim").replace(
    d_model=32, head_dim=8, d_ff=64, dtype="float32")


@pytest.fixture(scope="module")
def tiny_ds():
    return build_dataset(["503.bwaves", "541.leela"], TINY_BCFG, VOCAB)


def test_build_dataset_shapes(tiny_ds):
    ds = tiny_ds
    assert len(ds) > 10
    assert ds.clip_tokens.shape[1:] == (32, 16)
    assert ds.context_tokens.shape[1:] == (ctx_mod.CONTEXT_LEN,)
    assert (ds.time > 0).all()
    assert (ds.clip_mask.sum(-1) >= TINY_BCFG.l_min).all()
    assert set(ds.bench_names) == {"503.bwaves", "541.leela"}
    # token ids live inside the real vocab
    assert ds.clip_tokens.max() < VOCAB.size
    assert ds.context_tokens.max() < VOCAB.size


def test_split_and_batches(tiny_ds):
    tr, va, te = split_dataset(tiny_ds, seed=3)
    assert len(tr) + len(va) + len(te) == len(tiny_ds)
    b = next(batches(tr, 4))
    assert b["clip_tokens"].shape == (4, 32, 16)
    assert b["time"].shape == (4,)


def test_save_load_roundtrip(tiny_ds, tmp_path):
    p = tmp_path / "ds.npz"
    tiny_ds.save(p)
    from repro.data.dataset import ClipDataset
    ds2 = ClipDataset.load(p)
    np.testing.assert_array_equal(tiny_ds.clip_tokens, ds2.clip_tokens)
    np.testing.assert_array_equal(tiny_ds.time, ds2.time)
    assert ds2.bench_names == tiny_ds.bench_names


def test_shard_range_partitions():
    marks = np.zeros(103, int)
    for h in range(8):
        lo, hi = shard_range(103, h, 8)
        marks[lo:hi] += 1
    assert (marks == 1).all()


def test_pick_intervals_weights():
    b = progen.build_benchmark("505.mcf")
    ivals = pick_intervals(b.program, 8_000, 1_000, k=3)
    assert 1 <= len(ivals) <= 3
    assert abs(sum(i.weight for i in ivals) - 1.0) < 1e-6
    assert all(i.start == i.index * 1_000 for i in ivals)


def test_basic_block_leaders():
    prog = [Instruction("addi", dsts=("R1",), imm=1),
            Instruction("bc", imm=0, target=3),
            Instruction("nop"),
            Instruction("nop"),
            Instruction("b", target=0)]
    leaders = basic_block_leaders(prog)
    # 0: entry; 2: falls after bc@1; 3: bc target; 4: not a leader (pc 3 is
    # not a branch; b@4's own successor is out of range)
    assert leaders.tolist() == [True, False, True, True, False]


def test_serving_engine_multi_request(tiny_ds):
    params = predictor.init_params(SMALL_CFG, jax.random.PRNGKey(0))
    engine = PredictorEngine(params, SMALL_CFG,
                             EngineConfig(batch_size=8))
    n1, n2 = 5, 9
    engine.submit(Request(1, tiny_ds.clip_tokens[:n1],
                          tiny_ds.context_tokens[:n1],
                          tiny_ds.clip_mask[:n1]))
    engine.submit(Request(2, tiny_ds.clip_tokens[n1:n1 + n2],
                          tiny_ds.context_tokens[n1:n1 + n2],
                          tiny_ds.clip_mask[n1:n1 + n2]))
    results = engine.flush()
    assert [r.request_id for r in results] == [1, 2]
    assert results[0].n_clips == n1 and results[1].n_clips == n2
    assert all(r.total_cycles > 0 for r in results)
    # batching across requests == predicting each clip alone
    lone = PredictorEngine(params, SMALL_CFG,
                           EngineConfig(batch_size=8))
    lone.submit(Request(3, tiny_ds.clip_tokens[:n1],
                        tiny_ds.context_tokens[:n1],
                        tiny_ds.clip_mask[:n1]))
    alone = lone.flush()[0]
    np.testing.assert_allclose(alone.total_cycles, results[0].total_cycles,
                               rtol=1e-5)
    # the RT cache persists across flushes: replaying request 1 encodes
    # zero new static rows and reproduces the pooled result bitwise
    rt = engine.rt_stats
    assert rt is not None and rt.n_rows_encoded > 0
    encoded_before = rt.n_rows_encoded
    engine.submit(Request(4, tiny_ds.clip_tokens[:n1],
                          tiny_ds.context_tokens[:n1],
                          tiny_ds.clip_mask[:n1]))
    replay = engine.flush()[0]
    assert rt.n_rows_encoded == encoded_before
    assert replay.total_cycles == results[0].total_cycles
    # and the monolithic reference path agrees
    mono = PredictorEngine(params, SMALL_CFG,
                           EngineConfig(batch_size=8, rt_cache=False))
    mono.submit(Request(5, tiny_ds.clip_tokens[:n1],
                        tiny_ds.context_tokens[:n1],
                        tiny_ds.clip_mask[:n1]))
    assert mono.flush()[0].total_cycles == replay.total_cycles


def test_capsim_simulate_end_to_end():
    bench = progen.build_benchmark("525.x264")
    params = predictor.init_params(SMALL_CFG, jax.random.PRNGKey(0))
    r = capsim_simulate(bench, params, SMALL_CFG, VOCAB,
                        EngineConfig(interval_size=2_000, warmup=200,
                                     max_checkpoints=2, l_min=32,
                                     l_clip=32, batch_size=16))
    assert r.n_intervals == 2
    assert r.n_instructions == 4_000
    assert r.predicted_cycles > 0
    assert r.oracle_cycles > 0
    assert r.rel_error is not None and r.speedup is not None
