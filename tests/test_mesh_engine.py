"""Sharded inference engine: bitwise-equality gates (PR 6 tentpole).

In-process tests cover what a 1-device session can: a ``(1,)`` mesh
still dispatches through ``shard_map`` and must be bitwise equal to the
unsharded path (predict, RT-cache build, demux), and the bucket/align
math.  The real 8-way checks run in a subprocess that forces 8 host CPU
devices before jax initializes (the main pytest process is locked to
its device count at first backend init) — unless this process already
sees 8+ devices (the CI mesh leg), in which case they also run
in-process.
"""
import os
import subprocess
import sys

import jax
import numpy as np
import pytest

from repro.configs import get_config
from repro.core import predictor
from repro.core import standardize as std_mod
from repro.core.engine import BatchedPredictor, SimulationEngine, \
    bucket_sizes
from repro.core.engine_config import EngineConfig
from repro.core.rt_cache import RTCache, encode_bucket
from repro.isa import multicore, progen
from repro.launch.mesh import make_data_mesh

SMALL_CFG = get_config("capsim").replace(d_model=32, head_dim=8, d_ff=64,
                                         dtype="float32")
EC = EngineConfig(interval_size=1_000, warmup=100, max_checkpoints=1,
                  batch_size=16)


@pytest.fixture(scope="module")
def vocab():
    return std_mod.build_vocab()


@pytest.fixture(scope="module")
def params():
    return predictor.init_params(SMALL_CFG, jax.random.PRNGKey(0))


# ------------------------------ pure math ------------------------------ #

def test_bucket_sizes_alignment():
    assert bucket_sizes(256, 1) == (256, 128, 64, 32, 16, 8)
    assert bucket_sizes(32, 8) == (32, 16, 8)
    assert bucket_sizes(16, 8) == (16, 8)
    assert bucket_sizes(64, 8) == (64, 32, 16, 8)
    # every bucket divides by the mesh size and stays >= one row/device
    for bs, align in ((256, 8), (64, 4), (48, 8), (24, 8)):
        sizes = bucket_sizes(bs, align)
        assert sizes[0] == bs
        assert all(s % align == 0 for s in sizes[1:]), (bs, align, sizes)
        assert all(a > b for a, b in zip(sizes, sizes[1:]))
        assert sizes[-1] >= align


def test_encode_bucket_alignment():
    # floor = ENCODE_STABLE_MIN: every pass stays in the shape-stable
    # kernel class (row results independent of the batch dimension)
    assert encode_bucket(5) == 32
    assert encode_bucket(32) == 32
    assert encode_bucket(33) == 64
    assert encode_bucket(100) == 128
    # sharded: align = n_shards * 32 keeps every device's shard in the
    # stable class too
    assert encode_bucket(5, 8 * 32) == 256      # 32 rows/device at n=8
    assert encode_bucket(300, 8 * 32) == 512    # pow2 512 already aligned
    assert encode_bucket(9, 3 * 32) == 96       # non-power-of-two mesh
    assert encode_bucket(9, 3 * 32) % 3 == 0


def test_make_data_mesh_too_many_devices():
    with pytest.raises(ValueError, match="xla_force_host_platform"):
        make_data_mesh(len(jax.devices()) + 1)
    with pytest.raises(ValueError):
        make_data_mesh(0)


# ------------------------- 1-device mesh, in-process ------------------------- #

def test_mesh1_engine_bitwise_equal(params, vocab):
    """A (1,)-mesh engine routes through shard_map yet must be bitwise
    equal to the unsharded engine — predict AND oracle."""
    bench = progen.build_benchmark("505.mcf")
    r0 = SimulationEngine.from_config(params, SMALL_CFG, vocab,
                                      EC).run([bench])[0]
    r1 = SimulationEngine.from_config(
        params, SMALL_CFG, vocab,
        EC.replace(mesh_shape=(1,))).run([bench])[0]
    assert r1.predicted_cycles == r0.predicted_cycles
    assert r1.oracle_cycles == r0.oracle_cycles


def test_mesh1_rt_table_byte_identical(params, vocab):
    bench = progen.build_benchmark("519.lbm")
    cprog = bench.compiled()
    cfg = predictor.inference_config(SMALL_CFG)
    rows = cprog.token_table(vocab, 16)
    c0 = RTCache(params, cfg, 16)
    c1 = RTCache(params, cfg, 16, n_shards=1)
    ids0 = c0.ensure_rows(rows)
    ids1 = c1.ensure_rows(rows)
    assert np.array_equal(ids0, ids1)
    assert np.asarray(c0.table[:c0.n_rows]).tobytes() == \
        np.asarray(c1.table[:c1.n_rows]).tobytes()


def test_mesh1_pool_smaller_than_bucket(params, vocab):
    """Drain with fewer clips than the smallest bucket: the mesh path
    pads with masked zero rows and the demux drops them."""
    rng = np.random.RandomState(0)
    tok = rng.randint(0, vocab.size, (3, 128, SMALL_CFG.clip_tokens)
                      ).astype(np.int32)
    ctx = rng.randint(0, vocab.size, (3, SMALL_CFG.context_tokens)
                      ).astype(np.int32)
    mask = np.ones((3, 128), np.float32)
    ref = BatchedPredictor(params, SMALL_CFG,
                           config=EC.replace(rt_cache=False))
    ref.add(tok, ctx, mask)
    p_ref = ref.drain()
    bp = BatchedPredictor(
        params, SMALL_CFG,
        config=EC.replace(mesh_shape=(1,), rt_cache=False))
    bp.add(tok, ctx, mask)
    preds = bp.drain()
    assert preds.shape == (3,)
    assert bp.stats.n_pad == 5            # padded to the bucket floor 8
    assert np.array_equal(preds, p_ref)


# ------------------------------ 8-way subprocess ------------------------------ #

PROGRAM = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import jax
import numpy as np

from repro.configs import get_config
from repro.core import predictor
from repro.core import standardize as std_mod
from repro.core.engine import BatchedPredictor, SimulationEngine
from repro.core.engine_config import EngineConfig
from repro.isa import multicore, progen

assert len(jax.devices()) == 8, jax.devices()
cfg = get_config("capsim").replace(d_model=32, head_dim=8, d_ff=64,
                                   dtype="float32")
vocab = std_mod.build_vocab()
params = predictor.init_params(cfg, jax.random.PRNGKey(0))
ec = EngineConfig(interval_size=1_000, warmup=100, max_checkpoints=1,
                  batch_size=16)      # buckets (16, 8): all 8-aligned

# 1. single-core run: 8-way mesh bitwise equal to unsharded, predict
#    AND oracle, including the remainder shard padding
benches = [progen.build_benchmark(n) for n in ("505.mcf", "541.leela")]
e0 = SimulationEngine.from_config(params, cfg, vocab, ec)
r0 = e0.run(benches)
e8 = SimulationEngine.from_config(params, cfg, vocab,
                                  ec.replace(mesh_shape=(8,)))
r8 = e8.run(benches)
for a, b in zip(r0, r8):
    assert a.predicted_cycles == b.predicted_cycles, (a.name,)
    assert a.oracle_cycles == b.oracle_cycles, (a.name,)
print("single-core 8-way OK")

# 2. cold sharded RT-cache build: byte-identical table, same row ids
assert e0._rt_cache.n_rows == e8._rt_cache.n_rows
assert np.asarray(e0._rt_cache.table[:e0._rt_cache.n_rows]).tobytes() \
    == np.asarray(e8._rt_cache.table[:e8._rt_cache.n_rows]).tobytes()
print("rt table OK")

# 3. multicore (bench, core) shards demux bitwise per core and summed
mbenches = [multicore.build_multicore_benchmark(n, 2)
            for n in multicore.MULTICORE_NAMES]
m0 = SimulationEngine.from_config(params, cfg, vocab,
                                  ec).run_multicore(mbenches)
m8 = SimulationEngine.from_config(
    params, cfg, vocab,
    ec.replace(mesh_shape=(8,))).run_multicore(mbenches)
for a, b in zip(m0, m8):
    assert a.predicted_cycles == b.predicted_cycles, (a.name,)
    assert a.oracle_cycles == b.oracle_cycles, (a.name,)
    for ca, cb in zip(a.cores, b.cores):
        assert ca.predicted_cycles == cb.predicted_cycles, (ca.name,)
print("multicore 8-way OK")

# 4. pool of 3 clips on an 8-device mesh: pads to a full shard set
#    (bucket floor 8), demux drops the pads, bitwise vs unsharded
rng = np.random.RandomState(0)
tok = rng.randint(0, vocab.size, (3, 128, cfg.clip_tokens)).astype(np.int32)
ctx = rng.randint(0, vocab.size, (3, cfg.context_tokens)).astype(np.int32)
mask = np.ones((3, 128), np.float32)
bp8 = BatchedPredictor(params, cfg,
                       config=ec.replace(mesh_shape=(8,), rt_cache=False))
bp8.add(tok, ctx, mask)
p8 = bp8.drain()
assert p8.shape == (3,) and bp8.stats.n_pad == 5
bp0 = BatchedPredictor(params, cfg, config=ec.replace(rt_cache=False))
bp0.add(tok, ctx, mask)
assert np.array_equal(p8, bp0.drain())
print("tiny pool OK")
print("ALL MESH ENGINE CHECKS PASSED")
"""


def test_mesh8_engine_subprocess():
    r = subprocess.run([sys.executable, "-c", PROGRAM],
                       capture_output=True, text=True, timeout=500,
                       env={**os.environ, "PYTHONPATH": "src",
                            "XLA_FLAGS":
                            "--xla_force_host_platform_device_count=8"})
    assert "ALL MESH ENGINE CHECKS PASSED" in r.stdout, \
        f"stdout:\n{r.stdout}\nstderr:\n{r.stderr[-3000:]}"


@pytest.mark.skipif(len(jax.devices()) < 8,
                    reason="needs 8 devices (CI mesh leg sets "
                           "--xla_force_host_platform_device_count=8)")
def test_mesh8_engine_inprocess(params, vocab):
    """The CI 8-device leg runs the core equality in-process too (no
    subprocess indirection between the gate and the report)."""
    bench = progen.build_benchmark("505.mcf")
    r0 = SimulationEngine.from_config(params, SMALL_CFG, vocab,
                                      EC).run([bench])[0]
    r8 = SimulationEngine.from_config(
        params, SMALL_CFG, vocab,
        EC.replace(mesh_shape=(8,))).run([bench])[0]
    assert r8.predicted_cycles == r0.predicted_cycles
    assert r8.oracle_cycles == r0.oracle_cycles
    mb = multicore.build_multicore_benchmark(
        list(multicore.MULTICORE_NAMES)[0], 2)
    m0 = SimulationEngine.from_config(params, SMALL_CFG, vocab,
                                      EC).run_multicore([mb])[0]
    m8 = SimulationEngine.from_config(
        params, SMALL_CFG, vocab,
        EC.replace(mesh_shape=(8,))).run_multicore([mb])[0]
    assert m8.predicted_cycles == m0.predicted_cycles
    assert all(a.predicted_cycles == b.predicted_cycles
               for a, b in zip(m0.cores, m8.cores))
