"""Standardization transformation (Fig 5) + context matrix (Fig 6)."""
import numpy as np
import pytest

from repro.core.context import (CONTEXT_LEN, TOKENS_PER_REG,
                                context_token_ids)
from repro.core.standardize import (BYTE_TOKENS, CONST, DSTS, DSTS_E, END,
                                    MEM, MEM_E, OPCODE, REP, SRCS, SRCS_E,
                                    build_vocab, encode_clip,
                                    encode_instruction, standardize)
from repro.isa.isa import CONTEXT_REGS, OPCODES, Instruction

VOCAB = build_vocab()


def test_vocab_structure():
    assert VOCAB["<PAD>"] == 0
    assert VOCAB.size < 512                # fits the config's padded table
    # every opcode and register tokenizes
    for op in OPCODES:
        assert op in VOCAB.token_to_id
    for r in CONTEXT_REGS:
        assert r in VOCAB.token_to_id


def test_const_substitution():
    toks = standardize(Instruction("addi", dsts=("R1",), srcs=("R2",),
                                   imm=42))
    assert toks[:3] == [REP, OPCODE, "addi"]
    assert CONST in toks                    # Fig 5a: constants -> <CONST>
    assert "42" not in toks


def test_memory_segment():
    toks = standardize(Instruction("ld", dsts=("R3",), mem_base="R11",
                                   mem_offset=8))
    i = toks.index(MEM)
    assert toks[i:i + 4] == [MEM, "R11", CONST, MEM_E]   # Fig 5b


def test_implicit_registers():
    # Fig 5c: cmpi writes CR implicitly
    toks = standardize(Instruction("cmpi", srcs=("R5",), imm=0))
    d0, d1 = toks.index(DSTS), toks.index(DSTS_E)
    assert "CR" in toks[d0:d1]
    # bl writes LR; branches write NIA and read CIA
    toks = standardize(Instruction("bl", target=3))
    d0, d1 = toks.index(DSTS), toks.index(DSTS_E)
    assert "LR" in toks[d0:d1] and "NIA" in toks[d0:d1]
    s0, s1 = toks.index(SRCS), toks.index(SRCS_E)
    assert "CIA" in toks[s0:s1]
    # bdnz both reads and writes CTR
    toks = standardize(Instruction("bdnz", target=0))
    assert "CTR" in toks[toks.index(DSTS):toks.index(DSTS_E)]
    assert "CTR" in toks[toks.index(SRCS):toks.index(SRCS_E)]


@pytest.mark.parametrize("op", sorted(OPCODES))
def test_all_opcodes_fit_l_token(op):
    info = OPCODES[op]
    inst = Instruction(
        op,
        dsts=("R1",) if not op.startswith(("b", "st", "cmp", "nop")) else (),
        srcs=("R2", "R3", "R4")[: 3 if op == "fmadd" else 2],
        imm=1 if op in ("addi", "cmpi", "bc") else None,
        mem_base="R9" if info.is_load or info.is_store else None,
        target=0 if info.is_branch else None)
    toks = standardize(inst)
    assert toks[0] == REP and toks[-1] == END
    assert len(toks) <= 16
    ids = encode_instruction(inst, VOCAB, 16)
    assert ids.shape == (16,) and ids.dtype == np.int32
    assert ids[0] == VOCAB[REP]


def test_encode_clip_padding():
    insts = [Instruction("nop")] * 5
    toks, mask = encode_clip(insts, VOCAB, l_clip=8, l_token=16)
    assert toks.shape == (8, 16) and mask.shape == (8,)
    assert mask.tolist() == [1, 1, 1, 1, 1, 0, 0, 0]
    assert (toks[5:] == 0).all()


def test_context_tokens():
    snap = {r: 0 for r in CONTEXT_REGS}
    snap["R10"] = 0x0123_4567_89AB_CDEF      # the paper's Fig 6a example
    ids = context_token_ids(snap, VOCAB)
    assert ids.shape == (CONTEXT_LEN,)
    i = CONTEXT_REGS.index("R10") * TOKENS_PER_REG
    assert ids[i] == VOCAB["R10"]
    byte0 = VOCAB[BYTE_TOKENS[0]]
    got = [ids[i + 1 + k] - byte0 for k in range(8)]
    assert got == [0x01, 0x23, 0x45, 0x67, 0x89, 0xAB, 0xCD, 0xEF]
