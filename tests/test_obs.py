"""Observability layer: metrics registry, tracer, flight recorder.

Covers the tentpole's own contracts (thread-safe counters, Prometheus
exposition format, ring wraparound, disabled-mode zero cost) and the
integration path that matters most: an injected NaN demotion in the
real ``SimulationService`` must produce a postmortem JSON whose
tier-transition ledger agrees with the service snapshot's counters.
"""
import json
import threading

import jax
import numpy as np
import pytest

from repro.configs import get_config
from repro.core import predictor
from repro.core.engine_config import EngineConfig, ObservabilityConfig
from repro.core.standardize import build_vocab
from repro.obs import NULL_SPAN, MetricsRegistry, Observability, Tracer
from repro.obs.exporter import serve_metrics
from repro.serving.engine import Request
from repro.serving.faults import FaultInjector
from repro.serving.service import (ServiceSLA, ServiceSnapshot,
                                   SimulationService)

VOCAB = build_vocab()
SMALL_CFG = get_config("capsim").replace(
    d_model=32, head_dim=8, d_ff=64, dtype="float32")


@pytest.fixture(scope="module")
def params():
    return predictor.init_params(SMALL_CFG, jax.random.PRNGKey(0))


def _req(i, n=4):
    rng = np.random.RandomState(i)
    tok = rng.randint(0, VOCAB.size, (n, 128, SMALL_CFG.clip_tokens)
                      ).astype(np.int32)
    ctx = rng.randint(0, VOCAB.size, (n, SMALL_CFG.context_tokens)
                      ).astype(np.int32)
    return Request(i, tok, ctx, np.ones((n, 128), np.float32))


# --------------------------------------------------------------------------- #
# Metrics registry
# --------------------------------------------------------------------------- #

def test_counter_gauge_histogram_basics():
    m = MetricsRegistry()
    c = m.counter("c_total", "c", ("k",)).labels(k="a")
    c.inc()
    c.inc(2.5)
    assert m.value("c_total", k="a") == 3.5
    assert m.value("c_total", k="missing") == 0.0
    g = m.gauge("g", "g", ()).labels()
    g.set(7)
    g.dec(3)
    assert m.value("g") == 4
    h = m.histogram("h_seconds", "h", (), buckets=(1.0, 10.0)).labels()
    h.observe(0.5)
    h.observe(5.0)
    h.observe(50.0)
    [(labels, (total, count))] = m.collect("h_seconds")
    assert count == 3 and total == 55.5


def test_counter_negative_inc_rejected():
    m = MetricsRegistry()
    c = m.counter("n_total", "n", ()).labels()
    with pytest.raises(ValueError):
        c.inc(-1)


def test_registration_idempotent_but_kind_checked():
    m = MetricsRegistry()
    f1 = m.counter("x_total", "x", ("a",))
    f2 = m.counter("x_total", "x", ("a",))
    assert f1 is f2
    with pytest.raises(ValueError):
        m.gauge("x_total", "x", ("a",))
    with pytest.raises(ValueError):
        m.counter("x_total", "x", ("b",))


def test_registry_thread_safety():
    """N writers hammering one counter and one histogram concurrently:
    the final totals must be exact (the registry lock is real)."""
    m = MetricsRegistry()
    c = m.counter("race_total", "r", ("w",))
    h = m.histogram("race_seconds", "r", ())
    n_threads, n_iter = 8, 2_000
    barrier = threading.Barrier(n_threads)

    def work(w):
        handle = c.labels(w=str(w % 2))       # two shared series
        hh = h.labels()
        barrier.wait()
        for _ in range(n_iter):
            handle.inc()
            hh.observe(1.0)

    threads = [threading.Thread(target=work, args=(w,))
               for w in range(n_threads)]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    total = (m.value("race_total", w="0")
             + m.value("race_total", w="1"))
    assert total == n_threads * n_iter
    [(_, (hsum, hcount))] = m.collect("race_seconds")
    assert hcount == n_threads * n_iter and hsum == float(hcount)


def test_prometheus_exposition_golden():
    """Exact text-format golden: HELP/TYPE lines, escaped label values,
    cumulative histogram buckets with +Inf, _sum and _count."""
    m = MetricsRegistry()
    m.counter("req_total", 'requests with "quotes"\nand newline',
              ("tier",)).labels(tier="fused").inc(3)
    m.gauge("depth", "queue depth", ()).labels().set(2.5)
    h = m.histogram("lat_seconds", "latency", (),
                    buckets=(0.1, 1.0)).labels()
    h.observe(0.05)
    h.observe(0.5)
    h.observe(5.0)
    got = m.render_prometheus()
    want = "\n".join([
        '# HELP depth queue depth',
        '# TYPE depth gauge',
        'depth 2.5',
        '# HELP lat_seconds latency',
        '# TYPE lat_seconds histogram',
        'lat_seconds_bucket{le="0.1"} 1',
        'lat_seconds_bucket{le="1"} 2',
        'lat_seconds_bucket{le="+Inf"} 3',
        'lat_seconds_sum 5.55',
        'lat_seconds_count 3',
        '# HELP req_total requests with "quotes"\\nand newline',
        '# TYPE req_total counter',
        'req_total{tier="fused"} 3',
    ]) + "\n"
    assert got == want


def test_snapshot_is_json_roundtrippable():
    m = MetricsRegistry()
    m.counter("a_total", "a", ("x",)).labels(x="1").inc()
    m.histogram("b_seconds", "b", ()).labels().observe(0.2)
    snap = m.snapshot()
    assert json.loads(json.dumps(snap)) == snap


def test_exporter_serves_registry():
    m = MetricsRegistry()
    m.counter("served_total", "s", ()).labels().inc(5)
    server = serve_metrics(m, port=0)
    try:
        import urllib.request
        port = server.server_address[1]
        with urllib.request.urlopen(
                f"http://127.0.0.1:{port}/metrics", timeout=10) as r:
            body = r.read().decode()
            assert r.headers["Content-Type"].startswith("text/plain")
        assert "served_total 5" in body
        with urllib.request.urlopen(
                f"http://127.0.0.1:{port}/healthz", timeout=10) as r:
            assert r.read() == b"ok\n"
    finally:
        server.shutdown()


# --------------------------------------------------------------------------- #
# Tracer
# --------------------------------------------------------------------------- #

def test_disabled_tracer_is_free():
    """Disabled tracing returns THE null span singleton — no per-call
    allocation, no ring append."""
    tr = Tracer(enabled=False)
    assert tr.span("x") is NULL_SPAN
    assert tr.span("y", args={"a": 1}) is NULL_SPAN
    with tr.span("z") as sp:
        pass
    assert sp.seconds == 0.0
    tr.instant("ev")
    tr.record("pre", 0, 100)
    assert tr.spans() == []


def test_ring_wraparound_keeps_last_n():
    tr = Tracer(ring_size=8, enabled=True)
    for i in range(20):
        tr.record(f"s{i}", start_ns=i * 1000, dur_ns=10)
    spans = tr.spans()
    assert len(spans) == 8
    assert [s.name for s in spans] == [f"s{i}" for i in range(12, 20)]


def test_chrome_export_shape():
    tr = Tracer(enabled=True)
    with tr.span("outer", args={"k": "v"}):
        with tr.span("inner"):
            pass
    tr.instant("mark")
    doc = tr.export_chrome()
    events = doc["traceEvents"]
    names = [e["name"] for e in events]
    assert names == ["inner", "outer", "mark"]   # inner closes first
    outer = events[1]
    assert outer["ph"] == "X" and outer["args"]["k"] == "v"
    assert events[0]["args"]["depth"] == 1       # nested under outer
    assert events[2]["ph"] == "i"
    json.dumps(doc)                              # must be serializable


def test_obs_span_records_metrics_and_trace(tmp_path):
    obs = Observability.from_config(
        ObservabilityConfig(trace=True, trace_ring=16))
    with obs.span("unit.work", instance="t0") as sp:
        x = sum(range(100))
    assert x == 4950 and sp.seconds > 0
    assert obs.metrics.value("capsim_span_seconds_total",
                             span="unit.work", instance="t0") \
        == pytest.approx(sp.seconds)
    [rec] = [r for r in obs.tracer.spans() if r.name == "unit.work"]
    assert rec.args["instance"] == "t0"
    out = tmp_path / "trace.json"
    obs.tracer.dump(str(out))
    assert json.loads(out.read_text())["traceEvents"]


# --------------------------------------------------------------------------- #
# ServiceSnapshot
# --------------------------------------------------------------------------- #

def test_service_snapshot_roundtrip_and_stable_keys(params):
    svc = SimulationService(params, SMALL_CFG, EngineConfig(batch_size=8),
                            sla=ServiceSLA())
    snap = svc.snapshot()
    d = snap.to_dict()
    # the frozen key set benches and the CI chaos leg parse
    assert list(d) == [
        "submitted", "statuses", "current_tier", "backoff",
        "healthy_streak", "queued", "queued_clips", "clips_per_s_ewma",
        "n_flushes", "tiers", "faults_fired",
        "abandoned_flush_threads", "abandoned_flush_threads_total"]
    assert list(d["tiers"]) == ["fused_int8", "fused", "rt", "monolithic"]
    assert list(d["tiers"]["rt"]) == [
        "name", "flushes", "clips", "demotions", "promotions",
        "nan_trips", "relerr_trips", "fault_trips", "watchdog_trips",
        "persist_failures"]
    back = ServiceSnapshot.from_dict(json.loads(json.dumps(d)))
    assert back.to_dict() == d
    with pytest.raises(ValueError):
        ServiceSnapshot.from_dict({**d, "bogus": 1})
    # stats() is the thin compat wrapper over the same snapshot
    assert svc.stats() == svc.snapshot().to_dict()


# --------------------------------------------------------------------------- #
# Flight recorder on the real degradation path
# --------------------------------------------------------------------------- #

def test_nan_demotion_writes_consistent_postmortem(params, tmp_path):
    """A forced NaN on the top tier must demote AND dump a postmortem
    whose event ring agrees with the snapshot counters it embeds."""
    flight_dir = tmp_path / "flight"
    config = EngineConfig(
        batch_size=8, faults={"nan_output": 1.0},
        observability=ObservabilityConfig(flight_dir=str(flight_dir)))
    inj = FaultInjector({"nan_output": 1.0}, seed=3)
    inj.set_enabled(False)
    sla = ServiceSLA(watchdog_s=120.0, promote_after=1, check_every=0)
    with SimulationService(params, SMALL_CFG, config, sla=sla,
                           fault_injector=inj) as svc:
        svc.prewarm(_req(0, n=2))
        assert svc.submit(_req(1)).result(timeout=300).status == "ok"
        inj.set_enabled(True)                 # every retire goes NaN now
        res = svc.submit(_req(2)).result(timeout=300)
        inj.set_enabled(False)
        assert res.status in ("degraded", "failed")
        snap = svc.snapshot()
    fl = svc.obs.flight
    assert fl is not None and fl.postmortems
    post = json.loads(open(fl.postmortems[-1]).read())
    assert post["schema_version"] == 1
    assert post["reason"].startswith("demote_")
    assert post["metrics"] is not None
    # ledger consistency: transition events vs embedded snapshot counters
    tiers = post["state"]["tiers"]
    names = list(tiers)
    exp_demote = sum(tiers[n]["demotions"] for n in names[:-1])
    ev = [e for e in post["events"] if e["kind"] == "tier_transition"]
    got_demote = sum(1 for e in ev if e["reason"] != "promotion")
    assert got_demote == exp_demote > 0
    # the nan reason made it into both ledgers
    assert any(e["reason"] == "nan" for e in ev)
    assert sum(t["nan_trips"] for t in tiers.values()) > 0
    # the final live snapshot counts at least as many demotions
    live = sum(t["demotions"] for t in snap.tiers.values())
    assert live >= exp_demote


def test_faults_counter_lands_in_registry():
    from repro.obs import REGISTRY
    from repro.serving.faults import FAULTS_INJECTED_TOTAL
    before = REGISTRY.value(FAULTS_INJECTED_TOTAL, kind="device_error")
    inj = FaultInjector({"device_error": 1.0}, seed=0)
    assert inj.maybe("device_error")
    after = REGISTRY.value(FAULTS_INJECTED_TOTAL, kind="device_error")
    assert after == before + 1
