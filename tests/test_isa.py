"""Functional simulator + O3 timing oracle + benchmark generator."""
import pytest

from repro.isa import funcsim, progen, timing
from repro.isa.isa import Instruction

I = Instruction


def _run(prog, n=1000, st=None):
    return funcsim.run(prog, n, state=st)


def test_arithmetic_and_memory():
    prog = [
        I("addi", dsts=("R1",), imm=7),
        I("addi", dsts=("R2",), imm=5),
        I("add", dsts=("R3",), srcs=("R1", "R2")),     # 12
        I("mulld", dsts=("R4",), srcs=("R3", "R2")),   # 60
        I("std", srcs=("R4",), mem_base="R1", mem_offset=1),
        I("ld", dsts=("R5",), mem_base="R1", mem_offset=1),
        I("divd", dsts=("R6",), srcs=("R5", "R2")),    # 12
    ]
    trace, _, st = _run(prog)
    assert st.regs["R3"] == 12 and st.regs["R4"] == 60
    assert st.regs["R5"] == 60 and st.regs["R6"] == 12
    assert trace[4].ea == 8                            # 7 + 1


def test_branch_loop():
    prog = [
        I("addi", dsts=("R1",), imm=5),
        I("mtctr", srcs=("R1",)),
        I("addi", dsts=("R2",), srcs=("R2",), imm=1),  # loop body
        I("bdnz", target=2),
    ]
    trace, _, st = _run(prog)
    assert st.regs["R2"] == 5                          # 5 iterations
    assert st.regs["CTR"] == 0


def test_call_return():
    prog = [
        I("bl", target=3),
        I("addi", dsts=("R9",), srcs=("R9",), imm=100),
        I("b", target=6),
        I("addi", dsts=("R8",), imm=1),                # fn body
        I("mulld", dsts=("R8",), srcs=("R8", "R8")),
        I("blr"),
    ]
    trace, _, st = _run(prog)
    assert st.regs["R8"] == 1 and st.regs["R9"] == 100


def test_snapshot_at_positions():
    prog = [I("addi", dsts=("R1",), srcs=("R1",), imm=1)] * 10
    _, snaps, _ = funcsim.run(prog, 10, snapshot_at=[0, 3, 7])
    assert len(snaps) == 3
    assert snaps[0]["R1"] == 0 and snaps[1]["R1"] == 3 \
        and snaps[2]["R1"] == 7


# ---------------------------- timing oracle ---------------------------- #

def _trace_of(prog, n=2000, st=None):
    t, _, _ = funcsim.run(prog, n, state=st)
    return t


def test_commit_monotone_and_dependency_chain():
    dep = [I("mulld", dsts=("R1",), srcs=("R1", "R1"))] * 64
    indep = [I("mulld", dsts=(f"R{2 + i % 20}",), srcs=("R1", "R1"))
             for i in range(64)]
    cd = timing.simulate(_trace_of(dep))
    ci = timing.simulate(_trace_of(indep))
    assert all(b >= a for a, b in zip(cd, cd[1:]))
    assert cd[-1] > ci[-1] * 2      # serial chain much slower


def test_commit_width_bound():
    p = timing.TimingParams(commit_width=2)
    prog = [I("addi", dsts=(f"R{i % 28}",), imm=i) for i in range(128)]
    commits = timing.simulate(_trace_of(prog), p)
    from collections import Counter
    per_cycle = Counter(commits)
    assert max(per_cycle.values()) <= 2


def test_cache_miss_cost():
    def stream(stride):
        prog = [
            I("addi", dsts=("R1",), imm=0),
            I("addi", dsts=("R9",), imm=100),
            I("mtctr", srcs=("R9",)),
            I("ld", dsts=("R2",), mem_base="R1", mem_offset=0),
            I("addi", dsts=("R1",), srcs=("R1",), imm=stride),
            I("bdnz", target=3),
        ]
        return timing.total_cycles(_trace_of(prog))
    assert stream(256) > stream(8) * 1.5   # line-crossing strides miss


def test_rob_pressure():
    # one long-latency op followed by many independents: a small ROB stalls
    body = [I("divd", dsts=("R1",), srcs=("R1", "R2"))] + \
           [I("addi", dsts=(f"R{3 + i % 20}",), imm=i) for i in range(256)]
    prog = [I("addi", dsts=("R1",), imm=9), I("addi", dsts=("R2",), imm=2)] \
        + body
    tr = _trace_of(prog)
    big = timing.total_cycles(tr, timing.TimingParams(rob_entries=192))
    small = timing.total_cycles(tr, timing.TimingParams(rob_entries=16))
    assert small >= big


def test_width_monotonicity():
    bench = progen.build_benchmark("525.x264")
    tr = _trace_of(bench.program, 5000, progen.fresh_state(bench))
    wide = timing.total_cycles(tr, timing.TimingParams())
    narrow = timing.total_cycles(
        tr, timing.TimingParams(fetch_width=2, issue_width=2,
                                commit_width=2))
    assert narrow > wide


def test_mispredict_penalty_visible():
    bench = progen.build_benchmark("531.deepsjeng")   # CTRL-tagged
    tr = _trace_of(bench.program, 5000, progen.fresh_state(bench))
    base = timing.total_cycles(tr, timing.TimingParams())
    nopen = timing.total_cycles(
        tr, timing.TimingParams(mispredict_penalty=0))
    assert base > nopen


# ----------------------------- progen suite ----------------------------- #

def test_table_ii_complete():
    benches = progen.all_benchmarks()
    assert len(benches) == 24
    assert sum(b.ckp_num for b in benches) == 623      # Table II total
    sets = {b.set_no for b in benches}
    assert sets == set(progen.SET_NUMBERS)


@pytest.mark.parametrize("name", ["500.perlbench", "505.mcf", "519.lbm",
                                  "548.exchange2", "999.specrand"])
def test_benchmarks_run_forever(name):
    b = progen.build_benchmark(name)
    trace, _, _ = funcsim.run(b.program, 20_000,
                              state=progen.fresh_state(b))
    assert len(trace) == 20_000            # no early exit
    pcs = {e.pc for e in trace}
    assert len(pcs) > len(b.program) // 3  # decent static coverage


def test_tags_have_teeth():
    """MEM-tagged benchmarks should miss the D-cache more than COMP-only."""
    def miss_proxy(name):
        b = progen.build_benchmark(name)
        tr = _trace_of(b.program, 8000, progen.fresh_state(b))
        fast = timing.total_cycles(
            tr, timing.TimingParams(dcache_miss_cycles=2))
        slow = timing.total_cycles(
            tr, timing.TimingParams(dcache_miss_cycles=80))
        return slow / fast
    assert miss_proxy("505.mcf") > miss_proxy("525.x264")
