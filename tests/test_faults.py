"""Chaos layer: injector determinism, config round-trip, injection on
the REAL engine paths, and crash-safety of the checkpoint publishes.

The contract under test: every fault kind fires where the equivalent
real failure would surface (dispatch, retire, store read, persist), a
crash mid-persist never loses the previous generation, and the LATEST
pointer can never be observed truncated or pointing at garbage.
"""
import json
import os
import threading
import warnings

import jax
import numpy as np
import pytest

from repro.checkpoint.ckpt import latest_step, read_manifest, restore, save
from repro.configs import get_config
from repro.core import predictor
from repro.core.engine import BatchedPredictor
from repro.core.engine_config import FAULT_KINDS, EngineConfig
from repro.core.rt_cache import RTCache
from repro.core.standardize import build_vocab
from repro.isa import progen
from repro.serving.faults import FaultInjected, FaultInjector

VOCAB = build_vocab()
SMALL_CFG = get_config("capsim").replace(
    d_model=32, head_dim=8, d_ff=64, dtype="float32")


@pytest.fixture(scope="module")
def params():
    return predictor.init_params(SMALL_CFG, jax.random.PRNGKey(0))


@pytest.fixture(scope="module")
def table():
    cprog = progen.build_benchmark("505.mcf").compiled()
    return cprog.token_table(VOCAB, 16)


def _clips(n=4, seed=0):
    rng = np.random.RandomState(seed)
    tok = rng.randint(0, VOCAB.size, (n, 128, SMALL_CFG.clip_tokens)
                      ).astype(np.int32)
    ctx = rng.randint(0, VOCAB.size, (n, SMALL_CFG.context_tokens)
                      ).astype(np.int32)
    return tok, ctx, np.ones((n, 128), np.float32)


# --------------------------------------------------------------------------- #
# Injector + config plumbing
# --------------------------------------------------------------------------- #

def test_fault_config_round_trips_and_validates():
    cfg = EngineConfig(faults={"nan_output": 0.1, "device_error": 0.05},
                      fault_seed=7)
    back = EngineConfig.from_json(cfg.to_json())
    assert back == cfg and back.faults == cfg.faults
    assert json.loads(cfg.to_json())["faults"] == [
        ["device_error", 0.05], ["nan_output", 0.1]]
    with pytest.raises(ValueError, match="fault"):
        EngineConfig(faults={"meteor_strike": 0.1})
    with pytest.raises(ValueError, match="rate"):
        EngineConfig(faults={"nan_output": 1.5})
    # no faults -> no injector -> zero-overhead healthy path
    assert FaultInjector.from_config(EngineConfig()) is None


def test_injector_deterministic_and_toggleable():
    mk = lambda: FaultInjector({"nan_output": 0.3}, seed=11)
    a, b = mk(), mk()
    draws_a = [a.maybe("nan_output") for _ in range(64)]
    draws_b = [b.maybe("nan_output") for _ in range(64)]
    assert draws_a == draws_b and any(draws_a) and not all(draws_a)
    assert a.fired["nan_output"] == sum(draws_a)
    assert a.set_enabled(False) is True           # returns previous
    assert not any(a.maybe("nan_output") for _ in range(64))
    a.set_enabled(True)
    with pytest.raises(ValueError):
        FaultInjector({"bad_kind": 0.5})
    with pytest.raises(ValueError):
        a.set_rates({"bad_kind": 0.5})


def test_every_kind_is_drawable():
    inj = FaultInjector({k: 1.0 for k in FAULT_KINDS}, seed=0)
    for k in FAULT_KINDS:
        assert inj.maybe(k)


# --------------------------------------------------------------------------- #
# Injection on the real engine paths
# --------------------------------------------------------------------------- #

def test_device_error_raises_from_dispatch(params):
    cfg = EngineConfig(batch_size=8, faults={"device_error": 1.0})
    b = BatchedPredictor(params, SMALL_CFG, config=cfg)
    tok, ctx, mask = _clips()
    with pytest.raises(FaultInjected, match="device_error"):
        b.add(tok, ctx, mask)
        b.drain()


def test_nan_output_corrupts_retired_batch(params):
    cfg = EngineConfig(batch_size=8, faults={"nan_output": 1.0})
    b = BatchedPredictor(params, SMALL_CFG, config=cfg)
    tok, ctx, mask = _clips()
    b.add(tok, ctx, mask)
    out = b.drain()
    assert out.shape == (4,) and np.isnan(out).any()
    # same engine, injection off: clean output (state not poisoned)
    b._faults.set_enabled(False)
    b.reset_context_width()
    b.add(tok, ctx, mask)
    assert np.isfinite(b.drain()).all()


def test_corrupt_rt_read_warns_and_cold_encodes(params, table, tmp_path):
    clean = RTCache(params, SMALL_CFG, 16, store_dir=str(tmp_path),
                    store_extra=VOCAB.signature())
    clean.ensure_rows(table)
    assert clean.persist() is not None

    inj = FaultInjector({"corrupt_rt_read": 1.0})
    with warnings.catch_warnings(record=True) as w:
        warnings.simplefilter("always")
        c2 = RTCache(params, SMALL_CFG, 16, store_dir=str(tmp_path),
                     store_extra=VOCAB.signature(), fault_injector=inj)
    assert any("RT" in str(x.message) or "store" in str(x.message)
               for x in w)
    assert c2.stats.n_rows_loaded == 0            # fell back to cold
    c2.ensure_rows(table)                          # ...and still correct
    np.testing.assert_array_equal(
        np.asarray(clean.table[:clean.n_rows]),
        np.asarray(c2.table[:c2.n_rows]))


def test_crash_persist_keeps_previous_generation(params, table, tmp_path):
    c1 = RTCache(params, SMALL_CFG, 16, store_dir=str(tmp_path),
                 store_extra=VOCAB.signature())
    half = table[: table.shape[0] // 2]
    c1.ensure_rows(half)
    assert c1.persist() is not None                # generation 1

    inj = FaultInjector({"crash_persist": 1.0})
    c2 = RTCache(params, SMALL_CFG, 16, store_dir=str(tmp_path),
                 store_extra=VOCAB.signature(), fault_injector=inj)
    gen1_rows = c2.stats.n_rows_loaded
    assert gen1_rows == c1.n_rows
    c2.ensure_rows(table)                          # grow past gen 1
    with pytest.raises(FaultInjected, match="crash_persist"):
        c2.persist()                               # dies before publish

    # a post-crash process still loads generation 1, uncorrupted
    c3 = RTCache(params, SMALL_CFG, 16, store_dir=str(tmp_path),
                 store_extra=VOCAB.signature())
    assert c3.stats.n_rows_loaded == gen1_rows
    np.testing.assert_array_equal(
        np.asarray(c1.table[:c1.n_rows]), np.asarray(c3.table[:c1.n_rows]))


# --------------------------------------------------------------------------- #
# Checkpoint publish crash-safety (the LATEST-pointer regression)
# --------------------------------------------------------------------------- #

def _state(v=1.0):
    return {"w": np.full((4, 4), v, np.float32)}


def test_crash_before_publish_preserves_latest(tmp_path):
    save(_state(1.0), 1, str(tmp_path))
    assert latest_step(str(tmp_path)) == 1

    def boom():
        raise RuntimeError("simulated death before publish")

    with pytest.raises(RuntimeError):
        save(_state(2.0), 2, str(tmp_path), pre_publish=boom)
    # LATEST still points at the complete generation; no tmp litter
    assert latest_step(str(tmp_path)) == 1
    assert not [d for d in os.listdir(tmp_path) if ".tmp" in d]
    got = restore(_state(), 1, str(tmp_path))
    np.testing.assert_array_equal(np.asarray(got["w"]), _state(1.0)["w"])


def test_latest_scan_ignores_stray_tmp_dirs(tmp_path):
    save(_state(), 3, str(tmp_path))
    # a writer that died mid-save leaves a tmp dir; a stale LATEST from
    # a GC race points nowhere — the fallback scan must skip both
    (tmp_path / "step_00000009.tmp0-4242-7").mkdir()
    (tmp_path / "LATEST").write_text("9")
    assert latest_step(str(tmp_path)) == 3


def test_concurrent_saves_last_writer_wins(tmp_path):
    # many threads race the SAME step: writer-unique tmp names + the
    # retrying atomic publish mean the final dir is always one writer's
    # complete checkpoint, never a blend or a crash
    errs = []

    def write(v):
        try:
            save(_state(float(v)), 5, str(tmp_path))
        except Exception as exc:                   # pragma: no cover
            errs.append(exc)

    threads = [threading.Thread(target=write, args=(v,))
               for v in range(6)]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    assert not errs
    assert latest_step(str(tmp_path)) == 5
    got = np.asarray(restore(_state(), 5, str(tmp_path))["w"])
    assert float(got[0, 0]) in {float(v) for v in range(6)}
    assert (got == got[0, 0]).all()                # one writer, whole
    assert read_manifest(5, str(tmp_path))["step"] == 5
    assert not [d for d in os.listdir(tmp_path) if ".tmp" in d]
