"""Occurrence-threshold sampler invariants (Fig 3)."""
try:
    from hypothesis import given, settings, strategies as st
except ImportError:                    # container without the test extras
    from _hypothesis_compat import given, settings, strategies as st

from repro.core.sampler import (group_by_content, occurrence_histogram,
                                sample_clips)
from repro.core.slicer import Clip
from repro.isa.isa import Instruction


def _clip(tag: int, start: int) -> Clip:
    # distinct op streams per tag -> distinct content keys
    insts = [Instruction("addi", dsts=(f"R{tag % 28}",), imm=tag)] * 3
    return Clip(insts=insts, time=float(tag + 1), start=start)


def _make(counts):
    clips = []
    pos = 0
    for tag, n in enumerate(counts):
        for _ in range(n):
            clips.append(_clip(tag, pos))
            pos += 3
    return clips


def test_frequent_thinned_rare_category_sampled():
    clips = _make([100, 80, 2, 2, 2, 2, 2, 2, 2, 2, 2, 2])
    sampled, stats = sample_clips(clips, threshold=10, coef=0.1)
    assert stats.n_frequent_groups == 2
    assert stats.n_rare_groups == 10
    groups = group_by_content(sampled)
    hist = sorted((len(v) for v in groups.values()), reverse=True)
    # frequent groups: occurrences reduced to ~coef * count
    assert hist[0] == 10 and hist[1] == 8
    # rare groups: ~coef fraction of categories kept, each complete
    assert stats.n_rare_groups_kept == 1
    assert hist[2:] == [2]


@settings(max_examples=30, deadline=None)
@given(st.lists(st.integers(min_value=1, max_value=300), min_size=1,
                max_size=30),
       st.integers(min_value=1, max_value=100))
def test_property_sampler(counts, threshold):
    clips = _make(counts)
    sampled, stats = sample_clips(clips, threshold=threshold, coef=0.05)
    assert stats.n_out == len(sampled) <= stats.n_in == len(clips)
    # sampled clips are a subset (by identity of start offsets)
    starts = {c.start for c in clips}
    assert all(s.start in starts for s in sampled)
    # every frequent group survives with >= 1 occurrence
    in_groups = group_by_content(clips)
    out_groups = group_by_content(sampled)
    for key, idxs in in_groups.items():
        if len(idxs) > threshold:
            assert key in out_groups and len(out_groups[key]) >= 1
    # determinism
    sampled2, _ = sample_clips(clips, threshold=threshold, coef=0.05)
    assert [c.start for c in sampled2] == [c.start for c in sampled]


def test_histogram_sorted_desc():
    clips = _make([5, 1, 9, 3])
    assert occurrence_histogram(clips) == [9, 5, 3, 1]
