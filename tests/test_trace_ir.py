"""Columnar trace IR: CompiledProgram round-trips, columnar funcsim ==
object interpreter, gather-tokenize == ClipEncoder, columnar slicing ==
Algorithm 1, columnar dataset build == object reference."""
import numpy as np
import pytest

from repro.core import context as ctx_mod
from repro.core import slicer as slicer_mod
from repro.core import standardize as std_mod
from repro.core.standardize import ClipEncoder, build_vocab
from repro.data.dataset import BuildConfig, build_bench_clips
from repro.isa import funcsim, progen, timing
from repro.isa.compiled import OP_IS_MEM, CompileError, compile_program
from repro.isa.isa import Instruction

I = Instruction
VOCAB = build_vocab()
ALL_NAMES = sorted(progen.TABLE_II)
N_STEPS = 1_200


def _traces(name, n=N_STEPS, snapshot_every=100):
    bench = progen.build_benchmark(name)
    ref = funcsim.run_reference(bench.program, n,
                                state=progen.fresh_state(bench),
                                snapshot_every=snapshot_every)
    col = funcsim.run_compiled(bench.compiled(), n,
                               progen.fresh_compiled_state(bench),
                               snapshot_every=snapshot_every)
    return bench, ref, col


# ------------------------------ round-trip ------------------------------ #

@pytest.mark.parametrize("name", ALL_NAMES)
def test_compiled_program_roundtrips(name):
    prog = progen.build_benchmark(name).program
    cprog = compile_program(prog)
    assert cprog.n_static == len(prog)
    assert cprog.decode() == list(prog)


def test_roundtrip_preserves_zero_valued_fields():
    # imm=0 and target=0 are legitimate and distinct from "absent"
    prog = [I("addi", dsts=("R1",), imm=0),
            I("cmpi", srcs=("R1",), imm=0),
            I("b", target=0)]
    cprog = compile_program(prog)
    assert cprog.decode() == prog


def test_compile_error_falls_back_to_reference():
    # four sources overflow the SoA columns; the object adapter must
    # still execute the program (via run_reference)
    prog = [I("addi", dsts=("R1",), imm=7),
            I("add", dsts=("R2",), srcs=("R1", "R1", "R1", "R1"))]
    with pytest.raises(CompileError):
        compile_program(prog)
    trace, _, st = funcsim.run(prog, 10)
    assert st.regs["R2"] == 14 and len(trace) == 2


# ------------------- columnar interpreter equivalence ------------------- #

@pytest.mark.parametrize("name", ALL_NAMES)
def test_columnar_funcsim_matches_object(name):
    """Trace columns, snapshots, and final MachineState are bitwise equal
    to the object interpreter on every progen benchmark."""
    bench, (tr_ref, snaps_ref, st_ref), (tr_col, st_col) = _traces(name)
    assert tr_col.pc.tolist() == [e.pc for e in tr_ref]
    assert tr_col.ea.tolist() == [e.ea if e.ea is not None else 0
                                  for e in tr_ref]
    assert tr_col.taken.tolist() == [-1 if e.taken is None
                                     else int(e.taken) for e in tr_ref]
    assert tr_col.snapshot_dicts() == snaps_ref
    m = st_col.to_machine()
    assert m.regs == st_ref.regs
    assert m.fregs == st_ref.fregs
    assert m.mem == st_ref.mem
    # the object adapter reproduces TraceEntry semantics exactly
    entries = tr_col.entries()
    assert entries == tr_ref
    is_mem = OP_IS_MEM[tr_col.program.opcode[tr_col.pc]]
    assert all((e.ea is not None) == bool(m_)
               for e, m_ in zip(entries, is_mem))


def test_run_adapter_equals_reference_api():
    bench = progen.build_benchmark("505.mcf")
    out_ref = funcsim.run_reference(bench.program, 800,
                                    state=progen.fresh_state(bench),
                                    snapshot_at=[0, 100, 101, 400])
    out_ada = funcsim.run(bench.program, 800,
                          state=progen.fresh_state(bench),
                          snapshot_at=[0, 100, 101, 400])
    assert out_ada[0] == out_ref[0]
    assert out_ada[1] == out_ref[1]
    assert out_ada[2].regs == out_ref[2].regs


def test_compiled_state_roundtrip():
    st = progen.fresh_state(progen.build_benchmark("541.leela"))
    st.regs["R7"] = 123456789
    st.fregs["F3"] = -2.5
    cst = funcsim.CompiledState.from_machine(st)
    back = cst.to_machine()
    assert back.regs == st.regs and back.fregs == st.fregs
    assert back.mem is st.mem                  # memory adopted by reference
    clone = cst.clone()
    clone.iregs[0] = 99
    clone.mem[0] = 1
    assert cst.iregs[0] != 99 and 0 not in cst.mem


# ---------------------- gather tokenization path ----------------------- #

@pytest.mark.parametrize("name", ["503.bwaves", "520.omnetpp", "557.xz"])
@pytest.mark.parametrize("l_min,l_clip", [(32, 32), (100, 128), (48, 40)])
def test_gather_tokens_match_clip_encoder(name, l_min, l_clip):
    """token_table[trace.pc] gather == ClipEncoder.encode bitwise, full
    clips, remainder, and l_min > l_clip truncation included."""
    bench, _, (trace, _) = _traces(name, n=700, snapshot_every=None)
    cprog = trace.program
    table = cprog.token_table(VOCAB, 16)
    tok, mask = std_mod.encode_fixed_clips(table, trace.pc, l_min, l_clip)

    insts = [cprog.insts[pc] for pc in trace.pc.tolist()]
    clips = slicer_mod.slice_fixed(insts, l_min)
    tok_ref, mask_ref = ClipEncoder(VOCAB, l_clip, 16).encode(
        [c.insts for c in clips])
    assert tok.shape == tok_ref.shape
    np.testing.assert_array_equal(tok, tok_ref)
    np.testing.assert_array_equal(mask, mask_ref)


def test_token_table_matches_encode_instruction():
    cprog = progen.build_benchmark("500.perlbench").compiled()
    table = cprog.token_table(VOCAB, 16)
    assert table.shape == (cprog.n_static, 16) and table.dtype == np.int32
    for i in (0, 1, len(cprog) // 2, len(cprog) - 1):
        np.testing.assert_array_equal(
            table[i], std_mod.encode_instruction(cprog.insts[i], VOCAB, 16))
    assert cprog.token_table(VOCAB, 16) is table       # memoized


def test_context_matrix_matches_dict_path():
    _, (_, snaps_ref, _), (trace, _) = _traces("548.exchange2", n=900)
    got = ctx_mod.context_tokens_from_matrix(trace.snapshots, VOCAB)
    ref = ctx_mod.batch_context_tokens(snaps_ref, VOCAB)
    np.testing.assert_array_equal(got, ref)


# ------------------------- columnar slicing ---------------------------- #

def test_fixed_bounds_match_slice_fixed():
    for n, l_min in [(0, 10), (5, 10), (100, 10), (103, 10), (1, 1)]:
        bounds = slicer_mod.fixed_bounds(n, l_min)
        clips = slicer_mod.slice_fixed([I("nop")] * n, l_min)
        assert bounds.shape == (len(clips), 2)
        for (s, e), c in zip(bounds.tolist(), clips):
            assert s == c.start and e - s == len(c)


def test_slice_trace_columnar_matches_algorithm_1():
    rng = np.random.RandomState(0)
    for _ in range(40):
        n = int(rng.randint(1, 400))
        l_min = int(rng.randint(1, 50))
        commits = np.cumsum(rng.randint(0, 5, size=n)).astype(float)
        insts = [I("nop")] * n
        ref = slicer_mod.slice_trace(insts, commits.tolist(), l_min)
        bounds, times = slicer_mod.slice_trace_columnar(commits, l_min)
        got = slicer_mod.clips_from_columnar(insts, bounds, times)
        assert len(got) == len(ref)
        for a, b in zip(got, ref):
            assert a.start == b.start
            assert len(a) == len(b)
            assert abs(a.time - b.time) < 1e-9
        lens = slicer_mod.clip_lengths(bounds)
        assert lens.tolist() == [len(c) for c in ref]


def test_clip_key_zero_sentinel_fixed():
    """A clip whose content hash is 0 must still memoize (regression:
    the old code used 0 as the 'unset' sentinel and recomputed forever)."""
    clip = slicer_mod.Clip(insts=[I("nop")], time=0.0, start=0, _key=0)
    assert clip.key == 0                       # legit cached value kept
    clip2 = slicer_mod.Clip(insts=[I("nop")], time=0.0, start=0)
    k = clip2.key
    assert clip2._key is not None and clip2.key == k


# ------------------------- dataset columnar ---------------------------- #

def test_columnar_dataset_matches_object_reference():
    """The columnar build (sample=False) is bitwise the old object
    pipeline: object interpreter -> object oracle -> Algorithm 1 ->
    per-clip encode_clip / context_token_ids."""
    import copy
    bcfg = BuildConfig(interval_size=1_500, warmup=150, max_checkpoints=2,
                       l_min=24, l_clip=32, l_token=16, sample=False)
    bench = progen.build_benchmark("541.leela")
    ds = build_bench_clips(bench, bcfg, VOCAB)

    # inline object reference (the pre-IR builder)
    st = progen.fresh_state(bench)
    _, _, st = funcsim.run_reference(bench.program, bcfg.warmup, state=st)
    tok_l, ctx_l, mask_l, time_l = [], [], [], []
    for _ in range(min(bench.ckp_num, bcfg.max_checkpoints)):
        st_ckp = copy.deepcopy(st)
        trace, _, st = funcsim.run_reference(
            bench.program, bcfg.interval_size, state=st)
        commits = timing.simulate(trace, bcfg.timing_params)
        clips = slicer_mod.slice_trace([e.inst for e in trace], commits,
                                       bcfg.l_min)
        starts = [c.start for c in clips]
        _, snaps, _ = funcsim.run_reference(
            bench.program, bcfg.interval_size, state=st_ckp,
            snapshot_at=starts)
        for clip, snap in zip(clips, snaps):
            toks, mask = std_mod.encode_clip(clip.insts, VOCAB,
                                             bcfg.l_clip, bcfg.l_token)
            tok_l.append(toks)
            ctx_l.append(ctx_mod.context_token_ids(snap, VOCAB))
            mask_l.append(mask)
            time_l.append(clip.time)

    assert len(ds) == len(tok_l) > 0
    np.testing.assert_array_equal(ds.clip_tokens, np.stack(tok_l))
    np.testing.assert_array_equal(ds.context_tokens, np.stack(ctx_l))
    np.testing.assert_array_equal(ds.clip_mask, np.stack(mask_l))
    np.testing.assert_array_equal(ds.time,
                                  np.asarray(time_l, np.float32))
