"""Algorithm 1 invariants, unit + property-based."""
import numpy as np

try:
    from hypothesis import given, settings, strategies as st
except ImportError:                    # container without the test extras
    from _hypothesis_compat import given, settings, strategies as st

from repro.core.slicer import slice_fixed, slice_trace, total_time
from repro.isa.isa import Instruction

NOP = Instruction("nop")


def _insts(n):
    return [NOP] * n


def test_basic_slicing():
    # commit times: +1 every instruction -> boundary as soon as len >= l_min
    n = 50
    commits = list(range(1, n + 1))
    clips = slice_trace(_insts(n), commits, l_min=10)
    assert all(len(c) == 10 for c in clips)
    assert len(clips) == 5


def test_times_are_commit_deltas():
    insts = _insts(12)
    commits = [2, 2, 2, 5, 5, 9, 9, 9, 12, 12, 15, 18]
    clips = slice_trace(insts, commits, l_min=4)
    # first boundary at idx >= 4 where time changes
    assert clips[0].time > 0
    for c in clips:
        assert c.time >= 0


def test_same_cycle_group_never_split():
    """A boundary requires TimeNow != TimePrev: instructions committing in
    the same cycle stay in one clip."""
    insts = _insts(30)
    commits = [1] * 10 + [2] * 10 + [3] * 10
    clips = slice_trace(insts, commits, l_min=5)
    for c in clips:
        assert len(c) >= 5
        # boundaries land exactly at cycle edges (multiples of 10 here)
        assert c.start % 10 == 0


@settings(max_examples=50, deadline=None)
@given(st.lists(st.integers(min_value=0, max_value=5), min_size=1,
                max_size=400),
       st.integers(min_value=1, max_value=50))
def test_property_invariants(deltas, l_min):
    commits = np.cumsum(deltas).tolist()
    insts = _insts(len(commits))
    clips = slice_trace(insts, commits, l_min)
    n_covered = sum(len(c) for c in clips)
    assert n_covered <= len(insts)
    for c in clips:
        assert len(c) >= l_min                 # principle 1
        assert c.time >= 0
    # clip starts are non-decreasing and contiguous.  Algorithm 1 seeds b
    # with I[0] (line 3) so the FIRST clip carries one duplicated leading
    # instruction: its successor starts at a.start + len(a) - 1.
    starts = [c.start for c in clips]
    assert starts == sorted(starts)
    for i, (a, b) in enumerate(zip(clips, clips[1:])):
        expected = a.start + len(a) - (1 if i == 0 else 0)
        assert b.start == expected
    # total time telescopes: Algorithm 1 appends InstPrev (one-iteration
    # shift), so the last close at iteration J = sum(lens) - 1 yields
    # total == commits[J - 1] (== 0 for a degenerate first-instruction clip)
    if clips:
        j = n_covered - 1
        expected = commits[j - 1] if j >= 1 else 0.0
        assert abs(total_time(clips) - expected) < 1e-6


@settings(max_examples=25, deadline=None)
@given(st.integers(min_value=1, max_value=300),
       st.integers(min_value=1, max_value=64))
def test_slice_fixed_covers_everything(n, l_min):
    clips = slice_fixed(_insts(n), l_min)
    assert sum(len(c) for c in clips) == n
    for a, b in zip(clips, clips[1:]):
        assert b.start == a.start + len(a)
