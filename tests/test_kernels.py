"""Per-kernel correctness sweeps: Pallas (interpret mode) vs pure-jnp refs."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.flash_attention.ops import flash_attention
from repro.kernels.flash_attention.ref import attention_ref
from repro.kernels.ssd.ops import ssd_scan
from repro.kernels.ssd.ref import ssd_ref

RNG = np.random.RandomState(7)


def _qkv(B, Sq, Skv, H, D, dtype):
    q = RNG.randn(B, Sq, H, D).astype(dtype)
    k = RNG.randn(B, Skv, H, D).astype(dtype)
    v = RNG.randn(B, Skv, H, D).astype(dtype)
    return jnp.asarray(q), jnp.asarray(k), jnp.asarray(v)


FA_CASES = [
    # (B, Sq, Skv, H, D, causal, window, masked)
    (2, 128, 128, 4, 64, True, 0, False),
    (1, 100, 100, 2, 32, True, 0, False),     # non-multiple lengths
    (2, 16, 16, 4, 32, False, 0, True),       # instruction-encoder shape
    (1, 360, 128, 4, 32, False, 0, True),     # block-encoder cross shape
    (2, 256, 256, 2, 64, True, 64, False),    # sliding window
    (1, 1, 257, 2, 128, True, 0, False),      # decode-style single query
    (1, 64, 192, 1, 16, True, 0, False),      # Sq != Skv causal (suffix)
]


@pytest.mark.parametrize("case", FA_CASES)
@pytest.mark.parametrize("dtype", [np.float32, "bfloat16"])
def test_flash_attention_matches_ref(case, dtype):
    B, Sq, Skv, H, D, causal, window, masked = case
    dt = np.float32 if dtype == np.float32 else jnp.bfloat16
    q, k, v = _qkv(B, Sq, Skv, H, D, np.float32)
    q, k, v = q.astype(dt), k.astype(dt), v.astype(dt)
    kvm = None
    if masked:
        m = (RNG.rand(B, Skv) > 0.3).astype(np.float32)
        m[:, 0] = 1.0
        kvm = jnp.asarray(m)
    out = flash_attention(q, k, v, causal=causal, window=window,
                          kv_mask=kvm)
    ref = attention_ref(q, k, v, causal=causal, window=window, kv_mask=kvm)
    tol = 2e-5 if dtype == np.float32 else 2e-2
    err = float(jnp.max(jnp.abs(out.astype(jnp.float32)
                                - ref.astype(jnp.float32))))
    assert err < tol, f"{case} {dtype}: err {err}"


def test_flash_attention_fully_masked_rows_are_zero():
    q, k, v = _qkv(1, 8, 8, 1, 32, np.float32)
    kvm = jnp.zeros((1, 8), jnp.float32)       # nothing valid
    out = flash_attention(q, k, v, causal=False, kv_mask=kvm)
    assert float(jnp.max(jnp.abs(out))) == 0.0


def test_flash_attention_grad_flows():
    q, k, v = _qkv(1, 32, 32, 2, 32, np.float32)

    def f(q, k, v):
        return flash_attention(q, k, v, causal=True).sum()

    g = jax.grad(f)(q, k, v)
    assert np.isfinite(np.asarray(g)).all()


SSD_CASES = [
    # (Bt, S, H, P, N, chunk)
    (2, 64, 4, 32, 64, 16),
    (1, 128, 2, 64, 128, 64),
    (2, 100, 3, 16, 32, 32),                   # padding path
    (1, 256, 8, 64, 128, 256),                 # single chunk
]


@pytest.mark.parametrize("case", SSD_CASES)
@pytest.mark.parametrize("dtype", [np.float32, "bfloat16"])
def test_ssd_matches_ref(case, dtype):
    Bt, S, H, P, N, chunk = case
    dt_ = np.float32 if dtype == np.float32 else jnp.bfloat16
    x = jnp.asarray(RNG.randn(Bt, S, H, P).astype(np.float32) * 0.5
                    ).astype(dt_)
    dt = jnp.asarray(np.abs(RNG.randn(Bt, S, H)).astype(np.float32) * 0.4
                     + 0.01)
    B = jnp.asarray(RNG.randn(Bt, S, N).astype(np.float32) * 0.3
                    ).astype(dt_)
    C = jnp.asarray(RNG.randn(Bt, S, N).astype(np.float32) * 0.3
                    ).astype(dt_)
    A = jnp.asarray(-np.abs(RNG.randn(H)).astype(np.float32) - 0.1)
    y, st = ssd_scan(x, dt, B, C, A, chunk=chunk)
    y_ref, st_ref = ssd_ref(x, dt, B, C, A)
    tol = 2e-3 if dtype == np.float32 else 1e-1
    ey = float(jnp.max(jnp.abs(y.astype(jnp.float32)
                               - y_ref.astype(jnp.float32))))
    es = float(jnp.max(jnp.abs(st - st_ref)))
    assert ey < tol and es < tol, f"{case} {dtype}: y {ey} st {es}"


def test_ssd_state_continuation():
    """Scanning two halves with the kernel equals one full scan (the
    cross-chunk recurrence is exact, not approximate)."""
    Bt, S, H, P, N = 1, 64, 2, 16, 32
    x = jnp.asarray(RNG.randn(Bt, S, H, P).astype(np.float32) * 0.5)
    dt = jnp.asarray(np.abs(RNG.randn(Bt, S, H)).astype(np.float32) * 0.3
                     + 0.01)
    B = jnp.asarray(RNG.randn(Bt, S, N).astype(np.float32) * 0.3)
    C = jnp.asarray(RNG.randn(Bt, S, N).astype(np.float32) * 0.3)
    A = jnp.asarray(np.array([-0.5, -1.0], np.float32))
    _, st_full = ssd_scan(x, dt, B, C, A, chunk=16)
    _, st_ref = ssd_ref(x, dt, B, C, A)
    assert float(jnp.max(jnp.abs(st_full - st_ref))) < 1e-4


def test_sp_attention_q_offset_matches_full():
    """Sequence-parallel prefill correctness: computing each query slice
    with a global q_start offset against the full K/V equals the full
    causal attention (the per-shard computation of sp_prefill_attention)."""
    from repro.models.attention import _causal_attention_chunked
    B, S, H, D = 2, 64, 2, 16
    q = jnp.asarray(RNG.randn(B, S, H, D).astype(np.float32))
    k = jnp.asarray(RNG.randn(B, S, H, D).astype(np.float32))
    v = jnp.asarray(RNG.randn(B, S, H, D).astype(np.float32))
    full = _causal_attention_chunked(q, k, v, 16)
    n_sp = 4
    s_loc = S // n_sp
    parts = [
        _causal_attention_chunked(q[:, i * s_loc:(i + 1) * s_loc], k, v,
                                  16, q_start=i * s_loc)
        for i in range(n_sp)
    ]
    np.testing.assert_allclose(np.asarray(jnp.concatenate(parts, axis=1)),
                               np.asarray(full), rtol=2e-5, atol=2e-5)
