"""Table III: generalization across microarchitecture parameters.

The timing oracle is re-parameterized (FetchWidth / IssueWidth /
CommitWidth / ROBEntry — the paper's five rows); a baseline predictor is
pre-trained on the default configuration, then *fine-tuned* briefly per
variant (the paper's accelerated-training protocol) and evaluated on that
variant's re-timed clips.
"""
from __future__ import annotations

import time

import jax

from benchmarks.common import (BENCH_BCFG, DATA_DIR, VOCAB, bench_cfg,
                               eval_mape, train_model)
from repro.core import predictor
from repro.data.dataset import BuildConfig, build_dataset, split_dataset
from repro.isa.timing import TimingParams

# Table III rows: (fetch, issue, commit, rob)
CONFIGS = [
    ("base_8_8_8_192", dict()),
    ("fetch4", dict(fetch_width=4)),
    ("issue4", dict(issue_width=4)),
    ("commit4", dict(commit_width=4)),
    ("rob128", dict(rob_entries=128)),
]
BENCHES = ["503.bwaves", "505.mcf", "525.x264", "541.leela"]
PRETRAIN_STEPS = 40
FINETUNE_STEPS = 30
BATCH = 8


def _dataset(tag: str, tp: TimingParams):
    path = DATA_DIR / f"params_{tag}.npz"
    if path.exists():
        from repro.data.dataset import ClipDataset
        return ClipDataset.load(path)
    bcfg = BuildConfig(
        interval_size=BENCH_BCFG.interval_size, warmup=BENCH_BCFG.warmup,
        max_checkpoints=BENCH_BCFG.max_checkpoints, l_min=BENCH_BCFG.l_min,
        l_clip=BENCH_BCFG.l_clip, l_token=BENCH_BCFG.l_token,
        threshold=BENCH_BCFG.threshold, coef=BENCH_BCFG.coef,
        timing_params=tp)
    ds = build_dataset(BENCHES, bcfg, VOCAB)
    DATA_DIR.mkdir(parents=True, exist_ok=True)
    ds.save(path)
    return ds


def run(emit) -> None:
    cfg = bench_cfg()
    pred_fn = jax.jit(lambda p, b: predictor.predict_step(p, b, cfg))
    loss_fn = lambda p, b: predictor.mape_loss(p, b, cfg)  # noqa: E731

    base_state = None
    for tag, kw in CONFIGS:
        tp = TimingParams().replace(**kw)
        ds = _dataset(tag, tp)
        train, _, test = split_dataset(ds)
        t0 = time.time()
        if base_state is None:                  # pre-train the baseline
            params = predictor.init_params(cfg, jax.random.PRNGKey(0))
            base_state, _ = train_model(loss_fn, params, train,
                                        steps=PRETRAIN_STEPS,
                                        batch_size=BATCH)
            state = base_state
            steps = PRETRAIN_STEPS
        else:                                   # fine-tune from baseline
            state, _ = train_model(loss_fn, base_state["params"], train,
                                   steps=FINETUNE_STEPS, batch_size=BATCH)
            steps = FINETUNE_STEPS
        mape = eval_mape(pred_fn, state["params"], test)
        emit.emit(f"params.{tag}", (time.time() - t0) * 1e6 / steps,
                  f"test MAPE {mape:.4f} ({steps} steps; paper row "
                  f"~12-13% error)")


if __name__ == "__main__":
    from benchmarks.common import CsvEmitter
    run(CsvEmitter())
