"""Shared infrastructure for the benchmark harness.

Scale note: the paper trains E=128 / 4+4 layers on an RTX 4090; this
container is a single CPU core, so every *training-based* benchmark uses
the structure-faithful "bench scale" (E=64, same 4 heads / 4+4 layers /
full 360-row context matrix, clips of 50-64 instructions) and fewer steps.
The paper-exact model is exercised by examples/train_capsim.py and the
multi-pod dry-run.  Datasets are cached under results/bench_data/.
"""
from __future__ import annotations

import time
from pathlib import Path
from typing import Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.core.standardize import build_vocab
from repro.data.dataset import (BuildConfig, ClipDataset, batches,
                                build_dataset)
from repro.isa import progen
from repro.training.train_loop import (TrainConfig, init_train_state,
                                       make_train_step)

DATA_DIR = Path("results/bench_data")
VOCAB = build_vocab()

# Stamped into every bench JSON (writers) and checked FIRST by the CI
# gate readers: a field rename bumps this and fails the gate loudly
# instead of KeyError-ing halfway through a reader.  v1 = the implicit
# pre-stamp schema; v2 adds the stamp itself + the multicore breakdown;
# v4 adds the predict_stack tier ladder (fused / int8 / fused+int8 warm
# passes) and the rt_store restart block to the --multi artifact; v5
# embeds the end-of-run metrics-registry snapshot in the --multi
# artifact and stamps the --obs-overhead artifact.
BENCH_SCHEMA_VERSION = 5

# The mesh-scaling JSON (bench_speed --mesh) is a NEW artifact with its
# own reader, so it gets its own stamp: v3 = v2 fields + the per-mesh
# clips/sec + RT-build scaling block.  Existing v2 artifacts and their
# gate readers are untouched.
MESH_BENCH_SCHEMA_VERSION = 3

# The serving-service JSON (bench_serving) is likewise its own artifact:
# v1 = per-tenant-level healthy/faulted/recovery phase blocks (p50/p99
# latency, clips/sec, typed-status counts, end-of-phase tier) + the gate
# verdicts; v2 adds the live /metrics probe block (tier-transition
# counters scraped mid-run), the flight-recorder consistency gate, and
# the snapshot-shaped ``stats`` block (ServiceSnapshot keys).
SERVING_BENCH_SCHEMA_VERSION = 2

# The subsample-fusion JSON (bench_speed --subsample) is its own
# artifact too: v5 = per-benchmark full-vs-fused totals (clip ratio,
# added rel err vs the full fused+int8 prediction, bootstrap CI width +
# coverage) and the aggregate gate verdicts.
SUBSAMPLE_BENCH_SCHEMA_VERSION = 5

BENCH_BCFG = BuildConfig(interval_size=6_000, warmup=600,
                         max_checkpoints=2, l_min=50, l_clip=64,
                         l_token=16, threshold=50, coef=0.1)


def bench_cfg():
    return get_config("capsim").replace(
        d_model=64, head_dim=16, d_ff=256, dtype="float32")


def full_cfg():
    return get_config("capsim").replace(dtype="float32")


def get_dataset(names, tag: str, bcfg: Optional[BuildConfig] = None,
                verbose: bool = True) -> ClipDataset:
    """Build-or-load the clip dataset for a benchmark list."""
    DATA_DIR.mkdir(parents=True, exist_ok=True)
    path = DATA_DIR / f"{tag}.npz"
    if path.exists():
        return ClipDataset.load(path)
    t0 = time.time()
    ds = build_dataset(names, bcfg or BENCH_BCFG, VOCAB, verbose=verbose)
    ds.save(path)
    if verbose:
        print(f"  [{tag}] built {len(ds)} clips in {time.time()-t0:.0f}s")
    return ds


def get_set_dataset(set_no: int) -> ClipDataset:
    names = [b.name for b in progen.benchmarks_in_set(set_no)]
    return get_dataset(names, f"set{set_no}")


def get_mixed_dataset(n_benchmarks: int = 12) -> ClipDataset:
    names = list(progen.TABLE_II)[:n_benchmarks]
    return get_dataset(names, f"mixed{n_benchmarks}")


def train_model(loss_fn: Callable, params, train_ds: ClipDataset, *,
                steps: int = 80, batch_size: int = 16, lr: float = 1e-3,
                seed: int = 0, init_state=None, log_every: int = 0
                ) -> Tuple[dict, float]:
    """SGD-momentum training (paper recipe).  Returns (state, final loss)."""
    tcfg = TrainConfig(optimizer="sgdm", base_lr=lr,
                       warmup_steps=max(1, steps // 10), total_steps=steps)
    state = init_state or init_train_state(params, tcfg)
    step = jax.jit(make_train_step(loss_fn, tcfg))
    it = batches(train_ds, batch_size, seed=seed, epochs=100_000)
    loss = float("nan")
    for i in range(steps):
        b = {k: jnp.asarray(v) for k, v in next(it).items()}
        state, m = step(state, b)
        loss = float(m["loss"])
        if log_every and (i + 1) % log_every == 0:
            print(f"    step {i+1:4d} loss {loss:.4f}")
    return state, loss


def eval_mape(predict_fn: Callable, params, ds: ClipDataset,
              batch_size: int = 16) -> float:
    errs = []
    batch_size = max(1, min(batch_size, len(ds)))
    for b in batches(ds, batch_size, shuffle=False):
        bj = {k: jnp.asarray(v) for k, v in b.items()}
        pred = np.asarray(predict_fn(params, bj))
        fact = np.maximum(np.asarray(b["time"]), 1.0)
        errs.extend(np.abs(pred - fact) / fact)
    return float(np.mean(errs)) if errs else float("nan")


def per_bench_mape(predict_fn: Callable, params, ds: ClipDataset,
                   batch_size: int = 16) -> Dict[str, float]:
    names = np.array(ds.bench_names)
    out = {}
    for name in sorted(set(ds.bench_names)):
        sub = ds.select(np.flatnonzero(names == name))
        out[name] = eval_mape(predict_fn, params, sub, batch_size)
    return out


class CsvEmitter:
    """Benchmarks print ``name,us_per_call,derived`` rows via this."""

    def __init__(self):
        self.rows = []

    def emit(self, name: str, us_per_call: float, derived: str = "") -> None:
        self.rows.append((name, us_per_call, derived))
        print(f"{name},{us_per_call:.1f},{derived}")
