"""Benchmark driver: one section per paper table/figure.

    PYTHONPATH=src python -m benchmarks.run [--only speed,accuracy,...]

Emits ``name,us_per_call,derived`` CSV rows (benchmarks/common.CsvEmitter).
Datasets are cached in results/bench_data/ — the first run pays the build.
"""
from __future__ import annotations

import argparse
import time
import traceback

from benchmarks.common import CsvEmitter

SECTIONS = [
    ("sampler", "bench_sampler", "Fig 3/8: clip distribution + sampler"),
    ("kernels", "bench_kernels", "Pallas kernels vs oracles"),
    ("speed", "bench_speed", "Fig 7: CAPSim vs O3-oracle wall time"),
    ("training", "bench_training", "Fig 9: train/val loss curve"),
    ("accuracy", "bench_accuracy", "Fig 10: CAPSim vs LSTM vs no-ctx"),
    ("generalization", "bench_generalization", "Fig 11: 6x6 set matrix"),
    ("params", "bench_params", "Table III: microarch parameter sweep"),
]


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default=None,
                    help="comma-separated section names")
    args = ap.parse_args()
    only = set(args.only.split(",")) if args.only else None

    emit = CsvEmitter()
    print("name,us_per_call,derived")
    failures = []
    for name, module, desc in SECTIONS:
        if only and name not in only:
            continue
        print(f"# === {name}: {desc} ===")
        t0 = time.time()
        try:
            mod = __import__(f"benchmarks.{module}", fromlist=["run"])
            mod.run(emit)
        except Exception as e:  # noqa: BLE001 — keep the suite running
            traceback.print_exc()
            failures.append((name, str(e)))
        print(f"# === {name} done in {time.time()-t0:.0f}s ===")
    if failures:
        print("# FAILURES:", failures)
        raise SystemExit(1)


if __name__ == "__main__":
    main()
