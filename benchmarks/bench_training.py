"""Fig 9: training vs validation loss curve for the predictor.

Trains the bench-scale CAPSim predictor and records the MAPE trajectory on
train batches and a held-out validation split — the paper's convergence
evidence (its run stops near epoch 128; ours is step-scaled).
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp

from benchmarks.common import bench_cfg, eval_mape, get_mixed_dataset
from repro.core import predictor
from repro.data.dataset import batches, split_dataset
from repro.training.train_loop import (TrainConfig, init_train_state,
                                       make_train_step)

STEPS = 50
BATCH = 8
EVAL_EVERY = 20


def run(emit) -> None:
    cfg = bench_cfg()
    ds = get_mixed_dataset()
    train, val, _ = split_dataset(ds)

    tcfg = TrainConfig(optimizer="sgdm", base_lr=1e-3,
                       warmup_steps=STEPS // 10, total_steps=STEPS)
    params = predictor.init_params(cfg, jax.random.PRNGKey(0))
    state = init_train_state(params, tcfg)
    step = jax.jit(make_train_step(
        lambda p, b: predictor.mape_loss(p, b, cfg), tcfg))
    pred_fn = jax.jit(lambda p, b: predictor.predict_step(p, b, cfg))

    curve = []
    it = batches(train, BATCH, epochs=100_000)
    t0 = time.time()
    for i in range(1, STEPS + 1):
        b = {k: jnp.asarray(v) for k, v in next(it).items()}
        state, m = step(state, b)
        if i % EVAL_EVERY == 0 or i == 1:
            vl = eval_mape(pred_fn, state["params"], val)
            curve.append((i, float(m["loss"]), vl))
    us = (time.time() - t0) * 1e6 / STEPS

    pts = " ".join(f"s{i}:tr={tr:.3f}/va={va:.3f}" for i, tr, va in curve)
    emit.emit("training.loss_curve", us, pts)
    gap = curve[-1][2] - curve[-1][1]
    emit.emit("training.generalization_gap", us,
              f"final val-train gap {gap:+.3f} (no-overfit check, Fig 9)")


if __name__ == "__main__":
    from benchmarks.common import CsvEmitter
    run(CsvEmitter())
