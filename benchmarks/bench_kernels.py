"""Kernel microbenchmarks: Pallas flash-attention / SSD vs their oracles.

On this CPU host the Pallas kernels execute in interpret mode (Python), so
their wall time is NOT a TPU performance signal — correctness drift is the
payload here.  The XLA paths (chunked attention / chunked SSD), which are
what actually runs on CPU, are timed for real.
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels.flash_attention.ops import flash_attention
from repro.kernels.flash_attention.ref import attention_ref
from repro.kernels.ssd.ops import ssd_scan
from repro.kernels.ssd.ref import ssd_ref
from repro.models.attention import _causal_attention_chunked
from repro.models.mamba2 import ssd_chunked


def _time(fn, *args, n=5) -> float:
    out = fn(*args)
    jax.block_until_ready(out)
    t0 = time.time()
    for _ in range(n):
        out = fn(*args)
    jax.block_until_ready(out)
    return (time.time() - t0) / n * 1e6


def run(emit) -> None:
    rng = np.random.RandomState(0)
    B, S, H, D = 2, 256, 4, 64
    q = jnp.asarray(rng.randn(B, S, H, D).astype(np.float32))
    k = jnp.asarray(rng.randn(B, S, H, D).astype(np.float32))
    v = jnp.asarray(rng.randn(B, S, H, D).astype(np.float32))

    ref = attention_ref(q, k, v, causal=True)
    out = flash_attention(q, k, v, causal=True)
    drift = float(jnp.max(jnp.abs(out - ref)))
    us = _time(jax.jit(lambda a, b, c: _causal_attention_chunked(
        a, b, c, 128)), q, k, v)
    emit.emit("kernels.attn_chunked_xla", us,
              f"B{B}xS{S}xH{H}xD{D} causal (CPU execution path)")
    emit.emit("kernels.attn_pallas_drift", 0.0,
              f"flash kernel vs ref max|err| {drift:.2e} (interpret mode)")

    Bt, S2, H2, P, N = 2, 256, 4, 64, 128
    x = jnp.asarray(rng.randn(Bt, S2, H2, P).astype(np.float32) * 0.5)
    dt = jnp.asarray(np.abs(rng.randn(Bt, S2, H2)).astype(np.float32) * 0.3
                     + 0.01)
    Bm = jnp.asarray(rng.randn(Bt, S2, N).astype(np.float32) * 0.3)
    Cm = jnp.asarray(rng.randn(Bt, S2, N).astype(np.float32) * 0.3)
    A = jnp.asarray(-np.abs(rng.randn(H2)).astype(np.float32) - 0.1)

    y_ref, st_ref = ssd_ref(x, dt, Bm, Cm, A)
    y_k, st_k = ssd_scan(x, dt, Bm, Cm, A, chunk=64)
    drift2 = float(jnp.max(jnp.abs(y_k - y_ref)))
    us2 = _time(jax.jit(lambda *a: ssd_chunked(*a, 64)), x, dt, Bm, Cm, A)
    emit.emit("kernels.ssd_chunked_xla", us2,
              f"Bt{Bt}xS{S2}xH{H2}xP{P}xN{N} (CPU execution path)")
    emit.emit("kernels.ssd_pallas_drift", 0.0,
              f"SSD kernel vs naive-recurrence ref max|err| {drift2:.2e}")


if __name__ == "__main__":
    from benchmarks.common import CsvEmitter
    run(CsvEmitter())
