"""Fig 3 / Fig 8: clip-content occurrence distribution and sampler behavior.

Reproduces the paper's observation that an interval's clips split into a
few heavily-repeated contents plus a long tail of rare unique contents,
and that the sampler preserves the frequent-category distribution while
thinning occurrences (frequent) / categories (rare).
"""
from __future__ import annotations

import time

import numpy as np

from repro.core.sampler import (group_by_content, occurrence_histogram,
                                sample_clips)
from repro.core.slicer import slice_trace
from repro.isa import funcsim, progen, timing


def run(emit) -> None:
    bench = progen.build_benchmark("503.bwaves")
    st = progen.fresh_state(bench)
    trace, _, _ = funcsim.run(bench.program, 50_000, state=st)
    commits = timing.simulate(trace)
    clips = slice_trace([e.inst for e in trace], commits, l_min=100)

    hist = occurrence_histogram(clips)
    n_above = sum(1 for c in hist if c > 50)
    print(f"# Fig 8: {len(clips)} clips, {len(hist)} unique contents; "
          f"occurrence head {hist[:5]}, {n_above} contents above "
          f"threshold 50")

    t0 = time.time()
    sampled, stats = sample_clips(clips, threshold=50, coef=0.1)
    us = (time.time() - t0) * 1e6

    # distribution preservation among frequent contents
    def freq_dist(cs):
        groups = group_by_content(cs)
        counts = np.array(sorted((len(v) for v in groups.values()),
                                 reverse=True), float)
        return counts / counts.sum() if counts.size else counts

    d_in = freq_dist(clips)[: stats.n_frequent_groups]
    d_out = freq_dist(sampled)[: stats.n_frequent_groups]
    k = min(len(d_in), len(d_out))
    tv = 0.5 * float(np.abs(d_in[:k] / d_in[:k].sum()
                            - d_out[:k] / d_out[:k].sum()).sum()) \
        if k else 0.0

    emit.emit("sampler.reduction", us,
              f"kept {stats.n_out}/{stats.n_in} clips "
              f"({100*stats.reduction:.1f}%)")
    emit.emit("sampler.freq_dist_tv", us,
              f"total-variation drift of frequent-category distribution "
              f"{tv:.3f}")
    emit.emit("sampler.rare_categories", us,
              f"rare groups kept {stats.n_rare_groups_kept}/"
              f"{stats.n_rare_groups}")


if __name__ == "__main__":
    from benchmarks.common import CsvEmitter
    run(CsvEmitter())
