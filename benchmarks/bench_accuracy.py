"""Fig 10: CAPSim vs the Ithemal-style LSTM vs the no-context ablation.

Method 1 (§VI-B): mixed clips from many benchmarks, 80/10/10 split; train
each model with the paper recipe (SGD momentum 0.9, lr 1e-3, MAPE) and
compare test MAPE.  Paper: CAPSim beats LSTM by 15.8% accuracy on average
and beats its own no-context ablation by 6.2%.
"""
from __future__ import annotations

import time

import jax

from benchmarks.common import (bench_cfg, eval_mape, get_mixed_dataset,
                               train_model)
from repro.core import lstm_baseline, predictor
from repro.data.dataset import split_dataset

STEPS = 200
BATCH = 8


def run(emit) -> None:
    cfg = bench_cfg()
    ds = get_mixed_dataset()
    train, _, test = split_dataset(ds)
    print(f"# Fig 10: {len(train)} train / {len(test)} test clips")

    results = {}
    for label, loss_fn, pred_fn, init_fn in [
        ("capsim",
         lambda p, b: predictor.mape_loss(p, b, cfg),
         lambda p, b: predictor.predict_step(p, b, cfg),
         predictor.init_params),
        ("capsim_noctx",
         lambda p, b: predictor.mape_loss(p, b, cfg, use_context=False),
         lambda p, b: predictor.predict_step(p, b, cfg,
                                             use_context=False),
         predictor.init_params),
        ("lstm_ithemal",
         lambda p, b: lstm_baseline.mape_loss(p, b, cfg),
         lambda p, b: lstm_baseline.forward(p, b, cfg),
         lstm_baseline.init_params),
    ]:
        t0 = time.time()
        params = init_fn(cfg, jax.random.PRNGKey(0))
        state, tr_loss = train_model(loss_fn, params, train, steps=STEPS,
                                     batch_size=BATCH)
        mape = eval_mape(jax.jit(pred_fn), state["params"], test)
        secs = time.time() - t0
        results[label] = mape
        emit.emit(f"accuracy.{label}", secs * 1e6 / STEPS,
                  f"test MAPE {mape:.4f} (train loss {tr_loss:.4f}, "
                  f"{STEPS} steps)")

    d_lstm = 100 * (results["lstm_ithemal"] - results["capsim"])
    d_ctx = 100 * (results["capsim_noctx"] - results["capsim"])
    emit.emit("accuracy.delta_vs_lstm", 0.0,
              f"CAPSim better than LSTM by {d_lstm:.1f} MAPE pts "
              "(paper: avg 15.8)")
    emit.emit("accuracy.delta_vs_noctx", 0.0,
              f"context improves MAPE by {d_ctx:.1f} pts (paper: avg 6.2)")


if __name__ == "__main__":
    from benchmarks.common import CsvEmitter
    run(CsvEmitter())
