"""Fig 7: CAPSim (functional sim + batched predictor) vs the O3 oracle.

Honest accounting on this host: the paper compares gem5 (~10^5 inst/s on a
Xeon) against an RTX 4090; here BOTH paths share one CPU core and our
greedy O3 oracle is itself ~5x10^5 inst/s — ~500x faster than gem5 — so an
absolute wall-clock speedup is not reproducible and is reported as-is.
What does reproduce is the *structure* of the paper's claim:

  1. the oracle is inherently sequential: its wall time grows linearly
     with instruction count (measured below),
  2. the predictor path is embarrassingly parallel over clips: per-clip
     cost falls with batch size (measured below, compile amortized),
  3. on the target accelerator the clip batch is one dry-run cell:
     the compiled capsim x serve_clips artifact bounds throughput at
     16384 clips (~2.1M instructions) per step-time (derived below from
     results/dryrun), which is what the paper's Fig-7 GPU bars measure.
"""
from __future__ import annotations

import argparse
import json
import os
import sys
import tempfile
import time
from pathlib import Path

import jax
import jax.numpy as jnp
import numpy as np

if __package__ in (None, ""):     # direct `python benchmarks/bench_speed.py`
    sys.path.insert(0, str(Path(__file__).resolve().parents[1]))

from benchmarks.common import (BENCH_SCHEMA_VERSION,
                               MESH_BENCH_SCHEMA_VERSION,
                               SUBSAMPLE_BENCH_SCHEMA_VERSION, bench_cfg,
                               full_cfg)
from repro.core import context as ctx_mod
from repro.core import predictor
from repro.core import slicer as slicer_mod
from repro.core import standardize as std_mod
from repro.core.engine import SimulationEngine
from repro.core.engine_config import EngineConfig, SamplingConfig
from repro.core.simulate import capsim_simulate
from repro.core.standardize import build_vocab
from repro.isa import funcsim, multicore, progen, timing
from repro.obs import REGISTRY

BENCHES = ["503.bwaves", "505.mcf", "548.exchange2"]


def bench_scale_config(quick: bool) -> EngineConfig:
    """The one scale declaration shared by every engine-based pass
    (--multi / --multicore / --mesh) — previously each pass re-declared
    this as its own kwarg dict."""
    return EngineConfig(interval_size=2_000 if quick else 10_000,
                        max_checkpoints=1 if quick else 2,
                        l_min=100, l_clip=128, l_token=16,
                        batch_size=32 if quick else 64)


def resolve_engine_config(arg, quick: bool) -> EngineConfig:
    """--engine-config as a JSON object (inline or a file path) layered
    over the quick/full scale defaults."""
    config = bench_scale_config(quick)
    if arg:
        text = arg
        if not text.lstrip().startswith("{"):
            text = Path(text).read_text()
        config = config.replace(**json.loads(text))
    return config


def run(emit) -> None:
    vocab = build_vocab()
    cfg = full_cfg()
    params = predictor.init_params(cfg, jax.random.PRNGKey(0))

    # 1. oracle sequential scaling
    bench = progen.build_benchmark("505.mcf")
    times = []
    for n in (5_000, 10_000, 20_000):
        trace, _, _ = funcsim.run(bench.program, n,
                                  state=progen.fresh_state(bench))
        t0 = time.time()
        timing.simulate(trace)
        times.append(time.time() - t0)
    emit.emit("speed.oracle_scaling", times[-1] * 1e6 / 20_000,
              f"oracle seconds for 5k/10k/20k insts: "
              f"{times[0]:.3f}/{times[1]:.3f}/{times[2]:.3f} (linear — "
              "sequential, cannot parallelize)")

    # 2. predictor batch amortization (compile amortized by warmup)
    rng = np.random.RandomState(0)
    def batch(B):
        return {
            "clip_tokens": jnp.asarray(
                rng.randint(0, vocab.size, (B, 128, cfg.clip_tokens)),
                jnp.int32),
            "context_tokens": jnp.asarray(
                rng.randint(0, vocab.size, (B, cfg.context_tokens)),
                jnp.int32),
            "clip_mask": jnp.ones((B, 128), jnp.float32)}
    pred = jax.jit(lambda p, b: predictor.predict_step(p, b, cfg))
    per_clip = {}
    for B in (8, 32):
        b = batch(B)
        jax.block_until_ready(pred(params, b))          # compile+warm
        t0 = time.time()
        jax.block_until_ready(pred(params, b))
        per_clip[B] = (time.time() - t0) / B * 1e6
    emit.emit("speed.predictor_batching", per_clip[32],
              f"us/clip at batch 8 vs 32: {per_clip[8]:.0f} -> "
              f"{per_clip[32]:.0f}: flat per-clip cost on 1 core — the "
              "batch dimension is free parallelism on real accelerators "
              "(see v5e_projection)")

    # 3. end-to-end on this host (compile already amortized above)
    for name in BENCHES:
        bench = progen.build_benchmark(name)
        r = capsim_simulate(bench, params, cfg, vocab,
                            EngineConfig(interval_size=10_000,
                                         max_checkpoints=1,
                                         batch_size=32))
        emit.emit(f"speed.{name}",
                  r.capsim_seconds * 1e6 / max(r.n_instructions, 1),
                  f"oracle {r.oracle_seconds:.2f}s vs capsim "
                  f"{r.capsim_seconds:.2f}s = {r.speedup:.3f}x on 1 CPU "
                  f"core ({r.n_instructions} insts; paper: 2.2-8.3x with "
                  "gem5-vs-GPU cost ratio)")

    # 4. target-accelerator projection from the compiled dry-run cell
    rec_path = Path("results/dryrun/capsim__serve_clips__pod_16x16.json")
    if rec_path.exists():
        rec = json.loads(rec_path.read_text())
        m = rec["scanned"]["memory"]
        traffic = (m["argument_bytes"] + m["output_bytes"]
                   + 2 * m["temp_bytes"])
        step_s = max(traffic / 819e9,
                     (rec["scanned"]["cost"]["flops"] or 0) / 197e12)
        clips = 16_384
        insts = clips * 128
        emit.emit("speed.v5e_projection", step_s * 1e6 / clips,
                  f"serve_clips dry-run: {clips} clips "
                  f"({insts/1e6:.1f}M insts) per {step_s*1e3:.1f}ms pod "
                  f"step = {insts/step_s/1e9:.1f}G inst/s structural "
                  "bound vs oracle 5e5 inst/s/core")


# --------------------------------------------------------------------------- #
# Multi-benchmark throughput: sequential per-benchmark loop vs the engine
# --------------------------------------------------------------------------- #

def _sequential_simulate(bench, params, cfg, vocab, ec: EngineConfig, *,
                         with_oracle=False):
    """The pre-engine, pre-IR ``capsim_simulate`` inference path, kept
    verbatim as the baseline: the *object* interpreter
    (``funcsim.run_reference``), per-clip Python tokenization and context
    loops, fresh ``jax.jit`` per benchmark (re-trace + re-compile),
    per-benchmark remainder padded to a full batch, and a synchronous
    host round-trip after every device batch.  ``ec`` only supplies the
    scale knobs (interval/clip/batch sizes) — the path itself stays the
    seed loop.

    Returns ``(predicted_cycles, oracle_cycles, n_clips,
    frontend_seconds, oracle_seconds, predict_seconds)`` — front-end =
    functional sim + slice + tokenize + context (the part the columnar IR
    replaces); predict = the synchronous device loop incl. the fresh
    compile (the part the RT cache + pooled engine replace).
    """
    interval_size, max_checkpoints = ec.interval_size, ec.max_checkpoints
    l_min, l_clip, l_token = ec.l_min, ec.l_clip, ec.l_token
    batch_size = ec.batch_size
    predict = jax.jit(lambda p, b: predictor.predict_step(p, b, cfg))
    st = progen.fresh_state(bench)
    tok_l, ctx_l, mask_l = [], [], []
    oracle_cycles = 0.0
    fe_seconds = 0.0
    oracle_seconds = 0.0
    for _ in range(min(bench.ckp_num, max_checkpoints)):
        t0 = time.time()
        trace, snaps, st = funcsim.run_reference(
            bench.program, interval_size, state=st, snapshot_every=l_min)
        if not trace:
            fe_seconds += time.time() - t0
            break
        clips = slicer_mod.slice_fixed([e.inst for e in trace], l_min)
        for i, clip in enumerate(clips):
            toks, mask = std_mod.encode_clip(clip.insts, vocab, l_clip,
                                             l_token)
            tok_l.append(toks)
            ctx_l.append(ctx_mod.context_token_ids(
                snaps[min(i, len(snaps) - 1)], vocab))
            mask_l.append(mask)
        fe_seconds += time.time() - t0
        if with_oracle:
            t0 = time.time()
            oracle_cycles += timing.total_cycles(trace)
            oracle_seconds += time.time() - t0
    tok, ctx, mask = np.stack(tok_l), np.stack(ctx_l), np.stack(mask_l)
    n_real = tok.shape[0]
    pad = (-n_real) % batch_size
    if pad:
        tok = np.concatenate([tok, np.repeat(tok[-1:], pad, 0)])
        ctx = np.concatenate([ctx, np.repeat(ctx[-1:], pad, 0)])
        mask = np.concatenate([mask, np.zeros((pad,) + mask.shape[1:],
                                              mask.dtype)])
    preds = []
    t0 = time.time()
    for lo in range(0, tok.shape[0], batch_size):
        batch = {"clip_tokens": jnp.asarray(tok[lo:lo + batch_size]),
                 "context_tokens": jnp.asarray(ctx[lo:lo + batch_size]),
                 "clip_mask": jnp.asarray(mask[lo:lo + batch_size])}
        preds.append(np.asarray(predict(params, batch)))   # sync round-trip
    predict_seconds = time.time() - t0
    return (float(np.concatenate(preds)[:n_real].sum()), oracle_cycles,
            n_real, fe_seconds, oracle_seconds, predict_seconds)


def run_multi(emit, *, n_benchmarks: int = 8, quick: bool = False,
              config: "EngineConfig | None" = None,
              rt_store_dir: "str | None" = None) -> dict:
    """Sequential-vs-engine clips/sec on an n-benchmark mix.

    Sequential = one benchmark at a time through the seed inference loop
    (object interpreter + per-clip Python tokenization: the pre-IR
    baseline).  Engine = columnar trace IR front-end feeding one shared
    clip pool, cached jit, bucketed padding, async double-buffer.
    Per-benchmark predicted cycles AND O3 oracle cycles must agree
    bitwise between the two paths; the front-end (functional sim + slice
    + tokenize + context) throughput ratio is reported alongside the
    end-to-end one, with a per-stage breakdown of where engine host time
    goes.

    On top of the PR-6 passes sits the predict-stack ladder: bf16 and
    int8 precision rungs, the dedup-fused serving step, the fused+int8
    stack, and a store-restart pass that rebuilds a fresh engine against
    the persistent RT store (``rt_store_dir``; a temp dir when None) and
    must adopt the persisted table with zero re-encode, bitwise equal to
    the fp32 RT pass.
    """
    vocab = build_vocab()
    cfg = bench_cfg() if quick else full_cfg()
    # resolve the kernel choice once so the sequential baseline and every
    # engine variant compare the same numerics on any backend (on TPU all
    # paths get the Pallas kernel; on CPU this is the identity)
    cfg = predictor.inference_config(cfg)
    params = predictor.init_params(cfg, jax.random.PRNGKey(0))
    names = list(progen.TABLE_II)[:n_benchmarks]
    ec = (config or bench_scale_config(quick)).replace(
        warmup=0, with_oracle=False)
    # every RT-cached pass shares one persistent store: passes with
    # identical (params, cfg, vocab) content keys adopt each other's
    # table instead of re-paying the cold encode, and the restart pass
    # below proves a fresh process would do the same
    store_tmp = None
    if rt_store_dir is None:
        store_tmp = tempfile.TemporaryDirectory(prefix="rt_store_bench_")
        rt_store_dir = store_tmp.name

    benches = [progen.build_benchmark(name) for name in names]
    t0 = time.time()
    seq = {}
    seq_oracle = {}
    n_clips = 0
    seq_fe_seconds = 0.0
    seq_oracle_seconds = 0.0
    seq_predict_seconds = 0.0
    for bench in benches:
        cycles, ocycles, k, fe_s, o_s, p_s = _sequential_simulate(
            bench, params, cfg, vocab, ec, with_oracle=True)
        seq[bench.name] = cycles
        seq_oracle[bench.name] = ocycles
        n_clips += k
        seq_fe_seconds += fe_s
        seq_oracle_seconds += o_s
        seq_predict_seconds += p_s
    seq_seconds = time.time() - t0 - seq_oracle_seconds
    seq_cps = n_clips / max(seq_seconds, 1e-9)

    # timed engine runs stay oracle-free so the throughput accounting is
    # exact (host oracle work would overlap the async device pipeline,
    # making a wall-minus-oracle subtraction overstate the engine).  Each
    # variant runs twice: the cold pass pays jit compiles (and the RT
    # table build), the warm pass is the steady-state device cost the
    # predict gate compares.
    def engine_pass(rt_cache, precision=None, n_runs=2, fused=False,
                    store_dir=None):
        engine = SimulationEngine.from_config(
            params, cfg, vocab,
            ec.replace(rt_cache=rt_cache, precision=precision,
                       fused_serving=fused, rt_store_dir=store_dir))
        passes, results = [], None
        prev = {}
        for _ in range(n_runs):
            t0 = time.time()
            results = engine.run(benches)   # reuse the built benchmarks
            rt = engine.last_rt_stats       # (and their compiled caches)
            # cache stats are cumulative over the cache's lifetime —
            # report per-pass deltas so a 2-pass run doesn't double-count
            cum = rt.as_dict() if rt else {}
            delta = {k: v - prev.get(k, 0) for k, v in cum.items()}
            passes.append({"seconds": time.time() - t0,
                           "predict_seconds":
                               engine.last_stats.predict_seconds,
                           "rt_build_seconds":
                               delta.get("rt_build_seconds", 0.0),
                           "rt": delta})
            prev = cum
        return engine, results, passes

    _, res_nc, p_nc = engine_pass(rt_cache=False)
    engine, results, p_rt = engine_pass(rt_cache=True,
                                        store_dir=rt_store_dir)
    eng_seconds = p_rt[0]["seconds"]        # cold: end-to-end accounting
    stats = engine.last_stats
    fe = engine.frontend_stats
    eng_cps = stats.n_clips / max(eng_seconds, 1e-9)
    # per-run RT figures: all encoding happens in the cold pass; the warm
    # pass is pure gather service for one full workload
    rt_rows_encoded = sum(p["rt"]["rt_rows_encoded"] for p in p_rt)
    rt_rows_served = p_rt[-1]["rt"]["rt_rows_served"]
    rt_cache_stats = {"rt_rows_encoded": rt_rows_encoded,
                      "rt_encode_passes":
                          sum(p["rt"]["rt_encode_passes"] for p in p_rt),
                      "rt_rows_served_per_run": rt_rows_served,
                      "rt_rows_avoided_per_run":
                          max(rt_rows_served - rt_rows_encoded, 0),
                      "rt_build_seconds": p_rt[0]["rt_build_seconds"],
                      "rt_build_warm_seconds": p_rt[1]["rt_build_seconds"]}

    # opt-in low-precision mode: relative-error-bounded, never bitwise
    _, res_bf16, p_bf16 = engine_pass(rt_cache=True, precision="bf16",
                                      n_runs=1, store_dir=rt_store_dir)

    def rel_errors(res):
        return {r.name: abs(b.predicted_cycles - r.predicted_cycles)
                / max(abs(r.predicted_cycles), 1e-9)
                for r, b in zip(results, res)}

    bf16_rel = rel_errors(res_bf16)
    bf16_max_rel = max(bf16_rel.values())

    # int8: the storage/accuracy rung below bf16 — per-channel weight
    # fake-quantization at engine build, fp32 compute.  The resolved cfg
    # is the fp32 one, so the jit'd step is already warm from the rt
    # pass; one run suffices (its RT build encodes the quantized table).
    _, res_int8, p_int8 = engine_pass(rt_cache=True, precision="int8",
                                      n_runs=1, store_dir=rt_store_dir)
    int8_rel = rel_errors(res_int8)
    int8_max_rel = max(int8_rel.values())

    # fused serving step: context dedup + weighted attention +
    # precomputed cross K/V, fp32, tolerance-gated vs the unfused pass
    _, res_fused, p_fused = engine_pass(rt_cache=True, fused=True,
                                        store_dir=rt_store_dir)
    fused_rel = rel_errors(res_fused)
    fused_max_rel = max(fused_rel.values())

    # the full stack: int8 weights through the fused step
    _, res_stack, p_stack = engine_pass(rt_cache=True, precision="int8",
                                        fused=True,
                                        store_dir=rt_store_dir)
    stack_rel = rel_errors(res_stack)
    stack_max_rel = max(stack_rel.values())

    # store restart: a FRESH engine under the same content key as the rt
    # pass must adopt the persisted table (zero re-encode, sub-second
    # build) and reproduce the fp32 results bitwise — the "second
    # cold-start" the persistent store exists for
    _, res_restart, p_restart = engine_pass(rt_cache=True, n_runs=1,
                                            store_dir=rt_store_dir)
    restart_rt = p_restart[0]["rt"]
    restart_bitwise = all(
        a.predicted_cycles == b.predicted_cycles
        for a, b in zip(res_restart, results))
    if store_tmp is not None:
        store_tmp.cleanup()

    rt_warm = (p_rt[1]["predict_seconds"] + p_rt[1]["rt_build_seconds"])
    predict_speedup = p_nc[1]["predict_seconds"] / max(rt_warm, 1e-9)
    predict_speedup_cold = ((p_nc[0]["predict_seconds"])
                            / max(p_rt[0]["predict_seconds"]
                                  + p_rt[0]["rt_build_seconds"], 1e-9))
    seq_predict_speedup = seq_predict_seconds / max(rt_warm, 1e-9)

    # the predict-stack tier ladder: every warm tier normalized against
    # the monolithic pooled path so the gate compares like with like
    mono_warm = p_nc[1]["predict_seconds"]
    fused_warm = (p_fused[1]["predict_seconds"]
                  + p_fused[1]["rt_build_seconds"])
    stack_warm = (p_stack[1]["predict_seconds"]
                  + p_stack[1]["rt_build_seconds"])
    tiers = {
        "monolithic_warm_seconds": mono_warm,
        "rt_cold_seconds": (p_rt[0]["predict_seconds"]
                            + p_rt[0]["rt_build_seconds"]),
        "rt_warm_seconds": rt_warm,
        "bf16_warm_seconds": p_bf16[0]["predict_seconds"],
        "int8_warm_seconds": p_int8[0]["predict_seconds"],
        "fused_warm_seconds": fused_warm,
        "fused_int8_warm_seconds": stack_warm}
    predict_stack = {
        "tiers": tiers,
        "tier_speedups_vs_monolithic": {
            k.replace("_seconds", ""): mono_warm / max(v, 1e-9)
            for k, v in tiers.items()
            if k != "monolithic_warm_seconds"},
        "fused_speedup": rt_warm / max(fused_warm, 1e-9),
        "stack_speedup": rt_warm / max(stack_warm, 1e-9),
        "bf16_max_rel_error": bf16_max_rel,
        "int8_max_rel_error": int8_max_rel,
        "fused_max_rel_error": fused_max_rel,
        "stack_max_rel_error": stack_max_rel,
        "rt_store": {
            "store_dir_was_temp": store_tmp is not None,
            "restart_rt_build_seconds": restart_rt.get(
                "rt_build_seconds", 0.0),
            "restart_store_load_seconds": restart_rt.get(
                "rt_store_load_seconds", 0.0),
            "restart_rows_encoded": restart_rt.get("rt_rows_encoded", 0),
            "restart_rows_loaded": restart_rt.get("rt_rows_loaded", 0),
            "restart_bitwise_equal": restart_bitwise}}

    # untimed columnar-oracle pass over the same interval structure the
    # engine executes: the oracle half of the bitwise gate
    eng_oracle = {}
    t0 = time.time()
    for bench in benches:
        cprog = bench.compiled()
        cst = progen.fresh_compiled_state(bench)
        cycles = 0.0
        for _ in range(min(bench.ckp_num, ec.max_checkpoints)):
            tr, cst = funcsim.run_compiled(cprog, ec.interval_size, cst)
            if not len(tr):
                break
            cycles += timing.total_cycles_columnar(tr)
        eng_oracle[bench.name] = cycles
    eng_oracle_seconds = time.time() - t0

    per_bench = {}
    mismatches = []
    for r, r_nc in zip(results, res_nc):
        equal = seq[r.name] == r.predicted_cycles
        # the RT-cache gather path must reproduce the monolithic pooled
        # path bit for bit (fp32): the tentpole's correctness gate
        rt_equal = r_nc.predicted_cycles == r.predicted_cycles
        oracle_equal = seq_oracle[r.name] == eng_oracle[r.name]
        per_bench[r.name] = {"sequential_cycles": seq[r.name],
                             "engine_cycles": r.predicted_cycles,
                             "engine_monolithic_cycles":
                                 r_nc.predicted_cycles,
                             "bitwise_equal": equal,
                             "rt_cache_bitwise_equal": rt_equal,
                             "bf16_rel_error": bf16_rel[r.name],
                             "int8_rel_error": int8_rel[r.name],
                             "fused_rel_error": fused_rel[r.name],
                             "fused_int8_rel_error": stack_rel[r.name],
                             "sequential_oracle_cycles": seq_oracle[r.name],
                             "engine_oracle_cycles": eng_oracle[r.name],
                             "oracle_bitwise_equal": oracle_equal}
        if not (equal and rt_equal and oracle_equal):
            mismatches.append(r.name)
    assert stats.n_clips == n_clips, \
        f"engine saw {stats.n_clips} clips, sequential saw {n_clips}"

    ratio = eng_cps / max(seq_cps, 1e-9)
    fe_ratio = seq_fe_seconds / max(fe.frontend_seconds, 1e-9)
    emit.emit("speed.multi_sequential", seq_seconds * 1e6 / n_clips,
              f"{n_benchmarks} benchmarks one-at-a-time: {n_clips} clips "
              f"in {seq_seconds:.2f}s = {seq_cps:.0f} clips/s (fresh jit "
              "+ full-batch remainder pad per benchmark)")
    emit.emit("speed.multi_engine", eng_seconds * 1e6 / n_clips,
              f"shared pool: {stats.n_batches} batches, {stats.n_pad} pad "
              f"rows in {eng_seconds:.2f}s = {eng_cps:.0f} clips/s = "
              f"{ratio:.2f}x sequential; per-bench cycles "
              f"{'bitwise equal' if not mismatches else 'MISMATCH: ' + str(mismatches)}")
    emit.emit("speed.multi_frontend", fe.frontend_seconds * 1e6
              / max(n_clips, 1),
              f"columnar IR front-end {fe.frontend_seconds:.2f}s vs "
              f"object baseline {seq_fe_seconds:.2f}s = {fe_ratio:.2f}x "
              f"(interpret {fe.interpret_seconds:.2f}s / tokenize "
              f"{fe.tokenize_seconds:.2f}s / context "
              f"{fe.context_seconds:.2f}s)")
    emit.emit("speed.multi_predict", rt_warm * 1e6 / max(n_clips, 1),
              f"RT-cache predict {rt_warm:.2f}s vs monolithic pooled "
              f"{p_nc[1]['predict_seconds']:.2f}s warm = "
              f"{predict_speedup:.2f}x ({rt_rows_encoded} static "
              f"rows encoded once vs {rt_rows_served} dynamic "
              f"rows gathered per run); bf16 max rel err "
              f"{bf16_max_rel:.4%}")
    emit.emit("speed.multi_predict_stack", stack_warm * 1e6
              / max(n_clips, 1),
              f"fused+int8 warm predict {stack_warm:.2f}s = "
              f"{predict_stack['stack_speedup']:.2f}x over warm RT "
              f"({predict_stack['fused_speedup']:.2f}x fused alone); "
              f"rel err fused {fused_max_rel:.2e} int8 "
              f"{int8_max_rel:.4%} stack {stack_max_rel:.4%}; restart "
              f"loaded {predict_stack['rt_store']['restart_rows_loaded']}"
              f" rows, encoded "
              f"{predict_stack['rt_store']['restart_rows_encoded']}, "
              f"build "
              f"{predict_stack['rt_store']['restart_rt_build_seconds']:.2f}s")
    predict = {
        "sequential_seconds": seq_predict_seconds,
        "monolithic_cold_seconds": p_nc[0]["predict_seconds"],
        "monolithic_warm_seconds": p_nc[1]["predict_seconds"],
        "rt_cache_cold_seconds": p_rt[0]["predict_seconds"],
        "rt_cache_warm_seconds": p_rt[1]["predict_seconds"],
        "rt_build_cold_seconds": p_rt[0]["rt_build_seconds"],
        "rt_build_warm_seconds": p_rt[1]["rt_build_seconds"],
        "predict_speedup": predict_speedup,
        "predict_speedup_cold": predict_speedup_cold,
        "sequential_predict_speedup": seq_predict_speedup,
        "monolithic_clips_per_s":
            n_clips / max(p_nc[1]["predict_seconds"], 1e-9),
        "rt_cache_clips_per_s": n_clips / max(rt_warm, 1e-9),
        "bf16_predict_seconds": p_bf16[0]["predict_seconds"],
        "bf16_max_rel_error": bf16_max_rel,
        "rt_cache": rt_cache_stats}
    return {"schema_version": BENCH_SCHEMA_VERSION,
            "n_benchmarks": n_benchmarks, "n_clips": n_clips,
            "quick": quick,
            "predict_stack": {"schema_version": BENCH_SCHEMA_VERSION,
                              "quick": quick, "n_clips": n_clips,
                              **predict_stack},
            "sequential_seconds": seq_seconds,
            "engine_seconds": eng_seconds,
            "sequential_clips_per_s": seq_cps,
            "engine_clips_per_s": eng_cps,
            "engine_speedup": ratio,
            "engine_batches": stats.n_batches,
            "engine_pad_rows": stats.n_pad,
            "all_bitwise_equal": not mismatches,
            "predict": predict,
            "frontend": {
                "schema_version": BENCH_SCHEMA_VERSION,
                "sequential_seconds": seq_fe_seconds,
                "engine": fe.as_dict(),
                "predict_seconds": stats.predict_seconds,
                "sequential_oracle_seconds": seq_oracle_seconds,
                "columnar_oracle_seconds": eng_oracle_seconds,
                "frontend_speedup": fe_ratio,
                **rt_cache_stats,
                "predict_speedup": predict_speedup},
            "per_bench": per_bench,
            # the full registry at end of run: span totals, histograms,
            # per-instance predictor/rt counters — one artifact carries
            # both the derived figures above and their raw source
            "metrics": REGISTRY.snapshot()}


# --------------------------------------------------------------------------- #
# Dataset-build throughput: per-stage breakdown, single- vs multicore
# --------------------------------------------------------------------------- #

def _build_report(stats, seconds: float, n_clips: int) -> dict:
    return {"seconds": seconds,
            "n_clips": n_clips,
            "clips_per_s": n_clips / max(seconds, 1e-9),
            "instructions_per_s":
                stats.n_instructions / max(seconds, 1e-9),
            "stages": stats.as_dict()}


def run_dataset_build(emit, *, quick: bool = False,
                      n_cores: int = 2) -> dict:
    """Dataset-build throughput breakdown (training-side front end).

    Builds the single-core Table-II clip dataset and the N-core mt.*
    dataset through the shared tokenize/sample/shard pipeline, reporting
    build seconds per stage (interpret / oracle / slice / sample /
    replay / tokenize / context) and clips/sec — the perf-trajectory
    artifact for the training subsystem, alongside the inference-side
    front-end breakdown.
    """
    from repro.data.dataset import BuildConfig, BuildStats, build_dataset
    from repro.data.multicore_dataset import (MulticoreBuildConfig,
                                              build_multicore_dataset)

    vocab = build_vocab()
    kw = dict(interval_size=2_000 if quick else 10_000,
              warmup=200 if quick else 1_000,
              max_checkpoints=1 if quick else 2,
              l_min=50, l_clip=64, l_token=16, threshold=50, coef=0.1)
    names = list(progen.TABLE_II)[: 4 if quick else 8]

    stats = BuildStats()
    t0 = time.time()
    ds = build_dataset(names, BuildConfig(**kw), vocab, stats=stats)
    single = _build_report(stats, time.time() - t0, len(ds))
    emit.emit("speed.dataset_build_single",
              single["seconds"] * 1e6 / max(len(ds), 1),
              f"{len(names)} benchmarks -> {len(ds)} clips in "
              f"{single['seconds']:.2f}s = {single['clips_per_s']:.0f} "
              f"clips/s (oracle {stats.oracle_seconds:.2f}s interpret "
              f"{stats.interpret_seconds:.2f}s replay "
              f"{stats.replay_seconds:.2f}s)")

    mc_stats = BuildStats()
    mc_cfg = MulticoreBuildConfig(n_cores=n_cores, **kw)
    t0 = time.time()
    mds = build_multicore_dataset(list(multicore.MULTICORE_NAMES),
                                  mc_cfg, vocab, stats=mc_stats)
    mc = _build_report(mc_stats, time.time() - t0, len(mds))
    mc["n_cores"] = n_cores
    mc["context_len"] = mds.context_len
    emit.emit("speed.dataset_build_multicore",
              mc["seconds"] * 1e6 / max(len(mds), 1),
              f"{len(multicore.MULTICORE_NAMES)} mt benchmarks x "
              f"{n_cores} cores -> {len(mds)} clips in "
              f"{mc['seconds']:.2f}s = {mc['clips_per_s']:.0f} clips/s "
              f"(multicore oracle {mc_stats.oracle_seconds:.2f}s)")
    return {"schema_version": BENCH_SCHEMA_VERSION, "quick": quick,
            "single": single, "multicore": mc}


# --------------------------------------------------------------------------- #
# Multicore: engine (benchmark, core) shards vs sequential per-core path
# --------------------------------------------------------------------------- #

def _sequential_multicore(mb, params, cfg, vocab, ec: EngineConfig, *,
                          quantum, timing_params):
    """The no-engine multicore reference: the SAME interleaved front-end
    (``run_multicore``), but each (core, checkpoint) clip batch predicts
    through its own synchronous monolithic loop with full-batch padding —
    no pooling, no RT cache.  Accumulation mirrors the engine exactly:
    one ``float(chunk.sum())`` per (core, checkpoint) segment, so per-core
    AND summed cycles must agree bitwise with the pooled RT-cache path.
    Returns per-core predicted cycles, per-core oracle cycles
    (``simulate_multicore`` over the recorded interleave), clip counts,
    and the predict wall time.
    """
    interval_size, max_checkpoints = ec.interval_size, ec.max_checkpoints
    l_min, l_clip, l_token = ec.l_min, ec.l_clip, ec.l_token
    batch_size = ec.batch_size
    predict = jax.jit(lambda p, b: predictor.predict_step(p, b, cfg))
    cprogs = mb.compiled()
    tables = [cp.token_table(vocab, l_token) for cp in cprogs]
    states = mb.fresh_states()
    n = mb.n_cores
    pred_cycles = [0.0] * n
    oracle_cycles = [0.0] * n
    clips = [0] * n
    predict_seconds = 0.0
    oracle_seconds = 0.0
    for _ in range(min(mb.ckp_num, max_checkpoints)):
        mtrace = multicore.run_multicore(
            cprogs, interval_size, states, snapshot_every=l_min,
            quantum=quantum)
        if len(mtrace) == 0:
            break
        for c, trace in enumerate(mtrace.cores):
            if not len(trace):
                continue
            tok, mask = std_mod.encode_fixed_clips(
                tables[c], trace.pc, l_min, l_clip)
            ctx_all = ctx_mod.context_tokens_from_matrix(
                trace.snapshots, vocab, core_id=c)
            rows = np.minimum(np.arange(tok.shape[0]), len(ctx_all) - 1)
            ctx = ctx_all[rows]
            k = tok.shape[0]
            pad = (-k) % batch_size
            if pad:
                tok = np.concatenate(
                    [tok, np.zeros((pad,) + tok.shape[1:], tok.dtype)])
                ctx = np.concatenate(
                    [ctx, np.zeros((pad,) + ctx.shape[1:], ctx.dtype)])
                mask = np.concatenate(
                    [mask, np.zeros((pad,) + mask.shape[1:], mask.dtype)])
            preds = []
            t0 = time.time()
            for lo in range(0, tok.shape[0], batch_size):
                batch = {
                    "clip_tokens": jnp.asarray(tok[lo:lo + batch_size]),
                    "context_tokens": jnp.asarray(ctx[lo:lo + batch_size]),
                    "clip_mask": jnp.asarray(mask[lo:lo + batch_size])}
                preds.append(np.asarray(predict(params, batch)))
            predict_seconds += time.time() - t0
            pred_cycles[c] += float(np.concatenate(preds)[:k].sum())
            clips[c] += k
        t0 = time.time()
        totals = timing.total_cycles_multicore(
            mtrace.cores, mtrace.schedule, timing_params)
        oracle_seconds += time.time() - t0
        for c, cyc in enumerate(totals):
            oracle_cycles[c] += cyc
    return (pred_cycles, oracle_cycles, clips, predict_seconds,
            oracle_seconds)


def _columnar_oracle_n1(mb, *, interval_size, max_checkpoints, l_min,
                        timing_params):
    """Single-core anchor: the same intervals through plain
    ``run_compiled`` + ``simulate_columnar`` (no multicore machinery at
    all) — ``simulate_multicore`` at N=1 must match this bitwise."""
    assert mb.n_cores == 1
    cprog = mb.compiled()[0]
    st = mb.fresh_states()[0]
    cycles = 0.0
    for _ in range(min(mb.ckp_num, max_checkpoints)):
        trace, st = funcsim.run_compiled(cprog, interval_size, st,
                                         snapshot_every=l_min)
        if not len(trace):
            break
        cycles += timing.total_cycles_columnar(trace, timing_params)
    return cycles


def run_multicore_bench(emit, *, core_counts=(1, 2, 4),
                        quick: bool = False,
                        config: "EngineConfig | None" = None) -> dict:
    """Engine-vs-sequential equality and throughput at 1/2/4 cores.

    Engine = ``SimulationEngine.run_multicore``: interleaved per-core
    functional sims -> (benchmark, core) shards through one pooled
    RT-cached predictor -> demuxed per-core sums.  Sequential = the same
    front-end with per-(core, checkpoint) monolithic predict loops.  The
    gates (CI-enforced): per-core AND summed predicted cycles bitwise
    equal at every core count; oracle cycles equal between both paths;
    and at N=1 the multicore oracle bitwise equal to
    ``simulate_columnar``.
    """
    vocab = build_vocab()
    cfg = predictor.inference_config(bench_cfg() if quick else full_cfg())
    params = predictor.init_params(cfg, jax.random.PRNGKey(0))
    names = list(multicore.MULTICORE_NAMES)
    tp = timing.TimingParams()
    ec = (config or bench_scale_config(quick)).replace(
        warmup=0, with_oracle=False, rt_cache=True)
    quantum = multicore.DEFAULT_QUANTUM

    per_count = {}
    mismatches = []
    for n_cores in core_counts:
        mbenches = [multicore.build_multicore_benchmark(n, n_cores)
                    for n in names]
        engine = SimulationEngine.from_config(params, cfg, vocab, ec)
        t0 = time.time()
        results = engine.run_multicore(mbenches, quantum=quantum)
        eng_seconds = time.time() - t0
        fe = engine.frontend_stats
        stats = engine.last_stats
        n_clips = stats.n_clips

        t0 = time.time()
        per_bench = {}
        seq_predict_seconds = 0.0
        seq_oracle_seconds = 0.0
        prior_mismatches = len(mismatches)
        for mb, r in zip(mbenches, results):
            seq_pred, seq_oracle, seq_clips, p_s, o_s = \
                _sequential_multicore(mb, params, cfg, vocab, ec,
                                      quantum=quantum, timing_params=tp)
            seq_predict_seconds += p_s
            seq_oracle_seconds += o_s
            cores = []
            core_equal = True
            for c, cr in enumerate(r.cores):
                eq = cr.predicted_cycles == seq_pred[c]
                core_equal &= eq
                assert cr.n_clips == seq_clips[c], \
                    (cr.name, cr.n_clips, seq_clips[c])
                cores.append({"core": c,
                              "engine_cycles": cr.predicted_cycles,
                              "sequential_cycles": seq_pred[c],
                              "oracle_cycles": seq_oracle[c],
                              "n_clips": cr.n_clips,
                              "bitwise_equal": eq})
            summed_seq = 0.0
            for v in seq_pred:
                summed_seq += v
            summed_equal = r.predicted_cycles == summed_seq
            entry = {"cores": cores,
                     "summed_engine_cycles": r.predicted_cycles,
                     "summed_sequential_cycles": summed_seq,
                     "summed_bitwise_equal": summed_equal,
                     "oracle_cycles_total": sum(seq_oracle)}
            if not (core_equal and summed_equal):
                mismatches.append(f"{mb.name}@{n_cores}")
            per_bench[mb.name] = entry
        seq_seconds = (time.time() - t0 - seq_oracle_seconds)
        if n_cores == 1:
            # the single-core oracle anchor runs OUTSIDE the timed
            # window: it is a correctness reference, not part of the
            # sequential path's throughput accounting
            for mb in mbenches:
                entry = per_bench[mb.name]
                ref = _columnar_oracle_n1(
                    mb, interval_size=ec.interval_size,
                    max_checkpoints=ec.max_checkpoints,
                    l_min=ec.l_min, timing_params=tp)
                entry["n1_oracle_columnar_cycles"] = ref
                entry["n1_oracle_bitwise_equal"] = \
                    ref == entry["oracle_cycles_total"]
                if not entry["n1_oracle_bitwise_equal"]:
                    mismatches.append(f"{mb.name}@1:oracle")
        eng_cps = n_clips / max(eng_seconds, 1e-9)
        per_count[str(n_cores)] = {
            "n_clips": n_clips,
            "engine_seconds": eng_seconds,
            "sequential_seconds": seq_seconds,
            "engine_clips_per_s": eng_cps,
            "per_core_clips_per_s": eng_cps / n_cores,
            "sequential_clips_per_s": n_clips / max(seq_seconds, 1e-9),
            "sequential_predict_seconds": seq_predict_seconds,
            "engine_predict_seconds": stats.predict_seconds,
            "frontend": fe.as_dict(),
            "rt": (engine.last_rt_stats.as_dict()
                   if engine.last_rt_stats else {}),
            "per_bench": per_bench}
        emit.emit(f"speed.multicore_{n_cores}", eng_seconds * 1e6
                  / max(n_clips, 1),
                  f"{len(names)} mt benchmarks x {n_cores} cores: "
                  f"{n_clips} clips in {eng_seconds:.2f}s = "
                  f"{eng_cps:.0f} clips/s ({eng_cps / n_cores:.0f}/core) "
                  f"vs sequential {seq_seconds:.2f}s; cycles "
                  f"{'bitwise equal' if len(mismatches) == prior_mismatches else 'MISMATCH'}")

    return {"schema_version": BENCH_SCHEMA_VERSION,
            "quick": quick,
            "quantum": quantum,
            "core_counts": list(core_counts),
            "benchmarks": names,
            "all_bitwise_equal": not mismatches,
            "mismatches": mismatches,
            "per_core_count": per_count}


# --------------------------------------------------------------------------- #
# Mesh scaling: sharded engine vs the unsharded reference at 1/2/N devices
# --------------------------------------------------------------------------- #

def run_mesh(emit, *, max_mesh: int = 8, quick: bool = False,
             n_benchmarks: int = 4,
             config: "EngineConfig | None" = None) -> dict:
    """Data-mesh scaling of the sharded inference engine.

    For each mesh size in {1, 2, max_mesh} (capped at the visible device
    count): a fresh engine with ``mesh_shape=(n,)`` runs the single-core
    suite twice (cold pass pays jit + the sharded RT-table build, warm
    pass is steady state) plus the 2-core multicore suite, and every
    predicted AND oracle cycle count — per benchmark, per core, and
    summed — must be bitwise equal to the unsharded (``mesh_shape=()``)
    reference engine.  The JSON (schema v3) reports clips/sec per mesh
    size and the cold RT-build scaling ratio vs the 1-device mesh; on a
    single physical core the forced host devices timeshare, so the
    ratios are reported, not gated — the gate is bitwise equality.
    """
    vocab = build_vocab()
    cfg = predictor.inference_config(bench_cfg() if quick else full_cfg())
    params = predictor.init_params(cfg, jax.random.PRNGKey(0))
    ec = (config or bench_scale_config(quick)).replace(
        warmup=0, with_oracle=True, rt_cache=True, mesh_shape=())
    names = list(progen.TABLE_II)[:n_benchmarks]
    benches = [progen.build_benchmark(name) for name in names]
    mbenches = [multicore.build_multicore_benchmark(n, 2)
                for n in multicore.MULTICORE_NAMES]

    n_devices = len(jax.devices())
    sizes = [s for s in sorted({1, 2, max_mesh}) if 0 < s <= min(
        max_mesh, n_devices)]

    def one(engine_config):
        engine = SimulationEngine.from_config(params, cfg, vocab,
                                              engine_config)
        t0 = time.time()
        engine.run(benches)               # cold: jit + RT-table build
        cold = time.time() - t0
        build = (engine.last_rt_stats.build_seconds
                 if engine.last_rt_stats else 0.0)
        t0 = time.time()
        results = engine.run(benches)     # warm: steady-state throughput
        warm = time.time() - t0
        n_clips = engine.last_stats.n_clips
        mresults = engine.run_multicore(mbenches)
        return results, mresults, cold, warm, build, n_clips

    ref, ref_mc, ref_cold, ref_warm, ref_build, n_clips = one(ec)

    per_mesh = {}
    mismatches = []
    for n in sizes:
        results, mresults, cold, warm, build, _ = one(
            ec.replace(mesh_shape=(n,)))
        equal = all(r.predicted_cycles == s.predicted_cycles
                    and r.oracle_cycles == s.oracle_cycles
                    for r, s in zip(ref, results))
        mc_equal = all(
            mr.predicted_cycles == ms.predicted_cycles
            and mr.oracle_cycles == ms.oracle_cycles
            and all(a.predicted_cycles == b.predicted_cycles
                    for a, b in zip(mr.cores, ms.cores))
            for mr, ms in zip(ref_mc, mresults))
        if not equal:
            mismatches.append(f"mesh{n}:single-core")
        if not mc_equal:
            mismatches.append(f"mesh{n}:multicore")
        cps = n_clips / max(warm, 1e-9)
        per_mesh[str(n)] = {
            "cold_seconds": cold,
            "warm_seconds": warm,
            "clips_per_s": cps,
            "rt_build_seconds": build,
            "bitwise_equal": equal,
            "multicore_bitwise_equal": mc_equal}
        emit.emit(f"speed.mesh_{n}", warm * 1e6 / max(n_clips, 1),
                  f"{n}-device mesh: {n_clips} clips in {warm:.2f}s warm "
                  f"= {cps:.0f} clips/s, cold RT build {build:.2f}s; "
                  f"cycles vs unsharded "
                  f"{'bitwise equal' if equal and mc_equal else 'MISMATCH'}")

    build_1 = per_mesh.get("1", {}).get("rt_build_seconds", ref_build)
    scaling = {k: build_1 / max(v["rt_build_seconds"], 1e-9)
               for k, v in per_mesh.items()}
    return {"schema_version": MESH_BENCH_SCHEMA_VERSION,
            "quick": quick,
            "n_devices": n_devices,
            "requested_max_mesh": max_mesh,
            "mesh_sizes": sizes,
            "n_benchmarks": n_benchmarks,
            "multicore_n_cores": 2,
            "n_clips": n_clips,
            "unsharded": {"cold_seconds": ref_cold,
                          "warm_seconds": ref_warm,
                          "rt_build_seconds": ref_build,
                          "clips_per_s": n_clips / max(ref_warm, 1e-9)},
            "per_mesh": per_mesh,
            "rt_build_scaling": scaling,
            "all_bitwise_equal": not mismatches,
            "mismatches": mismatches}


# --------------------------------------------------------------------------- #
# Observability overhead: traced vs untraced warm fused+int8 predict
# --------------------------------------------------------------------------- #

def run_obs_overhead(emit, *, quick: bool = False, repeats: int = 3,
                     n_benchmarks: int = 8,
                     config: "EngineConfig | None" = None,
                     trace_out: "str | None" = None) -> dict:
    """Measure what span tracing costs on the hot path.

    Runs the warm fused+int8 suite twice — observability default (metrics
    registry only, tracer disabled) and with ``trace=True`` — taking the
    min of ``repeats`` warm passes each, so one GC pause or CI-runner
    hiccup cannot fake a regression.  The two runs must also stay bitwise
    equal: tracing must never perturb numerics.  ``--max-obs-overhead``
    gates the relative overhead (full scale target: <= 2%).
    """
    vocab = build_vocab()
    cfg = predictor.inference_config(bench_cfg() if quick else full_cfg())
    params = predictor.init_params(cfg, jax.random.PRNGKey(0))
    names = list(progen.TABLE_II)[:n_benchmarks]
    benches = [progen.build_benchmark(name) for name in names]
    ec = (config or bench_scale_config(quick)).replace(
        warmup=0, with_oracle=False, rt_cache=True,
        fused_serving=True, precision="int8")

    def best_warm(engine_config):
        engine = SimulationEngine.from_config(params, cfg, vocab,
                                              engine_config)
        engine.run(benches)               # cold: jit + RT-table build
        best, results = float("inf"), None
        for _ in range(repeats):
            t0 = time.perf_counter()
            results = engine.run(benches)
            best = min(best, time.perf_counter() - t0)
        return best, results, engine

    from repro.core.engine_config import ObservabilityConfig
    off_s, off_res, _ = best_warm(ec)
    on_s, on_res, traced = best_warm(ec.replace(
        observability=ObservabilityConfig(trace=True)))
    if trace_out:
        traced.obs.tracer.dump(trace_out)
    overhead = on_s / max(off_s, 1e-9) - 1.0
    bitwise = all(a.predicted_cycles == b.predicted_cycles
                  for a, b in zip(off_res, on_res))
    n_clips = sum(r.n_clips for r in off_res)
    emit.emit("speed.obs_overhead", on_s * 1e6 / max(n_clips, 1),
              f"warm fused+int8 min-of-{repeats}: untraced {off_s:.3f}s "
              f"vs traced {on_s:.3f}s = {overhead:+.2%} overhead "
              f"({len(traced.obs.tracer.spans())} spans recorded); "
              f"cycles {'bitwise equal' if bitwise else 'MISMATCH'}")
    return {"schema_version": BENCH_SCHEMA_VERSION, "quick": quick,
            "repeats": repeats, "n_clips": n_clips,
            "untraced_warm_seconds": off_s,
            "traced_warm_seconds": on_s,
            "overhead_ratio": overhead,
            "spans_recorded": len(traced.obs.tracer.spans()),
            "bitwise_equal": bitwise}


# --------------------------------------------------------------------------- #
# Subsample fusion: stratified clip subsampling vs the full fused+int8 path
# --------------------------------------------------------------------------- #

def run_subsample(emit, *, n_benchmarks: int = 8, quick: bool = False,
                  config: "EngineConfig | None" = None,
                  fraction: "float | None" = None, strata: int = 4,
                  min_clips_per_stratum: int = 2,
                  bootstrap_resamples: int = 200, seed: int = 0) -> dict:
    """Analytical-ML fusion accuracy/cost trade-off (ROADMAP item 4).

    Runs the Table-II suite twice through the SAME fused+int8 rung: once
    predicting every clip (the reference), once with stratified clip
    subsampling + ridge extrapolation + bootstrap CI.  Reports, per
    benchmark and in aggregate: the clip-prediction ratio
    (n_clips / clips_predicted), the ADDED relative cycles error of the
    fused estimate vs the full prediction (not vs the oracle — the gate
    is about what subsampling costs on top of the model), the bootstrap
    CI width, and whether the CI covers the full-prediction estimate.
    The full-scale targets: >= 10x fewer predicted clips at <= 2% added
    total-cycles error with the summed CI covering the full total.
    """
    vocab = build_vocab()
    cfg = predictor.inference_config(bench_cfg() if quick else full_cfg())
    params = predictor.init_params(cfg, jax.random.PRNGKey(0))
    names = list(progen.TABLE_II)[:n_benchmarks]
    benches = [progen.build_benchmark(name) for name in names]
    if fraction is None:
        # quick scale has ~20 clips/bench: a paper-scale fraction would
        # degenerate to the min-per-stratum floor, so quick exercises the
        # machinery at 0.25 and the full run targets the 10x reduction
        fraction = 0.25 if quick else 0.08
    scfg = SamplingConfig(fraction=fraction, strata=strata,
                          min_clips_per_stratum=min_clips_per_stratum,
                          bootstrap_resamples=bootstrap_resamples,
                          seed=seed)
    ec = (config or bench_scale_config(quick)).replace(
        warmup=0, with_oracle=False, rt_cache=True,
        fused_serving=True, precision="int8")

    def one(engine_config):
        engine = SimulationEngine.from_config(params, cfg, vocab,
                                              engine_config)
        engine.run(benches)               # cold: jit + RT-table build
        t0 = time.time()
        results = engine.run(benches)     # warm: steady state
        return results, time.time() - t0, engine.last_stats

    full_res, full_seconds, full_stats = one(ec)
    sub_res, sub_seconds, sub_stats = one(ec.replace(sampling=scfg))

    per_bench = {}
    tot_full = tot_sub = tot_lo = tot_hi = 0.0
    tot_clips = tot_predicted = 0
    n_covered = 0
    for f, s in zip(full_res, sub_res):
        lo, hi = s.cycles_ci
        err = abs(s.predicted_cycles - f.predicted_cycles) \
            / max(abs(f.predicted_cycles), 1e-9)
        covered = lo <= f.predicted_cycles <= hi
        n_covered += covered
        tot_full += f.predicted_cycles
        tot_sub += s.predicted_cycles
        tot_lo += lo
        tot_hi += hi
        tot_clips += f.n_clips
        tot_predicted += s.clips_predicted
        per_bench[f.name] = {
            "full_cycles": f.predicted_cycles,
            "fused_cycles": s.predicted_cycles,
            "added_rel_error": err,
            "n_clips": f.n_clips,
            "clips_predicted": s.clips_predicted,
            "clips_extrapolated": s.clips_extrapolated,
            "clip_ratio": f.n_clips / max(s.clips_predicted, 1),
            "ci": [lo, hi],
            "ci_width": hi - lo,
            "ci_covers_full": covered}

    clip_ratio = tot_clips / max(tot_predicted, 1)
    total_err = abs(tot_sub - tot_full) / max(abs(tot_full), 1e-9)
    per_errs = [v["added_rel_error"] for v in per_bench.values()]
    res = {
        "schema_version": SUBSAMPLE_BENCH_SCHEMA_VERSION,
        "quick": quick,
        "n_benchmarks": len(names),
        "sampling": scfg.to_dict(),
        "per_bench": per_bench,
        "total_full_cycles": tot_full,
        "total_fused_cycles": tot_sub,
        "total_ci": [tot_lo, tot_hi],
        "total_ci_covers_full": tot_lo <= tot_full <= tot_hi,
        "ci_coverage_fraction": n_covered / max(len(names), 1),
        "clip_ratio": clip_ratio,
        "total_clips": tot_clips,
        "total_clips_predicted": tot_predicted,
        "added_rel_error_total": total_err,
        "added_rel_error_max": max(per_errs),
        "added_rel_error_mean": sum(per_errs) / len(per_errs),
        "timing": {"full_seconds": full_seconds,
                   "subsample_seconds": sub_seconds,
                   "full_predict_seconds": full_stats.predict_seconds,
                   "subsample_predict_seconds": sub_stats.predict_seconds,
                   "n_predicted_full": full_stats.n_predicted,
                   "n_predicted_subsample": sub_stats.n_predicted}}
    emit.emit("speed.subsample_fusion", sub_seconds * 1e6
              / max(tot_predicted, 1),
              f"{len(names)} benchmarks: {tot_predicted}/{tot_clips} "
              f"clips predicted ({clip_ratio:.1f}x fewer), total added "
              f"err {total_err:.3%} (max per-bench {max(per_errs):.3%}), "
              f"summed CI {'covers' if res['total_ci_covers_full'] else 'MISSES'} "
              f"the full estimate; warm {full_seconds:.2f}s -> "
              f"{sub_seconds:.2f}s")
    return res


if __name__ == "__main__":
    from benchmarks.common import CsvEmitter
    ap = argparse.ArgumentParser()
    ap.add_argument("--multi", action="store_true",
                    help="multi-benchmark sequential-vs-engine throughput")
    ap.add_argument("--multicore", action="store_true",
                    help="multicore engine-vs-sequential equality + "
                         "per-core throughput at 1/2/4 cores")
    ap.add_argument("--core-counts", type=int, nargs="+",
                    default=[1, 2, 4],
                    help="core counts for --multicore")
    ap.add_argument("--dataset-build", action="store_true",
                    help="dataset-build throughput breakdown (build "
                         "seconds per stage, clips/sec) for the single- "
                         "and multicore training builds")
    ap.add_argument("--mesh", type=int, default=0, metavar="N",
                    help="mesh-scaling pass: sharded engine at 1/2/N "
                         "devices, bitwise-gated against the unsharded "
                         "reference.  Sets XLA_FLAGS to force N host "
                         "devices if too few are visible")
    ap.add_argument("--subsample", action="store_true",
                    help="analytical-ML fusion pass: stratified clip "
                         "subsampling + ridge extrapolation vs the full "
                         "fused+int8 prediction, with clip-ratio and "
                         "added-error gates")
    ap.add_argument("--subsample-fraction", type=float, default=None,
                    help="per-stratum sampling fraction for --subsample "
                         "(default: 0.25 quick / 0.08 full)")
    ap.add_argument("--strata", type=int, default=4,
                    help="number of analytical-feature strata for "
                         "--subsample")
    ap.add_argument("--min-clip-ratio", type=float, default=0.0,
                    help="fail if total n_clips / clips_predicted falls "
                         "below this (0 disables; full-scale target is "
                         ">= 10x, quick gates >= 2x)")
    ap.add_argument("--max-added-rel-err", type=float, default=0.0,
                    help="fail if the subsampled total cycles diverge "
                         "from the full fused+int8 prediction by more "
                         "than this relative error (0 disables; "
                         "full-scale target is <= 2%%, quick <= 5%%)")
    ap.add_argument("--obs-overhead", action="store_true",
                    help="observability-overhead pass: warm fused+int8 "
                         "suite with tracing on vs off (min-of-3), "
                         "bitwise-gated; see --max-obs-overhead")
    ap.add_argument("--max-obs-overhead", type=float, default=0.0,
                    help="--obs-overhead: fail if the traced warm pass "
                         "is slower than the untraced one by more than "
                         "this fraction (0 disables; full-scale target "
                         "is <= 0.02, quick runs use a lenient bound — "
                         "shared CI runners jitter more than 2%%)")
    ap.add_argument("--obs-repeats", type=int, default=3,
                    help="--obs-overhead: warm passes per arm (min "
                         "taken)")
    ap.add_argument("--trace-out", default=None, metavar="PATH",
                    help="--obs-overhead: dump the traced arm's "
                         "Chrome/Perfetto trace JSON here (open at "
                         "ui.perfetto.dev)")
    ap.add_argument("--quick", action="store_true",
                    help="CI smoke scale (small model, short intervals)")
    ap.add_argument("--n-benchmarks", type=int, default=8)
    ap.add_argument("--engine-config", default=None, metavar="JSON",
                    help="EngineConfig overrides as a JSON object (inline "
                         "or a file path) layered over the --quick/full "
                         "scale defaults; shared by --multi, --multicore "
                         "and --mesh")
    ap.add_argument("--min-speedup", type=float, default=1.5,
                    help="fail if engine/sequential clips/s falls below "
                         "this (the CI gate; pass 0 for measurement runs)")
    ap.add_argument("--min-frontend-speedup", type=float, default=0.0,
                    help="fail if columnar/object front-end throughput "
                         "falls below this (0 disables; full-scale target "
                         "is >= 3x)")
    ap.add_argument("--min-predict-speedup", type=float, default=0.0,
                    help="fail if ANY warm predict tier (RT cache, int8, "
                         "fused, fused+int8) falls below this speedup "
                         "over the monolithic warm path (0 disables; "
                         "full-scale target is >= 2x).  The cold tier is "
                         "gated separately: the store-restart pass must "
                         "rebuild in < 1s with zero re-encode")
    ap.add_argument("--min-stack-speedup", type=float, default=0.0,
                    help="fail if the fused+int8 warm predict falls "
                         "below this speedup over the warm RT-cache "
                         "path (0 disables; full-scale target is >= 2x)")
    ap.add_argument("--max-int8-rel-err", type=float, default=0.01,
                    help="fail if the int8 (or fused+int8) predicted "
                         "cycles diverge from fp32 by more than this "
                         "relative error.  Quantization error shrinks "
                         "with model width: the full-scale model gates "
                         "at the default 1%%; the --quick CI model is 4x "
                         "narrower and gates at 5%%")
    ap.add_argument("--rt-store-dir", default=None, metavar="DIR",
                    help="persistent RT-cache store directory shared by "
                         "every --multi RT pass and the store-restart "
                         "gate (default: a fresh temp dir, so the cold "
                         "encode is always paid once in-process)")
    ap.add_argument("--json", default=None,
                    help="write the --multi result dict to this path")
    ap.add_argument("--breakdown-json", default=None,
                    help="also write just the front-end breakdown dict "
                         "(interpret/slice/tokenize/context/predict "
                         "seconds) to this path — the CI artifact that "
                         "tracks where host time goes across PRs")
    ap.add_argument("--predict-stack-json", default=None,
                    help="also write just the predict-stack tier "
                         "breakdown (monolithic/rt/bf16/int8/fused warm "
                         "seconds, speedups, rel errors, rt_store "
                         "restart block) to this path")
    args = ap.parse_args()
    if args.mesh > 1:
        # must happen before jax's first backend init (importing jax does
        # not lock the device count; the first device query/op does)
        flags = os.environ.get("XLA_FLAGS", "")
        if "xla_force_host_platform_device_count" not in flags:
            os.environ["XLA_FLAGS"] = (
                f"{flags} --xla_force_host_platform_device_count="
                f"{args.mesh}").strip()
    emitter = CsvEmitter()
    engine_config = resolve_engine_config(args.engine_config, args.quick)
    if args.obs_overhead:
        res = run_obs_overhead(emitter, quick=args.quick,
                               repeats=args.obs_repeats,
                               n_benchmarks=args.n_benchmarks,
                               config=engine_config,
                               trace_out=args.trace_out)
        if args.json:
            Path(args.json).write_text(json.dumps(res, indent=2))
        if not res["bitwise_equal"]:
            raise SystemExit(
                "traced run predicted cycles diverged from the "
                "untraced run — tracing must never perturb numerics")
        if args.max_obs_overhead and \
                res["overhead_ratio"] > args.max_obs_overhead:
            raise SystemExit(
                f"observability overhead {res['overhead_ratio']:+.2%} > "
                f"{args.max_obs_overhead:.2%} — tracing is intruding on "
                "the hot path")
    elif args.dataset_build:
        res = run_dataset_build(emitter, quick=args.quick)
        if args.json:
            Path(args.json).write_text(json.dumps(res, indent=2))
    elif args.mesh:
        res = run_mesh(emitter, max_mesh=args.mesh, quick=args.quick,
                       n_benchmarks=min(args.n_benchmarks, 4),
                       config=engine_config)
        if args.json:
            Path(args.json).write_text(json.dumps(res, indent=2))
        if args.mesh not in res["mesh_sizes"]:
            raise SystemExit(
                f"requested --mesh {args.mesh} but only "
                f"{res['n_devices']} devices are visible — XLA_FLAGS "
                "was set too late (jax backend already initialized?)")
        if not res["all_bitwise_equal"]:
            raise SystemExit(
                "sharded engine cycles diverged from the unsharded "
                f"reference: {res['mismatches']}")
    elif args.subsample:
        res = run_subsample(emitter, n_benchmarks=args.n_benchmarks,
                            quick=args.quick, config=engine_config,
                            fraction=args.subsample_fraction,
                            strata=args.strata)
        if args.json:
            Path(args.json).write_text(json.dumps(res, indent=2))
        if not res["total_ci_covers_full"]:
            raise SystemExit(
                f"summed bootstrap CI {res['total_ci']} does not cover "
                f"the full-prediction total {res['total_full_cycles']}")
        if args.min_clip_ratio and res["clip_ratio"] < args.min_clip_ratio:
            raise SystemExit(
                f"clip-prediction ratio {res['clip_ratio']:.2f}x < "
                f"{args.min_clip_ratio}x — subsampling is not reducing "
                "predicted clips enough")
        if args.max_added_rel_err and \
                res["added_rel_error_total"] > args.max_added_rel_err:
            raise SystemExit(
                f"subsampled total cycles added rel error "
                f"{res['added_rel_error_total']:.4%} > "
                f"{args.max_added_rel_err:.4%} vs the full fused+int8 "
                "prediction")
    elif args.multicore:
        res = run_multicore_bench(emitter, core_counts=args.core_counts,
                                  quick=args.quick, config=engine_config)
        if args.json:
            Path(args.json).write_text(json.dumps(res, indent=2))
        if not res["all_bitwise_equal"]:
            raise SystemExit(
                "multicore engine/sequential/oracle cycles diverged: "
                f"{res['mismatches']}")
    elif args.multi:
        res = run_multi(emitter, n_benchmarks=args.n_benchmarks,
                        quick=args.quick, config=engine_config,
                        rt_store_dir=args.rt_store_dir)
        if args.json:
            Path(args.json).write_text(json.dumps(res, indent=2))
        if args.breakdown_json:
            Path(args.breakdown_json).write_text(
                json.dumps(res["frontend"], indent=2))
        if args.predict_stack_json:
            Path(args.predict_stack_json).write_text(
                json.dumps(res["predict_stack"], indent=2))
        if not res["all_bitwise_equal"]:
            raise SystemExit("engine/sequential/RT-cache predicted or "
                             "oracle cycles diverged from the reference")
        ps = res["predict_stack"]
        bf16_err = res["predict"]["bf16_max_rel_error"]
        if bf16_err > 0.01:
            raise SystemExit(
                f"bf16 predict mode rel error {bf16_err:.4%} > 1%")
        # the fused step is an fp32 refactoring of the same math: only
        # reassociation separates it from the unfused path
        if ps["fused_max_rel_error"] > 1e-3:
            raise SystemExit(
                f"fused serving rel error "
                f"{ps['fused_max_rel_error']:.2e} > 1e-3 vs unfused")
        for tier in ("int8", "stack"):
            err = ps[f"{tier}_max_rel_error"]
            if err > args.max_int8_rel_err:
                raise SystemExit(
                    f"{tier} predict rel error {err:.4%} > "
                    f"{args.max_int8_rel_err:.4%}")
        store = ps["rt_store"]
        if store["restart_rows_encoded"] != 0:
            raise SystemExit(
                f"store restart re-encoded "
                f"{store['restart_rows_encoded']} rows (persistent "
                "store should have served all of them)")
        if not store["restart_bitwise_equal"]:
            raise SystemExit(
                "store restart predicted cycles diverged from the "
                "fp32 RT pass (persisted table not byte-identical?)")
        if store["restart_rt_build_seconds"] >= 1.0:
            raise SystemExit(
                f"store restart rt_build_seconds "
                f"{store['restart_rt_build_seconds']:.2f}s >= 1s — the "
                "persistent store is not killing the cold encode")
        if res["engine_speedup"] < args.min_speedup:
            raise SystemExit(
                f"engine speedup {res['engine_speedup']:.2f}x < "
                f"{args.min_speedup}x")
        fe_ratio = res["frontend"]["frontend_speedup"]
        if fe_ratio < args.min_frontend_speedup:
            raise SystemExit(
                f"front-end speedup {fe_ratio:.2f}x < "
                f"{args.min_frontend_speedup}x")
        warm_tiers = ("rt_warm", "int8_warm", "fused_warm",
                      "fused_int8_warm")
        tier_speedups = ps["tier_speedups_vs_monolithic"]
        worst_tier = min(warm_tiers, key=lambda k: tier_speedups[k])
        if tier_speedups[worst_tier] < args.min_predict_speedup:
            raise SystemExit(
                f"predict tier {worst_tier} speedup "
                f"{tier_speedups[worst_tier]:.2f}x < "
                f"{args.min_predict_speedup}x vs monolithic warm")
        if ps["stack_speedup"] < args.min_stack_speedup:
            raise SystemExit(
                f"fused+int8 stack speedup {ps['stack_speedup']:.2f}x "
                f"< {args.min_stack_speedup}x over warm RT")
    else:
        run(emitter)
