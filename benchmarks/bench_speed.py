"""Fig 7: CAPSim (functional sim + batched predictor) vs the O3 oracle.

Honest accounting on this host: the paper compares gem5 (~10^5 inst/s on a
Xeon) against an RTX 4090; here BOTH paths share one CPU core and our
greedy O3 oracle is itself ~5x10^5 inst/s — ~500x faster than gem5 — so an
absolute wall-clock speedup is not reproducible and is reported as-is.
What does reproduce is the *structure* of the paper's claim:

  1. the oracle is inherently sequential: its wall time grows linearly
     with instruction count (measured below),
  2. the predictor path is embarrassingly parallel over clips: per-clip
     cost falls with batch size (measured below, compile amortized),
  3. on the target accelerator the clip batch is one dry-run cell:
     the compiled capsim x serve_clips artifact bounds throughput at
     16384 clips (~2.1M instructions) per step-time (derived below from
     results/dryrun), which is what the paper's Fig-7 GPU bars measure.
"""
from __future__ import annotations

import json
import time
from pathlib import Path

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import full_cfg
from repro.core import predictor
from repro.core.simulate import capsim_simulate
from repro.core.standardize import build_vocab
from repro.isa import funcsim, progen, timing

BENCHES = ["503.bwaves", "505.mcf", "548.exchange2"]


def run(emit) -> None:
    vocab = build_vocab()
    cfg = full_cfg()
    params = predictor.init_params(cfg, jax.random.PRNGKey(0))

    # 1. oracle sequential scaling
    bench = progen.build_benchmark("505.mcf")
    st = progen.fresh_state(bench)
    times = []
    for n in (5_000, 10_000, 20_000):
        trace, _, _ = funcsim.run(bench.program, n,
                                  state=progen.fresh_state(bench))
        t0 = time.time()
        timing.simulate(trace)
        times.append(time.time() - t0)
    emit.emit("speed.oracle_scaling", times[-1] * 1e6 / 20_000,
              f"oracle seconds for 5k/10k/20k insts: "
              f"{times[0]:.3f}/{times[1]:.3f}/{times[2]:.3f} (linear — "
              "sequential, cannot parallelize)")

    # 2. predictor batch amortization (compile amortized by warmup)
    rng = np.random.RandomState(0)
    def batch(B):
        return {
            "clip_tokens": jnp.asarray(
                rng.randint(0, vocab.size, (B, 128, cfg.clip_tokens)),
                jnp.int32),
            "context_tokens": jnp.asarray(
                rng.randint(0, vocab.size, (B, cfg.context_tokens)),
                jnp.int32),
            "clip_mask": jnp.ones((B, 128), jnp.float32)}
    pred = jax.jit(lambda p, b: predictor.predict_step(p, b, cfg))
    per_clip = {}
    for B in (8, 32):
        b = batch(B)
        jax.block_until_ready(pred(params, b))          # compile+warm
        t0 = time.time()
        jax.block_until_ready(pred(params, b))
        per_clip[B] = (time.time() - t0) / B * 1e6
    emit.emit("speed.predictor_batching", per_clip[32],
              f"us/clip at batch 8 vs 32: {per_clip[8]:.0f} -> "
              f"{per_clip[32]:.0f}: flat per-clip cost on 1 core — the "
              "batch dimension is free parallelism on real accelerators "
              "(see v5e_projection)")

    # 3. end-to-end on this host (compile already amortized above)
    for name in BENCHES:
        bench = progen.build_benchmark(name)
        r = capsim_simulate(bench, params, cfg, vocab,
                            interval_size=10_000, max_checkpoints=1,
                            batch_size=32)
        emit.emit(f"speed.{name}",
                  r.capsim_seconds * 1e6 / max(r.n_instructions, 1),
                  f"oracle {r.oracle_seconds:.2f}s vs capsim "
                  f"{r.capsim_seconds:.2f}s = {r.speedup:.3f}x on 1 CPU "
                  f"core ({r.n_instructions} insts; paper: 2.2-8.3x with "
                  "gem5-vs-GPU cost ratio)")

    # 4. target-accelerator projection from the compiled dry-run cell
    rec_path = Path("results/dryrun/capsim__serve_clips__pod_16x16.json")
    if rec_path.exists():
        rec = json.loads(rec_path.read_text())
        m = rec["scanned"]["memory"]
        traffic = (m["argument_bytes"] + m["output_bytes"]
                   + 2 * m["temp_bytes"])
        step_s = max(traffic / 819e9,
                     (rec["scanned"]["cost"]["flops"] or 0) / 197e12)
        clips = 16_384
        insts = clips * 128
        emit.emit("speed.v5e_projection", step_s * 1e6 / clips,
                  f"serve_clips dry-run: {clips} clips "
                  f"({insts/1e6:.1f}M insts) per {step_s*1e3:.1f}ms pod "
                  f"step = {insts/step_s/1e9:.1f}G inst/s structural "
                  "bound vs oracle 5e5 inst/s/core")


if __name__ == "__main__":
    from benchmarks.common import CsvEmitter
    run(CsvEmitter())
