"""Fig 11: 6x6 train/test generalization matrix over the Table-II sets.

Train one predictor per benchmark set, evaluate on all six sets: the
diagonal is in-distribution accuracy, off-diagonal is the unseen-benchmark
scenario (the simulator's real use case).  Paper: 91.3% on the training
set, 88.3% average accuracy (MAPE-based accuracy = 100% - MAPE).
"""
from __future__ import annotations

import time

import jax
import numpy as np

from benchmarks.common import bench_cfg, eval_mape, get_set_dataset, \
    train_model
from repro.core import predictor
from repro.isa.progen import SET_NUMBERS

STEPS = 40
BATCH = 8


def run(emit) -> None:
    cfg = bench_cfg()
    sets = {s: get_set_dataset(s) for s in SET_NUMBERS}
    for s, d in sets.items():
        print(f"# set {s}: {len(d)} clips "
              f"({', '.join(sorted(set(d.bench_names)))})")

    pred_fn = jax.jit(lambda p, b: predictor.predict_step(p, b, cfg))
    matrix = np.zeros((len(SET_NUMBERS), len(SET_NUMBERS)))
    for i, s_train in enumerate(SET_NUMBERS):
        t0 = time.time()
        params = predictor.init_params(cfg, jax.random.PRNGKey(s_train))
        state, _ = train_model(
            lambda p, b: predictor.mape_loss(p, b, cfg), params,
            sets[s_train], steps=STEPS, batch_size=BATCH)
        secs = time.time() - t0
        for j, s_test in enumerate(SET_NUMBERS):
            matrix[i, j] = eval_mape(pred_fn, state["params"], sets[s_test])
        emit.emit(f"generalization.train_set{s_train}", secs * 1e6 / STEPS,
                  "test MAPE per set: " +
                  " ".join(f"{m:.3f}" for m in matrix[i]))

    diag = float(np.mean(np.diag(matrix)))
    off = float((matrix.sum() - np.trace(matrix)) /
                (matrix.size - len(SET_NUMBERS)))
    emit.emit("generalization.in_set", 0.0,
              f"avg in-set accuracy {100*(1-diag):.1f}% (paper 91.3%)")
    emit.emit("generalization.cross_set", 0.0,
              f"avg unseen-set accuracy {100*(1-off):.1f}% (paper 88.3%)")


if __name__ == "__main__":
    from benchmarks.common import CsvEmitter
    run(CsvEmitter())
