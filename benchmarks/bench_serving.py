"""Serving-service benchmark: open-loop Poisson traffic vs the
fault-tolerant ``SimulationService``.

Three phases per tenant level (1 / 8 / 64 concurrent tenants):

  healthy    no injection — baseline p50/p99 latency and clips/sec,
  faulted    ~10% injected faults split across every chaos kind
             (device errors, NaN outputs, slow flushes, corrupt RT-store
             reads, mid-persist crashes) on the REAL serving path,
  recovery   injection off again — the service must climb the ladder
             back to the fused+int8 top tier (exponential backoff).

The driver is open-loop: each tenant submits on its own Poisson arrival
schedule regardless of completions, so overload shows up as typed
``overloaded``/``deadline_exceeded`` results, not as a stalled driver.

Gates (enforced here, read by the CI chaos leg):

  typed       every submitted request resolves to a typed result — no
              hang, no silent drop, in every phase including faulted,
  gated       every successful result in the faulted phase stays within
              the int8 rel-err gate vs the monolithic fp32 reference
              (the loosest rung of the ladder: 5% at bench scale
              d_model=64, 1% at the paper scale) — degradation never
              ships an ungated wrong answer,
  repromoted  after faults stop the service serves from the top tier
              again,
  p99         healthy-phase p99 latency at 1 tenant under a generous
              absolute bound (shared-CI-runner safe).
"""
from __future__ import annotations

import argparse
import json
import re
import sys
import time
import urllib.request
from pathlib import Path
from typing import Dict, List, Optional, Tuple

import jax
import numpy as np

if __package__ in (None, ""):   # direct `python benchmarks/bench_serving.py`
    sys.path.insert(0, str(Path(__file__).resolve().parents[1]))

from benchmarks.common import (SERVING_BENCH_SCHEMA_VERSION, bench_cfg,
                               full_cfg, get_mixed_dataset)
from repro.core import predictor
from repro.core.engine_config import EngineConfig, ObservabilityConfig
from repro.serving.engine import PredictorEngine, Request
from repro.serving.service import (TIER_TRANSITIONS_TOTAL, ServiceSLA,
                                   SimulationService)

# ~10% total injected fault probability per opportunity, split evenly
# across every chaos kind the stack supports
FAULT_MIX_10PCT = {"device_error": 0.02, "nan_output": 0.02,
                   "slow_flush": 0.02, "corrupt_rt_read": 0.02,
                   "crash_persist": 0.02}


def _percentile(xs: List[float], q: float) -> float:
    return float(np.percentile(np.asarray(xs), q)) if xs else float("nan")


_PROM_LINE = re.compile(r'^(\w+)\{(.*)\} (\S+)$')
_PROM_LABEL = re.compile(r'(\w+)="([^"]*)"')


def scrape_transitions(port: int, instance: str) -> List[Dict]:
    """GET /metrics and parse this service's tier-transition counter
    series — the same scrape a production Prometheus would do, driven
    mid-bench so the exporter path is exercised under live traffic."""
    with urllib.request.urlopen(
            f"http://127.0.0.1:{port}/metrics", timeout=10) as resp:
        text = resp.read().decode()
    rows = []
    for line in text.splitlines():
        m = _PROM_LINE.match(line)
        if not m or m.group(1) != TIER_TRANSITIONS_TOTAL:
            continue
        labels = dict(_PROM_LABEL.findall(m.group(2)))
        if labels.get("instance") != instance:
            continue
        labels["count"] = int(float(m.group(3)))
        rows.append(labels)
    return rows


def transition_gates(probe: List[Dict], stats: Dict,
                     flight_last: Optional[Dict]) -> Dict:
    """Cross-check the three independent transition ledgers: the scraped
    counter series, the snapshot's per-tier counters, and the flight
    recorder's event ring (when a postmortem was taken).

    Every demotion recorded on a non-floor tier produced exactly one
    transition (floor trips have nowhere to go); every promotion
    produced one.  All three ledgers must agree on those totals.
    """
    tiers = stats["tiers"]
    names = list(tiers)
    exp_demote = sum(tiers[n]["demotions"] for n in names[:-1])
    exp_promote = sum(tiers[n]["promotions"] for n in names)
    got_demote = sum(r["count"] for r in probe
                     if r["reason"] != "promotion")
    got_promote = sum(r["count"] for r in probe
                      if r["reason"] == "promotion")
    out = {
        "expected_demote_transitions": exp_demote,
        "expected_promote_transitions": exp_promote,
        "probed_demote_transitions": got_demote,
        "probed_promote_transitions": got_promote,
        "metrics_consistent": (got_demote == exp_demote
                               and got_promote == exp_promote),
    }
    if flight_last is not None:
        # the postmortem freezes (events, state) atomically inside
        # _demote, so ITS ledgers must agree with each other too
        ev = [e for e in flight_last["events"]
              if e["kind"] == "tier_transition"]
        ptiers = flight_last["state"]["tiers"]
        pnames = list(ptiers)
        p_exp_dem = sum(ptiers[n]["demotions"] for n in pnames[:-1])
        p_exp_pro = sum(ptiers[n]["promotions"] for n in pnames)
        f_dem = sum(1 for e in ev if e["reason"] != "promotion")
        f_pro = sum(1 for e in ev if e["reason"] == "promotion")
        out["flight_demote_events"] = f_dem
        out["flight_promote_events"] = f_pro
        out["flight_consistent"] = (f_dem == p_exp_dem
                                    and f_pro == p_exp_pro)
    else:
        out["flight_consistent"] = None      # no demotion, nothing to dump
    return out


def make_requests(ds, n_requests: int, clips_per_req: int, id0: int
                  ) -> List[Request]:
    """Slice the dataset's clip pool into request payloads (wrapping)."""
    reqs = []
    for i in range(n_requests):
        lo = (i * clips_per_req) % max(len(ds) - clips_per_req, 1)
        hi = lo + clips_per_req
        reqs.append(Request(id0 + i, ds.clip_tokens[lo:hi],
                            ds.context_tokens[lo:hi], ds.clip_mask[lo:hi]))
    return reqs


def reference_totals(params, cfg, config: EngineConfig,
                     reqs: List[Request]) -> Dict[int, float]:
    """Monolithic fp32 totals per request id — the trusted answer the
    faulted phase's successful results are gated against.  Callers pass
    a bounded sample: the monolithic path is the slow rung by design
    (that is the whole point of the ladder), so gating every full-scale
    request here would dwarf the bench itself."""
    eng = PredictorEngine(params, cfg, config.replace(
        precision=None, fused_serving=False, rt_cache=False,
        rt_store_dir=None, faults=()))
    for r in reqs:
        eng.submit(r)
    return {r.request_id: r.total_cycles for r in eng.flush()}


def drive_phase(svc: SimulationService, reqs: List[Request],
                n_tenants: int, mean_gap_s: float, deadline_s: float,
                rng: np.random.Generator
                ) -> Tuple[List, List[float], float]:
    """Open-loop Poisson driver: merge the tenants' exponential arrival
    schedules and submit on the clock.  Returns (results, client-side
    latencies of successful requests, wall seconds)."""
    per_tenant = max(1, len(reqs) // n_tenants)
    arrivals = []                                  # (t, req)
    k = 0
    for _ in range(n_tenants):
        t = 0.0
        for _ in range(per_tenant):
            if k >= len(reqs):
                break
            t += float(rng.exponential(mean_gap_s))
            arrivals.append((t, reqs[k]))
            k += 1
    arrivals.sort(key=lambda a: a[0])

    t0 = time.time()
    submitted = []                                 # (ticket, t_submit)
    for t_at, req in arrivals:
        now = time.time() - t0
        if t_at > now:
            time.sleep(t_at - now)
        submitted.append((svc.submit(req, deadline_s=deadline_s),
                          time.time()))
    results, latencies = [], []
    for ticket, t_sub in submitted:
        # typed-result contract: generous absolute cap, never a hang
        res = ticket.result(timeout=deadline_s + 600)
        results.append(res)
        if res.ok:
            latencies.append(time.time() - t_sub if not res.latency_seconds
                             else res.latency_seconds)
    return results, latencies, time.time() - t0


def settle_to_top(svc: SimulationService, reqs: List[Request],
                  deadline_s: float, max_extra: int = 60) -> int:
    """Trickle requests one at a time until the service re-promotes to
    the top tier (bounded).  Returns how many it took."""
    top = svc.tier_stats[0].name
    for i in range(max_extra):
        if svc.current_tier == top:
            return i
        r = reqs[i % len(reqs)]
        svc.submit(Request(10_000_000 + i, r.clip_tokens,
                           r.context_tokens, r.clip_mask),
                   deadline_s=deadline_s).result(timeout=deadline_s + 600)
    return max_extra


def phase_block(results, latencies, wall: float, svc) -> Dict:
    statuses: Dict[str, int] = {}
    for r in results:
        statuses[r.status] = statuses.get(r.status, 0) + 1
    ok_clips = sum(r.n_clips for r in results if r.ok)
    return {
        "n_requests": len(results),
        "statuses": statuses,
        "p50_s": _percentile(latencies, 50),
        "p99_s": _percentile(latencies, 99),
        "clips_per_s": ok_clips / max(wall, 1e-9),
        "wall_s": wall,
        "tier_end": svc.current_tier,
    }


def run_level(params, cfg, ds, n_tenants: int, *, quick: bool,
              rel_err_gate: float, seed: int,
              metrics_port: Optional[int] = None,
              flight_dir: Optional[str] = None,
              trace_out: Optional[str] = None) -> Dict:
    per_req = 8 if quick else 16
    n_req = n_tenants * (4 if quick else 6)
    mean_gap = 0.25 if quick else 0.1
    deadline = 30.0 if quick else 120.0
    obs_cfg = None
    if flight_dir or trace_out:
        obs_cfg = ObservabilityConfig(trace=bool(trace_out),
                                      flight_dir=flight_dir)
    config = EngineConfig(
        batch_size=32 if quick else 64, l_clip=64, l_token=16,
        faults=FAULT_MIX_10PCT, fault_seed=seed,
        observability=obs_cfg)
    sla = ServiceSLA(queue_limit=max(64, 2 * n_req),
                     default_deadline_s=deadline,
                     watchdog_s=15.0 if quick else 45.0,
                     promote_after=2, backoff_max=8)
    rng = np.random.default_rng(seed)

    level: Dict = {"n_tenants": n_tenants}
    with SimulationService(params, cfg, config, sla=sla) as svc:
        base = n_tenants * 1_000_000
        all_reqs = make_requests(ds, 3 * n_req, per_req, base)
        h_reqs, f_reqs, r_reqs = (all_reqs[:n_req],
                                  all_reqs[n_req:2 * n_req],
                                  all_reqs[2 * n_req:])
        # gate sample: only faulted-phase results are rel-err gated, and
        # only a bounded prefix of them is worth a monolithic replay
        ref = reference_totals(params, cfg, config,
                               f_reqs[: 24 if quick else 32])
        svc.prewarm(Request(base - 1, h_reqs[0].clip_tokens[:2],
                            h_reqs[0].context_tokens[:2],
                            h_reqs[0].clip_mask[:2]))

        svc.injector.set_enabled(False)
        res_h, lat_h, wall_h = drive_phase(svc, h_reqs, n_tenants,
                                           mean_gap, deadline, rng)
        level["healthy"] = phase_block(res_h, lat_h, wall_h, svc)

        svc.injector.set_enabled(True)
        res_f, lat_f, wall_f = drive_phase(svc, f_reqs, n_tenants,
                                           mean_gap, deadline, rng)
        level["faulted"] = phase_block(res_f, lat_f, wall_f, svc)
        level["faults_fired"] = svc.injector.stats()
        if metrics_port is not None:
            # live scrape between phases: the exporter serves while the
            # service is still taking traffic
            level["metrics_probe_mid"] = scrape_transitions(
                metrics_port, svc.instance)

        svc.injector.set_enabled(False)
        res_r, lat_r, wall_r = drive_phase(svc, r_reqs, n_tenants,
                                           mean_gap, deadline, rng)
        extra = settle_to_top(svc, r_reqs, deadline)
        level["recovery"] = phase_block(res_r, lat_r, wall_r, svc)
        level["recovery"]["settle_requests"] = extra

        # gates -----------------------------------------------------------
        every = res_h + res_f + res_r
        typed = all(r.status in ("ok", "degraded", "overloaded",
                                 "deadline_exceeded", "failed")
                    for r in every) and len(every) == 3 * n_req
        worst_rel = 0.0
        for r in res_f:
            if r.ok and ref.get(r.request_id):
                worst_rel = max(worst_rel,
                                abs(r.total_cycles - ref[r.request_id])
                                / abs(ref[r.request_id]))
        level["gates"] = {
            "typed": typed,
            "n_ref_sampled": len(ref),
            "worst_faulted_rel_err": worst_rel,
            "gated": worst_rel <= rel_err_gate,
            "repromoted": svc.current_tier == svc.tier_stats[0].name,
        }
        level["stats"] = svc.stats()
        if metrics_port is not None:
            probe = scrape_transitions(metrics_port, svc.instance)
            level["metrics_probe"] = probe
            flight_last = (svc.obs.flight.last
                           if svc.obs.flight is not None else None)
            level["gates"].update(transition_gates(
                probe, level["stats"], flight_last))
        if svc.obs.flight is not None:
            level["postmortems"] = list(svc.obs.flight.postmortems)
    if trace_out:
        svc.obs.tracer.dump(trace_out)
    return level


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true",
                    help="CI smoke: bench-scale model (d_model=64), "
                         "tenant levels 1/8, int8 gate 5%%")
    ap.add_argument("--tenants", type=int, nargs="*", default=None,
                    help="override the tenant levels (default 1 8 64; "
                         "--quick default 1 8)")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--json", default=None, metavar="PATH",
                    help="write the schema-stamped breakdown artifact")
    ap.add_argument("--metrics-port", type=int, default=0, metavar="PORT",
                    help="serve /metrics for the run and probe it "
                         "between phases (0 = ephemeral port; the "
                         "tier-transition consistency gates always run)")
    ap.add_argument("--no-metrics", action="store_true",
                    help="skip the exporter + probe + consistency gates")
    ap.add_argument("--flight-dir", default=None, metavar="DIR",
                    help="flight-recorder postmortem directory: every "
                         "demotion dumps events + spans + metrics + the "
                         "service snapshot as JSON")
    ap.add_argument("--trace-out", default=None, metavar="PATH",
                    help="enable span tracing; dump the last level's "
                         "Chrome/Perfetto trace JSON here")
    args = ap.parse_args()

    quick = args.quick
    levels = args.tenants or ([1, 8] if quick else [1, 8, 64])
    cfg = bench_cfg() if quick else full_cfg()
    rel_err_gate = 0.05 if quick else 0.01
    params = predictor.init_params(cfg, jax.random.PRNGKey(0))
    ds = get_mixed_dataset(4 if quick else 8)

    metrics_port = None
    metrics_server = None
    if not args.no_metrics:
        from repro.obs.exporter import serve_metrics
        metrics_server = serve_metrics(port=args.metrics_port)
        metrics_port = metrics_server.server_address[1]
        print(f"metrics: http://127.0.0.1:{metrics_port}/metrics")

    out = {"schema_version": SERVING_BENCH_SCHEMA_VERSION,
           "quick": quick, "rel_err_gate": rel_err_gate,
           "metrics_port": metrics_port, "levels": []}
    ok = True
    for n in levels:
        print(f"== {n} tenant(s) ==")
        level = run_level(params, cfg, ds, n, quick=quick,
                          rel_err_gate=rel_err_gate, seed=args.seed,
                          metrics_port=metrics_port,
                          flight_dir=args.flight_dir,
                          trace_out=args.trace_out)
        out["levels"].append(level)
        for ph in ("healthy", "faulted", "recovery"):
            b = level[ph]
            print(f"  {ph:9s} p50={b['p50_s']:6.2f}s p99={b['p99_s']:6.2f}s "
                  f"{b['clips_per_s']:7.1f} clips/s {b['statuses']} "
                  f"tier_end={b['tier_end']}")
        print(f"  faults fired: {level['faults_fired']}")
        g = level["gates"]
        print(f"  gates: typed={g['typed']} gated={g['gated']} "
              f"(worst rel err {g['worst_faulted_rel_err']:.2e} <= "
              f"{rel_err_gate}) repromoted={g['repromoted']}")
        ok = ok and g["typed"] and g["gated"] and g["repromoted"]
        if "metrics_consistent" in g:
            print(f"  ledgers: metrics_consistent="
                  f"{g['metrics_consistent']} "
                  f"(demote {g['probed_demote_transitions']}/"
                  f"{g['expected_demote_transitions']}, promote "
                  f"{g['probed_promote_transitions']}/"
                  f"{g['expected_promote_transitions']}) "
                  f"flight_consistent={g['flight_consistent']}")
            ok = ok and g["metrics_consistent"] \
                and g["flight_consistent"] is not False

    # the 1-tenant healthy p99 bound: generous, absolute, runner-safe
    p99_bound = 20.0 if quick else 60.0
    p99 = out["levels"][0]["healthy"]["p99_s"]
    out["p99_bound_s"] = p99_bound
    out["gates_pass"] = bool(ok and p99 <= p99_bound)
    print(f"1-tenant healthy p99 {p99:.2f}s (bound {p99_bound}s); "
          f"all gates {'PASS' if out['gates_pass'] else 'FAIL'}")
    if metrics_server is not None:
        metrics_server.shutdown()
    if args.json:
        Path(args.json).write_text(json.dumps(out, indent=2))
        print(f"wrote {args.json}")
    if not out["gates_pass"]:
        sys.exit(1)


if __name__ == "__main__":
    main()
