"""Train an assigned-architecture LM on synthetic tokens (runtime driver).

    PYTHONPATH=src python examples/train_lm.py --arch olmo-1b --steps 30

Uses the smoke-scale config of the requested architecture (the full configs
are exercised by the multi-pod dry-run; 1B-1T params do not fit a CPU dev
box).  Demonstrates the shared runtime: logical-axis sharding, AdamW,
gradient clipping, checkpoint/restart — identical code paths to the pod
launcher (repro/launch/train.py).
"""
import argparse
import time

import jax

from repro.checkpoint.ckpt import CheckpointManager
from repro.configs import ShapeConfig, get_smoke_config
from repro.distributed.fault_tolerance import ResilientTrainer
from repro.distributed.sharding import (LOGICAL_RULES_TRAIN,
                                        use_mesh_and_rules)
from repro.launch.mesh import make_test_mesh
from repro.launch.specs import random_batch
from repro.models import transformer as tfm
from repro.training.train_loop import (TrainConfig, init_train_state,
                                       make_train_step)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="olmo-1b")
    ap.add_argument("--steps", type=int, default=30)
    ap.add_argument("--batch-size", type=int, default=4)
    ap.add_argument("--seq-len", type=int, default=128)
    ap.add_argument("--ckpt-dir", default="results/ckpt_lm")
    args = ap.parse_args()

    cfg = get_smoke_config(args.arch)
    shape = ShapeConfig("train", args.seq_len, args.batch_size, "train")
    tcfg = TrainConfig(optimizer="adamw", base_lr=3e-4,
                       warmup_steps=max(1, args.steps // 10),
                       total_steps=args.steps)

    mesh = make_test_mesh()
    with use_mesh_and_rules(mesh, LOGICAL_RULES_TRAIN):
        params = tfm.init_params(cfg, jax.random.PRNGKey(0))
        n = sum(p.size for p in jax.tree_util.tree_leaves(params))
        print(f"{args.arch} (smoke): {n/1e6:.1f}M params, "
              f"batch {args.batch_size} x seq {args.seq_len}")
        state = init_train_state(params, tcfg)
        step = jax.jit(make_train_step(
            lambda p, b: tfm.loss_fn(p, b, cfg), tcfg))
        trainer = ResilientTrainer(
            step_fn=step,
            ckpt=CheckpointManager(args.ckpt_dir, keep=2),
            save_every=max(10, args.steps // 2), log_every=5,
            log_fn=lambda i, m: print(
                f"  step {i:4d} loss {m['loss']:.4f} ce {m['ce']:.4f} "
                f"gnorm {m['grad_norm']:.2f}"))

        def batch_iter():
            i = 0
            while True:
                yield random_batch(cfg, shape, "train", seed=i)
                i += 1

        t0 = time.time()
        state, n_steps = trainer.run(state, batch_iter(),
                                     total_steps=args.steps)
        print(f"{n_steps} steps in {time.time()-t0:.0f}s "
              f"({(time.time()-t0)/max(n_steps,1):.2f} s/step)")


if __name__ == "__main__":
    main()
