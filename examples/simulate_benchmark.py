"""CAPSim vs the O3 oracle on whole benchmarks (paper Fig 1 / Fig 7).

    PYTHONPATH=src python examples/simulate_benchmark.py [--ckpt results/ckpt_capsim]

All requested benchmarks run through the batched multi-benchmark
``SimulationEngine``: each program's functional sim + tokenization feeds a
*shared* clip pool, and one cached-jit predictor consumes size-bucketed
device batches asynchronously while the CPU works ahead on the next
program — so accelerator batches fill across program boundaries instead of
each benchmark padding its own remainder.  Per-benchmark results are
bitwise identical to the sequential ``capsim_simulate`` wrapper.

For each benchmark: report the functional+predictor wall time (CAPSim
path), the cycle-level oracle wall time (conventional path), the speedup,
and the prediction error.  With an untrained predictor the error column is
meaningless — pass --ckpt to use weights from examples/train_capsim.py.
"""
import argparse
import os

import jax

from repro.checkpoint.ckpt import CheckpointManager
from repro.configs import get_config
from repro.core import predictor
from repro.core.engine import SimulationEngine
from repro.core.engine_config import EngineConfig
from repro.core.standardize import build_vocab


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--ckpt", default=None)
    ap.add_argument("--benchmarks", nargs="*",
                    default=["503.bwaves", "505.mcf", "548.exchange2"])
    ap.add_argument("--interval-size", type=int, default=20_000)
    ap.add_argument("--max-checkpoints", type=int, default=4)
    ap.add_argument("--no-rt-cache", action="store_true",
                    help="monolithic predict path (bitwise reference)")
    ap.add_argument("--precision", default=None, choices=("fp32", "bf16"))
    ap.add_argument("--mesh", type=int, default=0,
                    help="shard inference over an N-device data mesh")
    args = ap.parse_args()
    if args.mesh > 1 and "--xla_force_host_platform_device_count" \
            not in os.environ.get("XLA_FLAGS", ""):
        # must land before jax's first backend init (imports don't lock
        # the device count; the first device op does)
        os.environ["XLA_FLAGS"] = (
            os.environ.get("XLA_FLAGS", "")
            + f" --xla_force_host_platform_device_count={args.mesh}")

    vocab = build_vocab()
    cfg = get_config("capsim").replace(dtype="float32")
    params = predictor.init_params(cfg, jax.random.PRNGKey(0))
    if args.ckpt:
        mgr = CheckpointManager(args.ckpt)
        from repro.training.train_loop import TrainConfig, init_train_state
        state_like = init_train_state(params, TrainConfig())
        restored, step = mgr.restore_latest(state_like)
        if restored is not None:
            params = restored["params"]
            print(f"restored predictor from step {step}")

    config = EngineConfig(interval_size=args.interval_size,
                          max_checkpoints=args.max_checkpoints,
                          rt_cache=not args.no_rt_cache,
                          precision=args.precision,
                          mesh_shape=(args.mesh,) if args.mesh else ())
    engine = SimulationEngine.from_config(params, cfg, vocab, config)
    engine.submit_names(args.benchmarks)
    results = engine.run()

    print(f"{'benchmark':16s} {'insts':>8s} {'clips':>6s} {'oracle_s':>9s} "
          f"{'capsim_s':>9s} {'speedup':>8s} {'rel_err':>8s}")
    for r in results:
        print(f"{r.name:16s} {r.n_instructions:8d} {r.n_clips:6d} "
              f"{r.oracle_seconds:9.2f} {r.capsim_seconds:9.2f} "
              f"{r.speedup:7.2f}x {100*r.rel_error:7.1f}%")
    stats = engine.last_stats
    print(f"pool: {stats.n_clips} clips in {stats.n_batches} device "
          f"batches ({stats.n_pad} pad rows)")
    rt = engine.last_rt_stats
    if rt is not None:
        print(f"rt-cache: {rt.n_rows_encoded} static rows encoded "
              f"({rt.build_seconds:.2f}s) served {rt.n_rows_served} "
              f"dynamic rows — instruction encoder skipped for "
              f"{rt.rows_avoided}")


if __name__ == "__main__":
    main()
