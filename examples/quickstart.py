"""Quickstart: the CAPSim pipeline end to end in ~a minute on CPU.

    PYTHONPATH=src python examples/quickstart.py

1. generate a synthetic benchmark (SPEC-2017 stand-in),
2. trace it functionally, time it with the O3 oracle,
3. slice the timed trace into code clips (Algorithm 1), sample them,
4. tokenize (standardization + context matrix),
5. run the attention predictor on the clips and compare against the oracle.
"""
import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.core import predictor
from repro.core.context import context_token_ids
from repro.core.sampler import sample_clips
from repro.core.slicer import slice_trace
from repro.core.standardize import build_vocab, encode_clip
from repro.isa import funcsim, progen, timing


def main() -> None:
    # 1. a benchmark from the suite (Table II)
    bench = progen.build_benchmark("503.bwaves")
    print(f"benchmark {bench.name}: tags={bench.tags}, "
          f"{len(bench.program)} static instructions")

    # 2. functional trace + O3 oracle commit times
    state = progen.fresh_state(bench)
    trace, snaps, _ = funcsim.run(bench.program, 20_000, state=state,
                                  snapshot_every=100)
    commits = timing.simulate(trace)
    print(f"traced {len(trace)} instructions -> {commits[-1]} cycles "
          f"(IPC {len(trace)/commits[-1]:.2f})")

    # 3. slice + sample
    clips = slice_trace([e.inst for e in trace], commits, l_min=100)
    sampled, stats = sample_clips(clips, threshold=50, coef=0.1)
    print(f"sliced {stats.n_in} clips ({stats.n_groups} unique contents) "
          f"-> sampled {stats.n_out}")

    # 4. tokenize
    vocab = build_vocab()
    cfg = get_config("capsim").replace(dtype="float32")
    batch = {"clip_tokens": [], "context_tokens": [], "clip_mask": []}
    for i, clip in enumerate(sampled[:16]):
        toks, mask = encode_clip(clip.insts, vocab, 128, cfg.clip_tokens)
        batch["clip_tokens"].append(toks)
        batch["clip_mask"].append(mask)
        snap = snaps[min(clip.start // 100, len(snaps) - 1)]
        batch["context_tokens"].append(context_token_ids(snap, vocab))
    batch = {k: jnp.asarray(np.stack(v)) for k, v in batch.items()}

    # 5. predict (untrained weights here; see train_capsim.py)
    params = predictor.init_params(cfg, jax.random.PRNGKey(0))
    pred = predictor.predict_step(params, batch, cfg)
    fact = np.array([c.time for c in sampled[:16]])
    print("\n  clip  predicted  oracle")
    for i in range(8):
        print(f"  {i:4d} {float(pred[i]):9.1f} {fact[i]:7.1f}")
    print("\n(untrained predictor — run examples/train_capsim.py to fit it)")


if __name__ == "__main__":
    main()
