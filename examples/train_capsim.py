"""End-to-end driver: build the clip dataset, train the CAPSim predictor,
report validation MAPE, checkpoint/resume.

    PYTHONPATH=src python examples/train_capsim.py [--steps 200] [--fast]

Paper recipe (§VI-B): SGD momentum 0.9, lr 1e-3, MAPE loss, 80/10/10 split.
``--fast`` shrinks the model/data for a ~2-minute CPU run; the default is
the paper-exact E=128 / 4+4-layer model.
"""
import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint.ckpt import CheckpointManager
from repro.configs import get_config
from repro.core import predictor
from repro.core.standardize import build_vocab
from repro.data.dataset import (BuildConfig, batches, build_dataset,
                                split_dataset)
from repro.distributed.fault_tolerance import ResilientTrainer
from repro.training.train_loop import (TrainConfig, init_train_state,
                                       make_train_step)


def evaluate(params, cfg, ds, batch_size) -> float:
    errs = []
    batch_size = max(1, min(batch_size, len(ds)))
    for b in batches(ds, batch_size, shuffle=False):
        bj = {k: jnp.asarray(v) for k, v in b.items()}
        pred = np.asarray(predictor.predict_step(params, bj, cfg))
        fact = np.maximum(np.asarray(b["time"]), 1.0)
        errs.extend(np.abs(pred - fact) / fact)
    return float(np.mean(errs)) if errs else float("nan")


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--batch-size", type=int, default=16)
    ap.add_argument("--fast", action="store_true",
                    help="reduced model + data (CI-sized)")
    ap.add_argument("--ckpt-dir", default="results/ckpt_capsim")
    args = ap.parse_args()

    vocab = build_vocab()
    cfg = get_config("capsim").replace(dtype="float32")
    bcfg = BuildConfig(interval_size=10_000, warmup=1_000,
                       max_checkpoints=2, threshold=50, coef=0.1)
    bench_names = ["503.bwaves", "505.mcf", "525.x264", "541.leela",
                   "520.omnetpp", "508.namd"]
    if args.fast:
        cfg = cfg.replace(d_model=64, head_dim=16, d_ff=256)
        bcfg = BuildConfig(interval_size=5_000, warmup=500,
                           max_checkpoints=1, threshold=50, coef=0.1,
                           l_clip=64, l_min=50)
        bench_names = bench_names[:3]

    print("building clip dataset ...")
    ds = build_dataset(bench_names, bcfg, vocab, verbose=True)
    train, val, test = split_dataset(ds)
    print(f"clips: train={len(train)} val={len(val)} test={len(test)}")

    tcfg = TrainConfig(optimizer="sgdm", base_lr=1e-3, momentum=0.9,
                       warmup_steps=max(1, args.steps // 10),
                       total_steps=args.steps)
    params = predictor.init_params(cfg, jax.random.PRNGKey(0))
    state = init_train_state(params, tcfg)
    step = jax.jit(make_train_step(
        lambda p, b: predictor.mape_loss(p, b, cfg), tcfg))

    trainer = ResilientTrainer(
        step_fn=lambda s, b: step(s, {k: jnp.asarray(v)
                                      for k, v in b.items()}),
        ckpt=CheckpointManager(args.ckpt_dir, keep=2),
        save_every=max(50, args.steps // 4),
        log_fn=lambda i, m: print(
            f"  step {i:5d} mape {m['loss']:.4f} lr {m['lr']:.2e}"))
    trainer.install_signal_handler()

    t0 = time.time()
    state, n = trainer.run(state, batches(train, args.batch_size,
                                          epochs=100_000),
                           total_steps=args.steps)
    print(f"trained {n} steps in {time.time()-t0:.0f}s")

    for name, d in (("val", val), ("test", test)):
        mape = evaluate(state["params"], cfg, d, args.batch_size)
        print(f"{name} MAPE {mape:.4f}  (accuracy {100*(1-mape):.1f}%)")


if __name__ == "__main__":
    main()
