"""Mesh construction (functions only — importing this never touches jax
device state; jax locks the device count on first backend init)."""
from __future__ import annotations

import jax


def make_mesh_compat(shape, axes):
    """``jax.make_mesh`` with explicit-Auto axis types where the installed
    jax supports them (>= 0.6, where meshes default to explicit sharding
    contexts) and plain construction on older releases that predate
    ``jax.sharding.AxisType``."""
    axis_type = getattr(jax.sharding, "AxisType", None)
    if axis_type is not None:
        return jax.make_mesh(shape, axes,
                             axis_types=(axis_type.Auto,) * len(axes))
    return jax.make_mesh(shape, axes)


def make_production_mesh(*, multi_pod: bool = False):
    """Single pod: (16,16)=256 chips (data, model).
    Multi-pod: (2,16,16)=512 chips (pod, data, model)."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return make_mesh_compat(shape, axes)


def make_test_mesh():
    """1-device mesh with the standard axis names (CPU tests)."""
    return make_mesh_compat((1, 1), ("data", "model"))


def make_data_mesh(n_shards: int):
    """1-D pure data-parallel mesh over ``n_shards`` devices — the
    inference-engine mesh (``EngineConfig.mesh_shape``): the predictor's
    ~2M params replicate, clip batches shard over the single "data"
    axis.  CI reaches 8 CPU shards via
    ``XLA_FLAGS=--xla_force_host_platform_device_count=8`` (set before
    jax's first backend init)."""
    if n_shards < 1:
        raise ValueError(f"n_shards must be >= 1, got {n_shards}")
    have = len(jax.devices())
    if n_shards > have:
        raise ValueError(
            f"mesh of {n_shards} devices requested but only {have} "
            f"visible — on CPU, set XLA_FLAGS="
            f"--xla_force_host_platform_device_count={n_shards} before "
            "jax initializes its backend")
    return make_mesh_compat((n_shards,), ("data",))


def mesh_axis_sizes(mesh) -> dict:
    return dict(zip(mesh.axis_names, mesh.devices.shape))


def num_chips(mesh) -> int:
    n = 1
    for s in mesh.devices.shape:
        n *= s
    return n
