"""Training launcher: ``python -m repro.launch.train --arch <id> ...``.

Two families share this entry point:
  - ``--arch capsim`` (default): build the clip dataset from the synthetic
    suite, train the attention predictor (paper §VI-B: SGD momentum 0.9,
    lr 1e-3, MAPE), with checkpoint/restart via ResilientTrainer.
    ``--multicore N`` switches the build to N-core mt.* shards with
    ``simulate_multicore`` commit deltas as ground truth and reports the
    held-out mt.* eval MAPE against that oracle (``--peer-channels``
    mixes the other cores' register blocks into every context matrix).
  - any LM-zoo arch: train the (smoke-scaled) LM on synthetic tokens —
    the end-to-end driver for the assigned-architecture runtime.

On a real pod this process runs once per host (jax.distributed initializes
from the cluster env); the mesh comes from launch/mesh.py and all shardings
from the logical rules.  On this CPU host it runs single-process.
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint.ckpt import CheckpointManager
from repro.configs import ShapeConfig, get_config, get_smoke_config
from repro.distributed.fault_tolerance import ResilientTrainer
from repro.distributed.sharding import (
    LOGICAL_RULES_PREDICTOR, LOGICAL_RULES_TRAIN, use_mesh_and_rules)
from repro.launch.mesh import make_test_mesh
from repro.training.train_loop import (
    TrainConfig, init_train_state, make_train_step)


def _capsim_cfg(args, vocab):
    """Resolve the predictor config for a training run.  Smoke keeps the
    tiny model but must still embed the REAL vocabulary: ids above
    vocab_size would silently clamp in the embedding gather."""
    cfg = get_config("capsim").replace(dtype="float32")
    if args.smoke:
        cfg = get_smoke_config("capsim")
    return cfg.replace(vocab_size=max(cfg.vocab_size, vocab.size))


def _fit_predictor(args, cfg, train_ds):
    """Shared MAPE training loop (paper §VI-B recipe) — returns the
    trained state.  Caller must hold the mesh/rules context."""
    from repro.core import predictor
    from repro.data.dataset import batches

    tcfg = TrainConfig(optimizer="sgdm", base_lr=args.lr,
                       warmup_steps=min(20, args.steps // 10),
                       total_steps=args.steps)
    params = predictor.init_params(cfg, jax.random.PRNGKey(args.seed))
    state = init_train_state(params, tcfg)
    step = jax.jit(make_train_step(
        lambda p, b: predictor.mape_loss(p, b, cfg), tcfg))

    ckpt = CheckpointManager(args.ckpt_dir, keep=2)
    trainer = ResilientTrainer(
        step_fn=lambda s, b: step(
            s, {k: jnp.asarray(v) for k, v in b.items()}),
        ckpt=ckpt, save_every=args.save_every,
        log_fn=lambda i, m: print(
            f"  step {i:5d} mape {m['loss']:.4f} lr {m['lr']:.2e}"))
    trainer.install_signal_handler()
    t0 = time.time()
    state, step_n = trainer.run(
        state, batches(train_ds, args.batch_size, epochs=10_000),
        total_steps=args.steps)
    print(f"trained to step {step_n} in {time.time()-t0:.0f}s")
    return state


def _eval_mape(params, cfg, ds, batch_size):
    """MAPE of the trained predictor against the dataset's ground-truth
    clip times (overall, per-benchmark).  For multicore builds the time
    column IS the ``simulate_multicore`` per-core commit delta, so this
    is the eval-vs-oracle number."""
    from repro.core import predictor

    errs, names = [], []
    n = len(ds)
    bs = max(1, min(batch_size, n))
    # plain range slicing, not dataset.batches(): that iterator drops the
    # short final batch (a training-loop convenience), which would leave
    # the last shard's tail out of the advertised held-out eval
    for off in range(0, n, bs):
        sub = ds.select(np.arange(off, min(off + bs, n)))
        bj = {"clip_tokens": jnp.asarray(sub.clip_tokens),
              "context_tokens": jnp.asarray(sub.context_tokens),
              "clip_mask": jnp.asarray(sub.clip_mask)}
        pred = predictor.predict_step(params, bj, cfg)
        fact = np.maximum(sub.time, 1.0)
        errs.extend(np.abs(np.asarray(pred) - fact) / fact)
        names.extend(sub.bench_names)
    if not errs:
        return float("nan"), {}
    errs = np.asarray(errs)
    names = np.asarray(names)
    per_bench = {n: float(errs[names == n].mean())
                 for n in sorted(set(names.tolist()))}
    return float(errs.mean()), per_bench


def train_capsim(args) -> None:
    from repro.core.standardize import build_vocab
    from repro.data.dataset import (BuildConfig, build_dataset,
                                    split_dataset)
    from repro.isa.progen import TABLE_II

    vocab = build_vocab()
    cfg = _capsim_cfg(args, vocab)
    bcfg = BuildConfig(interval_size=args.interval_size,
                       warmup=args.interval_size // 10,
                       max_checkpoints=args.max_checkpoints)
    names = list(TABLE_II)[: args.n_benchmarks]
    print(f"building clip dataset from {len(names)} benchmarks ...")
    ds = build_dataset(names, bcfg, vocab, verbose=True)
    train, val, _ = split_dataset(ds)
    print(f"clips: train={len(train)} val={len(val)}")

    mesh = make_test_mesh()
    with use_mesh_and_rules(mesh, LOGICAL_RULES_PREDICTOR):
        state = _fit_predictor(args, cfg, train)
        mape, _ = _eval_mape(state["params"], cfg, val, args.batch_size)
        if mape == mape:                               # not NaN
            print(f"validation MAPE: {mape:.4f} "
                  f"(accuracy {100*(1-mape):.1f}%)")


def train_capsim_multicore(args) -> None:
    """The multicore training subsystem end to end: contention-aware
    dataset build (per-core Algorithm-1 slicing over the
    ``simulate_multicore`` oracle) -> MAPE train -> held-out mt.* eval
    against the oracle's per-core commit deltas."""
    from repro.core import context as ctx_mod
    from repro.core.standardize import build_vocab
    from repro.data.dataset import BuildStats, split_dataset
    from repro.data.multicore_dataset import (MulticoreBuildConfig,
                                              build_multicore_dataset)
    from repro.isa.multicore import MULTICORE_NAMES

    vocab = build_vocab()
    cfg = _capsim_cfg(args, vocab)
    bcfg = MulticoreBuildConfig(
        interval_size=args.interval_size,
        warmup=args.interval_size // 10,
        max_checkpoints=args.max_checkpoints,
        n_cores=args.multicore,
        peer_channels=args.peer_channels)
    names = list(MULTICORE_NAMES)[: args.n_benchmarks]
    print(f"building multicore clip dataset: {len(names)} benchmarks "
          f"x {bcfg.n_cores} cores (peer_channels={bcfg.peer_channels}, "
          f"context width {bcfg.context_len}) ...")
    stats = BuildStats()
    t0 = time.time()
    ds = build_multicore_dataset(names, bcfg, vocab, verbose=True,
                                 stats=stats)
    build_s = time.time() - t0
    assert ds.context_len == ctx_mod.context_len(
        bcfg.n_cores, bcfg.peer_channels)
    print(f"built {len(ds)} clips in {build_s:.1f}s "
          f"({len(ds)/max(build_s, 1e-9):.0f} clips/s; interpret "
          f"{stats.interpret_seconds:.1f}s oracle "
          f"{stats.oracle_seconds:.1f}s replay "
          f"{stats.replay_seconds:.1f}s)")
    train, val, test = split_dataset(ds)
    print(f"clips: train={len(train)} val={len(val)} "
          f"held-out={len(test)}")

    mesh = make_test_mesh()
    with use_mesh_and_rules(mesh, LOGICAL_RULES_PREDICTOR):
        state = _fit_predictor(args, cfg, train)
        val_mape, _ = _eval_mape(state["params"], cfg, val,
                                 args.batch_size)
        test_mape, per_bench = _eval_mape(state["params"], cfg, test,
                                          args.batch_size)
    # ---- run summary (the mt.* eval protocol) ----
    print(f"validation MAPE: {val_mape:.4f}")
    print(f"mt.* held-out eval MAPE vs simulate_multicore oracle: "
          f"{test_mape:.4f} (accuracy {100*(1-test_mape):.1f}%, "
          f"{bcfg.n_cores} cores, peer_channels={bcfg.peer_channels})")
    for name, m in per_bench.items():
        print(f"  {name}: MAPE {m:.4f}")


def train_lm(args) -> None:
    from repro.launch.specs import random_batch
    from repro.models import transformer as tfm

    cfg = get_smoke_config(args.arch) if args.smoke else get_config(args.arch)
    shape = ShapeConfig("train", args.seq_len, args.batch_size, "train")
    tcfg = TrainConfig(optimizer="adamw", base_lr=args.lr,
                       warmup_steps=min(20, args.steps // 10),
                       total_steps=args.steps)
    mesh = make_test_mesh()
    with use_mesh_and_rules(mesh, LOGICAL_RULES_TRAIN):
        params = tfm.init_params(cfg, jax.random.PRNGKey(args.seed))
        n = sum(p.size for p in jax.tree_util.tree_leaves(params))
        print(f"{args.arch}: {n/1e6:.1f}M params (smoke={args.smoke})")
        state = init_train_state(params, tcfg)
        step = jax.jit(make_train_step(
            lambda p, b: tfm.loss_fn(p, b, cfg), tcfg))
        ckpt = CheckpointManager(args.ckpt_dir, keep=2)
        trainer = ResilientTrainer(
            step_fn=step, ckpt=ckpt, save_every=args.save_every,
            log_fn=lambda i, m: print(
                f"  step {i:5d} loss {m['loss']:.4f} ce {m['ce']:.4f}"))

        def batch_iter():
            i = 0
            while True:
                yield random_batch(cfg, shape, "train", seed=i)
                i += 1

        state, step_n = trainer.run(state, batch_iter(),
                                    total_steps=args.steps)
        print(f"trained to step {step_n}")


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="capsim")
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--batch-size", type=int, default=32)
    ap.add_argument("--seq-len", type=int, default=128)
    ap.add_argument("--lr", type=float, default=1e-3)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--ckpt-dir", default="results/ckpt")
    ap.add_argument("--save-every", type=int, default=100)
    ap.add_argument("--interval-size", type=int, default=10_000)
    ap.add_argument("--max-checkpoints", type=int, default=2)
    ap.add_argument("--n-benchmarks", type=int, default=8)
    ap.add_argument("--multicore", type=int, default=0, metavar="N",
                    help="train on N-core mt.* shards (per-core "
                         "Algorithm-1 slicing over the "
                         "simulate_multicore oracle); 0 = single-core")
    ap.add_argument("--peer-channels", action="store_true",
                    help="append the other cores' <CORE>-tagged register "
                         "blocks to every clip's context matrix")
    args = ap.parse_args()
    if args.arch != "capsim":
        train_lm(args)
    elif args.multicore:
        train_capsim_multicore(args)
    else:
        train_capsim(args)


if __name__ == "__main__":
    main()
