"""Training launcher: ``python -m repro.launch.train --arch <id> ...``.

Two families share this entry point:
  - ``--arch capsim`` (default): build the clip dataset from the synthetic
    suite, train the attention predictor (paper §VI-B: SGD momentum 0.9,
    lr 1e-3, MAPE), with checkpoint/restart via ResilientTrainer.
  - any LM-zoo arch: train the (smoke-scaled) LM on synthetic tokens —
    the end-to-end driver for the assigned-architecture runtime.

On a real pod this process runs once per host (jax.distributed initializes
from the cluster env); the mesh comes from launch/mesh.py and all shardings
from the logical rules.  On this CPU host it runs single-process.
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint.ckpt import CheckpointManager
from repro.configs import ShapeConfig, get_config, get_smoke_config
from repro.distributed.fault_tolerance import ResilientTrainer
from repro.distributed.sharding import (
    LOGICAL_RULES_PREDICTOR, LOGICAL_RULES_TRAIN, use_mesh_and_rules)
from repro.launch.mesh import make_test_mesh
from repro.training.train_loop import (
    TrainConfig, init_train_state, make_train_step)


def train_capsim(args) -> None:
    from repro.core import predictor
    from repro.core.standardize import build_vocab
    from repro.data.dataset import (BuildConfig, batches, build_dataset,
                                    split_dataset)
    from repro.isa.progen import TABLE_II

    vocab = build_vocab()
    cfg = get_config("capsim").replace(dtype="float32")
    if args.smoke:
        cfg = get_smoke_config("capsim")
    bcfg = BuildConfig(interval_size=args.interval_size,
                       warmup=args.interval_size // 10,
                       max_checkpoints=args.max_checkpoints)
    names = list(TABLE_II)[: args.n_benchmarks]
    print(f"building clip dataset from {len(names)} benchmarks ...")
    ds = build_dataset(names, bcfg, vocab, verbose=True)
    train, val, _ = split_dataset(ds)
    print(f"clips: train={len(train)} val={len(val)}")

    tcfg = TrainConfig(optimizer="sgdm", base_lr=args.lr,
                       warmup_steps=min(20, args.steps // 10),
                       total_steps=args.steps)
    mesh = make_test_mesh()
    with use_mesh_and_rules(mesh, LOGICAL_RULES_PREDICTOR):
        params = predictor.init_params(cfg, jax.random.PRNGKey(args.seed))
        state = init_train_state(params, tcfg)
        step = jax.jit(make_train_step(
            lambda p, b: predictor.mape_loss(p, b, cfg), tcfg))

        ckpt = CheckpointManager(args.ckpt_dir, keep=2)
        trainer = ResilientTrainer(
            step_fn=lambda s, b: step(
                s, {k: jnp.asarray(v) for k, v in b.items()}),
            ckpt=ckpt, save_every=args.save_every,
            log_fn=lambda i, m: print(
                f"  step {i:5d} mape {m['loss']:.4f} lr {m['lr']:.2e}"))
        trainer.install_signal_handler()
        t0 = time.time()
        state, step_n = trainer.run(
            state, batches(train, args.batch_size, epochs=10_000),
            total_steps=args.steps)
        print(f"trained to step {step_n} in {time.time()-t0:.0f}s")

        # validation MAPE
        errs = []
        eval_bs = max(1, min(args.batch_size, len(val)))
        for b in batches(val, eval_bs, shuffle=False):
            bj = {k: jnp.asarray(v) for k, v in b.items()}
            pred = predictor.predict_step(state["params"], bj, cfg)
            fact = np.maximum(np.asarray(b["time"]), 1.0)
            errs.extend(np.abs(np.asarray(pred) - fact) / fact)
        if errs:
            print(f"validation MAPE: {float(np.mean(errs)):.4f} "
                  f"(accuracy {100*(1-float(np.mean(errs))):.1f}%)")


def train_lm(args) -> None:
    from repro.launch.specs import random_batch
    from repro.models import transformer as tfm

    cfg = get_smoke_config(args.arch) if args.smoke else get_config(args.arch)
    shape = ShapeConfig("train", args.seq_len, args.batch_size, "train")
    tcfg = TrainConfig(optimizer="adamw", base_lr=args.lr,
                       warmup_steps=min(20, args.steps // 10),
                       total_steps=args.steps)
    mesh = make_test_mesh()
    with use_mesh_and_rules(mesh, LOGICAL_RULES_TRAIN):
        params = tfm.init_params(cfg, jax.random.PRNGKey(args.seed))
        n = sum(p.size for p in jax.tree_util.tree_leaves(params))
        print(f"{args.arch}: {n/1e6:.1f}M params (smoke={args.smoke})")
        state = init_train_state(params, tcfg)
        step = jax.jit(make_train_step(
            lambda p, b: tfm.loss_fn(p, b, cfg), tcfg))
        ckpt = CheckpointManager(args.ckpt_dir, keep=2)
        trainer = ResilientTrainer(
            step_fn=step, ckpt=ckpt, save_every=args.save_every,
            log_fn=lambda i, m: print(
                f"  step {i:5d} loss {m['loss']:.4f} ce {m['ce']:.4f}"))

        def batch_iter():
            i = 0
            while True:
                yield random_batch(cfg, shape, "train", seed=i)
                i += 1

        state, step_n = trainer.run(state, batch_iter(),
                                    total_steps=args.steps)
        print(f"trained to step {step_n}")


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="capsim")
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--batch-size", type=int, default=32)
    ap.add_argument("--seq-len", type=int, default=128)
    ap.add_argument("--lr", type=float, default=1e-3)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--ckpt-dir", default="results/ckpt")
    ap.add_argument("--save-every", type=int, default=100)
    ap.add_argument("--interval-size", type=int, default=10_000)
    ap.add_argument("--max-checkpoints", type=int, default=2)
    ap.add_argument("--n-benchmarks", type=int, default=8)
    args = ap.parse_args()
    if args.arch == "capsim":
        train_capsim(args)
    else:
        train_lm(args)


if __name__ == "__main__":
    main()
