"""Input specs (ShapeDtypeStruct stand-ins) + real random batches per
(arch x shape).  The dry-run lowers against the abstract version; smoke tests
and examples draw the concrete version.
"""
from __future__ import annotations


import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding

from repro.configs import ArchConfig, ShapeConfig
from repro.distributed.sharding import axis_rules


def _token_len(cfg: ArchConfig, seq_len: int) -> int:
    return seq_len - (cfg.frontend_len if cfg.frontend != "none" else 0)


def lm_batch_shapes(cfg: ArchConfig, shape: ShapeConfig, kind: str) -> dict:
    """Abstract structure of one input batch (without caches)."""
    B, S = shape.global_batch, shape.seq_len
    if kind == "decode":
        tok_shape = (B, 1, cfg.num_codebooks) if cfg.num_codebooks > 1 \
            else (B, 1)
        return {"tokens": jax.ShapeDtypeStruct(tok_shape, jnp.int32)}
    S_tok = _token_len(cfg, S)
    tok_shape = (B, S_tok, cfg.num_codebooks) if cfg.num_codebooks > 1 \
        else (B, S_tok)
    batch = {"tokens": jax.ShapeDtypeStruct(tok_shape, jnp.int32)}
    if cfg.frontend != "none":
        batch["frontend"] = jax.ShapeDtypeStruct(
            (B, cfg.frontend_len, cfg.d_model), jnp.float32)
    if cfg.mrope_sections:
        batch["positions"] = jax.ShapeDtypeStruct((3, B, S), jnp.int32)
    if kind == "train":
        lab_shape = (B, S, cfg.num_codebooks) if cfg.num_codebooks > 1 \
            else (B, S)
        batch["labels"] = jax.ShapeDtypeStruct(lab_shape, jnp.int32)
        batch["loss_mask"] = jax.ShapeDtypeStruct((B, S), jnp.float32)
    return batch


def capsim_batch_shapes(cfg: ArchConfig, shape: ShapeConfig,
                        kind: str) -> dict:
    B, L_clip = shape.global_batch, shape.seq_len
    batch = {
        "clip_tokens": jax.ShapeDtypeStruct(
            (B, L_clip, cfg.clip_tokens), jnp.int32),
        "context_tokens": jax.ShapeDtypeStruct(
            (B, cfg.context_tokens), jnp.int32),
        "clip_mask": jax.ShapeDtypeStruct((B, L_clip), jnp.float32),
    }
    if kind == "train":
        batch["time"] = jax.ShapeDtypeStruct((B,), jnp.float32)
    return batch


def input_specs(cfg: ArchConfig, shape: ShapeConfig, kind: str) -> dict:
    if cfg.family == "predictor":
        return capsim_batch_shapes(cfg, shape, kind)
    return lm_batch_shapes(cfg, shape, kind)


_BATCH_AXES = {
    "tokens": ("batch",),
    "labels": ("batch",),
    "loss_mask": ("batch",),
    "frontend": ("batch",),
    "clip_tokens": ("batch",),
    "context_tokens": ("batch",),
    "clip_mask": ("batch",),
    "time": ("batch",),
    "positions": (None, "batch"),  # (3, B, S): batch is dim 1
}


def batch_shardings(batch_abs: dict, mesh, rules) -> dict:
    out = {}
    for k, v in batch_abs.items():
        lead = _BATCH_AXES[k]
        logical = lead + (None,) * (len(v.shape) - len(lead))
        out[k] = NamedSharding(mesh, axis_rules(logical, rules=rules,
                                                mesh=mesh))
    return out


def random_batch(cfg: ArchConfig, shape: ShapeConfig, kind: str,
                 seed: int = 0) -> dict:
    """Concrete random batch matching input_specs (smoke tests/examples)."""
    rng = np.random.RandomState(seed)
    abs_batch = input_specs(cfg, shape, kind)
    out = {}
    for k, v in abs_batch.items():
        if v.dtype == jnp.int32:
            hi = cfg.vocab_size if "token" in k or k == "labels" else shape.seq_len
            out[k] = jnp.asarray(
                rng.randint(0, max(2, hi), size=v.shape), jnp.int32)
        else:
            if k == "loss_mask" or k == "clip_mask":
                out[k] = jnp.ones(v.shape, jnp.float32)
            elif k == "time":
                out[k] = jnp.asarray(
                    rng.uniform(50.0, 500.0, size=v.shape), jnp.float32)
            else:
                out[k] = jnp.asarray(
                    rng.randn(*v.shape).astype(np.float32))
    if "positions" in out:
        B, S = shape.global_batch, shape.seq_len
        pos = np.broadcast_to(np.arange(S, dtype=np.int32), (B, S))
        out["positions"] = jnp.asarray(
            np.broadcast_to(pos, (3, B, S)).copy())
    return out
