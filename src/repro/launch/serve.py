"""Serving launcher: ``python -m repro.launch.serve --arch capsim``.

Runs the clip-parallel PredictorEngine over functional-sim requests from
the synthetic suite (the CAPSim deployment), or a KV-cache decode loop for
an LM-zoo arch (prefill + N decode steps on the smoke config).

The capsim path is a thin wrapper over ``SimulationEngine.from_config``:
flags assemble one ``EngineConfig`` (``--engine-config`` takes a JSON
object or a path to one; individual flags override it).  ``--mesh N``
shards inference over an N-device data mesh — on CPU the launcher sets
``XLA_FLAGS=--xla_force_host_platform_device_count=N`` before jax's
first backend init so N host devices exist.
"""
from __future__ import annotations

import argparse
import os
import time


def parse_faults(spec):
    """``kind=rate,kind=rate`` -> dict for ``EngineConfig.faults``
    (validated there against the known chaos kinds)."""
    faults = {}
    for part in filter(None, (spec or "").split(",")):
        kind, _, rate = part.partition("=")
        faults[kind.strip()] = float(rate)
    return faults


def _build_engine_config(args):
    """Resolve --engine-config JSON (inline or @file) + flag overrides
    into one EngineConfig.  Import is deferred: callers must be able to
    set XLA_FLAGS before anything touches jax."""
    from repro.core.engine_config import EngineConfig
    if args.engine_config:
        text = args.engine_config
        if not text.lstrip().startswith("{"):
            with open(text) as fh:
                text = fh.read()
        config = EngineConfig.from_json(text)
    else:
        config = EngineConfig()
    overrides = dict(
        interval_size=args.interval_size, warmup=0, max_checkpoints=1,
        l_min=100, batch_size=args.batch_size, with_oracle=False,
        rt_cache=not args.no_rt_cache, precision=args.precision,
        multicore=args.multicore,
        fused_serving=args.fused_serving)
    if args.rt_store_dir:
        overrides["rt_store_dir"] = args.rt_store_dir
    if args.mesh:
        overrides["mesh_shape"] = (args.mesh,)
    if args.faults:
        overrides["faults"] = parse_faults(args.faults)
        overrides["fault_seed"] = args.fault_seed
    if args.subsample is not None:
        from repro.core.engine_config import SamplingConfig
        overrides["sampling"] = SamplingConfig(
            fraction=args.subsample, strata=args.strata,
            seed=args.sample_seed,
            min_clips_per_stratum=args.min_clips_per_stratum,
            bootstrap_resamples=args.bootstrap_resamples)
    if args.trace_out or args.flight_dir:
        from repro.core.engine_config import ObservabilityConfig
        overrides["observability"] = ObservabilityConfig(
            trace=bool(args.trace_out), flight_dir=args.flight_dir)
    return config.replace(**overrides)


def _start_metrics(args):
    """Start the /metrics exporter when --metrics-port is given.
    Returns the server (or None); the caller shuts it down."""
    if args.metrics_port is None:
        return None
    from repro.obs.exporter import serve_metrics
    server = serve_metrics(port=args.metrics_port)
    print(f"metrics: http://{server.server_address[0]}:"
          f"{server.server_address[1]}/metrics")
    return server


def _dump_trace(args, obs) -> None:
    """Write the Chrome/Perfetto trace when --trace-out is given."""
    if args.trace_out and obs.tracer.enabled:
        obs.tracer.dump(args.trace_out)
        print(f"trace: {args.trace_out} "
              f"({len(obs.tracer.spans())} spans; open at ui.perfetto.dev)")


def serve_capsim(args) -> None:
    import jax

    from repro.configs import get_config
    from repro.core import predictor
    from repro.core import standardize as std_mod
    from repro.core.engine import SimulationEngine
    from repro.isa import multicore, progen

    config = _build_engine_config(args)
    vocab = std_mod.build_vocab()
    cfg = get_config("capsim").replace(dtype="float32")
    params = predictor.init_params(cfg, jax.random.PRNGKey(0))
    engine = SimulationEngine.from_config(params, cfg, vocab, config)
    metrics_server = _start_metrics(args)

    if args.multicore > 0:
        # multicore serving: (benchmark, core) shards through the same
        # pooled predictor; per-core results demuxed, per-benchmark summed
        mbenches = multicore.all_multicore_benchmarks(args.multicore)
        t0 = time.time()
        mresults = engine.run_multicore(mbenches)
        wall = time.time() - t0
        stats = engine.last_stats
        for mr in mresults:
            line = (f"  {mr.name:16s} x{mr.n_cores} cores "
                    f"clips={mr.n_clips:5d} "
                    f"predicted={mr.predicted_cycles:12.0f} core-cycles")
            if mr.cycles_ci is not None:
                lo, hi = mr.cycles_ci
                line += f"  [{lo:.0f}, {hi:.0f}] 95% CI"
            print(line)
            for cr in mr.cores:
                print(f"    {cr.name:16s} clips={cr.n_clips:5d} "
                      f"predicted={cr.predicted_cycles:12.0f} cycles")
        served = (f"{len(mresults)} benchmarks x {args.multicore} cores "
                  f"({sum(mr.n_cores for mr in mresults)} core shards)")
    else:
        names = list(progen.TABLE_II)[: args.n_benchmarks]
        engine.submit_names(names)
        t0 = time.time()
        results = engine.run()
        wall = time.time() - t0
        stats = engine.last_stats
        for r in results:
            line = (f"  {r.name:16s} clips={r.n_clips:5d} "
                    f"predicted={r.predicted_cycles:12.0f} cycles")
            if r.cycles_ci is not None:
                lo, hi = r.cycles_ci
                line += (f"  [{lo:.0f}, {hi:.0f}] 95% CI "
                         f"({r.clips_predicted} predicted + "
                         f"{r.clips_extrapolated} extrapolated)")
            print(line)
        served = f"{len(results)} benchmarks"
    print(f"served {served} "
          f"({stats.n_clips} clips, {stats.n_batches} device batches, "
          f"{stats.n_pad} pad rows) in {wall:.1f}s "
          f"= {stats.n_clips / max(wall, 1e-9):.0f} clips/s")
    rt = engine.last_rt_stats
    if rt is not None:
        print(f"rt-cache: {rt.n_rows_encoded} static rows encoded in "
              f"{rt.build_seconds:.2f}s served {rt.n_rows_served} dynamic "
              f"rows ({rt.rows_avoided} instruction-encoder rows avoided)")
        if rt.n_rows_loaded:
            print(f"rt-store: {rt.n_rows_loaded} rows loaded in "
                  f"{rt.store_load_seconds:.2f}s (cold encode skipped)")
    _dump_trace(args, engine.obs)
    if metrics_server is not None:
        metrics_server.shutdown()


def serve_service(args) -> None:
    """Run the fault-tolerant ``SimulationService`` front-end over the
    synthetic suite: requests carry per-request deadlines, admission can
    shed (typed ``overloaded``), and --faults exercises the degradation
    ladder on live traffic."""
    import jax

    from repro.configs import get_config
    from repro.core import predictor
    from repro.core import standardize as std_mod
    from repro.data.dataset import BuildConfig, build_dataset
    from repro.isa import progen
    from repro.serving.engine import Request
    from repro.serving.service import ServiceSLA, SimulationService

    config = _build_engine_config(args)
    # the service owns precision/fusion (the degradation ladder) — the
    # base config only contributes the structural axes
    config = config.replace(precision=None, fused_serving=False)
    vocab = std_mod.build_vocab()
    cfg = get_config("capsim").replace(dtype="float32")
    params = predictor.init_params(cfg, jax.random.PRNGKey(0))

    names = list(progen.TABLE_II)[: args.n_benchmarks]
    bcfg = BuildConfig(interval_size=config.interval_size, warmup=0,
                       max_checkpoints=1, l_min=100,
                       l_clip=config.l_clip, l_token=config.l_token)
    ds = build_dataset(names, bcfg, vocab)
    sla = ServiceSLA(default_deadline_s=args.deadline_s,
                     watchdog_s=args.watchdog_s)

    metrics_server = _start_metrics(args)
    t0 = time.time()
    with SimulationService(params, cfg, config, sla=sla) as svc:
        tickets = []
        per_req = max(1, len(ds) // max(args.n_requests, 1))
        for i in range(args.n_requests):
            lo = (i * per_req) % len(ds)
            hi = min(lo + per_req, len(ds))
            tickets.append(svc.submit(Request(
                i, ds.clip_tokens[lo:hi], ds.context_tokens[lo:hi],
                ds.clip_mask[lo:hi])))
        results = [t.result(timeout=600) for t in tickets]
        stats = svc.stats()
    wall = time.time() - t0

    for r in results:
        extra = f" [{r.error}]" if r.error else ""
        print(f"  req {r.request_id:3d} {r.status:17s} "
              f"tier={r.tier or '-':10s} clips={r.n_clips:5d} "
              f"latency={r.latency_seconds:6.2f}s{extra}")
    n_clips = sum(r.n_clips for r in results if r.ok)
    print(f"service: {stats['statuses']} tier={stats['current_tier']} "
          f"in {wall:.1f}s = {n_clips / max(wall, 1e-9):.0f} clips/s")
    if "faults_fired" in stats:
        print(f"faults fired: {stats['faults_fired']}")
    for name, ts in stats["tiers"].items():
        hits = {k: v for k, v in ts.items() if v and k != "name"}
        if hits:
            print(f"  tier {name}: {hits}")
    _dump_trace(args, svc.obs)
    if svc.obs.flight is not None and svc.obs.flight.postmortems:
        print(f"postmortems: {len(svc.obs.flight.postmortems)} written "
              f"to {args.flight_dir}")
    if metrics_server is not None:
        metrics_server.shutdown()


def serve_lm(args) -> None:
    import jax
    import jax.numpy as jnp

    from repro.configs import ShapeConfig, get_smoke_config
    from repro.distributed.sharding import (
        LOGICAL_RULES_DECODE, use_mesh_and_rules)
    from repro.launch.mesh import make_test_mesh
    from repro.launch.specs import random_batch
    from repro.models import transformer as tfm

    cfg = get_smoke_config(args.arch)
    B, S = 2, 64
    mesh = make_test_mesh()
    with use_mesh_and_rules(mesh, LOGICAL_RULES_DECODE):
        params = tfm.init_params(cfg, jax.random.PRNGKey(0))
        pre = random_batch(cfg, ShapeConfig("p", S // 2, B, "prefill"),
                           "prefill")
        logits, caches = jax.jit(
            lambda p, b: tfm.prefill_step(p, b, cfg))(params, pre)
        full = tfm.init_cache(cfg, B, S)
        # place prefill caches into the fixed-size decode cache
        def put(dst, src):
            if src.ndim >= 3 and src.shape[2] == S // 2:
                return jax.lax.dynamic_update_slice_in_dim(
                    dst, src.astype(dst.dtype), 0, axis=2)
            return src.astype(dst.dtype)
        caches = jax.tree_util.tree_map(put, full, caches)
        step = jax.jit(lambda p, b, c, pos: tfm.decode_step(p, b, cfg, c,
                                                            pos))
        tok = jnp.argmax(logits[:, -1:], -1)
        if cfg.num_codebooks > 1:
            tok = jnp.broadcast_to(tok[..., None],
                                   (B, 1, cfg.num_codebooks))
        t0 = time.time()
        for i in range(args.decode_steps):
            logits, caches = step(params, {"tokens": tok}, caches,
                                  jnp.int32(S // 2 + i))
            tok = jnp.argmax(logits[:, -1:], -1)
            if cfg.num_codebooks > 1:
                tok = jnp.broadcast_to(tok[..., None],
                                       (B, 1, cfg.num_codebooks))
        jax.block_until_ready(tok)
        print(f"{args.arch}: prefill {S//2} tokens + "
              f"{args.decode_steps} decode steps in {time.time()-t0:.1f}s")


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="capsim")
    ap.add_argument("--batch-size", type=int, default=256)
    ap.add_argument("--interval-size", type=int, default=10_000)
    ap.add_argument("--n-benchmarks", type=int, default=4)
    ap.add_argument("--multicore", type=int, default=0, metavar="N_CORES",
                    help="serve the multi-threaded benchmark variants at "
                         "N cores per benchmark (0 = single-core suite)")
    ap.add_argument("--decode-steps", type=int, default=8)
    ap.add_argument("--no-rt-cache", action="store_true",
                    help="monolithic predict path (re-encode every "
                         "dynamic instruction row; the bitwise reference)")
    ap.add_argument("--precision", default=None,
                    choices=("fp32", "bf16", "int8"),
                    help="inference numerics; default keeps the config "
                         "dtype (fp32 here).  bf16 casts fp32 params at "
                         "dispatch; int8 per-channel fake-quantizes the "
                         "weights once at engine build (fp32 compute), "
                         "both ≤1%% rel-err gated")
    ap.add_argument("--fused-serving", action="store_true",
                    help="dedup-fused block-encoder serving step "
                         "(weighted attention over each clip's unique "
                         "context tokens + precomputed cross K/V; "
                         "tolerance-gated ≤1e-3 vs unfused)")
    ap.add_argument("--rt-store-dir", default=None, metavar="DIR",
                    help="persistent content-addressed RT-cache store: "
                         "load-or-rebuild the (row -> RT vector) table "
                         "keyed on (params, config, vocab), persisted "
                         "after each run — a restart never repays the "
                         "cold encode")
    ap.add_argument("--mesh", type=int, default=0, metavar="N",
                    help="shard inference over an N-device data mesh "
                         "(predict dispatch + RT-cache encode passes; "
                         "bitwise-equal to unsharded).  0 = no mesh")
    ap.add_argument("--subsample", type=float, default=None,
                    metavar="FRACTION",
                    help="analytical-ML fusion: predict only a "
                         "stratified FRACTION of each benchmark's clips "
                         "and extrapolate the rest from analytical "
                         "features with a bootstrap CI (default: full "
                         "prediction)")
    ap.add_argument("--strata", type=int, default=4,
                    help="--subsample: quantile strata over the "
                         "analytical cycle estimate")
    ap.add_argument("--min-clips-per-stratum", type=int, default=2,
                    help="--subsample: floor of sampled clips per "
                         "non-empty stratum")
    ap.add_argument("--bootstrap-resamples", type=int, default=200,
                    help="--subsample: bootstrap resamples behind the "
                         "95%% CI (0 disables)")
    ap.add_argument("--sample-seed", type=int, default=0,
                    help="--subsample: sampling + bootstrap seed")
    ap.add_argument("--engine-config", default=None, metavar="JSON",
                    help="EngineConfig as a JSON object or a path to a "
                         "JSON file; individual flags override its "
                         "fields")
    ap.add_argument("--service", action="store_true",
                    help="serve through the fault-tolerant "
                         "SimulationService (bounded queue, deadlines, "
                         "watchdog, graceful degradation) instead of the "
                         "batch SimulationEngine")
    ap.add_argument("--n-requests", type=int, default=8,
                    help="--service: number of requests to split the "
                         "suite's clips across")
    ap.add_argument("--deadline-s", type=float, default=120.0,
                    help="--service: per-request deadline (SLA)")
    ap.add_argument("--watchdog-s", type=float, default=60.0,
                    help="--service: abort any single flush after this "
                         "many seconds and retry a tier down")
    ap.add_argument("--metrics-port", type=int, default=None,
                    metavar="PORT",
                    help="serve Prometheus text at "
                         "http://127.0.0.1:PORT/metrics for the run's "
                         "duration (0 = ephemeral port, printed)")
    ap.add_argument("--trace-out", default=None, metavar="PATH",
                    help="enable span tracing and write a Chrome/"
                         "Perfetto trace-event JSON at exit (open at "
                         "ui.perfetto.dev)")
    ap.add_argument("--flight-dir", default=None, metavar="DIR",
                    help="enable the degradation flight recorder: every "
                         "service demotion dumps a postmortem JSON "
                         "(events + recent spans + metrics) into DIR")
    ap.add_argument("--faults", default=None, metavar="KIND=RATE,...",
                    help="chaos injection on the real serving path, e.g. "
                         "'nan_output=0.1,device_error=0.05' (kinds: "
                         "device_error nan_output slow_flush "
                         "corrupt_rt_read crash_persist)")
    ap.add_argument("--fault-seed", type=int, default=0)
    args = ap.parse_args()
    if args.mesh:
        # must land before jax's first backend init: jax locks the host
        # device count the moment a backend spins up
        flags = os.environ.get("XLA_FLAGS", "")
        if "xla_force_host_platform_device_count" not in flags:
            os.environ["XLA_FLAGS"] = (
                f"{flags} --xla_force_host_platform_device_count="
                f"{args.mesh}").strip()
    if args.arch == "capsim" and args.service:
        serve_service(args)
    elif args.arch == "capsim":
        serve_capsim(args)
    else:
        serve_lm(args)


if __name__ == "__main__":
    main()
