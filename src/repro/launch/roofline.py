"""Roofline-term extraction from compiled dry-run artifacts.

Terms (per device, TPU v5e targets):
    compute term    = HLO_FLOPs / peak_FLOPs      (197 TFLOP/s bf16)
    memory term     = HLO_bytes / HBM_bw          (819 GB/s)
    collective term = wire_bytes / link_bw        (~50 GB/s ICI)

``cost_analysis()`` gives per-device FLOPs / bytes.  Collective bytes are NOT
in cost_analysis: we parse the compiled HLO text and sum the tensor sizes of
every all-reduce / all-gather / reduce-scatter / all-to-all /
collective-permute, modeling ring-transfer wire bytes per op from the replica
group size g:
    all-reduce      2 * bytes * (g-1)/g
    all-gather      out_bytes * (g-1)/g
    reduce-scatter  out_bytes * (g-1)          (out = in/g)
    all-to-all      bytes * (g-1)/g
    collective-permute  bytes                  (single hop)
"""
from __future__ import annotations

import math
import re
from typing import Dict, Tuple

# v5e-like hardware constants (per chip)
PEAK_FLOPS_BF16 = 197e12
HBM_BW = 819e9
ICI_BW = 50e9

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "c128": 16, "s4": 1, "u4": 1, "f8e4m3fn": 1, "f8e5m2": 1,
}

_COLL_OPS = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
             "collective-permute")

# e.g. "bf16[256,4096,128]{2,1,0}" -> (dtype, numel)
_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
# replica_groups={{0,1},{2,3}} or replica_groups=[32,16]<=[512]
_GROUPS_IOTA_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")
_GROUPS_LIST_RE = re.compile(r"replica_groups=\{\{([^}]*)\}")


def _result_bytes(result_str: str) -> int:
    total = 0
    for dtype, dims in _SHAPE_RE.findall(result_str):
        if dtype not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dtype]
    return total


def _group_size(line: str) -> int:
    m = _GROUPS_IOTA_RE.search(line)
    if m:
        return int(m.group(2))       # [num_groups, group_size]<=[total]
    m = _GROUPS_LIST_RE.search(line)
    if m:
        return len([x for x in m.group(1).split(",") if x.strip()])
    return 2                          # unknown: conservative


def parse_collectives(hlo_text: str) -> Dict[str, Dict[str, float]]:
    """Returns {op_type: {count, bytes, wire_bytes}} per-device totals."""
    out: Dict[str, Dict[str, float]] = {}
    for line in hlo_text.splitlines():
        stripped = line.strip()
        if not stripped or "=" not in stripped:
            continue
        # match ' = <result-type> <opname>(' ; skip -done ops (size counted
        # at -start) but count plain and -start forms.
        m = re.search(r"=\s+(\(?[\w\[\],{}\s]*?\)?)\s+([\w-]+)\(", stripped)
        if not m:
            continue
        result_str, opname = m.group(1), m.group(2)
        base = None
        for op in _COLL_OPS:
            if opname == op or opname == op + "-start":
                base = op
                break
        if base is None:
            continue
        nbytes = _result_bytes(result_str)
        g = _group_size(stripped)
        if base == "all-reduce":
            wire = 2.0 * nbytes * (g - 1) / g
        elif base == "all-gather":
            wire = nbytes * (g - 1) / g
        elif base == "reduce-scatter":
            wire = float(nbytes) * (g - 1)
        elif base == "all-to-all":
            wire = nbytes * (g - 1) / g
        else:  # collective-permute
            wire = float(nbytes)
        d = out.setdefault(base, {"count": 0, "bytes": 0.0,
                                  "wire_bytes": 0.0})
        d["count"] += 1
        d["bytes"] += nbytes
        d["wire_bytes"] += wire
    return out


# NB: ops inside a scan/while body execute once per iteration; the HLO text
# lists them once.  We scale by trip count via the enclosing while loop's
# induction bound, which XLA annotates in the loop condition. Robustly
# extracting that is brittle; instead the model code reports its own
# trip counts (num_repeats, microbatches) and we scale here.
def scale_collectives(colls: dict, scale_inner: float,
                      hlo_text: str = "") -> dict:
    """Dry-run HLO keeps scan as while-loops: collectives inside the loop
    body run num_repeats times.  We conservatively scale ALL collectives by
    the layer trip count except those clearly outside (grad all-reduces are
    also per-step, so this is a good first-order model)."""
    out = {}
    for k, v in colls.items():
        out[k] = {kk: vv * (scale_inner if kk != "count" else 1)
                  for kk, vv in v.items()}
    return out


def roofline_terms(flops_per_dev: float, bytes_per_dev: float,
                   wire_bytes_per_dev: float) -> dict:
    t_compute = flops_per_dev / PEAK_FLOPS_BF16
    t_memory = bytes_per_dev / HBM_BW
    t_coll = wire_bytes_per_dev / ICI_BW
    terms = {"compute_s": t_compute, "memory_s": t_memory,
             "collective_s": t_coll}
    dom = max(terms, key=terms.get)
    bound = max(terms.values())
    total = sum(terms.values())
    terms["dominant"] = dom
    terms["roofline_fraction"] = bound / total if total > 0 else 0.0
    return terms


# --------------------------------------------------------------------------- #
# Model FLOPs (the "useful work" yardstick)
# --------------------------------------------------------------------------- #

def param_counts(cfg) -> Tuple[int, int]:
    """(total_params, active_params) from the ParamSpec tree."""
    from repro.models.layers import ParamSpec
    import jax

    if cfg.family == "predictor":
        from repro.core.predictor import model_specs
    else:
        from repro.models.transformer import model_specs
    specs = model_specs(cfg)
    total = 0
    active = 0.0
    k_over_e = (cfg.experts_per_token / cfg.num_experts
                if cfg.num_experts else 1.0)
    flat = jax.tree_util.tree_flatten_with_path(
        specs, is_leaf=lambda x: isinstance(x, ParamSpec))[0]
    for path, spec in flat:
        n = math.prod(spec.shape)
        total += n
        keys = [getattr(p, "key", getattr(p, "name", "")) for p in path]
        is_expert = (cfg.num_experts and "ffn" in keys
                     and len(spec.shape) >= 3
                     and cfg.num_experts in spec.shape)
        active += n * (k_over_e if is_expert else 1.0)
    return total, int(active)


def model_flops(cfg, shape, kind: str) -> float:
    """6*N_active*D for training, 2*N_active*D for inference forward.

    For the CAPSim predictor, D is the number of tokens flowing through
    the two encoders: per clip, L_clip instructions x L_token tokens in
    the instruction encoder plus (M context rows + L_clip vectors) in the
    block encoder.  The embedding table is excluded from N (lookup, not
    matmul)."""
    _, active = param_counts(cfg)
    if cfg.family == "predictor":
        from repro.core.predictor import model_specs as pred_specs
        from repro.models.layers import ParamSpec
        import jax as _jax

        specs = pred_specs(cfg)

        def count(tree):
            return sum(math.prod(s.shape) for s in
                       _jax.tree_util.tree_leaves(
                           tree, is_leaf=lambda x: isinstance(x, ParamSpec))
                       if isinstance(x := s, ParamSpec))

        n_inst = count(specs["inst"])
        n_block = count(specs["block"]) + count(specs["head"])
        B, L_clip = shape.global_batch, shape.seq_len
        tok_inst = B * L_clip * cfg.clip_tokens
        tok_block = B * cfg.context_tokens
        mult = 6.0 if kind == "train" else 2.0
        return mult * (n_inst * tok_inst + n_block * tok_block)
    if kind == "train":
        tokens = shape.global_batch * shape.seq_len
        return 6.0 * active * tokens
    if kind == "prefill":
        tokens = shape.global_batch * shape.seq_len
        return 2.0 * active * tokens
    tokens = shape.global_batch  # one decoded token per sequence
    return 2.0 * active * tokens
