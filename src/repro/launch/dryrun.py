import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (architecture x input shape) on
the production meshes, record memory/cost/collective analyses.

    PYTHONPATH=src python -m repro.launch.dryrun --arch olmo-1b --shape train_4k
    PYTHONPATH=src python -m repro.launch.dryrun --all [--multi-pod]

Per cell this produces results/dryrun/<arch>__<shape>__<mesh>.json with:
  - memory_analysis (bytes per device: args/outputs/temps/code)
  - cost_analysis of the scanned artifact (loop bodies counted ONCE by XLA)
  - per-layer extrapolated FLOPs/bytes/collectives from two small unrolled
    compiles (R=1, R=2), which is what §Roofline consumes
  - the collective schedule summary parsed from the compiled HLO

The 512-device count is forced above, BEFORE any jax import, so
jax.make_mesh can build the (2,16,16) multi-pod mesh on this CPU-only host.
The dry-run never allocates an array: inputs are ShapeDtypeStructs.
"""
import argparse
import json
import time
import traceback
from pathlib import Path

import jax

from repro.configs import ARCH_NAMES, ArchConfig, ShapeConfig, get_config
from repro.distributed.sharding import (
    LOGICAL_RULES_DECODE, LOGICAL_RULES_DECODE_LONG,
    LOGICAL_RULES_PREFILL_SP, LOGICAL_RULES_TRAIN,
    LOGICAL_RULES_TRAIN_FSDP, LOGICAL_RULES_TRAIN_ZERO3,
    use_mesh_and_rules)
from repro.launch import roofline as rf
from repro.launch.mesh import make_production_mesh, mesh_axis_sizes, num_chips
from repro.launch.specs import batch_shardings, input_specs
from repro.models import transformer as tfm
from repro.training.train_loop import (
    TrainConfig, abstract_train_state, make_train_step)

RESULTS_DIR = Path("results/dryrun")


def pick_rules(kind: str, shape: ShapeConfig, mesh, rules_name: str = ""):
    if rules_name == "fsdp":
        return LOGICAL_RULES_TRAIN_FSDP
    if rules_name == "zero3":
        return LOGICAL_RULES_TRAIN_ZERO3
    if rules_name == "sp":
        return LOGICAL_RULES_PREFILL_SP
    if kind != "decode":
        return LOGICAL_RULES_TRAIN
    sizes = mesh_axis_sizes(mesh)
    dp = sizes.get("pod", 1) * sizes.get("data", 1)
    if shape.global_batch % dp != 0:
        return LOGICAL_RULES_DECODE_LONG
    return LOGICAL_RULES_DECODE


def _state_shardings(cfg, tcfg: TrainConfig, mesh, rules):
    psh = tfm.param_shardings(cfg, mesh, rules)
    scalar = jax.sharding.NamedSharding(mesh, jax.sharding.PartitionSpec())
    if tcfg.optimizer == "sgdm":
        opt = {"mu": psh}
    elif tcfg.optimizer == "adamw":
        opt = {"mu": psh, "nu": psh, "count": scalar}
    elif tcfg.optimizer == "adafactor":
        # factored row/col stats are ~1e-4 of param bytes: replicate
        abs_opt = tcfg.make_optimizer().abstract_state(
            tfm.abstract_params(cfg))
        opt = jax.tree_util.tree_map(lambda _: scalar, abs_opt)
    else:
        raise NotImplementedError(tcfg.optimizer)
    sh = {"params": psh, "opt": opt,
          "step": scalar}
    if tcfg.compress_grads:
        sh["err_fb"] = psh
    return sh


def _metric_shardings(mesh):
    scalar = jax.sharding.NamedSharding(mesh, jax.sharding.PartitionSpec())
    return {k: scalar for k in
            ("loss", "grad_norm", "lr", "ce", "lb", "z")}


def lower_cell(cfg: ArchConfig, shape: ShapeConfig, mesh, *,
               tcfg: TrainConfig, rules_name: str = ""):
    """Build (lowered, lower_seconds) for one cell on one mesh."""
    kind = shape.kind
    rules = pick_rules(kind, shape, mesh, rules_name)
    B, S = shape.global_batch, shape.seq_len

    if cfg.family == "predictor":
        from repro.core import predictor as pred
        return pred.lower_cell(cfg, shape, mesh, rules, tcfg)

    with use_mesh_and_rules(mesh, rules):
        batch_abs = input_specs(cfg, shape, kind)
        batch_sh = batch_shardings(batch_abs, mesh, rules)
        t0 = time.time()
        if kind == "train":
            param_abs = tfm.abstract_params(cfg)
            state_abs = abstract_train_state(param_abs, tcfg)
            state_sh = _state_shardings(cfg, tcfg, mesh, rules)
            step = make_train_step(
                lambda p, b: tfm.loss_fn(p, b, cfg), tcfg)
            # donate the train state: new params/opt alias the old buffers
            # (without this the step holds TWO copies of the 400B states)
            lowered = jax.jit(
                step, in_shardings=(state_sh, batch_sh),
                out_shardings=(state_sh, _metric_shardings(mesh)),
                donate_argnums=0,
            ).lower(state_abs, batch_abs)
        elif kind == "prefill":
            param_abs = tfm.abstract_params(cfg)
            param_sh = tfm.param_shardings(cfg, mesh, rules)
            # prefill emits decode-layout caches (seq-sharded)
            cache_sh = tfm.cache_shardings(
                cfg, B, S, mesh, LOGICAL_RULES_DECODE
                if shape.name != "long_500k" else LOGICAL_RULES_DECODE_LONG)
            lowered = jax.jit(
                lambda p, b: tfm.prefill_step(p, b, cfg),
                in_shardings=(param_sh, batch_sh),
                out_shardings=(None, cache_sh),
            ).lower(param_abs, batch_abs)
        else:  # decode
            param_abs = tfm.abstract_params(cfg)
            param_sh = tfm.param_shardings(cfg, mesh, rules)
            cache_abs = tfm.abstract_cache(cfg, B, S)
            cache_sh = tfm.cache_shardings(cfg, B, S, mesh, rules)
            scalar_abs = jax.ShapeDtypeStruct((), jax.numpy.int32)
            lowered = jax.jit(
                lambda p, b, c, pos: tfm.decode_step(p, b, cfg, c, pos),
                in_shardings=(param_sh, batch_sh, cache_sh, None),
                out_shardings=(None, cache_sh),
            ).lower(param_abs, batch_abs, cache_abs, scalar_abs)
        return lowered, time.time() - t0


def analyze_compiled(compiled) -> dict:
    mem = compiled.memory_analysis()
    cost = compiled.cost_analysis()
    if isinstance(cost, (list, tuple)):
        cost = cost[0] if cost else {}
    hlo = compiled.as_text()
    colls = rf.parse_collectives(hlo)
    return {
        "memory": {
            "argument_bytes": getattr(mem, "argument_size_in_bytes", None),
            "output_bytes": getattr(mem, "output_size_in_bytes", None),
            "temp_bytes": getattr(mem, "temp_size_in_bytes", None),
            "code_bytes": getattr(mem, "generated_code_size_in_bytes", None),
            "alias_bytes": getattr(mem, "alias_size_in_bytes", None),
        },
        "cost": {
            "flops": cost.get("flops"),
            "bytes_accessed": cost.get("bytes accessed"),
        },
        "collectives": colls,
        "hlo_bytes": len(hlo),
    }


def run_cell(arch: str, shape_name: str, multi_pod: bool,
             optimizer: str = "sgdm", extrapolate: bool = True,
             out_dir: Path = RESULTS_DIR, overrides: dict = None,
             rules_name: str = "", microbatches: int = 1,
             accum_dtype: str = "float32", opt_state_dtype: str = "float32",
             tag: str = "") -> dict:
    cfg = get_config(arch)
    if overrides:
        cfg = cfg.replace(**overrides)
    mesh_name0 = "multipod_2x16x16" if multi_pod else "pod_16x16"
    if shape_name in cfg.skipped_shapes:
        rec = {"arch": arch, "shape": shape_name, "mesh": mesh_name0,
               "skipped": cfg.skip_reason}
        out_dir.mkdir(parents=True, exist_ok=True)
        (out_dir / f"{arch}__{shape_name}__{mesh_name0}.json").write_text(
            json.dumps(rec, indent=1))
        return rec
    shape = cfg.shapes()[shape_name]
    mesh = make_production_mesh(multi_pod=multi_pod)
    mesh_name = "multipod_2x16x16" if multi_pod else "pod_16x16"
    tcfg = TrainConfig(optimizer=optimizer, microbatches=microbatches,
                       accum_dtype=accum_dtype,
                       opt_state_dtype=opt_state_dtype)

    record = {"arch": arch, "shape": shape_name, "mesh": mesh_name,
              "kind": shape.kind, "chips": num_chips(mesh),
              "optimizer": optimizer}
    if rules_name:
        record["rules"] = rules_name
    if microbatches > 1:
        record["microbatches"] = microbatches
    if overrides:
        record["overrides"] = {k: str(v) for k, v in overrides.items()}

    with mesh:
        lowered, t_lower = lower_cell(cfg, shape, mesh, tcfg=tcfg,
                                      rules_name=rules_name)
        t0 = time.time()
        compiled = lowered.compile()
        t_compile = time.time() - t0
        record["lower_s"] = round(t_lower, 2)
        record["compile_s"] = round(t_compile, 2)
        record["scanned"] = analyze_compiled(compiled)
        print(f"[{arch} x {shape_name} x {mesh_name}] compiled "
              f"in {t_compile:.1f}s; memory:")
        print(" ", record["scanned"]["memory"])

        if extrapolate and cfg.family != "predictor":
            # two unrolled mini-depth compiles -> per-layer costs
            per_layer = {}
            for r in (1, 2):
                mini = cfg.replace(num_layers=r * cfg.pattern_len,
                                   scan_layers=False)
                lo, _ = lower_cell(mini, shape, mesh, tcfg=tcfg,
                                   rules_name=rules_name)
                per_layer[r] = analyze_compiled(lo.compile())
            record["unrolled_r1"] = per_layer[1]
            record["unrolled_r2"] = per_layer[2]
            record["extrapolated"] = extrapolate_costs(
                per_layer[1], per_layer[2], cfg.num_repeats)

    out_dir.mkdir(parents=True, exist_ok=True)
    suffix = f"__{tag}" if tag else ""
    fname = out_dir / f"{arch}__{shape_name}__{mesh_name}{suffix}.json"
    fname.write_text(json.dumps(record, indent=1))
    return record


def extrapolate_costs(r1: dict, r2: dict, repeats: int) -> dict:
    """cost(R) = outside + R*body, from measurements at R=1 and R=2."""
    def lin(a, b):
        if a is None or b is None:
            return None
        body = b - a
        outside = a - body
        return outside + repeats * body

    out = {"flops": lin(r1["cost"]["flops"], r2["cost"]["flops"]),
           "bytes_accessed": lin(r1["cost"]["bytes_accessed"],
                                 r2["cost"]["bytes_accessed"])}
    colls = {}
    keys = set(r1["collectives"]) | set(r2["collectives"])
    for k in keys:
        c1 = r1["collectives"].get(k, {"count": 0, "bytes": 0,
                                       "wire_bytes": 0})
        c2 = r2["collectives"].get(k, {"count": 0, "bytes": 0,
                                       "wire_bytes": 0})
        colls[k] = {kk: lin(float(c1[kk]), float(c2[kk]))
                    for kk in ("count", "bytes", "wire_bytes")}
    out["collectives"] = colls
    out["wire_bytes_total"] = sum(v["wire_bytes"] for v in colls.values())
    return out


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--both-meshes", action="store_true")
    ap.add_argument("--optimizer", default="sgdm")
    ap.add_argument("--no-extrapolate", action="store_true")
    ap.add_argument("--skip-existing", action="store_true")
    ap.add_argument("--out", default=str(RESULTS_DIR))
    ap.add_argument("--rules", default="", help="'' (default) | fsdp")
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--accum-dtype", default="float32")
    ap.add_argument("--opt-state-dtype", default="float32")
    ap.add_argument("--tag", default="", help="suffix for the result file")
    ap.add_argument("--override", action="append", default=[],
                    help="cfg field override, e.g. capacity_factor=1.0")
    args = ap.parse_args()
    overrides = {}
    for ov in args.override:
        k, v = ov.split("=", 1)
        try:
            v = eval(v)  # noqa: S307 — CLI-local literals
        except Exception:
            pass
        overrides[k] = v

    out_dir = Path(args.out)
    meshes = [False, True] if args.both_meshes else [args.multi_pod]
    if args.all:
        cells = []
        for name in ARCH_NAMES:
            cfg = get_config(name)
            for sname in cfg.shape_names:
                cells.append((name, sname))
            for sname in cfg.skipped_shapes:
                cells.append((name, sname))
    else:
        assert args.arch and args.shape, "--arch/--shape or --all"
        cells = [(args.arch, args.shape)]

    failures = []
    for arch, sname in cells:
        for mp in meshes:
            mesh_name = "multipod_2x16x16" if mp else "pod_16x16"
            suffix = f"__{args.tag}" if args.tag else ""
            fname = out_dir / f"{arch}__{sname}__{mesh_name}{suffix}.json"
            if args.skip_existing and fname.exists():
                print(f"skip existing {fname.name}")
                continue
            try:
                run_cell(arch, sname, mp, optimizer=args.optimizer,
                         extrapolate=not args.no_extrapolate,
                         out_dir=out_dir, rules_name=args.rules,
                         microbatches=args.microbatches, tag=args.tag,
                         accum_dtype=args.accum_dtype,
                         opt_state_dtype=args.opt_state_dtype,
                         overrides=overrides or None)
            except Exception as e:  # noqa: BLE001 — record and continue
                print(f"FAILED {arch} x {sname} x {mesh_name}: {e}")
                traceback.print_exc()
                failures.append((arch, sname, mesh_name, str(e)))
    if failures:
        print("\n== FAILURES ==")
        for f in failures:
            print(" ", f)
        raise SystemExit(1)
    print("\nall requested dry-run cells compiled OK")


if __name__ == "__main__":
    main()
