"""Roofline report: results/dryrun/*.json -> per-cell terms + markdown.

    PYTHONPATH=src python -m repro.launch.roofline_report [--mesh pod_16x16]

Per (arch x shape) cell on the single-pod mesh:
    compute_s    = HLO_FLOPs_per_device / 197 TFLOP/s
    memory_s     = HLO_bytes_per_device / 819 GB/s
    collective_s = wire_bytes_per_device / 50 GB/s
    dominant     = argmax
    model_ratio  = MODEL_FLOPS (6*N_active*D or 2*N_active*D) / HLO_FLOPs
    mfu_bound    = ideal model-FLOPs time / dominant term  (what MFU the
                   compiled program could reach if the dominant bottleneck
                   perfectly overlapped the others)

FLOPs/bytes come from the per-layer extrapolation (outside + R*body) when
present — cost_analysis counts a scanned loop body once — falling back to
the scanned artifact's numbers otherwise.
"""
from __future__ import annotations

import argparse
import json
from pathlib import Path
from typing import Optional

from repro.configs import get_config
from repro.launch import roofline as rf

RESULTS_DIR = Path("results/dryrun")


def _mem_traffic(memory: dict) -> float:
    """HBM traffic estimate from the POST-FUSION buffer assignment: every
    argument is read once, every output written once, every temp buffer
    written + read (>=1 each).  Far closer to real traffic than XLA's
    cost_analysis 'bytes accessed', which assumes zero fusion."""
    a = memory.get("argument_bytes") or 0
    o = memory.get("output_bytes") or 0
    t = memory.get("temp_bytes") or 0
    return float(a + o + 2 * t)


def cell_terms(rec: dict) -> Optional[dict]:
    if "skipped" in rec:
        return None
    chips = rec["chips"]
    src = rec.get("extrapolated")
    scanned = rec["scanned"]
    layers_scale = None
    if src and src.get("flops"):
        flops = src["flops"]
        bts_unfused = src["bytes_accessed"]
        wire = src["wire_bytes_total"]
        # per-layer memory-traffic extrapolation from the R=1/R=2 compiles
        m1 = _mem_traffic(rec["unrolled_r1"]["memory"])
        m2 = _mem_traffic(rec["unrolled_r2"]["memory"])
        body = m2 - m1
        cfg = get_config(rec["arch"])
        traffic = max(m1 - body, 0.0) + cfg.num_repeats * max(body, 0.0)
        traffic = max(traffic, _mem_traffic(scanned["memory"]))
    else:
        cfg = get_config(rec["arch"])
        if cfg.family == "predictor":
            # the two 4-layer scans count once in cost_analysis; their
            # saved-for-backward buffers are already stacked (4, ...) in
            # the buffer assignment, so traffic is NOT layer-scaled
            layers_scale = 4
            traffic = _mem_traffic(scanned["memory"])
        else:
            layers_scale = cfg.num_repeats
            mem = dict(scanned["memory"])
            traffic = ((mem.get("argument_bytes") or 0)
                       + (mem.get("output_bytes") or 0)
                       + 2 * (mem.get("temp_bytes") or 0) * layers_scale)
        flops = (scanned["cost"]["flops"] or 0.0) * layers_scale
        bts_unfused = (scanned["cost"]["bytes_accessed"] or 0.0) \
            * layers_scale
        wire = sum(v["wire_bytes"]
                   for v in scanned["collectives"].values()) * layers_scale

    terms = rf.roofline_terms(flops, traffic, wire)
    terms["memory_unfused_s"] = bts_unfused / rf.HBM_BW if bts_unfused \
        else 0.0

    cfg = get_config(rec["arch"])
    shape = cfg.shapes().get(rec["shape"])
    model_fl = rf.model_flops(cfg, shape, rec["kind"]) if shape else 0.0
    model_fl_dev = model_fl / chips
    terms["model_flops_ratio"] = (model_fl_dev / flops) if flops else 0.0
    ideal_s = model_fl_dev / rf.PEAK_FLOPS_BF16
    bound = max(terms["compute_s"], terms["memory_s"],
                terms["collective_s"])
    terms["mfu_bound"] = ideal_s / bound if bound else 0.0
    terms["flops"] = flops
    terms["bytes"] = traffic
    terms["wire_bytes"] = wire
    terms["approx"] = layers_scale is not None
    return terms


def load_cells(mesh: str, tag: str = "") -> dict:
    cells = {}
    suffix = f"__{mesh}__{tag}.json" if tag else f"__{mesh}.json"
    for f in sorted(RESULTS_DIR.glob(f"*{suffix}")):
        rec = json.loads(f.read_text())
        cells[(rec["arch"], rec["shape"])] = rec
    return cells


def fmt_s(x: float) -> str:
    if x >= 1.0:
        return f"{x:.2f}s"
    if x >= 1e-3:
        return f"{x*1e3:.1f}ms"
    return f"{x*1e6:.0f}us"


def report(mesh: str, markdown: bool = True, tag: str = "") -> str:
    cells = load_cells(mesh, tag)
    lines = []
    if markdown:
        lines.append(
            "| arch | shape | compute | memory | collective | dominant "
            "| model/HLO FLOPs | MFU bound |")
        lines.append("|---|---|---|---|---|---|---|---|")
    for (arch, shape), rec in sorted(cells.items()):
        if "skipped" in rec:
            lines.append(f"| {arch} | {shape} | — | — | — | skipped: "
                         f"{rec['skipped'][:48]} | — | — |")
            continue
        t = cell_terms(rec)
        star = "*" if t["approx"] else ""
        lines.append(
            f"| {arch} | {shape} | {fmt_s(t['compute_s'])} | "
            f"{fmt_s(t['memory_s'])} | {fmt_s(t['collective_s'])} | "
            f"{t['dominant'].replace('_s','')}{star} | "
            f"{t['model_flops_ratio']:.2f} | {t['mfu_bound']*100:.0f}% |")
    return "\n".join(lines)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--mesh", default="pod_16x16")
    ap.add_argument("--tag", default="", help="variant suffix, e.g. fsdp")
    ap.add_argument("--json", action="store_true")
    args = ap.parse_args()
    if args.json:
        cells = load_cells(args.mesh, args.tag)
        out = {f"{a}__{s}": cell_terms(r)
               for (a, s), r in cells.items() if "skipped" not in r}
        print(json.dumps(out, indent=1))
    else:
        print(report(args.mesh, tag=args.tag))


if __name__ == "__main__":
    main()
