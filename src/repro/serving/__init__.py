from repro.serving.engine import PredictorEngine, Request, Result  # noqa: F401
