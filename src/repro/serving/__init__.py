from repro.serving.engine import (PredictorEngine, Request,  # noqa: F401
                                  Result, validate_request)
from repro.serving.faults import (FaultInjected,  # noqa: F401
                                  FaultInjector)
from repro.serving.service import (ServiceResult, ServiceSLA,  # noqa: F401
                                   ServiceTicket, SimulationService,
                                   build_ladder)
