"""Fault-tolerant continuous-batching simulation service.

``PredictorEngine`` is a synchronous flush front-end: callers block, a
stuck flush hangs everyone, and a misbehaving fast path (fused / int8 /
RT store) returns whatever it returns.  ``SimulationService`` is the
production front: the contract is that **every admitted request ends in
a typed result** — a success, a degraded-tier success, or a clean,
immediate rejection — never a hang, and never an ungated wrong answer.

Structure:

  admission     a bounded queue with SLA-aware shedding: a full queue or
                a predicted wait beyond the request's deadline resolves
                the ticket *immediately* with ``overloaded`` instead of
                blocking the batch (``deadline_exceeded`` covers
                requests that expire while queued).
  continuous    one worker drains the queue into device batches with no
  batching      drain barrier between requests: a flush window tops up
                from the queue until ``sla.max_flush_clips`` (many
                requests share one device batch; one request may span
                several), and the backend's async dispatch keeps the
                device busy while the next request is packed.
  watchdog      every flush runs on a watchdog thread bounded by
                ``sla.watchdog_s``; a stuck flush (the ``slow_flush``
                chaos fault, a runaway compile, a wedged device) is
                abandoned, its tier's backend rebuilt, and the batch
                retried a tier down.
  degradation   a ``DegradationController`` walks the serving-tier
                ladder fused+int8 -> fused -> RT warm -> monolithic
                (the Concorde shape: cheap path backed by an accurate
                one).  Every flush is NaN/Inf-guarded; periodic spot
                checks re-run a few clips through the trusted monolithic
                reference and demote when the tier's rel-err gate (the
                same tolerances CI enforces) is exceeded.  Demotions
                back off exponentially: re-promotion needs a healthy
                streak that doubles with every repeated demotion, so a
                flapping fast path settles low instead of oscillating.
  chaos         ``EngineConfig.faults`` builds a ``FaultInjector``
                honored by the *real* engine stack (dispatch, retire,
                RT-store read, persist) — the tests and
                ``benchmarks/bench_serving.py`` drive exactly the code
                production traffic runs.

Known limit: an abandoned watchdogged flush thread cannot be killed
(JAX compute is not interruptible); it finishes against its *old*
backend object and is dropped.  The RT caches it may still read from
are only ever appended to, and jax arrays are immutable, so a late
straggler can never corrupt a retry's results.
"""
from __future__ import annotations

import dataclasses
import threading
import time
from collections import deque
from typing import Deque, Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.core import analytical
from repro.core import predictor as pred_mod
from repro.core import sampler as sampler_mod
from repro.core.engine import BatchedPredictor
from repro.core.engine_config import EngineConfig
from repro.core.rt_cache import RTCache
from repro.obs import Observability
from repro.serving.engine import Request, validate_request
from repro.serving.faults import FaultInjector

# service-level metric names (see README's Observability section).
# Every family carries an ``instance`` label (svc0, svc1, ...) so two
# services in one process — or an abandoned watchdog thread outliving a
# rebuilt one — never write into each other's series.
TIER_EVENTS_TOTAL = "capsim_service_tier_events_total"
TIER_TRANSITIONS_TOTAL = "capsim_service_tier_transitions_total"
ADMISSION_TOTAL = "capsim_service_admission_total"
QUEUE_DEPTH = "capsim_service_queue_depth"
QUEUED_CLIPS = "capsim_service_queued_clips"
FLUSH_SECONDS = "capsim_service_flush_seconds"
ABANDONED_THREADS = "capsim_service_abandoned_flush_threads"
ABANDONED_THREADS_TOTAL = "capsim_service_abandoned_flush_threads_total"

# typed result statuses: the full closed set a caller can observe
STATUS_OK = "ok"                          # served at the top tier
STATUS_DEGRADED = "degraded"              # served at a demoted tier
STATUS_OVERLOADED = "overloaded"          # shed at admission (clean)
STATUS_DEADLINE = "deadline_exceeded"     # expired before service
STATUS_FAILED = "failed"                  # every tier faulted (typed)
STATUS_CANCELLED = "cancelled"            # service stopped w/o drain
STATUSES = (STATUS_OK, STATUS_DEGRADED, STATUS_OVERLOADED,
            STATUS_DEADLINE, STATUS_FAILED, STATUS_CANCELLED)

# the degradation ladder, fastest first.  Tolerances are the existing
# CI gates for each rung measured against the monolithic fp32 reference:
# fused is ≤1e-3 vs unfused, int8 is width-dependent (~0.6% at the
# paper's d_model=128, gated 1% full scale / 5% quick), RT is bitwise
# (any drift at all means the table is corrupt).
TIER_LADDER = ("fused_int8", "fused", "rt", "monolithic")
DEFAULT_TIER_TOLERANCES = {"fused_int8": 0.05, "fused": 1e-3,
                           "rt": 1e-6, "monolithic": float("inf")}


class FlushTimeout(RuntimeError):
    """A watchdogged flush exceeded ``sla.watchdog_s``."""


@dataclasses.dataclass
class ServiceSLA:
    """The service-level knobs (see README's serving section).

    ``queue_limit``/``default_deadline_s`` drive admission;
    ``watchdog_s`` bounds any single flush; ``max_flush_clips`` caps a
    continuous-batching window; ``check_every``/``check_clips`` set the
    rel-err spot-check cadence and sample; ``promote_after`` is the
    base healthy streak a demoted service needs before re-promoting
    (doubles per repeated demotion up to ``backoff_max``).
    """

    queue_limit: int = 256
    default_deadline_s: float = 30.0
    watchdog_s: float = 10.0
    max_flush_clips: int = 1024
    check_every: int = 8
    check_clips: int = 4
    promote_after: int = 3
    backoff_max: int = 64
    tier_tolerances: Dict[str, float] = dataclasses.field(
        default_factory=lambda: dict(DEFAULT_TIER_TOLERANCES))


@dataclasses.dataclass
class ServiceResult:
    """The one typed terminal state of every submitted request."""

    request_id: int
    status: str                          # one of STATUSES
    total_cycles: Optional[float]        # None unless ok/degraded
    tier: Optional[str]                  # serving tier that produced it
    n_clips: int
    queue_seconds: float = 0.0
    service_seconds: float = 0.0
    error: Optional[str] = None

    @property
    def ok(self) -> bool:
        return self.status in (STATUS_OK, STATUS_DEGRADED)

    @property
    def latency_seconds(self) -> float:
        return self.queue_seconds + self.service_seconds


class ServiceTicket:
    """Future-like handle returned by ``submit``.  ``result()`` blocks
    until the request reaches its typed terminal state."""

    def __init__(self, request_id: int, n_clips: int):
        self.request_id = request_id
        self.n_clips = n_clips
        self._event = threading.Event()
        self._result: Optional[ServiceResult] = None

    def done(self) -> bool:
        return self._event.is_set()

    def result(self, timeout: Optional[float] = None) -> ServiceResult:
        if not self._event.wait(timeout):
            raise TimeoutError(
                f"request {self.request_id} not resolved in {timeout}s")
        assert self._result is not None
        return self._result

    def _resolve(self, result: ServiceResult) -> None:
        self._result = result
        self._event.set()


@dataclasses.dataclass
class _QueuedRequest:
    req: Request
    ticket: ServiceTicket
    arrival: float
    deadline: float                      # absolute time


class TierStats:
    """Live per-tier counters, as a view over the metrics registry
    (``capsim_service_tier_events_total{instance,tier,event}``).  The
    attribute surface of the retired accumulator dataclass is kept:
    ``ts.nan_trips`` etc. read the registry; writers call ``inc``."""

    # event label values == the legacy dataclass field names
    EVENTS = ("flushes", "clips", "demotions", "promotions", "nan_trips",
              "relerr_trips", "fault_trips", "watchdog_trips",
              "persist_failures")

    def __init__(self, name: str, obs: Observability, instance: str):
        self.name = name
        self._obs = obs
        self._instance = instance
        fam = obs.metrics.counter(
            TIER_EVENTS_TOTAL,
            "Per-tier serving events (flushes, clips, guard trips, ...).",
            ("instance", "tier", "event"))
        self._handles = {e: fam.labels(instance=instance, tier=name,
                                       event=e) for e in self.EVENTS}

    def inc(self, event: str, n: int = 1) -> None:
        self._handles[event].inc(n)

    def _val(self, event: str) -> int:
        return int(self._obs.metrics.value(
            TIER_EVENTS_TOTAL, instance=self._instance, tier=self.name,
            event=event))

    def __getattr__(self, item: str) -> int:
        if item in TierStats.EVENTS:
            return self._val(item)
        raise AttributeError(item)

    def as_dict(self) -> Dict[str, object]:
        d: Dict[str, object] = {"name": self.name}
        d.update({e: self._val(e) for e in self.EVENTS})
        return d


@dataclasses.dataclass(frozen=True)
class ServiceSnapshot:
    """One immutable, JSON-stable view of the whole service: admission
    ledger, degradation state, per-tier counters, chaos activity, and
    the abandoned-watchdog-thread ledger.  ``stats()`` is a thin compat
    wrapper returning ``snapshot().to_dict()``; the key set is frozen —
    benches, the flight recorder, and the CI chaos leg all parse it."""

    submitted: int
    statuses: Dict[str, int]
    current_tier: str
    backoff: int
    healthy_streak: int
    queued: int
    queued_clips: int
    clips_per_s_ewma: Optional[float]
    n_flushes: int
    tiers: Dict[str, Dict[str, object]]
    faults_fired: Dict[str, int]
    abandoned_flush_threads: int         # still alive right now
    abandoned_flush_threads_total: int   # ever abandoned (monotone)

    def to_dict(self) -> Dict[str, object]:
        return dataclasses.asdict(self)

    @classmethod
    def from_dict(cls, d: Dict[str, object]) -> "ServiceSnapshot":
        known = {f.name for f in dataclasses.fields(cls)}
        unknown = set(d) - known
        if unknown:
            raise ValueError(f"unknown ServiceSnapshot fields "
                             f"{sorted(unknown)}")
        return cls(**d)  # type: ignore[arg-type]


class DegradationController:
    """Tier pointer + exponential-backoff re-promotion policy.

    Healthy flushes build a streak; once it reaches the current backoff
    the service promotes one tier.  Any guard trip demotes one tier,
    zeroes the streak, and doubles the backoff (capped) — so a tier
    that keeps failing gets retried less and less often.  The backoff
    resets to base only after the service is back at the top tier and
    has stayed healthy for one more full streak.
    """

    def __init__(self, n_tiers: int, sla: ServiceSLA):
        self.n_tiers = n_tiers
        self.sla = sla
        self.idx = 0
        self.healthy_streak = 0
        self.backoff = sla.promote_after
        self._recovered = True

    def on_healthy(self) -> Optional[int]:
        """Record a healthy flush; returns the new tier index when this
        triggers a promotion, else None."""
        self.healthy_streak += 1
        if self.idx > 0 and self.healthy_streak >= self.backoff:
            self.idx -= 1
            self.healthy_streak = 0
            self._recovered = False
            return self.idx
        if (self.idx == 0 and not self._recovered
                and self.healthy_streak >= self.sla.promote_after):
            # fully re-promoted and stable: forgive the backoff
            self.backoff = self.sla.promote_after
            self._recovered = True
        return None

    def on_trip(self) -> Optional[int]:
        """Record a guard trip; returns the new (demoted) tier index,
        or None when already at the ladder floor."""
        self.healthy_streak = 0
        self.backoff = min(self.backoff * 2, self.sla.backoff_max)
        self._recovered = False
        if self.idx + 1 < self.n_tiers:
            self.idx += 1
            return self.idx
        return None


class _Tier:
    """One rung of the ladder: its config, resolved numerics, RT cache
    (possibly shared with a sibling rung) and lazily built backend."""

    def __init__(self, name: str, config: EngineConfig, params, cfg,
                 cache: Optional[RTCache],
                 injector: Optional[FaultInjector],
                 obs: Optional[Observability] = None):
        self.name = name
        self.config = config
        self.params = params
        self.cfg = cfg
        self.cache = cache
        self._injector = injector
        self._obs = obs
        self._backend: Optional[BatchedPredictor] = None

    def backend(self) -> BatchedPredictor:
        if self._backend is None:
            self._backend = BatchedPredictor(
                self.params, self.cfg, config=self.config,
                rt_cache=self.cache, fault_injector=self._injector,
                obs=self._obs)
        return self._backend

    def invalidate_backend(self) -> None:
        """Drop the backend after a mid-flush fault or watchdog abort:
        its buffered/in-flight state is unrecoverable, the (append-only)
        RT cache and jit caches are not and survive."""
        self._backend = None


def build_ladder(config: EngineConfig) -> List[Tuple[str, EngineConfig]]:
    """The degradation ladder as (name, EngineConfig) rungs, fastest
    first, honoring the base config's structural axes (a config without
    an RT cache or context has no fused rungs to degrade through)."""
    ladder: List[Tuple[str, EngineConfig]] = []
    if config.rt_cache and config.use_context:
        ladder.append(("fused_int8", config.replace(
            fused_serving=True, precision="int8")))
        ladder.append(("fused", config.replace(
            fused_serving=True, precision=None)))
    if config.rt_cache:
        ladder.append(("rt", config.replace(
            fused_serving=False, precision=None)))
    ladder.append(("monolithic", config.replace(
        fused_serving=False, precision=None, rt_cache=False,
        rt_store_dir=None)))
    return ladder


class SimulationService:
    """The continuous-batching, fault-tolerant serving front-end.

    Usage::

        sla = ServiceSLA(queue_limit=64, default_deadline_s=5.0)
        with SimulationService(params, cfg, config, sla=sla) as svc:
            ticket = svc.submit(request, deadline_s=2.0)
            result = ticket.result()        # always a typed result

    The service manages precision/fusion itself via the degradation
    ladder — the base config's ``precision``/``fused_serving`` fields
    are overridden per rung; batching, scale, mesh, store and fault
    fields pass through.
    """

    def __init__(self, params, cfg, config: Optional[EngineConfig] = None,
                 *, sla: Optional[ServiceSLA] = None,
                 fault_injector: Optional[FaultInjector] = None,
                 start_tier: int = 0):
        self.config = config or EngineConfig()
        self.sla = sla or ServiceSLA()
        self.obs = Observability.from_config(self.config.observability)
        m = self.obs.metrics
        self.instance = m.next_instance("svc")
        self._injector = fault_injector
        if self._injector is None and self.config.faults:
            # slow_flush must out-sleep the watchdog, or the chaos fault
            # would model a *slow* flush rather than a *stuck* one
            self._injector = FaultInjector.from_config(
                self.config, slow_seconds=self.sla.watchdog_s * 3)

        ladder = build_ladder(self.config)
        self._tiers: List[_Tier] = []
        caches: Dict[Tuple[int, object], Optional[RTCache]] = {}
        int8_params = None
        for name, tcfg in ladder:
            tparams = params
            if tcfg.precision == "int8":
                if int8_params is None:
                    from repro.core import quant
                    int8_params = quant.quantize_dequant_params(params)
                tparams = int8_params
            rcfg = pred_mod.inference_config(cfg, tcfg.precision)
            cache = None
            if tcfg.rt_cache:
                key = (id(tparams), rcfg)
                if key not in caches:
                    from repro.core.standardize import build_vocab
                    caches[key] = RTCache(
                        tparams, rcfg, tcfg.l_token,
                        n_shards=tcfg.n_shards,
                        store_dir=tcfg.rt_store_dir,
                        store_extra=build_vocab().signature(),
                        fault_injector=self._injector,
                        obs=self.obs)
                cache = caches[key]
            self._tiers.append(_Tier(name, tcfg, tparams, rcfg, cache,
                                     self._injector, self.obs))
        # the trusted auditor: monolithic fp32, NO fault injector — spot
        # checks must measure the tier under test, not their own chaos
        mono_cfg = ladder[-1][1]
        self._reference = _Tier("reference", mono_cfg, params,
                                pred_mod.inference_config(cfg, None),
                                None, None, self.obs)

        if not 0 <= start_tier < len(self._tiers):
            raise ValueError(f"start_tier {start_tier} outside the "
                             f"{len(self._tiers)}-rung ladder")
        self._ctrl = DegradationController(len(self._tiers), self.sla)
        self._ctrl.idx = start_tier
        self.tier_stats = [TierStats(t.name, self.obs, self.instance)
                           for t in self._tiers]
        self._status_counts: Dict[str, int] = {s: 0 for s in STATUSES}
        self._n_submitted = 0
        self._n_flushes = 0

        self._fam_transitions = m.counter(
            TIER_TRANSITIONS_TOTAL,
            "Degradation-ladder transitions by edge and reason.",
            ("instance", "from_tier", "to_tier", "reason"))
        self._fam_admission = m.counter(
            ADMISSION_TOTAL, "Admission decisions (admitted vs shed).",
            ("instance", "decision"))
        self._g_queue_depth = m.gauge(
            QUEUE_DEPTH, "Requests waiting in the admission queue.",
            ("instance",)).labels(instance=self.instance)
        self._g_queued_clips = m.gauge(
            QUEUED_CLIPS, "Clips waiting in the admission queue.",
            ("instance",)).labels(instance=self.instance)
        self._h_flush = m.histogram(
            FLUSH_SECONDS, "Watchdogged flush latency by serving tier.",
            ("instance", "tier"))
        self._g_abandoned = m.gauge(
            ABANDONED_THREADS,
            "Abandoned watchdog flush threads still alive.",
            ("instance",)).labels(instance=self.instance)
        self._c_abandoned = m.counter(
            ABANDONED_THREADS_TOTAL,
            "Watchdog flush threads ever abandoned.",
            ("instance",)).labels(instance=self.instance)
        self._abandoned: List[threading.Thread] = []

        self._lock = threading.Lock()
        self._cond = threading.Condition(self._lock)
        self._queue: Deque[_QueuedRequest] = deque()
        self._queued_clips = 0
        self._rate: Optional[float] = None        # EWMA clips/sec
        self._running = False
        self._draining = False
        self._worker: Optional[threading.Thread] = None

    # ------------------------------ lifecycle ------------------------------ #

    def start(self) -> "SimulationService":
        with self._lock:
            if self._running:
                return self
            self._running = True
            self._draining = False
        self._worker = threading.Thread(target=self._serve_loop,
                                        name="sim-service", daemon=True)
        self._worker.start()
        return self

    def stop(self, drain: bool = True,
             timeout: Optional[float] = None) -> None:
        """Stop the worker.  ``drain=True`` serves everything already
        queued first; ``drain=False`` resolves queued requests with the
        typed ``cancelled`` status immediately."""
        with self._cond:
            if not self._running:
                return
            self._running = False
            self._draining = drain
            if not drain:
                now = time.time()
                while self._queue:
                    qr = self._queue.popleft()
                    self._queued_clips -= qr.ticket.n_clips
                    self._finish(qr, ServiceResult(
                        request_id=qr.req.request_id,
                        status=STATUS_CANCELLED, total_cycles=None,
                        tier=None, n_clips=qr.ticket.n_clips,
                        queue_seconds=now - qr.arrival,
                        error="service stopped without drain"))
                self._update_queue_gauges()
            self._cond.notify_all()
        if self._worker is not None:
            self._worker.join(timeout)
            self._worker = None

    def __enter__(self) -> "SimulationService":
        return self.start()

    def __exit__(self, *exc) -> None:
        self.stop(drain=not any(exc))

    @property
    def injector(self) -> Optional[FaultInjector]:
        """The chaos injector the whole service stack consults (None on
        a fault-free config) — benches toggle it between phases."""
        return self._injector

    def prewarm(self, req: Request) -> None:
        """Compile every rung's jit path (and the reference's) with one
        small request before taking traffic, so the watchdog budget
        bounds *runtime*, not a first-flush compile.  Injection is
        suspended for the warmup — chaos belongs to the traffic phases."""
        validate_request(req, self.config,
                         (self.config.l_clip, self.config.l_token))
        prev = (self._injector.set_enabled(False)
                if self._injector is not None else None)
        try:
            for tier in self._tiers + [self._reference]:
                backend = tier.backend()
                backend.reset_context_width()
                backend.add(req.clip_tokens, req.context_tokens,
                            req.clip_mask)
                backend.drain()
        finally:
            if prev is not None:
                self._injector.set_enabled(prev)

    # ------------------------------ admission ------------------------------ #

    def submit(self, req: Request,
               deadline_s: Optional[float] = None) -> ServiceTicket:
        """Admit (or immediately shed) one request.  Always returns a
        ticket; a shed request's ticket is already resolved with the
        typed ``overloaded`` result — callers never block to learn they
        were rejected."""
        validate_request(req, self.config,
                         (self.config.l_clip, self.config.l_token))
        n_clips = req.clip_tokens.shape[0]
        ticket = ServiceTicket(req.request_id, n_clips)
        deadline = (deadline_s if deadline_s is not None
                    else self.sla.default_deadline_s)
        now = time.time()
        with self._cond:
            self._n_submitted += 1
            if not self._running:
                self._admission("not_running")
                self._resolve_ticket(ticket, ServiceResult(
                    request_id=req.request_id, status=STATUS_OVERLOADED,
                    total_cycles=None, tier=None, n_clips=n_clips,
                    error="service is not running"))
                return ticket
            if len(self._queue) >= self.sla.queue_limit:
                self._admission("queue_full")
                self._resolve_ticket(ticket, ServiceResult(
                    request_id=req.request_id, status=STATUS_OVERLOADED,
                    total_cycles=None, tier=None, n_clips=n_clips,
                    error=f"queue full "
                          f"({self.sla.queue_limit} requests)"))
                return ticket
            # SLA-aware shed: if the backlog alone predicts we blow the
            # deadline, reject NOW instead of letting the request expire
            # in queue (an open-loop client learns immediately)
            if self._rate:
                est_wait = self._queued_clips / self._rate
                if est_wait > deadline:
                    self._admission("predicted_wait")
                    self._resolve_ticket(ticket, ServiceResult(
                        request_id=req.request_id,
                        status=STATUS_OVERLOADED, total_cycles=None,
                        tier=None, n_clips=n_clips,
                        error=f"predicted wait {est_wait:.2f}s exceeds "
                              f"deadline {deadline:.2f}s"))
                    return ticket
            self._admission("admitted")
            self._queue.append(_QueuedRequest(
                req=req, ticket=ticket, arrival=now,
                deadline=now + deadline))
            self._queued_clips += n_clips
            self._update_queue_gauges()
            self._cond.notify()
        return ticket

    def _admission(self, decision: str) -> None:
        self._fam_admission.labels(instance=self.instance,
                                   decision=decision).inc()

    def _update_queue_gauges(self) -> None:
        """Mirror the queue state into the registry (lock held)."""
        self._g_queue_depth.set(len(self._queue))
        self._g_queued_clips.set(self._queued_clips)

    def _resolve_ticket(self, ticket: ServiceTicket,
                        result: ServiceResult) -> None:
        self._status_counts[result.status] += 1
        ticket._resolve(result)

    def _finish(self, qr: _QueuedRequest, result: ServiceResult) -> None:
        self._resolve_ticket(qr.ticket, result)

    # ------------------------------ serving ------------------------------ #

    @property
    def current_tier(self) -> str:
        return self._tiers[self._ctrl.idx].name

    def _serve_loop(self) -> None:
        while True:
            with self._cond:
                while not self._queue:
                    if not self._running:
                        return
                    self._cond.wait(0.05)
                if not self._running and not self._draining:
                    return
                batch = self._collect_window()
            if batch:
                self._serve_batch(batch)

    def _collect_window(self) -> List[_QueuedRequest]:
        """Pop one continuous-batching window off the queue (lock held):
        everything queued, up to ``max_flush_clips``.  Requests already
        past their deadline resolve here — typed, without burning a
        flush on work nobody is waiting for."""
        now = time.time()
        window: List[_QueuedRequest] = []
        clips = 0
        while self._queue and clips < self.sla.max_flush_clips:
            qr = self._queue.popleft()
            self._queued_clips -= qr.ticket.n_clips
            if now > qr.deadline:
                self._finish(qr, ServiceResult(
                    request_id=qr.req.request_id, status=STATUS_DEADLINE,
                    total_cycles=None, tier=None,
                    n_clips=qr.ticket.n_clips,
                    queue_seconds=now - qr.arrival,
                    error="deadline expired while queued"))
                continue
            window.append(qr)
            clips += qr.ticket.n_clips
        self._update_queue_gauges()
        return window

    def _serve_batch(self, batch: List[_QueuedRequest]) -> None:
        """Serve one window, walking down the tier ladder on faults.
        Every request in the window ends resolved, whatever happens."""
        t_start = time.time()
        attempts = 0
        max_attempts = len(self._tiers) + 2
        last_error = "unknown"
        while batch and attempts < max_attempts:
            attempts += 1
            # deadlines may expire between (watchdogged) attempts
            now = time.time()
            still: List[_QueuedRequest] = []
            for qr in batch:
                if now > qr.deadline:
                    self._finish(qr, ServiceResult(
                        request_id=qr.req.request_id,
                        status=STATUS_DEADLINE, total_cycles=None,
                        tier=None, n_clips=qr.ticket.n_clips,
                        queue_seconds=qr.deadline - qr.arrival,
                        service_seconds=now - qr.deadline,
                        error="deadline expired during degraded retry"))
                    continue
                still.append(qr)
            batch = still
            if not batch:
                return

            idx = self._ctrl.idx
            tier = self._tiers[idx]
            ts = self.tier_stats[idx]
            try:
                times, flush_s = self._flush_watchdogged(tier, batch)
            except FlushTimeout:
                ts.inc("watchdog_trips")
                tier.invalidate_backend()
                last_error = (f"watchdog abort after "
                              f"{self.sla.watchdog_s:.2f}s at {tier.name}")
                self._demote(idx, "watchdog", last_error)
                continue
            except Exception as exc:          # noqa: BLE001 — typed fail
                ts.inc("fault_trips")
                tier.invalidate_backend()
                last_error = f"{type(exc).__name__}: {exc} at {tier.name}"
                self._demote(idx, "fault", last_error)
                continue

            if not np.isfinite(times).all():
                ts.inc("nan_trips")
                last_error = f"non-finite predictions at {tier.name}"
                self._demote(idx, "nan", last_error)
                continue

            self._n_flushes += 1
            if (tier.name != "monolithic"
                    and self.sla.check_every > 0
                    and self._n_flushes % self.sla.check_every == 0):
                err = self._spot_check(tier, batch)
                tol = self.sla.tier_tolerances.get(
                    tier.name, float("inf"))
                if err is not None and err > tol:
                    ts.inc("relerr_trips")
                    last_error = (f"spot-check rel err {err:.2e} > "
                                  f"{tol:.2e} gate at {tier.name}")
                    self._demote(idx, "relerr", last_error)
                    continue

            # healthy flush: resolve, update throughput, maybe promote
            ts.inc("flushes")
            ts.inc("clips", int(times.shape[0]))
            if flush_s > 1e-6:
                rate = times.shape[0] / flush_s
                self._rate = (rate if self._rate is None
                              else 0.5 * self._rate + 0.5 * rate)
            status = STATUS_OK if idx == 0 else STATUS_DEGRADED
            done_t = time.time()
            off = 0
            for qr in batch:
                k = qr.ticket.n_clips
                self._finish(qr, ServiceResult(
                    request_id=qr.req.request_id, status=status,
                    total_cycles=float(times[off:off + k].sum()),
                    tier=tier.name, n_clips=k,
                    queue_seconds=t_start - qr.arrival,
                    service_seconds=done_t - t_start))
                off += k
            promoted = self._ctrl.on_healthy()
            if promoted is not None:
                self.tier_stats[promoted].inc("promotions")
                self._transition(tier.name, self._tiers[promoted].name,
                                 "promotion")
            return

        # ladder exhausted (or attempt cap): typed failure, never a hang
        now = time.time()
        for qr in batch:
            self._finish(qr, ServiceResult(
                request_id=qr.req.request_id, status=STATUS_FAILED,
                total_cycles=None, tier=None, n_clips=qr.ticket.n_clips,
                queue_seconds=t_start - qr.arrival,
                service_seconds=now - t_start,
                error=f"all serving tiers failed ({last_error})"))

    def _transition(self, from_tier: str, to_tier: str,
                    reason: str) -> None:
        """One ladder move: counter + flight/trace event, same ledger
        the CI chaos leg cross-checks against the bench JSON."""
        self._fam_transitions.labels(
            instance=self.instance, from_tier=from_tier,
            to_tier=to_tier, reason=reason).inc()
        self.obs.event("tier_transition", from_tier=from_tier,
                       to_tier=to_tier, reason=reason)

    def _demote(self, from_idx: int, reason: str,
                detail: str = "") -> None:
        self.tier_stats[from_idx].inc("demotions")
        new_idx = self._ctrl.on_trip()
        from_name = self._tiers[from_idx].name
        if new_idx is not None:
            self._transition(from_name, self._tiers[new_idx].name,
                             reason)
        else:
            # ladder floor: a trip with nowhere to go is still an event
            self.obs.event("tier_trip_floor", tier=from_name,
                           reason=reason)
        # postmortem AFTER the transition event so the flight ring
        # captures it; the snapshot is the post-demotion state
        state = self.snapshot().to_dict()
        if detail:
            state["detail"] = detail
        self.obs.postmortem(f"demote_{reason}", state=state)

    def _flush_watchdogged(self, tier: _Tier,
                           batch: Sequence[_QueuedRequest]
                           ) -> Tuple[np.ndarray, float]:
        """Run one flush on a watchdog thread.  Returns (times, flush
        seconds); raises ``FlushTimeout`` after ``sla.watchdog_s`` (the
        stuck thread is abandoned — see the module docstring)."""
        box: Dict[str, object] = {}
        done = threading.Event()
        t0 = time.time()

        def _run():
            try:
                backend = tier.backend()
                backend.reset_context_width()
                if self.config.sampling is not None:
                    box["times"] = self._drain_sampled(backend, batch)
                else:
                    for qr in batch:
                        r = qr.req
                        backend.add(r.clip_tokens, r.context_tokens,
                                    r.clip_mask)
                    box["times"] = backend.drain()
            except BaseException as exc:      # noqa: BLE001 — re-raised
                box["exc"] = exc
            finally:
                done.set()

        th = threading.Thread(target=_run, name=f"flush-{tier.name}",
                              daemon=True)
        th.start()
        if not done.wait(self.sla.watchdog_s):
            self._abandoned.append(th)
            self._c_abandoned.inc()
            self._prune_abandoned()
            raise FlushTimeout(tier.name)
        if "exc" in box:
            raise box["exc"]                  # type: ignore[misc]
        flush_s = time.time() - t0
        self._h_flush.labels(instance=self.instance,
                             tier=tier.name).observe(flush_s)
        if tier.cache is not None:
            # persist failures must not discard a finished flush: the
            # previous store generation is intact (atomic publish), so
            # this is a counter, not a demotion
            try:
                tier.cache.persist()
            except Exception:                 # noqa: BLE001
                self.tier_stats[self._tiers.index(tier)] \
                    .inc("persist_failures")
        return box["times"], flush_s          # type: ignore[return-value]

    def _drain_sampled(self, backend: BatchedPredictor,
                       batch: Sequence[_QueuedRequest]) -> np.ndarray:
        """Fusion flush body (``config.sampling``): predict only each
        request's stratified clip sample, extrapolate the rest from
        token-derived features, and synthesize a FULL-length per-clip
        times vector — so the NaN guard and per-request scatter in
        ``_serve_batch`` (and hence the typed-result contract) are
        untouched.  The bootstrap is skipped here: ``ServiceResult``
        carries totals, not intervals — use ``PredictorEngine`` with
        sampling for CIs."""
        scfg = self.config.sampling
        plans = []
        for qr in batch:
            r = qr.req
            feats = analytical.token_clip_features(r.clip_tokens,
                                                   r.clip_mask)
            strata = analytical.stratify(feats, scfg.strata,
                                         key_column=0)
            sampled, _ = sampler_mod.stratified_sample(
                strata, scfg.fraction, scfg.min_clips_per_stratum,
                scfg.seed, key=r.request_id)
            if sampled.shape[0]:
                backend.add(r.clip_tokens[sampled],
                            r.context_tokens[sampled],
                            r.clip_mask[sampled])
            plans.append((feats, strata, sampled))
        preds = backend.drain()
        full: List[np.ndarray] = []
        off = 0
        for qr, (feats, strata, sampled) in zip(batch, plans):
            k = int(sampled.shape[0])
            rep = analytical.fuse_predictions(
                feats, strata, sampled, preds[off:off + k],
                bootstrap_resamples=0, seed=scfg.seed,
                key=qr.req.request_id)
            full.append(np.asarray(rep.times, np.float64))
            off += k
        return (np.concatenate(full) if full
                else np.zeros(0, np.float64))

    def _spot_check(self, tier: _Tier,
                    batch: Sequence[_QueuedRequest]) -> Optional[float]:
        """Re-run a small sample of the window's clips through the
        trusted monolithic fp32 reference and return the max rel err
        (None when the reference itself fails — a reference fault must
        not demote the tier under test)."""
        k = self.sla.check_clips
        qr = batch[0]
        tok = qr.req.clip_tokens[:k]
        ctx = qr.req.context_tokens[:k]
        mask = qr.req.clip_mask[:k]
        if tok.shape[0] == 0:
            return None
        try:
            ref = self._reference.backend()
            ref.reset_context_width()
            ref.add(tok, ctx, mask)
            ref_times = ref.drain()
            tier_backend = tier.backend()
            tier_backend.reset_context_width()
            tier_backend.add(tok, ctx, mask)
            got = tier_backend.drain()
        except Exception:                     # noqa: BLE001
            self._reference.invalidate_backend()
            tier.invalidate_backend()
            return None
        if not np.isfinite(got).all():
            return float("inf")
        return float(np.max(np.abs(got - ref_times)
                            / np.maximum(np.abs(ref_times), 1.0)))

    # ------------------------------ stats ------------------------------ #

    def _prune_abandoned(self) -> None:
        """Drop finished stragglers; mirror the alive count into the
        gauge.  A straggler that finally finishes was writing into its
        OLD backend's per-instance metric series — never this one's."""
        self._abandoned = [t for t in self._abandoned if t.is_alive()]
        self._g_abandoned.set(len(self._abandoned))

    def snapshot(self) -> ServiceSnapshot:
        """One consistent, frozen, JSON-stable view of the service."""
        with self._lock:
            self._prune_abandoned()
            return ServiceSnapshot(
                submitted=self._n_submitted,
                statuses=dict(self._status_counts),
                current_tier=self.current_tier,
                backoff=self._ctrl.backoff,
                healthy_streak=self._ctrl.healthy_streak,
                queued=len(self._queue),
                queued_clips=self._queued_clips,
                clips_per_s_ewma=self._rate,
                n_flushes=self._n_flushes,
                tiers={t.name: s.as_dict() for t, s in
                       zip(self._tiers, self.tier_stats)},
                faults_fired=(self._injector.stats()
                              if self._injector is not None else {}),
                abandoned_flush_threads=len(self._abandoned),
                abandoned_flush_threads_total=int(self.obs.metrics.value(
                    ABANDONED_THREADS_TOTAL, instance=self.instance)),
            )

    def stats(self) -> Dict[str, object]:
        """Compat wrapper: ``snapshot().to_dict()`` (same keys as the
        pre-observability dict, plus the new snapshot fields)."""
        return self.snapshot().to_dict()
