"""Clip-parallel predictor serving engine (the CAPSim deployment path).

A *request* is one benchmark interval: the functional trace's clips
(tokenized) whose predicted runtimes must be summed.  The engine packs
clips from many concurrent requests into fixed-shape device batches
(padding only the last batch), runs the jit'd predictor, and scatters the
per-clip times back to their requests — so throughput is set by total clip
count, not by request boundaries.  This is exactly why CAPSim's speedup
grows with checkpoint count (paper Fig 7): requests never serialize.

The engine is synchronous-by-batch (submit/flush); a production front-end
would put a queue in front, but batching policy — the part that determines
accelerator utilization — is all here.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Callable, Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import predictor as pred_mod


@dataclasses.dataclass
class Request:
    request_id: int
    clip_tokens: np.ndarray           # (n, l_clip, l_token) int32
    context_tokens: np.ndarray        # (n, 360) int32
    clip_mask: np.ndarray             # (n, l_clip) float32


@dataclasses.dataclass
class Result:
    request_id: int
    total_cycles: float
    n_clips: int
    seconds: float


class PredictorEngine:
    def __init__(self, params, cfg, *, batch_size: int = 256,
                 use_context: bool = True):
        self.params = params
        self.cfg = cfg
        self.batch_size = batch_size
        self._predict = jax.jit(
            lambda p, b: pred_mod.predict_step(p, b, cfg, use_context))
        self._pending: List[Request] = []

    def submit(self, req: Request) -> None:
        self._pending.append(req)

    def flush(self) -> List[Result]:
        """Run every pending clip through the predictor; one device batch
        may span many requests."""
        if not self._pending:
            return []
        reqs = self._pending
        self._pending = []
        t0 = time.time()

        tok = np.concatenate([r.clip_tokens for r in reqs])
        ctx = np.concatenate([r.context_tokens for r in reqs])
        mask = np.concatenate([r.clip_mask for r in reqs])
        n = tok.shape[0]
        bs = self.batch_size
        pad = (-n) % bs
        if pad:
            tok = np.concatenate([tok, np.repeat(tok[-1:], pad, 0)])
            ctx = np.concatenate([ctx, np.repeat(ctx[-1:], pad, 0)])
            mask = np.concatenate(
                [mask, np.zeros((pad,) + mask.shape[1:], mask.dtype)])

        preds = []
        for lo in range(0, tok.shape[0], bs):
            batch = {"clip_tokens": jnp.asarray(tok[lo:lo + bs]),
                     "context_tokens": jnp.asarray(ctx[lo:lo + bs]),
                     "clip_mask": jnp.asarray(mask[lo:lo + bs])}
            preds.append(np.asarray(self._predict(self.params, batch)))
        times = np.concatenate(preds)[:n]
        seconds = time.time() - t0

        results = []
        off = 0
        for r in reqs:
            k = r.clip_tokens.shape[0]
            results.append(Result(
                request_id=r.request_id,
                total_cycles=float(times[off:off + k].sum()),
                n_clips=k,
                seconds=seconds * (k / max(n, 1))))
            off += k
        return results
