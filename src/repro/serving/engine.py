"""Clip-parallel predictor serving engine (the CAPSim deployment path).

A *request* is one benchmark interval: the functional trace's clips
(tokenized) whose predicted runtimes must be summed.  The engine packs
clips from many concurrent requests into fixed-shape device batches,
runs the jit'd predictor, and scatters the per-clip times back to their
requests — so throughput is set by total clip count, not by request
boundaries.  This is exactly why CAPSim's speedup grows with checkpoint
count (paper Fig 7): requests never serialize.

The batch backend is ``repro.core.engine.BatchedPredictor``: the shared
cached-jit predict step (no re-trace per engine instance), size-bucketed
remainder padding (bounded compiled shapes), and async double-buffered
dispatch.  On top of it sits the static-instruction RT cache
(``repro.core.rt_cache``, on by default): request token rows are deduped
against a content-addressed table that persists *across flushes*, so a
steady request stream pays the 4-layer instruction encoder only for
never-before-seen static rows and every clip runs block-encoder-only
FLOPs.  ``precision="bf16"`` selects the low-precision inference mode
(fp32 master params cast at dispatch; relative-error bounded).

The engine is synchronous-by-batch (submit/flush); a production front-end
would put a queue in front, but batching policy — the part that
determines accelerator utilization — is all in the backend.
"""
from __future__ import annotations

import dataclasses
import time
from typing import List, Optional

import numpy as np

from repro.core import context as ctx_mod
from repro.core import predictor as pred_mod
from repro.core.engine import BatchedPredictor
from repro.core.engine_config import EngineConfig, legacy_engine_config
from repro.core.rt_cache import RTCache, RTCacheStats


@dataclasses.dataclass
class Request:
    request_id: int
    clip_tokens: np.ndarray           # (n, l_clip, l_token) int32
    # (n, M) int32 — M is one of the context.context_len layouts
    # (single-core / core-tagged / peer-channel)
    context_tokens: np.ndarray
    clip_mask: np.ndarray             # (n, l_clip) float32


@dataclasses.dataclass
class Result:
    request_id: int
    total_cycles: float
    n_clips: int
    seconds: float


class PredictorEngine:
    """Construction is config-first: batching, precision, RT cache and
    the device mesh all travel in one ``EngineConfig`` (a non-empty
    ``mesh_shape`` shards every flush's device batches AND the RT-cache
    encode passes over the data mesh, bitwise equal to unsharded).  The
    old loose keyword arguments (``batch_size=``, ``precision=``, ...)
    still work but raise a ``DeprecationWarning``."""

    def __init__(self, params, cfg,
                 config: Optional[EngineConfig] = None, **legacy):
        if legacy:
            config = legacy_engine_config(config, legacy,
                                          "PredictorEngine")
        config = config or EngineConfig()
        self.config = config
        if config.precision == "int8":
            from repro.core import quant
            params = quant.quantize_dequant_params(params)
        self.params = params
        self.cfg = pred_mod.inference_config(cfg, config.precision)
        self.batch_size = config.batch_size
        self.use_context = config.use_context
        self.max_in_flight = config.max_in_flight
        # params are pinned for the engine's lifetime, so the RT table
        # survives across flushes: only unseen static rows ever encode.
        # The cache shares the engine's mesh: encode passes shard too.
        # With rt_store_dir the table additionally survives across
        # *process restarts* (content-keyed load-or-rebuild).
        if config.rt_cache:
            from repro.core.standardize import build_vocab
            self._cache = RTCache(params, self.cfg, config.l_token,
                                  n_shards=config.n_shards,
                                  store_dir=config.rt_store_dir,
                                  store_extra=build_vocab().signature())
        else:
            self._cache = None
        self._pending: List[Request] = []

    @classmethod
    def from_config(cls, params, cfg,
                    config: Optional[EngineConfig] = None
                    ) -> "PredictorEngine":
        """Canonical constructor (mirrors ``SimulationEngine``)."""
        return cls(params, cfg, config)

    @property
    def rt_stats(self) -> Optional[RTCacheStats]:
        return self._cache.stats if self._cache is not None else None

    def submit(self, req: Request) -> None:
        ctx_mod.validate_context_width(req.context_tokens.shape[1],
                                       f"Request {req.request_id}")
        self._pending.append(req)

    def flush(self) -> List[Result]:
        """Run every pending clip through the predictor; one device batch
        may span many requests."""
        if not self._pending:
            return []
        reqs = self._pending
        self._pending = []
        t0 = time.time()

        backend = BatchedPredictor(self.params, self.cfg,
                                   config=self.config,
                                   rt_cache=self._cache)
        for r in reqs:
            backend.add(r.clip_tokens, r.context_tokens, r.clip_mask)
        times = backend.drain()
        if self._cache is not None:
            self._cache.persist()             # no-op without a store_dir
        n = backend.stats.n_predicted
        seconds = time.time() - t0

        results = []
        off = 0
        for r in reqs:
            k = r.clip_tokens.shape[0]
            results.append(Result(
                request_id=r.request_id,
                total_cycles=float(times[off:off + k].sum()),
                n_clips=k,
                seconds=seconds * (k / max(n, 1))))
            off += k
        return results
