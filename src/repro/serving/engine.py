"""Clip-parallel predictor serving engine (the CAPSim deployment path).

A *request* is one benchmark interval: the functional trace's clips
(tokenized) whose predicted runtimes must be summed.  The engine packs
clips from many concurrent requests into fixed-shape device batches,
runs the jit'd predictor, and scatters the per-clip times back to their
requests — so throughput is set by total clip count, not by request
boundaries.  This is exactly why CAPSim's speedup grows with checkpoint
count (paper Fig 7): requests never serialize.

The batch backend is ``repro.core.engine.BatchedPredictor``: the shared
cached-jit predict step (no re-trace per engine instance), size-bucketed
remainder padding (bounded compiled shapes), and async double-buffered
dispatch.  On top of it sits the static-instruction RT cache
(``repro.core.rt_cache``, on by default): request token rows are deduped
against a content-addressed table that persists *across flushes*, so a
steady request stream pays the 4-layer instruction encoder only for
never-before-seen static rows and every clip runs block-encoder-only
FLOPs.  ``precision="bf16"`` selects the low-precision inference mode
(fp32 master params cast at dispatch; relative-error bounded).

The engine is synchronous-by-batch (submit/flush) and holds ONE backend
(``BatchedPredictor``) for its whole lifetime: the cached jit step, the
RT table, and — under ``fused_serving`` — the per-table-version cross-K/V
serving plan all survive across flushes, so a steady request stream pays
plan precompute only when the table actually grows, never per flush.
The production front-end that puts a queue, deadlines and graceful
degradation on top is ``repro.serving.service.SimulationService``.
"""
from __future__ import annotations

import dataclasses
from typing import List, Optional, Tuple

import numpy as np

from repro.core import analytical
from repro.core import context as ctx_mod
from repro.core import predictor as pred_mod
from repro.core import sampler as sampler_mod
from repro.core.engine import BatchedPredictor
from repro.core.engine_config import EngineConfig, reject_legacy_kwargs
from repro.core.rt_cache import RTCache, RTCacheStats
from repro.obs import Observability


@dataclasses.dataclass
class Request:
    request_id: int
    clip_tokens: np.ndarray           # (n, l_clip, l_token) int32
    # (n, M) int32 — M is one of the context.context_len layouts
    # (single-core / core-tagged / peer-channel)
    context_tokens: np.ndarray
    clip_mask: np.ndarray             # (n, l_clip) float32


@dataclasses.dataclass
class Result:
    request_id: int
    total_cycles: float
    n_clips: int
    seconds: float
    # --- PredictionReport fields (config.sampling flushes only) ---
    cycles_ci: Optional[Tuple[float, float]] = None
    clips_predicted: Optional[int] = None     # None -> every clip (full path)
    clips_extrapolated: int = 0

    def __post_init__(self):
        if self.clips_predicted is None:
            self.clips_predicted = self.n_clips


def validate_request(req: Request, config: EngineConfig,
                     expect: Optional[tuple] = None) -> None:
    """Full submission-boundary payload check: ndims, dtypes, and
    internal shape consistency of every array (not just the context
    width).  ``expect=(l_clip, l_token)`` additionally pins the clip
    shape — the ``SimulationService`` pins it to its config, and the
    raw engine pins it to the flush's first request (the engine itself
    is shape-polymorphic in ``l_clip`` across flushes, but one flush's
    clips concatenate into shared device batches).  Raises
    ``ValueError`` naming the request and the offending field — a
    malformed tenant payload must never surface as a downstream
    concatenate/jit shape error (or worse, a silently wrong gather)."""
    who = f"Request {req.request_id}"
    tok, ctx, mask = req.clip_tokens, req.context_tokens, req.clip_mask
    if tok.ndim != 3:
        raise ValueError(f"{who}: clip_tokens must be "
                         f"(n, l_clip, l_token), got shape {tok.shape}")
    n = tok.shape[0]
    if expect is not None and tok.shape[1:] != tuple(expect):
        raise ValueError(
            f"{who}: clip_tokens shape {tok.shape} does not match the "
            f"engine's (n, l_clip={expect[0]}, l_token={expect[1]})")
    if not np.issubdtype(tok.dtype, np.integer):
        raise ValueError(f"{who}: clip_tokens dtype {tok.dtype} is not "
                         f"an integer token dtype (expected int32)")
    if ctx.ndim != 2 or ctx.shape[0] != n:
        raise ValueError(
            f"{who}: context_tokens must be (n={n}, M), "
            f"got shape {ctx.shape}")
    if not np.issubdtype(ctx.dtype, np.integer):
        raise ValueError(f"{who}: context_tokens dtype {ctx.dtype} is "
                         f"not an integer token dtype (expected int32)")
    ctx_mod.validate_context_width(ctx.shape[1], who)
    if mask.shape != (n, tok.shape[1]):
        raise ValueError(
            f"{who}: clip_mask shape {mask.shape} does not match "
            f"clip_tokens' (n={n}, l_clip={tok.shape[1]})")
    if not np.issubdtype(mask.dtype, np.floating):
        raise ValueError(f"{who}: clip_mask dtype {mask.dtype} is not a "
                         f"float mask dtype (expected float32)")


class PredictorEngine:
    """Construction is config-first: batching, precision, RT cache and
    the device mesh all travel in one ``EngineConfig`` (a non-empty
    ``mesh_shape`` shards every flush's device batches AND the RT-cache
    encode passes over the data mesh, bitwise equal to unsharded).
    ``config.sampling`` switches flushes to the analytical-ML fusion
    path: only a stratified sample of each request's clips runs through
    the predictor, the rest extrapolate from token-derived features, and
    each ``Result`` carries a bootstrap CI.  The pre-PR-6 loose keyword
    signature is retired: extra keywords raise ``TypeError`` pointing at
    ``EngineConfig``."""

    def __init__(self, params, cfg,
                 config: Optional[EngineConfig] = None, **legacy):
        reject_legacy_kwargs(legacy, "PredictorEngine")
        config = config or EngineConfig()
        self.config = config
        self.obs = Observability.from_config(config.observability)
        self.instance = self.obs.metrics.next_instance("pengine")
        if config.precision == "int8":
            from repro.core import quant
            params = quant.quantize_dequant_params(params)
        self.params = params
        self.cfg = pred_mod.inference_config(cfg, config.precision)
        self.batch_size = config.batch_size
        self.use_context = config.use_context
        self.max_in_flight = config.max_in_flight
        # params are pinned for the engine's lifetime, so the RT table
        # survives across flushes: only unseen static rows ever encode.
        # The cache shares the engine's mesh: encode passes shard too.
        # With rt_store_dir the table additionally survives across
        # *process restarts* (content-keyed load-or-rebuild).
        if config.rt_cache:
            from repro.core.standardize import build_vocab
            self._cache = RTCache(params, self.cfg, config.l_token,
                                  n_shards=config.n_shards,
                                  store_dir=config.rt_store_dir,
                                  store_extra=build_vocab().signature(),
                                  obs=self.obs)
        else:
            self._cache = None
        self._faults = None
        if config.faults:
            from repro.serving.faults import FaultInjector
            self._faults = FaultInjector.from_config(config)
        self._pending: List[Request] = []
        # ONE backend for the engine's lifetime (see module docstring):
        # rebuilding per flush rebuilt the fused serving_plan every time
        self._backend: Optional[BatchedPredictor] = None

    @classmethod
    def from_config(cls, params, cfg,
                    config: Optional[EngineConfig] = None
                    ) -> "PredictorEngine":
        """Canonical constructor (mirrors ``SimulationEngine``)."""
        return cls(params, cfg, config)

    @property
    def rt_stats(self) -> Optional[RTCacheStats]:
        return self._cache.stats if self._cache is not None else None

    def submit(self, req: Request) -> None:
        """Queue one request, validating the full payload contract at
        the submission boundary (with the producer on the stack), not as
        a shape error inside a later concatenate or jit re-trace.  The
        flush's first request pins its clip shape."""
        expect = (self._pending[0].clip_tokens.shape[1:]
                  if self._pending else None)
        validate_request(req, self.config, expect)
        self._pending.append(req)

    def backend(self) -> BatchedPredictor:
        """The engine-lifetime batch backend (built lazily on first
        flush, then reused: cached jit step, RT table, and fused
        serving plan all persist)."""
        if self._backend is None:
            self._backend = BatchedPredictor(self.params, self.cfg,
                                             config=self.config,
                                             rt_cache=self._cache,
                                             fault_injector=self._faults,
                                             obs=self.obs)
        return self._backend

    def flush(self) -> List[Result]:
        """Run every pending clip through the predictor; one device batch
        may span many requests."""
        if not self._pending:
            return []
        reqs = self._pending
        self._pending = []
        if self.config.sampling is not None:
            return self._flush_sampled(reqs)
        with self.obs.span("serving.flush", instance=self.instance,
                           args={"requests": len(reqs)}) as sp:
            backend = self.backend()
            # flushes are independent: each may carry a different (but
            # internally consistent) context layout
            backend.reset_context_width()
            for r in reqs:
                backend.add(r.clip_tokens, r.context_tokens, r.clip_mask)
            times = backend.drain()           # exactly this flush's clips
            if self._cache is not None:
                self._cache.persist()         # no-op without a store_dir
        n = times.shape[0]
        seconds = sp.seconds

        results = []
        off = 0
        for r in reqs:
            k = r.clip_tokens.shape[0]
            results.append(Result(
                request_id=r.request_id,
                total_cycles=float(times[off:off + k].sum()),
                n_clips=k,
                seconds=seconds * (k / max(n, 1))))
            off += k
        return results

    def _flush_sampled(self, reqs: List[Request]) -> List[Result]:
        """Fusion path of ``flush()``: per request, stratify on
        token-derived features (``analytical.token_clip_features`` —
        serving never sees the columnar trace), predict only the
        stratified sample, extrapolate the rest, and attach the
        bootstrap CI.  Every request still resolves to exactly one
        typed ``Result``; the draw is keyed by ``request_id`` so a
        retried request samples identically."""
        scfg = self.config.sampling
        plans = []
        with self.obs.span("serving.flush", instance=self.instance,
                           args={"requests": len(reqs),
                                 "sampled": True}) as sp:
            backend = self.backend()
            backend.reset_context_width()
            for r in reqs:
                feats = analytical.token_clip_features(r.clip_tokens,
                                                       r.clip_mask)
                # token features have no analytical-cycles column; clip
                # occupancy (column 0) is the work-amount proxy
                strata = analytical.stratify(feats, scfg.strata,
                                             key_column=0)
                sampled, _ = sampler_mod.stratified_sample(
                    strata, scfg.fraction, scfg.min_clips_per_stratum,
                    scfg.seed, key=r.request_id)
                if sampled.shape[0]:
                    backend.add(r.clip_tokens[sampled],
                                r.context_tokens[sampled],
                                r.clip_mask[sampled])
                plans.append((feats, strata, sampled))
            preds = backend.drain()           # exactly the sampled clips
            if self._cache is not None:
                self._cache.persist()         # no-op without a store_dir
        n = preds.shape[0]
        seconds = sp.seconds

        results = []
        off = 0
        for r, (feats, strata, sampled) in zip(reqs, plans):
            k = int(sampled.shape[0])
            rep = analytical.fuse_predictions(
                feats, strata, sampled, preds[off:off + k],
                bootstrap_resamples=scfg.bootstrap_resamples,
                seed=scfg.seed, key=r.request_id)
            results.append(Result(
                request_id=r.request_id,
                total_cycles=rep.total_cycles,
                n_clips=int(r.clip_tokens.shape[0]),
                seconds=seconds * (k / max(n, 1)),
                cycles_ci=rep.cycles_ci,
                clips_predicted=rep.clips_predicted,
                clips_extrapolated=rep.clips_extrapolated))
            off += k
        return results
