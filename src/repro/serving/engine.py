"""Clip-parallel predictor serving engine (the CAPSim deployment path).

A *request* is one benchmark interval: the functional trace's clips
(tokenized) whose predicted runtimes must be summed.  The engine packs
clips from many concurrent requests into fixed-shape device batches,
runs the jit'd predictor, and scatters the per-clip times back to their
requests — so throughput is set by total clip count, not by request
boundaries.  This is exactly why CAPSim's speedup grows with checkpoint
count (paper Fig 7): requests never serialize.

The batch backend is ``repro.core.engine.BatchedPredictor``: the shared
cached-jit predict step (no re-trace per engine instance), size-bucketed
remainder padding (bounded compiled shapes), and async double-buffered
dispatch.  The engine is synchronous-by-batch (submit/flush); a production
front-end would put a queue in front, but batching policy — the part that
determines accelerator utilization — is all in the backend.
"""
from __future__ import annotations

import dataclasses
import time
from typing import List

import numpy as np

from repro.core.engine import BatchedPredictor


@dataclasses.dataclass
class Request:
    request_id: int
    clip_tokens: np.ndarray           # (n, l_clip, l_token) int32
    context_tokens: np.ndarray        # (n, 360) int32
    clip_mask: np.ndarray             # (n, l_clip) float32


@dataclasses.dataclass
class Result:
    request_id: int
    total_cycles: float
    n_clips: int
    seconds: float


class PredictorEngine:
    def __init__(self, params, cfg, *, batch_size: int = 256,
                 use_context: bool = True, max_in_flight: int = 2):
        self.params = params
        self.cfg = cfg
        self.batch_size = batch_size
        self.use_context = use_context
        self.max_in_flight = max_in_flight
        self._pending: List[Request] = []

    def submit(self, req: Request) -> None:
        self._pending.append(req)

    def flush(self) -> List[Result]:
        """Run every pending clip through the predictor; one device batch
        may span many requests."""
        if not self._pending:
            return []
        reqs = self._pending
        self._pending = []
        t0 = time.time()

        backend = BatchedPredictor(
            self.params, self.cfg, batch_size=self.batch_size,
            use_context=self.use_context, max_in_flight=self.max_in_flight)
        for r in reqs:
            backend.add(r.clip_tokens, r.context_tokens, r.clip_mask)
        times = backend.drain()
        n = backend.stats.n_predicted
        seconds = time.time() - t0

        results = []
        off = 0
        for r in reqs:
            k = r.clip_tokens.shape[0]
            results.append(Result(
                request_id=r.request_id,
                total_cycles=float(times[off:off + k].sum()),
                n_clips=k,
                seconds=seconds * (k / max(n, 1))))
            off += k
        return results
