"""First-class fault injection for the serving stack (chaos layer).

Production claims about graceful degradation are untestable unless the
faults that trigger them are *injectable on the real code paths*: a mock
engine exercises the mock, not the watchdog.  ``FaultInjector`` is the
one chaos surface the whole stack consults —

  ``BatchedPredictor._dispatch``   ``device_error`` (raises
                                   ``FaultInjected``) and ``slow_flush``
                                   (stalls the dispatch long enough to
                                   trip the service watchdog),
  ``BatchedPredictor._retire``     ``nan_output`` (the batch's retired
                                   predictions come back non-finite, the
                                   exact signature of a bad kernel or a
                                   corrupted table row),
  ``RTCache._load_store``          ``corrupt_rt_read`` (a key-matching
                                   store read yields corrupt data; the
                                   cache must warn + cold-encode),
  ``RTCache.persist``              ``crash_persist`` (the process "dies"
                                   after writing array files but BEFORE
                                   the atomic publish; the previous
                                   store generation must stay loadable).

The spec travels in ``EngineConfig.faults`` (``(kind, rate)`` pairs,
kinds in ``engine_config.FAULT_KINDS``) + ``fault_seed``, so one JSON
config drives a chaos run end to end, and every engine entry point
builds its injector with ``FaultInjector.from_config``.  Draws are
deterministic in (seed, call order); rates can be flipped at runtime
(``set_enabled`` / ``set_rates``) so a bench can run a healthy phase, a
fault phase, and a recovery phase against one live service.
"""
from __future__ import annotations

import threading
import time
from typing import Dict, Iterable, Mapping, Optional, Tuple, Union

import numpy as np

from repro.core.engine_config import FAULT_KINDS, EngineConfig
from repro.obs.metrics import REGISTRY

FaultSpec = Union[Mapping[str, float], Iterable[Tuple[str, float]]]

# fleet-wide fault ledger: every fired injection also lands in the
# process metrics registry so /metrics shows chaos activity live
FAULTS_INJECTED_TOTAL = "capsim_faults_injected_total"


class FaultInjected(RuntimeError):
    """An injected fault fired.  ``kind`` names which one."""

    def __init__(self, kind: str, detail: str = ""):
        self.kind = kind
        super().__init__(f"injected fault: {kind}"
                         + (f" ({detail})" if detail else ""))


class FaultInjector:
    """Deterministic, rate-based fault source.

    ``maybe(kind)`` returns True when the fault fires this draw;
    ``maybe_raise(kind)`` raises ``FaultInjected`` instead.  Draws come
    from one ``np.random.Generator`` seeded at construction, so a given
    (seed, call sequence) replays bit for bit — chaos tests are as
    reproducible as the bitwise-equality ones.  Thread-safe: the serving
    worker, the watchdogged flush thread, and the RT-cache loader may
    all consult one injector concurrently.
    """

    def __init__(self, faults: FaultSpec = (), seed: int = 0, *,
                 slow_seconds: float = 0.25):
        rates = dict(faults.items() if isinstance(faults, Mapping)
                     else faults)
        unknown = set(rates) - set(FAULT_KINDS)
        if unknown:
            raise ValueError(f"unknown fault kinds {sorted(unknown)} "
                             f"(known: {list(FAULT_KINDS)})")
        self._rates: Dict[str, float] = {k: float(rates.get(k, 0.0))
                                         for k in FAULT_KINDS}
        self._rng = np.random.default_rng(seed)
        self._lock = threading.Lock()
        self._enabled = True
        self.slow_seconds = slow_seconds
        # per-kind fire counters: the bench/service stats report exactly
        # how many of each fault the run actually saw
        self.fired: Dict[str, int] = {k: 0 for k in FAULT_KINDS}
        fam = REGISTRY.counter(
            FAULTS_INJECTED_TOTAL,
            "Injected chaos faults that actually fired, by kind.",
            ("kind",))
        self._metric = {k: fam.labels(kind=k) for k in FAULT_KINDS}

    @classmethod
    def from_config(cls, config: EngineConfig, *,
                    slow_seconds: float = 0.25
                    ) -> Optional["FaultInjector"]:
        """Build the injector an engine should honor — None when the
        config injects nothing, so the healthy path stays hook-free."""
        if not config.faults:
            return None
        return cls(config.faults, config.fault_seed,
                   slow_seconds=slow_seconds)

    # ------------------------------ control ------------------------------ #

    def set_enabled(self, enabled: bool) -> bool:
        """Master switch: a disabled injector never fires (the bench's
        healthy / faulted / recovery phases toggle this).  Returns the
        previous setting so callers can restore it."""
        with self._lock:
            prev = self._enabled
            self._enabled = enabled
            return prev

    def set_rates(self, faults: FaultSpec) -> None:
        with self._lock:
            for k, r in (faults.items() if isinstance(faults, Mapping)
                         else faults):
                if k not in self._rates:
                    raise ValueError(f"unknown fault kind {k!r}")
                self._rates[k] = float(r)

    def rate(self, kind: str) -> float:
        return self._rates[kind]

    # ------------------------------ draws ------------------------------ #

    def maybe(self, kind: str) -> bool:
        """One deterministic draw against ``kind``'s rate."""
        with self._lock:
            rate = self._rates[kind] if self._enabled else 0.0
            if rate <= 0.0:
                return False
            fired = bool(self._rng.random() < rate)
            if fired:
                self.fired[kind] += 1
                self._metric[kind].inc()
            return fired

    def maybe_raise(self, kind: str, detail: str = "") -> None:
        if self.maybe(kind):
            raise FaultInjected(kind, detail)

    # --------------------------- stack hooks --------------------------- #

    def on_dispatch(self) -> None:
        """Consulted by ``BatchedPredictor._dispatch`` before every
        device batch: may stall (slow_flush) and/or raise
        (device_error)."""
        if self.maybe("slow_flush"):
            time.sleep(self.slow_seconds)
        self.maybe_raise("device_error", "predict dispatch failed")

    def corrupt_output(self, out: np.ndarray) -> np.ndarray:
        """Consulted by ``BatchedPredictor._retire``: on a nan_output
        draw the retired batch comes back non-finite — the service-level
        NaN guard must catch it before any result reaches a caller."""
        if out.size and self.maybe("nan_output"):
            out = np.array(out, copy=True)
            out[0] = np.nan
        return out

    def crash_hook(self):
        """``pre_publish`` hook for ``ckpt.save``: fires crash_persist
        right before the atomic rename, the worst-case crash point."""
        def _hook():
            self.maybe_raise(
                "crash_persist",
                "simulated process death before atomic publish")
        return _hook

    def stats(self) -> Dict[str, int]:
        return {k: v for k, v in self.fired.items() if v}
