"""Sharded checkpointing: atomic save/restore/resume with a manifest.

Layout (one directory per step):

    <dir>/step_000120/
        manifest.json       tree structure, shapes, dtypes, step, metadata
        arr_00000.npy ...   one file per leaf (host-local shard)
    <dir>/LATEST            text file holding the newest complete step

Writes are atomic: arrays land in a writer-unique ``step_N.tmp*``
directory which is renamed only after the manifest is fsync'd, so a
killed writer can never leave a half-checkpoint that restore would pick
up — the crash-restart path in distributed/fault_tolerance.py and the
persistent RT-cache store (core/rt_cache.py) rely on this.  The
``LATEST`` pointer is published the same way (temp file + fsync +
``os.replace``), so a crash mid-write can never leave it truncated.
Concurrent writers racing one step are safe: tmp names embed pid + a
serial so they never collide, and the publish rename retries through
the delete/rename window — last writer wins with no corrupt final dir.

``pre_publish`` (chaos hook) runs right before the final rename — the
worst-case crash point; ``serving/faults.py`` uses it to prove the
previous checkpoint generation survives a mid-persist death.

On a multi-host pod each process saves only its addressable shards
(``host`` / ``n_hosts`` name the files disjointly) and restore re-shards
via device_put against the provided shardings; on this single-process CPU
host that degenerates to whole-array files, but the format is the same.

``CheckpointManager`` adds async saves (overlap serialization with the
next train steps — distributed-optimization trick #3 in DESIGN.md) and
keep-last-K garbage collection.
"""
from __future__ import annotations

import itertools
import json
import os
import re
import shutil
import threading
from pathlib import Path
from typing import Any, Callable, Dict, Optional

import jax
import numpy as np

_STEP_DIR = re.compile(r"step_(\d+)$")

# writer-unique tmp suffix serial: two saves in one process (or two
# engine threads sharing an RT store dir) never collide on a tmp path
_TMP_SERIAL = itertools.count()


def _completed_steps(ckpt_dir: Path):
    """Step numbers of *published* checkpoint dirs only — tmp dirs (any
    ``step_N.tmp*`` writer suffix) and stray files never match."""
    out = []
    for d in ckpt_dir.iterdir():
        m = _STEP_DIR.fullmatch(d.name)
        if m and d.is_dir():
            out.append(int(m.group(1)))
    return sorted(out)


def _write_latest(ckpt_dir: Path, step: int, host: int) -> None:
    """Atomic LATEST publish: a crash can truncate the temp file, never
    the pointer itself (the old truncate-then-write left a window where
    a killed writer orphaned every published step)."""
    tmp = ckpt_dir / f"LATEST.tmp{host}-{os.getpid()}-{next(_TMP_SERIAL)}"
    with open(tmp, "w") as f:
        f.write(str(step))
        f.flush()
        os.fsync(f.fileno())
    os.replace(tmp, ckpt_dir / "LATEST")


def _flatten(tree) -> Dict[str, Any]:
    flat = jax.tree_util.tree_flatten_with_path(tree)[0]
    out = {}
    for path, leaf in flat:
        key = "/".join(str(getattr(p, "key", getattr(p, "idx", p)))
                       for p in path)
        out[key] = leaf
    return out


def save(state, step: int, ckpt_dir: str, *, host: int = 0,
         n_hosts: int = 1, metadata: Optional[dict] = None,
         pre_publish: Optional[Callable[[], None]] = None) -> Path:
    ckpt_dir = Path(ckpt_dir)
    final = ckpt_dir / f"step_{step:08d}"
    tmp = ckpt_dir / (f"step_{step:08d}.tmp{host}"
                      f"-{os.getpid()}-{next(_TMP_SERIAL)}")
    tmp.mkdir(parents=True, exist_ok=True)

    try:
        flat = _flatten(state)
        entries = {}
        for i, (key, leaf) in enumerate(sorted(flat.items())):
            arr = np.asarray(leaf)
            fname = f"arr_{i:05d}.h{host}.npy"
            np.save(tmp / fname, arr)
            entries[key] = {"file": fname, "shape": list(arr.shape),
                            "dtype": str(arr.dtype)}
        manifest = {"step": step, "host": host, "n_hosts": n_hosts,
                    "entries": entries, "metadata": metadata or {}}
        mpath = tmp / f"manifest.h{host}.json"
        with open(mpath, "w") as f:
            json.dump(manifest, f, indent=1)
            f.flush()
            os.fsync(f.fileno())

        if pre_publish is not None:
            pre_publish()       # chaos hook: worst-case crash point

        # publish: replace any previous generation of this step.  Two
        # writers racing the same step can interleave rmtree/rename, so
        # retry through the window — last writer wins, and a loser never
        # leaves a half-deleted final dir (rmtree happens on OUR tmp's
        # turn only; the published dir is always a complete rename).
        for attempt in range(5):
            if final.exists():
                shutil.rmtree(final, ignore_errors=True)
            try:
                tmp.rename(final)                        # atomic publish
                break
            except OSError:
                if attempt == 4:
                    raise
    except BaseException:
        shutil.rmtree(tmp, ignore_errors=True)
        raise
    _write_latest(ckpt_dir, step, host)
    return final


def latest_step(ckpt_dir: str) -> Optional[int]:
    p = Path(ckpt_dir) / "LATEST"
    if not p.exists():
        return None
    step = int(p.read_text().strip())
    if not (Path(ckpt_dir) / f"step_{step:08d}").exists():
        # LATEST points at a GC'd/missing dir: fall back to scanning the
        # published step dirs (tmp dirs of any writer-suffix shape are
        # excluded by the regex, not by a fragile endswith list)
        steps = _completed_steps(Path(ckpt_dir))
        return steps[-1] if steps else None
    return step


def read_manifest(step: int, ckpt_dir: str, *, host: int = 0) -> dict:
    """Load a step's manifest (entries + metadata) without touching the
    array files — how the RT-cache store validates its content key before
    paying the restore."""
    final = Path(ckpt_dir) / f"step_{step:08d}"
    return json.loads((final / f"manifest.h{host}.json").read_text())


def restore(state_like, step: int, ckpt_dir: str, *, host: int = 0,
            shardings=None):
    """Rebuild the state tree from disk.  ``state_like`` provides the tree
    structure (concrete arrays or ShapeDtypeStructs); ``shardings`` (same
    tree shape, optional) re-shards each leaf via device_put."""
    final = Path(ckpt_dir) / f"step_{step:08d}"
    manifest = json.loads((final / f"manifest.h{host}.json").read_text())
    entries = manifest["entries"]
    flat_keys = sorted(_flatten(state_like))
    assert flat_keys == sorted(entries), (
        f"checkpoint tree mismatch: {set(flat_keys) ^ set(entries)}")

    sh_flat = _flatten(shardings) if shardings is not None else {}
    leaves = {}
    for key in flat_keys:
        arr = np.load(final / entries[key]["file"])
        if key in sh_flat:
            leaves[key] = jax.device_put(arr, sh_flat[key])
        else:
            leaves[key] = jax.numpy.asarray(arr)

    treedef = jax.tree_util.tree_structure(state_like)
    ordered = [leaves[k] for k in
               ("/".join(str(getattr(p, "key", getattr(p, "idx", p)))
                         for p in path)
                for path, _ in jax.tree_util.tree_flatten_with_path(
                    state_like)[0])]
    return jax.tree_util.tree_unflatten(treedef, ordered)


class CheckpointManager:
    """Async, keep-last-K checkpointing for the training loop."""

    def __init__(self, ckpt_dir: str, *, keep: int = 3,
                 async_save: bool = True, host: int = 0, n_hosts: int = 1):
        self.dir = Path(ckpt_dir)
        self.keep = keep
        self.async_save = async_save
        self.host = host
        self.n_hosts = n_hosts
        self._thread: Optional[threading.Thread] = None

    def wait(self) -> None:
        if self._thread is not None:
            self._thread.join()
            self._thread = None

    def save(self, state, step: int, metadata: Optional[dict] = None):
        self.wait()                                     # one in flight
        # materialize on host *now* so training can mutate device state
        host_state = jax.tree_util.tree_map(np.asarray, state)

        def _do():
            save(host_state, step, str(self.dir), host=self.host,
                 n_hosts=self.n_hosts, metadata=metadata)
            self._gc()

        if self.async_save:
            self._thread = threading.Thread(target=_do, daemon=True)
            self._thread.start()
        else:
            _do()

    def _gc(self) -> None:
        steps = _completed_steps(self.dir)
        for s in steps[: -self.keep]:
            shutil.rmtree(self.dir / f"step_{s:08d}", ignore_errors=True)

    def restore_latest(self, state_like, shardings=None):
        self.wait()
        step = latest_step(str(self.dir))
        if step is None:
            return None, None
        return restore(state_like, step, str(self.dir), host=self.host,
                       shardings=shardings), step
