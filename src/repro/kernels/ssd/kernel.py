"""Pallas TPU kernel for the chunked SSD (Mamba2) scan.

Grid = (Bt*H, n_chunks) with the chunk axis sequential: the cross-chunk
state (P, N) lives in VMEM scratch and is carried across grid steps — the
TPU analogue of Mamba2's chunked GPU algorithm, where the within-chunk
quadratic part runs on the MXU as (Q x N x Q) / (Q x Q x P) matmuls and the
inter-chunk recurrence is a scalar-decay update of the scratch state.

Zero-copy broadcast tricks in the BlockSpecs:
  - B/C projections are shared across heads (single SSD group): their
    index_map divides the head-grid coordinate by H, so the (Bt, S, N)
    arrays are never materialized per head.
  - A is indexed by (bh mod H): one scalar per head.

Padding: callers pad S to a chunk multiple with dt = 0 -> exp(dt*A) = 1 and
dt*x = 0, so padded steps neither decay nor inject state (y rows at padded
positions are garbage and dropped by the wrapper).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

NEG_INF = -1e30


def _ssd_kernel(x_ref, dt_ref, b_ref, c_ref, a_ref, y_ref, st_out_ref,
                state_scr, *, q: int):
    """One (head-row, chunk) step.

    x_ref: (q, P); dt_ref: (1, q); b_ref/c_ref: (q, N); a_ref: (1, 1);
    y_ref: (q, P); st_out_ref: (P, N); state_scr: (P, N) f32.
    """
    g = pl.program_id(1)

    @pl.when(g == 0)
    def _init():
        state_scr[...] = jnp.zeros_like(state_scr)

    x = x_ref[...].astype(jnp.float32)                   # (q, P)
    dt = dt_ref[0, :].astype(jnp.float32)                # (q,)
    B = b_ref[...].astype(jnp.float32)                   # (q, N)
    C = c_ref[...].astype(jnp.float32)                   # (q, N)
    A = a_ref[0, 0].astype(jnp.float32)                  # scalar (negative)

    dA = dt * A                                          # (q,) <= 0
    seg = jnp.cumsum(dA)                                 # (q,)

    # within-chunk: scores[i,j] = (C_i . B_j) * exp(seg_i - seg_j) for i>=j
    diff = seg[:, None] - seg[None, :]
    ii = jax.lax.broadcasted_iota(jnp.int32, (q, q), 0)
    jj = jax.lax.broadcasted_iota(jnp.int32, (q, q), 1)
    L = jnp.where(ii >= jj, jnp.exp(diff), 0.0)          # (q, q)
    CB = jax.lax.dot_general(C, B, (((1,), (1,)), ((), ())),
                             preferred_element_type=jnp.float32)
    scores = CB * L
    xdt = x * dt[:, None]                                # (q, P)
    y = jax.lax.dot_general(scores, xdt, (((1,), (0,)), ((), ())),
                            preferred_element_type=jnp.float32)

    # incoming-state contribution: exp(seg_i) * (C_i . state)
    state = state_scr[...]                               # (P, N)
    y_off = jax.lax.dot_general(C, state, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32)
    y += jnp.exp(seg)[:, None] * y_off                   # (q, P)

    # state update: state' = state * exp(seg_q) + x^T @ (B * w), w = dt*decay
    decay_end = jnp.exp(seg[-1] - seg)                   # (q,)
    w = dt * decay_end
    upd = jax.lax.dot_general(x * w[:, None], B, (((0,), (0,)), ((), ())),
                              preferred_element_type=jnp.float32)  # (P, N)
    state_scr[...] = state * jnp.exp(seg[-1]) + upd

    y_ref[...] = y.astype(y_ref.dtype)
    st_out_ref[...] = state_scr[...]                     # last write wins


@functools.partial(jax.jit,
                   static_argnames=("h", "q", "interpret"))
def ssd_scan_grid(x, dt, B, C, A, *, h: int, q: int, interpret: bool):
    """x: (BtH, S, P); dt: (BtH, S); B/C: (Bt, S, N); A: (H, 1);
    S divisible by q.  Returns (y (BtH, S, P), state (BtH, P, N) f32)."""
    BtH, S, P = x.shape
    N = B.shape[-1]
    n_chunks = S // q

    kernel = functools.partial(_ssd_kernel, q=q)
    return pl.pallas_call(
        kernel,
        grid=(BtH, n_chunks),
        in_specs=[
            pl.BlockSpec((None, q, P), lambda b, g: (b, g, 0)),
            pl.BlockSpec((None, 1, q), lambda b, g: (b, 0, g)),
            pl.BlockSpec((None, q, N), lambda b, g, h=h: (b // h, g, 0)),
            pl.BlockSpec((None, q, N), lambda b, g, h=h: (b // h, g, 0)),
            pl.BlockSpec((None, 1, 1), lambda b, g, h=h: (b % h, 0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((None, q, P), lambda b, g: (b, g, 0)),
            pl.BlockSpec((None, P, N), lambda b, g: (b, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((BtH, S, P), x.dtype),
            jax.ShapeDtypeStruct((BtH, P, N), jnp.float32),
        ],
        scratch_shapes=[_vmem((P, N))],
        interpret=interpret,
    )(x, dt, B, C, A)


def _vmem(shape):
    from jax.experimental.pallas import tpu as pltpu
    return pltpu.VMEM(shape, jnp.float32)
