"""Pure-jnp oracle for the SSD (Mamba2) scan: the naive per-timestep
recurrence.  Deliberately a *different algorithm* from both the Pallas
kernel and models/mamba2.ssd_chunked (which are chunked), so agreement is
meaningful:

    state_t = state_{t-1} * exp(dt_t * A) + dt_t * B_t x_t^T
    y_t     = C_t . state_t
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def ssd_ref(x: jax.Array, dt: jax.Array, B: jax.Array, C: jax.Array,
            A: jax.Array):
    """x: (Bt, S, H, P); dt: (Bt, S, H) positive; B/C: (Bt, S, N);
    A: (H,) negative.  Returns (y (Bt, S, H, P), state (Bt, H, P, N))."""
    Bt, S, H, P = x.shape
    N = B.shape[-1]
    xf = x.astype(jnp.float32)
    dtf = dt.astype(jnp.float32)
    Bf = B.astype(jnp.float32)
    Cf = C.astype(jnp.float32)
    Af = A.astype(jnp.float32)

    def step(state, inp):
        xt, dtt, Bt_, Ct = inp                          # (Bt,H,P),(Bt,H),(Bt,N)x2
        decay = jnp.exp(dtt * Af[None, :])              # (Bt, H)
        upd = jnp.einsum("bn,bh,bhp->bhpn", Bt_, dtt, xt)
        state = state * decay[..., None, None] + upd
        y = jnp.einsum("bn,bhpn->bhp", Ct, state)
        return state, y

    state0 = jnp.zeros((Bt, H, P, N), jnp.float32)
    state, ys = jax.lax.scan(
        step, state0,
        (jnp.moveaxis(xf, 1, 0), jnp.moveaxis(dtf, 1, 0),
         jnp.moveaxis(Bf, 1, 0), jnp.moveaxis(Cf, 1, 0)))
    y = jnp.moveaxis(ys, 0, 1).astype(x.dtype)          # (Bt, S, H, P)
    return y, state
