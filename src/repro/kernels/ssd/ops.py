"""jit'd public wrapper for the SSD scan kernel: layout, padding, fallback.

Model convention (models/mamba2.py): x (Bt, S, H, P), dt (Bt, S, H),
B/C (Bt, S, N), A (H,).  Kernel convention: head-major rows (Bt*H, S, P).
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.kernels.ssd.kernel import ssd_scan_grid


def _round_up(x: int, m: int) -> int:
    return (x + m - 1) // m * m


def ssd_scan(x: jax.Array, dt: jax.Array, B: jax.Array, C: jax.Array,
             A: jax.Array, chunk: int = 256,
             interpret: Optional[bool] = None
             ) -> Tuple[jax.Array, jax.Array]:
    """Returns (y (Bt, S, H, P), final_state (Bt, H, P, N) f32)."""
    Bt, S, H, P = x.shape
    N = B.shape[-1]
    if interpret is None:
        interpret = jax.default_backend() == "cpu"
    q = min(chunk, S)

    S_pad = _round_up(S, q)
    pad = S_pad - S
    if pad:
        # dt = 0 on padded steps: no decay, no state injection
        x = jnp.pad(x, ((0, 0), (0, pad), (0, 0), (0, 0)))
        dt = jnp.pad(dt, ((0, 0), (0, pad), (0, 0)))
        B = jnp.pad(B, ((0, 0), (0, pad), (0, 0)))
        C = jnp.pad(C, ((0, 0), (0, pad), (0, 0)))

    xk = jnp.swapaxes(x, 1, 2).reshape(Bt * H, S_pad, P)
    dtk = jnp.swapaxes(dt, 1, 2).reshape(Bt * H, 1, S_pad)
    Ak = A.reshape(H, 1, 1).astype(jnp.float32)

    y, state = ssd_scan_grid(xk, dtk, B, C, Ak, h=H, q=q,
                             interpret=interpret)
    y = jnp.swapaxes(y.reshape(Bt, H, S_pad, P), 1, 2)[:, :S]
    return y, state.reshape(Bt, H, P, N)
