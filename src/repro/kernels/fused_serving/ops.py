"""Public wrappers for weighted attention (the fused serving step's core).

Two implementations with one contract (inference-only — the serving path
never differentiates through these):

  weighted_attention_xla   the XLA twin the CPU serving path runs: a
                           *no-shift* clamped exponential with deferred
                           normalization.  Skipping the row-max pass and
                           normalizing once after the value matmul is
                           measurably faster on CPU than jax.nn.softmax
                           and exact while scores stay below the clamp
                           (trivially true at inference scale; beyond it
                           the path is tolerance-gated anyway).
  weighted_attention       the Pallas kernel (online max-shifted softmax,
                           numerically safe at any score magnitude) for
                           TPU — interpret mode on CPU, mirroring
                           kernels/flash_attention/ops.py.

Layout convention matches flash_attention: (B, S, H, D) in/out, weights
(B, Skv) f32.  A zero weight excludes the key; a query row whose keys all
carry zero weight outputs zeros (never NaN).
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.kernels.fused_serving.kernel import weighted_attention_bhsd

# exp(80) ~ 5.5e34: far above any inference-time score, far below f32
# overflow even summed over thousands of keys
SCORE_CLAMP = 80.0
_TINY = 1e-30


def _round_up(x: int, m: int) -> int:
    return (x + m - 1) // m * m


def _pick_blocks(sq: int, skv: int) -> tuple:
    bq = min(128, _round_up(sq, 16))
    bk = min(128, _round_up(skv, 16))
    return bq, bk


def weighted_attention_xla(q: jax.Array, k: jax.Array, v: jax.Array,
                           kv_weight: jax.Array) -> jax.Array:
    """q: (B, Sq, H, D); k/v: (B, Skv, H, D); kv_weight: (B, Skv) f32.

    No-shift clamped exponential, f32 scores/accumulation, one deferred
    normalization after the value matmul.  Returns (B, Sq, H, D) in
    q.dtype.
    """
    D = q.shape[-1]
    s = jnp.einsum("bqhd,bkhd->bhqk", q, k,
                   preferred_element_type=jnp.float32)
    s = s * (1.0 / jnp.sqrt(jnp.float32(D)))
    e = jnp.exp(jnp.minimum(s, SCORE_CLAMP))
    e = e * kv_weight[:, None, None, :].astype(jnp.float32)
    o = jnp.einsum("bhqk,bkhd->bqhd", e, v,
                   preferred_element_type=jnp.float32)
    den = jnp.maximum(e.sum(-1), _TINY)                  # (B, H, Sq)
    o = o / jnp.swapaxes(den, 1, 2)[..., None]
    return o.astype(q.dtype)


def weighted_attention(q: jax.Array, k: jax.Array, v: jax.Array,
                       kv_weight: jax.Array, *, impl: str = "chunked",
                       block_q: int = 0, block_k: int = 0,
                       interpret: Optional[bool] = None) -> jax.Array:
    """Dispatch by attention impl: ``"pallas"`` runs the weighted flash
    kernel (interpret mode on CPU), anything else the XLA twin."""
    if impl != "pallas":
        return weighted_attention_xla(q, k, v, kv_weight)
    B, Sq, H, D = q.shape
    Skv = k.shape[1]
    if interpret is None:
        interpret = jax.default_backend() == "cpu"
    bq, bk = _pick_blocks(Sq, Skv)
    block_q = block_q or bq
    block_k = block_k or bk
    Sq_pad = _round_up(Sq, block_q)
    Skv_pad = _round_up(Skv, block_k)

    def to_bhsd(x, s_pad):
        x = jnp.swapaxes(x, 1, 2)                        # (B, H, S, D)
        if s_pad != x.shape[2]:
            x = jnp.pad(x, ((0, 0), (0, 0), (0, s_pad - x.shape[2]),
                            (0, 0)))
        return x.reshape(B * H, s_pad, D)

    qb = to_bhsd(q, Sq_pad)
    kb = to_bhsd(k, Skv_pad)
    vb = to_bhsd(v, Skv_pad)

    w = kv_weight.astype(jnp.float32)
    if Skv_pad != Skv:
        w = jnp.pad(w, ((0, 0), (0, Skv_pad - Skv)))     # pad keys weigh 0
    w = jnp.broadcast_to(w[:, None, None, :], (B, H, 1, Skv_pad)) \
        .reshape(B * H, 1, Skv_pad)

    o = weighted_attention_bhsd(
        qb, kb, vb, w, sq=Sq, skv=Skv, block_q=block_q, block_k=block_k,
        interpret=interpret)
    o = o.reshape(B, H, Sq_pad, D)[:, :, :Sq]
    return jnp.swapaxes(o, 1, 2)
