"""Weighted-attention kernel for the fused block-encoder serving step.

``ops.weighted_attention`` generalizes the flash-attention kernel's
kv-mask to a per-key multiplicity *weight*: attention over a context
row that appears c times equals attention over one copy carrying weight
c.  The fused serving path (``predictor.forward_cached_fused``) uses it
to run the block encoder over the ~64-128 *unique* context tokens of a
clip instead of all M=360 rows — the dedup trick that makes the fused
step a >2x predict win rather than a ~1.2x fusion win.
"""
from repro.kernels.fused_serving.ops import (weighted_attention,
                                             weighted_attention_xla)

__all__ = ["weighted_attention", "weighted_attention_xla"]
