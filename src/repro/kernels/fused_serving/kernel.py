"""Pallas weighted-attention kernel (online softmax over weighted keys).

The block encoder is permutation-equivariant over its context stream (no
positional encoding is added to context rows), so attention over a key
that occurs c times in the row equals attention over ONE copy of that key
whose exponentiated score is multiplied by c:

    softmax_j(s)·V  ==  Σ_u c_u·exp(s_u)·V_u / Σ_u c_u·exp(s_u)

This kernel is the flash-attention kernel's recurrence with the binary
kv-validity mask generalized to a per-key f32 weight w (w = 0 recovers
masking, w = 1 recovers plain attention, w = c is the dedup multiplicity).
The running max / normalizer / accumulator scratch scheme is identical to
``kernels/flash_attention/kernel.py`` — the weight multiplies p after the
max-shifted exponential, so the shift cancels in the final division and
the result is exact (up to fp reassociation) regardless of weights.

A query row whose keys all carry zero weight (a fully-padded drain row)
ends with normalizer l == 0 and outputs zeros instead of NaN.
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

NEG_INF = -1e30


def _wa_kernel(q_ref, k_ref, v_ref, w_ref, o_ref, m_scr, l_scr, acc_scr,
               *, scale: float, sq: int, skv: int, block_q: int,
               block_k: int):
    """One (head, q-block, kv-block) grid step.

    q_ref: (block_q, D); k_ref/v_ref: (block_k, D); w_ref: (1, block_k)
    per-key weight; o_ref: (block_q, D).  Scratch: m/l (block_q, 1) f32,
    acc (block_q, D) f32.
    """
    kv_idx = pl.program_id(2)
    q_idx = pl.program_id(1)

    @pl.when(kv_idx == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    q = q_ref[...]
    k = k_ref[...]
    s = jax.lax.dot_general(
        q, k, (((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32) * scale      # (bq, bk)

    qpos = q_idx * block_q + jax.lax.broadcasted_iota(
        jnp.int32, (block_q, block_k), 0)
    kpos = kv_idx * block_k + jax.lax.broadcasted_iota(
        jnp.int32, (block_q, block_k), 1)
    mask = (qpos < sq) & (kpos < skv)
    s = jnp.where(mask, s, NEG_INF)

    m_prev = m_scr[...]                                  # (bq, 1)
    m_cur = jnp.max(s, axis=1, keepdims=True)
    m_new = jnp.maximum(m_prev, m_cur)
    alpha = jnp.exp(m_prev - m_new)
    p = jnp.exp(s - m_new)                               # (bq, bk)
    # the weight multiplies the shifted exponential: multiplicity for
    # deduped keys, 0 for masked/padded keys (which also kills any
    # residual exp(NEG_INF - m) underflow noise)
    p = p * w_ref[0, :][None, :]
    p = jnp.where(mask, p, 0.0)

    l_scr[...] = l_scr[...] * alpha + jnp.sum(p, axis=1, keepdims=True)
    acc_scr[...] = acc_scr[...] * alpha + jax.lax.dot_general(
        p.astype(v_ref.dtype), v_ref[...], (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)
    m_scr[...] = m_new

    @pl.when(kv_idx == pl.num_programs(2) - 1)
    def _finish():
        l = l_scr[...]
        o_ref[...] = (acc_scr[...] /
                      jnp.where(l == 0.0, 1.0, l)).astype(o_ref.dtype)


@functools.partial(
    jax.jit,
    static_argnames=("sq", "skv", "block_q", "block_k", "interpret"))
def weighted_attention_bhsd(q, k, v, kv_weight, *, sq: int, skv: int,
                            block_q: int, block_k: int, interpret: bool):
    """q: (BH, Sq_pad, D); k/v: (BH, Skv_pad, D); kv_weight:
    (BH, Skv_pad) f32.  Shapes already padded to block multiples (weights
    zero-padded); sq/skv are the true lengths.
    """
    BH, Sq_pad, D = q.shape
    Skv_pad = k.shape[1]
    n_q = Sq_pad // block_q
    n_k = Skv_pad // block_k
    scale = 1.0 / math.sqrt(D)

    kernel = functools.partial(
        _wa_kernel, scale=scale, sq=sq, skv=skv, block_q=block_q,
        block_k=block_k)

    return pl.pallas_call(
        kernel,
        grid=(BH, n_q, n_k),
        in_specs=[
            pl.BlockSpec((None, block_q, D), lambda b, i, j: (b, i, 0)),
            pl.BlockSpec((None, block_k, D), lambda b, i, j: (b, j, 0)),
            pl.BlockSpec((None, block_k, D), lambda b, i, j: (b, j, 0)),
            pl.BlockSpec((None, 1, block_k), lambda b, i, j: (b, 0, j)),
        ],
        out_specs=pl.BlockSpec((None, block_q, D), lambda b, i, j: (b, i, 0)),
        out_shape=jax.ShapeDtypeStruct((BH, Sq_pad, D), q.dtype),
        scratch_shapes=[
            _vmem((block_q, 1)),      # running max
            _vmem((block_q, 1)),      # running normalizer
            _vmem((block_q, D)),      # weighted-value accumulator
        ],
        interpret=interpret,
    )(q, k, v, kv_weight)


def _vmem(shape):
    from jax.experimental.pallas import tpu as pltpu
    return pltpu.VMEM(shape, jnp.float32)
