"""jit'd public wrapper for the flash-attention kernel.

Handles layout ((B, S, H, D) model convention -> (B*H, S, D) kernel
convention), block-size selection, padding to block multiples, kv-mask
plumbing, and the CPU fallback (interpret mode executes the kernel body in
Python — used by every correctness test in tests/test_kernels.py).
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp

from repro.kernels.flash_attention.kernel import flash_attention_bhsd


def _round_up(x: int, m: int) -> int:
    return (x + m - 1) // m * m


def _pick_blocks(sq: int, skv: int) -> tuple:
    """(block_q, block_k): MXU-aligned 128 tiles, shrunk for short seqs
    (the instruction encoder's L_token=16 shouldn't pad 8x)."""
    bq = min(128, _round_up(sq, 16))
    bk = min(128, _round_up(skv, 16))
    return bq, bk


def flash_attention(q: jax.Array, k: jax.Array, v: jax.Array, *,
                    causal: bool = False, window: int = 0,
                    kv_mask: Optional[jax.Array] = None,
                    block_q: int = 0, block_k: int = 0,
                    interpret: Optional[bool] = None) -> jax.Array:
    """q: (B, Sq, H, D); k/v: (B, Skv, H, D) (kv heads already broadcast);
    kv_mask: (B, Skv), 1 = valid.  Returns (B, Sq, H, D).

    Differentiable: the forward runs the Pallas kernel; the backward is a
    custom_vjp through the pure-jnp reference (recompute — flash-style
    no-residual autodiff).  A dedicated backward kernel is a possible next
    step; training on this host uses the chunked XLA path anyway.
    """
    B, Sq, H, D = q.shape
    Skv = k.shape[1]
    if interpret is None:
        interpret = jax.default_backend() == "cpu"
    bq, bk = _pick_blocks(Sq, Skv)
    block_q = block_q or bq
    block_k = block_k or bk
    if kv_mask is None:
        kv_mask = jnp.ones((B, Skv), jnp.float32)
    return _fa(q, k, v, kv_mask.astype(jnp.float32), causal, window,
               block_q, block_k, interpret)


@functools.partial(jax.custom_vjp, nondiff_argnums=(4, 5, 6, 7, 8))
def _fa(q, k, v, kv_mask, causal, window, block_q, block_k, interpret):
    return _fa_impl(q, k, v, kv_mask, causal, window, block_q, block_k,
                    interpret)


def _fa_fwd(q, k, v, kv_mask, causal, window, block_q, block_k, interpret):
    out = _fa_impl(q, k, v, kv_mask, causal, window, block_q, block_k,
                   interpret)
    return out, (q, k, v, kv_mask)


def _fa_bwd(causal, window, block_q, block_k, interpret, res, g):
    from repro.kernels.flash_attention.ref import attention_ref
    q, k, v, kv_mask = res
    _, vjp = jax.vjp(
        lambda q_, k_, v_: attention_ref(q_, k_, v_, causal=causal,
                                         window=window, kv_mask=kv_mask),
        q, k, v)
    dq, dk, dv = vjp(g)
    return dq, dk, dv, jnp.zeros_like(kv_mask)


_fa.defvjp(_fa_fwd, _fa_bwd)


def _fa_impl(q, k, v, kv_mask, causal, window, block_q, block_k,
             interpret):
    B, Sq, H, D = q.shape
    Skv = k.shape[1]
    Sq_pad = _round_up(Sq, block_q)
    Skv_pad = _round_up(Skv, block_k)

    def to_bhsd(x, s_pad):
        x = jnp.swapaxes(x, 1, 2)                       # (B, H, S, D)
        if s_pad != x.shape[2]:
            x = jnp.pad(x, ((0, 0), (0, 0), (0, s_pad - x.shape[2]), (0, 0)))
        return x.reshape(B * H, s_pad, D)

    qb = to_bhsd(q, Sq_pad)
    kb = to_bhsd(k, Skv_pad)
    vb = to_bhsd(v, Skv_pad)

    m = kv_mask
    if Skv_pad != Skv:
        m = jnp.pad(m, ((0, 0), (0, Skv_pad - Skv)))
    m = jnp.broadcast_to(m[:, None, None, :], (B, H, 1, Skv_pad)) \
        .reshape(B * H, 1, Skv_pad)

    o = flash_attention_bhsd(
        qb, kb, vb, m, causal=causal, window=window, sq=Sq, skv=Skv,
        block_q=block_q, block_k=block_k, interpret=interpret)

    o = o.reshape(B, H, Sq_pad, D)[:, :, :Sq]
    return jnp.swapaxes(o, 1, 2)
