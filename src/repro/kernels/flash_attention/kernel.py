"""Pallas TPU flash-attention kernel (online softmax, VMEM-tiled).

Grid = (B*H, Sq/block_q, Skv/block_k) with the kv axis innermost and
sequential ("arbitrary"): per (head, q-block) the kernel streams kv blocks
through VMEM, maintaining the running max / normalizer / weighted
accumulator in scratch (the Flash-Attention-2 recurrence), and writes the
normalized output tile once on the last kv step.

TPU adaptation notes (vs the CUDA original):
  - tiles are (block_q x head_dim) / (block_k x head_dim) with head_dim on
    the 128-wide lane axis and block sizes multiples of the 8-sublane f32
    tile; the two matmuls per step hit the MXU at (128 x D x 128).
  - there is no warp-level shuffle: the online-softmax reduction happens in
    VREGs over lanes, which is exactly what jnp.max/sum lower to.
  - masks (causal / sliding-window / kv-validity) are computed from iota on
    the fly — no (Sq, Skv) mask tensor ever exists in HBM.
  - the same kernel body serves self-attention (LM zoo, instruction
    encoder) and cross-attention (block encoder: context rows query
    instruction vectors) — cross is just causal=False with Sq != Skv.
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

NEG_INF = -1e30


def _fa_kernel(q_ref, k_ref, v_ref, kvm_ref, o_ref, m_scr, l_scr, acc_scr,
               *, scale: float, causal: bool, window: int, sq: int,
               skv: int, block_q: int, block_k: int, q_offset: int):
    """One (head, q-block, kv-block) grid step.

    q_ref: (block_q, D); k_ref/v_ref: (block_k, D); kvm_ref: (1, block_k)
    validity; o_ref: (block_q, D).  Scratch: m/l (block_q, 1) f32,
    acc (block_q, D) f32.
    """
    kv_idx = pl.program_id(2)
    q_idx = pl.program_id(1)

    @pl.when(kv_idx == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    q = q_ref[...]
    k = k_ref[...]
    s = jax.lax.dot_general(
        q, k, (((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32) * scale      # (bq, bk)

    # positions (q aligned to the END of kv, decode-style, via q_offset)
    qpos = q_idx * block_q + jax.lax.broadcasted_iota(
        jnp.int32, (block_q, block_k), 0) + q_offset
    kpos = kv_idx * block_k + jax.lax.broadcasted_iota(
        jnp.int32, (block_q, block_k), 1)
    mask = (qpos < sq + q_offset) & (kpos < skv)
    if causal:
        mask &= qpos >= kpos
        if window > 0:
            mask &= qpos - kpos < window
    mask &= kvm_ref[0, :][None, :] > 0
    s = jnp.where(mask, s, NEG_INF)

    m_prev = m_scr[...]                                  # (bq, 1)
    m_cur = jnp.max(s, axis=1, keepdims=True)
    m_new = jnp.maximum(m_prev, m_cur)
    alpha = jnp.exp(m_prev - m_new)
    p = jnp.exp(s - m_new)                               # (bq, bk)
    p = jnp.where(mask, p, 0.0)

    l_scr[...] = l_scr[...] * alpha + jnp.sum(p, axis=1, keepdims=True)
    acc_scr[...] = acc_scr[...] * alpha + jax.lax.dot_general(
        p.astype(v_ref.dtype), v_ref[...], (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)
    m_scr[...] = m_new

    @pl.when(kv_idx == pl.num_programs(2) - 1)
    def _finish():
        l = l_scr[...]
        o_ref[...] = (acc_scr[...] /
                      jnp.where(l == 0.0, 1.0, l)).astype(o_ref.dtype)


@functools.partial(
    jax.jit,
    static_argnames=("causal", "window", "sq", "skv", "block_q", "block_k",
                     "interpret"))
def flash_attention_bhsd(q, k, v, kv_mask, *, causal: bool, window: int,
                         sq: int, skv: int, block_q: int, block_k: int,
                         interpret: bool):
    """q: (BH, Sq_pad, D); k/v: (BH, Skv_pad, D); kv_mask: (BH, Skv_pad).

    Shapes already padded to block multiples; sq/skv are the true lengths.
    """
    BH, Sq_pad, D = q.shape
    Skv_pad = k.shape[1]
    n_q = Sq_pad // block_q
    n_k = Skv_pad // block_k
    scale = 1.0 / math.sqrt(D)
    q_offset = skv - sq                     # align q to the end of kv

    kernel = functools.partial(
        _fa_kernel, scale=scale, causal=causal, window=window, sq=sq,
        skv=skv, block_q=block_q, block_k=block_k, q_offset=q_offset)

    return pl.pallas_call(
        kernel,
        grid=(BH, n_q, n_k),
        in_specs=[
            pl.BlockSpec((None, block_q, D), lambda b, i, j: (b, i, 0)),
            pl.BlockSpec((None, block_k, D), lambda b, i, j: (b, j, 0)),
            pl.BlockSpec((None, block_k, D), lambda b, i, j: (b, j, 0)),
            pl.BlockSpec((None, 1, block_k), lambda b, i, j: (b, 0, j)),
        ],
        out_specs=pl.BlockSpec((None, block_q, D), lambda b, i, j: (b, i, 0)),
        out_shape=jax.ShapeDtypeStruct((BH, Sq_pad, D), q.dtype),
        scratch_shapes=[
            _vmem((block_q, 1)),      # running max
            _vmem((block_q, 1)),      # running normalizer
            _vmem((block_q, D)),      # weighted-value accumulator
        ],
        interpret=interpret,
    )(q, k, v, kv_mask)


def _vmem(shape):
    from jax.experimental.pallas import tpu as pltpu
    return pltpu.VMEM(shape, jnp.float32)
