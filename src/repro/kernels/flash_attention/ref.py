"""Pure-jnp oracle for the flash-attention kernel.

Materializes the full (Sq, Skv) score matrix with fp32 softmax — the
mathematically obvious implementation the Pallas kernel must match.
"""
from __future__ import annotations

import math
from typing import Optional

import jax
import jax.numpy as jnp

NEG_INF = float("-inf")


def attention_ref(q: jax.Array, k: jax.Array, v: jax.Array, *,
                  causal: bool = False, window: int = 0,
                  kv_mask: Optional[jax.Array] = None) -> jax.Array:
    """q: (B, Sq, H, D); k/v: (B, Skv, H, D); kv_mask: (B, Skv) 1=valid.

    window > 0 limits causal attention to the last ``window`` positions.
    Returns (B, Sq, H, D) in q.dtype.
    """
    B, Sq, H, D = q.shape
    Skv = k.shape[1]
    s = jnp.einsum("bqhd,bkhd->bhqk", q.astype(jnp.float32),
                   k.astype(jnp.float32)) / math.sqrt(D)
    qpos = jnp.arange(Sq)[:, None] + (Skv - Sq)   # align ends (decode-style)
    kpos = jnp.arange(Skv)[None, :]
    mask = jnp.ones((Sq, Skv), bool)
    if causal:
        mask &= qpos >= kpos
        if window > 0:
            mask &= qpos - kpos < window
    m = mask[None, None]
    if kv_mask is not None:
        m = m & (kv_mask[:, None, None, :] > 0)
    s = jnp.where(m, s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    # fully-masked rows produce NaN in softmax; zero them like the kernel
    p = jnp.where(jnp.any(m, -1, keepdims=True), p, 0.0)
    return jnp.einsum("bhqk,bkhd->bqhd", p, v.astype(jnp.float32)
                      ).astype(q.dtype)
