"""CAPSim attention-based performance predictor (paper §III/§V, Fig 4).

Two-level architecture, exactly Eq 5-9:

  instruction encoder   4 pre-LN transformer layers of self-attention over
                        each instruction's standardized tokens (L_token, E);
                        the <REP> position's output is the instruction's
                        ideal-execution-time vector RT_i (Eq 5-8).  All
                        (B, L_clip) instructions run as one folded batch —
                        the clip-level parallelism that is the paper's speed
                        story, and on TPU one Pallas flash-attention grid.
  block encoder         sinusoidal positional encoding over the clip
                        sequence, then 4 layers in which the *context matrix*
                        (register-state rows, §V-B) self-attends and
                        cross-attends into the stacked instruction vectors
                        (Eq 9) — the learnable T_total = Σ t_i·α_i
                        factorization of Eq 3-4.
  head                  MLP -> per-row scalar -> arithmetic mean.  The mean
                        is passed through softplus and scaled by the clip's
                        instruction count, i.e. the head predicts
                        cycles-per-instruction; positivity + the length prior
                        stabilize MAPE training without changing the
                        architecture.

Loss = MAPE (Eq 11).  The no-context ablation (Fig 10) drops the context
stream: the block encoder then self-attends over the instruction vectors and
the head averages over instruction positions instead.

Sharding: the model is ~2M params — weights replicate; the batch axis shards
over EVERY mesh axis (pod, data, model): clips are i.i.d. so a 512-chip pod
group is pure clip-parallelism.  See LOGICAL_RULES_PREDICTOR.
"""
from __future__ import annotations

import math
import time
from typing import Optional

import jax
import jax.numpy as jnp

from repro.distributed.sharding import shard_logical
from repro.models.layers import (
    ParamSpec, abstract_from_specs, dense_spec, init_from_specs, rms_norm,
    shardings_from_specs, specs_with_leading_stack)

NEG_INF = -1e30


# --------------------------------------------------------------------------- #
# Param specs
# --------------------------------------------------------------------------- #

def _mha_specs(cfg, prefix: str = "") -> dict:
    E = cfg.d_model
    H, Dh = cfg.num_heads, cfg.head_dim
    return {
        f"{prefix}wq": dense_spec(E, H * Dh, ("embed", "qkv")),
        f"{prefix}wk": dense_spec(E, H * Dh, ("embed", "qkv")),
        f"{prefix}wv": dense_spec(E, H * Dh, ("embed", "qkv")),
        f"{prefix}wo": dense_spec(H * Dh, E, ("qkv", "embed")),
    }


def _ffn_specs(cfg) -> dict:
    E, F = cfg.d_model, cfg.d_ff
    return {"w1": dense_spec(E, F, ("embed", "mlp")),
            "w2": dense_spec(F, E, ("mlp", "embed"))}


def _norm_spec(cfg) -> ParamSpec:
    return ParamSpec((cfg.d_model,), ("embed",), std=0.0, dtype="float32")


def _encoder_layer_specs(cfg) -> dict:
    return {**_mha_specs(cfg), **_ffn_specs(cfg),
            "norm1": _norm_spec(cfg), "norm2": _norm_spec(cfg)}


def _block_layer_specs(cfg) -> dict:
    return {**_mha_specs(cfg, "self_"), **_mha_specs(cfg, "cross_"),
            **_ffn_specs(cfg),
            "norm1": _norm_spec(cfg), "norm2": _norm_spec(cfg),
            "norm3": _norm_spec(cfg)}


N_INST_LAYERS = 4
N_BLOCK_LAYERS = 4


def model_specs(cfg) -> dict:
    E, V = cfg.d_model, cfg.vocab_size
    return {
        "embed": ParamSpec((V, E), ("vocab_in", "embed"),
                           std=1.0 / math.sqrt(E)),
        "inst": specs_with_leading_stack(_encoder_layer_specs(cfg),
                                         N_INST_LAYERS),
        "block": specs_with_leading_stack(_block_layer_specs(cfg),
                                          N_BLOCK_LAYERS),
        "final_norm": _norm_spec(cfg),
        "head": {"w1": dense_spec(E, E, ("embed", "mlp")),
                 "b1": ParamSpec((E,), ("mlp",), std=0.0),
                 "w2": dense_spec(E, 1, ("mlp", None)),
                 "b2": ParamSpec((1,), (None,), std=0.0)},
    }


def init_params(cfg, key):
    return init_from_specs(model_specs(cfg), key, cfg.param_dtype)


def abstract_params(cfg):
    return abstract_from_specs(model_specs(cfg), cfg.param_dtype)


def param_shardings(cfg, mesh, rules):
    return shardings_from_specs(model_specs(cfg), mesh, rules)


# --------------------------------------------------------------------------- #
# Attention primitives
# --------------------------------------------------------------------------- #

def _heads(x, cfg):
    B, S, _ = x.shape
    return x.reshape(B, S, cfg.num_heads, cfg.head_dim)


def _w(p, name, cfg):
    """fp32 master params compute in cfg.dtype (mixed precision): without
    this cast every matmul output promotes to f32 and the backward saves
    f32 activations — 2x the HBM traffic and scan-residual memory (§Perf
    capsim iteration v2)."""
    return p[name].astype(cfg.dtype)


def _mha(p, q_in, kv_in, cfg, kv_mask=None, prefix: str = ""):
    """q_in: (B, Sq, E); kv_in: (B, Sk, E); kv_mask: (B, Sk) 1=valid."""
    q = _heads(jnp.einsum("bsd,dh->bsh", q_in, _w(p, f"{prefix}wq", cfg)),
               cfg)
    k = _heads(jnp.einsum("bsd,dh->bsh", kv_in, _w(p, f"{prefix}wk", cfg)),
               cfg)
    v = _heads(jnp.einsum("bsd,dh->bsh", kv_in, _w(p, f"{prefix}wv", cfg)),
               cfg)
    if cfg.attn_impl == "pallas":
        from repro.kernels.flash_attention import ops as fa_ops
        o = fa_ops.flash_attention(q, k, v, causal=False, kv_mask=kv_mask)
    else:
        s = jnp.einsum("bqhd,bkhd->bhqk", q, k,
                       preferred_element_type=jnp.float32)
        s = s / math.sqrt(cfg.head_dim)
        if kv_mask is not None:
            s = jnp.where(kv_mask[:, None, None, :] > 0, s, NEG_INF)
        a = jax.nn.softmax(s, axis=-1).astype(v.dtype)
        # f32 accumulation for the output matmul too: in bf16 mode the
        # weights/values stay bf16 but partial sums do not round per-step
        # (identical bits in f32 mode, where this is already the dtype)
        o = jnp.einsum("bhqk,bkhd->bqhd", a, v,
                       preferred_element_type=jnp.float32).astype(v.dtype)
    o = o.reshape(q_in.shape[0], q_in.shape[1], -1)
    out = jnp.einsum("bsh,hd->bsd", o, _w(p, f"{prefix}wo", cfg))
    return out.astype(q_in.dtype)


def _ffn(p, x, cfg):
    h = jnp.einsum("bsd,df->bsf", x, _w(p, "w1", cfg))
    out = jnp.einsum("bsf,fd->bsd", jax.nn.gelu(h), _w(p, "w2", cfg))
    return out.astype(x.dtype)


def _scan_layers(layer_fn, stacked_params, x, *extra, remat: bool = False):
    def body(carry, lp):
        return layer_fn(lp, carry, *extra), None
    if remat:
        # recompute encoder layers in the backward: the scan then saves
        # only the layer carries instead of ~10 intermediates per layer
        # (§Perf capsim iteration v3); the predictor is memory-bound with
        # compute 30x below the HBM roof, so recompute is nearly free.
        body = jax.checkpoint(
            body, policy=jax.checkpoint_policies.nothing_saveable)
    y, _ = jax.lax.scan(body, x, stacked_params)
    return y


# --------------------------------------------------------------------------- #
# Forward
# --------------------------------------------------------------------------- #

def _sinusoidal(n: int, e: int, dtype) -> jax.Array:
    pos = jnp.arange(n, dtype=jnp.float32)[:, None]
    dim = jnp.arange(e // 2, dtype=jnp.float32)[None, :]
    ang = pos / jnp.power(10_000.0, 2.0 * dim / e)
    return jnp.concatenate([jnp.sin(ang), jnp.cos(ang)], -1).astype(dtype)


def instruction_encoder(params, clip_tokens, cfg):
    """clip_tokens: (B, L_clip, L_token) int32 -> RT vectors (B, L_clip, E).

    The (B, L_clip) axes fold into one batch: every instruction encodes
    independently (Eq 7), which is what the TPU grid parallelizes.
    """
    B, L, T = clip_tokens.shape
    tok_mask = (clip_tokens != 0).astype(jnp.float32)   # <PAD> == 0
    flat = clip_tokens.reshape(B * L, T)
    x = params["embed"][flat].astype(cfg.dtype)          # (B*L, T, E)
    x = shard_logical(x, "batch", None, None)
    mask = tok_mask.reshape(B * L, T)

    def layer(p, h, m):
        h = h + _mha(p, rms_norm(h, p["norm1"]), rms_norm(h, p["norm1"]),
                     cfg, kv_mask=m)
        h = h + _ffn(p, rms_norm(h, p["norm2"]), cfg)
        return h

    x = _scan_layers(layer, params["inst"], x, mask,
                     remat=cfg.remat)
    rt = x[:, 0, :]                                      # <REP> slot (Eq 8)
    return rt.reshape(B, L, cfg.d_model)


def block_encoder(params, rt, ctx, clip_mask, cfg):
    """rt: (B, L_clip, E) instruction vectors; ctx: (B, M, E) context rows.

    Context stream queries the instruction stream (Eq 9).  Without context
    (ablation) the instruction stream self-attends instead.
    """
    B, L, E = rt.shape
    rt = rt + _sinusoidal(L, E, rt.dtype)[None]

    if ctx is None:                                      # no-context ablation
        def layer(p, h, m):
            h = h + _mha(p, rms_norm(h, p["norm1"]), rms_norm(h, p["norm1"]),
                         cfg, kv_mask=m, prefix="self_")
            h = h + _mha(p, rms_norm(h, p["norm2"]), rt, cfg, kv_mask=m,
                         prefix="cross_")
            h = h + _ffn(p, rms_norm(h, p["norm3"]), cfg)
            return h
        out = _scan_layers(layer, params["block"], rt, clip_mask,
                           remat=cfg.remat)
        return out, clip_mask

    def layer(p, h, m):
        h = h + _mha(p, rms_norm(h, p["norm1"]), rms_norm(h, p["norm1"]),
                     cfg, prefix="self_")
        h = h + _mha(p, rms_norm(h, p["norm2"]), rt, cfg, kv_mask=m,
                     prefix="cross_")
        h = h + _ffn(p, rms_norm(h, p["norm3"]), cfg)
        return shard_logical(h, "batch", None, None)

    out = _scan_layers(layer, params["block"], ctx, clip_mask,
                       remat=cfg.remat)
    return out, None                                     # all M rows valid


def encode_instructions(params, token_rows, cfg):
    """Static half of the split forward: (N, L_token) int32 standardized
    rows -> (N, E) RT vectors (Eq 5-8).

    Standardization (and therefore RT_i) depends only on the *static*
    instruction, so a program's ``token_table`` needs exactly one pass
    through the 4-layer instruction encoder — the RT-cache build.  Rows
    encode independently, so the result is bitwise the rows the monolithic
    ``forward`` would compute inside a (B, L_clip) clip batch.
    """
    return instruction_encoder(params, token_rows[None], cfg)[0]


def block_forward(params, rt, batch, cfg, use_context: bool = True):
    """Dynamic half of the split forward: block encoder + head over
    already-encoded RT vectors.

    rt: (B, L_clip, E) instruction vectors (from ``instruction_encoder``
    or an RT-table gather); batch supplies context_tokens (B, M) and
    clip_mask (B, L_clip).  Returns predicted clip times (B,) in cycles.

    The context stream is width-agnostic: M may be the single-core
    register matrix (``context.CONTEXT_LEN``), the core-tagged multicore
    layout, or the peer-channel layout in which every other core's
    ``<CORE>``-tagged register block is appended — the block encoder's
    self-attention then mixes rows *across cores*, which is how the
    multicore-trained predictor learns to price LLC/bus interference
    from the peers' architectural state.  Width validation lives at the
    dataset-build and engine-dispatch boundaries
    (``context.validate_context_width``), not here, so ablations and
    synthetic-spec batches stay unconstrained.
    """
    clip_mask = batch["clip_mask"].astype(jnp.float32)
    rt = shard_logical(rt, "batch", None, None)

    ctx = None
    if use_context:
        ctx = params["embed"][batch["context_tokens"]].astype(cfg.dtype)
        ctx = shard_logical(ctx, "batch", None, None)
    out, out_mask = block_encoder(params, rt, ctx, clip_mask, cfg)
    out = shard_logical(out, "batch", None, None)

    h = rms_norm(out, params["final_norm"])
    hw = params["head"]
    h = jax.nn.gelu(jnp.einsum("bsd,df->bsf", h, hw["w1"].astype(cfg.dtype))
                    + hw["b1"].astype(cfg.dtype))
    y = (jnp.einsum("bsf,fo->bso", h, hw["w2"].astype(cfg.dtype))
         + hw["b2"].astype(cfg.dtype))[..., 0]           # (B, rows)
    y = y.astype(jnp.float32)
    if out_mask is None:
        cpi = jnp.mean(y, axis=-1)                       # arithmetic mean
    else:
        denom = jnp.maximum(out_mask.sum(-1), 1.0)
        cpi = (y * out_mask).sum(-1) / denom
    n_inst = jnp.maximum(clip_mask.sum(-1), 1.0)
    return jax.nn.softplus(cpi) * n_inst                 # cycles


def forward(params, batch, cfg, use_context: bool = True):
    """batch: clip_tokens (B,L,T), context_tokens (B,M), clip_mask (B,L).

    Returns predicted clip times (B,) in cycles.  Monolithic path: the
    instruction encoder runs over every dynamic clip row.  The serving
    engines use ``forward_cached`` instead, which replaces it with an
    RT-table gather.
    """
    rt = instruction_encoder(params, batch["clip_tokens"], cfg)
    return block_forward(params, rt, batch, cfg, use_context)


def forward_cached(params, rt_table, batch, cfg, use_context: bool = True):
    """RT-cache serving path: batch carries rt_idx (B, L_clip) int32 rows
    into ``rt_table`` ((C, E), from ``encode_instructions``) instead of
    clip_tokens.  Device FLOPs drop to block encoder + head only; in fp32
    the result is bitwise equal to ``forward`` on the gathered tokens.
    """
    rt = rt_table[batch["rt_idx"]]                       # (B, L_clip, E)
    return block_forward(params, rt, batch, cfg, use_context)


# --------------------------------------------------------------------------- #
# Fused serving step (EngineConfig.fused_serving)
# --------------------------------------------------------------------------- #
#
# Two exact identities collapse the per-batch work of ``forward_cached``:
#
# 1. Cross-attention K/V are linear in the kv input, and the kv input is
#    rt_table[rt_idx] + posenc — so per layer
#        K = (table @ cross_wk)[rt_idx] + (posenc @ cross_wk)
#    and ``serving_plan`` precomputes (table @ cross_wk/wv) ONCE per table
#    version.  The per-batch cost of the (B, L, E) rt gather, the posenc
#    add, and all 8 cross K/V projections drops to a (B, L, H·Dh) gather.
#
# 2. The block encoder adds no positional encoding to the context stream,
#    so it is permutation-equivariant over context rows: self-attention
#    over the M=360 context tokens equals *weighted* attention over the
#    ~64-128 unique tokens with multiplicity weights, and the head's
#    arithmetic mean equals the count-weighted mean (Σ c_u·y_u / M).  The
#    host dedupes each row (``standardize.dedupe_context_tokens``, ~2 ms
#    per batch) and the device runs the whole block stack at U instead of
#    M rows — a >5x serving win at full scale, exact up to fp
#    reassociation.

def serving_plan(params, rt_table, cfg):
    """Per-table-version precompute for ``forward_cached_fused``: the
    cross-attention K/V projections of every RT row, (L_layers, N, H·Dh).
    Rebuild whenever the RT table grows (the engine keys on table
    identity); ~ms at full scale."""
    dt = cfg.dtype
    table = rt_table.astype(dt)
    blk = params["block"]
    return {
        "cross_kt": jnp.einsum("ne,led->lnd", table,
                               blk["cross_wk"].astype(dt)),
        "cross_vt": jnp.einsum("ne,led->lnd", table,
                               blk["cross_wv"].astype(dt)),
    }


def _weighted_mha(q, k, v, weight, cfg):
    """Multi-head weighted attention over already-projected q/k/v
    ((B, S, H·Dh)); weight (B, Skv) f32 multiplicities."""
    from repro.kernels.fused_serving import ops as wa_ops
    B, Sq = q.shape[0], q.shape[1]
    o = wa_ops.weighted_attention(_heads(q, cfg), _heads(k, cfg),
                                  _heads(v, cfg), weight,
                                  impl=cfg.attn_impl)
    return o.reshape(B, Sq, -1)


def forward_cached_fused(params, plan, batch, cfg):
    """Fused serving twin of ``forward_cached`` (context path only).

    batch carries rt_idx (B, L_clip) int32, ctx_uniq (B, U) int32 deduped
    context token ids, ctx_count (B, U) f32 multiplicities (summing to M
    per row), clip_mask (B, L_clip).  ``plan`` is ``serving_plan`` for the
    current rt_table.  Returns predicted clip times (B,) in cycles, equal
    to ``forward_cached`` on the un-deduped batch up to fp reassociation
    (gated ≤1e-3 rel err; measured ~4e-7 at full scale).
    """
    idx = batch["rt_idx"]
    cw = batch["ctx_count"].astype(jnp.float32)
    clip_mask = batch["clip_mask"].astype(jnp.float32)
    L = idx.shape[1]
    dt = cfg.dtype
    blk = params["block"]

    pos = _sinusoidal(L, cfg.d_model, dt)
    pk = jnp.einsum("je,led->ljd", pos, blk["cross_wk"].astype(dt))
    pv = jnp.einsum("je,led->ljd", pos, blk["cross_wv"].astype(dt))
    k_all = plan["cross_kt"][:, idx] + pk[:, None]       # (Lyr, B, L, HDh)
    v_all = plan["cross_vt"][:, idx] + pv[:, None]
    wqkv = jnp.concatenate(
        [blk["self_wq"], blk["self_wk"], blk["self_wv"]],
        axis=-1).astype(dt)                              # (Lyr, E, 3·HDh)

    h = params["embed"][batch["ctx_uniq"]].astype(dt)    # (B, U, E)

    def layer(carry, xs):
        lp, wqkv_l, k_l, v_l = xs
        h = carry
        qkv = jnp.einsum("bud,dh->buh", rms_norm(h, lp["norm1"]), wqkv_l)
        q, sk, sv = jnp.split(qkv, 3, axis=-1)
        o = _weighted_mha(q, sk, sv, cw, cfg)
        h = h + jnp.einsum("buh,hd->bud", o,
                           _w(lp, "self_wo", cfg)).astype(h.dtype)
        q2 = jnp.einsum("bud,dh->buh", rms_norm(h, lp["norm2"]),
                        _w(lp, "cross_wq", cfg))
        o2 = _weighted_mha(q2, k_l, v_l, clip_mask, cfg)
        h = h + jnp.einsum("buh,hd->bud", o2,
                           _w(lp, "cross_wo", cfg)).astype(h.dtype)
        h = h + _ffn(lp, rms_norm(h, lp["norm3"]), cfg)
        return shard_logical(h, "batch", None, None), None

    h, _ = jax.lax.scan(layer, h, (blk, wqkv, k_all, v_all))

    h = rms_norm(h, params["final_norm"])
    hw = params["head"]
    h = jax.nn.gelu(jnp.einsum("bud,df->buf", h, hw["w1"].astype(dt))
                    + hw["b1"].astype(dt))
    y = (jnp.einsum("buf,fo->buo", h, hw["w2"].astype(dt))
         + hw["b2"].astype(dt))[..., 0]
    y = y.astype(jnp.float32)
    # head mean over the M context rows == count-weighted mean over uniques
    cpi = (y * cw).sum(-1) / jnp.maximum(cw.sum(-1), 1.0)
    n_inst = jnp.maximum(clip_mask.sum(-1), 1.0)
    return jax.nn.softplus(cpi) * n_inst


# --------------------------------------------------------------------------- #
# Multi-device sharded inference (EngineConfig.mesh_shape)
# --------------------------------------------------------------------------- #
#
# Clips (and static RT rows) are row-independent, so data-parallel
# sharding over a 1-D "data" mesh is bitwise equal to the single-device
# dispatch of the same batch: each shard computes exactly the rows it
# would compute inside the full batch, and the demux concatenates
# per-shard outputs in row order.  Params and the RT table replicate
# (P() specs) — the model is ~2M params, so replication is free and the
# only cross-device traffic is the batch scatter / result gather.

def _batch_shard_specs(mesh, token_key: str):
    from jax.sharding import PartitionSpec as P
    data = P(mesh.axis_names[0])
    return {token_key: data, "context_tokens": data, "clip_mask": data}


def sharded_predict_step(cfg, use_context: bool, mesh):
    """``predict_step`` shard_mapped over the batch axis of ``mesh``
    (monolithic path: batch carries clip_tokens)."""
    from jax.sharding import PartitionSpec as P

    from repro.distributed.sharding import compat_shard_map
    return compat_shard_map(
        lambda p, b: predict_step(p, b, cfg, use_context),
        mesh=mesh,
        in_specs=(P(), _batch_shard_specs(mesh, "clip_tokens")),
        out_specs=P(mesh.axis_names[0]))


def sharded_forward_cached(cfg, use_context: bool, mesh):
    """``forward_cached`` shard_mapped over the batch axis of ``mesh``;
    the RT table replicates so every shard gathers locally."""
    from jax.sharding import PartitionSpec as P

    from repro.distributed.sharding import compat_shard_map
    return compat_shard_map(
        lambda p, table, b: forward_cached(p, table, b, cfg, use_context),
        mesh=mesh,
        in_specs=(P(), P(), _batch_shard_specs(mesh, "rt_idx")),
        out_specs=P(mesh.axis_names[0]))


def sharded_forward_cached_fused(cfg, mesh):
    """``forward_cached_fused`` shard_mapped over the batch axis; params,
    RT table and serving plan replicate."""
    from jax.sharding import PartitionSpec as P

    from repro.distributed.sharding import compat_shard_map
    data = P(mesh.axis_names[0])
    specs = {"rt_idx": data, "ctx_uniq": data, "ctx_count": data,
             "clip_mask": data}
    return compat_shard_map(
        lambda p, plan, b: forward_cached_fused(p, plan, b, cfg),
        mesh=mesh, in_specs=(P(), P(), specs),
        out_specs=P(mesh.axis_names[0]))


def sharded_encode_instructions(cfg, mesh):
    """``encode_instructions`` shard_mapped over the static-row axis:
    the RT-cache *build* divides by mesh size while the resulting table
    stays byte-identical (rows encode independently)."""
    from jax.sharding import PartitionSpec as P

    from repro.distributed.sharding import compat_shard_map
    data = P(mesh.axis_names[0])
    return compat_shard_map(
        lambda p, rows: encode_instructions(p, rows, cfg),
        mesh=mesh, in_specs=(P(), data), out_specs=data)


# Inference precision knob: fp32 is the bitwise-reference mode; bf16 keeps
# fp32 master params and casts at dispatch (``_w``) with fp32 softmax and
# fp32 score/output accumulation (``preferred_element_type`` above), so it
# is relative-error-bounded rather than bitwise.  int8 is *storage*
# precision: weights are per-channel fake-quantized once at engine build
# (``core.quant.quantize_dequant_params``) and all compute stays fp32 —
# measured, XLA's CPU int8 dot is ~5x slower than f32, so int8 compute
# would be a regression on this backend while fp32-on-quantized-weights
# measures exactly the deployment error (gated ≤1%).
PRECISION_DTYPES = {"fp32": "float32", "bf16": "bfloat16",
                    "int8": "float32"}


def inference_config(cfg, precision: Optional[str] = None):
    """Resolve the inference-time numerics + kernel config.

    ``precision`` None leaves cfg.dtype untouched (the bitwise-compatible
    default); "fp32"/"bf16" select the compute dtype.  On TPU the default
    XLA attention is swapped for the Pallas flash kernel (which takes the
    same ``kv_mask``) unless the config already picked an attn_impl other
    than the "chunked" default.  The kernel swap is allclose-not-bitwise
    vs XLA, so any reference comparison must resolve BOTH sides through
    this function (as ``bench_speed.run_multi`` does) — on CPU it is the
    identity for precision=None.
    """
    if precision is not None:
        try:
            cfg = cfg.replace(dtype=PRECISION_DTYPES[precision])
        except KeyError:
            raise ValueError(
                f"precision must be one of {sorted(PRECISION_DTYPES)}, "
                f"got {precision!r}") from None
    if jax.default_backend() == "tpu" and cfg.attn_impl == "chunked":
        cfg = cfg.replace(attn_impl="pallas")
    return cfg


def mape_loss(params, batch, cfg, use_context: bool = True):
    """Eq 11: |prediction - fact| / fact, averaged over the batch."""
    pred = forward(params, batch, cfg, use_context)
    fact = jnp.maximum(batch["time"].astype(jnp.float32), 1.0)
    mape = jnp.mean(jnp.abs(pred - fact) / fact)
    return mape, {"mape": mape}


def predict_step(params, batch, cfg, use_context: bool = True):
    return forward(params, batch, cfg, use_context)


# --------------------------------------------------------------------------- #
# Dry-run lowering (called from launch/dryrun.py for --arch capsim)
# --------------------------------------------------------------------------- #

def lower_cell(cfg, shape, mesh, rules, tcfg):
    """Lower the predictor's train / serve step on the production mesh."""
    from repro.distributed.sharding import (
        LOGICAL_RULES_PREDICTOR, use_mesh_and_rules)
    from repro.launch.specs import batch_shardings, input_specs
    from repro.training.train_loop import (
        abstract_train_state, make_train_step)

    rules = LOGICAL_RULES_PREDICTOR
    with use_mesh_and_rules(mesh, rules):
        batch_abs = input_specs(cfg, shape, shape.kind)
        batch_sh = batch_shardings(batch_abs, mesh, rules)
        param_abs = abstract_params(cfg)
        param_sh = param_shardings(cfg, mesh, rules)
        t0 = time.time()
        if shape.kind == "train":
            state_abs = abstract_train_state(param_abs, tcfg)
            scalar = jax.sharding.NamedSharding(
                mesh, jax.sharding.PartitionSpec())
            if tcfg.optimizer == "sgdm":
                opt_sh = {"mu": param_sh}
            else:
                opt_sh = {"mu": param_sh, "nu": param_sh, "count": scalar}
            state_sh = {"params": param_sh, "opt": opt_sh, "step": scalar}
            if tcfg.compress_grads:
                state_sh["err_fb"] = param_sh
            step = make_train_step(
                lambda p, b: mape_loss(p, b, cfg), tcfg)
            metric_sh = {k: scalar for k in
                         ("loss", "grad_norm", "lr", "mape")}
            lowered = jax.jit(step, in_shardings=(state_sh, batch_sh),
                              out_shardings=(state_sh, metric_sh)
                              ).lower(state_abs, batch_abs)
        else:
            from repro.distributed.sharding import axis_rules
            out_sh = jax.sharding.NamedSharding(
                mesh, axis_rules(("batch",), rules=rules, mesh=mesh))
            lowered = jax.jit(
                lambda p, b: predict_step(p, b, cfg),
                in_shardings=(param_sh, batch_sh),
                out_shardings=out_sh).lower(param_abs, batch_abs)
        return lowered, time.time() - t0
