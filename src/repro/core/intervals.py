"""SimPoint-style interval selection (paper §II sampling background, Fig 2).

Programs are executed functionally in fixed-size intervals; each interval is
summarized by its Basic-Block Vector (how often each basic block is entered,
SimPoint's metric).  k-means over the normalized BBVs picks one
representative interval (checkpoint) per cluster with a weight equal to the
cluster's share — the classic SimPoint recipe, implemented in numpy so the
framework carries no external dependency.
"""
from __future__ import annotations

import dataclasses
from typing import List, Optional, Sequence, Tuple

import numpy as np

from repro.isa.funcsim import MachineState, run
from repro.isa.isa import Instruction


def basic_block_leaders(program: Sequence[Instruction]) -> np.ndarray:
    """Boolean mask over pcs: True where a basic block starts."""
    leaders = np.zeros(len(program), bool)
    if len(program):
        leaders[0] = True
    for pc, inst in enumerate(program):
        if inst.info.is_branch:
            if pc + 1 < len(program):
                leaders[pc + 1] = True
            if inst.target is not None and 0 <= inst.target < len(program):
                leaders[inst.target] = True
    return leaders


@dataclasses.dataclass(frozen=True)
class IntervalInfo:
    index: int                 # interval number within the run
    start: int                 # dynamic instruction offset
    weight: float              # cluster share
    bbv: np.ndarray


def interval_bbvs(program: Sequence[Instruction], total_insts: int,
                  interval_size: int,
                  state: Optional[MachineState] = None
                  ) -> Tuple[np.ndarray, MachineState]:
    """Run functionally, counting basic-block entries per interval.

    Returns (bbvs (n_intervals, n_blocks) float32, final_state).
    """
    leaders = basic_block_leaders(program)
    block_id = np.cumsum(leaders) - 1                   # pc -> block index
    n_blocks = int(block_id[-1]) + 1 if len(program) else 0

    st = state or MachineState.fresh()
    bbvs: List[np.ndarray] = []
    remaining = total_insts
    while remaining > 0:
        n = min(interval_size, remaining)
        trace, _, st = run(program, n, state=st)
        if not trace:
            break
        vec = np.zeros(n_blocks, np.float32)
        for e in trace:
            if leaders[e.pc]:
                vec[block_id[e.pc]] += 1.0
        bbvs.append(vec)
        remaining -= len(trace)
        if len(trace) < n:                              # program exited
            break
    return (np.stack(bbvs) if bbvs else
            np.zeros((0, n_blocks), np.float32)), st


def _kmeans(x: np.ndarray, k: int, iters: int = 25,
            seed: int = 0) -> Tuple[np.ndarray, np.ndarray]:
    """Plain Lloyd's k-means.  Returns (assignments, centroids)."""
    rng = np.random.RandomState(seed)
    n = x.shape[0]
    k = min(k, n)
    centroids = x[rng.choice(n, size=k, replace=False)].copy()
    assign = np.zeros(n, np.int64)
    for _ in range(iters):
        d = ((x[:, None, :] - centroids[None]) ** 2).sum(-1)
        new_assign = d.argmin(1)
        if np.array_equal(new_assign, assign):
            break
        assign = new_assign
        for c in range(k):
            m = assign == c
            if m.any():
                centroids[c] = x[m].mean(0)
    return assign, centroids


def pick_intervals(program: Sequence[Instruction], total_insts: int,
                   interval_size: int, k: int,
                   seed: int = 0) -> List[IntervalInfo]:
    """SimPoint: representative interval per k-means cluster + weights."""
    bbvs, _ = interval_bbvs(program, total_insts, interval_size)
    n = bbvs.shape[0]
    if n == 0:
        return []
    norms = np.linalg.norm(bbvs, axis=1, keepdims=True)
    x = bbvs / np.maximum(norms, 1e-9)
    assign, centroids = _kmeans(x, k, seed=seed)
    out: List[IntervalInfo] = []
    for c in range(centroids.shape[0]):
        members = np.flatnonzero(assign == c)
        if members.size == 0:
            continue
        d = ((x[members] - centroids[c]) ** 2).sum(1)
        rep = int(members[d.argmin()])
        out.append(IntervalInfo(index=rep, start=rep * interval_size,
                                weight=members.size / n, bbv=bbvs[rep]))
    out.sort(key=lambda i: i.index)
    return out
