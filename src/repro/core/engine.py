"""Batched multi-benchmark simulation engine.

CAPSim's speed claim rests on amortizing predictor inference over large
accelerator batches, but a per-benchmark ``capsim_simulate`` loop leaves
three factors of throughput on the floor:

  1. it re-traces/re-compiles the jit'd predict step on every call —
     ``predict_fn`` below caches the compiled step per (config, ablation);
  2. each benchmark pads its own batch remainder — the engine feeds one
     *shared global clip pool*, so clips from many programs fill one
     device batch and only the final remainder pads (to a size bucket,
     bounding compiled shapes to ~log2(batch_size) variants);
  3. the Python functional sim serializes against inference — the engine
     exploits JAX's async dispatch as a double buffer: up to
     ``max_in_flight`` device batches run while the CPU tokenizes the
     next benchmark, and ``jax.block_until_ready`` is deferred to drain
     time.

The host front-end runs entirely on the columnar trace IR
(``repro.isa.compiled``): programs are compiled once to structure-of-
arrays, the table-dispatched interpreter emits pc/ea/taken columns plus a
uint64 snapshot matrix, per-clip tokenization is one
``token_table[trace.pc]`` gather, and context matrices come from a
vectorized byte decomposition — ``FrontendStats`` breaks the host time
down by stage (interpret / slice / tokenize / context) so regressions
show up in the bench JSON artifact.

Device FLOPs are cut by the static-instruction RT cache
(``repro.core.rt_cache``, on by default): each benchmark's ``n_static``
token rows go through the 4-layer instruction encoder exactly once, and
every clip batch then ships (n, l_clip) int32 RT-table indices instead of
token tensors — the jit'd ``forward_cached`` gathers the table on device
and runs only the block encoder + head.  ``precision="bf16"`` additionally
casts the fp32 master params to bfloat16 at dispatch (fp32 softmax and
accumulation), trading bitwise equality for a relative-error bound; on
TPU the block encoder's masked cross/self-attention routes through the
Pallas flash kernel by default (``predictor.inference_config``).

Per-clip predictions in fp32 are bitwise identical to the sequential
monolithic path (XLA CPU rows are independent of batch composition, and
the RT gather returns exactly the rows the folded batch would compute),
and per-benchmark sums are taken over the same contiguous per-benchmark
arrays — so results demux back into ``SimResult``s with unchanged
semantics.
"""
from __future__ import annotations

import dataclasses
from collections import deque
from functools import lru_cache
from typing import Deque, Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import analytical
from repro.core import context as ctx_mod
from repro.core import predictor as pred_mod
from repro.core import sampler as sampler_mod
from repro.core import standardize as std_mod
from repro.core.analytical import PredictionReport
from repro.core.engine_config import EngineConfig, reject_legacy_kwargs
from repro.core.rt_cache import RTCache, RTCacheStats
from repro.isa import funcsim, multicore, progen, timing
from repro.obs import SPAN_SECONDS_TOTAL, Observability


@dataclasses.dataclass
class SimResult:
    name: str
    n_intervals: int
    n_instructions: int
    n_clips: int
    predicted_cycles: float
    oracle_cycles: Optional[float]
    func_seconds: float               # functional sim + tokenize
    predict_seconds: float            # batched predictor inference (share)
    oracle_seconds: Optional[float]   # O3 oracle wall time
    # --- PredictionReport fields (analytical-ML fusion path) ---
    # Full-prediction runs keep the old meanings exactly: every clip is
    # model-predicted (clips_predicted == n_clips, nothing
    # extrapolated) and there is no interval (cycles_ci None).  Under
    # EngineConfig.sampling, predicted_cycles becomes the stratified
    # estimate, cycles_ci its 95% bootstrap interval, and
    # clip_provenance marks model (True) vs analytical-residual (False)
    # per clip.
    cycles_ci: Optional[Tuple[float, float]] = None
    clips_predicted: Optional[int] = None
    clips_extrapolated: int = 0
    clip_provenance: Optional[np.ndarray] = dataclasses.field(
        default=None, compare=False, repr=False)

    def __post_init__(self):
        if self.clips_predicted is None:
            self.clips_predicted = self.n_clips

    @property
    def capsim_seconds(self) -> float:
        return self.func_seconds + self.predict_seconds

    @property
    def speedup(self) -> Optional[float]:
        if self.oracle_seconds is None:
            return None
        return self.oracle_seconds / max(self.capsim_seconds, 1e-9)

    @property
    def rel_error(self) -> Optional[float]:
        if not self.oracle_cycles:
            return None
        return abs(self.predicted_cycles - self.oracle_cycles) \
            / self.oracle_cycles

    @property
    def prediction_report(self) -> PredictionReport:
        """The result's fused-prediction view as one typed object."""
        ci = (self.cycles_ci if self.cycles_ci is not None
              else (self.predicted_cycles, self.predicted_cycles))
        return PredictionReport(
            total_cycles=self.predicted_cycles, cycles_ci=ci,
            clips_predicted=self.clips_predicted,
            clips_extrapolated=self.clips_extrapolated,
            clip_provenance=self.clip_provenance)


@lru_cache(maxsize=64)
def predict_fn(cfg, use_context: bool = True):
    """Cached jit'd predict step: one trace+compile per (config, ablation)
    for the whole process instead of one per ``capsim_simulate`` call.
    ``cfg`` is a frozen dataclass, so it keys the cache directly."""
    return jax.jit(lambda p, b: pred_mod.predict_step(p, b, cfg,
                                                      use_context))


@lru_cache(maxsize=64)
def predict_cached_fn(cfg, use_context: bool = True):
    """Cached jit'd RT-cache predict step: the batch carries ``rt_idx``
    rows into a device-resident RT table, so only the block encoder +
    head run per clip (``predictor.forward_cached``)."""
    return jax.jit(lambda p, table, b: pred_mod.forward_cached(
        p, table, b, cfg, use_context))


@lru_cache(maxsize=64)
def serving_plan_fn(cfg):
    """Cached jit'd per-table-version precompute for the fused serving
    step (``predictor.serving_plan``): cross-attention K/V projections
    of every RT row.  Rebuilt only when the table object changes."""
    return jax.jit(lambda p, table: pred_mod.serving_plan(p, table, cfg))


@lru_cache(maxsize=64)
def predict_cached_fused_fn(cfg):
    """Cached jit'd fused serving step: deduped-context weighted
    attention over precomputed cross K/V (``forward_cached_fused``)."""
    return jax.jit(lambda p, plan, b: pred_mod.forward_cached_fused(
        p, plan, b, cfg))


@lru_cache(maxsize=64)
def predict_cached_fused_mesh_fn(cfg, n_shards: int):
    """Sharded twin of ``predict_cached_fused_fn``: the batch axis splits
    over the data mesh; params, RT table and plan replicate."""
    from repro.launch.mesh import make_data_mesh
    return jax.jit(pred_mod.sharded_forward_cached_fused(
        cfg, make_data_mesh(n_shards)))


@lru_cache(maxsize=64)
def predict_mesh_fn(cfg, use_context: bool, n_shards: int):
    """Sharded twin of ``predict_fn``: the batch axis splits over an
    n-device data mesh (params replicated) — bitwise equal to the
    single-device dispatch because clips are row-independent."""
    from repro.launch.mesh import make_data_mesh
    return jax.jit(pred_mod.sharded_predict_step(
        cfg, use_context, make_data_mesh(n_shards)))


@lru_cache(maxsize=64)
def predict_cached_mesh_fn(cfg, use_context: bool, n_shards: int):
    """Sharded twin of ``predict_cached_fn``: rt_idx/context/mask shard
    over the data mesh, the RT table replicates to every device."""
    from repro.launch.mesh import make_data_mesh
    return jax.jit(pred_mod.sharded_forward_cached(
        cfg, use_context, make_data_mesh(n_shards)))


def bucket_sizes(batch_size: int, align: int = 1) -> Tuple[int, ...]:
    """Descending pad targets for the final partial batch: the full batch
    plus halvings down to 8.  Bounds distinct compiled shapes while keeping
    remainder padding < 2x.  ``align`` (the mesh shard count) keeps every
    bucket a multiple of the mesh size — and at least one row per device —
    so a sharded dispatch never hands a device an empty or ragged shard."""
    floor = max(8, align)
    sizes = [batch_size]
    b = batch_size
    while b > floor:
        b = max((b // 2 + align - 1) // align * align, floor)
        sizes.append(b)
    return tuple(sizes)


# stage span name per FrontendStats field — the engine times these via
# obs spans and the stats view reads the registry back
_FE_SPANS = {"interpret_seconds": "engine.interpret",
             "slice_seconds": "engine.slice",
             "tokenize_seconds": "engine.tokenize",
             "context_seconds": "engine.context",
             "analytical_seconds": "engine.analytical"}


class FrontendStats:
    """Host front-end breakdown across one ``SimulationEngine.run``.

    A live *view* over the obs metrics registry: the engine writes
    stage spans + counters (the same cells ``/metrics`` serves) and a
    fresh view snapshots a baseline at construction, so each ``run``
    reads per-run deltas while the registry keeps lifetime totals.
    No-arg construction is the all-zeros stand-in.
    """

    def __init__(self, obs: Optional[Observability] = None,
                 instance: str = ""):
        self._obs = obs
        self._instance = instance
        base: Dict[str, float] = {}
        if obs is not None:
            for field, span in _FE_SPANS.items():
                base[field] = obs.metrics.value(
                    SPAN_SECONDS_TOTAL, span=span, instance=instance)
            base["n_instructions"] = obs.metrics.value(
                "capsim_frontend_instructions_total", instance=instance)
            base["n_clips"] = obs.metrics.value(
                "capsim_frontend_clips_total", instance=instance)
        self._base = base

    def _span_delta(self, field: str) -> float:
        if self._obs is None:
            return 0.0
        now = self._obs.metrics.value(
            SPAN_SECONDS_TOTAL, span=_FE_SPANS[field],
            instance=self._instance)
        return now - self._base[field]

    def _count_delta(self, name: str, key: str) -> int:
        if self._obs is None:
            return 0
        now = self._obs.metrics.value(name, instance=self._instance)
        return int(now - self._base[key])

    @property
    def interpret_seconds(self) -> float:
        return self._span_delta("interpret_seconds")

    @property
    def slice_seconds(self) -> float:
        return self._span_delta("slice_seconds")

    @property
    def tokenize_seconds(self) -> float:
        return self._span_delta("tokenize_seconds")

    @property
    def context_seconds(self) -> float:
        return self._span_delta("context_seconds")

    @property
    def analytical_seconds(self) -> float:
        return self._span_delta("analytical_seconds")

    @property
    def n_instructions(self) -> int:
        return self._count_delta("capsim_frontend_instructions_total",
                                 "n_instructions")

    @property
    def n_clips(self) -> int:
        return self._count_delta("capsim_frontend_clips_total", "n_clips")

    @property
    def frontend_seconds(self) -> float:
        return (self.interpret_seconds + self.slice_seconds
                + self.tokenize_seconds + self.context_seconds
                + self.analytical_seconds)

    def as_dict(self) -> Dict[str, float]:
        return {"interpret_seconds": self.interpret_seconds,
                "slice_seconds": self.slice_seconds,
                "tokenize_seconds": self.tokenize_seconds,
                "context_seconds": self.context_seconds,
                "analytical_seconds": self.analytical_seconds,
                "frontend_seconds": self.frontend_seconds,
                "n_instructions": self.n_instructions,
                "n_clips": self.n_clips}


class PredictorStats:
    """Live view over one predictor instance's registry cells.

    Each ``BatchedPredictor`` gets a process-unique ``instance`` label,
    so its cells start at zero and concurrent predictors (including
    flushes abandoned by the serving watchdog) can never corrupt each
    other's accounting — which keeps the drain demux assert exact.
    """

    def __init__(self, obs: Optional[Observability] = None,
                 instance: str = ""):
        self._obs = obs
        self._instance = instance

    def _val(self, name: str) -> float:
        if self._obs is None:
            return 0.0
        return self._obs.metrics.value(name, instance=self._instance)

    @property
    def n_clips(self) -> int:              # real clips fed in
        return int(self._val("capsim_predictor_clips_total"))

    @property
    def n_predicted(self) -> int:          # real clips retired
        return int(self._val("capsim_predictor_predicted_total"))

    @property
    def n_pad(self) -> int:                # padding rows dispatched
        return int(self._val("capsim_predictor_pad_rows_total"))

    @property
    def batch_shapes(self) -> Dict[int, int]:
        if self._obs is None:
            return {}
        return {int(labels["shape"]): int(v)
                for labels, v in self._obs.metrics.collect(
                    "capsim_predictor_batches_total",
                    instance=self._instance)}

    @property
    def n_batches(self) -> int:
        return sum(self.batch_shapes.values())

    @property
    def dispatch_seconds(self) -> float:
        if self._obs is None:
            return 0.0
        return self._obs.metrics.value(
            SPAN_SECONDS_TOTAL, span="predict.dispatch",
            instance=self._instance)

    @property
    def drain_seconds(self) -> float:
        if self._obs is None:
            return 0.0
        return self._obs.metrics.value(
            SPAN_SECONDS_TOTAL, span="predict.drain",
            instance=self._instance)

    @property
    def predict_seconds(self) -> float:
        return self.dispatch_seconds + self.drain_seconds


class BatchedPredictor:
    """Size-bucketed async batcher over a global clip pool.

    ``add`` buffers tokenized clips and dispatches a device batch whenever
    a full ``batch_size`` accumulates; dispatch is asynchronous, so the
    caller keeps tokenizing while the device computes.  At most
    ``max_in_flight`` batches stay un-retired (the double buffer) to bound
    host memory.  ``drain`` pads the remainder to the smallest size bucket
    with fully-masked zero rows, blocks on everything outstanding, and
    returns per-clip predictions in submission order.

    With ``rt_cache`` set, batches carry (n, l_clip) int32 RT-table
    indices instead of token tensors and dispatch through the
    block-encoder-only ``forward_cached`` step — feed them via
    ``add_indexed`` (trace engine) or plain ``add`` (tokenized requests
    are deduped through the cache first).  ``config.fused_serving``
    additionally dedupes each batch's context rows on the host and
    dispatches through ``forward_cached_fused`` over a per-table-version
    cross-K/V serving plan (tolerance-gated ≤1e-3 vs the unfused path).

    Construction is config-first: ``config`` (an ``EngineConfig``)
    supplies batch size, precision, mesh shape, context ablation and
    in-flight depth; ``rt_cache`` stays a direct object parameter (the
    cache is shared state owned by the caller, not a setting).  With a
    non-empty ``config.mesh_shape`` every device batch shard_maps over
    the data mesh: buckets stay multiples of the mesh size, so no shard
    is ever empty, and demuxed rows are bitwise the single-device rows.
    The pre-PR-6 loose keyword arguments (``batch_size=``,
    ``precision=``, ...) are retired: they raise ``TypeError`` pointing
    at the ``EngineConfig`` field to use.
    """

    def __init__(self, params, cfg, *, config: Optional[EngineConfig] = None,
                 rt_cache: Optional[RTCache] = None,
                 fault_injector=None,
                 obs: Optional[Observability] = None, **legacy):
        reject_legacy_kwargs(legacy, "BatchedPredictor")
        config = config or EngineConfig()
        self.config = config
        self.obs = (obs if obs is not None
                    else Observability.from_config(config.observability))
        m = self.obs.metrics
        self.instance = m.next_instance("predictor")
        self._c_clips = m.counter(
            "capsim_predictor_clips_total", "Real clips fed in.",
            ("instance",)).labels(instance=self.instance)
        self._c_predicted = m.counter(
            "capsim_predictor_predicted_total",
            "Real clips with a retired prediction.",
            ("instance",)).labels(instance=self.instance)
        self._c_pad = m.counter(
            "capsim_predictor_pad_rows_total",
            "Padding rows dispatched.",
            ("instance",)).labels(instance=self.instance)
        self._fam_batches = m.counter(
            "capsim_predictor_batches_total",
            "Device batches dispatched, by padded batch shape.",
            ("instance", "shape"))
        self._batch_handles: Dict[int, object] = {}
        self._g_in_flight = m.gauge(
            "capsim_predictor_in_flight",
            "Un-retired device batches (the double buffer).",
            ("instance",)).labels(instance=self.instance)
        self._h_occupancy = m.histogram(
            "capsim_predictor_bucket_occupancy",
            "Real-row share of each dispatched bucket.",
            ("instance",),
            buckets=(0.25, 0.5, 0.75, 0.9, 0.99, 1.0)).labels(
                instance=self.instance)
        if fault_injector is None and config.faults:
            # deferred import: repro.serving imports this module
            from repro.serving.faults import FaultInjector
            fault_injector = FaultInjector.from_config(config)
        self._faults = fault_injector
        self.params = params
        self.cfg = pred_mod.inference_config(cfg, config.precision)
        self.batch_size = config.batch_size
        self._shards = config.n_shards         # 0 = unsharded path
        self.buckets = bucket_sizes(config.batch_size,
                                    max(self._shards, 1))
        self.max_in_flight = config.max_in_flight
        use_context = config.use_context
        self._cache = rt_cache
        self._fused = config.fused_serving
        self._plan = None          # serving_plan for the current table
        self._plan_src: Optional[jax.Array] = None
        if self._fused and rt_cache is None:
            raise ValueError(
                "fused_serving requires an RTCache (the fused step IS "
                "the RT-gather + block encoder)")
        if rt_cache is not None:
            # the table is a pure function of (params, cfg numerics +
            # kernel); any mismatch silently breaks the bitwise contract
            assert rt_cache.params is params and rt_cache.cfg == self.cfg, \
                "RT cache must be built with the same params and " \
                "resolved config as the predict step"
            if self._fused:
                self._predict = (
                    predict_cached_fused_mesh_fn(self.cfg, self._shards)
                    if self._shards
                    else predict_cached_fused_fn(self.cfg))
            else:
                self._predict = (
                    predict_cached_mesh_fn(self.cfg, use_context,
                                           self._shards)
                    if self._shards
                    else predict_cached_fn(self.cfg, use_context))
        else:
            self._predict = (
                predict_mesh_fn(self.cfg, use_context, self._shards)
                if self._shards
                else predict_fn(self.cfg, use_context))
        self._tok: List[np.ndarray] = []      # token tensors OR rt_idx rows
        self._ctx: List[np.ndarray] = []
        self._mask: List[np.ndarray] = []
        self._ctx_width: Optional[int] = None  # pinned by the first add
        self._buffered = 0
        self._pending: Deque[Tuple[jax.Array, int]] = deque()
        self._retired: List[np.ndarray] = []
        self._drained = 0           # clips returned by previous drains
        self.stats = PredictorStats(self.obs, self.instance)

    def add(self, tok: np.ndarray, ctx: np.ndarray,
            mask: np.ndarray) -> None:
        """tok (n, l_clip, l_token) int32; ctx (n, M) int32;
        mask (n, l_clip) float32."""
        if tok.shape[0] == 0:
            return
        if self._cache is not None:
            self.add_indexed(self._cache.index_clips(tok), ctx, mask)
            return
        self._buffer(tok, ctx, mask)

    def add_indexed(self, rt_idx: np.ndarray, ctx: np.ndarray,
                    mask: np.ndarray) -> None:
        """RT-cache fast path: rt_idx (n, l_clip) int32 rows into the
        cache table (masked slots on the pad row); ctx/mask as ``add``."""
        assert self._cache is not None, "add_indexed needs an RT cache"
        if rt_idx.shape[0] == 0:
            return
        self._cache.record_served(int(mask.sum()))
        self._buffer(rt_idx, ctx, mask)

    def _buffer(self, tok: np.ndarray, ctx: np.ndarray,
                mask: np.ndarray) -> None:
        # dispatch-boundary width check: the pool concatenates context
        # rows across many programs/cores, so a mixed or unknown layout
        # must fail HERE with the producer on the stack, not as a shape
        # error inside a later np.concatenate or jit re-trace
        ctx_mod.validate_context_width(ctx.shape[1], "BatchedPredictor")
        if self._ctx_width is None:
            self._ctx_width = ctx.shape[1]
        elif ctx.shape[1] != self._ctx_width:
            raise ValueError(
                f"BatchedPredictor: context width {ctx.shape[1]} differs "
                f"from the pool's {self._ctx_width} — single-core, "
                "core-tagged, and peer-channel clips cannot share one "
                "batch pool")
        self._tok.append(tok)
        self._ctx.append(ctx)
        self._mask.append(mask)
        self._buffered += tok.shape[0]
        self._c_clips.inc(tok.shape[0])
        while self._buffered >= self.batch_size:
            tok_b, ctx_b, mask_b = self._take(self.batch_size)
            self._dispatch(tok_b, ctx_b, mask_b, self.batch_size)

    def _take(self, k: int) -> Tuple[np.ndarray, np.ndarray, np.ndarray]:
        """Pop exactly k rows off the buffer head."""
        out = []
        for buf in (self._tok, self._ctx, self._mask):
            have, taken = 0, []
            while have < k:
                chunk = buf.pop(0)
                need = k - have
                if chunk.shape[0] > need:
                    taken.append(chunk[:need])
                    buf.insert(0, chunk[need:])
                    have = k
                else:
                    taken.append(chunk)
                    have += chunk.shape[0]
            out.append(taken[0] if len(taken) == 1
                       else np.concatenate(taken))
        self._buffered -= k
        return tuple(out)

    def reset_context_width(self) -> None:
        """Unpin the pool's context-width check between *independent*
        flushes (the pool must be empty).  A long-lived backend — the
        serving engine holds one for its whole lifetime now — calls this
        at each flush boundary so consecutive flushes may carry
        different (but internally consistent) context layouts."""
        assert self._buffered == 0, \
            "cannot reset context width with clips still buffered"
        self._ctx_width = None

    def _dispatch(self, tok, ctx, mask, n_real: int) -> None:
        # the dispatch span includes any blocking retires forced by the
        # in-flight cap — the same accounting window the pre-obs
        # dispatch_seconds stopwatch covered
        with self.obs.span("predict.dispatch", instance=self.instance):
            self._dispatch_inner(tok, ctx, mask, n_real)

    def _dispatch_inner(self, tok, ctx, mask, n_real: int) -> None:
        if self._faults is not None:
            # chaos layer: may stall (slow_flush) or raise (device_error)
            # exactly where a real device failure would surface
            self._faults.on_dispatch()
        if self._shards:
            # sharded dispatch contract: every device gets a non-empty,
            # equal shard (bucket_sizes keeps buckets aligned; a pool
            # smaller than the mesh was padded with masked zero rows)
            assert tok.shape[0] >= self._shards \
                and tok.shape[0] % self._shards == 0, \
                (tok.shape[0], self._shards)
        if self._fused:
            # host-side context dedup (~ms per batch): the fused step
            # attends over each row's unique tokens with multiplicity
            # weights instead of all M context rows
            uniq, counts = std_mod.dedupe_context_tokens(ctx)
            batch = {"rt_idx": jnp.asarray(tok),
                     "ctx_uniq": jnp.asarray(uniq),
                     "ctx_count": jnp.asarray(counts),
                     "clip_mask": jnp.asarray(mask)}
            out = self._predict(self.params, self._serving_plan(), batch)
        elif self._cache is not None:
            batch = {"rt_idx": jnp.asarray(tok),
                     "context_tokens": jnp.asarray(ctx),
                     "clip_mask": jnp.asarray(mask)}
            out = self._predict(self.params, self._cache.table, batch)
        else:
            batch = {"clip_tokens": jnp.asarray(tok),
                     "context_tokens": jnp.asarray(ctx),
                     "clip_mask": jnp.asarray(mask)}
            out = self._predict(self.params, batch)   # async dispatch
        self._pending.append((out, n_real))
        shape = tok.shape[0]
        handle = self._batch_handles.get(shape)
        if handle is None:
            handle = self._fam_batches.labels(instance=self.instance,
                                              shape=shape)
            self._batch_handles[shape] = handle
        handle.inc()
        self._c_pad.inc(shape - n_real)
        self._h_occupancy.observe(n_real / shape)
        while len(self._pending) > self.max_in_flight:
            self._retire()
        self._g_in_flight.set(len(self._pending))

    def _serving_plan(self):
        """Per-table-version cross K/V plan: rebuilt when (and only when)
        the cache table object changes — ``ensure_rows`` growth replaces
        the (immutable) array, and holding the strong reference in
        ``_plan_src`` makes the identity check GC-safe."""
        table = self._cache.table
        if self._plan is None or self._plan_src is not table:
            self._plan = serving_plan_fn(self.cfg)(self.params, table)
            self._plan_src = table
        return self._plan

    def _retire(self) -> None:
        with self.obs.span("predict.retire", instance=self.instance):
            out, n_real = self._pending.popleft()
            out = np.asarray(out)[:n_real]              # blocks this batch
            if self._faults is not None:
                # nan_output chaos: the retired batch comes back
                # non-finite; the service-level guard must catch it
                # before demux
                out = self._faults.corrupt_output(out)
            self._retired.append(out)
            self._c_predicted.inc(n_real)

    def drain(self) -> np.ndarray:
        """Flush the remainder, block on all outstanding batches, and
        return (n_clips,) float32 predictions in submission order."""
        with self.obs.span("predict.drain", instance=self.instance):
            return self._drain_inner()

    def _drain_inner(self) -> np.ndarray:
        if self._buffered:
            n = self._buffered
            tok, ctx, mask = self._take(n)
            bucket = min((b for b in self.buckets if b >= n),
                         default=self.batch_size)
            pad = bucket - n
            if pad:
                # zero rows, not repeats of the last real clip: repeated
                # real rows burn block-encoder FLOPs on phantom work.  A
                # zero token row is all-<PAD>; a zero rt_idx row is the
                # cache's pad slot; a zero mask excludes the row entirely.
                # On a mesh the bucket floor is max(8, n_shards), so a
                # pool smaller than the device count pads up to a full
                # (aligned) shard set instead of dispatching an empty
                # shard; the [:n_real] demux in _retire drops the pads.
                tok = np.concatenate(
                    [tok, np.zeros((pad,) + tok.shape[1:], tok.dtype)])
                ctx = np.concatenate(
                    [ctx, np.zeros((pad,) + ctx.shape[1:], ctx.dtype)])
                mask = np.concatenate(
                    [mask, np.zeros((pad,) + mask.shape[1:], mask.dtype)])
                assert not mask[n:].any(), \
                    "padded remainder rows must be fully masked"
            self._dispatch(tok, ctx, mask, n)
        while self._pending:
            self._retire()
        self._g_in_flight.set(0)
        preds = (np.concatenate(self._retired) if self._retired
                 else np.zeros(0, np.float32))
        # n_predicted accumulates over the backend's lifetime (many
        # flushes); each drain returns exactly the clips added since the
        # previous drain
        assert preds.shape[0] == self.stats.n_predicted - self._drained, \
            "demux must return exactly the real (non-pad) clips"
        self._drained = self.stats.n_predicted
        self._retired = []
        return preds


@dataclasses.dataclass
class _Job:
    bench: object                     # Benchmark or (multicore) core label
    offset: int = 0                   # first clip index in the global pool
    n_clips: int = 0
    n_intervals: int = 0
    n_instructions: int = 0
    oracle_cycles: float = 0.0
    oracle_seconds: float = 0.0
    func_seconds: float = 0.0
    # multicore demux: (bench, core) clips land in per-checkpoint
    # segments interleaved across cores, so predictions accumulate
    # segment-by-segment instead of as one contiguous pool slice
    predicted_cycles: float = 0.0
    name: str = ""


@dataclasses.dataclass
class MulticoreSimResult:
    """One multicore benchmark's demuxed (benchmark, core) results.

    ``predicted_cycles`` / ``oracle_cycles`` are the across-core sums —
    total core-cycles of the N-core run; the per-core breakdown is in
    ``cores`` (entries named ``<bench>#c<k>``).
    """

    name: str
    n_cores: int
    cores: List[SimResult]

    @property
    def predicted_cycles(self) -> float:
        return sum(r.predicted_cycles for r in self.cores)

    @property
    def oracle_cycles(self) -> Optional[float]:
        if any(r.oracle_cycles is None for r in self.cores):
            return None
        return sum(r.oracle_cycles for r in self.cores)

    @property
    def n_clips(self) -> int:
        return sum(r.n_clips for r in self.cores)

    @property
    def n_instructions(self) -> int:
        return sum(r.n_instructions for r in self.cores)

    # --- PredictionReport aggregates (analytical-ML fusion path) ---

    @property
    def cycles_ci(self) -> Optional[Tuple[float, float]]:
        """Across-core CI: summed per-core bounds (conservative — the
        per-core draws are independent, so the true interval is
        narrower).  None unless every core ran the fusion path."""
        if any(r.cycles_ci is None for r in self.cores):
            return None
        return (sum(r.cycles_ci[0] for r in self.cores),
                sum(r.cycles_ci[1] for r in self.cores))

    @property
    def clips_predicted(self) -> int:
        return sum(r.clips_predicted for r in self.cores)

    @property
    def clips_extrapolated(self) -> int:
        return sum(r.clips_extrapolated for r in self.cores)


class SimulationEngine:
    """Queue of benchmarks -> functional sims -> one shared clip pool ->
    cached-jit bucketed inference -> demultiplexed ``SimResult``s.

    Construction is config-first: ``SimulationEngine.from_config(params,
    cfg, vocab, EngineConfig(...))`` (or the equivalent ``config=``
    keyword) is the single way every knob — trace scale, batching,
    precision, RT cache, multicore N and the device mesh — reaches the
    engine; ``capsim_simulate``/``capsim_simulate_multicore``, serving
    ``PredictorEngine`` and ``launch/serve.py`` are all thin wrappers
    over it.  A non-empty ``mesh_shape`` shards every predict dispatch
    AND every RT-cache encode pass across the data mesh, bitwise equal
    to the unsharded engine.  ``config.sampling`` switches runs to the
    analytical-ML fusion path: only a stratified sample of each
    benchmark's clips reaches the predictor and the rest extrapolate
    from analytical features with a bootstrap CI (``sampling=None``
    keeps the full-prediction path bitwise).  The pre-PR-6 loose
    keyword signature is retired: extra keywords raise ``TypeError``
    pointing at ``EngineConfig``.
    """

    def __init__(self, params, cfg, vocab: std_mod.Vocab,
                 config: Optional[EngineConfig] = None, *,
                 timing_params: Optional[timing.TimingParams] = None,
                 **legacy):
        reject_legacy_kwargs(legacy, "SimulationEngine")
        config = config or EngineConfig()
        self.config = config
        self.obs = Observability.from_config(config.observability)
        self.instance = self.obs.metrics.next_instance("engine")
        self._c_instructions = self.obs.metrics.counter(
            "capsim_frontend_instructions_total",
            "Instructions functionally simulated.",
            ("instance",)).labels(instance=self.instance)
        self._c_fe_clips = self.obs.metrics.counter(
            "capsim_frontend_clips_total",
            "Clips sliced/tokenized by the front-end.",
            ("instance",)).labels(instance=self.instance)
        if config.precision == "int8":
            # per-channel weight fake-quantization at engine build: the
            # cache, plan and predict step all see the SAME quantized
            # tree, so the bitwise params-identity contract holds within
            # the engine (and the RT store keys on the quantized bytes)
            from repro.core import quant
            params = quant.quantize_dequant_params(params)
        self.params = params
        self.cfg = pred_mod.inference_config(cfg, config.precision)
        self.vocab = vocab
        # mirror the config's trace-scale fields as attributes (the
        # pre-EngineConfig public surface; internal code reads these too)
        self.interval_size = config.interval_size
        self.warmup = config.warmup
        self.max_checkpoints = config.max_checkpoints
        self.l_min = config.l_min
        self.l_clip = config.l_clip
        self.l_token = config.l_token
        self.batch_size = config.batch_size
        self.use_context = config.use_context
        self.with_oracle = config.with_oracle
        self.timing_params = (timing_params if timing_params is not None
                              else timing.TimingParams())
        self.max_in_flight = config.max_in_flight
        # one fault injector per engine (None without config.faults): the
        # cache and every per-run BatchedPredictor share its RNG stream,
        # so a chaos run's injection schedule is one deterministic
        # sequence across the whole stack
        self._faults = None
        if config.faults:
            from repro.serving.faults import FaultInjector
            self._faults = FaultInjector.from_config(config)
        # one cache per engine: params are pinned at construction, so the
        # table never goes stale; new programs just append unseen rows.
        # The cache shares the engine's mesh: encode passes shard too.
        # With rt_store_dir the cache loads (or later persists) the
        # table under a (params, cfg, l_token, vocab) content key.
        self._rt_cache = (RTCache(self.params, self.cfg, config.l_token,
                                  n_shards=config.n_shards,
                                  store_dir=config.rt_store_dir,
                                  store_extra=vocab.signature(),
                                  fault_injector=self._faults,
                                  obs=self.obs)
                          if config.rt_cache else None)
        self._queue: List[progen.Benchmark] = []
        self.last_stats: Optional[PredictorStats] = None
        self.last_rt_stats = None
        self.frontend_stats = FrontendStats(self.obs, self.instance)

    @classmethod
    def from_config(cls, params, cfg, vocab: std_mod.Vocab,
                    config: Optional[EngineConfig] = None, *,
                    timing_params: Optional[timing.TimingParams] = None
                    ) -> "SimulationEngine":
        """Canonical constructor: every public entry point routes here."""
        return cls(params, cfg, vocab, config,
                   timing_params=timing_params)

    def submit(self, bench: progen.Benchmark) -> None:
        self._queue.append(bench)

    def submit_names(self, names: Sequence[str]) -> None:
        for name in names:
            self.submit(progen.build_benchmark(name))

    def _feed_trace(self, trace, token_table, static_ids,
                    pred: BatchedPredictor, job: _Job,
                    core_id: Optional[int] = None,
                    sink: Optional[list] = None) -> int:
        """Tokenize + context one interval trace and enqueue its clips —
        the shared interval body of the single-core and multicore paths
        (``core_id=None`` keeps the single-core context layout bit for
        bit).  Returns the clip count enqueued.

        With ``sink`` (the fusion path) nothing reaches the predictor
        yet: clip tensors land in the sink together with their
        analytical feature rows, and the caller feeds only the
        stratified sample once the job's trace is complete."""
        n = len(trace)
        job.n_intervals += 1
        job.n_instructions += n
        self._c_instructions.inc(n)

        with self.obs.span("engine.tokenize", instance=self.instance):
            if static_ids is not None:
                tok, mask = std_mod.fixed_clip_indices(
                    static_ids, trace.pc, self.l_min, self.l_clip)
            else:
                tok, mask = std_mod.encode_fixed_clips(
                    token_table, trace.pc, self.l_min, self.l_clip)
            n_clips = tok.shape[0]             # slice_fixed partition

        with self.obs.span("engine.context", instance=self.instance):
            ctx_all = ctx_mod.context_tokens_from_matrix(
                trace.snapshots, self.vocab, core_id=core_id)
            rows = np.minimum(np.arange(n_clips), len(ctx_all) - 1)
            ctx = ctx_all[rows]

        job.n_clips += n_clips
        self._c_fe_clips.inc(n_clips)
        if sink is not None:
            with self.obs.span("engine.analytical",
                               instance=self.instance):
                feats = analytical.clip_features(trace, self.l_min,
                                                 self.timing_params)
            assert feats.shape[0] == n_clips, \
                "analytical windows must mirror the clip partition"
            sink.append((tok, ctx, mask, feats))
        elif static_ids is not None:
            pred.add_indexed(tok, ctx, mask)
        else:
            pred.add(tok, ctx, mask)
        return n_clips

    def _feed_sample(self, pred: BatchedPredictor, sink: list,
                     job: _Job, job_key: int):
        """Stratify one job's collected clips, select the sample, and
        feed ONLY those rows to the predictor (preserving clip order,
        so cross-benchmark pipelining survives: the device crunches
        this job's sample while the next job's functional sim runs).

        Returns the per-job fusion plan ``(features, strata, sampled)``
        the post-drain demux hands to ``fuse_predictions``."""
        scfg = self.config.sampling
        if sink:
            tok = np.concatenate([s[0] for s in sink])
            ctx = np.concatenate([s[1] for s in sink])
            mask = np.concatenate([s[2] for s in sink])
            feats = np.concatenate([s[3] for s in sink])
        else:
            feats = np.zeros((0, analytical.N_FEATURES), np.float64)
        strata = analytical.stratify(feats, scfg.strata)
        sampled, _ = sampler_mod.stratified_sample(
            strata, scfg.fraction, scfg.min_clips_per_stratum,
            scfg.seed, key=job_key)
        if sampled.shape[0]:
            if self._rt_cache is not None:
                pred.add_indexed(tok[sampled], ctx[sampled],
                                 mask[sampled])
            else:
                pred.add(tok[sampled], ctx[sampled], mask[sampled])
        return feats, strata, sampled

    def _functional(self, bench: progen.Benchmark, pred: BatchedPredictor,
                    job: _Job, sink: Optional[list] = None) -> None:
        """Columnar functional sim + slice + tokenize one benchmark,
        feeding clips straight into the (asynchronously consuming)
        predictor.  Tokens/contexts are bitwise identical to the object
        path (``ClipEncoder`` over ``slice_fixed`` clips).  With
        ``sink`` the clips collect there instead (fusion path)."""
        cprog = bench.compiled()
        token_table = cprog.token_table(self.vocab, self.l_token)
        static_ids = None
        if self._rt_cache is not None:
            # one instruction-encoder pass over n_static rows serves every
            # dynamic clip of this benchmark (and dedupes across programs)
            static_ids = self._rt_cache.ensure_rows(
                token_table,
                keys=cprog.token_row_keys(self.vocab, self.l_token))
        st = progen.fresh_compiled_state(bench)
        with self.obs.span("engine.interpret", instance=self.instance):
            _, st = funcsim.run_compiled(cprog, self.warmup, st)
        n_ckp = min(bench.ckp_num, self.max_checkpoints)
        for _ in range(n_ckp):
            with self.obs.span("engine.interpret",
                               instance=self.instance):
                trace, st = funcsim.run_compiled(
                    cprog, self.interval_size, st,
                    snapshot_every=self.l_min)
            if not len(trace):
                break
            self._feed_trace(trace, token_table, static_ids, pred, job,
                             sink=sink)
            if self.with_oracle:
                with self.obs.span("engine.oracle",
                                   instance=self.instance) as osp:
                    job.oracle_cycles += timing.total_cycles_columnar(
                        trace, self.timing_params)
                job.oracle_seconds += osp.seconds

    def run(self, benches: Optional[Sequence[progen.Benchmark]] = None
            ) -> List[SimResult]:
        """Drain the queue (plus ``benches``) and return one ``SimResult``
        per benchmark, in submission order."""
        jobs = [_Job(b) for b in self._queue]
        self._queue = []
        if benches is not None:
            jobs.extend(_Job(b) for b in benches)
        if self.config.sampling is not None:
            return self._run_sampled(jobs)
        self.frontend_stats = FrontendStats(self.obs, self.instance)
        pred = BatchedPredictor(self.params, self.cfg, config=self.config,
                                rt_cache=self._rt_cache,
                                fault_injector=self._faults, obs=self.obs)
        rt_stats = (self._rt_cache.stats if self._rt_cache is not None
                    else RTCacheStats())
        offset = 0
        for job in jobs:
            job.offset = offset
            d0 = pred.stats.dispatch_seconds
            b0 = rt_stats.build_seconds
            with self.obs.span("engine.job", instance=self.instance,
                               args={"bench": job.bench.name}) as jsp:
                self._functional(job.bench, pred, job)
            # dispatch (and any blocking retire) and the RT-cache build
            # overlap the functional window; subtract both so device
            # predict time isn't counted twice
            job.func_seconds = (jsp.seconds - job.oracle_seconds
                                - (pred.stats.dispatch_seconds - d0)
                                - (rt_stats.build_seconds - b0))
            offset = job.offset + job.n_clips
        preds = pred.drain()
        if self._rt_cache is not None:
            self._rt_cache.persist()          # no-op without a store_dir
        self.last_stats = pred.stats
        self.last_rt_stats = (rt_stats.freeze()
                              if self._rt_cache is not None else None)
        assert preds.shape[0] == offset == pred.stats.n_predicted, \
            "clip accounting mismatch between pool and predictions"

        results = []
        total_clips = max(offset, 1)
        for job in jobs:
            mine = preds[job.offset:job.offset + job.n_clips]
            share = job.n_clips / total_clips
            results.append(SimResult(
                name=job.bench.name,
                n_intervals=job.n_intervals,
                n_instructions=job.n_instructions,
                n_clips=job.n_clips,
                predicted_cycles=float(mine.sum()),
                oracle_cycles=job.oracle_cycles if self.with_oracle
                else None,
                func_seconds=job.func_seconds,
                predict_seconds=pred.stats.predict_seconds * share,
                oracle_seconds=job.oracle_seconds if self.with_oracle
                else None))
        return results

    def simulate(self, bench: progen.Benchmark) -> SimResult:
        """Single-benchmark convenience path (``capsim_simulate``)."""
        return self.run([bench])[0]

    def _run_sampled(self, jobs: List[_Job]) -> List[SimResult]:
        """Fusion path of ``run()``: per benchmark, collect every clip's
        tensors + analytical features, stratify on the analytical cycle
        estimate, run ONLY the stratified sample through the predictor,
        then extrapolate the rest with a ridge residual fit and a
        bootstrap CI (``analytical.fuse_predictions``).

        At ``fraction=1.0`` every clip is "sampled" in original order,
        the fit never runs, and the total is the plain ``float(sum())``
        over the same prediction rows the unsampled path sums — bitwise
        equal by the batch-composition-independence contract."""
        scfg = self.config.sampling
        self.frontend_stats = FrontendStats(self.obs, self.instance)
        pred = BatchedPredictor(self.params, self.cfg, config=self.config,
                                rt_cache=self._rt_cache,
                                fault_injector=self._faults, obs=self.obs)
        rt_stats = (self._rt_cache.stats if self._rt_cache is not None
                    else RTCacheStats())
        plans = []                    # (features, strata, sampled) per job
        offset = 0
        for j, job in enumerate(jobs):
            sink: list = []
            d0 = pred.stats.dispatch_seconds
            b0 = rt_stats.build_seconds
            with self.obs.span("engine.job", instance=self.instance,
                               args={"bench": job.bench.name}) as jsp:
                self._functional(job.bench, pred, job, sink=sink)
                feats, strata, sampled = self._feed_sample(pred, sink,
                                                           job, j)
            job.func_seconds = (jsp.seconds - job.oracle_seconds
                                - (pred.stats.dispatch_seconds - d0)
                                - (rt_stats.build_seconds - b0))
            job.offset = offset
            offset += int(sampled.shape[0])
            plans.append((feats, strata, sampled))
        preds = pred.drain()
        if self._rt_cache is not None:
            self._rt_cache.persist()          # no-op without a store_dir
        self.last_stats = pred.stats
        self.last_rt_stats = (rt_stats.freeze()
                              if self._rt_cache is not None else None)
        assert preds.shape[0] == offset == pred.stats.n_predicted, \
            "clip accounting mismatch between sample and predictions"

        results = []
        total_sampled = max(offset, 1)
        for j, (job, (feats, strata, sampled)) in enumerate(
                zip(jobs, plans)):
            n_samp = int(sampled.shape[0])
            mine = preds[job.offset:job.offset + n_samp]
            rep = analytical.fuse_predictions(
                feats, strata, sampled, mine,
                bootstrap_resamples=scfg.bootstrap_resamples,
                seed=scfg.seed, key=j)
            share = n_samp / total_sampled
            results.append(SimResult(
                name=job.bench.name,
                n_intervals=job.n_intervals,
                n_instructions=job.n_instructions,
                n_clips=job.n_clips,
                predicted_cycles=rep.total_cycles,
                oracle_cycles=job.oracle_cycles if self.with_oracle
                else None,
                func_seconds=job.func_seconds,
                predict_seconds=pred.stats.predict_seconds * share,
                oracle_seconds=job.oracle_seconds if self.with_oracle
                else None,
                cycles_ci=rep.cycles_ci,
                clips_predicted=rep.clips_predicted,
                clips_extrapolated=rep.clips_extrapolated,
                clip_provenance=rep.clip_provenance))
        return results

    # ------------------------------ multicore ------------------------------ #

    def run_multicore(self,
                      mbenches: Sequence[multicore.MulticoreBenchmark], *,
                      quantum: Optional[int] = None
                      ) -> List[MulticoreSimResult]:
        """Multicore path: interleaved per-core functional sims ->
        (benchmark, core) clip shards through the SAME pooled
        ``BatchedPredictor`` + shared ``RTCache`` -> demuxed per-core
        ``SimResult``s summed into per-benchmark cycles.

        Clips arrive in per-(core, checkpoint) segments interleaved
        across cores, so demux walks the recorded segment list; per-core
        predicted cycles accumulate one ``float(segment.sum())`` per
        checkpoint — the exact accumulation order the sequential
        reference path (``bench_speed.run_multicore_bench``) mirrors, so
        equality is bitwise, per core and summed.  Each core's context
        matrices carry its ``core_id`` channel
        (``context_tokens_from_matrix(..., core_id=c)``); the oracle is
        ``timing.simulate_multicore`` over the recorded commit
        interleave.
        """
        if self.config.peer_channels:
            raise NotImplementedError(
                "peer_channels serving is reserved (ROADMAP item 8): the "
                "peer-context training channels are not wired into the "
                "trace engine's context layout yet")
        if quantum is None:
            quantum = (self.config.quantum
                       if self.config.quantum is not None
                       else multicore.DEFAULT_QUANTUM)
        if self.config.sampling is not None:
            return self._run_multicore_sampled(mbenches, quantum)
        self.frontend_stats = FrontendStats(self.obs, self.instance)
        pred = BatchedPredictor(self.params, self.cfg, config=self.config,
                                rt_cache=self._rt_cache,
                                fault_injector=self._faults, obs=self.obs)
        rt_stats = (self._rt_cache.stats if self._rt_cache is not None
                    else RTCacheStats())
        all_jobs: List[List[_Job]] = []
        segments: List[Tuple[_Job, int]] = []
        for mb in mbenches:
            cprogs = mb.compiled()
            token_tables = [cp.token_table(self.vocab, self.l_token)
                            for cp in cprogs]
            static_ids = None
            if self._rt_cache is not None:
                # all cores of one program share identical token tables
                # (immediates collapse to <CONST>), so rows dedupe to one
                # RT-table entry set across the whole benchmark
                static_ids = [
                    self._rt_cache.ensure_rows(
                        tt, keys=cp.token_row_keys(self.vocab,
                                                   self.l_token))
                    for cp, tt in zip(cprogs, token_tables)]
            jobs = [_Job(bench=mb, name=f"{mb.name}#c{c}")
                    for c in range(mb.n_cores)]
            all_jobs.append(jobs)
            states = mb.fresh_states()
            d0 = pred.stats.dispatch_seconds
            b0 = rt_stats.build_seconds
            oracle_s = 0.0
            with self.obs.span("engine.job", instance=self.instance,
                               args={"bench": mb.name}) as jsp:
                if self.warmup:
                    with self.obs.span("engine.interpret",
                                       instance=self.instance):
                        multicore.run_multicore(cprogs, self.warmup,
                                                states, quantum=quantum)
                n_ckp = min(mb.ckp_num, self.max_checkpoints)
                for _ in range(n_ckp):
                    with self.obs.span("engine.interpret",
                                       instance=self.instance):
                        mtrace = multicore.run_multicore(
                            cprogs, self.interval_size, states,
                            snapshot_every=self.l_min, quantum=quantum)
                    if len(mtrace) == 0:
                        break
                    for c, trace in enumerate(mtrace.cores):
                        if not len(trace):
                            continue
                        n_clips = self._feed_trace(
                            trace, token_tables[c],
                            static_ids[c] if static_ids is not None
                            else None,
                            pred, jobs[c], core_id=c)
                        segments.append((jobs[c], n_clips))
                    if self.with_oracle:
                        with self.obs.span("engine.oracle",
                                           instance=self.instance) as osp:
                            totals = timing.total_cycles_multicore(
                                mtrace.cores, mtrace.schedule,
                                self.timing_params)
                        dt = osp.seconds
                        oracle_s += dt
                        for c, cyc in enumerate(totals):
                            jobs[c].oracle_cycles += cyc
                            jobs[c].oracle_seconds += dt / mb.n_cores
            mb_seconds = (jsp.seconds - oracle_s
                          - (pred.stats.dispatch_seconds - d0)
                          - (rt_stats.build_seconds - b0))
            mb_clips = max(sum(j.n_clips for j in jobs), 1)
            for job in jobs:
                job.func_seconds = mb_seconds * (job.n_clips / mb_clips)

        preds = pred.drain()
        if self._rt_cache is not None:
            self._rt_cache.persist()          # no-op without a store_dir
        self.last_stats = pred.stats
        self.last_rt_stats = (rt_stats.freeze()
                              if self._rt_cache is not None else None)
        total = sum(n for _, n in segments)
        assert preds.shape[0] == total == pred.stats.n_predicted, \
            "clip accounting mismatch between shards and predictions"
        off = 0
        for job, n in segments:
            job.predicted_cycles += float(preds[off:off + n].sum())
            off += n

        results = []
        total_clips = max(total, 1)
        for mb, jobs in zip(mbenches, all_jobs):
            cores = [SimResult(
                name=job.name,
                n_intervals=job.n_intervals,
                n_instructions=job.n_instructions,
                n_clips=job.n_clips,
                predicted_cycles=job.predicted_cycles,
                oracle_cycles=job.oracle_cycles if self.with_oracle
                else None,
                func_seconds=job.func_seconds,
                predict_seconds=pred.stats.predict_seconds
                * (job.n_clips / total_clips),
                oracle_seconds=job.oracle_seconds if self.with_oracle
                else None) for job in jobs]
            results.append(MulticoreSimResult(
                name=mb.name, n_cores=mb.n_cores, cores=cores))
        return results

    def _run_multicore_sampled(
            self, mbenches: Sequence[multicore.MulticoreBenchmark],
            quantum: int) -> List[MulticoreSimResult]:
        """Fusion path of ``run_multicore()``: each core's clips (all
        checkpoints) collect in a per-core sink, then the per-core
        stratified sample feeds the pooled predictor in core order.
        One ``fuse_predictions`` per (benchmark, core) job; the job key
        counts flattened jobs so every core draws independently but
        reproducibly."""
        scfg = self.config.sampling
        self.frontend_stats = FrontendStats(self.obs, self.instance)
        pred = BatchedPredictor(self.params, self.cfg, config=self.config,
                                rt_cache=self._rt_cache,
                                fault_injector=self._faults, obs=self.obs)
        rt_stats = (self._rt_cache.stats if self._rt_cache is not None
                    else RTCacheStats())
        all_jobs: List[List[_Job]] = []
        plans = []                 # (job, features, strata, sampled)
        offset = 0
        key = 0
        for mb in mbenches:
            cprogs = mb.compiled()
            token_tables = [cp.token_table(self.vocab, self.l_token)
                            for cp in cprogs]
            static_ids = None
            if self._rt_cache is not None:
                static_ids = [
                    self._rt_cache.ensure_rows(
                        tt, keys=cp.token_row_keys(self.vocab,
                                                   self.l_token))
                    for cp, tt in zip(cprogs, token_tables)]
            jobs = [_Job(bench=mb, name=f"{mb.name}#c{c}")
                    for c in range(mb.n_cores)]
            all_jobs.append(jobs)
            sinks: List[list] = [[] for _ in range(mb.n_cores)]
            states = mb.fresh_states()
            d0 = pred.stats.dispatch_seconds
            b0 = rt_stats.build_seconds
            oracle_s = 0.0
            with self.obs.span("engine.job", instance=self.instance,
                               args={"bench": mb.name}) as jsp:
                if self.warmup:
                    with self.obs.span("engine.interpret",
                                       instance=self.instance):
                        multicore.run_multicore(cprogs, self.warmup,
                                                states, quantum=quantum)
                n_ckp = min(mb.ckp_num, self.max_checkpoints)
                for _ in range(n_ckp):
                    with self.obs.span("engine.interpret",
                                       instance=self.instance):
                        mtrace = multicore.run_multicore(
                            cprogs, self.interval_size, states,
                            snapshot_every=self.l_min, quantum=quantum)
                    if len(mtrace) == 0:
                        break
                    for c, trace in enumerate(mtrace.cores):
                        if not len(trace):
                            continue
                        self._feed_trace(
                            trace, token_tables[c],
                            static_ids[c] if static_ids is not None
                            else None,
                            pred, jobs[c], core_id=c, sink=sinks[c])
                    if self.with_oracle:
                        with self.obs.span("engine.oracle",
                                           instance=self.instance) as osp:
                            totals = timing.total_cycles_multicore(
                                mtrace.cores, mtrace.schedule,
                                self.timing_params)
                        dt = osp.seconds
                        oracle_s += dt
                        for c, cyc in enumerate(totals):
                            jobs[c].oracle_cycles += cyc
                            jobs[c].oracle_seconds += dt / mb.n_cores
                for c, job in enumerate(jobs):
                    feats, strata, sampled = self._feed_sample(
                        pred, sinks[c], job, key)
                    key += 1
                    job.offset = offset
                    offset += int(sampled.shape[0])
                    plans.append((job, feats, strata, sampled))
            mb_seconds = (jsp.seconds - oracle_s
                          - (pred.stats.dispatch_seconds - d0)
                          - (rt_stats.build_seconds - b0))
            mb_clips = max(sum(j.n_clips for j in jobs), 1)
            for job in jobs:
                job.func_seconds = mb_seconds * (job.n_clips / mb_clips)

        preds = pred.drain()
        if self._rt_cache is not None:
            self._rt_cache.persist()          # no-op without a store_dir
        self.last_stats = pred.stats
        self.last_rt_stats = (rt_stats.freeze()
                              if self._rt_cache is not None else None)
        assert preds.shape[0] == offset == pred.stats.n_predicted, \
            "clip accounting mismatch between sample and predictions"

        total_sampled = max(offset, 1)
        reports: Dict[int, Tuple[analytical.PredictionReport, int]] = {}
        for k, (job, feats, strata, sampled) in enumerate(plans):
            n_samp = int(sampled.shape[0])
            mine = preds[job.offset:job.offset + n_samp]
            rep = analytical.fuse_predictions(
                feats, strata, sampled, mine,
                bootstrap_resamples=scfg.bootstrap_resamples,
                seed=scfg.seed, key=k)
            reports[id(job)] = (rep, n_samp)

        results = []
        for mb, jobs in zip(mbenches, all_jobs):
            cores = []
            for job in jobs:
                rep, n_samp = reports[id(job)]
                cores.append(SimResult(
                    name=job.name,
                    n_intervals=job.n_intervals,
                    n_instructions=job.n_instructions,
                    n_clips=job.n_clips,
                    predicted_cycles=rep.total_cycles,
                    oracle_cycles=job.oracle_cycles if self.with_oracle
                    else None,
                    func_seconds=job.func_seconds,
                    predict_seconds=pred.stats.predict_seconds
                    * (n_samp / total_sampled),
                    oracle_seconds=job.oracle_seconds if self.with_oracle
                    else None,
                    cycles_ci=rep.cycles_ci,
                    clips_predicted=rep.clips_predicted,
                    clips_extrapolated=rep.clips_extrapolated,
                    clip_provenance=rep.clip_provenance))
            results.append(MulticoreSimResult(
                name=mb.name, n_cores=mb.n_cores, cores=cores))
        return results
