"""CAPSim core — the paper's contribution.

    standardize   instruction -> structured token sequence (Fig 5)
    context       architectural register state -> context matrix (Fig 6)
    slicer        Algorithm 1: timed trace -> code trace clips
    sampler       occurrence-threshold clip sampler (Fig 3/8)
    intervals     SimPoint-style BBV/k-means interval picking
    predictor     the attention performance predictor (Fig 4, Eq 3-9)
    lstm_baseline Ithemal-style hierarchical LSTM (Fig 10 baseline)
    simulate      end-to-end CAPSim vs O3-oracle runs (Fig 1/7)
    engine        batched multi-benchmark simulation engine (shared clip
                  pool, cached-jit bucketed inference, async pipeline)
"""
