"""Post-training int8 weight quantization for inference.

The int8 rung below bf16 on the precision ladder: weights are quantized
per output channel to int8 and immediately dequantized back to float32,
so every matmul still runs in fp32 (measured: XLA's CPU int8 dot is ~5x
SLOWER than f32, so keeping int8 *storage semantics* with fp32 compute is
both the accurate and the fast choice on this backend).  The model
therefore sees exactly the values an int8 deployment would see, and the
engine's ≤1% rel-err gate measures true quantization error.

Per-channel scheme: for a weight of shape (..., d_out) the scale is the
absmax over all axes except the last, one scale per output channel.
1-D leaves (biases, norm gains) are left untouched — standard PTQ
practice, and they carry almost no dynamic range anyway.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

Q_MAX = 127.0


def quantize_dequant(w: jax.Array) -> jax.Array:
    """Fake-quantize one weight to per-channel int8 and back to f32."""
    if w.ndim < 2:
        return w.astype(jnp.float32)
    w = w.astype(jnp.float32)
    axes = tuple(range(w.ndim - 1))
    scale = jnp.max(jnp.abs(w), axis=axes, keepdims=True)
    scale = jnp.where(scale == 0.0, 1.0, scale)
    q = jnp.clip(jnp.round(w / scale * Q_MAX), -Q_MAX, Q_MAX)
    return q * (scale / Q_MAX)


def quantize_dequant_params(params) -> dict:
    """Fake-quantize every ≥2-D leaf of a parameter pytree to int8."""
    return jax.tree_util.tree_map(quantize_dequant, params)
