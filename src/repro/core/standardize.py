"""Standardization transformation (paper §V-A, Fig 5).

Raw assembly instructions become a structured token sequence:

    <REP> <OPCODE> op <DSTS> d... </DSTS> <SRCS> s... </SRCS>
          [<MEM> base <CONST> </MEM>] <END>

- constants are replaced by the token ``<CONST>`` (Fig 5a)
- memory operands get their own segment (Fig 5b)
- implicit control registers (CR written by compares, LR by calls, CTR by
  bdnz, NIA by every branch, CIA read by every branch) are inserted
  manually (Fig 5c) — they are not spelled in the assembly but matter to
  the execution flow
- all four segments are optional; <REP> is the learnable representation
  slot whose encoder output becomes the instruction's ideal-execution-time
  vector (Eq 5-8)

The same vocabulary also covers the context matrix's value tokens
(``<B00>``..``<BFF>``, one per byte; context.py) so one embedding table
serves both streams.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Sequence, Tuple

import numpy as np

from repro.isa.isa import OPCODES, REGS, Instruction

# --------------------------------------------------------------------------- #
# Vocabulary
# --------------------------------------------------------------------------- #

PAD = "<PAD>"
REP = "<REP>"
END = "<END>"
OPCODE = "<OPCODE>"
DSTS, DSTS_E = "<DSTS>", "</DSTS>"
SRCS, SRCS_E = "<SRCS>", "</SRCS>"
MEM, MEM_E = "<MEM>", "</MEM>"
CONST = "<CONST>"

SPECIAL_TOKENS = (PAD, REP, END, OPCODE, DSTS, DSTS_E, SRCS, SRCS_E,
                  MEM, MEM_E, CONST)

BYTE_TOKENS = tuple(f"<B{b:02X}>" for b in range(256))

# Multicore context channel name (context.py): the core-id pseudo-register
# heading one extra 9-token row appended to the context matrix.  Appended
# AFTER the byte tokens so every pre-existing token id is unchanged.
CORE = "<CORE>"


@dataclasses.dataclass(frozen=True)
class Vocab:
    token_to_id: Dict[str, int]
    id_to_token: Tuple[str, ...]

    @property
    def size(self) -> int:
        return len(self.id_to_token)

    def __getitem__(self, tok: str) -> int:
        return self.token_to_id[tok]

    def encode(self, tokens: Sequence[str]) -> List[int]:
        t2i = self.token_to_id
        return [t2i[t] for t in tokens]

    def signature(self) -> str:
        """Content hash of the id -> token mapping.  Any vocabulary change
        (token added, reordered, renamed) yields a new signature — the
        vocab component of the persistent RT store's key."""
        import hashlib
        blob = "\x00".join(self.id_to_token).encode()
        return hashlib.sha256(blob).hexdigest()[:16]


def build_vocab() -> Vocab:
    toks: List[str] = list(SPECIAL_TOKENS)
    toks.extend(sorted(OPCODES))
    toks.extend(REGS)
    toks.extend(BYTE_TOKENS)
    toks.append(CORE)                      # keep last: ids above are frozen
    assert len(set(toks)) == len(toks), "duplicate vocabulary tokens"
    return Vocab(token_to_id={t: i for i, t in enumerate(toks)},
                 id_to_token=tuple(toks))


# The PAD token must be id 0 so zero-padded arrays are valid token ids.
assert SPECIAL_TOKENS[0] == PAD


# --------------------------------------------------------------------------- #
# Instruction -> standardized tokens
# --------------------------------------------------------------------------- #

def standardize(inst: Instruction) -> List[str]:
    """Fig 5 transformation with implicit-register insertion (Fig 5c)."""
    info = inst.info
    toks = [REP, OPCODE, inst.op]

    dsts = list(inst.dsts)
    if info.writes_cr and "CR" not in dsts:
        dsts.append("CR")
    if info.writes_lr and "LR" not in dsts:
        dsts.append("LR")
    if info.uses_ctr and "CTR" not in dsts:
        dsts.append("CTR")
    if info.is_branch and "NIA" not in dsts:
        dsts.append("NIA")
    if dsts:
        toks.append(DSTS)
        toks.extend(dsts)
        toks.append(DSTS_E)

    srcs = list(inst.srcs)
    if inst.op == "bc" and "CR" not in srcs:
        srcs.append("CR")
    if info.uses_ctr and "CTR" not in srcs:
        srcs.append("CTR")
    if inst.op == "blr" and "LR" not in srcs:
        srcs.append("LR")
    if info.is_branch and "CIA" not in srcs:
        srcs.append("CIA")
    has_const = inst.imm is not None or (info.is_branch and
                                         inst.target is not None)
    if srcs or has_const:
        toks.append(SRCS)
        toks.extend(srcs)
        if has_const:
            toks.append(CONST)
        toks.append(SRCS_E)

    if inst.mem_base is not None:
        toks.append(MEM)
        toks.append(inst.mem_base)
        toks.append(CONST)
        toks.append(MEM_E)

    toks.append(END)
    return toks


def max_token_len() -> int:
    """Upper bound on standardized length across the ISA (for L_token)."""
    # <REP> <OPCODE> op + <DSTS> d CR LR CTR NIA </DSTS>
    # + <SRCS> s s s CR CTR LR CIA <CONST> </SRCS> + <MEM> b <CONST> </MEM>
    # + <END>; the practical max over OPCODES is much smaller.
    return 16


def encode_instruction(inst: Instruction, vocab: Vocab,
                       l_token: int) -> np.ndarray:
    """(l_token,) int32, zero (=<PAD>) padded."""
    ids = vocab.encode(standardize(inst))
    assert len(ids) <= l_token, (
        f"standardized length {len(ids)} > L_token={l_token}: "
        f"{standardize(inst)}")
    out = np.zeros(l_token, np.int32)
    out[: len(ids)] = ids
    return out


def encode_clip(insts: Sequence[Instruction], vocab: Vocab, l_clip: int,
                l_token: int) -> Tuple[np.ndarray, np.ndarray]:
    """((l_clip, l_token) int32 tokens, (l_clip,) float32 mask)."""
    toks = np.zeros((l_clip, l_token), np.int32)
    mask = np.zeros(l_clip, np.float32)
    n = min(len(insts), l_clip)
    for i in range(n):
        toks[i] = encode_instruction(insts[i], vocab, l_token)
        mask[i] = 1.0
    return toks, mask


# --------------------------------------------------------------------------- #
# Batched clip encoding
# --------------------------------------------------------------------------- #

def _inst_key(inst: Instruction) -> tuple:
    """Everything ``standardize`` reads: constants and memory offsets only
    matter through their presence (Fig 5a), so instructions collapse onto a
    small set of shapes — traces are loopy and the hit rate is ~99%."""
    return (inst.op, inst.dsts, inst.srcs, inst.imm is not None,
            inst.mem_base, inst.target is not None)


class ClipEncoder:
    """Vectorized batch path over ``encode_clip`` with a standardized-row
    memo.  ``encode(clips)`` returns the same bits as stacking
    ``encode_clip`` per clip; the memo turns the per-instruction dict walks
    of ``standardize`` into a single tuple-key lookup."""

    def __init__(self, vocab: Vocab, l_clip: int, l_token: int):
        self.vocab = vocab
        self.l_clip = l_clip
        self.l_token = l_token
        self._memo: Dict[tuple, np.ndarray] = {}

    def encode_row(self, inst: Instruction) -> np.ndarray:
        key = _inst_key(inst)
        row = self._memo.get(key)
        if row is None:
            row = encode_instruction(inst, self.vocab, self.l_token)
            row.setflags(write=False)
            self._memo[key] = row
        return row

    def encode(self, clips: Sequence[Sequence[Instruction]]
               ) -> Tuple[np.ndarray, np.ndarray]:
        """((N, l_clip, l_token) int32 tokens, (N, l_clip) float32 mask)."""
        n = len(clips)
        toks = np.zeros((n, self.l_clip, self.l_token), np.int32)
        mask = np.zeros((n, self.l_clip), np.float32)
        for ci, insts in enumerate(clips):
            k = min(len(insts), self.l_clip)
            for i in range(k):
                toks[ci, i] = self.encode_row(insts[i])
            mask[ci, :k] = 1.0
        return toks, mask


def encode_clips(clips: Sequence[Sequence[Instruction]], vocab: Vocab,
                 l_clip: int, l_token: int) -> Tuple[np.ndarray, np.ndarray]:
    """One-shot batch encode (fresh memo) over object clips.  The engine
    itself tokenizes via the columnar gather path below; this object
    path remains for ad-hoc callers and differential tests."""
    return ClipEncoder(vocab, l_clip, l_token).encode(clips)


# --------------------------------------------------------------------------- #
# Columnar gather path
# --------------------------------------------------------------------------- #
#
# Standardization depends only on the *static* instruction, so a
# ``CompiledProgram.token_table(vocab, l_token)`` row gathered by trace pc
# is bitwise the row ``encode_instruction`` would produce.  Tokenizing a
# fixed-sliced trace then needs no per-instruction Python at all: one
# fancy-index gather plus a reshape.

def encode_fixed_clips(token_table: np.ndarray, pcs: np.ndarray,
                       l_min: int, l_clip: int
                       ) -> Tuple[np.ndarray, np.ndarray]:
    """Gather-tokenize a fixed-sliced columnar trace.

    ``token_table`` is the program's ``(n_static, l_token)`` table and
    ``pcs`` the trace pc column; clips are the ``slice_fixed`` partition
    (``l_min`` windows + remainder).  Returns the same
    ``((n_clips, l_clip, l_token) int32, (n_clips, l_clip) float32)``
    bits as ``ClipEncoder.encode`` over the object clips.
    """
    l_token = token_table.shape[1]
    n = pcs.shape[0]
    k_full, rem = n // l_min, n % l_min
    n_clips = k_full + (1 if rem else 0)
    toks = np.zeros((n_clips, l_clip, l_token), np.int32)
    mask = np.zeros((n_clips, l_clip), np.float32)
    rows = token_table[pcs]
    w = min(l_min, l_clip)
    if k_full:
        full = rows[: k_full * l_min].reshape(k_full, l_min, l_token)
        toks[:k_full, :w] = full[:, :w]
        mask[:k_full, :w] = 1.0
    if rem:
        r = min(rem, l_clip)
        toks[k_full, :r] = rows[n - rem: n - rem + r]
        mask[k_full, :r] = 1.0
    return toks, mask


def gather_bounded_clip(rows: np.ndarray, start: int, end: int,
                        lead_dup: bool, l_clip: int) -> np.ndarray:
    """Token rows for one Algorithm-1-bounded clip, truncated to
    ``l_clip``.  ``lead_dup`` reproduces the slicer's quirk: Algorithm 1
    seeds its block with I[0], so the interval's clip 0 carries a
    duplicated leading instruction."""
    body = rows[start:end]
    if lead_dup:
        body = np.concatenate([rows[:1], body])
    return body[:l_clip]


def bounded_clip_keys(rows: np.ndarray, bounds: np.ndarray) -> List[bytes]:
    """Sampler content keys for Algorithm-1-bounded clips: the bytes of
    each clip's (untruncated) gathered standardized-token rows — exactly
    what Fig-5 standardization preserves of the instructions.  Shared by
    the single- and multicore dataset builds so the occurrence sampler
    sees identical keys through either."""
    n = rows.shape[0]
    return [gather_bounded_clip(rows, int(s), int(e), j == 0,
                                max(n + 1, 1)).tobytes()
            for j, (s, e) in enumerate(bounds)]


def encode_bounded_clips(rows: np.ndarray, bounds: np.ndarray,
                         keep: Sequence[int], l_clip: int
                         ) -> Tuple[np.ndarray, np.ndarray]:
    """Tokenize the kept Algorithm-1 clips of one interval trace.

    ``rows`` is the trace's gathered ``token_table[trace.pc]`` matrix,
    ``bounds`` the ``(k, 2)`` Algorithm-1 bounds, ``keep`` the sampler's
    surviving clip indices.  Returns ``((n_keep, l_clip, l_token) int32,
    (n_keep, l_clip) float32)`` — the bounded-slicing analogue of
    ``encode_fixed_clips``, shared by the single- and multicore builds.
    """
    l_token = rows.shape[1]
    toks = np.zeros((len(keep), l_clip, l_token), np.int32)
    mask = np.zeros((len(keep), l_clip), np.float32)
    for row_i, j in enumerate(keep):
        body = gather_bounded_clip(rows, int(bounds[j, 0]),
                                   int(bounds[j, 1]), j == 0, l_clip)
        k = body.shape[0]
        toks[row_i, :k] = body
        mask[row_i, :k] = 1.0
    return toks, mask


def dedupe_token_rows(rows: np.ndarray) -> Tuple[np.ndarray, np.ndarray]:
    """Content-dedupe standardized token rows: (k, l_token) ->
    ``(uniq (n_unique, l_token) int32, inverse (k,) int32)`` with
    ``uniq[inverse]`` bitwise equal to ``rows``.

    Token ids are non-negative, so when an all-<PAD> (zero) row is present
    it lexicographically sorts to local id 0 — the convention the RT
    cache's pad slot and ``data.dataset.indexed_clips`` both rely on.
    """
    uniq, inv = np.unique(rows, axis=0, return_inverse=True)
    return (np.ascontiguousarray(uniq, np.int32),
            inv.reshape(rows.shape[0]).astype(np.int32))


def dedup_bucket(n: int, cap: int) -> int:
    """Smallest ladder bucket (32, 48, 64, 96, 128, 192, 256, ...) that
    holds ``n`` unique tokens, capped at ``cap``.  The 1.5x/1.33x ladder
    keeps the fused serving path's jit-shape count small while wasting at
    most ~50% padding over the true unique count."""
    b = 32
    while b < n:
        b = b * 3 // 2 if (b & (b - 1)) == 0 else (b // 3) * 4
    return min(b, cap)


def dedupe_context_tokens(ctx: np.ndarray, bucket: int = None
                          ) -> Tuple[np.ndarray, np.ndarray]:
    """Dedupe each context row's token ids into (unique ids, counts).

    ctx: (n, M) int32 token ids.  Returns ``(uniq (n, U) int32,
    counts (n, U) float32)`` with ``counts[i].sum() == M`` for every row
    and unused slots carrying id 0 / count 0.  U is ``bucket`` when given
    (ValueError if any row has more uniques), else the auto
    ``dedup_bucket`` size for the batch's max unique count.

    The block encoder adds no positional encoding to the context stream,
    so it is permutation-equivariant over context rows: attending over a
    token that occurs c times equals attending over ONE copy whose
    exponentiated score carries weight c (kernels/fused_serving).  This
    host-side dedupe is what turns the fused serving step's M=360
    attention into a ~U=64-128 attention.
    """
    ctx = np.ascontiguousarray(ctx, np.int32)
    n, m = ctx.shape
    srt = np.sort(ctx, axis=1)
    first = np.ones((n, m), bool)
    first[:, 1:] = srt[:, 1:] != srt[:, :-1]
    max_u = int(first.sum(1).max()) if n else 1
    if bucket is None:
        bucket = dedup_bucket(max_u, m)
    elif max_u > bucket:
        raise ValueError(
            f"context row has {max_u} unique tokens > bucket {bucket}")
    rank = np.cumsum(first, axis=1) - 1                  # unique slot per elt
    rows = np.arange(n)[:, None]
    uniq = np.zeros((n, bucket), np.int32)
    counts = np.zeros((n, bucket), np.float32)
    uniq[rows, rank] = srt          # duplicate writes carry the same value
    np.add.at(counts, (rows, rank), 1.0)
    return uniq, counts


def fixed_clip_indices(static_ids: np.ndarray, pcs: np.ndarray,
                       l_min: int, l_clip: int, pad_id: int = 0
                       ) -> Tuple[np.ndarray, np.ndarray]:
    """RT-cache analogue of ``encode_fixed_clips``: same ``slice_fixed``
    partition and mask, but each instruction becomes one int32 RT-table
    row id instead of an (l_token,) token row — the front-end never
    materializes token tensors at all.

    ``static_ids`` maps static pc -> global RT row id (from
    ``RTCache.ensure_rows`` over the program's token table); ``pad_id``
    (default 0, the cache's all-<PAD> row) fills masked slots.  Returns
    ``((n_clips, l_clip) int32 rt_idx, (n_clips, l_clip) float32 mask)``
    with mask bitwise equal to the ``encode_fixed_clips`` mask.
    """
    n = pcs.shape[0]
    k_full, rem = n // l_min, n % l_min
    n_clips = k_full + (1 if rem else 0)
    idx = np.full((n_clips, l_clip), pad_id, np.int32)
    mask = np.zeros((n_clips, l_clip), np.float32)
    ids = static_ids[pcs]
    w = min(l_min, l_clip)
    if k_full:
        idx[:k_full, :w] = ids[: k_full * l_min].reshape(k_full, l_min)[:, :w]
        mask[:k_full, :w] = 1.0
    if rem:
        r = min(rem, l_clip)
        idx[k_full, :r] = ids[n - rem: n - rem + r]
        mask[k_full, :r] = 1.0
    return idx, mask
