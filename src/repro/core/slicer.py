"""Instruction sequence slicer (paper §IV-A, Algorithm 1).

Cuts a committed instruction trace into *code trace clips*.  A clip closes
once (a) it holds at least ``l_min`` instructions AND (b) the current
commit time differs from the previous instruction's commit time — so a
clip boundary never splits a group of instructions that committed in the
same cycle, which keeps the clip runtime well defined (the paper's two
principles).  The clip's ground-truth runtime is the difference between
the previous commit time and the clip's begin time.

At inference CAPSim has no commit times (the functional simulator is
atomic), so ``slice_fixed`` cuts every ``l_min`` instructions; the
commit-boundary rule exists to make *training* targets exact.

Columnar path: on a ``repro.isa.compiled.Trace`` a clip is just a
``(start, end)`` view into the trace columns, so ``fixed_bounds`` and
``slice_trace_columnar`` return ``(k, 2)`` bound arrays (plus times)
instead of materialized ``Clip`` objects — ``slice_trace_columnar`` finds
commit-time boundaries with one ``np.diff`` and a greedy pass over the
(few) change points.  ``clips_from_columnar`` is the object adapter.
"""
from __future__ import annotations

import dataclasses
from typing import List, Optional, Sequence, Tuple

import numpy as np

from repro.isa.isa import Instruction


@dataclasses.dataclass
class Clip:
    insts: List[Instruction]
    time: float                 # runtime in cycles (0.0 when unknown)
    start: int                  # trace position of first instruction
    # content key for the sampler (None = not yet computed; a computed
    # key may legitimately be 0, so 0 must not double as the sentinel)
    _key: Optional[int] = None

    def __len__(self) -> int:
        return len(self.insts)

    @property
    def key(self) -> int:
        if self._key is None:
            self._key = hash(tuple(
                (i.op, i.dsts, i.srcs, i.imm is not None,
                 i.mem_base) for i in self.insts))
        return self._key


def slice_trace(insts: Sequence[Instruction],
                commit_times: Sequence[float],
                l_min: int) -> List[Clip]:
    """Algorithm 1.  ``commit_times[i]`` is instruction i's commit cycle."""
    assert len(insts) == len(commit_times)
    clips: List[Clip] = []
    if not insts:
        return clips
    b: List[Instruction] = []
    b_start = 0
    inst_prev = insts[0]
    block_length = 0
    time_prev = 0.0
    time_begin = 0.0
    for idx in range(len(insts)):
        inst_now = insts[idx]
        time_now = float(commit_times[idx])
        b.append(inst_prev)
        block_length += 1
        if block_length >= l_min and time_now != time_prev:
            clips.append(Clip(insts=b, time=time_prev - time_begin,
                              start=b_start))
            time_begin = time_prev
            b = []
            b_start = idx
            block_length = 0
        inst_prev = inst_now
        time_prev = time_now
    return clips


def slice_fixed(insts: Sequence[Instruction], l_min: int) -> List[Clip]:
    """Fixed-length slicing for inference (no commit times available)."""
    clips = []
    for off in range(0, len(insts) - l_min + 1, l_min):
        clips.append(Clip(insts=list(insts[off: off + l_min]), time=0.0,
                          start=off))
    rem = len(insts) % l_min
    if rem:
        off = len(insts) - rem
        clips.append(Clip(insts=list(insts[off:]), time=0.0, start=off))
    return clips


def clip_boundaries(clips: Sequence[Clip]) -> List[int]:
    return [c.start for c in clips]


def total_time(clips: Sequence[Clip]) -> float:
    return sum(c.time for c in clips)


# --------------------------------------------------------------------------- #
# Columnar slicing: clips as (start, end) bounds into trace columns
# --------------------------------------------------------------------------- #

def fixed_bounds(n: int, l_min: int) -> np.ndarray:
    """``slice_fixed`` bounds: ``(k, 2) int64`` rows of (start, end).

    Same clip partition as ``slice_fixed`` over an ``n``-entry trace:
    full ``l_min`` windows plus one remainder clip.
    """
    starts = np.arange(0, max(n - l_min + 1, 0), l_min, dtype=np.int64)
    ends = starts + l_min
    rem = n % l_min
    if rem:
        starts = np.append(starts, n - rem)
        ends = np.append(ends, n)
    return np.stack([starts, ends], axis=1)


def _slice_commit_column(commit_times: np.ndarray, l_min: int,
                         include_tail: bool
                         ) -> Tuple[np.ndarray, np.ndarray]:
    """Shared Algorithm-1 core over one commit-cycle column.

    With ``include_tail`` the residue after the final Algorithm-1 close
    (the block that never reaches ``l_min`` *and* a commit change point)
    becomes one extra closing clip, so the bounds partition the whole
    trace and the clip times telescope to ``commit[-1]`` exactly — the
    multicore training-target mode.  Without it, the residue is dropped,
    matching ``slice_trace`` / the paper's Algorithm 1 verbatim.
    """
    c = np.asarray(commit_times, np.float64)
    n = c.shape[0]
    if n == 0:
        return np.zeros((0, 2), np.int64), np.zeros(0, np.float64)
    changes = np.flatnonzero(np.diff(c) != 0.0) + 1
    if c[0] != 0.0:                            # time_prev starts at 0.0
        changes = np.concatenate([[0], changes])
    closes: List[int] = []
    last = -1
    for idx in changes.tolist():
        if idx - last >= l_min:                # block_length == idx - last
            closes.append(idx)
            last = idx
    if include_tail and last < n:
        closes.append(n)                       # residue clip, < l_min ok
    k = len(closes)
    if k == 0:
        return np.zeros((0, 2), np.int64), np.zeros(0, np.float64)
    ends = np.asarray(closes, np.int64)
    starts = np.concatenate([[0], ends[:-1]])
    # clip j runtime telescopes between the commit times just before the
    # closes; time_begin is 0.0 before the first close
    prev_commit = np.where(ends >= 1, c[np.maximum(ends - 1, 0)], 0.0)
    times = np.diff(np.concatenate([[0.0], prev_commit]))
    return np.stack([starts, ends], axis=1), times


def slice_trace_columnar(commit_times: np.ndarray, l_min: int
                         ) -> Tuple[np.ndarray, np.ndarray]:
    """Columnar Algorithm 1 over a commit-cycle column.

    Returns ``(bounds, times)``: ``bounds[j] = (start, end)`` indexes the
    trace columns and ``times[j]`` is the clip runtime.  Equivalent to
    ``slice_trace`` with one quirk inherited from it: Algorithm 1 seeds
    the block with I[0], so clip 0 additionally carries a duplicated
    leading instruction (``clips_from_columnar`` reproduces it; bounds
    alone describe clips 1..k-1 exactly).

    A clip closes at trace position ``idx`` when the block holds at
    least ``l_min`` instructions and ``commit[idx] != commit[idx-1]`` —
    i.e. at a commit-time *change point*, found here with ``np.diff``;
    the greedy selection walks only the change points, not the trace.
    """
    return _slice_commit_column(commit_times, l_min, include_tail=False)


def slice_multicore_columnar(commits: Sequence[np.ndarray], l_min: int,
                             include_tail: bool = False
                             ) -> List[Tuple[np.ndarray, np.ndarray]]:
    """Per-core Algorithm-1 slicing over multicore commit columns.

    ``commits`` is ``timing.simulate_multicore``'s output: one commit-
    cycle column per core, in the shared-resource interleave.  Each core
    slices independently — clip boundaries are core-local commit events,
    so a clip's runtime is that core's commit-cycle delta *including* any
    LLC/bus stalls other cores inflicted on it — which is exactly the
    contention signal the multicore training targets must price.

    Returns one ``(bounds, times)`` pair per core (``slice_trace_columnar``
    semantics, duplicated-lead quirk included).  ``include_tail`` closes
    the sub-``l_min`` residue block after each core's final Algorithm-1
    boundary as one extra clip, making the bounds cover the core's whole
    trace and ``times`` sum to the core's total cycles (``commit[-1]``);
    the default drops the residue, bitwise matching the single-core
    training slicer — the ``N=1 == build_dataset`` anchor.
    """
    return [_slice_commit_column(c, l_min, include_tail) for c in commits]


def clip_lengths(bounds: np.ndarray) -> np.ndarray:
    """Instruction count per columnar clip (clip 0 carries the
    duplicated leading instruction — see ``slice_trace_columnar``)."""
    lens = bounds[:, 1] - bounds[:, 0]
    if len(lens):
        lens = lens.copy()
        lens[0] += 1
    return lens


def clips_from_columnar(insts: Sequence[Instruction], bounds: np.ndarray,
                        times: Optional[np.ndarray] = None) -> List[Clip]:
    """Object adapter: materialize ``Clip``s from columnar bounds
    (matches ``slice_trace`` bit for bit, duplicated lead included)."""
    out: List[Clip] = []
    for j in range(bounds.shape[0]):
        s, e = int(bounds[j, 0]), int(bounds[j, 1])
        body = list(insts[s:e])
        if j == 0:
            body = [insts[0]] + body
        out.append(Clip(insts=body,
                        time=float(times[j]) if times is not None else 0.0,
                        start=s))
    return out
