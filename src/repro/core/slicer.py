"""Instruction sequence slicer (paper §IV-A, Algorithm 1).

Cuts a committed instruction trace into *code trace clips*.  A clip closes
once (a) it holds at least ``l_min`` instructions AND (b) the current
commit time differs from the previous instruction's commit time — so a
clip boundary never splits a group of instructions that committed in the
same cycle, which keeps the clip runtime well defined (the paper's two
principles).  The clip's ground-truth runtime is the difference between
the previous commit time and the clip's begin time.

At inference CAPSim has no commit times (the functional simulator is
atomic), so ``slice_fixed`` cuts every ``l_min`` instructions; the
commit-boundary rule exists to make *training* targets exact.
"""
from __future__ import annotations

import dataclasses
from typing import List, Sequence, Tuple

from repro.isa.isa import Instruction


@dataclasses.dataclass
class Clip:
    insts: List[Instruction]
    time: float                 # runtime in cycles (0.0 when unknown)
    start: int                  # trace position of first instruction
    # content key for the sampler (filled lazily)
    _key: int = 0

    def __len__(self) -> int:
        return len(self.insts)

    @property
    def key(self) -> int:
        if self._key == 0:
            self._key = hash(tuple(
                (i.op, i.dsts, i.srcs, i.imm is not None,
                 i.mem_base) for i in self.insts))
        return self._key


def slice_trace(insts: Sequence[Instruction],
                commit_times: Sequence[float],
                l_min: int) -> List[Clip]:
    """Algorithm 1.  ``commit_times[i]`` is instruction i's commit cycle."""
    assert len(insts) == len(commit_times)
    clips: List[Clip] = []
    if not insts:
        return clips
    b: List[Instruction] = []
    b_start = 0
    inst_prev = insts[0]
    block_length = 0
    time_prev = 0.0
    time_begin = 0.0
    for idx in range(len(insts)):
        inst_now = insts[idx]
        time_now = float(commit_times[idx])
        b.append(inst_prev)
        block_length += 1
        if block_length >= l_min and time_now != time_prev:
            clips.append(Clip(insts=b, time=time_prev - time_begin,
                              start=b_start))
            time_begin = time_prev
            b = []
            b_start = idx
            block_length = 0
        inst_prev = inst_now
        time_prev = time_now
    return clips


def slice_fixed(insts: Sequence[Instruction], l_min: int) -> List[Clip]:
    """Fixed-length slicing for inference (no commit times available)."""
    clips = []
    for off in range(0, len(insts) - l_min + 1, l_min):
        clips.append(Clip(insts=list(insts[off: off + l_min]), time=0.0,
                          start=off))
    rem = len(insts) % l_min
    if rem:
        off = len(insts) - rem
        clips.append(Clip(insts=list(insts[off:]), time=0.0, start=off))
    return clips


def clip_boundaries(clips: Sequence[Clip]) -> List[int]:
    return [c.start for c in clips]


def total_time(clips: Sequence[Clip]) -> float:
    return sum(c.time for c in clips)
