"""EngineConfig: the one construction surface for the inference engines.

Every serving entry point — ``SimulationEngine`` / ``BatchedPredictor``
(core), ``PredictorEngine`` (serving), ``capsim_simulate`` /
``capsim_simulate_multicore`` (wrappers), and ``launch/serve.py`` — used
to re-declare the same knob set as loose keyword arguments, so adding an
axis (precision, RT cache, multicore N, and now the device mesh) meant
threading one more kwarg through five signatures.  ``EngineConfig``
collapses them into a single frozen dataclass: sharding is a config
*axis*, not another kwarg.

Field groups:

  mesh          ``mesh_shape`` — data-parallel device mesh for predict
                AND RT-cache encode dispatch.  ``()`` (default) is the
                unsharded single-device path; ``(n,)`` (or any shape
                whose product is n) shards clip batches n ways via
                ``shard_map`` over a 1-D "data" mesh — bitwise equal to
                unsharded because clips are row-independent.
  numerics      ``precision`` (None keeps cfg.dtype; the ladder is
                "fp32" bitwise -> "bf16" ≤1% rel err -> "int8"
                per-channel weight quant, fp32 compute, ≤1% rel err),
                ``rt_cache``, ``use_context``, ``fused_serving`` (the
                dedup-fused block-encoder serving step; requires
                rt_cache + use_context, tolerance-gated ≤1e-3 vs the
                unfused path), ``rt_store_dir`` (persistent
                content-addressed RT-cache store; None = in-memory
                only).  Precision is validated HERE at construction,
                not at first dispatch inside ``inference_config``.
  batching      ``batch_size`` (must divide by the mesh size so no
                shard is ever empty), ``max_in_flight``.
  trace scale   ``interval_size``, ``warmup``, ``max_checkpoints``,
                ``l_min``, ``l_clip``, ``l_token``, ``with_oracle``.
  multicore     ``multicore`` (N cores; 0 = single-core suite),
                ``quantum`` (None = scheduler default),
                ``peer_channels`` (peer-context serving — reserved,
                ROADMAP item 8).
  faults        ``faults`` — chaos-engineering fault-injection spec, a
                tuple of ``(kind, rate)`` pairs (``FAULT_KINDS`` below)
                consumed by ``repro.serving.faults.FaultInjector`` and
                honored by the REAL engine stack (``BatchedPredictor``
                dispatch/retire, ``RTCache`` store load/persist), so
                chaos tests and ``bench_serving.py`` exercise the same
                code paths production traffic does.  ``()`` (default)
                injects nothing and costs nothing.  ``fault_seed``
                makes every injection schedule deterministic.
  sampling      ``sampling`` — opt-in analytical-ML fusion mode: a
                nested ``SamplingConfig`` (or an equivalent mapping; a
                JSON round trip hands one back).  Only a stratified
                sample of each benchmark's clips runs through the
                attention predictor; the rest are extrapolated from a
                ridge fit over per-clip analytical features
                (``repro.core.analytical``) with a bootstrap confidence
                interval over the stratified estimate.  ``None``
                (default) preserves the exact full-prediction path
                bitwise.
  observability ``observability`` — a nested ``ObservabilityConfig``
                (or mapping) enabling span tracing and the degradation
                flight recorder (``repro.obs``).  The metrics registry
                is always on; ``None`` (default) just means no trace
                ring and no postmortem files.

The config is JSON round-trippable (``to_json``/``from_json``) so one
``--engine-config`` flag can drive every bench pass and CI leg.  The
pre-PR-6 loose keyword signatures are fully retired: any extra keyword
on an entry point raises ``TypeError`` (``reject_legacy_kwargs``)
pointing at the ``EngineConfig`` field to use instead.
"""
from __future__ import annotations

import dataclasses
import json
from typing import Any, Dict, Mapping, Optional, Tuple

PRECISIONS = (None, "fp32", "bf16", "int8")

# Injectable fault kinds (see repro/serving/faults.py for what each does
# and README's failure-mode table for the expected recovery):
#   device_error     predict dispatch raises (transient device failure)
#   nan_output       a dispatched batch's predictions come back non-finite
#   slow_flush       a dispatch stalls (stuck device / runaway compile)
#   corrupt_rt_read  a persistent RT-store read returns corrupt data
#   crash_persist    the process "dies" mid RTCache.persist (before the
#                    atomic publish, so the previous store must survive)
FAULT_KINDS = ("device_error", "nan_output", "slow_flush",
               "corrupt_rt_read", "crash_persist")


@dataclasses.dataclass(frozen=True)
class SamplingConfig:
    """Stratified clip-subsampling knobs for the analytical-ML fusion
    path (``EngineConfig.sampling``).

    ``fraction``: target share of each stratum's clips that run through
    the attention predictor (``1.0`` samples everything and is bitwise
    the unsampled engine).  ``strata``: number of quantile bins of the
    analytical cycle estimate per benchmark.  ``min_clips_per_stratum``
    floors every non-empty stratum's sample so rare-but-expensive
    strata are never extrapolated blind.  ``bootstrap_resamples``:
    within-stratum bootstrap replicates behind the 95% ``cycles_ci``
    (``0`` degenerates the CI to a point).  ``seed`` drives every
    selection and resample deterministically.
    """

    fraction: float = 0.1
    strata: int = 4
    seed: int = 0
    min_clips_per_stratum: int = 2
    bootstrap_resamples: int = 200

    def __post_init__(self):
        self.validate()

    def validate(self) -> None:
        if not 0.0 < self.fraction <= 1.0:
            raise ValueError(
                f"sampling fraction must be in (0, 1], "
                f"got {self.fraction}")
        if self.strata < 1:
            raise ValueError(f"strata must be >= 1, got {self.strata}")
        if self.min_clips_per_stratum < 1:
            raise ValueError(
                f"min_clips_per_stratum must be >= 1, "
                f"got {self.min_clips_per_stratum}")
        if self.bootstrap_resamples < 0:
            raise ValueError(
                f"bootstrap_resamples must be >= 0, "
                f"got {self.bootstrap_resamples}")

    def replace(self, **kw) -> "SamplingConfig":
        return dataclasses.replace(self, **kw)

    def to_dict(self) -> Dict[str, Any]:
        return dataclasses.asdict(self)

    @classmethod
    def from_dict(cls, data: Mapping[str, Any]) -> "SamplingConfig":
        fields = {f.name for f in dataclasses.fields(cls)}
        unknown = set(data) - fields
        if unknown:
            raise ValueError(
                f"unknown SamplingConfig fields {sorted(unknown)} "
                f"(known: {sorted(fields)})")
        return cls(**dict(data))


@dataclasses.dataclass(frozen=True)
class ObservabilityConfig:
    """Observability knobs (``EngineConfig.observability``).

    The metrics registry is always on — it replaced the ad-hoc Stats
    accumulators, so it costs what they cost.  ``trace`` opts into the
    span tracer (a private ``repro.obs.Tracer`` ring of ``trace_ring``
    spans, Chrome-trace exportable); disabled tracing allocates nothing
    on the span path.  ``flight_dir`` opts into the degradation flight
    recorder: the last ``flight_events`` structured events and
    ``flight_spans`` trace spans are frozen into an atomic postmortem
    JSON under that directory whenever the service demotes a tier, the
    watchdog abandons a flush, or a persist fault fires.
    """

    trace: bool = False
    trace_ring: int = 4096
    flight_dir: Optional[str] = None
    flight_spans: int = 256
    flight_events: int = 512

    def __post_init__(self):
        self.validate()

    def validate(self) -> None:
        if self.trace_ring < 1:
            raise ValueError(
                f"trace_ring must be >= 1, got {self.trace_ring}")
        if self.flight_spans < 0:
            raise ValueError(
                f"flight_spans must be >= 0, got {self.flight_spans}")
        if self.flight_events < 1:
            raise ValueError(
                f"flight_events must be >= 1, got {self.flight_events}")
        if self.flight_dir is not None and not isinstance(
                self.flight_dir, str):
            raise ValueError(
                f"flight_dir must be a path string or None, "
                f"got {self.flight_dir!r}")

    def replace(self, **kw) -> "ObservabilityConfig":
        return dataclasses.replace(self, **kw)

    def to_dict(self) -> Dict[str, Any]:
        return dataclasses.asdict(self)

    @classmethod
    def from_dict(cls, data: Mapping[str, Any]) -> "ObservabilityConfig":
        fields = {f.name for f in dataclasses.fields(cls)}
        unknown = set(data) - fields
        if unknown:
            raise ValueError(
                f"unknown ObservabilityConfig fields {sorted(unknown)} "
                f"(known: {sorted(fields)})")
        return cls(**dict(data))


@dataclasses.dataclass(frozen=True)
class EngineConfig:
    # --- mesh ---
    mesh_shape: Tuple[int, ...] = ()
    # --- numerics / caching ---
    precision: Optional[str] = None
    rt_cache: bool = True
    use_context: bool = True
    fused_serving: bool = False
    rt_store_dir: Optional[str] = None
    # --- batching ---
    batch_size: int = 256
    max_in_flight: int = 2
    # --- trace scale ---
    interval_size: int = 20_000
    warmup: int = 2_000
    max_checkpoints: int = 4
    l_min: int = 100
    l_clip: int = 128
    l_token: int = 16
    with_oracle: bool = True
    # --- multicore ---
    multicore: int = 0
    quantum: Optional[int] = None
    peer_channels: bool = False
    # --- fault injection (chaos) ---
    faults: Tuple[Tuple[str, float], ...] = ()
    fault_seed: int = 0
    # --- analytical-ML fusion (None = full prediction, bitwise) ---
    sampling: Optional[SamplingConfig] = None
    # --- observability (None = metrics only: no tracing, no flight) ---
    observability: Optional[ObservabilityConfig] = None

    def __post_init__(self):
        # normalize mesh_shape so (config equality == behavior equality)
        # survives JSON round trips (lists) and scalar convenience input
        shape = self.mesh_shape
        if isinstance(shape, int):
            shape = (shape,)
        object.__setattr__(self, "mesh_shape", tuple(int(s) for s in shape))
        # normalize faults the same way: JSON lists / dicts of
        # {kind: rate} all collapse to one sorted tuple-of-pairs form
        faults = self.faults
        if isinstance(faults, Mapping):
            faults = faults.items()
        object.__setattr__(
            self, "faults",
            tuple(sorted((str(k), float(r)) for k, r in faults)))
        # normalize sampling: a JSON round trip hands back a mapping
        if isinstance(self.sampling, Mapping):
            object.__setattr__(self, "sampling",
                               SamplingConfig.from_dict(self.sampling))
        if isinstance(self.observability, Mapping):
            object.__setattr__(
                self, "observability",
                ObservabilityConfig.from_dict(self.observability))
        self.validate()

    @property
    def n_shards(self) -> int:
        """Data-parallel shard count: 0 = no mesh (unsharded path); a
        1-device mesh (``(1,)``) still dispatches through shard_map."""
        if not self.mesh_shape:
            return 0
        n = 1
        for s in self.mesh_shape:
            n *= s
        return n

    def validate(self) -> None:
        if any(s < 1 for s in self.mesh_shape):
            raise ValueError(
                f"mesh_shape must be positive, got {self.mesh_shape}")
        if self.precision not in PRECISIONS:
            raise ValueError(
                f"precision must be one of {PRECISIONS}, "
                f"got {self.precision!r}")
        if self.fused_serving and not (self.rt_cache and self.use_context):
            raise ValueError(
                "fused_serving requires rt_cache=True and "
                "use_context=True (the fused step is the RT-gather + "
                "context block encoder)")
        if self.rt_store_dir is not None and not isinstance(
                self.rt_store_dir, str):
            raise ValueError(
                f"rt_store_dir must be a path string or None, "
                f"got {self.rt_store_dir!r}")
        if self.batch_size < 1:
            raise ValueError(f"batch_size must be >= 1, "
                             f"got {self.batch_size}")
        n = self.n_shards
        if n and self.batch_size % n:
            raise ValueError(
                f"batch_size {self.batch_size} must divide by the mesh "
                f"size {n} so no device ever receives an empty shard")
        if self.multicore < 0:
            raise ValueError(f"multicore must be >= 0, "
                             f"got {self.multicore}")
        if self.peer_channels and self.multicore < 1:
            raise ValueError("peer_channels requires multicore >= 1")
        if self.quantum is not None and self.quantum < 1:
            raise ValueError(f"quantum must be >= 1, got {self.quantum}")
        seen = set()
        for kind, rate in self.faults:
            if kind not in FAULT_KINDS:
                raise ValueError(
                    f"unknown fault kind {kind!r} "
                    f"(known: {list(FAULT_KINDS)})")
            if kind in seen:
                raise ValueError(f"duplicate fault kind {kind!r}")
            seen.add(kind)
            if not 0.0 <= rate <= 1.0:
                raise ValueError(
                    f"fault rate for {kind!r} must be in [0, 1], "
                    f"got {rate}")
        if self.sampling is not None and not isinstance(self.sampling,
                                                        SamplingConfig):
            raise ValueError(
                f"sampling must be a SamplingConfig (or a mapping of "
                f"its fields) or None, got {self.sampling!r}")
        if self.observability is not None and not isinstance(
                self.observability, ObservabilityConfig):
            raise ValueError(
                f"observability must be an ObservabilityConfig (or a "
                f"mapping of its fields) or None, "
                f"got {self.observability!r}")

    def replace(self, **kw) -> "EngineConfig":
        return dataclasses.replace(self, **kw)

    # ------------------------------ JSON ------------------------------ #

    def to_dict(self) -> Dict[str, Any]:
        d = dataclasses.asdict(self)          # nests sampling as a dict
        d["mesh_shape"] = list(self.mesh_shape)
        d["faults"] = [[k, r] for k, r in self.faults]
        return d

    def to_json(self, **kw) -> str:
        return json.dumps(self.to_dict(), **kw)

    @classmethod
    def from_dict(cls, data: Mapping[str, Any]) -> "EngineConfig":
        fields = {f.name for f in dataclasses.fields(cls)}
        unknown = set(data) - fields
        if unknown:
            raise ValueError(
                f"unknown EngineConfig fields {sorted(unknown)} "
                f"(known: {sorted(fields)})")
        return cls(**dict(data))

    @classmethod
    def from_json(cls, text: str) -> "EngineConfig":
        return cls.from_dict(json.loads(text))


# config field names — used only to phrase the retirement TypeError
_CONFIG_FIELDS = frozenset(f.name for f in dataclasses.fields(EngineConfig))


def reject_legacy_kwargs(kwargs: Dict[str, Any], where: str) -> None:
    """The PR-6 deprecated loose-kwarg shims are retired.

    Every entry point now accepts knobs exclusively through
    ``config=EngineConfig(...)``; any leftover keyword raises
    ``TypeError``.  Keywords that name real config fields get a message
    pointing at the exact ``EngineConfig(...)`` construction to use."""
    if not kwargs:
        return
    names = sorted(kwargs)
    known = sorted(set(kwargs) & _CONFIG_FIELDS)
    if known:
        fields = ", ".join(f"{k}=..." for k in known)
        raise TypeError(
            f"{where}() no longer accepts {names} as keyword arguments "
            f"(the deprecated shims were removed) — construct an "
            f"EngineConfig and pass config=EngineConfig({fields})")
    raise TypeError(
        f"{where}() got unexpected keyword arguments {names}")
