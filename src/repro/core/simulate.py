"""End-to-end CAPSim simulation (paper Fig 1, right-hand path).

Given a benchmark: run the fast *functional* simulator (atomic, no timing),
slice the trace into fixed-length clips, snapshot contexts at clip starts,
tokenize, and predict every clip's runtime *in one accelerator batch* —
then sum.  The left-hand path (the O3 cycle oracle) is ``oracle_simulate``;
the two wall-times are the Fig-7 speed comparison, and the two totals are
the accuracy comparison.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Callable, List, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import context as ctx_mod
from repro.core import predictor as pred_mod
from repro.core import slicer as slicer_mod
from repro.core import standardize as std_mod
from repro.isa import funcsim, progen, timing


@dataclasses.dataclass
class SimResult:
    name: str
    n_intervals: int
    n_instructions: int
    predicted_cycles: float
    oracle_cycles: Optional[float]
    func_seconds: float               # functional sim + tokenize
    predict_seconds: float            # batched predictor inference
    oracle_seconds: Optional[float]   # O3 oracle wall time

    @property
    def capsim_seconds(self) -> float:
        return self.func_seconds + self.predict_seconds

    @property
    def speedup(self) -> Optional[float]:
        if self.oracle_seconds is None:
            return None
        return self.oracle_seconds / max(self.capsim_seconds, 1e-9)

    @property
    def rel_error(self) -> Optional[float]:
        if not self.oracle_cycles:
            return None
        return abs(self.predicted_cycles - self.oracle_cycles) \
            / self.oracle_cycles


def _pad_batch(tok, ctx, mask, batch_size):
    n = tok.shape[0]
    if n % batch_size == 0:
        return tok, ctx, mask, n
    pad = batch_size - n % batch_size
    tok = np.concatenate([tok, np.repeat(tok[-1:], pad, 0)])
    ctx = np.concatenate([ctx, np.repeat(ctx[-1:], pad, 0)])
    mask = np.concatenate([mask, np.zeros((pad,) + mask.shape[1:],
                                          mask.dtype)])
    return tok, ctx, mask, n


def capsim_simulate(bench: progen.Benchmark, params, cfg,
                    vocab: std_mod.Vocab, *,
                    interval_size: int = 20_000, warmup: int = 2_000,
                    max_checkpoints: int = 4, l_min: int = 100,
                    l_clip: int = 128, l_token: int = 16,
                    batch_size: int = 256, use_context: bool = True,
                    with_oracle: bool = True,
                    timing_params: timing.TimingParams =
                    timing.TimingParams()) -> SimResult:
    predict = jax.jit(lambda p, b: pred_mod.predict_step(
        p, b, cfg, use_context))

    st = progen.fresh_state(bench)
    _, _, st = funcsim.run(bench.program, warmup, state=st)

    n_ckp = min(bench.ckp_num, max_checkpoints)
    tok_l: List[np.ndarray] = []
    ctx_l: List[np.ndarray] = []
    mask_l: List[np.ndarray] = []
    oracle_cycles = 0.0
    oracle_seconds = 0.0
    n_instructions = 0

    t_func = time.time()
    traces = []
    for _ in range(n_ckp):
        trace, snaps, st = funcsim.run(
            bench.program, interval_size, state=st, snapshot_every=l_min)
        if not trace:
            break
        traces.append(trace)
        n_instructions += len(trace)
        clips = slicer_mod.slice_fixed([e.inst for e in trace], l_min)
        for i, clip in enumerate(clips):
            toks, mask = std_mod.encode_clip(clip.insts, vocab, l_clip,
                                             l_token)
            tok_l.append(toks)
            snap = snaps[min(i, len(snaps) - 1)]
            ctx_l.append(ctx_mod.context_token_ids(snap, vocab))
            mask_l.append(mask)
    func_seconds = time.time() - t_func

    if with_oracle:
        t_oracle = time.time()
        for trace in traces:
            oracle_cycles += timing.total_cycles(trace, timing_params)
        oracle_seconds = time.time() - t_oracle

    tok = np.stack(tok_l)
    ctx = np.stack(ctx_l)
    mask = np.stack(mask_l)
    tok, ctx, mask, n_real = _pad_batch(tok, ctx, mask, batch_size)

    t_pred = time.time()
    preds = []
    for lo in range(0, tok.shape[0], batch_size):
        batch = {"clip_tokens": jnp.asarray(tok[lo:lo + batch_size]),
                 "context_tokens": jnp.asarray(ctx[lo:lo + batch_size]),
                 "clip_mask": jnp.asarray(mask[lo:lo + batch_size])}
        preds.append(np.asarray(predict(params, batch)))
    total_pred = float(np.concatenate(preds)[:n_real].sum())
    predict_seconds = time.time() - t_pred

    return SimResult(
        name=bench.name, n_intervals=len(traces),
        n_instructions=n_instructions,
        predicted_cycles=total_pred,
        oracle_cycles=oracle_cycles if with_oracle else None,
        func_seconds=func_seconds, predict_seconds=predict_seconds,
        oracle_seconds=oracle_seconds if with_oracle else None)
