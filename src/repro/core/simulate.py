"""End-to-end CAPSim simulation (paper Fig 1, right-hand path).

Given a benchmark: run the fast *functional* simulator (atomic, no timing),
slice the trace into fixed-length clips, snapshot contexts at clip starts,
tokenize, and predict every clip's runtime *in one accelerator batch* —
then sum.  The left-hand path (the O3 cycle oracle) is ``oracle_simulate``;
the two wall-times are the Fig-7 speed comparison, and the two totals are
the accuracy comparison.

``capsim_simulate`` is the single-benchmark convenience wrapper over
``repro.core.engine.SimulationEngine`` — the multi-benchmark batch engine
that shares one clip pool and one cached-jit predict step across programs.
Both wrappers are thin shells over ``SimulationEngine.from_config``: all
knobs (trace scale, batching, precision, RT cache, device mesh, clip
subsampling) travel in one ``EngineConfig``.  The PR-6 deprecated loose
keyword arguments are retired: passing one raises ``TypeError`` pointing
at the matching ``EngineConfig`` field.  Use the engine directly when
simulating more than one benchmark.
"""
from __future__ import annotations

from typing import Optional

from repro.core import standardize as std_mod
from repro.core.engine import (MulticoreSimResult, SimResult,
                               SimulationEngine)
from repro.core.engine_config import EngineConfig, reject_legacy_kwargs
from repro.isa import multicore as mc_mod
from repro.isa import progen, timing

__all__ = ["EngineConfig", "MulticoreSimResult", "SimResult",
           "capsim_simulate", "capsim_simulate_multicore"]


def capsim_simulate(bench: progen.Benchmark, params, cfg,
                    vocab: std_mod.Vocab,
                    config: Optional[EngineConfig] = None, *,
                    timing_params: Optional[timing.TimingParams] = None,
                    **legacy) -> SimResult:
    """One benchmark through ``SimulationEngine.from_config``.

    ``config.rt_cache`` (default on) serves clips from the
    static-instruction RT table (bitwise-equal in fp32);
    ``config.precision`` None keeps cfg.dtype, "fp32"/"bf16" select the
    inference numerics (bf16 is relative-error bounded, not bitwise); a
    non-empty ``config.mesh_shape`` shards clip batches and RT-cache
    encode passes over the data mesh (bitwise-equal to unsharded);
    ``config.sampling`` predicts only a stratified clip sample and
    extrapolates the rest with a bootstrap CI (``sampling=None`` keeps
    the full path bitwise)."""
    reject_legacy_kwargs(legacy, "capsim_simulate")
    engine = SimulationEngine.from_config(params, cfg, vocab, config,
                                          timing_params=timing_params)
    return engine.simulate(bench)


def capsim_simulate_multicore(mbench: mc_mod.MulticoreBenchmark, params,
                              cfg, vocab: std_mod.Vocab,
                              config: Optional[EngineConfig] = None, *,
                              timing_params:
                              Optional[timing.TimingParams] = None,
                              **legacy) -> MulticoreSimResult:
    """Single multicore-benchmark convenience wrapper over
    ``SimulationEngine.run_multicore``: N interleaved per-core functional
    sims feeding one pooled predictor (shared RT cache, core-id context
    channel), demuxed per core and summed per benchmark.  The scheduler
    quantum travels as ``config.quantum`` (None = scheduler default)."""
    reject_legacy_kwargs(legacy, "capsim_simulate_multicore")
    engine = SimulationEngine.from_config(params, cfg, vocab, config,
                                          timing_params=timing_params)
    return engine.run_multicore([mbench])[0]
