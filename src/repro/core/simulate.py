"""End-to-end CAPSim simulation (paper Fig 1, right-hand path).

Given a benchmark: run the fast *functional* simulator (atomic, no timing),
slice the trace into fixed-length clips, snapshot contexts at clip starts,
tokenize, and predict every clip's runtime *in one accelerator batch* —
then sum.  The left-hand path (the O3 cycle oracle) is ``oracle_simulate``;
the two wall-times are the Fig-7 speed comparison, and the two totals are
the accuracy comparison.

``capsim_simulate`` is the single-benchmark convenience wrapper over
``repro.core.engine.SimulationEngine`` — the multi-benchmark batch engine
that shares one clip pool and one cached-jit predict step across programs.
Use the engine directly when simulating more than one benchmark.
"""
from __future__ import annotations

from repro.core import standardize as std_mod
from repro.core.engine import (MulticoreSimResult, SimResult,
                               SimulationEngine)
from repro.isa import multicore as mc_mod
from repro.isa import progen, timing

__all__ = ["MulticoreSimResult", "SimResult", "capsim_simulate",
           "capsim_simulate_multicore"]


def capsim_simulate(bench: progen.Benchmark, params, cfg,
                    vocab: std_mod.Vocab, *,
                    interval_size: int = 20_000, warmup: int = 2_000,
                    max_checkpoints: int = 4, l_min: int = 100,
                    l_clip: int = 128, l_token: int = 16,
                    batch_size: int = 256, use_context: bool = True,
                    with_oracle: bool = True,
                    timing_params: timing.TimingParams =
                    timing.TimingParams(),
                    rt_cache: bool = True,
                    precision: "str | None" = None) -> SimResult:
    """``rt_cache`` (default on) serves clips from the static-instruction
    RT table (bitwise-equal in fp32); ``precision`` None keeps cfg.dtype,
    "fp32"/"bf16" select the inference numerics (bf16 is relative-error
    bounded, not bitwise)."""
    engine = SimulationEngine(
        params, cfg, vocab, interval_size=interval_size, warmup=warmup,
        max_checkpoints=max_checkpoints, l_min=l_min, l_clip=l_clip,
        l_token=l_token, batch_size=batch_size, use_context=use_context,
        with_oracle=with_oracle, timing_params=timing_params,
        rt_cache=rt_cache, precision=precision)
    return engine.simulate(bench)


def capsim_simulate_multicore(mbench: mc_mod.MulticoreBenchmark, params,
                              cfg, vocab: std_mod.Vocab, *,
                              interval_size: int = 20_000,
                              warmup: int = 2_000,
                              max_checkpoints: int = 4, l_min: int = 100,
                              l_clip: int = 128, l_token: int = 16,
                              batch_size: int = 256,
                              use_context: bool = True,
                              with_oracle: bool = True,
                              timing_params: timing.TimingParams =
                              timing.TimingParams(),
                              rt_cache: bool = True,
                              precision: "str | None" = None,
                              quantum: int = mc_mod.DEFAULT_QUANTUM
                              ) -> MulticoreSimResult:
    """Single multicore-benchmark convenience wrapper over
    ``SimulationEngine.run_multicore``: N interleaved per-core functional
    sims feeding one pooled predictor (shared RT cache, core-id context
    channel), demuxed per core and summed per benchmark."""
    engine = SimulationEngine(
        params, cfg, vocab, interval_size=interval_size, warmup=warmup,
        max_checkpoints=max_checkpoints, l_min=l_min, l_clip=l_clip,
        l_token=l_token, batch_size=batch_size, use_context=use_context,
        with_oracle=with_oracle, timing_params=timing_params,
        rt_cache=rt_cache, precision=precision)
    return engine.run_multicore([mbench], quantum=quantum)[0]
