"""Analytical-ML fusion: per-clip analytical features + residual fit.

Concorde-style fusion (PAPERS.md): a cheap compositional *analytical*
model captures most of each clip's cycle count from first principles —
ILP dependency chains, functional-unit structural bounds, cache-line
footprints, memory-level parallelism, branch behaviour — and a small ML
correction closes the gap.  Here the attention predictor plays the
"expensive model" role: only a stratified *sample* of clips runs through
it (``core/sampler.stratified_sample``); a ridge fit from analytical
features to the sampled model predictions extrapolates the rest, with a
per-stratum mean-residual correction and a stratified bootstrap
confidence interval over the total — PAI-style projection of a full
benchmark from partial simulation, with honest error bars.

Feature vocabulary mirrors ``launch/roofline.py``: each clip gets a
compute term (dependency-chain critical path, FU occupancy bound), a
memory term (unique D-cache lines x miss latency, MSHR-bounded miss
waves), and the roofline max of the two as the clip's analytical cycle
estimate — the stratification key.  All statics come straight from the
timing oracle's own tables (``timing._static_tables``), so the features
and the O3 oracle describe the same machine.

Two feature front-ends feed the same estimator:

  ``clip_features``       trace engine — the columnar ``Trace`` is in
                          hand, so features are exact per the greedy
                          model's vocabulary.  Windows follow the
                          ``slice_fixed`` partition exactly
                          (``l_min`` windows + remainder), so feature
                          row i describes predicted clip i.
  ``token_clip_features`` serving engine — requests carry only
                          tokenized clips, so features degrade to
                          token-level occupancy/diversity proxies.
                          Coarser, but the same stratify/fit/CI
                          machinery applies and every request still
                          resolves to exactly one typed result.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

import numpy as np

from repro.isa import compiled as comp
from repro.isa import timing

# clip_features column order (the serving token features use their own)
FEATURE_NAMES = (
    "n_insts",          # window length (commit-width floor: n / commit_width)
    "lat_sum",          # total static latency (serial work upper bound)
    "dep_chain",        # latency-weighted dependency critical path (ILP bound)
    "fu_bound",         # max FU-class structural occupancy bound
    "n_loads",
    "n_stores",
    "uniq_dlines",      # unique D-cache lines touched (miss-rate proxy)
    "uniq_ilines",      # unique I-cache lines touched (front-end proxy)
    "n_branches",
    "n_taken",
    "miss_waves",       # MSHR-serialized miss waves (MLP bound proxy)
    "analytical_cycles",  # roofline max of compute/memory/width terms
)
N_FEATURES = len(FEATURE_NAMES)


def clip_features(trace: comp.Trace, l_min: int,
                  params: Optional[timing.TimingParams] = None
                  ) -> np.ndarray:
    """(n_clips, N_FEATURES) float64 analytical features per clip window.

    Windows are the ``slice_fixed`` partition over the trace
    (``k_full = n // l_min`` full windows plus one remainder), exactly
    the clips ``encode_fixed_clips`` / ``fixed_clip_indices`` produce —
    feature row i always describes predicted clip i.  Dependency state
    resets at every window boundary, so each row is a pure function of
    its own window's (pc, ea, taken) rows: features are invariant to
    clip order by construction.
    """
    p = params if params is not None else timing.TimingParams()
    (fu_idx, latency, is_load, is_store, is_branch,
     read_slots, write_slots) = timing._static_tables(trace.program)
    fu_count = [1] * len(timing.FU_ORDER)
    for cls, cnt in p.fu_counts:
        fu_count[timing._FU_INDEX[cls]] = max(cnt, 1)

    pcs = trace.pc.tolist()
    eas = trace.ea.tolist()
    takens = trace.taken.tolist()
    n = len(pcs)
    if n == 0:
        return np.zeros((0, N_FEATURES), np.float64)
    k_full, rem = n // l_min, n % l_min
    n_clips = k_full + (1 if rem else 0)
    out = np.zeros((n_clips, N_FEATURES), np.float64)

    for c in range(n_clips):
        start = c * l_min if c < k_full else n - rem
        end = start + l_min if c < k_full else n
        lat_sum = 0
        depth = {}                       # reg slot -> chain depth (cycles)
        crit = 0
        fu_occ = [0] * len(fu_count)
        n_ld = n_st = n_br = n_tk = 0
        dlines = set()
        ilines = set()
        for i in range(start, end):
            pc = pcs[i]
            lat = latency[pc]
            if is_load[pc]:
                lat = p.dcache_hit_cycles    # hit-latency chain; misses
                n_ld += 1                    # are modeled by the memory
                dlines.add(eas[i] // p.dcache_line_bytes)   # term below
            elif is_store[pc]:
                n_st += 1
                dlines.add(eas[i] // p.dcache_line_bytes)
            if is_branch[pc]:
                n_br += 1
                if takens[i] == 1:
                    n_tk += 1
            lat_sum += lat
            ilines.add(pc // p.icache_line_insts)
            d = 0
            for s in read_slots[pc]:
                ds = depth.get(s, 0)
                if ds > d:
                    d = ds
            d += lat
            for s in write_slots[pc]:
                depth[s] = d
            if d > crit:
                crit = d
            fu = fu_idx[pc]
            # unpipelined dividers occupy their unit for the full
            # latency; everything else has 1-cycle occupancy
            fu_occ[fu] += lat if fu in (2, 4) else 1
        fu_bound = max(occ / fu_count[k] for k, occ in enumerate(fu_occ))
        n_insts = end - start
        uniq_d = len(dlines)
        # memory term: every distinct line is a potential miss; misses
        # overlap up to mshr_entries deep (MLP), hits pipeline freely
        miss_waves = -(-uniq_d // max(p.mshr_entries, 1))
        mem_term = (miss_waves * p.dcache_miss_cycles
                    + (n_ld + n_st - uniq_d) * p.dcache_hit_cycles
                    / max(p.mshr_entries, 1))
        width_term = n_insts / max(p.commit_width, 1)
        analytical = max(crit, fu_bound, mem_term, width_term)
        out[c] = (n_insts, lat_sum, crit, fu_bound, n_ld, n_st,
                  uniq_d, len(ilines), n_br, n_tk, miss_waves,
                  analytical)
    return out


def token_clip_features(clip_tokens: np.ndarray,
                        clip_mask: np.ndarray) -> np.ndarray:
    """(n, 6) float64 token-derived features for serving requests.

    The serving path never sees the columnar trace — requests arrive
    pre-tokenized — so features degrade to occupancy and diversity
    proxies over the (n, l_clip, l_token) token tensor (or the
    (n, l_clip) RT-index matrix): clip length, distinct static
    instructions, token-level entropy proxies.  Same estimator
    downstream, coarser strata.
    """
    tok = np.asarray(clip_tokens)
    mask = np.asarray(clip_mask, np.float64)
    n = tok.shape[0]
    if n == 0:
        return np.zeros((0, 6), np.float64)
    if tok.ndim == 2:                       # rt_idx rows: lift to 3-D
        tok = tok[:, :, None]
    n_valid = mask.sum(axis=1)
    out = np.zeros((n, 6), np.float64)
    for i in range(n):
        valid = mask[i] > 0
        rows = tok[i][valid]
        if rows.shape[0] == 0:
            continue
        uniq_rows = len({r.tobytes() for r in rows})
        vals, counts = np.unique(rows, return_counts=True)
        p_tok = counts / counts.sum()
        ent = float(-(p_tok * np.log(p_tok)).sum())
        out[i] = (n_valid[i], uniq_rows, len(vals), ent,
                  float(rows.mean()), n_valid[i] / max(uniq_rows, 1))
    return out


# --------------------------------------------------------------------------- #
# Stratification + the fused estimator
# --------------------------------------------------------------------------- #

def stratify(features: np.ndarray, n_strata: int,
             key_column: int = N_FEATURES - 1) -> np.ndarray:
    """(n,) int32 stratum label per clip: quantile bins of the
    analytical-cycles column (order statistics, so labels are invariant
    to clip order and deterministic).  Ties or low diversity collapse
    bins — empty strata are fine, the sampler skips them."""
    f = np.asarray(features, np.float64)
    n = f.shape[0]
    if n == 0:
        return np.zeros(0, np.int32)
    key = f[:, key_column] if f.ndim == 2 else f
    if n_strata <= 1:
        return np.zeros(n, np.int32)
    qs = np.quantile(key, np.arange(1, n_strata) / n_strata)
    return np.searchsorted(qs, key, side="left").astype(np.int32)


@dataclasses.dataclass(frozen=True)
class PredictionReport:
    """Typed result of one fused (subsampled) prediction.

    ``total_cycles`` is the stratified estimate; ``cycles_ci`` the 95%
    bootstrap interval around it (degenerate at the point when nothing
    was extrapolated or ``bootstrap_resamples == 0``);
    ``clip_provenance`` marks each clip True if its time came from the
    attention model, False if from the analytical-residual fit.
    """

    total_cycles: float
    cycles_ci: Tuple[float, float]
    clips_predicted: int
    clips_extrapolated: int
    clip_provenance: np.ndarray = dataclasses.field(compare=False,
                                                    repr=False,
                                                    default=None)
    times: np.ndarray = dataclasses.field(compare=False, repr=False,
                                          default=None)

    @property
    def n_clips(self) -> int:
        return self.clips_predicted + self.clips_extrapolated

    @property
    def ci_width(self) -> float:
        return self.cycles_ci[1] - self.cycles_ci[0]


def _ridge_fit(X: np.ndarray, y: np.ndarray, lam: float = 1e-3):
    """Standardized ridge regression; returns a predict closure.

    Features standardize to the sample's moments (constant columns
    drop to zero weight), the target centers, and the intercept stays
    unregularized — so a constant target extrapolates exactly."""
    mu = X.mean(axis=0)
    sd = X.std(axis=0)
    sd = np.where(sd > 0, sd, 1.0)
    Xs = (X - mu) / sd
    ym = y.mean()
    k = X.shape[1]
    A = Xs.T @ Xs + lam * np.eye(k)
    w = np.linalg.solve(A, Xs.T @ (y - ym))

    def predict(Z: np.ndarray) -> np.ndarray:
        return ym + ((Z - mu) / sd) @ w
    return predict


def _extrapolate(features, strata, sampled, sampled_preds):
    """Ridge fit + per-stratum mean-residual correction.

    Returns (n,) float64 times for EVERY clip: sampled positions carry
    their model predictions verbatim, the rest the corrected fit."""
    y = np.asarray(sampled_preds, np.float64)
    fit = _ridge_fit(features[sampled], y)
    times = np.empty(features.shape[0], np.float64)
    times[sampled] = y
    rest = np.ones(features.shape[0], bool)
    rest[sampled] = False
    if rest.any():
        est = fit(features[rest])
        # per-stratum residual correction: the ridge is global, the
        # bias it leaves is local — shift each stratum's extrapolations
        # by that stratum's mean sampled residual
        resid = y - fit(features[sampled])
        s_sample = strata[sampled]
        rest_idx = np.flatnonzero(rest)
        for s in np.unique(strata[rest_idx]):
            in_s = s_sample == s
            if in_s.any():
                est[strata[rest_idx] == s] += resid[in_s].mean()
        # clip runtimes are positive; a wild extrapolation must not go
        # below the cheapest observed clip
        est = np.maximum(est, max(y.min(), 0.0))
        times[rest] = est
    return times


def fuse_predictions(features: np.ndarray, strata: np.ndarray,
                     sampled: np.ndarray, sampled_preds: np.ndarray,
                     bootstrap_resamples: int = 200, seed: int = 0,
                     key: int = 0) -> PredictionReport:
    """The fused estimator: model predictions for the sampled clips,
    ridge+residual extrapolation for the rest, stratified bootstrap CI.

    ``sampled`` holds sorted clip indices; ``sampled_preds`` their model
    predictions in that order.  When every clip was sampled the total
    is exactly ``float(sampled_preds.sum())`` — the bitwise contract
    behind ``fraction=1.0`` — and the CI degenerates to the point.

    The bootstrap resamples the *sample* within each stratum (with
    replacement, sizes preserved), refits, and recomputes the whole
    estimator — so the interval reflects both within-stratum sampling
    variance and fit uncertainty.  95% percentile interval, seeded by
    ``(seed, key)`` so every (benchmark, core) job draws independently
    but deterministically.
    """
    features = np.asarray(features, np.float64)
    strata = np.asarray(strata)
    sampled = np.asarray(sampled, np.int64)
    preds_raw = np.asarray(sampled_preds)
    preds = preds_raw.astype(np.float64)
    n = features.shape[0]
    provenance = np.zeros(n, bool)
    provenance[sampled] = True
    n_extra = n - sampled.shape[0]

    if n_extra == 0:
        # sum in the predictor's own dtype: the unsampled engine does
        # float(float32_rows.sum()), and fraction=1.0 must match it bit
        # for bit
        total = float(preds_raw.sum())
        return PredictionReport(
            total_cycles=total, cycles_ci=(total, total),
            clips_predicted=int(sampled.shape[0]), clips_extrapolated=0,
            clip_provenance=provenance,
            times=preds.astype(np.float64))

    times = _extrapolate(features, strata, sampled, preds)
    total = float(preds.sum()) + float(times[~provenance].sum())

    lo = hi = total
    if bootstrap_resamples > 0:
        rng = np.random.default_rng(
            np.asarray([abs(int(seed)), abs(int(key))], np.uint64))
        s_sample = strata[sampled]
        groups = [np.flatnonzero(s_sample == s)
                  for s in np.unique(s_sample)]
        totals = np.empty(bootstrap_resamples, np.float64)
        for b in range(bootstrap_resamples):
            take = np.sort(np.concatenate(
                [g[rng.integers(0, g.shape[0], g.shape[0])]
                 for g in groups]))
            t_b = _extrapolate(features, strata, sampled[take],
                               preds[take])
            totals[b] = (float(preds[take].sum())
                         + float(t_b[~provenance].sum()))
        lo, hi = np.percentile(totals, [2.5, 97.5])
        lo, hi = float(min(lo, total)), float(max(hi, total))

    return PredictionReport(
        total_cycles=total, cycles_ci=(lo, hi),
        clips_predicted=int(sampled.shape[0]),
        clips_extrapolated=int(n_extra),
        clip_provenance=provenance, times=times)
