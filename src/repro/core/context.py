"""Context matrix construction (paper §V-B, Fig 6, Table I).

The context is the architectural register state *before* a trace clip
executes.  Each of the 40 context registers (32 GPRs + 8 specials; VSRs are
folded per the paper's FPR note) contributes 9 rows to the context matrix:

    [ <reg-name token> , <byte 7> , <byte 6> , ... , <byte 0> ]

where each byte of the 64-bit value maps to one of the 256 ``<Bxx>`` tokens
(Fig 6a: "the register's value is segmented into 16 groups based on each two
of hexadecimal numbers" — two hex digits = one byte).  Stacking all registers
yields the (M, E)-shaped context matrix after embedding, M = 40 * 9 = 360
(Fig 6b, Eq 10).
"""
from __future__ import annotations

from typing import Dict, Optional, Sequence

import numpy as np

from repro.core.standardize import BYTE_TOKENS, CORE, Vocab
from repro.isa.isa import CONTEXT_REGS

TOKENS_PER_REG = 9          # 1 name + 8 value bytes
CONTEXT_LEN = len(CONTEXT_REGS) * TOKENS_PER_REG
# Multicore context: one extra pseudo-register row (<CORE> name + the
# core id's 8 value bytes) appended after the architectural rows, so
# the predictor can condition on WHICH core a clip executed on.  The
# single-core layout (and every token id inside it) is unchanged.
MULTICORE_CONTEXT_LEN = CONTEXT_LEN + TOKENS_PER_REG
# Peer-channel mode appends, for every OTHER core, that core's full
# register block + its own <CORE> channel — one MULTICORE_CONTEXT_LEN
# block per core, self first — so the block encoder's context stream can
# attend across cores and learn the interference the shared-resource
# oracle prices.  All widths derive from CONTEXT_REGS/TOKENS_PER_REG;
# nothing below may hard-code 360/369.


def context_len(n_cores: int = 1, peer_channels: bool = False) -> int:
    """Context-matrix width M for a build: ``CONTEXT_LEN`` single-core,
    ``MULTICORE_CONTEXT_LEN`` per-core-tagged, ``n_cores`` such blocks
    when peer channels are mixed in.  At ``n_cores <= 1`` the layout is
    ALWAYS the single-core one — there are no peers to mix, and the N=1
    build must stay bitwise identical to ``build_dataset`` whether or
    not the flag is set."""
    if n_cores <= 1:
        return CONTEXT_LEN
    if not peer_channels:
        return MULTICORE_CONTEXT_LEN
    return n_cores * MULTICORE_CONTEXT_LEN


def validate_context_width(width: int, where: str) -> None:
    """Boundary check (dataset build / engine dispatch): a context row
    width must be one of the layouts above; anything else means a stale
    hard-coded shape or a mixed-layout batch slipped through."""
    ok = (width == CONTEXT_LEN
          or (width >= MULTICORE_CONTEXT_LEN
              and width % MULTICORE_CONTEXT_LEN == 0))
    if not ok:
        raise ValueError(
            f"{where}: context width {width} is not a known layout "
            f"(single-core {CONTEXT_LEN}, core-tagged "
            f"{MULTICORE_CONTEXT_LEN}, or k*{MULTICORE_CONTEXT_LEN} "
            f"with peer channels)")


def context_token_ids(snapshot: Dict[str, int], vocab: Vocab) -> np.ndarray:
    """snapshot: {reg_name: 64-bit value} -> (CONTEXT_LEN,) int32 ids."""
    out = np.empty(CONTEXT_LEN, np.int32)
    byte0 = vocab[BYTE_TOKENS[0]]
    i = 0
    for reg in CONTEXT_REGS:
        out[i] = vocab[reg]
        v = snapshot.get(reg, 0) & ((1 << 64) - 1)
        for shift in range(56, -8, -8):                  # big-endian bytes
            out[i + 1 + (56 - shift) // 8] = byte0 + ((v >> shift) & 0xFF)
        i += TOKENS_PER_REG
    return out


def batch_context_tokens(snapshots: Sequence[Dict[str, int]],
                         vocab: Vocab) -> np.ndarray:
    """(B, CONTEXT_LEN) int32."""
    return np.stack([context_token_ids(s, vocab) for s in snapshots])


def core_id_tokens(core_id: int, vocab: Vocab) -> np.ndarray:
    """The core-id context channel: ``(TOKENS_PER_REG,) int32`` —
    ``<CORE>`` name token followed by the big-endian bytes of the id."""
    out = np.empty(TOKENS_PER_REG, np.int32)
    out[0] = vocab[CORE]
    byte0 = vocab[BYTE_TOKENS[0]]
    v = int(core_id) & ((1 << 64) - 1)
    for shift in range(56, -8, -8):                      # big-endian bytes
        out[1 + (56 - shift) // 8] = byte0 + ((v >> shift) & 0xFF)
    return out


def context_tokens_from_matrix(snapshots: np.ndarray, vocab: Vocab,
                               core_id: Optional[int] = None) -> np.ndarray:
    """Columnar path: ``(B, 40) uint64`` snapshot matrix (rows in
    ``CONTEXT_REGS`` order, as emitted by the columnar funcsim) ->
    ``(B, CONTEXT_LEN) int32`` token ids, bitwise equal to stacking
    ``context_token_ids`` over the equivalent dicts.

    The per-register byte loop becomes one vectorized big-endian byte
    decomposition: shift the whole matrix by 56..0 and mask.

    With ``core_id`` set (the multicore engine), one extra
    ``core_id_tokens`` row is appended to every matrix —
    ``(B, MULTICORE_CONTEXT_LEN)`` out — so clips from different cores of
    one benchmark carry distinct contexts; ``core_id=None`` keeps the
    single-core layout bit for bit.
    """
    snaps = np.ascontiguousarray(snapshots, np.uint64)
    b = snaps.shape[0]
    shifts = np.arange(56, -8, -8, dtype=np.uint64)      # big-endian bytes
    bytes_ = (snaps[:, :, None] >> shifts) & np.uint64(0xFF)
    out = np.empty((b, len(CONTEXT_REGS), TOKENS_PER_REG), np.int32)
    out[:, :, 0] = np.asarray([vocab[r] for r in CONTEXT_REGS], np.int32)
    out[:, :, 1:] = bytes_.astype(np.int32) + vocab[BYTE_TOKENS[0]]
    flat = out.reshape(b, CONTEXT_LEN)
    if core_id is None:
        return flat
    chan = np.broadcast_to(core_id_tokens(core_id, vocab),
                           (b, TOKENS_PER_REG))
    return np.concatenate([flat, chan], axis=1)


def peer_context_tokens(snapshots: np.ndarray, peer_snapshots: np.ndarray,
                        core_id: int, vocab: Vocab) -> np.ndarray:
    """Peer-channel context: ``(B, n_cores * MULTICORE_CONTEXT_LEN)``.

    ``snapshots`` is core ``core_id``'s own precise ``(B, 40)`` snapshot
    matrix (state immediately before each clip start);
    ``peer_snapshots`` is the scheduler's ``(B, n_cores, 40)``
    whole-machine capture at the enclosing quantum's start
    (``multicore.run_multicore(..., peer_snapshots=True)``) — other
    cores' state cannot change inside the quantum, so their rows are
    exact; the own-core row is stale and is NOT used.

    Layout: the own core's ``MULTICORE_CONTEXT_LEN`` block first (bitwise
    ``context_tokens_from_matrix(..., core_id=core_id)``), then one
    ``<CORE>``-tagged block per peer in ascending core order.  The block
    encoder attends over all rows, so the predictor can correlate a
    clip's runtime with the peers' pointer/loop state — the contention
    context single-core clips never carry.
    """
    b, n_cores = peer_snapshots.shape[0], peer_snapshots.shape[1]
    assert snapshots.shape[0] == b, (snapshots.shape, peer_snapshots.shape)
    assert 0 <= core_id < n_cores, (core_id, n_cores)
    blocks = [context_tokens_from_matrix(snapshots, vocab, core_id=core_id)]
    for peer in range(n_cores):
        if peer == core_id:
            continue
        blocks.append(context_tokens_from_matrix(
            peer_snapshots[:, peer], vocab, core_id=peer))
    return np.concatenate(blocks, axis=1)
