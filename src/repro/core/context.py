"""Context matrix construction (paper §V-B, Fig 6, Table I).

The context is the architectural register state *before* a trace clip
executes.  Each of the 40 context registers (32 GPRs + 8 specials; VSRs are
folded per the paper's FPR note) contributes 9 rows to the context matrix:

    [ <reg-name token> , <byte 7> , <byte 6> , ... , <byte 0> ]

where each byte of the 64-bit value maps to one of the 256 ``<Bxx>`` tokens
(Fig 6a: "the register's value is segmented into 16 groups based on each two
of hexadecimal numbers" — two hex digits = one byte).  Stacking all registers
yields the (M, E)-shaped context matrix after embedding, M = 40 * 9 = 360
(Fig 6b, Eq 10).
"""
from __future__ import annotations

from typing import Dict, Optional, Sequence

import numpy as np

from repro.core.standardize import BYTE_TOKENS, CORE, Vocab
from repro.isa.isa import CONTEXT_REGS

TOKENS_PER_REG = 9          # 1 name + 8 value bytes
CONTEXT_LEN = len(CONTEXT_REGS) * TOKENS_PER_REG
assert CONTEXT_LEN == 360
# Multicore context: one extra pseudo-register row (<CORE> name + the
# core id's 8 value bytes) appended after the 40 architectural rows, so
# the predictor can condition on WHICH core a clip executed on.  The
# single-core layout (and every token id inside it) is unchanged.
MULTICORE_CONTEXT_LEN = CONTEXT_LEN + TOKENS_PER_REG
assert MULTICORE_CONTEXT_LEN == 369


def context_token_ids(snapshot: Dict[str, int], vocab: Vocab) -> np.ndarray:
    """snapshot: {reg_name: 64-bit value} -> (360,) int32 token ids."""
    out = np.empty(CONTEXT_LEN, np.int32)
    byte0 = vocab[BYTE_TOKENS[0]]
    i = 0
    for reg in CONTEXT_REGS:
        out[i] = vocab[reg]
        v = snapshot.get(reg, 0) & ((1 << 64) - 1)
        for shift in range(56, -8, -8):                  # big-endian bytes
            out[i + 1 + (56 - shift) // 8] = byte0 + ((v >> shift) & 0xFF)
        i += TOKENS_PER_REG
    return out


def batch_context_tokens(snapshots: Sequence[Dict[str, int]],
                         vocab: Vocab) -> np.ndarray:
    """(B, 360) int32."""
    return np.stack([context_token_ids(s, vocab) for s in snapshots])


def core_id_tokens(core_id: int, vocab: Vocab) -> np.ndarray:
    """The core-id context channel: ``(TOKENS_PER_REG,) int32`` —
    ``<CORE>`` name token followed by the big-endian bytes of the id."""
    out = np.empty(TOKENS_PER_REG, np.int32)
    out[0] = vocab[CORE]
    byte0 = vocab[BYTE_TOKENS[0]]
    v = int(core_id) & ((1 << 64) - 1)
    for shift in range(56, -8, -8):                      # big-endian bytes
        out[1 + (56 - shift) // 8] = byte0 + ((v >> shift) & 0xFF)
    return out


def context_tokens_from_matrix(snapshots: np.ndarray, vocab: Vocab,
                               core_id: Optional[int] = None) -> np.ndarray:
    """Columnar path: ``(B, 40) uint64`` snapshot matrix (rows in
    ``CONTEXT_REGS`` order, as emitted by the columnar funcsim) ->
    ``(B, 360) int32`` token ids, bitwise equal to stacking
    ``context_token_ids`` over the equivalent dicts.

    The per-register byte loop becomes one vectorized big-endian byte
    decomposition: shift the whole matrix by 56..0 and mask.

    With ``core_id`` set (the multicore engine), one extra
    ``core_id_tokens`` row is appended to every matrix —
    ``(B, MULTICORE_CONTEXT_LEN)`` out — so clips from different cores of
    one benchmark carry distinct contexts; ``core_id=None`` keeps the
    single-core layout bit for bit.
    """
    snaps = np.ascontiguousarray(snapshots, np.uint64)
    b = snaps.shape[0]
    shifts = np.arange(56, -8, -8, dtype=np.uint64)      # big-endian bytes
    bytes_ = (snaps[:, :, None] >> shifts) & np.uint64(0xFF)
    out = np.empty((b, len(CONTEXT_REGS), TOKENS_PER_REG), np.int32)
    out[:, :, 0] = np.asarray([vocab[r] for r in CONTEXT_REGS], np.int32)
    out[:, :, 1:] = bytes_.astype(np.int32) + vocab[BYTE_TOKENS[0]]
    flat = out.reshape(b, CONTEXT_LEN)
    if core_id is None:
        return flat
    chan = np.broadcast_to(core_id_tokens(core_id, vocab),
                           (b, TOKENS_PER_REG))
    return np.concatenate([flat, chan], axis=1)
