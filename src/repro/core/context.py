"""Context matrix construction (paper §V-B, Fig 6, Table I).

The context is the architectural register state *before* a trace clip
executes.  Each of the 40 context registers (32 GPRs + 8 specials; VSRs are
folded per the paper's FPR note) contributes 9 rows to the context matrix:

    [ <reg-name token> , <byte 7> , <byte 6> , ... , <byte 0> ]

where each byte of the 64-bit value maps to one of the 256 ``<Bxx>`` tokens
(Fig 6a: "the register's value is segmented into 16 groups based on each two
of hexadecimal numbers" — two hex digits = one byte).  Stacking all registers
yields the (M, E)-shaped context matrix after embedding, M = 40 * 9 = 360
(Fig 6b, Eq 10).
"""
from __future__ import annotations

from typing import Dict, Sequence

import numpy as np

from repro.core.standardize import BYTE_TOKENS, Vocab
from repro.isa.isa import CONTEXT_REGS

TOKENS_PER_REG = 9          # 1 name + 8 value bytes
CONTEXT_LEN = len(CONTEXT_REGS) * TOKENS_PER_REG
assert CONTEXT_LEN == 360


def context_token_ids(snapshot: Dict[str, int], vocab: Vocab) -> np.ndarray:
    """snapshot: {reg_name: 64-bit value} -> (360,) int32 token ids."""
    out = np.empty(CONTEXT_LEN, np.int32)
    byte0 = vocab[BYTE_TOKENS[0]]
    i = 0
    for reg in CONTEXT_REGS:
        out[i] = vocab[reg]
        v = snapshot.get(reg, 0) & ((1 << 64) - 1)
        for shift in range(56, -8, -8):                  # big-endian bytes
            out[i + 1 + (56 - shift) // 8] = byte0 + ((v >> shift) & 0xFF)
        i += TOKENS_PER_REG
    return out


def batch_context_tokens(snapshots: Sequence[Dict[str, int]],
                         vocab: Vocab) -> np.ndarray:
    """(B, 360) int32."""
    return np.stack([context_token_ids(s, vocab) for s in snapshots])


def context_tokens_from_matrix(snapshots: np.ndarray,
                               vocab: Vocab) -> np.ndarray:
    """Columnar path: ``(B, 40) uint64`` snapshot matrix (rows in
    ``CONTEXT_REGS`` order, as emitted by the columnar funcsim) ->
    ``(B, 360) int32`` token ids, bitwise equal to stacking
    ``context_token_ids`` over the equivalent dicts.

    The per-register byte loop becomes one vectorized big-endian byte
    decomposition: shift the whole matrix by 56..0 and mask.
    """
    snaps = np.ascontiguousarray(snapshots, np.uint64)
    b = snaps.shape[0]
    shifts = np.arange(56, -8, -8, dtype=np.uint64)      # big-endian bytes
    bytes_ = (snaps[:, :, None] >> shifts) & np.uint64(0xFF)
    out = np.empty((b, len(CONTEXT_REGS), TOKENS_PER_REG), np.int32)
    out[:, :, 0] = np.asarray([vocab[r] for r in CONTEXT_REGS], np.int32)
    out[:, :, 1:] = bytes_.astype(np.int32) + vocab[BYTE_TOKENS[0]]
    return out.reshape(b, CONTEXT_LEN)
