"""Static-instruction RT cache: the two-level inference split.

An instruction's ideal-execution-time vector RT_i (paper Eq 5-8) depends
only on its *static* standardized tokens — the same static/dynamic split
the columnar IR's ``token_table`` exploits one level down.  The monolithic
``forward`` nevertheless re-runs the 4-layer instruction encoder over all
B x L_clip dynamic rows of every batch.  This cache hoists that work out
of the per-clip loop:

  build   one device pass of ``encode_instructions`` over a program's
          ``n_static`` unique rows (orders of magnitude fewer than the
          dynamic rows a benchmark's trace expands them into),
  serve   every clip batch becomes an ``rt_table[rt_idx]`` gather inside
          the jit'd ``forward_cached`` — device FLOPs per clip drop from
          (instruction encoder + block encoder) to (block encoder only).

The cache is *content-addressed*: rows are keyed by their standardized
token bytes, so it is shared across programs (common instruction shapes
dedupe globally) and serves both the trace engine (whole token tables at
once) and the serving engine (arbitrary tokenized requests, deduped via
``index_clips``).  Row id 0 is reserved for the all-<PAD> row, so masked
clip slots gather a real encoder output and fp32 results stay bitwise
identical to the monolithic path (rows encode independently).

Invalidation: entries are pure functions of (params, cfg numerics, row
bytes).  The cache pins the params it was built with — build a fresh
``RTCache`` (or engine) when params change; new *programs* never
invalidate anything, their unseen rows are simply appended.

Persistence (``store_dir``): the (row bytes -> RT vector) table can be
checkpointed to disk via ``checkpoint/ckpt.py`` under a content key
hashing (params bytes, model config, l_token, extra — by convention the
vocab signature).  A fresh cache with a matching key adopts the stored
table byte-identically instead of re-encoding (a full-scale cold build is
~49 s); ANY key component changing — retrained params, different
numerics, new vocabulary — lands on a different store path, so stale rows
are structurally unservable.  A corrupt or truncated store warns and
falls back to the cold encode.
"""
from __future__ import annotations

import dataclasses
import hashlib
import warnings
from functools import lru_cache
from pathlib import Path
from typing import Dict, List, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint import ckpt
from repro.core import predictor as pred_mod
from repro.obs import SPAN_SECONDS_TOTAL, Observability

PAD_ROW_ID = 0

# Bump when the persisted layout/semantics change: old stores then fail
# the metadata check and rebuild cold instead of being misread.
RT_STORE_VERSION = 1


def rt_store_key(params, cfg, l_token: Optional[int] = None,
                 extra: str = "") -> str:
    """Content key for the persistent RT store: a hash over the exact
    parameter bytes, the model config repr (numerics/dtype/attn choices
    included), the token-row width, and ``extra`` (the vocab signature by
    convention).  Equal keys => bitwise-equal tables."""
    h = hashlib.sha256()
    flat = ckpt._flatten(params)
    for key in sorted(flat):
        arr = np.asarray(flat[key])
        h.update(key.encode())
        h.update(str(arr.shape).encode())
        h.update(str(arr.dtype).encode())
        h.update(arr.tobytes())
    h.update(repr(cfg).encode())
    h.update(str(l_token).encode())
    h.update(extra.encode())
    return h.hexdigest()[:32]


@lru_cache(maxsize=64)
def rt_encode_fn(cfg):
    """Cached jit'd RT-table build pass: (N, L_token) rows -> (N, E)."""
    return jax.jit(lambda p, rows: pred_mod.encode_instructions(p, rows,
                                                                cfg))


@lru_cache(maxsize=64)
def rt_encode_mesh_fn(cfg, n_shards: int):
    """Sharded twin of ``rt_encode_fn``: the encode pass splits its row
    axis over an ``n_shards``-device data mesh, so a cold table build
    divides by mesh size.  Rows encode independently, so the assembled
    table is byte-identical to the single-device build."""
    from repro.launch.mesh import make_data_mesh
    return jax.jit(pred_mod.sharded_encode_instructions(
        cfg, make_data_mesh(n_shards)))


# XLA CPU matmul results are row-independent of the batch dimension only
# above ~32 rows — below that the backend may pick a different reduction
# order (measured: a d_model=64 encode at 8 or 16 rows differs ~2.6e-6
# from the same rows inside a >=32-row pass).  Keeping every encode pass
# AND every per-device shard of one at >= 32 rows keeps the whole build
# in one numerical equivalence class, so tables are bitwise reproducible
# across flush patterns and mesh sizes.
ENCODE_STABLE_MIN = 32


def encode_bucket(n: int, align: int = 1) -> int:
    """Pad target for an encode pass: next power of two >=
    max(n, ENCODE_STABLE_MIN), bounding compiled shapes to
    ~log2(n_static) variants while staying in the shape-stable kernel
    class.  ``align`` (the mesh shard count x ENCODE_STABLE_MIN) rounds
    the bucket up to a multiple so every device receives an equal-size,
    stable-class row shard."""
    b = ENCODE_STABLE_MIN
    while b < n:
        b *= 2
    if align > 1:
        b = (b + align - 1) // align * align
    return b


class _RTStatsDictMixin:
    @property
    def rows_avoided(self) -> int:
        """Dynamic instruction-encoder rows the gather replaced."""
        return max(self.n_rows_served - self.n_rows_encoded, 0)

    def as_dict(self) -> Dict[str, float]:
        return {"rt_rows_encoded": self.n_rows_encoded,
                "rt_encode_passes": self.n_encode_passes,
                "rt_rows_served": self.n_rows_served,
                "rt_rows_avoided": self.rows_avoided,
                "rt_lookups": self.n_lookups,
                "rt_build_seconds": self.build_seconds,
                "rt_rows_loaded": self.n_rows_loaded,
                "rt_store_load_seconds": self.store_load_seconds}


@dataclasses.dataclass(frozen=True)
class RTCacheStatsSnapshot(_RTStatsDictMixin):
    """Point-in-time copy of an :class:`RTCacheStats` view (what
    ``SimulationEngine.last_rt_stats`` hands out)."""

    n_rows_encoded: int = 0
    n_encode_passes: int = 0
    n_rows_served: int = 0
    n_lookups: int = 0
    build_seconds: float = 0.0
    n_rows_loaded: int = 0
    store_load_seconds: float = 0.0


class RTCacheStats(_RTStatsDictMixin):
    """Live *view* over the obs metrics registry for one cache instance.

    The cache writes counters/gauges/spans into ``repro.obs`` (that is
    the system of record — ``/metrics`` serves the same cells); this
    class keeps the historical attribute surface by reading them back.
    Constructed with no arguments it is an all-zeros stand-in (the
    engine's "no RT cache" placeholder).  ``freeze()`` returns an
    immutable :class:`RTCacheStatsSnapshot`.
    """

    def __init__(self, obs: Optional[Observability] = None,
                 instance: str = ""):
        self._obs = obs
        self._instance = instance

    def _val(self, name: str) -> float:
        if self._obs is None:
            return 0.0
        return self._obs.metrics.value(name, instance=self._instance)

    def _span_s(self, span: str) -> float:
        if self._obs is None:
            return 0.0
        return self._obs.metrics.value(SPAN_SECONDS_TOTAL, span=span,
                                       instance=self._instance)

    @property
    def n_rows_encoded(self) -> int:
        return int(self._val("capsim_rt_rows_encoded_total"))

    @property
    def n_encode_passes(self) -> int:
        return int(self._val("capsim_rt_encode_passes_total"))

    @property
    def n_rows_served(self) -> int:
        return int(self._val("capsim_rt_rows_served_total"))

    @property
    def n_lookups(self) -> int:
        return int(self._val("capsim_rt_lookups_total"))

    @property
    def build_seconds(self) -> float:
        return self._span_s("rt.build")

    @property
    def n_rows_loaded(self) -> int:
        return int(self._val("capsim_rt_rows_loaded"))

    @property
    def store_load_seconds(self) -> float:
        return self._span_s("rt.store_load")

    def freeze(self) -> RTCacheStatsSnapshot:
        return RTCacheStatsSnapshot(
            n_rows_encoded=self.n_rows_encoded,
            n_encode_passes=self.n_encode_passes,
            n_rows_served=self.n_rows_served,
            n_lookups=self.n_lookups,
            build_seconds=self.build_seconds,
            n_rows_loaded=self.n_rows_loaded,
            store_load_seconds=self.store_load_seconds)


class RTCache:
    """Content-addressed map from standardized token rows to rows of a
    device-resident RT table.

    ``ensure_rows`` returns global int32 row ids, encoding unseen rows in
    one bucketed device pass; ``table`` is the (capacity, E) device array
    ``forward_cached`` gathers from.  The table grows by doubling, so jit
    retraces stay bounded; in-flight batches keep referencing the
    (immutable) array version they were dispatched with.
    """

    def __init__(self, params, cfg, l_token: Optional[int] = None, *,
                 capacity: int = 4096, n_shards: int = 0,
                 store_dir: Optional[str] = None, store_extra: str = "",
                 fault_injector=None, obs: Optional[Observability] = None):
        self.params = params
        self.cfg = cfg
        self.l_token = l_token
        self.obs = obs if obs is not None else Observability()
        m = self.obs.metrics
        self.instance = m.next_instance("rt")
        self._c_encoded = m.counter(
            "capsim_rt_rows_encoded_total",
            "Unique static rows run through the instruction encoder.",
            ("instance",)).labels(instance=self.instance)
        self._c_passes = m.counter(
            "capsim_rt_encode_passes_total",
            "Device encode passes (one per new-row flush).",
            ("instance",)).labels(instance=self.instance)
        self._c_served = m.counter(
            "capsim_rt_rows_served_total",
            "Dynamic (unmasked) rows answered by the RT gather.",
            ("instance",)).labels(instance=self.instance)
        self._c_lookups = m.counter(
            "capsim_rt_lookups_total",
            "Rows presented to ensure_rows.",
            ("instance",)).labels(instance=self.instance)
        self._g_loaded = m.gauge(
            "capsim_rt_rows_loaded",
            "Rows adopted from the persistent store (0 after a failed "
            "load).", ("instance",)).labels(instance=self.instance)
        # chaos layer (repro.serving.faults.FaultInjector or None): may
        # corrupt store reads and crash persists on the REAL code paths
        self._faults = fault_injector
        # n_shards = 0: single-device encode passes (the default);
        # n_shards >= 1: encode passes shard their row axis over an
        # n-device data mesh (EngineConfig.mesh_shape) — byte-identical
        # table, build time divided by mesh size
        self.n_shards = n_shards
        self._encode = (rt_encode_mesh_fn(cfg, n_shards) if n_shards
                        else rt_encode_fn(cfg))
        self._index: Dict[bytes, int] = {}
        self._table: Optional[jax.Array] = None
        self._capacity = capacity
        self._n = 0
        self.stats = RTCacheStats(self.obs, self.instance)
        # persistent store: one ckpt directory per content key under
        # store_dir; loaded eagerly so a warm store never cold-encodes
        self._store_path: Optional[Path] = None
        self._persisted_rows = 0
        if store_dir is not None:
            self._store_key = rt_store_key(params, cfg, l_token,
                                           store_extra)
            self._store_path = Path(store_dir) / self._store_key
            self._load_store()

    @property
    def n_rows(self) -> int:
        return self._n

    @property
    def table(self) -> jax.Array:
        assert self._table is not None, "RT cache is empty (no rows ensured)"
        return self._table

    def ensure_rows(self, rows: np.ndarray,
                    keys: Optional[Sequence[bytes]] = None) -> np.ndarray:
        """rows: (k, L_token) int32 standardized rows -> (k,) int32 global
        RT row ids; unseen rows are encoded in one padded device pass.
        ``keys`` (the rows' ``tobytes()``, e.g. a program's memoized
        ``token_row_keys``) skips re-hashing."""
        with self.obs.span("rt.build", instance=self.instance):
            rows = np.ascontiguousarray(rows, dtype=np.int32)
            if self.l_token is None:
                self.l_token = rows.shape[1]
            assert (rows.ndim == 2
                    and rows.shape[1] == self.l_token), rows.shape
            if keys is None:
                keys = [r.tobytes() for r in rows]
            self._c_lookups.inc(rows.shape[0])

            new_rows: List[np.ndarray] = []
            pending: Dict[bytes, int] = {}
            if self._n == 0:                 # reserve the all-<PAD> row
                pad = np.zeros(self.l_token, np.int32)
                pending[pad.tobytes()] = PAD_ROW_ID
                new_rows.append(pad)
            ids = np.empty(rows.shape[0], np.int32)
            index = self._index
            for i, key in enumerate(keys):
                gid = index.get(key)
                if gid is None:
                    gid = pending.get(key)
                    if gid is None:
                        gid = self._n + len(new_rows)
                        pending[key] = gid
                        new_rows.append(rows[i])
                ids[i] = gid
            if new_rows:
                self._flush(np.stack(new_rows), pending)
        return ids

    def record_served(self, n: int) -> None:
        """Count dynamic rows the gather answered (called by the
        predictor's indexed dispatch path)."""
        self._c_served.inc(n)

    def index_clips(self, clip_tokens: np.ndarray) -> np.ndarray:
        """Serving-path adapter: (n, L_clip, L_token) tokenized clips ->
        (n, L_clip) int32 RT row ids.  Dynamic rows are deduped before the
        encoder sees them; all-<PAD> (masked) slots land on row 0."""
        from repro.core.standardize import dedupe_token_rows
        n, L, T = clip_tokens.shape
        uniq, inv = dedupe_token_rows(clip_tokens.reshape(n * L, T))
        ids = self.ensure_rows(uniq)
        return ids[inv].reshape(n, L).astype(np.int32)

    def _flush(self, rows: np.ndarray, pending: Dict[bytes, int]) -> None:
        k = rows.shape[0]
        # sharded: every device must get >= ENCODE_STABLE_MIN rows so its
        # local pass stays in the same kernel class as the unsharded one
        align = (self.n_shards * ENCODE_STABLE_MIN if self.n_shards
                 else 1)
        bucket = encode_bucket(k, align)
        if bucket != k:
            rows = np.concatenate(
                [rows, np.zeros((bucket - k, self.l_token), np.int32)])
        rt = self._encode(self.params, jnp.asarray(rows))[:k]
        lo = self._n
        while lo + k > self._capacity:
            self._capacity *= 2
        if self._table is None or self._table.shape[0] < self._capacity:
            table = jnp.zeros((self._capacity, rt.shape[1]), rt.dtype)
            if self._table is not None and lo:
                table = table.at[:lo].set(self._table[:lo])
            self._table = table
        self._table = self._table.at[lo:lo + k].set(rt)
        self._table.block_until_ready()      # build time stays in stats
        self._index.update(pending)
        self._n += k
        self._c_encoded.inc(k)
        self._c_passes.inc()

    # ------------------------------------------------------------------ #
    # Persistent store
    # ------------------------------------------------------------------ #

    def _load_store(self) -> None:
        """Adopt the persisted (rows -> RT vectors) table if a store
        exists under this cache's content key.  Key/version mismatch is
        the *expected* invalidation path (silent clean rebuild); a store
        that matches the key but fails validation — truncated file,
        wrong shapes, non-finite values — warns and cold-encodes."""
        path = self._store_path
        with self.obs.span("rt.store_load", instance=self.instance):
            self._load_store_inner(path)

    def _load_store_inner(self, path: Optional[Path]) -> None:
        try:
            step = ckpt.latest_step(str(path))
            if step is None:
                return
            meta = ckpt.read_manifest(step, str(path)).get("metadata", {})
            if (meta.get("store_key") != self._store_key
                    or meta.get("version") != RT_STORE_VERSION):
                return                           # clean rebuild, no warn
            n, lt, e = (int(meta["n_rows"]), int(meta["l_token"]),
                        int(meta["d_model"]))
            if n < 1 or (self.l_token is not None and lt != self.l_token):
                return
            state = ckpt.restore(
                {"rows": np.zeros((n, lt), np.int32),
                 "table": np.zeros((n, e), np.float32)},
                step, str(path))
            if self._faults is not None:
                # corrupt_rt_read chaos: a read that returned garbage —
                # raising inside this try exercises the real warn +
                # cold-encode fallback below
                self._faults.maybe_raise(
                    "corrupt_rt_read", "injected corrupt RT-store read")
            rows = np.ascontiguousarray(state["rows"])
            table = np.asarray(state["table"])
            if rows.shape != (n, lt) or table.shape != (n, e):
                raise ValueError(
                    f"stored shapes {rows.shape}/{table.shape} != "
                    f"manifest ({n}, {lt})/({n}, {e})")
            if rows.dtype != np.int32:
                raise ValueError(f"stored rows dtype {rows.dtype}")
            if not np.isfinite(table).all():
                raise ValueError("stored table has non-finite values")
            if rows[0].any():
                raise ValueError("stored pad row (id 0) is not all-<PAD>")
            keys = [r.tobytes() for r in rows]
            if len(set(keys)) != n:
                raise ValueError("stored rows are not unique")
            self.l_token = lt
            while self._capacity < n:
                self._capacity *= 2
            self._table = jnp.zeros(
                (self._capacity, e), table.dtype).at[:n].set(
                    jnp.asarray(table))
            self._table.block_until_ready()
            self._index = {k: i for i, k in enumerate(keys)}
            self._n = n
            self._persisted_rows = n
            self._g_loaded.set(n)
        except Exception as exc:                     # noqa: BLE001
            warnings.warn(
                f"RT store at {path} unreadable ({exc!r}); "
                "falling back to cold encode", stacklevel=2)
            self._index = {}
            self._table = None
            self._n = 0
            self._persisted_rows = 0
            self._g_loaded.set(0)
            self.obs.event("rt_store_load_failure", path=str(path),
                           error=repr(exc))

    def persist(self) -> Optional[Path]:
        """Checkpoint the current table under the store key (atomic
        overwrite via ``ckpt.save``).  No-op without a store, on an empty
        cache, or when nothing grew since the last load/persist.  Rows
        are reconstructed from the index keys, so the persisted mapping
        is exactly what ``ensure_rows`` would serve."""
        if (self._store_path is None or self._n == 0
                or self._n == self._persisted_rows):
            return None
        rows = np.zeros((self._n, self.l_token), np.int32)
        for key, gid in self._index.items():
            rows[gid] = np.frombuffer(key, np.int32)
        table = np.asarray(self._table[:self._n])
        meta = {"store_key": self._store_key,
                "version": RT_STORE_VERSION,
                "n_rows": int(self._n),
                "l_token": int(self.l_token),
                "d_model": int(table.shape[1])}
        out = ckpt.save({"rows": rows, "table": table}, 0,
                        str(self._store_path), metadata=meta,
                        pre_publish=(self._faults.crash_hook()
                                     if self._faults is not None
                                     else None))
        self._persisted_rows = self._n
        return out
