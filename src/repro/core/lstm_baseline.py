"""Ithemal-style LSTM baseline (paper Fig 10 comparison).

Hierarchical LSTM exactly per Ithemal [16]: a token-level LSTM summarizes
each instruction's standardized tokens into an instruction embedding, an
instruction-level LSTM runs over the clip's instruction embeddings, and a
linear head maps the final hidden state to the clip runtime.  Same
softplus(CPI) * length output parameterization as the attention predictor so
the Fig-10 comparison isolates the *architecture*, not the output scaling.
"""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp

from repro.models.layers import (
    ParamSpec, abstract_from_specs, dense_spec, init_from_specs)


def _lstm_specs(d_in: int, d_h: int) -> dict:
    return {"wx": dense_spec(d_in, 4 * d_h, ("embed", "mlp")),
            "wh": dense_spec(d_h, 4 * d_h, ("embed", "mlp")),
            "b": ParamSpec((4 * d_h,), ("mlp",), std=0.0)}


def model_specs(cfg) -> dict:
    E = cfg.d_model
    return {
        "embed": ParamSpec((cfg.vocab_size, E), ("vocab_in", "embed"),
                           std=1.0 / math.sqrt(E)),
        "tok_lstm": _lstm_specs(E, E),
        "inst_lstm": _lstm_specs(E, E),
        "head": {"w": dense_spec(E, 1, ("embed", None)),
                 "b": ParamSpec((1,), (None,), std=0.0)},
    }


def init_params(cfg, key):
    return init_from_specs(model_specs(cfg), key, cfg.param_dtype)


def abstract_params(cfg):
    return abstract_from_specs(model_specs(cfg), cfg.param_dtype)


def _lstm(p, xs, mask):
    """xs: (B, S, D); mask: (B, S) 1=valid.  Returns last valid hidden (B, H).

    Masked positions carry state through unchanged, so the 'final' hidden is
    the one at each sequence's true end.
    """
    B, S, D = xs.shape
    H = p["wh"].shape[0]
    h0 = jnp.zeros((B, H), xs.dtype)
    c0 = jnp.zeros((B, H), jnp.float32)

    def step(carry, inp):
        h, c = carry
        x, m = inp
        gates = (jnp.einsum("bd,dh->bh", x, p["wx"]) +
                 jnp.einsum("bd,dh->bh", h, p["wh"]) + p["b"])
        i, f, g, o = jnp.split(gates.astype(jnp.float32), 4, axis=-1)
        c_new = jax.nn.sigmoid(f + 1.0) * c + jax.nn.sigmoid(i) * jnp.tanh(g)
        h_new = (jax.nn.sigmoid(o) * jnp.tanh(c_new)).astype(h.dtype)
        m = m[:, None]
        return (jnp.where(m > 0, h_new, h),
                jnp.where(m > 0, c_new, c)), None

    (h, _), _ = jax.lax.scan(step, (h0, c0),
                             (jnp.moveaxis(xs, 1, 0),
                              jnp.moveaxis(mask, 1, 0)))
    return h


def forward(params, batch, cfg):
    """Same batch layout as the attention predictor; context unused
    (Ithemal has no context stream)."""
    clip_tokens = batch["clip_tokens"]                   # (B, L, T)
    clip_mask = batch["clip_mask"].astype(jnp.float32)   # (B, L)
    B, L, T = clip_tokens.shape
    tok_mask = (clip_tokens != 0).astype(jnp.float32)

    x = params["embed"][clip_tokens.reshape(B * L, T)].astype(cfg.dtype)
    inst_emb = _lstm(params["tok_lstm"], x, tok_mask.reshape(B * L, T))
    inst_emb = inst_emb.reshape(B, L, -1)

    h = _lstm(params["inst_lstm"], inst_emb, clip_mask)  # (B, E)
    y = (jnp.einsum("bd,do->bo", h, params["head"]["w"])
         + params["head"]["b"])[:, 0].astype(jnp.float32)
    n_inst = jnp.maximum(clip_mask.sum(-1), 1.0)
    return jax.nn.softplus(y) * n_inst


def mape_loss(params, batch, cfg):
    pred = forward(params, batch, cfg)
    fact = jnp.maximum(batch["time"].astype(jnp.float32), 1.0)
    mape = jnp.mean(jnp.abs(pred - fact) / fact)
    return mape, {"mape": mape}
