"""Code trace clip sampler (paper §IV-B, Fig 3, Fig 8).

Intervals are dominated by a few clip *contents* repeated thousands of times
(loop bodies) plus a long tail of rare unique clips (Fig 8).  The sampler:

  1. groups clips by content key and sorts groups by occurrence count,
  2. splits at ``threshold`` (paper: 200):
       frequent groups  -> sample *within* each group: keep
                           ``max(1, round(count * coef))`` occurrences so the
                           category distribution is preserved while the
                           occurrence numbers drop (paper's "lowering the
                           occurrence number ... preserving category
                           distribution"),
       rare groups      -> sample *across* groups: keep every occurrence of a
                           periodic ``coef`` fraction of the groups (paper's
                           "reduction of categories represented ... instead
                           of adjusting their occurrence number"),
  3. coefficient 0.02 turns the paper's 300 h training corpus into ~10 h.

``stratified_sample`` below is the *inference-time* sampler for the
analytical-ML fusion path (ROADMAP item 4): given per-clip stratum
labels (quantile bins of the analytical cycle estimate,
``analytical.stratify``), it picks a small representative subset per
stratum — deterministic under a seed, every non-empty stratum covered
with at least ``min_per_stratum`` clips — so only that subset runs
through the attention predictor.
"""
from __future__ import annotations

import dataclasses
import math
from collections import defaultdict
from typing import Dict, Hashable, List, Sequence, Tuple

import numpy as np

from repro.core.slicer import Clip


@dataclasses.dataclass(frozen=True)
class SampleStats:
    n_in: int
    n_out: int
    n_groups: int
    n_frequent_groups: int
    n_rare_groups: int
    n_rare_groups_kept: int

    @property
    def reduction(self) -> float:
        return self.n_out / max(self.n_in, 1)


def group_by_content(clips: Sequence[Clip]) -> Dict[int, List[int]]:
    """content key -> indices into ``clips`` (order of appearance)."""
    groups: Dict[int, List[int]] = defaultdict(list)
    for i, c in enumerate(clips):
        groups[c.key].append(i)
    return groups


def occurrence_histogram(clips: Sequence[Clip]) -> List[int]:
    """Occurrence count per unique content, descending (Fig 8b)."""
    return sorted((len(v) for v in group_by_content(clips).values()),
                  reverse=True)


def select_from_groups(groups: Dict[Hashable, List[int]], n_in: int,
                       threshold: int, coef: float
                       ) -> Tuple[List[int], SampleStats]:
    """Core selection over content groups (key -> occurrence indices in
    order of appearance); returns kept indices, sorted ascending.
    Shared by the object (``sample_clips``) and columnar
    (``sample_indices``) paths."""
    # deterministic order: by count desc, then first appearance
    ordered = sorted(groups.items(), key=lambda kv: (-len(kv[1]), kv[1][0]))

    keep: List[int] = []
    n_freq = n_rare = n_rare_kept = 0
    rare_period = max(1, round(1.0 / coef))
    rare_rank = 0
    for key, idxs in ordered:
        count = len(idxs)
        if count > threshold:
            n_freq += 1
            n_keep = max(1, round(count * coef))
            stride = count / n_keep
            keep.extend(idxs[int(j * stride)] for j in range(n_keep))
        else:
            n_rare += 1
            if rare_rank % rare_period == 0:       # periodic across groups
                n_rare_kept += 1
                keep.extend(idxs)
            rare_rank += 1

    keep.sort()
    stats = SampleStats(n_in=n_in, n_out=len(keep),
                        n_groups=len(ordered), n_frequent_groups=n_freq,
                        n_rare_groups=n_rare, n_rare_groups_kept=n_rare_kept)
    return keep, stats


def sample_clips(clips: Sequence[Clip], threshold: int = 200,
                 coef: float = 0.02) -> Tuple[List[Clip], SampleStats]:
    keep, stats = select_from_groups(group_by_content(clips), len(clips),
                                     threshold, coef)
    return [clips[i] for i in keep], stats


def sample_indices(keys: Sequence[Hashable], threshold: int = 200,
                   coef: float = 0.02) -> Tuple[List[int], SampleStats]:
    """Columnar path: clips are identified by precomputed content keys
    (e.g. the bytes of their gathered standardized-token rows) instead of
    materialized ``Clip`` objects.  Returns kept clip indices."""
    groups: Dict[Hashable, List[int]] = defaultdict(list)
    for i, k in enumerate(keys):
        groups[k].append(i)
    return select_from_groups(groups, len(keys), threshold, coef)


# --------------------------------------------------------------------------- #
# Stratified inference-time sampler (analytical-ML fusion path)
# --------------------------------------------------------------------------- #

@dataclasses.dataclass(frozen=True)
class StratifiedStats:
    n_in: int
    n_out: int
    n_strata: int                     # non-empty strata
    per_stratum: Tuple[Tuple[int, int, int], ...]   # (label, size, kept)

    @property
    def reduction(self) -> float:
        return self.n_out / max(self.n_in, 1)


def stratified_sample(strata: np.ndarray, fraction: float,
                      min_per_stratum: int = 1, seed: int = 0,
                      key: int = 0
                      ) -> Tuple[np.ndarray, StratifiedStats]:
    """Pick ``max(min_per_stratum, ceil(fraction * size))`` clips per
    non-empty stratum, without replacement, deterministically.

    ``strata`` is the (n,) per-clip label array; the draw is seeded by
    ``(seed, key)`` so distinct jobs (benchmarks, cores) sample
    independently but reproducibly.  Strata iterate in sorted label
    order and each stratum's picks come back sorted, so the result is
    invariant to how labels were numbered.  Returns (sorted indices,
    stats); ``fraction=1.0`` returns every index — the bitwise-identity
    contract the fusion path's ``fraction=1.0`` mode relies on.
    """
    strata = np.asarray(strata)
    n = strata.shape[0]
    rng = np.random.default_rng(
        np.asarray([abs(int(seed)), abs(int(key))], np.uint64))
    keep: List[np.ndarray] = []
    per: List[Tuple[int, int, int]] = []
    for label in np.unique(strata):
        idxs = np.flatnonzero(strata == label)
        size = idxs.shape[0]
        k = min(size, max(min_per_stratum,
                          math.ceil(fraction * size)))
        # rng.choice without replacement, sorted: deterministic and
        # independent of the stratum's internal ordering
        take = np.sort(rng.choice(size, size=k, replace=False))
        keep.append(idxs[take])
        per.append((int(label), size, k))
    indices = (np.sort(np.concatenate(keep)) if keep
               else np.zeros(0, np.int64)).astype(np.int64)
    stats = StratifiedStats(n_in=n, n_out=int(indices.shape[0]),
                            n_strata=len(per), per_stratum=tuple(per))
    return indices, stats
