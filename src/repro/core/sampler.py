"""Code trace clip sampler (paper §IV-B, Fig 3, Fig 8).

Intervals are dominated by a few clip *contents* repeated thousands of times
(loop bodies) plus a long tail of rare unique clips (Fig 8).  The sampler:

  1. groups clips by content key and sorts groups by occurrence count,
  2. splits at ``threshold`` (paper: 200):
       frequent groups  -> sample *within* each group: keep
                           ``max(1, round(count * coef))`` occurrences so the
                           category distribution is preserved while the
                           occurrence numbers drop (paper's "lowering the
                           occurrence number ... preserving category
                           distribution"),
       rare groups      -> sample *across* groups: keep every occurrence of a
                           periodic ``coef`` fraction of the groups (paper's
                           "reduction of categories represented ... instead
                           of adjusting their occurrence number"),
  3. coefficient 0.02 turns the paper's 300 h training corpus into ~10 h.
"""
from __future__ import annotations

import dataclasses
from collections import defaultdict
from typing import Dict, Hashable, List, Sequence, Tuple

from repro.core.slicer import Clip


@dataclasses.dataclass(frozen=True)
class SampleStats:
    n_in: int
    n_out: int
    n_groups: int
    n_frequent_groups: int
    n_rare_groups: int
    n_rare_groups_kept: int

    @property
    def reduction(self) -> float:
        return self.n_out / max(self.n_in, 1)


def group_by_content(clips: Sequence[Clip]) -> Dict[int, List[int]]:
    """content key -> indices into ``clips`` (order of appearance)."""
    groups: Dict[int, List[int]] = defaultdict(list)
    for i, c in enumerate(clips):
        groups[c.key].append(i)
    return groups


def occurrence_histogram(clips: Sequence[Clip]) -> List[int]:
    """Occurrence count per unique content, descending (Fig 8b)."""
    return sorted((len(v) for v in group_by_content(clips).values()),
                  reverse=True)


def select_from_groups(groups: Dict[Hashable, List[int]], n_in: int,
                       threshold: int, coef: float
                       ) -> Tuple[List[int], SampleStats]:
    """Core selection over content groups (key -> occurrence indices in
    order of appearance); returns kept indices, sorted ascending.
    Shared by the object (``sample_clips``) and columnar
    (``sample_indices``) paths."""
    # deterministic order: by count desc, then first appearance
    ordered = sorted(groups.items(), key=lambda kv: (-len(kv[1]), kv[1][0]))

    keep: List[int] = []
    n_freq = n_rare = n_rare_kept = 0
    rare_period = max(1, round(1.0 / coef))
    rare_rank = 0
    for key, idxs in ordered:
        count = len(idxs)
        if count > threshold:
            n_freq += 1
            n_keep = max(1, round(count * coef))
            stride = count / n_keep
            keep.extend(idxs[int(j * stride)] for j in range(n_keep))
        else:
            n_rare += 1
            if rare_rank % rare_period == 0:       # periodic across groups
                n_rare_kept += 1
                keep.extend(idxs)
            rare_rank += 1

    keep.sort()
    stats = SampleStats(n_in=n_in, n_out=len(keep),
                        n_groups=len(ordered), n_frequent_groups=n_freq,
                        n_rare_groups=n_rare, n_rare_groups_kept=n_rare_kept)
    return keep, stats


def sample_clips(clips: Sequence[Clip], threshold: int = 200,
                 coef: float = 0.02) -> Tuple[List[Clip], SampleStats]:
    keep, stats = select_from_groups(group_by_content(clips), len(clips),
                                     threshold, coef)
    return [clips[i] for i in keep], stats


def sample_indices(keys: Sequence[Hashable], threshold: int = 200,
                   coef: float = 0.02) -> Tuple[List[int], SampleStats]:
    """Columnar path: clips are identified by precomputed content keys
    (e.g. the bytes of their gathered standardized-token rows) instead of
    materialized ``Clip`` objects.  Returns kept clip indices."""
    groups: Dict[Hashable, List[int]] = defaultdict(list)
    for i, k in enumerate(keys):
        groups[k].append(i)
    return select_from_groups(groups, len(keys), threshold, coef)
