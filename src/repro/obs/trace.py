"""Low-overhead span tracer with Chrome-trace-event / Perfetto export.

Spans time with :func:`time.perf_counter_ns`, track nesting depth via
thread-local span stacks, and land in a bounded ring buffer
(``deque(maxlen=ring_size)``) so a long-running service never grows
without bound.  When the tracer is disabled, :meth:`Tracer.span`
returns the shared :data:`NULL_SPAN` singleton — no allocation, no
clock read — which is what keeps always-present instrumentation out of
the hot path's profile.

``export_chrome()`` emits the Chrome trace-event JSON format (complete
``"ph": "X"`` events, microsecond timestamps); open the file at
https://ui.perfetto.dev to get a zoomable per-thread timeline.
"""
from __future__ import annotations

import json
import threading
import time
from collections import deque
from typing import Dict, List, Optional


class _NullSpan:
    """Shared no-op span for the disabled path (identity-stable)."""

    __slots__ = ()
    seconds = 0.0

    def __enter__(self):
        return self

    def __exit__(self, *exc):
        return False


NULL_SPAN = _NullSpan()


class SpanRecord:
    """One finished span (or instant event when ``dur_ns`` is None)."""

    __slots__ = ("name", "cat", "start_ns", "dur_ns", "tid", "depth", "args")

    def __init__(self, name: str, cat: str, start_ns: int,
                 dur_ns: Optional[int], tid: int, depth: int,
                 args: Optional[Dict[str, object]]):
        self.name = name
        self.cat = cat
        self.start_ns = start_ns
        self.dur_ns = dur_ns
        self.tid = tid
        self.depth = depth
        self.args = args


class _LiveSpan:
    __slots__ = ("_tracer", "_name", "_cat", "_args", "_start", "seconds")

    def __init__(self, tracer: "Tracer", name: str, cat: str,
                 args: Optional[Dict[str, object]]):
        self._tracer = tracer
        self._name = name
        self._cat = cat
        self._args = args
        self.seconds = 0.0

    def __enter__(self):
        self._tracer._stack().append(self)
        self._start = time.perf_counter_ns()
        return self

    def __exit__(self, *exc):
        end = time.perf_counter_ns()
        stack = self._tracer._stack()
        if stack and stack[-1] is self:
            stack.pop()
        dur = end - self._start
        self.seconds = dur * 1e-9
        self._tracer._append(SpanRecord(
            self._name, self._cat, self._start, dur,
            threading.get_ident(), len(stack), self._args))
        return False


class Tracer:
    """Span tracer writing into a bounded ring buffer."""

    def __init__(self, ring_size: int = 4096, enabled: bool = False):
        self.enabled = bool(enabled)
        self._ring: deque = deque(maxlen=int(ring_size))
        self._lock = threading.Lock()
        self._tls = threading.local()
        self._t0_ns = time.perf_counter_ns()

    # -- internals ----------------------------------------------------------
    def _stack(self) -> list:
        stack = getattr(self._tls, "stack", None)
        if stack is None:
            stack = self._tls.stack = []
        return stack

    def _append(self, rec: SpanRecord) -> None:
        with self._lock:
            self._ring.append(rec)

    # -- recording ----------------------------------------------------------
    def span(self, name: str, cat: str = "span",
             args: Optional[Dict[str, object]] = None):
        """Context manager timing one span; NULL_SPAN when disabled."""
        if not self.enabled:
            return NULL_SPAN
        return _LiveSpan(self, name, cat, args)

    def record(self, name: str, start_ns: int, dur_ns: int,
               cat: str = "span",
               args: Optional[Dict[str, object]] = None) -> None:
        """Append an already-timed span (the Observability fast path)."""
        if not self.enabled:
            return
        self._append(SpanRecord(name, cat, start_ns, dur_ns,
                                threading.get_ident(),
                                len(self._stack()), args))

    def instant(self, name: str, cat: str = "event",
                args: Optional[Dict[str, object]] = None) -> None:
        """Record a zero-duration instant event (tier trips, faults)."""
        if not self.enabled:
            return
        self._append(SpanRecord(name, cat, time.perf_counter_ns(), None,
                                threading.get_ident(),
                                len(self._stack()), args))

    # -- control / export ---------------------------------------------------
    def set_enabled(self, enabled: bool) -> bool:
        prev, self.enabled = self.enabled, bool(enabled)
        return prev

    def clear(self) -> None:
        with self._lock:
            self._ring.clear()

    def spans(self) -> List[SpanRecord]:
        with self._lock:
            return list(self._ring)

    def export_chrome(self) -> dict:
        """Chrome trace-event JSON (open at ui.perfetto.dev)."""
        events = []
        for rec in self.spans():
            ev = {"name": rec.name, "cat": rec.cat,
                  "ts": (rec.start_ns - self._t0_ns) / 1e3,
                  "pid": 0, "tid": rec.tid}
            if rec.dur_ns is None:
                ev["ph"] = "i"
                ev["s"] = "t"
            else:
                ev["ph"] = "X"
                ev["dur"] = rec.dur_ns / 1e3
            args = dict(rec.args) if rec.args else {}
            args["depth"] = rec.depth
            ev["args"] = args
            events.append(ev)
        return {"traceEvents": events, "displayTimeUnit": "ms"}

    def dump(self, path: str) -> str:
        with open(path, "w") as f:
            json.dump(self.export_chrome(), f)
        return path


#: Process-global default tracer (disabled until someone enables it).
TRACER = Tracer()
