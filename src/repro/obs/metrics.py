"""Thread-safe metrics registry: counters, gauges, log-scale histograms.

One process-global :data:`REGISTRY` backs every entry point (engine,
serving, benchmarks, launch) so a single ``/metrics`` scrape sees the
whole picture; tests construct private :class:`MetricsRegistry`
instances for isolation.  The design is Prometheus-flavored:

* a metric *family* has a name, a kind (counter / gauge / histogram), a
  help string, and a fixed tuple of label names;
* ``family.labels(**labels)`` resolves one labeled *cell* and returns a
  bound handle (``inc`` / ``set`` / ``observe``) that owning objects
  cache on their hot paths — after the first resolve, a write is one
  lock acquire and one float add;
* components that may coexist (several predictors, rebuilt service
  backends, abandoned watchdog flush threads) isolate their series via
  :meth:`MetricsRegistry.next_instance` labels, which is what lets the
  Stats view classes stay exact under concurrency.

Everything here is stdlib-only and safe to import from any layer.
"""
from __future__ import annotations

import bisect
import itertools
import math
import threading
from typing import Dict, List, Mapping, Optional, Sequence, Tuple

COUNTER = "counter"
GAUGE = "gauge"
HISTOGRAM = "histogram"


def exp_buckets(start: float, factor: float, count: int) -> Tuple[float, ...]:
    """Log-scale histogram bucket upper bounds (``+Inf`` is implicit)."""
    if start <= 0 or factor <= 1 or count < 1:
        raise ValueError("exp_buckets needs start>0, factor>1, count>=1")
    out, v = [], float(start)
    for _ in range(count):
        out.append(v)
        v *= factor
    return tuple(out)


# 10 us .. ~84 s in factor-2 steps: spans a single tokenize interval up
# to a full-scale device predict.
DEFAULT_TIME_BUCKETS = exp_buckets(1e-5, 2.0, 24)


def _fmt(v: float) -> str:
    """Prometheus text-format number: integral floats render as ints."""
    if v == math.inf:
        return "+Inf"
    if v == -math.inf:
        return "-Inf"
    if v != v:  # NaN
        return "NaN"
    if float(v).is_integer() and abs(v) < 1e15:
        return str(int(v))
    return repr(float(v))


def _escape(v: str) -> str:
    return v.replace("\\", "\\\\").replace("\n", "\\n").replace('"', '\\"')


def _escape_help(v: str) -> str:
    # HELP text escapes only backslash and newline (format 0.0.4).
    return v.replace("\\", "\\\\").replace("\n", "\\n")


class _HistCell:
    __slots__ = ("counts", "sum", "n")

    def __init__(self, n_buckets: int):
        self.counts = [0] * (n_buckets + 1)  # last slot = +Inf overflow
        self.sum = 0.0
        self.n = 0


class CounterHandle:
    __slots__ = ("_lock", "_cell")

    def __init__(self, lock: threading.Lock, cell: List[float]):
        self._lock = lock
        self._cell = cell

    def inc(self, v: float = 1.0) -> None:
        if v < 0:
            raise ValueError("counters only go up; use a gauge")
        with self._lock:
            self._cell[0] += v

    @property
    def value(self) -> float:
        with self._lock:
            return self._cell[0]


class GaugeHandle:
    __slots__ = ("_lock", "_cell")

    def __init__(self, lock: threading.Lock, cell: List[float]):
        self._lock = lock
        self._cell = cell

    def set(self, v: float) -> None:
        with self._lock:
            self._cell[0] = float(v)

    def inc(self, v: float = 1.0) -> None:
        with self._lock:
            self._cell[0] += v

    def dec(self, v: float = 1.0) -> None:
        with self._lock:
            self._cell[0] -= v

    @property
    def value(self) -> float:
        with self._lock:
            return self._cell[0]


class HistogramHandle:
    __slots__ = ("_lock", "_cell", "_bounds")

    def __init__(self, lock: threading.Lock, cell: _HistCell,
                 bounds: Tuple[float, ...]):
        self._lock = lock
        self._cell = cell
        self._bounds = bounds

    def observe(self, v: float) -> None:
        idx = bisect.bisect_left(self._bounds, v)
        with self._lock:
            self._cell.counts[idx] += 1
            self._cell.sum += v
            self._cell.n += 1


class Family:
    """One named metric family; cells are resolved by label values."""

    def __init__(self, registry: "MetricsRegistry", name: str, kind: str,
                 help: str, labelnames: Tuple[str, ...],
                 buckets: Optional[Tuple[float, ...]] = None):
        self.name = name
        self.kind = kind
        self.help = help
        self.labelnames = labelnames
        self.buckets = buckets
        self._lock = registry._lock
        self._cells: Dict[Tuple[str, ...], object] = {}
        self._handles: Dict[Tuple[str, ...], object] = {}

    def _key(self, labels: Mapping[str, object]) -> Tuple[str, ...]:
        if set(labels) != set(self.labelnames):
            raise ValueError(
                f"{self.name}: labels {sorted(labels)} != declared "
                f"{sorted(self.labelnames)}")
        return tuple(str(labels[k]) for k in self.labelnames)

    def labels(self, **labels: object):
        key = self._key(labels)
        with self._lock:
            handle = self._handles.get(key)
            if handle is None:
                if self.kind == HISTOGRAM:
                    cell = _HistCell(len(self.buckets))
                    handle = HistogramHandle(self._lock, cell, self.buckets)
                else:
                    cell = [0.0]
                    cls = (CounterHandle if self.kind == COUNTER
                           else GaugeHandle)
                    handle = cls(self._lock, cell)
                self._cells[key] = cell
                self._handles[key] = handle
            return handle


class MetricsRegistry:
    """Thread-safe registry of metric families with text exposition."""

    def __init__(self):
        self._lock = threading.Lock()
        self._families: Dict[str, Family] = {}
        self._instance_seq = itertools.count()

    # -- family registration ------------------------------------------------
    def _family(self, name: str, kind: str, help: str,
                labelnames: Sequence[str],
                buckets: Optional[Sequence[float]] = None) -> Family:
        labelnames = tuple(labelnames)
        bt = tuple(buckets) if buckets is not None else None
        with self._lock:
            fam = self._families.get(name)
            if fam is not None:
                if fam.kind != kind or fam.labelnames != labelnames:
                    raise ValueError(
                        f"metric {name!r} re-registered with a different "
                        f"kind/labelnames")
                return fam
            fam = Family(self, name, kind, help, labelnames, bt)
            self._families[name] = fam
            return fam

    def counter(self, name: str, help: str = "",
                labelnames: Sequence[str] = ()) -> Family:
        return self._family(name, COUNTER, help, labelnames)

    def gauge(self, name: str, help: str = "",
              labelnames: Sequence[str] = ()) -> Family:
        return self._family(name, GAUGE, help, labelnames)

    def histogram(self, name: str, help: str = "",
                  labelnames: Sequence[str] = (),
                  buckets: Sequence[float] = DEFAULT_TIME_BUCKETS) -> Family:
        return self._family(name, HISTOGRAM, help, labelnames, buckets)

    def next_instance(self, prefix: str) -> str:
        """A process-unique instance label, e.g. ``predictor3``."""
        return f"{prefix}{next(self._instance_seq)}"

    # -- reads --------------------------------------------------------------
    def value(self, name: str, **labels: object) -> float:
        """Current value of one cell; 0.0 if the cell never existed.

        Counters/gauges return their value; histograms their ``sum``.
        """
        with self._lock:
            fam = self._families.get(name)
            if fam is None:
                return 0.0
            key = tuple(str(labels.get(k, "")) for k in fam.labelnames)
            cell = fam._cells.get(key)
            if cell is None:
                return 0.0
            return cell.sum if fam.kind == HISTOGRAM else cell[0]

    def collect(self, name: str, **match: object
                ) -> List[Tuple[Dict[str, str], object]]:
        """All cells of a family whose labels match ``match`` (subset).

        Returns ``[(labels_dict, value), ...]``; histogram values are
        ``(sum, count)`` tuples.
        """
        out: List[Tuple[Dict[str, str], object]] = []
        smatch = {k: str(v) for k, v in match.items()}
        with self._lock:
            fam = self._families.get(name)
            if fam is None:
                return out
            for key, cell in fam._cells.items():
                labels = dict(zip(fam.labelnames, key))
                if any(labels.get(k) != v for k, v in smatch.items()):
                    continue
                if fam.kind == HISTOGRAM:
                    out.append((labels, (cell.sum, cell.n)))
                else:
                    out.append((labels, cell[0]))
        return out

    def snapshot(self) -> Dict[str, dict]:
        """JSON-able dump of every family (for bench artifacts)."""
        snap: Dict[str, dict] = {}
        with self._lock:
            for name in sorted(self._families):
                fam = self._families[name]
                values = []
                for key in sorted(fam._cells):
                    cell = fam._cells[key]
                    labels = dict(zip(fam.labelnames, key))
                    if fam.kind == HISTOGRAM:
                        cum, buckets = 0, []
                        for le, c in zip(fam.buckets, cell.counts):
                            cum += c
                            buckets.append([le, cum])
                        buckets.append(["+Inf", cum + cell.counts[-1]])
                        values.append({"labels": labels, "sum": cell.sum,
                                       "count": cell.n, "buckets": buckets})
                    else:
                        values.append({"labels": labels, "value": cell[0]})
                snap[name] = {"kind": fam.kind, "help": fam.help,
                              "values": values}
        return snap

    # -- exposition ---------------------------------------------------------
    def render_prometheus(self) -> str:
        """Prometheus text exposition format (version 0.0.4)."""
        lines: List[str] = []
        with self._lock:
            for name in sorted(self._families):
                fam = self._families[name]
                lines.append(f"# HELP {name} {_escape_help(fam.help)}")
                lines.append(f"# TYPE {name} {fam.kind}")
                for key in sorted(fam._cells):
                    cell = fam._cells[key]
                    base = ",".join(
                        f'{k}="{_escape(v)}"'
                        for k, v in zip(fam.labelnames, key))
                    if fam.kind == HISTOGRAM:
                        cum = 0
                        for le, c in zip(fam.buckets, cell.counts):
                            cum += c
                            sep = "," if base else ""
                            lines.append(
                                f'{name}_bucket{{{base}{sep}le='
                                f'"{_fmt(le)}"}} {cum}')
                        cum += cell.counts[-1]
                        sep = "," if base else ""
                        lines.append(
                            f'{name}_bucket{{{base}{sep}le="+Inf"}} {cum}')
                        suffix = f"{{{base}}}" if base else ""
                        lines.append(f"{name}_sum{suffix} {_fmt(cell.sum)}")
                        lines.append(f"{name}_count{suffix} {cell.n}")
                    else:
                        suffix = f"{{{base}}}" if base else ""
                        lines.append(f"{name}{suffix} {_fmt(cell[0])}")
        return "\n".join(lines) + "\n"


#: Process-global default registry; ``/metrics`` serves this one.
REGISTRY = MetricsRegistry()
