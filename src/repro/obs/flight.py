"""Degradation flight recorder: bounded event ring + postmortem dumps.

The recorder keeps the last N structured events (tier transitions,
watchdog trips, fault injections, admission sheds) in memory at
near-zero cost.  When something goes wrong — the degradation controller
demotes a tier, the watchdog abandons a flush, a fault fires on a
persist — :meth:`FlightRecorder.postmortem` freezes the event ring, the
tail of the span trace, a metrics snapshot, and the caller's state dict
into one JSON file, written atomically (tmp + rename) so a crash
mid-dump never leaves a torn postmortem.
"""
from __future__ import annotations

import json
import os
import re
import threading
import time
from collections import deque
from typing import Dict, List, Optional

POSTMORTEM_SCHEMA_VERSION = 1

_SAFE = re.compile(r"[^A-Za-z0-9_.-]+")


class FlightRecorder:
    """Records recent events; dumps postmortems on degradation."""

    def __init__(self, out_dir: Optional[str] = None, *,
                 max_spans: int = 256, max_events: int = 512):
        self.out_dir = out_dir
        self.max_spans = int(max_spans)
        self._events: deque = deque(maxlen=int(max_events))
        self._lock = threading.Lock()
        self._seq = 0
        self.postmortems: List[str] = []
        self.last: Optional[dict] = None

    def record(self, kind: str, **data: object) -> None:
        """Append one structured event to the ring."""
        ev = {"wall_time": time.time(), "kind": kind}
        ev.update(data)
        with self._lock:
            self._events.append(ev)

    def events(self) -> List[dict]:
        with self._lock:
            return list(self._events)

    def postmortem(self, reason: str, *, state: Optional[dict] = None,
                   tracer=None, metrics=None) -> Optional[str]:
        """Freeze events + span tail + metrics + state; write JSON.

        Returns the file path (None when no ``out_dir`` is configured;
        the dict is still kept on :attr:`last` either way).
        """
        with self._lock:
            self._seq += 1
            seq = self._seq
            events = list(self._events)
        spans = []
        if tracer is not None:
            for rec in tracer.spans()[-self.max_spans:]:
                spans.append({
                    "name": rec.name, "cat": rec.cat,
                    "start_ns": rec.start_ns, "dur_ns": rec.dur_ns,
                    "tid": rec.tid, "depth": rec.depth,
                    "args": rec.args})
        post: Dict[str, object] = {
            "schema_version": POSTMORTEM_SCHEMA_VERSION,
            "reason": reason,
            "wall_time": time.time(),
            "seq": seq,
            "state": state,
            "events": events,
            "spans": spans,
            "metrics": metrics.snapshot() if metrics is not None else None,
        }
        self.last = post
        if self.out_dir is None:
            return None
        os.makedirs(self.out_dir, exist_ok=True)
        slug = _SAFE.sub("_", reason)[:64] or "unknown"
        path = os.path.join(self.out_dir,
                            f"postmortem_{seq:04d}_{slug}.json")
        tmp = f"{path}.tmp{os.getpid()}"
        with open(tmp, "w") as f:
            json.dump(post, f, indent=1)
        os.replace(tmp, path)
        self.postmortems.append(path)
        return path
