"""Unified observability: span tracing, metrics, flight recorder.

:class:`Observability` is the bundle components hold.  Its
:meth:`~Observability.span` primitive always times (the registry is the
system of record — the Stats view classes read it back), and feeds the
tracer ring only when tracing is enabled, so one ``with obs.span(...)``
stanza replaces both the old ad-hoc ``time.time()`` accounting and the
bench-only ``perf_counter`` breakdowns.

Construction::

    obs = Observability.from_config(config.observability)  # None -> defaults
    with obs.span("engine.tokenize", instance=self._inst) as sp:
        ...
    elapsed = sp.seconds          # same clock the registry recorded

``from_config(None)`` shares the process-global registry and the
disabled global tracer; ``ObservabilityConfig(trace=True)`` gets a
private enabled :class:`Tracer` the owner can dump with
``obs.tracer.dump(path)``.
"""
from __future__ import annotations

import time
from typing import Dict, Optional, Tuple

from .flight import POSTMORTEM_SCHEMA_VERSION, FlightRecorder
from .metrics import (COUNTER, DEFAULT_TIME_BUCKETS, GAUGE, HISTOGRAM,
                      REGISTRY, MetricsRegistry, exp_buckets)
from .trace import NULL_SPAN, TRACER, SpanRecord, Tracer

__all__ = [
    "COUNTER", "GAUGE", "HISTOGRAM", "DEFAULT_TIME_BUCKETS", "REGISTRY",
    "TRACER", "NULL_SPAN", "POSTMORTEM_SCHEMA_VERSION", "MetricsRegistry",
    "Tracer", "SpanRecord", "FlightRecorder", "Observability",
    "exp_buckets", "SPAN_SECONDS_TOTAL", "SPAN_SECONDS_HIST",
]

SPAN_SECONDS_TOTAL = "capsim_span_seconds_total"
SPAN_SECONDS_HIST = "capsim_span_seconds"


class _ObsSpan:
    """Times one span; writes the registry always, the tracer if on."""

    __slots__ = ("_obs", "_name", "_instance", "_args", "_start", "seconds")

    def __init__(self, obs: "Observability", name: str, instance: str,
                 args: Optional[Dict[str, object]]):
        self._obs = obs
        self._name = name
        self._instance = instance
        self._args = args
        self.seconds = 0.0

    def __enter__(self):
        self._start = time.perf_counter_ns()
        return self

    def __exit__(self, *exc):
        dur_ns = time.perf_counter_ns() - self._start
        self.seconds = dur_ns * 1e-9
        self._obs._record_span(self._name, self._instance, self._start,
                               dur_ns, self._args)
        return False


class Observability:
    """Bundle of tracer + metrics registry + optional flight recorder."""

    def __init__(self, *, metrics: Optional[MetricsRegistry] = None,
                 tracer: Optional[Tracer] = None,
                 flight: Optional[FlightRecorder] = None):
        self.metrics = REGISTRY if metrics is None else metrics
        self.tracer = TRACER if tracer is None else tracer
        self.flight = flight
        self._span_total = self.metrics.counter(
            SPAN_SECONDS_TOTAL, "Cumulative seconds per span.",
            ("span", "instance"))
        self._span_hist = self.metrics.histogram(
            SPAN_SECONDS_HIST, "Span latency distribution.",
            ("span", "instance"))
        self._handles: Dict[Tuple[str, str], tuple] = {}

    @classmethod
    def from_config(cls, config=None) -> "Observability":
        """Build from an ``ObservabilityConfig`` (or None -> defaults)."""
        if config is None:
            return cls()
        tracer = (Tracer(ring_size=config.trace_ring, enabled=True)
                  if config.trace else None)
        flight = (FlightRecorder(config.flight_dir,
                                 max_spans=config.flight_spans,
                                 max_events=config.flight_events)
                  if config.flight_dir is not None else None)
        return cls(tracer=tracer, flight=flight)

    # -- span primitive -----------------------------------------------------
    def span(self, name: str, instance: str = "",
             args: Optional[Dict[str, object]] = None) -> _ObsSpan:
        return _ObsSpan(self, name, instance, args)

    def _record_span(self, name: str, instance: str, start_ns: int,
                     dur_ns: int, args: Optional[Dict[str, object]]) -> None:
        key = (name, instance)
        handles = self._handles.get(key)
        if handles is None:
            handles = (self._span_total.labels(span=name, instance=instance),
                       self._span_hist.labels(span=name, instance=instance))
            self._handles[key] = handles
        secs = dur_ns * 1e-9
        handles[0].inc(secs)
        handles[1].observe(secs)
        if self.tracer.enabled:
            targs = dict(args) if args else {}
            if instance:
                targs["instance"] = instance
            self.tracer.record(name, start_ns, dur_ns, args=targs or None)

    # -- events -------------------------------------------------------------
    def event(self, kind: str, **data: object) -> None:
        """Record a structured event to flight ring + trace (if on)."""
        if self.flight is not None:
            self.flight.record(kind, **data)
        if self.tracer.enabled:
            self.tracer.instant(kind, args=dict(data) or None)

    def postmortem(self, reason: str,
                   state: Optional[dict] = None) -> Optional[str]:
        """Dump a postmortem if a flight recorder is configured."""
        if self.flight is None:
            return None
        return self.flight.postmortem(reason, state=state,
                                      tracer=(self.tracer
                                              if self.tracer.enabled
                                              else None),
                                      metrics=self.metrics)
