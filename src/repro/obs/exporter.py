"""Stdlib HTTP exporter serving Prometheus text at ``/metrics``.

No third-party dependency: a daemon-threaded
:class:`http.server.ThreadingHTTPServer` renders the registry on each
scrape.  ``port=0`` binds an ephemeral port (read it back from
``server.server_address``), which is what the benches and tests use.
"""
from __future__ import annotations

import threading
from http.server import BaseHTTPRequestHandler, ThreadingHTTPServer

from .metrics import REGISTRY, MetricsRegistry


def serve_metrics(registry: MetricsRegistry = REGISTRY, port: int = 0,
                  host: str = "127.0.0.1") -> ThreadingHTTPServer:
    """Start a daemon /metrics server; returns the (running) server.

    Call ``server.shutdown()`` to stop it; the bound port is
    ``server.server_address[1]``.
    """

    class Handler(BaseHTTPRequestHandler):
        def do_GET(self):  # noqa: N802 (stdlib API name)
            if self.path.split("?")[0] == "/metrics":
                body = registry.render_prometheus().encode()
                self.send_response(200)
                self.send_header("Content-Type",
                                 "text/plain; version=0.0.4; charset=utf-8")
                self.send_header("Content-Length", str(len(body)))
                self.end_headers()
                self.wfile.write(body)
            elif self.path.split("?")[0] == "/healthz":
                self.send_response(200)
                self.send_header("Content-Length", "3")
                self.end_headers()
                self.wfile.write(b"ok\n")
            else:
                self.send_error(404)

        def log_message(self, *args):  # silence per-scrape stderr spam
            pass

    server = ThreadingHTTPServer((host, port), Handler)
    server.daemon_threads = True
    thread = threading.Thread(target=server.serve_forever,
                              name="metrics-exporter", daemon=True)
    thread.start()
    return server
