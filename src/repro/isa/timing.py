"""O3 superscalar timing oracle (the paper's gem5 O3 golden model stand-in).

Computes per-instruction commit cycles for a dynamic trace under an
out-of-order core model parameterized exactly by the paper's Table III
knobs (FetchWidth, IssueWidth, CommitWidth, ROBEntry) plus functional-unit
counts/latencies, I/D caches, and a 2-bit branch predictor.

The model is *greedy-scheduled* rather than cycle-stepped: each instruction's
fetch / issue / complete / commit cycles are derived in trace order from
resource-availability bookkeeping.  That captures the first-order O3
behaviour the predictor must learn — data-dependency chains, structural FU
hazards, ROB back-pressure, cache locality, branch mispredict flushes —
at ~10^5-10^6 instructions/second in pure Python, which is what makes the
dataset pipeline runnable offline (gem5 itself is unavailable).

Commit times feed Algorithm 1 (core/slicer.py): clip runtime is the delta
of commit cycles across the clip boundary, exactly as the paper defines it.
"""
from __future__ import annotations

import dataclasses
from collections import defaultdict
from typing import Dict, List, Optional, Sequence, Tuple

from repro.isa.funcsim import TraceEntry
from repro.isa.isa import OPCODES


@dataclasses.dataclass(frozen=True)
class TimingParams:
    # Table III knobs
    fetch_width: int = 8
    issue_width: int = 8
    commit_width: int = 8
    rob_entries: int = 192
    # front end
    icache_lines: int = 128          # direct-mapped, 8 insts per line
    icache_line_insts: int = 8
    icache_miss_cycles: int = 8
    mispredict_penalty: int = 12
    decode_depth: int = 4            # fetch->dispatch pipeline depth
    # memory
    dcache_lines: int = 512          # direct-mapped, 64 B lines
    dcache_line_bytes: int = 64
    dcache_hit_cycles: int = 2
    dcache_miss_cycles: int = 40
    mshr_entries: int = 4            # outstanding misses (bounds MLP)
    # functional units: class -> number of units
    fu_counts: Tuple[Tuple[str, int], ...] = (
        ("int", 4), ("mul", 1), ("div", 1), ("fp", 2), ("fdiv", 1),
        ("lsu", 2), ("br", 1))

    def replace(self, **kw) -> "TimingParams":
        return dataclasses.replace(self, **kw)


class _TwoBitPredictor:
    """Per-pc 2-bit saturating counters, initialized weakly taken."""

    __slots__ = ("table",)

    def __init__(self):
        self.table: Dict[int, int] = {}

    def predict_and_update(self, pc: int, taken: bool) -> bool:
        c = self.table.get(pc, 2)
        pred = c >= 2
        self.table[pc] = min(3, c + 1) if taken else max(0, c - 1)
        return pred == taken


class _DirectMappedCache:
    __slots__ = ("tags", "n")

    def __init__(self, n_lines: int):
        self.tags = [-1] * n_lines
        self.n = n_lines

    def access(self, line: int) -> bool:
        idx = line % self.n
        hit = self.tags[idx] == line
        self.tags[idx] = line
        return hit


def simulate(trace: Sequence[TraceEntry],
             params: TimingParams = TimingParams()) -> List[int]:
    """Returns the commit cycle of every instruction in ``trace``."""
    p = params
    n = len(trace)
    commit = [0] * n
    if n == 0:
        return commit

    icache = _DirectMappedCache(p.icache_lines)
    dcache = _DirectMappedCache(p.dcache_lines)
    bpred = _TwoBitPredictor()
    fu_free: Dict[str, List[int]] = {
        cls: [0] * cnt for cls, cnt in p.fu_counts}
    mshr: List[int] = [0] * p.mshr_entries
    reg_ready: Dict[str, int] = {}          # reg -> cycle its value is ready
    issue_used: Dict[int, int] = defaultdict(int)
    store_ready: Dict[int, int] = {}        # mem line -> store completion

    fetch_cycle = 0                          # cycle of the current fetch group
    fetch_in_group = 0
    fetch_barrier = 0                        # redirect/miss stall point
    commit_cycle = 0
    commit_in_group = 0

    for i, e in enumerate(trace):
        info = OPCODES[e.inst.op]

        # ---------------- fetch ----------------
        line = e.pc // p.icache_line_insts
        if not icache.access(line):
            fetch_barrier = max(fetch_barrier,
                                fetch_cycle + p.icache_miss_cycles)
        if fetch_cycle < fetch_barrier:
            fetch_cycle = fetch_barrier
            fetch_in_group = 0
        elif fetch_in_group >= p.fetch_width:
            fetch_cycle += 1
            fetch_in_group = 0
            if fetch_cycle < fetch_barrier:
                fetch_cycle = fetch_barrier
        f_cyc = fetch_cycle
        fetch_in_group += 1

        # ---------------- dispatch (ROB back-pressure) ----------------
        disp = f_cyc + p.decode_depth
        if i >= p.rob_entries:
            disp = max(disp, commit[i - p.rob_entries])

        # ---------------- operand readiness ----------------
        ready = disp
        for s in e.inst.srcs:
            ready = max(ready, reg_ready.get(s, 0))
        if e.inst.mem_base is not None:
            ready = max(ready, reg_ready.get(e.inst.mem_base, 0))
        if info.uses_ctr:
            ready = max(ready, reg_ready.get("CTR", 0))
        if e.inst.op == "bc":
            ready = max(ready, reg_ready.get("CR", 0))
        if e.inst.op == "blr":
            ready = max(ready, reg_ready.get("LR", 0))

        # ---------------- issue: FU + issue-bandwidth ----------------
        units = fu_free[info.fu]
        u = min(range(len(units)), key=units.__getitem__)
        issue = max(ready, units[u])
        while issue_used[issue] >= p.issue_width:
            issue += 1
        issue_used[issue] += 1

        # ---------------- execute ----------------
        lat = info.latency
        if info.is_load:
            mline = (e.ea or 0) // p.dcache_line_bytes
            hit = dcache.access(mline)
            lat = p.dcache_hit_cycles if hit else p.dcache_miss_cycles
            dep = store_ready.get(mline)
            if dep is not None:              # store-to-load forwarding point
                issue = max(issue, dep)
            if not hit:                      # MSHR slot bounds miss overlap
                m = min(range(len(mshr)), key=mshr.__getitem__)
                issue = max(issue, mshr[m])
                mshr[m] = issue + lat
        complete = issue + lat
        units[u] = issue + 1                 # pipelined FUs: 1-cycle occupancy
        if info.fu in ("div", "fdiv"):
            units[u] = complete              # unpipelined dividers

        # ---------------- writeback ----------------
        for d in e.inst.dsts:
            reg_ready[d] = complete
        if info.writes_cr:
            reg_ready["CR"] = complete
        if info.writes_lr:
            reg_ready["LR"] = complete
        if info.uses_ctr:
            reg_ready["CTR"] = complete
        if info.is_store:
            mline = (e.ea or 0) // p.dcache_line_bytes
            dcache.access(mline)
            store_ready[mline] = complete

        # ---------------- branch resolution ----------------
        if info.is_branch and e.taken is not None:
            correct = bpred.predict_and_update(e.pc, e.taken)
            if not correct:
                fetch_barrier = max(fetch_barrier,
                                    complete + p.mispredict_penalty)

        # ---------------- commit (in order) ----------------
        c = max(complete + 1, commit_cycle)
        if c > commit_cycle:
            commit_cycle = c
            commit_in_group = 0
        elif commit_in_group >= p.commit_width:
            commit_cycle += 1
            commit_in_group = 0
        commit_in_group += 1
        commit[i] = commit_cycle

    return commit


def total_cycles(trace: Sequence[TraceEntry],
                 params: TimingParams = TimingParams()) -> int:
    c = simulate(trace, params)
    return c[-1] if c else 0
