"""O3 superscalar timing oracle (the paper's gem5 O3 golden model stand-in).

Computes per-instruction commit cycles for a dynamic trace under an
out-of-order core model parameterized exactly by the paper's Table III
knobs (FetchWidth, IssueWidth, CommitWidth, ROBEntry) plus functional-unit
counts/latencies, I/D caches, and a 2-bit branch predictor.

The model is *greedy-scheduled* rather than cycle-stepped: each instruction's
fetch / issue / complete / commit cycles are derived in trace order from
resource-availability bookkeeping.  That captures the first-order O3
behaviour the predictor must learn — data-dependency chains, structural FU
hazards, ROB back-pressure, cache locality, branch mispredict flushes —
at ~10^5-10^6 instructions/second in pure Python, which is what makes the
dataset pipeline runnable offline (gem5 itself is unavailable).

Commit times feed Algorithm 1 (core/slicer.py): clip runtime is the delta
of commit cycles across the clip boundary, exactly as the paper defines it.
"""
from __future__ import annotations

import dataclasses
from collections import defaultdict
from typing import Dict, List, Sequence, Tuple

import numpy as np

from repro.isa import compiled as comp
from repro.isa.funcsim import TraceEntry
from repro.isa.isa import OPCODES


@dataclasses.dataclass(frozen=True)
class TimingParams:
    # Table III knobs
    fetch_width: int = 8
    issue_width: int = 8
    commit_width: int = 8
    rob_entries: int = 192
    # front end
    icache_lines: int = 128          # direct-mapped, 8 insts per line
    icache_line_insts: int = 8
    icache_miss_cycles: int = 8
    mispredict_penalty: int = 12
    decode_depth: int = 4            # fetch->dispatch pipeline depth
    # memory
    dcache_lines: int = 512          # direct-mapped, 64 B lines
    dcache_line_bytes: int = 64
    dcache_hit_cycles: int = 2
    dcache_miss_cycles: int = 40
    mshr_entries: int = 4            # outstanding misses (bounds MLP)
    # functional units: class -> number of units
    fu_counts: Tuple[Tuple[str, int], ...] = (
        ("int", 4), ("mul", 1), ("div", 1), ("fp", 2), ("fdiv", 1),
        ("lsu", 2), ("br", 1))
    # shared resources (``simulate_multicore`` only; all three model
    # CROSS-core interference exclusively, so at n_cores == 1 they are
    # structurally inert and the oracle stays bitwise equal to
    # ``simulate_columnar``)
    llc_lines: int = 2048            # shared direct-mapped last-level cache
    llc_extra_miss_cycles: int = 60  # extra L1-miss latency when another
                                     # core's fill evicted the LLC line
    bus_cycles_per_miss: int = 4     # shared-bus occupancy per L1 miss

    def replace(self, **kw) -> "TimingParams":
        return dataclasses.replace(self, **kw)


class _TwoBitPredictor:
    """Per-pc 2-bit saturating counters, initialized weakly taken."""

    __slots__ = ("table",)

    def __init__(self):
        self.table: Dict[int, int] = {}

    def predict_and_update(self, pc: int, taken: bool) -> bool:
        c = self.table.get(pc, 2)
        pred = c >= 2
        self.table[pc] = min(3, c + 1) if taken else max(0, c - 1)
        return pred == taken


class _DirectMappedCache:
    __slots__ = ("tags", "n")

    def __init__(self, n_lines: int):
        self.tags = [-1] * n_lines
        self.n = n_lines

    def access(self, line: int) -> bool:
        idx = line % self.n
        hit = self.tags[idx] == line
        self.tags[idx] = line
        return hit


def simulate(trace: Sequence[TraceEntry],
             params: TimingParams = TimingParams()) -> List[int]:
    """Returns the commit cycle of every instruction in ``trace``."""
    p = params
    n = len(trace)
    commit = [0] * n
    if n == 0:
        return commit

    icache = _DirectMappedCache(p.icache_lines)
    dcache = _DirectMappedCache(p.dcache_lines)
    bpred = _TwoBitPredictor()
    fu_free: Dict[str, List[int]] = {
        cls: [0] * cnt for cls, cnt in p.fu_counts}
    mshr: List[int] = [0] * p.mshr_entries
    reg_ready: Dict[str, int] = {}          # reg -> cycle its value is ready
    issue_used: Dict[int, int] = defaultdict(int)
    store_ready: Dict[int, int] = {}        # mem line -> store completion

    fetch_cycle = 0                          # cycle of the current fetch group
    fetch_in_group = 0
    fetch_barrier = 0                        # redirect/miss stall point
    commit_cycle = 0
    commit_in_group = 0

    for i, e in enumerate(trace):
        info = OPCODES[e.inst.op]

        # ---------------- fetch ----------------
        line = e.pc // p.icache_line_insts
        if not icache.access(line):
            fetch_barrier = max(fetch_barrier,
                                fetch_cycle + p.icache_miss_cycles)
        if fetch_cycle < fetch_barrier:
            fetch_cycle = fetch_barrier
            fetch_in_group = 0
        elif fetch_in_group >= p.fetch_width:
            fetch_cycle += 1
            fetch_in_group = 0
            if fetch_cycle < fetch_barrier:
                fetch_cycle = fetch_barrier
        f_cyc = fetch_cycle
        fetch_in_group += 1

        # ---------------- dispatch (ROB back-pressure) ----------------
        disp = f_cyc + p.decode_depth
        if i >= p.rob_entries:
            disp = max(disp, commit[i - p.rob_entries])

        # ---------------- operand readiness ----------------
        ready = disp
        for s in e.inst.srcs:
            ready = max(ready, reg_ready.get(s, 0))
        if e.inst.mem_base is not None:
            ready = max(ready, reg_ready.get(e.inst.mem_base, 0))
        if info.uses_ctr:
            ready = max(ready, reg_ready.get("CTR", 0))
        if e.inst.op == "bc":
            ready = max(ready, reg_ready.get("CR", 0))
        if e.inst.op == "blr":
            ready = max(ready, reg_ready.get("LR", 0))

        # ---------------- issue: FU + issue-bandwidth ----------------
        units = fu_free[info.fu]
        u = min(range(len(units)), key=units.__getitem__)
        issue = max(ready, units[u])
        while issue_used[issue] >= p.issue_width:
            issue += 1
        issue_used[issue] += 1

        # ---------------- execute ----------------
        lat = info.latency
        if info.is_load:
            mline = (e.ea or 0) // p.dcache_line_bytes
            hit = dcache.access(mline)
            lat = p.dcache_hit_cycles if hit else p.dcache_miss_cycles
            dep = store_ready.get(mline)
            if dep is not None:              # store-to-load forwarding point
                issue = max(issue, dep)
            if not hit:                      # MSHR slot bounds miss overlap
                m = min(range(len(mshr)), key=mshr.__getitem__)
                issue = max(issue, mshr[m])
                mshr[m] = issue + lat
        complete = issue + lat
        units[u] = issue + 1                 # pipelined FUs: 1-cycle occupancy
        if info.fu in ("div", "fdiv"):
            units[u] = complete              # unpipelined dividers

        # ---------------- writeback ----------------
        for d in e.inst.dsts:
            reg_ready[d] = complete
        if info.writes_cr:
            reg_ready["CR"] = complete
        if info.writes_lr:
            reg_ready["LR"] = complete
        if info.uses_ctr:
            reg_ready["CTR"] = complete
        if info.is_store:
            mline = (e.ea or 0) // p.dcache_line_bytes
            dcache.access(mline)
            store_ready[mline] = complete

        # ---------------- branch resolution ----------------
        if info.is_branch and e.taken is not None:
            correct = bpred.predict_and_update(e.pc, e.taken)
            if not correct:
                fetch_barrier = max(fetch_barrier,
                                    complete + p.mispredict_penalty)

        # ---------------- commit (in order) ----------------
        c = max(complete + 1, commit_cycle)
        if c > commit_cycle:
            commit_cycle = c
            commit_in_group = 0
        elif commit_in_group >= p.commit_width:
            commit_cycle += 1
            commit_in_group = 0
        commit_in_group += 1
        commit[i] = commit_cycle

    return commit


def total_cycles(trace: Sequence[TraceEntry],
                 params: TimingParams = TimingParams()) -> int:
    c = simulate(trace, params)
    return c[-1] if c else 0


# --------------------------------------------------------------------------- #
# Columnar path: same greedy model over ``repro.isa.compiled.Trace``
# --------------------------------------------------------------------------- #

FU_ORDER = ("int", "mul", "div", "fp", "fdiv", "lsu", "br")
_FU_INDEX = {cls: i for i, cls in enumerate(FU_ORDER)}


def _static_tables(cprog: comp.CompiledProgram):
    """Per-static-instruction operand/property tables for the columnar
    oracle: everything ``simulate`` reads off ``TraceEntry.inst`` is
    precomputed once per program instead of per dynamic instruction.

    ``read_slots[pc]`` folds explicit sources, the memory base, and the
    implicit CR/CTR/LR reads into one tuple of unified register slots;
    ``write_slots[pc]`` does the same for destinations and implicit
    writes — so the hot loop is pure list indexing.
    """
    if cprog._timing_tables is not None:
        return cprog._timing_tables
    fu_idx: List[int] = []
    latency: List[int] = []
    is_load: List[bool] = []
    is_store: List[bool] = []
    is_branch: List[bool] = []
    read_slots: List[Tuple[int, ...]] = []
    write_slots: List[Tuple[int, ...]] = []
    for i, inst in enumerate(cprog.insts):
        info = OPCODES[inst.op]
        fu_idx.append(_FU_INDEX[info.fu])
        latency.append(info.latency)
        is_load.append(info.is_load)
        is_store.append(info.is_store)
        is_branch.append(info.is_branch)
        reads = [int(x) for x in cprog.srcs[i] if x >= 0]
        if cprog.mem_base[i] >= 0:
            reads.append(int(cprog.mem_base[i]))
        if info.uses_ctr:
            reads.append(comp.CTR_SLOT)
        if inst.op == "bc":
            reads.append(comp.CR_SLOT)
        if inst.op == "blr":
            reads.append(comp.LR_SLOT)
        read_slots.append(tuple(reads))
        writes = [int(x) for x in cprog.dsts[i] if x >= 0]
        if info.writes_cr:
            writes.append(comp.CR_SLOT)
        if info.writes_lr:
            writes.append(comp.LR_SLOT)
        if info.uses_ctr:
            writes.append(comp.CTR_SLOT)
        write_slots.append(tuple(writes))
    tables = (fu_idx, latency, is_load, is_store, is_branch,
              read_slots, write_slots)
    cprog._timing_tables = tables
    return tables


def simulate_columnar(trace: comp.Trace,
                      params: TimingParams = TimingParams()) -> np.ndarray:
    """Commit cycle of every instruction in a columnar ``Trace``.

    Bitwise identical to ``simulate`` on the equivalent object trace:
    the same greedy bookkeeping, with per-static decode hoisted out of
    the loop and name-keyed dicts replaced by slot-indexed lists.
    """
    p = params
    n = len(trace)
    commit = [0] * n
    if n == 0:
        return np.zeros(0, np.int64)

    (fu_idx, latency_t, is_load_t, is_store_t, is_branch_t,
     read_slots, write_slots) = _static_tables(trace.program)
    pcs = trace.pc.tolist()
    eas = trace.ea.tolist()
    takens = trace.taken.tolist()

    fu_units: List[List[int]] = [[] for _ in FU_ORDER]
    for cls, cnt in p.fu_counts:
        fu_units[_FU_INDEX[cls]] = [0] * cnt
    itags = [-1] * p.icache_lines
    dtags = [-1] * p.dcache_lines
    n_ilines, n_dlines = p.icache_lines, p.dcache_lines
    bpred: Dict[int, int] = {}
    mshr: List[int] = [0] * p.mshr_entries
    reg_ready = [0] * comp.N_SLOTS
    issue_used: Dict[int, int] = defaultdict(int)
    store_ready: Dict[int, int] = {}

    fetch_cycle = 0
    fetch_in_group = 0
    fetch_barrier = 0
    commit_cycle = 0
    commit_in_group = 0

    for i in range(n):
        pc = pcs[i]

        # ---------------- fetch ----------------
        line = pc // p.icache_line_insts
        idx = line % n_ilines
        if itags[idx] != line:
            itags[idx] = line
            fetch_barrier = max(fetch_barrier,
                                fetch_cycle + p.icache_miss_cycles)
        else:
            itags[idx] = line
        if fetch_cycle < fetch_barrier:
            fetch_cycle = fetch_barrier
            fetch_in_group = 0
        elif fetch_in_group >= p.fetch_width:
            fetch_cycle += 1
            fetch_in_group = 0
            if fetch_cycle < fetch_barrier:
                fetch_cycle = fetch_barrier
        f_cyc = fetch_cycle
        fetch_in_group += 1

        # ---------------- dispatch (ROB back-pressure) ----------------
        disp = f_cyc + p.decode_depth
        if i >= p.rob_entries:
            disp = max(disp, commit[i - p.rob_entries])

        # ---------------- operand readiness ----------------
        ready = disp
        for s in read_slots[pc]:
            r = reg_ready[s]
            if r > ready:
                ready = r

        # ---------------- issue: FU + issue-bandwidth ----------------
        units = fu_units[fu_idx[pc]]
        u = min(range(len(units)), key=units.__getitem__)
        issue = max(ready, units[u])
        while issue_used[issue] >= p.issue_width:
            issue += 1
        issue_used[issue] += 1

        # ---------------- execute ----------------
        lat = latency_t[pc]
        if is_load_t[pc]:
            mline = eas[i] // p.dcache_line_bytes
            didx = mline % n_dlines
            hit = dtags[didx] == mline
            dtags[didx] = mline
            lat = p.dcache_hit_cycles if hit else p.dcache_miss_cycles
            dep = store_ready.get(mline)
            if dep is not None:              # store-to-load forwarding point
                issue = max(issue, dep)
            if not hit:                      # MSHR slot bounds miss overlap
                m = min(range(len(mshr)), key=mshr.__getitem__)
                issue = max(issue, mshr[m])
                mshr[m] = issue + lat
        complete = issue + lat
        units[u] = issue + 1                 # pipelined FUs: 1-cycle occupancy
        fu = fu_idx[pc]
        if fu == 2 or fu == 4:               # unpipelined div/fdiv
            units[u] = complete

        # ---------------- writeback ----------------
        for d in write_slots[pc]:
            reg_ready[d] = complete
        if is_store_t[pc]:
            mline = eas[i] // p.dcache_line_bytes
            dtags[mline % n_dlines] = mline
            store_ready[mline] = complete

        # ---------------- branch resolution ----------------
        if is_branch_t[pc] and takens[i] >= 0:
            c = bpred.get(pc, 2)
            pred = c >= 2
            taken = takens[i] == 1
            bpred[pc] = min(3, c + 1) if taken else max(0, c - 1)
            if pred != taken:
                fetch_barrier = max(fetch_barrier,
                                    complete + p.mispredict_penalty)

        # ---------------- commit (in order) ----------------
        c = complete + 1
        if c < commit_cycle:
            c = commit_cycle
        if c > commit_cycle:
            commit_cycle = c
            commit_in_group = 0
        elif commit_in_group >= p.commit_width:
            commit_cycle += 1
            commit_in_group = 0
        commit_in_group += 1
        commit[i] = commit_cycle

    return np.asarray(commit, np.int64)


def total_cycles_columnar(trace: comp.Trace,
                          params: TimingParams = TimingParams()) -> int:
    c = simulate_columnar(trace, params)
    return int(c[-1]) if len(c) else 0


# --------------------------------------------------------------------------- #
# Multicore oracle: per-core simulate_columnar state + shared LLC / bus
# --------------------------------------------------------------------------- #


class _CoreTimingState:
    """One core's complete ``simulate_columnar`` bookkeeping, stepped in
    interleaved chunks by ``simulate_multicore``.  Field-for-field the
    locals of ``simulate_columnar`` so the per-core model is the same
    greedy machine bit for bit."""

    __slots__ = ("tables", "pcs", "eas", "takens", "commit", "i",
                 "fu_units", "itags", "dtags", "bpred", "mshr",
                 "reg_ready", "issue_used", "store_ready",
                 "fetch_cycle", "fetch_in_group", "fetch_barrier",
                 "commit_cycle", "commit_in_group")

    def __init__(self, trace: comp.Trace, p: TimingParams):
        self.tables = _static_tables(trace.program)
        self.pcs = trace.pc.tolist()
        self.eas = trace.ea.tolist()
        self.takens = trace.taken.tolist()
        self.commit = [0] * len(trace)
        self.i = 0
        self.fu_units = [[] for _ in FU_ORDER]
        for cls, cnt in p.fu_counts:
            self.fu_units[_FU_INDEX[cls]] = [0] * cnt
        self.itags = [-1] * p.icache_lines
        self.dtags = [-1] * p.dcache_lines
        self.bpred: Dict[int, int] = {}
        self.mshr: List[int] = [0] * p.mshr_entries
        self.reg_ready = [0] * comp.N_SLOTS
        self.issue_used: Dict[int, int] = defaultdict(int)
        self.store_ready: Dict[int, int] = {}
        self.fetch_cycle = 0
        self.fetch_in_group = 0
        self.fetch_barrier = 0
        self.commit_cycle = 0
        self.commit_in_group = 0


def simulate_multicore(traces: Sequence[comp.Trace],
                       schedule: Sequence[Tuple[int, int]],
                       params: TimingParams = TimingParams()
                       ) -> List[np.ndarray]:
    """Commit cycle of every instruction of every core.

    ``traces``/``schedule`` come from ``multicore.run_multicore``: the
    oracle replays the same deterministic interleaved commit order, each
    core stepping its own private ``simulate_columnar`` machine (front
    end, ROB back-pressure, L1 caches, branch predictor, FUs, MSHRs)
    while L1 misses additionally contend on two SHARED structures:

      shared LLC   a direct-mapped tag array filled by every core's L1
                   misses; a miss whose LLC slot holds a line installed
                   by a DIFFERENT core pays ``llc_extra_miss_cycles``
                   (cross-core conflict eviction).  Cold misses and
                   same-core conflicts cost exactly the single-core
                   ``dcache_miss_cycles``.
      shared bus   each L1 miss occupies the memory bus for
                   ``bus_cycles_per_miss``; a miss issued while ANOTHER
                   core's transfer holds the bus waits for it (a core's
                   own misses already serialize through its MSHRs).

    Both penalties key on *another core*, so at N=1 neither can fire and
    the returned commit array is bitwise equal to ``simulate_columnar``
    on the same trace — the subsystem's oracle anchor, enforced by the
    CI multicore gate.
    """
    p = params
    cores = [_CoreTimingState(t, p) for t in traces]
    need = [0] * len(cores)
    for c, n in schedule:
        need[c] += n
    for c, st in enumerate(cores):
        assert need[c] <= len(st.commit), \
            f"schedule overruns core {c}'s trace " \
            f"({need[c]} > {len(st.commit)})"

    n_llc = p.llc_lines
    llc_tags = [-1] * n_llc
    llc_owner = [-1] * n_llc
    bus_free = 0
    bus_owner = -1

    for core_id, count in schedule:
        st = cores[core_id]
        (fu_idx, latency_t, is_load_t, is_store_t, is_branch_t,
         read_slots, write_slots) = st.tables
        pcs, eas, takens, commit = st.pcs, st.eas, st.takens, st.commit
        itags, dtags = st.itags, st.dtags
        n_ilines, n_dlines = p.icache_lines, p.dcache_lines
        reg_ready, issue_used = st.reg_ready, st.issue_used
        mshr, store_ready, bpred = st.mshr, st.store_ready, st.bpred

        for i in range(st.i, st.i + count):
            pc = pcs[i]

            # ---------------- fetch ----------------
            line = pc // p.icache_line_insts
            idx = line % n_ilines
            if itags[idx] != line:
                itags[idx] = line
                st.fetch_barrier = max(
                    st.fetch_barrier,
                    st.fetch_cycle + p.icache_miss_cycles)
            else:
                itags[idx] = line
            if st.fetch_cycle < st.fetch_barrier:
                st.fetch_cycle = st.fetch_barrier
                st.fetch_in_group = 0
            elif st.fetch_in_group >= p.fetch_width:
                st.fetch_cycle += 1
                st.fetch_in_group = 0
                if st.fetch_cycle < st.fetch_barrier:
                    st.fetch_cycle = st.fetch_barrier
            f_cyc = st.fetch_cycle
            st.fetch_in_group += 1

            # ---------------- dispatch (ROB back-pressure) ----------------
            disp = f_cyc + p.decode_depth
            if i >= p.rob_entries:
                disp = max(disp, commit[i - p.rob_entries])

            # ---------------- operand readiness ----------------
            ready = disp
            for s in read_slots[pc]:
                r = reg_ready[s]
                if r > ready:
                    ready = r

            # ---------------- issue: FU + issue-bandwidth ----------------
            units = st.fu_units[fu_idx[pc]]
            u = min(range(len(units)), key=units.__getitem__)
            issue = max(ready, units[u])
            while issue_used[issue] >= p.issue_width:
                issue += 1
            issue_used[issue] += 1

            # ---------------- execute ----------------
            lat = latency_t[pc]
            if is_load_t[pc]:
                mline = eas[i] // p.dcache_line_bytes
                didx = mline % n_dlines
                hit = dtags[didx] == mline
                dtags[didx] = mline
                lat = p.dcache_hit_cycles if hit else p.dcache_miss_cycles
                dep = store_ready.get(mline)
                if dep is not None:          # store-to-load forwarding point
                    issue = max(issue, dep)
                if not hit:
                    # shared LLC: only a line another core's fill evicted
                    # costs extra (cold/same-core misses == single-core)
                    lidx = mline % n_llc
                    if llc_tags[lidx] != mline:
                        if llc_tags[lidx] != -1 \
                                and llc_owner[lidx] != core_id:
                            lat += p.llc_extra_miss_cycles
                        llc_tags[lidx] = mline
                    llc_owner[lidx] = core_id
                    # shared bus: wait only on ANOTHER core's transfer
                    if bus_owner != core_id and bus_free > issue:
                        issue = bus_free
                    # MSHR slot bounds this core's own miss overlap
                    m = min(range(len(mshr)), key=mshr.__getitem__)
                    issue = max(issue, mshr[m])
                    mshr[m] = issue + lat
                    bus_owner = core_id
                    bus_free = issue + p.bus_cycles_per_miss
            complete = issue + lat
            units[u] = issue + 1             # pipelined FUs
            fu = fu_idx[pc]
            if fu == 2 or fu == 4:           # unpipelined div/fdiv
                units[u] = complete

            # ---------------- writeback ----------------
            for d in write_slots[pc]:
                reg_ready[d] = complete
            if is_store_t[pc]:
                mline = eas[i] // p.dcache_line_bytes
                dtags[mline % n_dlines] = mline
                store_ready[mline] = complete

            # ---------------- branch resolution ----------------
            if is_branch_t[pc] and takens[i] >= 0:
                c = bpred.get(pc, 2)
                pred = c >= 2
                taken = takens[i] == 1
                bpred[pc] = min(3, c + 1) if taken else max(0, c - 1)
                if pred != taken:
                    st.fetch_barrier = max(
                        st.fetch_barrier,
                        complete + p.mispredict_penalty)

            # ---------------- commit (in order) ----------------
            c = complete + 1
            if c < st.commit_cycle:
                c = st.commit_cycle
            if c > st.commit_cycle:
                st.commit_cycle = c
                st.commit_in_group = 0
            elif st.commit_in_group >= p.commit_width:
                st.commit_cycle += 1
                st.commit_in_group = 0
            st.commit_in_group += 1
            commit[i] = st.commit_cycle
        st.i += count

    for core_id, st in enumerate(cores):
        assert st.i == len(st.commit), \
            f"schedule left core {core_id} partially simulated"
    return [np.asarray(st.commit, np.int64) for st in cores]


def total_cycles_multicore(traces: Sequence[comp.Trace],
                           schedule: Sequence[Tuple[int, int]],
                           params: TimingParams = TimingParams()
                           ) -> List[int]:
    """Per-core total cycles (last commit cycle, 0 for an empty core)."""
    commits = simulate_multicore(traces, schedule, params)
    return [int(c[-1]) if len(c) else 0 for c in commits]
