"""Multi-core trace simulation subsystem (front-end half).

The paper motivates CAPSim by the cost of simulating modern multi-core
CPUs, yet the base repro is single-core everywhere.  This module adds the
missing workload axis while reusing the whole existing stack *per core*:

``MulticoreBenchmark``
    N per-core programs (``progen.build_core_program`` multi-threaded
    variants: sharded stream/chase kernels plus a shared-counter
    contention kernel) over ONE shared data memory.  Every core's program
    is structurally identical — only heap-base immediates differ — so the
    compiled token tables (and therefore the static-instruction RT cache)
    are shared across cores for free.

``run_multicore``
    drives ``funcsim.run_compiled`` per core in a deterministic
    round-robin quantum schedule over the shared memory: core ``order[0]``
    commits up to ``quantum`` instructions, then ``order[1]``, ... until
    every core has retired ``max_instructions_per_core`` (or exited).
    Stores from core i's quantum are architecturally visible to every
    later quantum — the interleaved commit order the timing oracle
    (``timing.simulate_multicore``) replays.  Emits one columnar ``Trace``
    per core plus the ``(core, n)`` chunk schedule.

At N=1 the quantum scheduler degenerates to consecutive resumed
``run_compiled`` calls on one state, so the emitted trace (pc/ea/taken
columns AND snapshot rows) is bitwise identical to a single
``run_compiled`` call — the anchor for the subsystem's bitwise gates.
"""
from __future__ import annotations

import dataclasses
import zlib
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.isa import funcsim, progen
from repro.isa.compiled import N_IREGS, NIA_SLOT, CompiledProgram, Trace, \
    compile_program
from repro.isa.funcsim import CompiledState, MachineState
from repro.isa.isa import Instruction

DEFAULT_QUANTUM = 64

MULTICORE_KINDS = progen.MT_KINDS
MULTICORE_NAMES = tuple(f"mt.{k}" for k in MULTICORE_KINDS)


@dataclasses.dataclass
class MulticoreBenchmark:
    """N per-core programs over a shared data memory."""

    name: str                              # e.g. "mt.mix"
    kind: str                              # progen.MT_KINDS member
    n_cores: int
    ckp_num: int
    seed: int
    programs: List[List[Instruction]]      # one per core
    _compiled: Optional[List[CompiledProgram]] = dataclasses.field(
        default=None, repr=False, compare=False)

    def compiled(self) -> List[CompiledProgram]:
        """Per-core columnar SoA programs, compiled once."""
        if self._compiled is None:
            self._compiled = [compile_program(p) for p in self.programs]
        return self._compiled

    def fresh_states(self) -> List[CompiledState]:
        """Per-core architectural states sharing ONE memory dict,
        initialized by ``progen.mt_setup_memory``."""
        mem: Dict[int, int] = {}
        progen.mt_setup_memory(mem, self.n_cores, self.seed)
        return [CompiledState(iregs=[0] * N_IREGS, fregs=[0.0] * 32,
                              mem=mem) for _ in range(self.n_cores)]


def build_multicore_benchmark(name: str, n_cores: int,
                              ckp_num: int = 4) -> MulticoreBenchmark:
    """``name`` is "mt.<kind>" (or a bare kind) with kind one of
    ``progen.MT_KINDS``."""
    kind = name.split(".", 1)[1] if name.startswith("mt.") else name
    if n_cores < 1:
        raise ValueError(f"n_cores must be >= 1, got {n_cores}")
    seed = zlib.crc32(f"mt.{kind}".encode()) & 0xFFFFFFFF
    programs = [progen.build_core_program(kind, core, seed)
                for core in range(n_cores)]
    return MulticoreBenchmark(name=f"mt.{kind}", kind=kind,
                              n_cores=n_cores, ckp_num=ckp_num, seed=seed,
                              programs=programs)


def all_multicore_benchmarks(n_cores: int) -> List[MulticoreBenchmark]:
    return [build_multicore_benchmark(n, n_cores) for n in MULTICORE_NAMES]


def single_core_benchmark(name: str, ckp_num: int = 4) -> progen.Benchmark:
    """An mt.* benchmark as a plain single-core ``progen.Benchmark``:
    core 0's program over the 1-core shared-memory setup.  This is the
    bridge to the single-core dataset pipeline — at N=1 the multicore
    builders must be bitwise identical to ``build_dataset`` over this."""
    mb = build_multicore_benchmark(name, 1, ckp_num=ckp_num)

    def setup(st: MachineState) -> None:
        progen.mt_setup_memory(st.mem, 1, mb.seed)

    return progen.Benchmark(name=mb.name, tags="mt", set_no=0,
                            ckp_num=ckp_num, program=mb.programs[0],
                            setup=setup)


def clone_states(states: Sequence[CompiledState]) -> List[CompiledState]:
    """Replay anchor for a multicore checkpoint: independent copies of
    the per-core register files sharing ONE copy of the shared memory
    (``CompiledState.clone`` would give each core a private memory and
    break cross-core store visibility on replay)."""
    mem = dict(states[0].mem)
    for st in states:
        assert st.mem is states[0].mem, \
            "multicore states must share one memory dict"
    return [CompiledState(iregs=list(st.iregs), fregs=list(st.fregs),
                          mem=mem) for st in states]


@dataclasses.dataclass
class MulticoreTrace:
    """Per-core columnar traces plus the deterministic commit interleave.

    ``schedule`` lists ``(core, n)`` chunks in global commit order: the
    first ``n`` uncommitted instructions of ``cores[core]`` committed as
    one quantum.  ``sum(n for core==c) == len(cores[c])``.

    ``peer_snapshots`` (``run_multicore(..., peer_snapshots=True)``) has
    one ``(n_snaps_c, n_cores, N_IREGS) uint64`` matrix per core: for
    each of core c's snapshot positions, EVERY core's integer file as of
    the enclosing quantum's start.  Within a quantum only the running
    core mutates, so peer rows are exact at any position inside it; the
    own-core row is the stale quantum-start state — consumers must take
    core c's precise row from ``cores[c].snapshots``.
    """

    cores: List[Trace]
    schedule: List[Tuple[int, int]]
    peer_snapshots: Optional[List[np.ndarray]] = None

    @property
    def n_cores(self) -> int:
        return len(self.cores)

    def __len__(self) -> int:
        return sum(len(t) for t in self.cores)


def _concat_traces(cprog: CompiledProgram, chunks: List[Trace]) -> Trace:
    if not chunks:
        return Trace(program=cprog,
                     pc=np.zeros(0, np.int32), ea=np.zeros(0, np.uint64),
                     taken=np.zeros(0, np.int8),
                     snapshots=np.zeros((0, N_IREGS), np.uint64))
    if len(chunks) == 1:
        return chunks[0]
    return Trace(
        program=cprog,
        pc=np.concatenate([t.pc for t in chunks]),
        ea=np.concatenate([t.ea for t in chunks]),
        taken=np.concatenate([t.taken for t in chunks]),
        snapshots=np.concatenate([t.snapshots for t in chunks]))


def run_multicore(cprogs: Sequence[CompiledProgram],
                  max_instructions_per_core: int,
                  states: Sequence[CompiledState],
                  snapshot_every: Optional[int] = None,
                  quantum: int = DEFAULT_QUANTUM,
                  core_order: Optional[Sequence[int]] = None,
                  snapshot_at: Optional[Sequence[Sequence[int]]] = None,
                  peer_snapshots: bool = False
                  ) -> MulticoreTrace:
    """Round-robin interleaved execution of N cores over shared memory.

    Each scheduling round visits the cores in ``core_order`` (default
    0..N-1); a visit resumes the core at its saved pc and retires up to
    ``quantum`` instructions through ``funcsim.run_compiled``.  All cores
    start at pc 0 (one ``run_multicore`` call is one interval, matching
    the single-core engine's restart-at-0 checkpoint semantics; state
    carries across calls through ``states``).

    ``snapshot_every`` snapshots core c's integer file before its OWN
    trace positions 0, k, 2k, ... — the same per-trace-position contract
    as ``run_compiled``, computed against the core-local instruction
    count so the emitted rows line up with the per-core clip slicing.
    ``snapshot_at`` instead takes one sorted position list PER CORE (the
    training replay pass: snapshots exactly at the surviving clip
    starts); the two are mutually exclusive.  ``peer_snapshots``
    additionally captures the whole machine's integer files at each
    snapshotting quantum's start (see ``MulticoreTrace``).
    """
    n_cores = len(cprogs)
    assert len(states) == n_cores, (len(states), n_cores)
    order = list(core_order) if core_order is not None \
        else list(range(n_cores))
    assert sorted(order) == list(range(n_cores)), \
        f"core_order must permute 0..{n_cores - 1}, got {order}"
    assert quantum >= 1, quantum
    assert not (snapshot_every and snapshot_at is not None), \
        "snapshot_every and snapshot_at are mutually exclusive"
    at_lists: Optional[List[List[int]]] = None
    at_ptr = [0] * n_cores
    if snapshot_at is not None:
        assert len(snapshot_at) == n_cores, (len(snapshot_at), n_cores)
        at_lists = [sorted(int(k) for k in pos) for pos in snapshot_at]
    chunks: List[List[Trace]] = [[] for _ in range(n_cores)]
    schedule: List[Tuple[int, int]] = []
    peers: Optional[List[List[np.ndarray]]] = \
        [[] for _ in range(n_cores)] if peer_snapshots else None
    done = [0] * n_cores                   # instructions retired per core
    pc = [0] * n_cores                     # resume pc per core
    active = [True] * n_cores
    budget = max_instructions_per_core
    while True:
        progressed = False
        for c in order:
            if not active[c] or done[c] >= budget:
                continue
            q = min(quantum, budget - done[c])
            at = None
            if snapshot_every:
                at = [k for k in range(q)
                      if (done[c] + k) % snapshot_every == 0]
            elif at_lists is not None:
                lo, p = done[c], at_ptr[c]
                mine = at_lists[c]
                at = []
                while p < len(mine) and mine[p] < lo + q:
                    assert mine[p] >= lo, \
                        f"snapshot_at position {mine[p]} for core {c} " \
                        "already passed (positions must be >= 0, sorted)"
                    at.append(mine[p] - lo)
                    p += 1
                at_ptr[c] = p
            mat = None
            if peers is not None and at:
                # other cores cannot commit inside this quantum, so one
                # quantum-start capture is exact for every peer row of
                # every snapshot position the quantum serves
                mat = np.array([st.iregs for st in states], np.uint64)
            tr, _ = funcsim.run_compiled(
                cprogs[c], q, states[c],
                snapshot_at=at or None, start_pc=pc[c])
            if mat is not None:
                # one peer matrix per snapshot row actually emitted (a
                # mid-quantum exit can serve fewer positions than asked)
                peers[c].extend([mat] * tr.snapshots.shape[0])
            k = len(tr)
            if k:
                chunks[c].append(tr)
                schedule.append((c, k))
                done[c] += k
                pc[c] = int(states[c].iregs[NIA_SLOT])
                progressed = True
            if k < q:                      # program exited mid-quantum
                active[c] = False
        if not progressed:
            break
    cores = [_concat_traces(cprogs[c], chunks[c]) for c in range(n_cores)]
    peer_out = None
    if peers is not None:
        peer_out = [
            np.stack(peers[c]) if peers[c]
            else np.zeros((0, n_cores, N_IREGS), np.uint64)
            for c in range(n_cores)]
        for c in range(n_cores):
            assert peer_out[c].shape[0] == cores[c].snapshots.shape[0], \
                (c, peer_out[c].shape, cores[c].snapshots.shape)
    return MulticoreTrace(cores=cores, schedule=schedule,
                          peer_snapshots=peer_out)
