"""Atomic functional simulator (the paper's gem5 AtomicSimple stand-in).

Executes a program (list of Instruction) at register/memory semantics with no
timing: every instruction completes in one atomic step.  Produces the dynamic
instruction trace the slicer consumes, plus architectural register snapshots
at requested trace positions (context matrices for the predictor).
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Sequence, Tuple

from repro.isa.isa import CONTEXT_REGS, Instruction

MASK64 = (1 << 64) - 1


@dataclasses.dataclass
class MachineState:
    regs: Dict[str, int]
    fregs: Dict[str, float]
    mem: Dict[int, int]

    @classmethod
    def fresh(cls) -> "MachineState":
        regs = {f"R{i}": 0 for i in range(32)}
        regs.update({"CR": 0, "LR": 0, "CTR": 0, "XER": 0, "FPSCR": 0,
                     "VSCR": 0, "CIA": 0, "NIA": 0})
        fregs = {f"F{i}": 0.0 for i in range(32)}
        return cls(regs=regs, fregs=fregs, mem={})

    def snapshot_context(self) -> Dict[str, int]:
        return {r: self.regs[r] & MASK64 for r in CONTEXT_REGS}


@dataclasses.dataclass(frozen=True)
class TraceEntry:
    pc: int
    inst: Instruction
    ea: Optional[int]          # effective address for mem ops
    taken: Optional[bool]      # branch outcome


def _val(st: MachineState, name: str):
    if name.startswith("F"):
        return st.fregs[name]
    return st.regs[name]


def _setval(st: MachineState, name: str, v):
    if name.startswith("F"):
        st.fregs[name] = float(v)
    else:
        st.regs[name] = int(v) & MASK64


def _sext(v: int) -> int:
    v &= MASK64
    return v - (1 << 64) if v >> 63 else v


def step(st: MachineState, pc: int, inst: Instruction
         ) -> Tuple[int, Optional[int], Optional[bool]]:
    """Execute one instruction; returns (next_pc, effective_addr, taken)."""
    op = inst.op
    s = inst.srcs
    ea = None
    taken = None
    next_pc = pc + 1
    st.regs["CIA"] = pc

    if op == "addi":
        _setval(st, inst.dsts[0], _val(st, s[0]) + inst.imm if s
                else inst.imm)
    elif op == "add":
        _setval(st, inst.dsts[0], _val(st, s[0]) + _val(st, s[1]))
    elif op == "subf":
        _setval(st, inst.dsts[0], _val(st, s[1]) - _val(st, s[0]))
    elif op == "neg":
        _setval(st, inst.dsts[0], -_val(st, s[0]))
    elif op == "and":
        _setval(st, inst.dsts[0], _val(st, s[0]) & _val(st, s[1]))
    elif op == "or":
        _setval(st, inst.dsts[0], _val(st, s[0]) | _val(st, s[1]))
    elif op == "xor":
        _setval(st, inst.dsts[0], _val(st, s[0]) ^ _val(st, s[1]))
    elif op in ("rldicl", "sld"):
        sh = inst.imm if inst.imm is not None else (_val(st, s[1]) & 63)
        _setval(st, inst.dsts[0], (_val(st, s[0]) << sh) & MASK64)
    elif op == "srd":
        sh = inst.imm if inst.imm is not None else (_val(st, s[1]) & 63)
        _setval(st, inst.dsts[0], (_val(st, s[0]) & MASK64) >> sh)
    elif op == "extsw":
        v = _val(st, s[0]) & 0xFFFFFFFF
        _setval(st, inst.dsts[0], v - (1 << 32) if v >> 31 else v)
    elif op in ("mulld", "mulhd"):
        prod = _sext(_val(st, s[0])) * _sext(_val(st, s[1]))
        _setval(st, inst.dsts[0],
                prod if op == "mulld" else (prod >> 64))
    elif op in ("divd", "modsd"):
        a, b = _sext(_val(st, s[0])), _sext(_val(st, s[1]))
        b = b if b != 0 else 1
        q, r = abs(a) // abs(b), abs(a) % abs(b)
        if (a < 0) != (b < 0):
            q = -q
        _setval(st, inst.dsts[0], q if op == "divd" else r)
    elif op in ("cmpi", "cmpl", "cmpd"):
        a = _sext(_val(st, s[0]))
        b = inst.imm if op == "cmpi" else _sext(_val(st, s[1]))
        st.regs["CR"] = (4 if a < b else (2 if a > b else 1))
    elif op == "fcmpu":
        a, b = _val(st, s[0]), _val(st, s[1])
        st.regs["CR"] = (4 if a < b else (2 if a > b else 1))
    elif op in ("ld", "lwz", "lbz"):
        ea = (_val(st, inst.mem_base) + inst.mem_offset) & MASK64
        v = st.mem.get(ea >> 3, 0)
        if op == "lwz":
            v &= 0xFFFFFFFF
        elif op == "lbz":
            v &= 0xFF
        _setval(st, inst.dsts[0], v)
    elif op == "lfd":
        ea = (_val(st, inst.mem_base) + inst.mem_offset) & MASK64
        raw = st.mem.get(ea >> 3, 0)
        st.fregs[inst.dsts[0]] = float(_sext(raw)) * 2.0 ** -16
    elif op in ("std", "stw", "stb"):
        ea = (_val(st, inst.mem_base) + inst.mem_offset) & MASK64
        st.mem[ea >> 3] = _val(st, s[0]) & MASK64
    elif op == "stfd":
        ea = (_val(st, inst.mem_base) + inst.mem_offset) & MASK64
        st.mem[ea >> 3] = int(st.fregs[s[0]] * 2 ** 16) & MASK64
    elif op in ("fadd", "fsub", "fmul", "fmadd", "fdiv", "fsqrt", "fmr"):
        a = st.fregs[s[0]]
        if op == "fadd":
            r = a + st.fregs[s[1]]
        elif op == "fsub":
            r = a - st.fregs[s[1]]
        elif op == "fmul":
            r = a * st.fregs[s[1]]
        elif op == "fmadd":
            r = a * st.fregs[s[1]] + st.fregs[s[2]]
        elif op == "fdiv":
            d = st.fregs[s[1]]
            r = a / d if abs(d) > 1e-30 else 0.0
        elif op == "fsqrt":
            r = abs(a) ** 0.5
        else:
            r = a
        if abs(r) > 1e30:
            r = 0.0
        st.fregs[inst.dsts[0]] = r
    elif op == "b":
        next_pc = inst.target
        taken = True
    elif op == "bc":
        # branch if CR bit set per imm: 0 -> lt(4), 1 -> gt(2), 2 -> eq(1),
        # 3 -> not-eq
        cr = st.regs["CR"]
        cond = {0: cr & 4, 1: cr & 2, 2: cr & 1, 3: (cr & 1) == 0}[
            inst.imm or 0]
        taken = bool(cond)
        if taken:
            next_pc = inst.target
    elif op == "bl":
        st.regs["LR"] = pc + 1
        next_pc = inst.target
        taken = True
    elif op == "blr":
        next_pc = st.regs["LR"]
        taken = True
    elif op == "bdnz":
        st.regs["CTR"] = (st.regs["CTR"] - 1) & MASK64
        taken = st.regs["CTR"] != 0
        if taken:
            next_pc = inst.target
    elif op == "mtctr":
        st.regs["CTR"] = _val(st, s[0])
    elif op == "mtlr":
        st.regs["LR"] = _val(st, s[0])
    elif op == "mflr":
        _setval(st, inst.dsts[0], st.regs["LR"])
    elif op == "nop":
        pass
    else:
        raise ValueError(f"unimplemented opcode {op}")

    st.regs["NIA"] = next_pc
    return next_pc, ea, taken


def run(program: Sequence[Instruction], max_instructions: int,
        state: Optional[MachineState] = None,
        snapshot_every: Optional[int] = None,
        snapshot_at: Optional[Sequence[int]] = None
        ) -> Tuple[List[TraceEntry], List[Dict[str, int]], MachineState]:
    """Execute until program exit or ``max_instructions``.

    Returns (trace, snapshots, final_state).  With ``snapshot_every``,
    ``snapshots[i]`` is the architectural context BEFORE trace position
    i*snapshot_every; with ``snapshot_at`` (a sorted sequence of trace
    positions, e.g. clip starts from the slicer), one snapshot per
    requested position.
    """
    st = state or MachineState.fresh()
    trace: List[TraceEntry] = []
    snapshots: List[Dict[str, int]] = []
    at = list(snapshot_at) if snapshot_at is not None else None
    at_i = 0
    pc = 0
    n = 0
    while 0 <= pc < len(program) and n < max_instructions:
        if snapshot_every and n % snapshot_every == 0:
            snapshots.append(st.snapshot_context())
        if at is not None:
            while at_i < len(at) and at[at_i] == n:
                snapshots.append(st.snapshot_context())
                at_i += 1
        inst = program[pc]
        next_pc, ea, taken = step(st, pc, inst)
        trace.append(TraceEntry(pc=pc, inst=inst, ea=ea, taken=taken))
        pc = next_pc
        n += 1
    return trace, snapshots, st
