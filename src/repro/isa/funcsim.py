"""Atomic functional simulator (the paper's gem5 AtomicSimple stand-in).

Executes a program at register/memory semantics with no timing: every
instruction completes in one atomic step.  Produces the dynamic
instruction trace the slicer consumes, plus architectural register
snapshots at requested trace positions (context matrices for the
predictor).

Two interpreters share the same semantics:

``run_compiled``
    the production path: a table-dispatched interpreter over a
    ``CompiledProgram`` (one precompiled closure per static instruction,
    register files as flat lists in ``CONTEXT_REGS`` slot order) emitting
    a columnar ``Trace`` — no per-step dataclass allocation, no dict
    lookups, snapshots as uint64 matrix rows.

``run_reference``
    the original object interpreter (``step`` over ``Instruction``,
    ``List[TraceEntry]`` out).  Kept verbatim as the differential-testing
    golden model and the pre-IR performance baseline.

``run`` keeps the historical object API but executes on the columnar
interpreter, converting at the boundary (and falling back to the
reference path for programs the SoA encoding cannot represent).
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.isa.compiled import (CIA_SLOT, CR_SLOT, CTR_SLOT, LR_SLOT,
                                N_IREGS, NIA_SLOT, CompiledProgram,
                                CompileError, Trace, compile_program)
from repro.isa.isa import CONTEXT_REGS, Instruction

MASK64 = (1 << 64) - 1


@dataclasses.dataclass
class MachineState:
    regs: Dict[str, int]
    fregs: Dict[str, float]
    mem: Dict[int, int]

    @classmethod
    def fresh(cls) -> "MachineState":
        regs = {f"R{i}": 0 for i in range(32)}
        regs.update({"CR": 0, "LR": 0, "CTR": 0, "XER": 0, "FPSCR": 0,
                     "VSCR": 0, "CIA": 0, "NIA": 0})
        fregs = {f"F{i}": 0.0 for i in range(32)}
        return cls(regs=regs, fregs=fregs, mem={})

    def snapshot_context(self) -> Dict[str, int]:
        return {r: self.regs[r] & MASK64 for r in CONTEXT_REGS}


@dataclasses.dataclass(frozen=True)
class TraceEntry:
    pc: int
    inst: Instruction
    ea: Optional[int]          # effective address for mem ops
    taken: Optional[bool]      # branch outcome


@dataclasses.dataclass
class CompiledState:
    """Columnar architectural state: flat register files in slot order
    (``iregs[i]`` is ``CONTEXT_REGS[i]``), shared memory dict."""

    iregs: List[int]                           # len N_IREGS
    fregs: List[float]                         # len 32
    mem: Dict[int, int]

    @classmethod
    def fresh(cls) -> "CompiledState":
        return cls(iregs=[0] * N_IREGS, fregs=[0.0] * 32, mem={})

    @classmethod
    def from_machine(cls, st: MachineState) -> "CompiledState":
        """Adopts ``st.mem`` by reference (mutations stay shared)."""
        return cls(iregs=[st.regs[r] for r in CONTEXT_REGS],
                   fregs=[st.fregs[f"F{i}"] for i in range(32)],
                   mem=st.mem)

    def to_machine(self) -> MachineState:
        st = MachineState.fresh()
        st.mem = self.mem
        self.write_back(st)
        return st

    def write_back(self, st: MachineState) -> None:
        for i, r in enumerate(CONTEXT_REGS):
            st.regs[r] = self.iregs[i]
        for i in range(32):
            st.fregs[f"F{i}"] = self.fregs[i]

    def clone(self) -> "CompiledState":
        """Replay anchor: independent copy (mem is a flat int dict)."""
        return CompiledState(iregs=list(self.iregs), fregs=list(self.fregs),
                             mem=dict(self.mem))

    def snapshot_context(self) -> Dict[str, int]:
        return {r: self.iregs[i] & MASK64
                for i, r in enumerate(CONTEXT_REGS)}


def _val(st: MachineState, name: str):
    if name.startswith("F"):
        return st.fregs[name]
    return st.regs[name]


def _setval(st: MachineState, name: str, v):
    if name.startswith("F"):
        st.fregs[name] = float(v)
    else:
        st.regs[name] = int(v) & MASK64


def _sext(v: int) -> int:
    v &= MASK64
    return v - (1 << 64) if v >> 63 else v


def step(st: MachineState, pc: int, inst: Instruction
         ) -> Tuple[int, Optional[int], Optional[bool]]:
    """Execute one instruction; returns (next_pc, effective_addr, taken)."""
    op = inst.op
    s = inst.srcs
    ea = None
    taken = None
    next_pc = pc + 1
    st.regs["CIA"] = pc

    if op == "addi":
        _setval(st, inst.dsts[0], _val(st, s[0]) + inst.imm if s
                else inst.imm)
    elif op == "add":
        _setval(st, inst.dsts[0], _val(st, s[0]) + _val(st, s[1]))
    elif op == "subf":
        _setval(st, inst.dsts[0], _val(st, s[1]) - _val(st, s[0]))
    elif op == "neg":
        _setval(st, inst.dsts[0], -_val(st, s[0]))
    elif op == "and":
        _setval(st, inst.dsts[0], _val(st, s[0]) & _val(st, s[1]))
    elif op == "or":
        _setval(st, inst.dsts[0], _val(st, s[0]) | _val(st, s[1]))
    elif op == "xor":
        _setval(st, inst.dsts[0], _val(st, s[0]) ^ _val(st, s[1]))
    elif op in ("rldicl", "sld"):
        sh = inst.imm if inst.imm is not None else (_val(st, s[1]) & 63)
        _setval(st, inst.dsts[0], (_val(st, s[0]) << sh) & MASK64)
    elif op == "srd":
        sh = inst.imm if inst.imm is not None else (_val(st, s[1]) & 63)
        _setval(st, inst.dsts[0], (_val(st, s[0]) & MASK64) >> sh)
    elif op == "extsw":
        v = _val(st, s[0]) & 0xFFFFFFFF
        _setval(st, inst.dsts[0], v - (1 << 32) if v >> 31 else v)
    elif op in ("mulld", "mulhd"):
        prod = _sext(_val(st, s[0])) * _sext(_val(st, s[1]))
        _setval(st, inst.dsts[0],
                prod if op == "mulld" else (prod >> 64))
    elif op in ("divd", "modsd"):
        a, b = _sext(_val(st, s[0])), _sext(_val(st, s[1]))
        b = b if b != 0 else 1
        q, r = abs(a) // abs(b), abs(a) % abs(b)
        if (a < 0) != (b < 0):
            q = -q
        _setval(st, inst.dsts[0], q if op == "divd" else r)
    elif op in ("cmpi", "cmpl", "cmpd"):
        a = _sext(_val(st, s[0]))
        b = inst.imm if op == "cmpi" else _sext(_val(st, s[1]))
        st.regs["CR"] = (4 if a < b else (2 if a > b else 1))
    elif op == "fcmpu":
        a, b = _val(st, s[0]), _val(st, s[1])
        st.regs["CR"] = (4 if a < b else (2 if a > b else 1))
    elif op in ("ld", "lwz", "lbz"):
        ea = (_val(st, inst.mem_base) + inst.mem_offset) & MASK64
        v = st.mem.get(ea >> 3, 0)
        if op == "lwz":
            v &= 0xFFFFFFFF
        elif op == "lbz":
            v &= 0xFF
        _setval(st, inst.dsts[0], v)
    elif op == "lfd":
        ea = (_val(st, inst.mem_base) + inst.mem_offset) & MASK64
        raw = st.mem.get(ea >> 3, 0)
        st.fregs[inst.dsts[0]] = float(_sext(raw)) * 2.0 ** -16
    elif op in ("std", "stw", "stb"):
        ea = (_val(st, inst.mem_base) + inst.mem_offset) & MASK64
        st.mem[ea >> 3] = _val(st, s[0]) & MASK64
    elif op == "stfd":
        ea = (_val(st, inst.mem_base) + inst.mem_offset) & MASK64
        st.mem[ea >> 3] = int(st.fregs[s[0]] * 2 ** 16) & MASK64
    elif op in ("fadd", "fsub", "fmul", "fmadd", "fdiv", "fsqrt", "fmr"):
        a = st.fregs[s[0]]
        if op == "fadd":
            r = a + st.fregs[s[1]]
        elif op == "fsub":
            r = a - st.fregs[s[1]]
        elif op == "fmul":
            r = a * st.fregs[s[1]]
        elif op == "fmadd":
            r = a * st.fregs[s[1]] + st.fregs[s[2]]
        elif op == "fdiv":
            d = st.fregs[s[1]]
            r = a / d if abs(d) > 1e-30 else 0.0
        elif op == "fsqrt":
            r = abs(a) ** 0.5
        else:
            r = a
        if abs(r) > 1e30:
            r = 0.0
        st.fregs[inst.dsts[0]] = r
    elif op == "b":
        next_pc = inst.target
        taken = True
    elif op == "bc":
        # branch if CR bit set per imm: 0 -> lt(4), 1 -> gt(2), 2 -> eq(1),
        # 3 -> not-eq
        cr = st.regs["CR"]
        cond = {0: cr & 4, 1: cr & 2, 2: cr & 1, 3: (cr & 1) == 0}[
            inst.imm or 0]
        taken = bool(cond)
        if taken:
            next_pc = inst.target
    elif op == "bl":
        st.regs["LR"] = pc + 1
        next_pc = inst.target
        taken = True
    elif op == "blr":
        next_pc = st.regs["LR"]
        taken = True
    elif op == "bdnz":
        st.regs["CTR"] = (st.regs["CTR"] - 1) & MASK64
        taken = st.regs["CTR"] != 0
        if taken:
            next_pc = inst.target
    elif op == "mtctr":
        st.regs["CTR"] = _val(st, s[0])
    elif op == "mtlr":
        st.regs["LR"] = _val(st, s[0])
    elif op == "mflr":
        _setval(st, inst.dsts[0], st.regs["LR"])
    elif op == "nop":
        pass
    else:
        raise ValueError(f"unimplemented opcode {op}")

    st.regs["NIA"] = next_pc
    return next_pc, ea, taken


# --------------------------------------------------------------------------- #
# Table-dispatched columnar interpreter
# --------------------------------------------------------------------------- #
#
# One closure per *static* instruction: operand slots, immediates, and
# targets are baked in at compile time, so the per-step work is a single
# ``handlers[pc](...)`` call doing flat list indexing.  Every handler
# returns ``(next_pc, ea, taken)`` with ``ea=0`` for non-memory ops and
# ``taken=-1`` for non-branches — the columnar encoding of the object
# interpreter's ``(next_pc, None, None)``.

def _ir_slot(slot: int, what: str) -> int:
    if not 0 <= slot < N_IREGS:
        raise CompileError(f"{what} must be an integer register")
    return slot


def _fr_slot(slot: int, what: str) -> int:
    if slot < N_IREGS:
        raise CompileError(f"{what} must be a float register")
    return slot - N_IREGS


def _make_handler(op: str, d, s, imm, mb, mo, tgt):
    """Build the closure for one static instruction.

    ``d`` is the first destination slot (-1 if none), ``s`` the tuple of
    source slots, ``imm`` the immediate or None, ``mb``/``mo`` the memory
    base slot (-1 if none) and offset, ``tgt`` the branch target or None.
    """
    if op == "addi":
        di = _ir_slot(d, "addi dst")
        if s:
            s0 = _ir_slot(s[0], "addi src")
            def h(ir, fr, mem, pc, di=di, s0=s0, imm=imm):
                ir[di] = (ir[s0] + imm) & MASK64
                return pc + 1, 0, -1
        else:
            val = int(imm) & MASK64
            def h(ir, fr, mem, pc, di=di, val=val):
                ir[di] = val
                return pc + 1, 0, -1
        return h
    if op in ("add", "and", "or", "xor", "subf"):
        di = _ir_slot(d, f"{op} dst")
        s0, s1 = (_ir_slot(x, f"{op} src") for x in s[:2])
        ops = {"add": lambda a, b: a + b, "and": lambda a, b: a & b,
               "or": lambda a, b: a | b, "xor": lambda a, b: a ^ b,
               "subf": lambda a, b: b - a}
        fn = ops[op]
        def h(ir, fr, mem, pc, di=di, s0=s0, s1=s1, fn=fn):
            ir[di] = fn(ir[s0], ir[s1]) & MASK64
            return pc + 1, 0, -1
        return h
    if op == "neg":
        di = _ir_slot(d, "neg dst")
        s0 = _ir_slot(s[0], "neg src")
        def h(ir, fr, mem, pc, di=di, s0=s0):
            ir[di] = (-ir[s0]) & MASK64
            return pc + 1, 0, -1
        return h
    if op in ("rldicl", "sld", "srd"):
        di = _ir_slot(d, f"{op} dst")
        s0 = _ir_slot(s[0], f"{op} src")
        left = op != "srd"
        if imm is not None:
            sh = int(imm)
            if left:
                def h(ir, fr, mem, pc, di=di, s0=s0, sh=sh):
                    ir[di] = (ir[s0] << sh) & MASK64
                    return pc + 1, 0, -1
            else:
                def h(ir, fr, mem, pc, di=di, s0=s0, sh=sh):
                    ir[di] = ir[s0] >> sh
                    return pc + 1, 0, -1
        else:
            s1 = _ir_slot(s[1], f"{op} shift src")
            if left:
                def h(ir, fr, mem, pc, di=di, s0=s0, s1=s1):
                    ir[di] = (ir[s0] << (ir[s1] & 63)) & MASK64
                    return pc + 1, 0, -1
            else:
                def h(ir, fr, mem, pc, di=di, s0=s0, s1=s1):
                    ir[di] = ir[s0] >> (ir[s1] & 63)
                    return pc + 1, 0, -1
        return h
    if op == "extsw":
        di = _ir_slot(d, "extsw dst")
        s0 = _ir_slot(s[0], "extsw src")
        def h(ir, fr, mem, pc, di=di, s0=s0):
            v = ir[s0] & 0xFFFFFFFF
            ir[di] = ((v - (1 << 32)) if v >> 31 else v) & MASK64
            return pc + 1, 0, -1
        return h
    if op in ("mulld", "mulhd"):
        di = _ir_slot(d, f"{op} dst")
        s0, s1 = (_ir_slot(x, f"{op} src") for x in s[:2])
        high = op == "mulhd"
        def h(ir, fr, mem, pc, di=di, s0=s0, s1=s1, high=high):
            prod = _sext(ir[s0]) * _sext(ir[s1])
            ir[di] = ((prod >> 64) if high else prod) & MASK64
            return pc + 1, 0, -1
        return h
    if op in ("divd", "modsd"):
        di = _ir_slot(d, f"{op} dst")
        s0, s1 = (_ir_slot(x, f"{op} src") for x in s[:2])
        want_mod = op == "modsd"
        def h(ir, fr, mem, pc, di=di, s0=s0, s1=s1, want_mod=want_mod):
            a, b = _sext(ir[s0]), _sext(ir[s1])
            b = b if b != 0 else 1
            q, r = abs(a) // abs(b), abs(a) % abs(b)
            if (a < 0) != (b < 0):
                q = -q
            ir[di] = (r if want_mod else q) & MASK64
            return pc + 1, 0, -1
        return h
    if op in ("cmpi", "cmpl", "cmpd"):
        s0 = _ir_slot(s[0], f"{op} src")
        if op == "cmpi":
            b_imm = int(imm) if imm is not None else None
            if b_imm is None:
                raise CompileError("cmpi without immediate")
            def h(ir, fr, mem, pc, s0=s0, b=b_imm):
                a = _sext(ir[s0])
                ir[CR_SLOT] = 4 if a < b else (2 if a > b else 1)
                return pc + 1, 0, -1
        else:
            s1 = _ir_slot(s[1], f"{op} src")
            def h(ir, fr, mem, pc, s0=s0, s1=s1):
                a, b = _sext(ir[s0]), _sext(ir[s1])
                ir[CR_SLOT] = 4 if a < b else (2 if a > b else 1)
                return pc + 1, 0, -1
        return h
    if op == "fcmpu":
        f0, f1 = (_fr_slot(x, "fcmpu src") for x in s[:2])
        def h(ir, fr, mem, pc, f0=f0, f1=f1):
            a, b = fr[f0], fr[f1]
            ir[CR_SLOT] = 4 if a < b else (2 if a > b else 1)
            return pc + 1, 0, -1
        return h
    if op in ("ld", "lwz", "lbz"):
        di = _ir_slot(d, f"{op} dst")
        base = _ir_slot(mb, f"{op} base")
        mask = {"ld": MASK64, "lwz": 0xFFFFFFFF, "lbz": 0xFF}[op]
        def h(ir, fr, mem, pc, di=di, base=base, off=mo, mask=mask):
            ea = (ir[base] + off) & MASK64
            ir[di] = mem.get(ea >> 3, 0) & mask
            return pc + 1, ea, -1
        return h
    if op == "lfd":
        fd = _fr_slot(d, "lfd dst")
        base = _ir_slot(mb, "lfd base")
        def h(ir, fr, mem, pc, fd=fd, base=base, off=mo):
            ea = (ir[base] + off) & MASK64
            fr[fd] = float(_sext(mem.get(ea >> 3, 0))) * 2.0 ** -16
            return pc + 1, ea, -1
        return h
    if op in ("std", "stw", "stb"):
        s0 = _ir_slot(s[0], f"{op} src")
        base = _ir_slot(mb, f"{op} base")
        def h(ir, fr, mem, pc, s0=s0, base=base, off=mo):
            ea = (ir[base] + off) & MASK64
            mem[ea >> 3] = ir[s0] & MASK64
            return pc + 1, ea, -1
        return h
    if op == "stfd":
        f0 = _fr_slot(s[0], "stfd src")
        base = _ir_slot(mb, "stfd base")
        def h(ir, fr, mem, pc, f0=f0, base=base, off=mo):
            ea = (ir[base] + off) & MASK64
            mem[ea >> 3] = int(fr[f0] * 2 ** 16) & MASK64
            return pc + 1, ea, -1
        return h
    if op in ("fadd", "fsub", "fmul", "fdiv"):
        fd = _fr_slot(d, f"{op} dst")
        f0, f1 = (_fr_slot(x, f"{op} src") for x in s[:2])
        ops = {"fadd": lambda a, b: a + b, "fsub": lambda a, b: a - b,
               "fmul": lambda a, b: a * b,
               "fdiv": lambda a, b: a / b if abs(b) > 1e-30 else 0.0}
        fn = ops[op]
        def h(ir, fr, mem, pc, fd=fd, f0=f0, f1=f1, fn=fn):
            r = fn(fr[f0], fr[f1])
            if abs(r) > 1e30:
                r = 0.0
            fr[fd] = r
            return pc + 1, 0, -1
        return h
    if op == "fmadd":
        fd = _fr_slot(d, "fmadd dst")
        f0, f1, f2 = (_fr_slot(x, "fmadd src") for x in s[:3])
        def h(ir, fr, mem, pc, fd=fd, f0=f0, f1=f1, f2=f2):
            r = fr[f0] * fr[f1] + fr[f2]
            if abs(r) > 1e30:
                r = 0.0
            fr[fd] = r
            return pc + 1, 0, -1
        return h
    if op in ("fsqrt", "fmr"):
        fd = _fr_slot(d, f"{op} dst")
        f0 = _fr_slot(s[0], f"{op} src")
        root = op == "fsqrt"
        def h(ir, fr, mem, pc, fd=fd, f0=f0, root=root):
            r = abs(fr[f0]) ** 0.5 if root else fr[f0]
            if abs(r) > 1e30:
                r = 0.0
            fr[fd] = r
            return pc + 1, 0, -1
        return h
    if op == "b":
        if tgt is None:
            raise CompileError("b without target")
        def h(ir, fr, mem, pc, tgt=tgt):
            return tgt, 0, 1
        return h
    if op == "bc":
        if tgt is None:
            raise CompileError("bc without target")
        cond = int(imm or 0)
        if cond not in (0, 1, 2, 3):
            raise CompileError(f"bc condition {cond} out of range")
        bit = {0: 4, 1: 2, 2: 1}.get(cond)
        if bit is not None:
            def h(ir, fr, mem, pc, tgt=tgt, bit=bit):
                if ir[CR_SLOT] & bit:
                    return tgt, 0, 1
                return pc + 1, 0, 0
        else:                                  # cond 3: not-eq
            def h(ir, fr, mem, pc, tgt=tgt):
                if ir[CR_SLOT] & 1:
                    return pc + 1, 0, 0
                return tgt, 0, 1
        return h
    if op == "bl":
        if tgt is None:
            raise CompileError("bl without target")
        def h(ir, fr, mem, pc, tgt=tgt):
            ir[LR_SLOT] = pc + 1
            return tgt, 0, 1
        return h
    if op == "blr":
        def h(ir, fr, mem, pc):
            return ir[LR_SLOT], 0, 1
        return h
    if op == "bdnz":
        if tgt is None:
            raise CompileError("bdnz without target")
        def h(ir, fr, mem, pc, tgt=tgt):
            ctr = (ir[CTR_SLOT] - 1) & MASK64
            ir[CTR_SLOT] = ctr
            if ctr:
                return tgt, 0, 1
            return pc + 1, 0, 0
        return h
    if op in ("mtctr", "mtlr"):
        s0 = _ir_slot(s[0], f"{op} src")
        dst_slot = CTR_SLOT if op == "mtctr" else LR_SLOT
        def h(ir, fr, mem, pc, s0=s0, dst_slot=dst_slot):
            ir[dst_slot] = ir[s0]
            return pc + 1, 0, -1
        return h
    if op == "mflr":
        di = _ir_slot(d, "mflr dst")
        def h(ir, fr, mem, pc, di=di):
            ir[di] = ir[LR_SLOT] & MASK64
            return pc + 1, 0, -1
        return h
    if op == "nop":
        def h(ir, fr, mem, pc):
            return pc + 1, 0, -1
        return h
    raise CompileError(f"no columnar handler for opcode {op!r}")


def build_handlers(cprog: CompiledProgram) -> list:
    """One closure per static instruction, cached on the program."""
    if cprog._handlers is None:
        handlers = []
        for i, inst in enumerate(cprog.insts):
            d = int(cprog.dsts[i, 0])
            s = tuple(int(x) for x in cprog.srcs[i] if x >= 0)
            imm = int(cprog.imm[i]) if cprog.has_imm[i] else None
            mb = int(cprog.mem_base[i])
            mo = int(cprog.mem_offset[i])
            tgt = int(cprog.target[i]) if cprog.has_target[i] else None
            handlers.append(_make_handler(inst.op, d, s, imm, mb, mo, tgt))
        cprog._handlers = handlers
    return cprog._handlers


def run_compiled(cprog: CompiledProgram, max_instructions: int,
                 state: Optional[CompiledState] = None,
                 snapshot_every: Optional[int] = None,
                 snapshot_at: Optional[Sequence[int]] = None,
                 start_pc: int = 0) -> Tuple[Trace, CompiledState]:
    """Columnar ``run``: execute until program exit or
    ``max_instructions``, returning ``(Trace, state)``.

    Snapshot semantics match ``run_reference``: with ``snapshot_every``,
    row i of ``trace.snapshots`` is the architectural context BEFORE
    trace position ``i*snapshot_every``; with ``snapshot_at`` (sorted
    trace positions), one row per requested position.

    ``start_pc`` resumes execution mid-program (the multicore quantum
    scheduler's hook): after any call that retired >= 1 instruction the
    next pc is ``state.iregs[NIA_SLOT]``, so
    ``run_compiled(cprog, q, st, start_pc=st.iregs[NIA_SLOT])`` continues
    exactly where the previous quantum stopped.
    """
    st = state or CompiledState.fresh()
    handlers = build_handlers(cprog)
    ir, fr, mem = st.iregs, st.fregs, st.mem
    n_static = cprog.n_static
    pcs: List[int] = []
    eas: List[int] = []
    takens: List[int] = []
    snaps: List[List[int]] = []
    at = list(snapshot_at) if snapshot_at is not None else None
    at_i = 0
    at_n = len(at) if at is not None else 0
    every = snapshot_every or 0
    next_every = 0 if every else -1
    pc = start_pc
    n = 0
    pcs_append, eas_append = pcs.append, eas.append
    takens_append = takens.append
    while 0 <= pc < n_static and n < max_instructions:
        if n == next_every:
            snaps.append(ir.copy())
            next_every += every
        if at_i < at_n:
            while at_i < at_n and at[at_i] == n:
                snaps.append(ir.copy())
                at_i += 1
        ir[CIA_SLOT] = pc
        next_pc, ea, taken = handlers[pc](ir, fr, mem, pc)
        ir[NIA_SLOT] = next_pc
        pcs_append(pc)
        eas_append(ea)
        takens_append(taken)
        pc = next_pc
        n += 1
    trace = Trace(
        program=cprog,
        pc=np.array(pcs, np.int32),
        ea=np.array(eas, np.uint64),
        taken=np.array(takens, np.int8),
        snapshots=np.array(snaps, np.uint64).reshape(len(snaps), N_IREGS))
    return trace, st


def run_reference(program: Sequence[Instruction], max_instructions: int,
                  state: Optional[MachineState] = None,
                  snapshot_every: Optional[int] = None,
                  snapshot_at: Optional[Sequence[int]] = None
                  ) -> Tuple[List[TraceEntry], List[Dict[str, int]],
                             MachineState]:
    """The original object interpreter (golden model / perf baseline)."""
    st = state or MachineState.fresh()
    trace: List[TraceEntry] = []
    snapshots: List[Dict[str, int]] = []
    at = list(snapshot_at) if snapshot_at is not None else None
    at_i = 0
    pc = 0
    n = 0
    while 0 <= pc < len(program) and n < max_instructions:
        if snapshot_every and n % snapshot_every == 0:
            snapshots.append(st.snapshot_context())
        if at is not None:
            while at_i < len(at) and at[at_i] == n:
                snapshots.append(st.snapshot_context())
                at_i += 1
        inst = program[pc]
        next_pc, ea, taken = step(st, pc, inst)
        trace.append(TraceEntry(pc=pc, inst=inst, ea=ea, taken=taken))
        pc = next_pc
        n += 1
    return trace, snapshots, st


def run(program: Sequence[Instruction], max_instructions: int,
        state: Optional[MachineState] = None,
        snapshot_every: Optional[int] = None,
        snapshot_at: Optional[Sequence[int]] = None
        ) -> Tuple[List[TraceEntry], List[Dict[str, int]], MachineState]:
    """Object-API adapter over the columnar interpreter.

    Same signature and results as ``run_reference`` (the passed
    ``MachineState`` is mutated in place and returned); programs the SoA
    encoding cannot represent fall back to the object path.
    """
    st = state or MachineState.fresh()
    try:
        cprog = compile_program(program)
        cst = CompiledState.from_machine(st)
        trace, cst = run_compiled(cprog, max_instructions, cst,
                                  snapshot_every=snapshot_every,
                                  snapshot_at=snapshot_at)
    except CompileError:
        return run_reference(program, max_instructions, state=st,
                             snapshot_every=snapshot_every,
                             snapshot_at=snapshot_at)
    cst.write_back(st)
    return trace.entries(), trace.snapshot_dicts(), st
