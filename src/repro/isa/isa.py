"""Mini Power-ISA subset (the paper's gem5 model targets Power ISA).

~40 opcodes across integer, floating-point (mapped onto VSR per the paper's
Table I note), load/store, compare and branch classes.  Each opcode carries
its functional-unit class and latency for the O3 timing oracle.

Registers modeled (Table I): R0-R31 (GPR), F0-F31 (VSR/FPR), CR, LR, CTR,
XER, FPSCR, VSCR, CIA, NIA.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

# functional-unit classes
INT, MUL, DIV, FP, FDIV, LSU, BR = "int", "mul", "div", "fp", "fdiv", "lsu", "br"


@dataclasses.dataclass(frozen=True)
class OpInfo:
    fu: str
    latency: int
    is_load: bool = False
    is_store: bool = False
    is_branch: bool = False
    writes_cr: bool = False
    writes_lr: bool = False
    uses_ctr: bool = False


OPCODES = {
    # integer ALU
    "addi":   OpInfo(INT, 1),
    "add":    OpInfo(INT, 1),
    "subf":   OpInfo(INT, 1),
    "neg":    OpInfo(INT, 1),
    "and":    OpInfo(INT, 1),
    "or":     OpInfo(INT, 1),
    "xor":    OpInfo(INT, 1),
    "rldicl": OpInfo(INT, 1),   # rotate-left + clear (shift family)
    "sld":    OpInfo(INT, 1),
    "srd":    OpInfo(INT, 1),
    "extsw":  OpInfo(INT, 1),
    # integer mul/div
    "mulld":  OpInfo(MUL, 5),
    "mulhd":  OpInfo(MUL, 5),
    "divd":   OpInfo(DIV, 20),
    "modsd":  OpInfo(DIV, 22),
    # compares (write CR)
    "cmpi":   OpInfo(INT, 1, writes_cr=True),
    "cmpl":   OpInfo(INT, 1, writes_cr=True),
    "cmpd":   OpInfo(INT, 1, writes_cr=True),
    # loads
    "ld":     OpInfo(LSU, 2, is_load=True),
    "lwz":    OpInfo(LSU, 2, is_load=True),
    "lbz":    OpInfo(LSU, 2, is_load=True),
    "lfd":    OpInfo(LSU, 3, is_load=True),
    # stores
    "std":    OpInfo(LSU, 1, is_store=True),
    "stw":    OpInfo(LSU, 1, is_store=True),
    "stb":    OpInfo(LSU, 1, is_store=True),
    "stfd":   OpInfo(LSU, 1, is_store=True),
    # floating point (VSR)
    "fadd":   OpInfo(FP, 4),
    "fsub":   OpInfo(FP, 4),
    "fmul":   OpInfo(FP, 4),
    "fmadd":  OpInfo(FP, 5),
    "fdiv":   OpInfo(FDIV, 25),
    "fsqrt":  OpInfo(FDIV, 30),
    "fcmpu":  OpInfo(FP, 2, writes_cr=True),
    "fmr":    OpInfo(FP, 1),
    # branches
    "b":      OpInfo(BR, 1, is_branch=True),
    "bc":     OpInfo(BR, 1, is_branch=True),           # conditional on CR
    "bl":     OpInfo(BR, 1, is_branch=True, writes_lr=True),
    "blr":    OpInfo(BR, 1, is_branch=True),
    "bdnz":   OpInfo(BR, 1, is_branch=True, uses_ctr=True),
    # move to/from special regs
    "mtctr":  OpInfo(INT, 1),
    "mtlr":   OpInfo(INT, 1),
    "mflr":   OpInfo(INT, 1),
    "nop":    OpInfo(INT, 1),
}

GPRS = tuple(f"R{i}" for i in range(32))
FPRS = tuple(f"F{i}" for i in range(32))
SPECIALS = ("CR", "LR", "CTR", "XER", "FPSCR", "VSCR", "CIA", "NIA")
REGS = GPRS + FPRS + SPECIALS

# context-matrix registers (Table I; paper uses the architectural state
# before the clip).  40 registers x (1 name + 8 value-byte tokens) = 360.
CONTEXT_REGS = GPRS + SPECIALS
assert len(CONTEXT_REGS) == 40


@dataclasses.dataclass(frozen=True)
class Instruction:
    op: str
    dsts: Tuple[str, ...] = ()
    srcs: Tuple[str, ...] = ()
    imm: Optional[int] = None
    # memory operand: addr = [mem_base] + mem_offset
    mem_base: Optional[str] = None
    mem_offset: int = 0
    # branch target: label index in the program (resolved), None for blr
    target: Optional[int] = None

    @property
    def info(self) -> OpInfo:
        return OPCODES[self.op]

    def text(self) -> str:
        parts = [self.op]
        if self.dsts:
            parts.append(",".join(self.dsts))
        if self.srcs:
            parts.append(",".join(self.srcs))
        if self.imm is not None:
            parts.append(str(self.imm))
        if self.mem_base is not None:
            parts.append(f"{self.mem_offset}({self.mem_base})")
        if self.target is not None:
            parts.append(f"@{self.target}")
        return " ".join(parts)
