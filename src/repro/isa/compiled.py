"""Columnar trace IR: structure-of-arrays program + trace encodings.

The object model (``List[Instruction]`` programs, ``List[TraceEntry]``
traces) is convenient but every downstream layer — slicer, tokenizer,
context builder, timing oracle — pays per-instruction Python attribute
walks and dataclass allocation for it.  This module is the columnar
alternative:

``CompiledProgram``
    a *static* structure-of-arrays encoding of a program, built once per
    benchmark: int32 opcode codes, unified register-slot indices for
    destinations/sources, immediates + presence flags, branch targets,
    and memory base/offset columns.  It also carries a precomputed
    per-static-instruction standardized-token table
    (``(n_static, l_token) int32``): the Fig-5 standardization depends
    only on the static instruction, so per-clip tokenization collapses to
    one ``token_table[trace.pc[a:b]]`` gather.

``Trace``
    a *dynamic* columnar trace: ``pc`` (int32 static index), ``ea``
    (uint64 effective address, 0 for non-memory ops), ``taken`` (int8,
    -1 for non-branches) plus a ``(n_snaps, 40) uint64`` architectural
    snapshot matrix in ``CONTEXT_REGS`` order.

Register slots are unified across both files: integer registers (the 40
``CONTEXT_REGS``: R0-R31 then CR, LR, CTR, XER, FPSCR, VSCR, CIA, NIA)
occupy slots 0..39 — so a snapshot is literally a copy of the integer
file — and F0-F31 occupy slots 40..71.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.isa.isa import CONTEXT_REGS, OPCODES, Instruction

# --------------------------------------------------------------------------- #
# Opcode + register-slot numbering
# --------------------------------------------------------------------------- #

OPCODE_LIST: Tuple[str, ...] = tuple(sorted(OPCODES))
OPCODE_CODE: Dict[str, int] = {op: i for i, op in enumerate(OPCODE_LIST)}

N_IREGS = len(CONTEXT_REGS)                    # 40: slots 0..39
N_FREGS = 32                                   # slots 40..71
N_SLOTS = N_IREGS + N_FREGS

IREG_SLOT: Dict[str, int] = {r: i for i, r in enumerate(CONTEXT_REGS)}
FREG_SLOT: Dict[str, int] = {f"F{i}": N_IREGS + i for i in range(32)}
REG_SLOT: Dict[str, int] = {**IREG_SLOT, **FREG_SLOT}
SLOT_NAME: Tuple[str, ...] = tuple(CONTEXT_REGS) + tuple(
    f"F{i}" for i in range(32))

CR_SLOT = IREG_SLOT["CR"]
LR_SLOT = IREG_SLOT["LR"]
CTR_SLOT = IREG_SLOT["CTR"]
CIA_SLOT = IREG_SLOT["CIA"]
NIA_SLOT = IREG_SLOT["NIA"]

MAX_DSTS = 2
MAX_SRCS = 3

# per-opcode-code property tables (index with CompiledProgram.opcode)
OP_IS_LOAD = np.array([OPCODES[o].is_load for o in OPCODE_LIST], bool)
OP_IS_STORE = np.array([OPCODES[o].is_store for o in OPCODE_LIST], bool)
OP_IS_MEM = OP_IS_LOAD | OP_IS_STORE


class CompileError(ValueError):
    """Program shape the SoA encoding cannot represent (e.g. more than
    ``MAX_DSTS`` destinations); callers fall back to the object path."""


@dataclasses.dataclass(eq=False)                # ndarray fields: no __eq__
class CompiledProgram:
    """Structure-of-arrays encoding of a static program.

    All register columns hold unified slots (see module docstring) with
    -1 for "absent"; ``has_imm``/``has_target`` disambiguate legitimate
    zero immediates and branch targets from absent ones.
    """

    insts: Tuple[Instruction, ...]             # originals (adapters/tests)
    opcode: np.ndarray                         # (n,) int32 OPCODE_LIST code
    dsts: np.ndarray                           # (n, MAX_DSTS) int32 slots
    srcs: np.ndarray                           # (n, MAX_SRCS) int32 slots
    imm: np.ndarray                            # (n,) int64
    has_imm: np.ndarray                        # (n,) bool
    mem_base: np.ndarray                       # (n,) int32 slot or -1
    mem_offset: np.ndarray                     # (n,) int64
    target: np.ndarray                         # (n,) int32
    has_target: np.ndarray                     # (n,) bool
    _token_tables: Dict[int, Tuple[object, np.ndarray]] = \
        dataclasses.field(default_factory=dict, repr=False, compare=False)
    _token_keys: Dict[int, Tuple[object, Tuple[bytes, ...]]] = \
        dataclasses.field(default_factory=dict, repr=False, compare=False)
    _handlers: Optional[list] = \
        dataclasses.field(default=None, repr=False, compare=False)
    # per-static operand/property tables memoized by isa/timing
    _timing_tables: Optional[tuple] = \
        dataclasses.field(default=None, repr=False, compare=False)

    @property
    def n_static(self) -> int:
        return self.opcode.shape[0]

    def __len__(self) -> int:
        return self.n_static

    # ---------------------------- round-trip ---------------------------- #

    def instruction(self, i: int) -> Instruction:
        """Decode static instruction ``i`` back to the object form."""
        return Instruction(
            op=OPCODE_LIST[int(self.opcode[i])],
            dsts=tuple(SLOT_NAME[s] for s in self.dsts[i] if s >= 0),
            srcs=tuple(SLOT_NAME[s] for s in self.srcs[i] if s >= 0),
            imm=int(self.imm[i]) if self.has_imm[i] else None,
            mem_base=(SLOT_NAME[int(self.mem_base[i])]
                      if self.mem_base[i] >= 0 else None),
            mem_offset=int(self.mem_offset[i]),
            target=int(self.target[i]) if self.has_target[i] else None)

    def decode(self) -> List[Instruction]:
        return [self.instruction(i) for i in range(self.n_static)]

    # --------------------------- token table ---------------------------- #

    def token_table(self, vocab, l_token: int) -> np.ndarray:
        """``(n_static, l_token) int32`` standardized-token rows (Fig 5).

        Standardization reads only static fields, so the table is built
        once per (vocab, l_token) and per-clip tokenization becomes a
        gather ``table[trace.pc[a:b]]``.
        """
        # keyed by l_token with the vocab held by reference: identity is
        # checked (not id(), which could be reused after a gc) and the
        # cached vocab stays alive as long as its table does
        cached = self._token_tables.get(l_token)
        if cached is not None and cached[0] is vocab:
            return cached[1]
        from repro.core.standardize import encode_instruction
        table = np.stack([encode_instruction(inst, vocab, l_token)
                          for inst in self.insts]) if self.insts else \
            np.zeros((0, l_token), np.int32)
        table.setflags(write=False)
        self._token_tables[l_token] = (vocab, table)
        return table

    def token_row_keys(self, vocab, l_token: int) -> Tuple[bytes, ...]:
        """Memoized content keys (``tobytes`` per ``token_table`` row) —
        what the static-instruction RT cache dedupes on.  Keyed like
        ``token_table`` (identity-checked vocab per l_token)."""
        cached = self._token_keys.get(l_token)
        if cached is not None and cached[0] is vocab:
            return cached[1]
        table = self.token_table(vocab, l_token)
        keys = tuple(r.tobytes() for r in np.ascontiguousarray(table))
        self._token_keys[l_token] = (vocab, keys)
        return keys


def compile_program(program: Sequence[Instruction]) -> CompiledProgram:
    """Build the SoA encoding; raises ``CompileError`` on shapes the
    columns cannot hold (callers then use the object interpreter)."""
    n = len(program)
    opcode = np.zeros(n, np.int32)
    dsts = np.full((n, MAX_DSTS), -1, np.int32)
    srcs = np.full((n, MAX_SRCS), -1, np.int32)
    imm = np.zeros(n, np.int64)
    has_imm = np.zeros(n, bool)
    mem_base = np.full(n, -1, np.int32)
    mem_offset = np.zeros(n, np.int64)
    target = np.full(n, -1, np.int32)
    has_target = np.zeros(n, bool)

    for i, inst in enumerate(program):
        code = OPCODE_CODE.get(inst.op)
        if code is None:
            raise CompileError(f"unknown opcode {inst.op!r}")
        if len(inst.dsts) > MAX_DSTS or len(inst.srcs) > MAX_SRCS:
            raise CompileError(
                f"operand overflow at pc {i}: {inst.text()}")
        try:
            for k, d in enumerate(inst.dsts):
                dsts[i, k] = REG_SLOT[d]
            for k, s in enumerate(inst.srcs):
                srcs[i, k] = REG_SLOT[s]
            if inst.mem_base is not None:
                mem_base[i] = REG_SLOT[inst.mem_base]
        except KeyError as e:                  # unknown register name
            raise CompileError(f"unknown register {e} at pc {i}") from e
        opcode[i] = code
        if inst.imm is not None:
            imm[i] = inst.imm
            has_imm[i] = True
        mem_offset[i] = inst.mem_offset
        if inst.target is not None:
            target[i] = inst.target
            has_target[i] = True

    return CompiledProgram(
        insts=tuple(program), opcode=opcode, dsts=dsts, srcs=srcs,
        imm=imm, has_imm=has_imm, mem_base=mem_base,
        mem_offset=mem_offset, target=target, has_target=has_target)


# --------------------------------------------------------------------------- #
# Columnar dynamic trace
# --------------------------------------------------------------------------- #

@dataclasses.dataclass(eq=False)                # ndarray fields: no __eq__
class Trace:
    """Columnar dynamic trace (replaces ``List[TraceEntry]``).

    ``ea`` is 0 for non-memory instructions (whether an entry *has* an
    effective address is a static property: ``OP_IS_MEM[opcode[pc]]``);
    ``taken`` is -1 for non-branches, else 0/1.
    """

    program: CompiledProgram
    pc: np.ndarray                             # (n,) int32
    ea: np.ndarray                             # (n,) uint64
    taken: np.ndarray                          # (n,) int8
    snapshots: np.ndarray                      # (n_snaps, N_IREGS) uint64

    def __len__(self) -> int:
        return self.pc.shape[0]

    def entries(self) -> list:
        """Thin object adapter: the equivalent ``List[TraceEntry]``."""
        from repro.isa.funcsim import TraceEntry
        insts = self.program.insts
        is_mem = OP_IS_MEM[self.program.opcode]
        pcs = self.pc.tolist()
        eas = self.ea.tolist()
        takens = self.taken.tolist()
        return [TraceEntry(pc=pc, inst=insts[pc],
                           ea=eas[i] if is_mem[pc] else None,
                           taken=None if takens[i] < 0 else bool(takens[i]))
                for i, pc in enumerate(pcs)]

    def snapshot_dicts(self) -> List[Dict[str, int]]:
        """Thin object adapter: snapshots as {reg_name: value} dicts."""
        return [{r: int(v) for r, v in zip(CONTEXT_REGS, row)}
                for row in self.snapshots.tolist()]
