"""Synthetic benchmark suite (the SPEC 2017 stand-in, paper Table II).

SPEC 2017 binaries are unavailable offline, so the framework carries 24
generated Power-ISA programs named and tagged after Table II.  Each program
is a composition of behaviour motifs matched to its CTRL / COMP / MEM tags:

    COMP  floating-point fmadd chains, integer mul/div kernels
    MEM   streaming loads/stores (stride > cache line), pointer chasing
          (serial D-cache misses), blocked gather/scatter
    CTRL  data-dependent branch ladders (mispredict pressure), call/return
          chains, short irregular loops

The per-benchmark RNG (seeded by the benchmark name) varies loop lengths,
chain depths, strides, and register assignments, so the 24 programs exercise
distinct code and distinct microarchitectural bottlenecks — which is what
the 6-set train/test generalization protocol (Fig 11) needs.
"""
from __future__ import annotations

import dataclasses
import zlib
from typing import Callable, Dict, List, Optional, Tuple

import numpy as np

from repro.isa.compiled import CompiledProgram, compile_program
from repro.isa.funcsim import CompiledState, MachineState
from repro.isa.isa import Instruction

I = Instruction

# Table II: name -> (ckp_num, tags, set_no)
TABLE_II: Dict[str, Tuple[int, str, int]] = {
    "500.perlbench": (7, "CTRL", 1),
    "502.gcc": (1, "CTRL", 2),
    "503.bwaves": (24, "COMP+MEM", 1),
    "505.mcf": (32, "COMP+MEM", 2),
    "507.cactuBSSN": (20, "COMP+MEM", 3),
    "508.namd": (70, "COMP+MEM", 4),
    "510.parest": (78, "COMP+MEM", 5),
    "511.povray": (16, "COMP+MEM", 6),
    "519.lbm": (16, "COMP+MEM", 1),
    "520.omnetpp": (26, "CTRL", 3),
    "521.wrf": (71, "COMP+MEM", 2),
    "523.xalancbmk": (5, "CTRL+MEM", 4),
    "525.x264": (13, "COMP", 3),
    "526.blender": (13, "COMP+MEM", 4),
    "527.cam4": (86, "COMP+MEM", 5),
    "531.deepsjeng": (4, "CTRL", 5),
    "538.imagick": (4, "COMP+MEM", 6),
    "541.leela": (11, "CTRL+MEM", 1),
    "544.nab": (17, "COMP+MEM", 2),
    "548.exchange2": (40, "CTRL+MEM", 6),
    "549.fotonik3d": (15, "COMP+MEM", 3),
    "554.roms": (43, "COMP+MEM", 4),
    "557.xz": (8, "COMP+MEM", 5),
    "999.specrand": (3, "COMP+MEM", 6),
}

SET_NUMBERS = (1, 2, 3, 4, 5, 6)


@dataclasses.dataclass
class Benchmark:
    name: str
    tags: str
    set_no: int
    ckp_num: int
    program: List[Instruction]
    setup: Callable[[MachineState], None]
    _compiled: Optional[CompiledProgram] = dataclasses.field(
        default=None, repr=False, compare=False)

    @property
    def tag_list(self) -> Tuple[str, ...]:
        return tuple(self.tags.split("+"))

    def compiled(self) -> CompiledProgram:
        """Columnar SoA form of ``program``, compiled once per benchmark."""
        if self._compiled is None:
            self._compiled = compile_program(self.program)
        return self._compiled


# --------------------------------------------------------------------------- #
# Motif generators.  Each returns a list of instructions with branch targets
# RELATIVE to its own start; ``_emit`` rebases them into the program.
# --------------------------------------------------------------------------- #

def _loop(body: List[Instruction], iters_reg_val: int,
          scratch: str = "R9") -> List[Instruction]:
    """mtctr <n>; body; bdnz -> len(head) (loop start).

    Body-internal relative targets shift by len(head) so they stay correct
    after the head is prepended.
    """
    head = [I("addi", dsts=(scratch,), imm=iters_reg_val),
            I("mtctr", srcs=(scratch,))]
    shifted = [dataclasses.replace(i, target=i.target + len(head))
               if i.target is not None else i for i in body]
    loop = shifted + [I("bdnz", target=len(head))]
    return head + loop


def fp_chain(rng: np.random.RandomState, depth: int, base_reg: str,
             mem_ratio: float) -> List[Instruction]:
    """fmadd dependency chain, optionally fed from / drained to memory."""
    body: List[Instruction] = []
    fr = [f"F{i}" for i in rng.choice(16, size=6, replace=False)]
    if rng.rand() < mem_ratio:
        body.append(I("lfd", dsts=(fr[0],), mem_base=base_reg,
                      mem_offset=int(rng.randint(0, 16)) * 8))
    for d in range(depth):
        a, b, c = fr[d % 3], fr[(d + 1) % 3], fr[3 + d % 3]
        op = rng.choice(["fmadd", "fmul", "fadd", "fsub"])
        if op == "fmadd":
            body.append(I("fmadd", dsts=(a,), srcs=(a, b, c)))
        else:
            body.append(I(op, dsts=(a,), srcs=(a, b)))
    if rng.rand() < mem_ratio:
        body.append(I("stfd", srcs=(fr[0],), mem_base=base_reg,
                      mem_offset=int(rng.randint(0, 16)) * 8))
        body.append(I("addi", dsts=(base_reg,), srcs=(base_reg,), imm=64))
    return body


def int_kernel(rng: np.random.RandomState, n: int,
               div_ratio: float) -> List[Instruction]:
    body: List[Instruction] = []
    gr = [f"R{i}" for i in rng.choice(range(16, 28), size=6, replace=False)]
    for k in range(n):
        a, b = gr[k % 4], gr[(k + 1) % 4]
        r = rng.rand()
        if r < div_ratio:
            body.append(I("divd", dsts=(a,), srcs=(a, gr[4])))
        elif r < div_ratio + 0.25:
            body.append(I("mulld", dsts=(a,), srcs=(a, b)))
        else:
            op = rng.choice(["add", "xor", "and", "or", "subf"])
            body.append(I(op, dsts=(a,), srcs=(a, b)))
    body.append(I("addi", dsts=(gr[4],), srcs=(gr[4],), imm=3))
    return body


def stream_kernel(rng: np.random.RandomState, ptr: str, stride: int,
                  store: bool) -> List[Instruction]:
    """Strided load(+store) sweep; stride > 64 B defeats the line cache."""
    v = f"R{int(rng.randint(16, 28))}"
    body = [I("ld", dsts=(v,), mem_base=ptr, mem_offset=0),
            I("add", dsts=(v,), srcs=(v, v))]
    if store:
        body.append(I("std", srcs=(v,), mem_base=ptr, mem_offset=8))
    body.append(I("addi", dsts=(ptr,), srcs=(ptr,), imm=stride))
    return body


def chase_kernel(ptr: str) -> List[Instruction]:
    """Pointer chase: each load's address depends on the previous load."""
    return [I("ld", dsts=(ptr,), mem_base=ptr, mem_offset=0)]


def branch_ladder(rng: np.random.RandomState, ptr: str,
                  n_rungs: int) -> List[Instruction]:
    """Data-dependent compare+branch rungs over a random-valued array.

    Each rung: load, compare against a threshold, conditionally skip a
    couple of ALU ops.  Random data -> ~50% taken -> mispredict pressure.
    """
    body: List[Instruction] = []
    v = f"R{int(rng.randint(16, 24))}"
    t = f"R{int(rng.randint(24, 28))}"
    for _ in range(n_rungs):
        body.append(I("ld", dsts=(v,), mem_base=ptr, mem_offset=0))
        body.append(I("cmpi", srcs=(v,), imm=int(rng.randint(10, 120))))
        skip = [I("add", dsts=(t,), srcs=(t, v)),
                I("xor", dsts=(v,), srcs=(v, t))]
        # bc cond=0 (branch if lt) over the skip block
        body.append(I("bc", imm=0, target=None))
        patch_at = len(body) - 1
        body.extend(skip)
        body[patch_at] = I("bc", imm=0, target=len(body))
        body.append(I("addi", dsts=(ptr,), srcs=(ptr,), imm=8))
    return body


def call_block(rng: np.random.RandomState,
               fn_bodies: int) -> List[Instruction]:
    """bl/blr call chain: emit N tiny leaf functions + a caller sequence.

    Layout: [caller: bl f0; bl f1; ...; b end] [f0 ... blr] [f1 ... blr] end.
    """
    callers: List[Instruction] = []
    fns: List[List[Instruction]] = []
    for _ in range(fn_bodies):
        g = f"R{int(rng.randint(16, 28))}"
        fn = [I("addi", dsts=(g,), srcs=(g,), imm=int(rng.randint(1, 9))),
              I("mulld", dsts=(g,), srcs=(g, g)),
              I("blr")]
        fns.append(fn)
    n_callers = fn_bodies + 1                       # bl xN + trailing b
    out: List[Instruction] = []
    fn_starts = []
    off = n_callers
    for fn in fns:
        fn_starts.append(off)
        off += len(fn)
    for k in range(fn_bodies):
        out.append(I("bl", target=fn_starts[k]))
    out.append(I("b", target=off))                  # jump past the bodies
    for fn in fns:
        out.extend(fn)
    return out


# --------------------------------------------------------------------------- #
# Program assembly
# --------------------------------------------------------------------------- #

def _emit(program: List[Instruction], block: List[Instruction]) -> None:
    base = len(program)
    for inst in block:
        if inst.target is not None:
            inst = dataclasses.replace(inst, target=inst.target + base)
        program.append(inst)


def build_benchmark(name: str) -> Benchmark:
    ckp, tags, set_no = TABLE_II[name]
    seed = zlib.crc32(name.encode()) & 0xFFFFFFFF
    rng = np.random.RandomState(seed)
    tagset = set(tags.split("+"))

    program: List[Instruction] = []
    # pointer registers with well-separated heaps
    p_stream, p_chase, p_data = "R11", "R12", "R13"
    heap_stream, heap_chase, heap_data = 0x10000, 0x400000, 0x800000
    prologue = [
        I("addi", dsts=(p_stream,), imm=heap_stream),
        I("addi", dsts=(p_chase,), imm=heap_chase),
        I("addi", dsts=(p_data,), imm=heap_data),
        I("addi", dsts=("R28",), imm=int(rng.randint(3, 60))),
    ]
    _emit(program, prologue)
    outer_start = len(program)

    n_motifs = int(rng.randint(3, 6))
    for _ in range(n_motifs):
        choices = []
        if "COMP" in tagset:
            choices += ["fp", "int"] * 2
        if "MEM" in tagset:
            choices += ["stream", "chase"] * 2
        if "CTRL" in tagset:
            choices += ["branch", "call"] * 2
        kind = rng.choice(choices)
        iters = int(rng.randint(24, 120))
        if kind == "fp":
            body = fp_chain(rng, depth=int(rng.randint(3, 9)),
                            base_reg=p_stream,
                            mem_ratio=0.7 if "MEM" in tagset else 0.15)
            block = _loop(body, iters)
        elif kind == "int":
            body = int_kernel(rng, n=int(rng.randint(4, 10)),
                              div_ratio=float(rng.uniform(0.0, 0.15)))
            block = _loop(body, iters)
        elif kind == "stream":
            stride = int(rng.choice([8, 64, 72, 136, 264]))
            body = stream_kernel(rng, p_stream, stride,
                                 store=bool(rng.rand() < 0.5))
            block = _loop(body, iters)
        elif kind == "chase":
            block = _loop(chase_kernel(p_chase) * int(rng.randint(1, 4)),
                          iters)
        elif kind == "branch":
            body = branch_ladder(rng, p_data, n_rungs=int(rng.randint(2, 5)))
            block = _loop(body, iters)
        else:  # call
            block = _loop(call_block(rng, fn_bodies=int(rng.randint(2, 4))),
                          max(8, iters // 4))
        _emit(program, block)
        # re-anchor the pointers so repeated outer iterations stay in-heap
        _emit(program, [
            I("addi", dsts=(p_stream,), imm=heap_stream +
              int(rng.randint(0, 64)) * 8),
            I("addi", dsts=(p_data,), imm=heap_data),
        ])
    program.append(I("b", target=outer_start))     # absolute, no rebase

    chase_slots = 4096
    data_slots = 4096
    perm = rng.permutation(chase_slots)

    def setup(st: MachineState, _perm=perm, _rng_seed=seed) -> None:
        r = np.random.RandomState(_rng_seed ^ 0x5EED)
        st.regs[p_chase] = heap_chase
        # pointer-chase cycle: mem[heap + 8*i] -> heap + 8*perm[i]
        for i in range(chase_slots):
            ea = heap_chase + 8 * i
            st.mem[ea >> 3] = heap_chase + 8 * int(_perm[i])
        # random data for the branch ladders
        for i in range(data_slots):
            ea = heap_data + 8 * i
            st.mem[ea >> 3] = int(r.randint(0, 128))

    return Benchmark(name=name, tags=tags, set_no=set_no, ckp_num=ckp,
                     program=program, setup=setup)


# --------------------------------------------------------------------------- #
# Multi-threaded variants (the multicore subsystem's per-core programs)
# --------------------------------------------------------------------------- #
#
# Each core runs the SAME program structure over a shared data memory;
# only the heap-base immediates differ per core.  Standardization
# collapses immediates to <CONST> (Fig 5a), so every core's token table
# is bitwise identical and the static-instruction RT cache is shared
# perfectly across cores.  Two sharing regimes:
#
#   sharded   stream / chase kernels over per-core disjoint slices of the
#             shared heaps — a core's trace is invariant under core count
#             and scheduling order (no conflicts by construction),
#   shared    a read-modify-write counter kernel on ONE address all cores
#             hammer — the classic contention/lost-update workload whose
#             loaded values depend on the deterministic interleave.

MT_HEAP_STREAM = 0x10000
MT_HEAP_CHASE = 0x400000
MT_SHARD_SLOTS = 2048            # 8-byte slots per core in each sharded heap
MT_COUNTER_EA = 0xC00000         # the one shared contention counter

MT_KINDS = ("stream", "chase", "counter", "mix")


def shared_counter_kernel(ptr: str, scratch: str) -> List[Instruction]:
    """Non-atomic read-modify-write on one shared address: every core
    runs ld/addi/std against ``MT_COUNTER_EA`` — cross-core conflict
    visibility (and lost updates) by design."""
    return [I("ld", dsts=(scratch,), mem_base=ptr, mem_offset=0),
            I("addi", dsts=(scratch,), srcs=(scratch,), imm=1),
            I("std", srcs=(scratch,), mem_base=ptr, mem_offset=0)]


def _mt_stream_base(core_id: int) -> int:
    return MT_HEAP_STREAM + core_id * MT_SHARD_SLOTS * 8


def _mt_chase_base(core_id: int) -> int:
    return MT_HEAP_CHASE + core_id * MT_SHARD_SLOTS * 8


def build_core_program(kind: str, core_id: int,
                       seed: int) -> List[Instruction]:
    """One core's program for a multi-threaded variant.

    The RNG is seeded by ``seed`` only (not the core id), so all cores
    share one program shape; ``core_id`` enters solely through the
    heap-base immediates that shard the stream/chase heaps.
    """
    if kind not in MT_KINDS:
        raise ValueError(f"unknown multicore kind {kind!r} "
                         f"(one of {MT_KINDS})")
    rng = np.random.RandomState(seed)
    program: List[Instruction] = []
    p_stream, p_chase, p_ctr = "R11", "R12", "R13"
    _emit(program, [
        I("addi", dsts=(p_stream,), imm=_mt_stream_base(core_id)),
        I("addi", dsts=(p_chase,), imm=_mt_chase_base(core_id)),
        I("addi", dsts=(p_ctr,), imm=MT_COUNTER_EA),
    ])
    outer_start = len(program)

    def stream_block():
        # stride * iters stays inside the core's MT_SHARD_SLOTS*8 shard,
        # so streams never cross into a neighbour core's slice
        stride = int(rng.choice([8, 64, 72]))
        iters = int(rng.randint(32, 96))
        body = stream_kernel(rng, p_stream, stride,
                             store=bool(rng.rand() < 0.5))
        return _loop(body, iters)

    def chase_block():
        return _loop(chase_kernel(p_chase) * int(rng.randint(1, 4)),
                     int(rng.randint(32, 96)))

    def counter_block():
        body = shared_counter_kernel(p_ctr, "R20")
        body += int_kernel(rng, n=int(rng.randint(3, 7)), div_ratio=0.0)
        return _loop(body, int(rng.randint(32, 96)))

    blocks = {"stream": [stream_block, stream_block],
              "chase": [chase_block, chase_block],
              "counter": [counter_block, counter_block],
              "mix": [stream_block, chase_block, counter_block]}[kind]
    for make in blocks:
        _emit(program, make())
        # re-anchor the sharded pointers so repeated outer iterations
        # stay inside this core's slice
        _emit(program, [
            I("addi", dsts=(p_stream,), imm=_mt_stream_base(core_id)),
            I("addi", dsts=(p_chase,), imm=_mt_chase_base(core_id)),
        ])
    program.append(I("b", target=outer_start))     # absolute, no rebase
    return program


def mt_setup_memory(mem: Dict[int, int], n_cores: int, seed: int) -> None:
    """Initialize the SHARED data memory for an n-core run: one private
    pointer-chase cycle per core (inside its shard) plus the zeroed
    shared counter.  Core i's region depends only on ``core_id``, never
    on ``n_cores`` — the sharded-trace invariance the tests pin down."""
    for core in range(n_cores):
        base = _mt_chase_base(core)
        perm = np.random.RandomState(
            (seed ^ 0x5EED) + core).permutation(MT_SHARD_SLOTS)
        for i in range(MT_SHARD_SLOTS):
            mem[(base + 8 * i) >> 3] = base + 8 * int(perm[i])
    mem[MT_COUNTER_EA >> 3] = 0


def all_benchmarks() -> List[Benchmark]:
    return [build_benchmark(n) for n in TABLE_II]


def benchmarks_in_set(set_no: int) -> List[Benchmark]:
    return [build_benchmark(n) for n, (_, _, s) in TABLE_II.items()
            if s == set_no]


def fresh_state(bench: Benchmark) -> MachineState:
    st = MachineState.fresh()
    bench.setup(st)
    return st


def fresh_compiled_state(bench: Benchmark) -> CompiledState:
    """Columnar initial state (setup still writes the object form)."""
    return CompiledState.from_machine(fresh_state(bench))
