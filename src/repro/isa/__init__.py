from repro.isa.compiled import (CompileError, CompiledProgram,  # noqa: F401
                                Trace, compile_program)
from repro.isa.isa import Instruction, OPCODES, REGS  # noqa: F401
from repro.isa.multicore import (MulticoreBenchmark,  # noqa: F401
                                 MulticoreTrace, build_multicore_benchmark,
                                 run_multicore)
