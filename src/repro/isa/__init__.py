from repro.isa.isa import Instruction, OPCODES, REGS  # noqa: F401
