from repro.isa.compiled import (CompiledProgram, CompileError,  # noqa: F401
                                Trace, compile_program)
from repro.isa.isa import OPCODES, REGS, Instruction  # noqa: F401
from repro.isa.multicore import (MulticoreBenchmark,  # noqa: F401
                                 MulticoreTrace, build_multicore_benchmark,
                                 run_multicore)
