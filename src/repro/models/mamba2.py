"""Mamba2 (SSD — state-space duality) mixer block.

Faithful to the SSD formulation of arXiv:2405.21060: a chunked algorithm that
computes the within-chunk part as a masked quadratic attention-like product
and carries cross-chunk state through an associative recurrence.  The same
math backs three paths:

  train/prefill  chunked SSD over the full sequence (jnp here; the Pallas
                 kernel in kernels/ssd implements the same chunk computation
                 with VMEM tiling and is validated against kernels/ssd/ref.py)
  decode         O(1) single-step state update — this is what makes the
                 long_500k cells linear-cost.

Sharding: d_inner (and therefore the SSD head axis) is tensor-parallel over
'model'; the B/C state projections are small and replicated (analogous to GQA
KV heads); the cross-chunk state (B, heads, head_dim, d_state) shards over
batch + heads.
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.distributed.sharding import shard_logical
from repro.models.layers import ParamSpec, dense_spec, rms_norm


def ssm_dims(cfg):
    d_inner = cfg.ssm_expand * cfg.d_model
    nheads = d_inner // cfg.ssm_head_dim
    return d_inner, nheads


def ssm_specs(cfg) -> dict:
    d = cfg.d_model
    d_inner, nheads = ssm_dims(cfg)
    ds, w = cfg.ssm_state, cfg.ssm_conv_width
    return {
        "wz": dense_spec(d, d_inner, ("embed", "ssm_inner")),
        "wx": dense_spec(d, d_inner, ("embed", "ssm_inner")),
        "wB": dense_spec(d, ds, ("embed", None)),
        "wC": dense_spec(d, ds, ("embed", None)),
        "wdt": dense_spec(d, nheads, ("embed", None)),
        "conv_x": ParamSpec((w, d_inner), (None, "ssm_inner"), std=0.5),
        "conv_B": ParamSpec((w, ds), (None, None), std=0.5),
        "conv_C": ParamSpec((w, ds), (None, None), std=0.5),
        "A_log": ParamSpec((nheads,), (None,), std=-1.0, dtype="float32"),
        "dt_bias": ParamSpec((nheads,), (None,), std=0.0, dtype="float32"),
        "D": ParamSpec((nheads,), (None,), std=-1.0, dtype="float32"),
        "gate_norm": ParamSpec((d_inner,), ("ssm_inner",), std=0.0,
                               dtype="float32"),
        "out_proj": dense_spec(d_inner, d, ("ssm_inner", "embed")),
    }


def _shift_conv(x, w, cache=None):
    """Causal depthwise conv of width W via shifted adds.

    x: (B, S, C); w: (W, C).  With a decode cache (B, W-1, C) holding the
    previous W-1 inputs, S may be 1.  Returns (y, new_cache).
    """
    W = w.shape[0]
    if cache is None:
        pad = jnp.zeros_like(x[:, : W - 1])
        xp = jnp.concatenate([pad, x], axis=1)
    else:
        xp = jnp.concatenate([cache.astype(x.dtype), x], axis=1)
    S = x.shape[1]
    y = sum(xp[:, i : i + S] * w[i][None, None, :] for i in range(W))
    new_cache = xp[:, -(W - 1):] if W > 1 else xp[:, :0]
    return jax.nn.silu(y), new_cache


def _segsum(dA):
    """dA: (..., Q).  Returns (..., Q, Q) with out[i, j] = sum_{j<t<=i} dA_t
    for i >= j, -inf elsewhere (log of the decay matrix L)."""
    Q = dA.shape[-1]
    cs = jnp.cumsum(dA, axis=-1)
    diff = cs[..., :, None] - cs[..., None, :]          # seg_i - seg_j
    mask = jnp.tril(jnp.ones((Q, Q), bool), 0)
    return jnp.where(mask, diff, -jnp.inf)


def ssd_chunked(x, dt, B, C, A, chunk: int, init_state=None):
    """Chunked SSD scan.

    x:  (Bt, S, H, P)   inputs per head
    dt: (Bt, S, H)      positive step sizes (already softplus'd)
    B:  (Bt, S, N)      input->state projection (single group, broadcast to H)
    C:  (Bt, S, N)      state->output projection
    A:  (H,)            negative per-head decay rate
    Returns (y (Bt,S,H,P), final_state (Bt,H,P,N)).
    """
    Bt, S, H, Pd = x.shape
    N = B.shape[-1]
    Q = min(chunk, S)
    if S % Q != 0:
        Q = S
    NC = S // Q

    xc = x.reshape(Bt, NC, Q, H, Pd)
    dtc = dt.reshape(Bt, NC, Q, H).astype(jnp.float32)
    Bc = B.reshape(Bt, NC, Q, N)
    Cc = C.reshape(Bt, NC, Q, N)
    dA = dtc * A[None, None, None, :]                   # (Bt, NC, Q, H) <= 0

    if init_state is None:
        init_state = jnp.zeros((Bt, H, Pd, N), jnp.float32)

    def body(state, inputs):
        xq, dtq, Bq, Cq, dAq = inputs                   # chunk-local
        # (Bt, H, Q) time-major per head
        dAh = jnp.moveaxis(dAq, -1, 1)                  # (Bt, H, Q)
        L = jnp.exp(_segsum(dAh))                       # (Bt, H, Q, Q)
        seg = jnp.cumsum(dAh, axis=-1)                  # (Bt, H, Q)
        # within-chunk (quadratic in Q)
        CB = jnp.einsum("bin,bjn->bij", Cq, Bq,
                        preferred_element_type=jnp.float32)
        scores = CB[:, None] * L                        # (Bt, H, Q, Q)
        xdt = xq * dtq[..., None]                       # (Bt, Q, H, P)
        y_diag = jnp.einsum("bhij,bjhp->bihp", scores.astype(xq.dtype), xdt)
        # contribution of incoming state
        y_off = jnp.einsum("bin,bhpn->bihp", Cq, state.astype(xq.dtype)) \
            * jnp.exp(seg).transpose(0, 2, 1)[..., None].astype(xq.dtype)
        # new state
        decay_to_end = jnp.exp(seg[..., -1:] - seg)     # (Bt, H, Q)
        w = (dtq.transpose(0, 2, 1) * decay_to_end)     # (Bt, H, Q)
        new_state = state * jnp.exp(seg[..., -1])[..., None, None] + \
            jnp.einsum("bjn,bhj,bjhp->bhpn", Bq.astype(jnp.float32),
                       w, xq.astype(jnp.float32))
        return new_state, y_diag + y_off

    xs = (jnp.moveaxis(xc, 1, 0), jnp.moveaxis(dtc, 1, 0),
          jnp.moveaxis(Bc, 1, 0), jnp.moveaxis(Cc, 1, 0),
          jnp.moveaxis(dA, 1, 0))
    final_state, yc = jax.lax.scan(body, init_state, xs)
    y = jnp.moveaxis(yc, 0, 1).reshape(Bt, S, H, Pd)
    return y.astype(x.dtype), final_state


def ssd_decode_step(x, dt, B, C, A, state):
    """One-token SSD update.  x: (Bt, H, P); dt: (Bt, H); B/C: (Bt, N);
    state: (Bt, H, P, N) fp32.  Returns (y, new_state)."""
    dA = jnp.exp(dt.astype(jnp.float32) * A[None, :])   # (Bt, H)
    upd = jnp.einsum("bn,bh,bhp->bhpn", B.astype(jnp.float32),
                     dt.astype(jnp.float32), x.astype(jnp.float32))
    new_state = state * dA[..., None, None] + upd
    y = jnp.einsum("bn,bhpn->bhp", C.astype(jnp.float32), new_state)
    return y.astype(x.dtype), new_state


def init_ssm_cache_specs(cfg, batch: int) -> dict:
    d_inner, nheads = ssm_dims(cfg)
    ds, w = cfg.ssm_state, cfg.ssm_conv_width
    return {
        "conv_x": ParamSpec((batch, w - 1, d_inner),
                            ("cache_batch", None, "act_ssm")),
        "conv_B": ParamSpec((batch, w - 1, ds), ("cache_batch", None, None)),
        "conv_C": ParamSpec((batch, w - 1, ds), ("cache_batch", None, None)),
        "state": ParamSpec((batch, nheads, cfg.ssm_head_dim, ds),
                           ("cache_batch", "act_ssm", None, None),
                           dtype="float32"),
    }


def ssm_forward(params, x, cfg, mode: str,
                cache: Optional[dict] = None) -> Tuple[jax.Array, Optional[dict]]:
    """x: (Bt, S, d).  Returns (out (Bt, S, d), updated cache or None)."""
    Bt, S, d = x.shape
    d_inner, nheads = ssm_dims(cfg)
    Pd = cfg.ssm_head_dim

    z = jnp.einsum("bsd,di->bsi", x, params["wz"])
    xin = jnp.einsum("bsd,di->bsi", x, params["wx"])
    Bp = jnp.einsum("bsd,dn->bsn", x, params["wB"])
    Cp = jnp.einsum("bsd,dn->bsn", x, params["wC"])
    dt = jnp.einsum("bsd,dh->bsh", x, params["wdt"])
    dt = jax.nn.softplus(dt.astype(jnp.float32) +
                         params["dt_bias"][None, None, :])
    A = -jnp.exp(params["A_log"].astype(jnp.float32))

    xin, cx = _shift_conv(xin, params["conv_x"],
                          None if cache is None else cache["conv_x"])
    Bp, cB = _shift_conv(Bp, params["conv_B"],
                         None if cache is None else cache["conv_B"])
    Cp, cC = _shift_conv(Cp, params["conv_C"],
                         None if cache is None else cache["conv_C"])

    xh = xin.reshape(Bt, S, nheads, Pd)
    xh = shard_logical(xh, "batch", "act_seq", "act_ssm", None)

    new_cache = None
    if mode == "decode":
        assert cache is not None and S == 1
        y, new_state = ssd_decode_step(
            xh[:, 0], dt[:, 0], Bp[:, 0], Cp[:, 0], A, cache["state"])
        y = y[:, None]                                   # (Bt, 1, H, P)
        new_cache = {"conv_x": cx, "conv_B": cB, "conv_C": cC,
                     "state": new_state}
    else:
        if cfg.ssm_impl == "pallas":
            from repro.kernels.ssd import ops as ssd_ops
            y, final_state = ssd_ops.ssd_scan(xh, dt, Bp, Cp, A,
                                              cfg.ssm_chunk)
        else:
            y, final_state = ssd_chunked(xh, dt, Bp, Cp, A, cfg.ssm_chunk)
        if mode == "prefill":
            new_cache = {"conv_x": cx, "conv_B": cB, "conv_C": cC,
                         "state": final_state}

    y = y + xh * params["D"].astype(x.dtype)[None, None, :, None]
    y = y.reshape(Bt, S, d_inner)
    y = rms_norm(y * jax.nn.silu(z.astype(jnp.float32)).astype(y.dtype),
                 params["gate_norm"])
    out = jnp.einsum("bsi,id->bsd", y, params["out_proj"]).astype(x.dtype)
    return shard_logical(out, "batch", "act_seq", "act_embed"), new_cache
