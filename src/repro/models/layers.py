"""Shared building blocks: param specs, norms, activations, rotary embeddings.

Parameters are described by ``ParamSpec`` trees so the same definition yields
(a) materialized params for execution, (b) ShapeDtypeStructs for AOT lowering
(the multi-pod dry-run never allocates), and (c) NamedShardings from logical
axis names.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.distributed.sharding import logical_sharding


@dataclasses.dataclass(frozen=True)
class ParamSpec:
    shape: Tuple[int, ...]
    logical_axes: Tuple[Optional[str], ...]
    std: float = 0.0          # 0.0 -> zeros; <0 -> ones; >0 -> normal(std)
    dtype: Optional[str] = None  # override param dtype (e.g. fp32 norms)

    def __post_init__(self):
        assert len(self.shape) == len(self.logical_axes), (
            self.shape, self.logical_axes)


def dense_spec(d_in: int, d_out: int, axes, scale: float = 1.0) -> ParamSpec:
    return ParamSpec((d_in, d_out), axes, std=scale / math.sqrt(d_in))


def is_spec_tree(t) -> bool:
    return any(isinstance(l, ParamSpec) for l in jax.tree_util.tree_leaves(
        t, is_leaf=lambda x: isinstance(x, ParamSpec)))


def _map_specs(fn, specs):
    return jax.tree_util.tree_map(
        fn, specs, is_leaf=lambda x: isinstance(x, ParamSpec))


def init_from_specs(specs, key, param_dtype: str):
    flat, treedef = jax.tree_util.tree_flatten(
        specs, is_leaf=lambda x: isinstance(x, ParamSpec))
    keys = jax.random.split(key, len(flat))
    leaves = []
    for spec, k in zip(flat, keys):
        dt = jnp.dtype(spec.dtype or param_dtype)
        if spec.std == 0.0:
            leaves.append(jnp.zeros(spec.shape, dt))
        elif spec.std < 0:
            leaves.append(jnp.ones(spec.shape, dt))
        else:
            leaves.append(
                (jax.random.normal(k, spec.shape, jnp.float32) * spec.std
                 ).astype(dt))
    return jax.tree_util.tree_unflatten(treedef, leaves)


def abstract_from_specs(specs, param_dtype: str):
    return _map_specs(
        lambda s: jax.ShapeDtypeStruct(s.shape, jnp.dtype(s.dtype or param_dtype)),
        specs)


def shardings_from_specs(specs, mesh, rules):
    return _map_specs(
        lambda s: logical_sharding(s.logical_axes, mesh=mesh, rules=rules),
        specs)


def specs_with_leading_stack(specs, n: int):
    """Prepend a scanned 'layers' dimension of size n to every spec."""
    return _map_specs(
        lambda s: ParamSpec((n,) + s.shape, ("layers",) + s.logical_axes,
                            std=s.std, dtype=s.dtype),
        specs)


# --------------------------------------------------------------------------- #
# Norms / activations
# --------------------------------------------------------------------------- #

def rms_norm(x, scale, eps: float = 1e-6):
    dt = x.dtype
    x = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(x), axis=-1, keepdims=True)
    y = x * jax.lax.rsqrt(var + eps)
    if scale is not None:
        y = y * (1.0 + scale.astype(jnp.float32))
    return y.astype(dt)


def nonparam_layer_norm(x, eps: float = 1e-6):
    """OLMo's non-parametric LayerNorm: standardize, no learnable affine."""
    dt = x.dtype
    x = x.astype(jnp.float32)
    mu = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.var(x, axis=-1, keepdims=True)
    return ((x - mu) * jax.lax.rsqrt(var + eps)).astype(dt)


def norm(x, params, cfg):
    if cfg.nonparametric_norm:
        return nonparam_layer_norm(x)
    return rms_norm(x, params["scale"])


def norm_spec(cfg) -> dict:
    if cfg.nonparametric_norm:
        return {}
    return {"scale": ParamSpec((cfg.d_model,), ("embed",), std=0.0,
                               dtype="float32")}


def activation(h, kind: str):
    if kind == "squared_relu":
        r = jax.nn.relu(h)
        return r * r
    if kind == "gelu":
        return jax.nn.gelu(h)
    if kind in ("silu", "swiglu"):
        return jax.nn.silu(h)
    raise ValueError(kind)


# --------------------------------------------------------------------------- #
# Rotary embeddings (standard + M-RoPE)
# --------------------------------------------------------------------------- #

def _rope_freqs(head_dim: int, theta: float):
    half = head_dim // 2
    return 1.0 / (theta ** (jnp.arange(half, dtype=jnp.float32) / half))


def apply_rope(x, positions, theta: float,
               mrope_sections: Tuple[int, ...] = ()):
    """x: (B, S, H, D).  positions: (B, S) int32, or (3, B, S) for M-RoPE.

    M-RoPE (Qwen2-VL): the head_dim/2 frequency slots are split into
    sections (t, h, w); section i rotates by position stream i.
    """
    B, S, H, D = x.shape
    half = D // 2
    freqs = _rope_freqs(D, theta)                       # (half,)
    if mrope_sections:
        assert sum(mrope_sections) == half, (mrope_sections, half)
        assert positions.ndim == 3, "M-RoPE needs (3, B, S) positions"
        pos_parts = []
        for i, sec in enumerate(mrope_sections):
            pos_parts.append(
                jnp.broadcast_to(positions[i][..., None], (B, S, sec)))
        pos = jnp.concatenate(pos_parts, axis=-1)       # (B, S, half)
        angle = pos.astype(jnp.float32) * freqs[None, None, :]
    else:
        if positions.ndim == 3:
            positions = positions[0]
        angle = positions.astype(jnp.float32)[..., None] * freqs  # (B,S,half)
    cos = jnp.cos(angle)[:, :, None, :]
    sin = jnp.sin(angle)[:, :, None, :]
    x1, x2 = x[..., :half], x[..., half:]
    dt = x.dtype
    x1f, x2f = x1.astype(jnp.float32), x2.astype(jnp.float32)
    return jnp.concatenate(
        [x1f * cos - x2f * sin, x2f * cos + x1f * sin], axis=-1).astype(dt)
