"""Top-k MoE FFN with explicit expert parallelism (shard_map).

Design (kimi-k2: 384 experts top-8; llama4: 128 experts top-1; jamba: 16/top-2):

  - Expert weights are sharded over 'model' on the expert axis (EP) and over
    'data' on the d_model axis (FSDP storage).  Inside the shard_map the FSDP
    shards are re-assembled with a tiled all_gather — on a real pod this
    overlaps with the previous layer's compute under the scan.
  - Activations arrive batch-sharded over ('pod','data') and replicated over
    'model'.  Every model shard routes ALL of its local tokens, keeps the
    (token, slot) pairs that map to its local experts, and scatters them into
    an (E_local, capacity, d) buffer — a local, sort-free dispatch.  Combine
    is a single psum over 'model' (same collective volume as a Megatron TP
    FFN all-reduce).
  - Capacity-based dropping with renormalized top-k gates; aux losses
    (load-balance + router z-loss) are returned to the caller.

This keeps every collective explicit: one all_gather (FSDP) + one psum per
MoE layer — no XLA-SPMD surprises from scatters on sharded operands.
"""
from __future__ import annotations

import math
from functools import partial
from typing import Tuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.distributed.sharding import (axis_rules, compat_shard_map,
                                        current_mesh)
from repro.models.layers import ParamSpec


def moe_specs(cfg) -> dict:
    d, f, E = cfg.d_model, cfg.d_ff, cfg.num_experts
    std = 1.0 / math.sqrt(d)
    return {
        "router": ParamSpec((d, E), ("embed", None), std=std, dtype="float32"),
        "w_gate": ParamSpec((E, d, f), ("expert", "embed", "expert_mlp"), std=std),
        "w_up": ParamSpec((E, d, f), ("expert", "embed", "expert_mlp"), std=std),
        "w_down": ParamSpec((E, f, d), ("expert", "expert_mlp", "embed"),
                            std=1.0 / math.sqrt(f)),
    }


def _capacity(t_loc: int, k: int, n_exp: int, cf: float) -> int:
    c = int(math.ceil(cf * t_loc * k / n_exp))
    return max(8, ((c + 7) // 8) * 8)


def _moe_local(xf, router, wg, wu, wd, *, k: int, n_exp: int, e_loc: int,
               cap: int, dp_axes: Tuple[str, ...], act: str):
    """Per-device MoE.  xf: (T_loc, d) local tokens (replicated over 'model');
    wg/wu: (E_loc, d_shard, f); wd: (E_loc, f, d_shard)."""
    # Re-assemble FSDP weight shards along d_model.
    wg = jax.lax.all_gather(wg, "data", axis=1, tiled=True)
    wu = jax.lax.all_gather(wu, "data", axis=1, tiled=True)
    wd = jax.lax.all_gather(wd, "data", axis=2, tiled=True)

    t_loc, d = xf.shape
    scores = (xf.astype(jnp.float32) @ router)                # (T_loc, E)
    probs = jax.nn.softmax(scores, axis=-1)
    gates, idx = jax.lax.top_k(probs, k)                      # (T_loc, k)
    gates = gates / jnp.maximum(jnp.sum(gates, -1, keepdims=True), 1e-9)

    m = jax.lax.axis_index("model")
    local_e = idx - m * e_loc                                 # (T_loc, k)
    is_local = (local_e >= 0) & (local_e < e_loc)
    e_sel = jnp.where(is_local, local_e, 0)

    # Position of each (token, slot) within its expert: exclusive running
    # count over the flattened slot order (deterministic, sort-free).
    oh = jax.nn.one_hot(jnp.where(is_local, local_e, e_loc), e_loc + 1,
                        dtype=jnp.int32).reshape(t_loc * k, e_loc + 1)
    pos = (jnp.cumsum(oh, axis=0) - oh)
    pos = jnp.sum(pos * oh, axis=-1).reshape(t_loc, k)
    keep = is_local & (pos < cap)

    buf = jnp.zeros((e_loc, cap, d), xf.dtype)
    for j in range(k):                                        # static, small
        p = jnp.where(keep[:, j], pos[:, j], cap)             # cap -> dropped
        buf = buf.at[e_sel[:, j], p].add(
            xf * keep[:, j, None].astype(xf.dtype), mode="drop")

    up = jnp.einsum("ecd,edf->ecf", buf, wu)
    if act == "swiglu":
        gate = jnp.einsum("ecd,edf->ecf", buf, wg)
        h = jax.nn.silu(gate) * up
    elif act == "squared_relu":
        r = jax.nn.relu(up)
        h = r * r
    else:
        h = jax.nn.gelu(up)
    down = jnp.einsum("ecf,efd->ecd", h, wd)

    y = jnp.zeros_like(xf)
    for j in range(k):
        p = jnp.where(keep[:, j], pos[:, j], 0)
        w = (gates[:, j] * keep[:, j]).astype(xf.dtype)
        y = y + down[e_sel[:, j], p] * w[:, None]
    y = jax.lax.psum(y, "model")

    # ---- aux losses (replicated over 'model' by construction) ----
    counts = jnp.sum(jax.nn.one_hot(idx, n_exp, dtype=jnp.float32),
                     axis=(0, 1))                             # (E,)
    if dp_axes:
        counts = jax.lax.psum(counts, dp_axes)
        mean_probs = jax.lax.pmean(jnp.mean(probs, axis=0), dp_axes)
        t_tot = t_loc * jax.lax.psum(1, dp_axes)
    else:
        mean_probs = jnp.mean(probs, axis=0)
        t_tot = t_loc
    frac = counts / (t_tot * k)
    lb_loss = n_exp * jnp.sum(frac * mean_probs)
    if dp_axes:
        z = jax.lax.pmean(
            jnp.mean(jnp.square(jax.nn.logsumexp(scores, axis=-1))), dp_axes)
    else:
        z = jnp.mean(jnp.square(jax.nn.logsumexp(scores, axis=-1)))
    return y, lb_loss, z


def moe_forward(params, x, cfg) -> Tuple[jax.Array, jax.Array, jax.Array]:
    """x: (B, S, d) -> (y, lb_loss, z_loss)."""
    B, S, d = x.shape
    mesh = current_mesh()
    E, k = cfg.num_experts, cfg.experts_per_token
    xf = x.reshape(B * S, d)

    if mesh is None:
        # meshless fallback (unit tests): single "device", E_loc = E
        y, lb, z = _run_local_nomesh(params, xf, cfg)
        return y.reshape(B, S, d), lb, z

    sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
    n_model = sizes.get("model", 1)
    dp_axes = tuple(a for a in ("pod", "data") if a in sizes)
    dp = 1
    for a in dp_axes:
        dp *= sizes[a]
    if E % n_model != 0:
        raise ValueError(f"{cfg.name}: experts={E} not divisible by "
                         f"model={n_model}")
    if (B * S) % dp != 0:
        # batch too small to shard over the DP axes (long-context decode):
        # replicate tokens, keep EP over 'model' only.
        dp, dp_axes = 1, ()
    t_loc = (B * S) // dp
    cap = _capacity(t_loc, k, E, cfg.capacity_factor)

    batch_axes = axis_rules(("batch",), mesh=mesh)[0] if dp_axes else None
    tok_spec = P(batch_axes, None)
    fn = compat_shard_map(
        partial(_moe_local, k=k, n_exp=E, e_loc=E // n_model, cap=cap,
                dp_axes=dp_axes, act=cfg.activation),
        mesh=mesh,
        in_specs=(tok_spec, P(None, None), P("model", "data", None),
                  P("model", "data", None), P("model", None, "data")),
        out_specs=(tok_spec, P(), P()),
        check_vma=False,
    )
    y, lb, z = fn(xf, params["router"], params["w_gate"], params["w_up"],
                  params["w_down"])
    return y.reshape(B, S, d), lb, z


def _run_local_nomesh(params, xf, cfg):
    """Reference path without a mesh — identical math, E_loc = E."""
    E, k = cfg.num_experts, cfg.experts_per_token
    t = xf.shape[0]
    cap = _capacity(t, k, E, cfg.capacity_factor)
    scores = xf.astype(jnp.float32) @ params["router"]
    probs = jax.nn.softmax(scores, -1)
    gates, idx = jax.lax.top_k(probs, k)
    gates = gates / jnp.maximum(jnp.sum(gates, -1, keepdims=True), 1e-9)
    oh = jax.nn.one_hot(idx, E, dtype=jnp.int32).reshape(t * k, E)
    pos = (jnp.cumsum(oh, 0) - oh)
    pos = jnp.sum(pos * oh, -1).reshape(t, k)
    keep = pos < cap
    buf = jnp.zeros((E, cap, xf.shape[1]), xf.dtype)
    for j in range(k):
        p = jnp.where(keep[:, j], pos[:, j], cap)
        buf = buf.at[idx[:, j], p].add(
            xf * keep[:, j, None].astype(xf.dtype), mode="drop")
    up = jnp.einsum("ecd,edf->ecf", buf, params["w_up"])
    if cfg.activation == "swiglu":
        h = jax.nn.silu(jnp.einsum("ecd,edf->ecf", buf, params["w_gate"])) * up
    elif cfg.activation == "squared_relu":
        r = jax.nn.relu(up)
        h = r * r
    else:
        h = jax.nn.gelu(up)
    down = jnp.einsum("ecf,efd->ecd", h, params["w_down"])
    y = jnp.zeros_like(xf)
    for j in range(k):
        p = jnp.where(keep[:, j], pos[:, j], 0)
        w = (gates[:, j] * keep[:, j]).astype(xf.dtype)
        y = y + down[idx[:, j], p] * w[:, None]
    counts = jnp.sum(jax.nn.one_hot(idx, E, dtype=jnp.float32), axis=(0, 1))
    lb = E * jnp.sum(counts / (t * k) * jnp.mean(probs, 0))
    z = jnp.mean(jnp.square(jax.nn.logsumexp(scores, -1)))
    return y, lb, z
