"""GQA attention: q-chunked training/prefill path + shard_map flash-decoding.

Three execution paths share one set of weights:

  train/prefill  full-sequence causal attention, scanned over query chunks so
                 the (chunk, S) score tile bounds transient memory (32k prefill
                 would otherwise materialize S^2 scores).  Optionally routed to
                 the Pallas flash kernel (cfg.attn_impl == "pallas").
  decode         one query token against a KV cache whose *sequence* dimension
                 is sharded over the 'model' mesh axis.  Implemented as an
                 explicit shard_map flash-decoding: every model shard computes
                 a partial softmax over its sequence slice and the partials are
                 merged with psum — collective volume is O(B*H*D), independent
                 of context length.  This is the TPU analogue of GPU
                 flash-decoding and is what makes long_500k cells viable.
"""
from __future__ import annotations

import math
from functools import partial
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.distributed.sharding import (axis_rules, compat_shard_map,
                                        current_mesh, shard_logical)
from repro.models.layers import ParamSpec, apply_rope, dense_spec, rms_norm


# --------------------------------------------------------------------------- #
# Param specs
# --------------------------------------------------------------------------- #

def attn_specs(cfg) -> dict:
    d, H, KV, Dh = cfg.d_model, cfg.num_heads, cfg.num_kv_heads, cfg.head_dim
    specs = {
        "wq": dense_spec(d, H * Dh, ("embed", "qkv")),
        "wk": dense_spec(d, KV * Dh, ("embed", "kv")),
        "wv": dense_spec(d, KV * Dh, ("embed", "kv")),
        "wo": dense_spec(H * Dh, d, ("qkv", "embed")),
    }
    if cfg.qk_norm:
        specs["q_norm"] = ParamSpec((Dh,), (None,), std=0.0, dtype="float32")
        specs["k_norm"] = ParamSpec((Dh,), (None,), std=0.0, dtype="float32")
    return specs


# --------------------------------------------------------------------------- #
# Training / prefill attention (q-chunked, causal)
# --------------------------------------------------------------------------- #

def _causal_attention_chunked(q, k, v, chunk: int, q_start=0):
    """q,k,v: (B, Sq, H, Dh)/(B, Skv, H, Dh) with kv already broadcast.

    lax.scan over query chunks; each chunk attends over the full key range
    with a causal mask.  fp32 softmax accumulation.  Transient score tile
    is (B, H, chunk, Skv) instead of (B, H, Sq, Skv).  ``q_start`` offsets
    the query positions globally (sequence-parallel prefill: each shard
    owns rows [q_start, q_start + Sq)).
    """
    B, S, H, Dh = q.shape
    Skv = k.shape[1]
    scale = 1.0 / math.sqrt(Dh)
    chunk = min(chunk, S)
    if S % chunk != 0:
        chunk = S  # fall back to a single chunk (smoke shapes)
    n_chunks = S // chunk
    kpos = jnp.arange(Skv)

    def body(_, idx):
        off = idx * chunk
        qc = jax.lax.dynamic_slice_in_dim(q, off, chunk, axis=1)
        s = jnp.einsum("bchd,bshd->bhcs", qc, k,
                       preferred_element_type=jnp.float32) * scale
        qpos = q_start + off + jnp.arange(chunk)
        mask = qpos[:, None] >= kpos[None, :]
        s = jnp.where(mask[None, None], s, -1e30)
        p = jax.nn.softmax(s, axis=-1).astype(v.dtype)
        oc = jnp.einsum("bhcs,bshd->bchd", p, v)
        return _, oc

    _, out = jax.lax.scan(body, None, jnp.arange(n_chunks))
    # out: (n_chunks, B, chunk, H, Dh) -> (B, S, H, Dh)
    out = jnp.moveaxis(out, 0, 1).reshape(B, S, H, Dh)
    return out


def _causal_attention_pallas(q, k, v):
    from repro.kernels.flash_attention import ops as fa_ops
    return fa_ops.flash_attention(q, k, v, causal=True)


# --------------------------------------------------------------------------- #
# Sequence-parallel prefill attention (§Perf cell E)
# --------------------------------------------------------------------------- #

def sp_prefill_attention(q, k, v, cfg):
    """Ring-style sequence parallelism for prefill/train attention.

    Under LOGICAL_RULES_PREFILL_SP the residual stream is sequence-sharded
    over 'model' (no tensor parallelism at all): FFNs and norms are purely
    local, and attention is the ONLY cross-shard op.  Each shard
    all-gathers the (small, GQA) K/V heads — O(S·KV·Dh) per layer instead
    of the O(B·S·d) all-reduces TP pays — and computes the causal scores
    for its own query rows with a global position offset.

    q: (B, S, H, Dh); k/v: (B, S, KV, Dh) (pre-broadcast: gathering KV=8
    heads then repeating locally is G x cheaper than gathering H=48).
    Returns (B, S, H, Dh), sequence-sharded like q.
    """
    mesh = current_mesh()
    B, S, H, Dh = q.shape
    KV = k.shape[2]
    G = H // KV
    sizes = dict(zip(mesh.axis_names, mesh.devices.shape)) if mesh else {}
    n_sp = sizes.get("model", 1)
    if mesh is None or n_sp <= 1 or S % n_sp != 0:
        kb = jnp.repeat(k, G, axis=2)
        vb = jnp.repeat(v, G, axis=2)
        return _causal_attention_chunked(q, kb, vb, cfg.attn_chunk)

    batch_entry = axis_rules(("batch",), mesh=mesh)[0]
    n_batch = 1
    for a in _axes_tuple(batch_entry):
        n_batch *= sizes[a]
    if n_batch and B % n_batch != 0:
        batch_entry = None
    spec = P(batch_entry, "model", None, None)
    s_loc = S // n_sp

    def local(q_loc, k_loc, v_loc):
        m = jax.lax.axis_index("model")
        k_full = jax.lax.all_gather(k_loc, "model", axis=1, tiled=True)
        v_full = jax.lax.all_gather(v_loc, "model", axis=1, tiled=True)
        kb = jnp.repeat(k_full, G, axis=2)
        vb = jnp.repeat(v_full, G, axis=2)
        return _causal_attention_chunked(q_loc, kb, vb, cfg.attn_chunk,
                                         q_start=m * s_loc)

    fn = compat_shard_map(local, mesh=mesh,
                          in_specs=(spec, spec, spec), out_specs=spec,
                          check_vma=False)
    return fn(q, k, v)


# --------------------------------------------------------------------------- #
# Flash-decoding (shard_map over 'model'; cache seq-sharded)
# --------------------------------------------------------------------------- #

def _axes_tuple(entry) -> Tuple[str, ...]:
    if entry is None:
        return ()
    return entry if isinstance(entry, tuple) else (entry,)


def _flash_decode_local(q, k, v, cache_pos, *, s_loc, scale, seq_axes,
                        axis_sizes):
    """Local partial attention of one shard over its sequence slice.

    q: (B, KV, G, Dh) replicated over seq_axes; k,v: (B, S_loc, KV, Dh)
    local slice; returns merged (B, KV, G, Dh) after psum over seq_axes.
    """
    shard = jnp.zeros((), jnp.int32)
    for a in seq_axes:                                  # row-major combined id
        shard = shard * axis_sizes[a] + jax.lax.axis_index(a)
    kpos = shard * s_loc + jnp.arange(s_loc)            # global positions
    valid = kpos <= cache_pos                           # causal/filled mask
    s = jnp.einsum("bkgd,bskd->bkgs", q, k,
                   preferred_element_type=jnp.float32) * scale
    s = jnp.where(valid[None, None, None, :], s, -jnp.inf)
    m_loc = jnp.max(s, axis=-1)                         # (B, KV, G)
    m = jax.lax.pmax(m_loc, seq_axes)
    p = jnp.exp(s - m[..., None])
    p = jnp.where(valid[None, None, None, :], p, 0.0)
    l = jax.lax.psum(jnp.sum(p, axis=-1), seq_axes)     # (B, KV, G)
    o = jnp.einsum("bkgs,bskd->bkgd", p.astype(v.dtype), v,
                   preferred_element_type=jnp.float32)
    o = jax.lax.psum(o, seq_axes)
    return (o / jnp.maximum(l[..., None], 1e-30)).astype(v.dtype)


def flash_decode(q, k_cache, v_cache, cache_pos, cfg):
    """q: (B, 1, H, Dh); caches: (B, S, KV, Dh), seq dim sharded per the
    active 'cache_seq' rule ('model' for batched decode; the whole mesh for
    long-context B=1 cells)."""
    mesh = current_mesh()
    B, _, H, Dh = q.shape
    KV = cfg.num_kv_heads
    G = H // KV
    S = k_cache.shape[1]
    scale = 1.0 / math.sqrt(Dh)
    qg = q.reshape(B, KV, G, Dh)

    seq_axes = _axes_tuple(
        axis_rules(("cache_seq",), mesh=mesh)[0]) if mesh is not None else ()
    sizes = dict(zip(mesh.axis_names, mesh.devices.shape)) if mesh else {}
    n_seq = 1
    for a in seq_axes:
        n_seq *= sizes[a]

    if mesh is None or n_seq <= 1 or S % n_seq != 0:
        # single-device / unsharded fallback: plain masked attention
        kpos = jnp.arange(S)
        s = jnp.einsum("bkgd,bskd->bkgs", qg, k_cache,
                       preferred_element_type=jnp.float32) * scale
        s = jnp.where((kpos <= cache_pos)[None, None, None], s, -1e30)
        p = jax.nn.softmax(s, axis=-1).astype(v_cache.dtype)
        o = jnp.einsum("bkgs,bskd->bkgd", p, v_cache)
        return o.reshape(B, 1, H, Dh)

    s_loc = S // n_seq
    batch_entry = axis_rules(("cache_batch",), mesh=mesh)[0]
    n_batch = 1
    for a in _axes_tuple(batch_entry):
        n_batch *= sizes[a]
    if n_batch == 0 or B % max(n_batch, 1) != 0:
        batch_entry = None
    q_spec = P(batch_entry, None, None, None)
    kv_spec = P(batch_entry, seq_axes, None, None)

    fn = compat_shard_map(
        partial(_flash_decode_local, s_loc=s_loc, scale=scale,
                seq_axes=seq_axes, axis_sizes=sizes),
        mesh=mesh,
        in_specs=(q_spec, kv_spec, kv_spec, P()),
        out_specs=q_spec,
        check_vma=False,
    )
    o = fn(qg, k_cache, v_cache, cache_pos)
    return o.reshape(B, 1, H, Dh)


# --------------------------------------------------------------------------- #
# Block entry point
# --------------------------------------------------------------------------- #

def init_cache_specs(cfg, batch: int, max_seq: int) -> dict:
    KV, Dh = cfg.num_kv_heads, cfg.head_dim
    return {
        "k": ParamSpec((batch, max_seq, KV, Dh),
                       ("cache_batch", "cache_seq", "cache_kv",
                        "cache_head_dim")),
        "v": ParamSpec((batch, max_seq, KV, Dh),
                       ("cache_batch", "cache_seq", "cache_kv",
                        "cache_head_dim")),
    }


def attention_forward(params, x, positions, cfg, mode: str,
                      cache: Optional[dict] = None,
                      cache_pos=None) -> Tuple[jax.Array, Optional[dict]]:
    """x: (B, S, d).  mode: 'train' | 'prefill' | 'decode'.

    decode: S == 1; cache holds (B, S_max, KV, Dh) seq-sharded k/v and the
    query position is ``cache_pos`` (scalar int32).
    Returns (out (B, S, d), updated cache or None).
    """
    B, S, d = x.shape
    H, KV, Dh = cfg.num_heads, cfg.num_kv_heads, cfg.head_dim
    G = H // KV

    q = jnp.einsum("bsd,dh->bsh", x, params["wq"]).reshape(B, S, H, Dh)
    k = jnp.einsum("bsd,dh->bsh", x, params["wk"]).reshape(B, S, KV, Dh)
    v = jnp.einsum("bsd,dh->bsh", x, params["wv"]).reshape(B, S, KV, Dh)

    if cfg.qk_norm:
        q = rms_norm(q, params["q_norm"])
        k = rms_norm(k, params["k_norm"])

    q = apply_rope(q, positions, cfg.rope_theta, cfg.mrope_sections)
    k = apply_rope(k, positions, cfg.rope_theta, cfg.mrope_sections)

    new_cache = None
    if mode == "decode":
        assert cache is not None and S == 1
        k_cache = jax.lax.dynamic_update_slice_in_dim(
            cache["k"], k.astype(cache["k"].dtype), cache_pos, axis=1)
        v_cache = jax.lax.dynamic_update_slice_in_dim(
            cache["v"], v.astype(cache["v"].dtype), cache_pos, axis=1)
        k_cache = shard_logical(k_cache, "cache_batch", "cache_seq",
                                "cache_kv", "cache_head_dim")
        v_cache = shard_logical(v_cache, "cache_batch", "cache_seq",
                                "cache_kv", "cache_head_dim")
        o = flash_decode(q, k_cache, v_cache, cache_pos, cfg)
        new_cache = {"k": k_cache, "v": v_cache}
        o = o.reshape(B, S, H * Dh)
    elif cfg.attn_impl == "sp":
        # sequence-parallel: q/k/v stay seq-sharded; KV gathered in-kernel
        q = shard_logical(q, "batch", "act_seq", None, None)
        k = shard_logical(k, "batch", "act_seq", None, None)
        v = shard_logical(v, "batch", "act_seq", None, None)
        o = sp_prefill_attention(q, k, v, cfg)
        o = o.reshape(B, S, H * Dh)
        if mode == "prefill":
            new_cache = {"k": k, "v": v}
    else:
        # Broadcast KV heads to H (Megatron-style when TP > num_kv_heads):
        # q/k/v all (B, S, H, Dh), head axis TP-sharded over 'model'.
        kb = jnp.repeat(k, G, axis=2)
        vb = jnp.repeat(v, G, axis=2)
        q = shard_logical(q, "batch", "act_seq", "act_heads", None)
        kb = shard_logical(kb, "batch", "act_seq", "act_heads", None)
        vb = shard_logical(vb, "batch", "act_seq", "act_heads", None)
        if cfg.attn_impl == "pallas":
            o = _causal_attention_pallas(q, kb, vb)
        else:
            o = _causal_attention_chunked(q, kb, vb, cfg.attn_chunk)
        o = o.reshape(B, S, H * Dh)
        if mode == "prefill":
            new_cache = {"k": k, "v": v}

    out = jnp.einsum("bsh,hd->bsd", o, params["wo"])
    return shard_logical(out, "batch", "act_seq", "act_embed"), new_cache
