"""Unified decoder LM covering all assigned architectures.

One model definition, driven entirely by ArchConfig:
  - per-layer schedule cfg.pattern(): mixer in {attn, ssm} x ffn in
    {dense, moe, none} (jamba interleave, llama4 alternation, ...)
  - layers execute under jax.lax.scan over ``num_repeats`` stacked
    super-blocks of ``pattern_len`` layers — keeps HLO size O(pattern_len)
    regardless of depth (72-layer jamba compiles as 9 scanned repeats of 8)
  - modality frontends (vlm/audio) are precomputed embeddings prepended to
    the token embeddings (stub per assignment)
  - three entry modes: 'train' (loss), 'prefill' (logits + caches),
    'decode' (one token against seq-sharded caches / SSM states)

Params are ParamSpec trees (models/layers.py): the dry-run lowers against
ShapeDtypeStructs without ever allocating 1T-parameter models.
"""
from __future__ import annotations

import math
from typing import Tuple

import jax
import jax.numpy as jnp

from repro.distributed.sharding import shard_logical
from repro.models import attention as attn_mod
from repro.models import mamba2 as ssm_mod
from repro.models import moe as moe_mod
from repro.models.layers import (
    ParamSpec,
    abstract_from_specs,
    activation,
    dense_spec,
    init_from_specs,
    norm,
    norm_spec,
    shardings_from_specs,
    specs_with_leading_stack,
)

# --------------------------------------------------------------------------- #
# Param specs
# --------------------------------------------------------------------------- #


def _ffn_specs(cfg) -> dict:
    d, f = cfg.d_model, cfg.d_ff
    if cfg.activation == "swiglu":
        return {
            "w_gate": dense_spec(d, f, ("embed", "mlp")),
            "w_up": dense_spec(d, f, ("embed", "mlp")),
            "w_down": dense_spec(f, d, ("mlp", "embed")),
        }
    return {
        "w_up": dense_spec(d, f, ("embed", "mlp")),
        "w_down": dense_spec(f, d, ("mlp", "embed")),
    }


def _block_specs(cfg, mixer: str, ffn: str) -> dict:
    specs = {"norm1": norm_spec(cfg)}
    specs["mixer"] = (attn_mod.attn_specs(cfg) if mixer == "attn"
                      else ssm_mod.ssm_specs(cfg))
    if ffn == "dense":
        specs["norm2"] = norm_spec(cfg)
        specs["ffn"] = _ffn_specs(cfg)
    elif ffn == "moe":
        specs["norm2"] = norm_spec(cfg)
        specs["ffn"] = moe_mod.moe_specs(cfg)
    return specs


def padded_vocab(cfg) -> int:
    """Embedding tables pad the vocab up to a TP-shardable multiple (16 =
    the 'model' axis; standard MaxText-style table padding).  Padded logit
    columns are masked to -inf in _logits so they never receive probability
    mass; token ids stay < cfg.vocab_size so gathers are unaffected."""
    m = 16
    return (cfg.vocab_size + m - 1) // m * m


def model_specs(cfg) -> dict:
    d, V = cfg.d_model, padded_vocab(cfg)
    emb_std = 1.0 / math.sqrt(d)
    specs: dict = {}
    # Tied tables serve as the unembedding too: shard their vocab dim over
    # 'model' so the logits matmul emits vocab-sharded logits directly
    # (otherwise XLA materializes full-vocab logits per device and
    # all-gathers their f32 gradient).  Untied input tables stay
    # model-replicated ('vocab_in' -> None) for a cheap lookup.
    vocab_axis = "vocab" if cfg.tie_embeddings else "vocab_in"
    if cfg.num_codebooks > 1:
        specs["embed"] = ParamSpec((cfg.num_codebooks, V, d),
                                   ("codebook", vocab_axis, "embed"),
                                   std=emb_std)
    else:
        specs["embed"] = ParamSpec((V, d), (vocab_axis, "embed"), std=emb_std)
    blocks = {}
    for j, (mixer, ffn) in enumerate(cfg.pattern()):
        blocks[f"i{j}"] = specs_with_leading_stack(
            _block_specs(cfg, mixer, ffn), cfg.num_repeats)
    specs["blocks"] = blocks
    specs["final_norm"] = norm_spec(cfg)
    if not cfg.tie_embeddings:
        if cfg.num_codebooks > 1:
            specs["unembed"] = ParamSpec((cfg.num_codebooks, d, V),
                                         ("codebook", "embed", "vocab"),
                                         std=emb_std)
        else:
            specs["unembed"] = dense_spec(d, V, ("embed", "vocab"))
    return specs


def cache_specs(cfg, batch: int, max_seq: int) -> dict:
    """Stacked per-layer decode caches (leading num_repeats dim)."""
    blocks = {}
    for j, (mixer, _) in enumerate(cfg.pattern()):
        cs = (attn_mod.init_cache_specs(cfg, batch, max_seq)
              if mixer == "attn" else ssm_mod.init_ssm_cache_specs(cfg, batch))
        blocks[f"i{j}"] = specs_with_leading_stack(cs, cfg.num_repeats)
    return blocks


def init_params(cfg, key):
    return init_from_specs(model_specs(cfg), key, cfg.param_dtype)


def abstract_params(cfg):
    return abstract_from_specs(model_specs(cfg), cfg.param_dtype)


def param_shardings(cfg, mesh, rules):
    return shardings_from_specs(model_specs(cfg), mesh, rules)


def init_cache(cfg, batch: int, max_seq: int, dtype=None):
    dt = dtype or cfg.dtype
    return jax.tree_util.tree_map(
        lambda s: jnp.zeros(s.shape, jnp.dtype(s.dtype or dt)),
        cache_specs(cfg, batch, max_seq),
        is_leaf=lambda x: isinstance(x, ParamSpec))


def abstract_cache(cfg, batch: int, max_seq: int, dtype=None):
    dt = dtype or cfg.dtype
    return abstract_from_specs(cache_specs(cfg, batch, max_seq), dt)


def cache_shardings(cfg, batch: int, max_seq: int, mesh, rules):
    return shardings_from_specs(cache_specs(cfg, batch, max_seq), mesh, rules)


# --------------------------------------------------------------------------- #
# Forward
# --------------------------------------------------------------------------- #


def _embed_tokens(params, tokens, cfg):
    emb = params["embed"]
    if cfg.num_codebooks > 1:
        # tokens: (B, S, C); sum codebook embeddings (MusicGen)
        parts = [emb[c][tokens[..., c]] for c in range(cfg.num_codebooks)]
        x = sum(parts)
    else:
        x = emb[tokens]
    return x.astype(cfg.dtype)


def _block_forward(bparams, x, positions, cfg, mixer, ffn, mode,
                   cache, cache_pos):
    h = norm(x, bparams["norm1"], cfg)
    if mixer == "attn":
        y, new_cache = attn_mod.attention_forward(
            bparams["mixer"], h, positions, cfg, mode, cache, cache_pos)
    else:
        y, new_cache = ssm_mod.ssm_forward(bparams["mixer"], h, cfg, mode,
                                           cache)
    x = x + y
    lb = jnp.zeros((), jnp.float32)
    z = jnp.zeros((), jnp.float32)
    if ffn != "none":
        h = norm(x, bparams["norm2"], cfg)
        if ffn == "moe":
            y, lb, z = moe_mod.moe_forward(bparams["ffn"], h, cfg)
        else:
            p = bparams["ffn"]
            up = jnp.einsum("bsd,df->bsf", h, p["w_up"])
            if cfg.activation == "swiglu":
                gate = jnp.einsum("bsd,df->bsf", h, p["w_gate"])
                a = jax.nn.silu(gate) * up
            else:
                a = activation(up, cfg.activation)
            a = shard_logical(a, "batch", "act_seq", "act_mlp")
            y = jnp.einsum("bsf,fd->bsd", a, p["w_down"])
            y = shard_logical(y, "batch", "act_seq", "act_embed")
        x = x + y
    return x, new_cache, lb, z


def _stack_forward(params, x, positions, cfg, mode: str,
                   caches=None, cache_pos=None):
    """Scan over num_repeats super-blocks."""
    pattern = cfg.pattern()

    def body(carry, xs):
        x, lb_sum, z_sum = carry
        bparams, bcaches = xs
        new_caches = {}
        for j, (mixer, ffn) in enumerate(pattern):
            cache_j = None if bcaches is None else bcaches[f"i{j}"]
            x, nc, lb, z = _block_forward(
                bparams[f"i{j}"], x, positions, cfg, mixer, ffn, mode,
                cache_j, cache_pos)
            new_caches[f"i{j}"] = nc
            lb_sum = lb_sum + lb
            z_sum = z_sum + z
        if all(v is None for v in new_caches.values()):
            new_caches = None
        return (x, lb_sum, z_sum), new_caches

    if cfg.remat and mode == "train":
        body = jax.checkpoint(body,
                              policy=jax.checkpoint_policies.nothing_saveable)

    zero = jnp.zeros((), jnp.float32)
    if cfg.scan_layers:
        (x, lb, z), new_caches = jax.lax.scan(
            body, (x, zero, zero), (params["blocks"], caches))
        return x, new_caches, lb, z

    # Unrolled path (used by the dry-run's per-layer cost extrapolation and
    # available as a perf knob: unrolling exposes cross-layer overlap to XLA).
    carry = (x, zero, zero)
    cache_list = []
    for r in range(cfg.num_repeats):
        bparams = jax.tree_util.tree_map(lambda a: a[r], params["blocks"])
        bcaches = (None if caches is None else
                   jax.tree_util.tree_map(lambda a: a[r], caches))
        carry, nc = body(carry, (bparams, bcaches))
        cache_list.append(nc)
    (x, lb, z) = carry
    if cache_list and cache_list[0] is not None:
        new_caches = jax.tree_util.tree_map(
            lambda *xs: jnp.stack(xs), *cache_list)
    else:
        new_caches = None
    return x, new_caches, lb, z


def _logits(params, x, cfg):
    if cfg.tie_embeddings:
        emb = params["embed"]
        if cfg.num_codebooks > 1:
            logits = jnp.einsum("bsd,cvd->bscv", x, emb)
        else:
            logits = jnp.einsum("bsd,vd->bsv", x, emb)
    else:
        if cfg.num_codebooks > 1:
            logits = jnp.einsum("bsd,cdv->bscv", x, params["unembed"])
        else:
            logits = jnp.einsum("bsd,dv->bsv", x, params["unembed"])
    V_pad = logits.shape[-1]
    if V_pad != cfg.vocab_size:
        # mask padded columns: never any probability mass, argmax-safe
        iota = jax.lax.broadcasted_iota(jnp.int32, logits.shape,
                                        logits.ndim - 1)
        logits = jnp.where(iota < cfg.vocab_size, logits,
                           jnp.asarray(-1e30, logits.dtype))
    if cfg.num_codebooks > 1:
        return shard_logical(logits, "batch", "act_seq", None, "act_vocab")
    return shard_logical(logits, "batch", "act_seq", "act_vocab")


def forward(params, batch, cfg, mode: str, caches=None, cache_pos=None):
    """Returns (logits, new_caches, lb_loss, z_loss).

    batch keys: 'tokens' (B,S[,C]); optional 'positions' ((B,S) or (3,B,S));
    optional 'frontend' (B,F,d_model) precomputed modality embeddings.
    """
    tokens = batch["tokens"]
    x = _embed_tokens(params, tokens, cfg)
    if cfg.frontend != "none" and "frontend" in batch:
        fe = batch["frontend"].astype(cfg.dtype)
        x = jnp.concatenate([fe, x], axis=1)
    B, S = x.shape[0], x.shape[1]
    if "positions" in batch:
        positions = batch["positions"]
    elif mode == "decode":
        shape = (3, B, 1) if cfg.mrope_sections else (B, 1)
        positions = jnp.full(shape, cache_pos, jnp.int32)
    else:
        positions = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32), (B, S))
    x = shard_logical(x, "batch", "act_seq", "act_embed")

    x, new_caches, lb, z = _stack_forward(
        params, x, positions, cfg, mode, caches, cache_pos)

    x = norm(x, params["final_norm"], cfg)
    logits = _logits(params, x, cfg)
    return logits, new_caches, lb, z


# --------------------------------------------------------------------------- #
# Losses / steps
# --------------------------------------------------------------------------- #

LB_COEF = 0.01
Z_COEF = 1e-3


def loss_fn(params, batch, cfg) -> Tuple[jax.Array, dict]:
    """Causal-LM loss.  batch: tokens, labels (B,S[,C]), loss_mask (B,S)."""
    logits, _, lb, z = forward(params, batch, cfg, "train")
    labels = batch["labels"]
    mask = batch["loss_mask"].astype(jnp.float32)

    # Fused one-hot label pick: take_along_axis would gather over the
    # vocab-sharded logits (forcing an all-gather of the full logits);
    # compare+select+reduce stays local per vocab shard and fuses.
    iota = jax.lax.broadcasted_iota(jnp.int32, logits.shape,
                                    logits.ndim - 1)
    picked = jnp.where(iota == labels[..., None],
                       logits.astype(jnp.float32), 0.0)
    lab_logit = jnp.sum(picked, axis=-1)
    lse = jax.nn.logsumexp(logits.astype(jnp.float32), axis=-1)
    if cfg.num_codebooks > 1:
        ce = (lse - lab_logit).mean(-1)                      # mean codebooks
    else:
        # frontend positions carry no labels: logits were computed for
        # frontend+token positions; labels/mask are sized to match.
        ce = lse - lab_logit
    denom = jnp.maximum(mask.sum(), 1.0)
    ce = (ce * mask).sum() / denom
    total = ce + LB_COEF * lb + Z_COEF * z
    return total, {"ce": ce, "lb": lb, "z": z}


def prefill_step(params, batch, cfg):
    logits, caches, _, _ = forward(params, batch, cfg, "prefill")
    return logits, caches


def decode_step(params, batch, cfg, caches, cache_pos):
    logits, new_caches, _, _ = forward(params, batch, cfg, "decode",
                                       caches, cache_pos)
    return logits, new_caches
