"""Fault tolerance for 1000+-node runs: crash-restart, elastic rescale,
straggler detection.

What runs where:
  - ``ResilientTrainer`` wraps any (state, batch) -> (state, metrics) step
    with periodic async checkpointing (checkpoint/ckpt.py), SIGTERM-drain
    (preemption saves a final checkpoint before exit), and
    restore-on-restart.  This is the per-process control loop a pod
    scheduler (Borg/K8s) supervises; a node failure means the replacement
    process restarts from the newest complete checkpoint.
  - ``rescale_state`` implements elastic scaling: checkpoints are
    mesh-agnostic host arrays, so resuming on a different device count is
    device_put against the new mesh's shardings.  The data pipeline splits
    by ``shard_range(n, host, n_hosts)`` and the global batch stays fixed,
    so changing pod count changes per-host batch, not semantics.
  - ``StragglerMonitor`` tracks per-step wall times; a host whose EWMA
    exceeds ``threshold`` x the median is flagged.  On TPU pods the
    mitigation is re-slicing the i.i.d. clip stream (smaller shard to the
    slow host) — ``rebalance`` computes those weights.  (Synchronous SPMD
    collectives make *compute* stragglers rare; the realistic straggler is
    input-bound, which is exactly what re-slicing the data shard fixes.)
"""
from __future__ import annotations

import dataclasses
import signal
import time
from typing import Callable, Dict, List

import jax
import numpy as np

from repro.checkpoint.ckpt import CheckpointManager


# --------------------------------------------------------------------------- #
# Crash-restart training loop
# --------------------------------------------------------------------------- #

@dataclasses.dataclass
class ResilientTrainer:
    step_fn: Callable                     # (state, batch) -> (state, metrics)
    ckpt: CheckpointManager
    save_every: int = 100
    log_every: int = 25
    log_fn: Callable[[int, Dict], None] = lambda step, m: None

    _preempted: bool = dataclasses.field(default=False, init=False)
    _prev_handlers: Dict = dataclasses.field(default_factory=dict,
                                             init=False, repr=False)

    # both schedulers' preemption signals: K8s/Borg send SIGTERM, an
    # operator (or a tty) sends SIGINT — either way the right move is a
    # drain-checkpoint, not an unclean death
    _SIGNALS = (signal.SIGTERM, signal.SIGINT)

    def install_signal_handler(self) -> None:
        """Install drain-on-preemption handlers for SIGTERM *and* SIGINT.

        The previous handlers are chained, not clobbered: a launcher
        that already registered its own SIGTERM hook (log flushing, lock
        release) still runs it.  ``uninstall_signal_handler`` restores
        the pre-install handlers; ``run`` does so automatically on exit
        so a trainer's handlers never outlive its loop.
        """
        if self._prev_handlers:
            return                                  # already installed
        for sig in self._SIGNALS:
            prev = signal.getsignal(sig)

            def _handler(signum, frame, _prev=prev):
                self._preempted = True
                # chain custom hooks only: SIG_DFL/SIG_IGN aren't
                # callable, and the default SIGINT handler would raise
                # KeyboardInterrupt — the unclean death this exists to
                # replace
                if callable(_prev) and _prev is not \
                        signal.default_int_handler:
                    _prev(signum, frame)
            self._prev_handlers[sig] = prev
            signal.signal(sig, _handler)

    def uninstall_signal_handler(self) -> None:
        """Restore the handlers that were active before install (no-op
        if never installed)."""
        while self._prev_handlers:
            sig, prev = self._prev_handlers.popitem()
            signal.signal(sig, prev)

    def run(self, state, batch_iter, *, start_step: int = 0,
            total_steps: int = 1000, state_like=None, shardings=None):
        """Resumes from the latest checkpoint if one exists."""
        restored, ck_step = self.ckpt.restore_latest(
            state_like if state_like is not None else state,
            shardings=shardings)
        if restored is not None:
            state, start_step = restored, ck_step
        step = start_step
        installed_here = not self._prev_handlers
        if installed_here:
            self.install_signal_handler()
        try:
            for batch in batch_iter:
                if step >= total_steps or self._preempted:
                    break
                state, metrics = self.step_fn(state, batch)
                step += 1
                if step % self.log_every == 0:
                    self.log_fn(step,
                                jax.tree_util.tree_map(float, metrics))
                if step % self.save_every == 0:
                    self.ckpt.save(state, step)
            # drain: final checkpoint on preemption or completion
            self.ckpt.save(state, step)
            self.ckpt.wait()
        finally:
            if installed_here:
                self.uninstall_signal_handler()
        return state, step


# --------------------------------------------------------------------------- #
# Elastic rescale
# --------------------------------------------------------------------------- #

def rescale_state(host_state, new_shardings):
    """Re-shard a host-array state tree onto a (differently sized) mesh.

    Checkpoints store plain numpy; placing them under the new mesh's
    NamedShardings is all that elastic scale-up/down requires, because
    every sharding in this framework is expressed logically (rules), not
    by device index.
    """
    return jax.tree_util.tree_map(
        lambda a, s: jax.device_put(np.asarray(a), s),
        host_state, new_shardings)


# --------------------------------------------------------------------------- #
# Straggler detection / mitigation
# --------------------------------------------------------------------------- #

class StragglerMonitor:
    """EWMA step-time tracking per host; flags and re-balances outliers."""

    def __init__(self, n_hosts: int, alpha: float = 0.2,
                 threshold: float = 1.5):
        self.n_hosts = n_hosts
        self.alpha = alpha
        self.threshold = threshold
        self.ewma = np.zeros(n_hosts)
        self._seen = np.zeros(n_hosts, bool)

    def record(self, host: int, seconds: float) -> None:
        if not self._seen[host]:
            self.ewma[host] = seconds
            self._seen[host] = True
        else:
            self.ewma[host] = (self.alpha * seconds +
                               (1 - self.alpha) * self.ewma[host])

    def stragglers(self) -> List[int]:
        if not self._seen.any():
            return []
        med = float(np.median(self.ewma[self._seen]))
        return [h for h in range(self.n_hosts)
                if self._seen[h] and self.ewma[h] > self.threshold * med]

    def rebalance(self) -> np.ndarray:
        """Per-host data-shard weights inversely proportional to step time
        (normalized to sum to n_hosts).  Hosts at weight 1.0 keep their
        shard; a 2x-slow host gets ~0.5x the clips."""
        if not self._seen.all():
            return np.ones(self.n_hosts)
        inv = 1.0 / np.maximum(self.ewma, 1e-9)
        return inv * (self.n_hosts / inv.sum())


def timed_step(step_fn):
    """Wraps a jitted step to also return wall seconds (blocks on result)."""
    def wrapped(state, batch):
        t0 = time.time()
        state, metrics = step_fn(state, batch)
        jax.block_until_ready(metrics)
        return state, metrics, time.time() - t0
    return wrapped
