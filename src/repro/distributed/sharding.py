"""Logical-axis sharding rules (MaxText-style) for the whole framework.

Model code never names mesh axes directly.  Params and activations carry
*logical* axis names; a rules table maps logical names -> mesh axes per
execution mode (train/prefill vs decode).  This is what lets one model
definition serve a (16,16) single-pod mesh, a (2,16,16) multi-pod mesh and a
(1,1)/(1,1,1) CPU test mesh without edits.

Mesh axes (see launch/mesh.py):
    'pod'    inter-pod data parallelism (multi-pod only)
    'data'   intra-pod data parallelism + FSDP weight sharding
    'model'  tensor / expert parallelism

Conventions:
    - weight axes: 'embed' (d_model rows, FSDP over 'data'), 'qkv' (fused
      query head dim, TP), 'kv' (kv head dim; small under GQA -> replicated),
      'mlp' (FFN hidden, TP), 'expert' (MoE expert dim, EP), 'vocab'
      (unembedding columns, TP), 'layers' (scan-stacked repeats, never sharded)
    - activation axes: 'batch', 'act_seq', 'act_embed', 'act_heads', ...
    - decode caches: 'cache_batch', 'cache_seq' (seq-sharded flash-decoding),
      'cache_kv', 'cache_head_dim'
"""
from __future__ import annotations

import contextlib
import threading
from typing import Optional, Sequence, Tuple

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

Rules = Tuple[Tuple[str, Optional[Tuple[str, ...]]], ...]


def _norm(rules) -> Rules:
    out = []
    for name, axes in rules:
        if axes is None:
            out.append((name, None))
        elif isinstance(axes, str):
            out.append((name, (axes,)))
        else:
            out.append((name, tuple(axes)))
    return tuple(out)


# --------------------------------------------------------------------------- #
# Rule tables
# --------------------------------------------------------------------------- #

LOGICAL_RULES_TRAIN: Rules = _norm([
    # activations
    ("batch", ("pod", "data")),
    ("act_seq", None),
    ("act_embed", None),
    ("act_heads", "model"),
    ("act_mlp", "model"),
    ("act_ssm", "model"),
    ("act_vocab", "model"),
    # weights: FSDP over 'data' on the d_model rows, TP over 'model'
    ("embed", "data"),
    ("vocab", "model"),
    ("vocab_in", None),
    ("qkv", "model"),
    ("kv", None),
    ("mlp", "model"),
    ("expert", "model"),
    ("expert_mlp", None),
    ("ssm_inner", "model"),
    ("ssm_heads", "model"),
    ("ssm_state", None),
    ("conv_dim", "model"),
    ("layers", None),
    ("codebook", None),
    # decode caches (unused in train but kept total)
    ("cache_batch", ("pod", "data")),
    ("cache_seq", None),
    ("cache_kv", None),
    ("cache_head_dim", None),
])

# Decode: KV caches are sequence-sharded over 'model' (flash-decoding);
# SSM states are head-sharded.  Weights are TP-sharded but NOT FSDP'd
# ('embed' -> None): decode is latency-critical and re-gathering
# FSDP-sharded weights every token step cost ~89 MB/layer of all-gather
# on the dry-run (§Perf cell D) — resident weights cost 2-3 GB HBM and
# eliminate it.
LOGICAL_RULES_DECODE: Rules = _norm([
    ("batch", ("pod", "data")),
    ("act_seq", None),
    ("act_embed", None),
    ("act_heads", "model"),
    ("act_mlp", "model"),
    ("act_ssm", "model"),
    ("act_vocab", "model"),
    ("embed", None),
    ("vocab", "model"),
    ("vocab_in", None),
    ("qkv", "model"),
    ("kv", None),
    ("mlp", "model"),
    ("expert", "model"),
    ("expert_mlp", None),
    ("ssm_inner", "model"),
    ("ssm_heads", "model"),
    ("ssm_state", None),
    ("conv_dim", "model"),
    ("layers", None),
    ("codebook", None),
    ("cache_batch", ("pod", "data")),
    ("cache_seq", "model"),
    ("cache_kv", None),
    ("cache_head_dim", None),
])

# Long-context decode (global_batch smaller than the DP axes, e.g. the
# 500k-token single-sequence cells): no batch sharding; the KV cache / score
# sequence dim shards over the WHOLE mesh (sequence parallelism), so a 512-chip
# multi-pod mesh holds 1024 tokens of cache per chip.
LOGICAL_RULES_DECODE_LONG: Rules = tuple(
    (name, (("pod", "data", "model") if name == "cache_seq" else
            (None if name in ("batch", "cache_batch") else axes)))
    for name, axes in LOGICAL_RULES_DECODE
)


# ZeRO-3-across-pods variant (§Perf B4): identical to the train table but
# weight rows also shard over 'pod', halving resident state per chip on the
# multi-pod mesh (gathers cross the DCN boundary — viable with prefetch,
# and the only way a 400B+bf16-momentum state fits 16 GB chips).
LOGICAL_RULES_TRAIN_ZERO3: Rules = tuple(
    (name, (("pod", "data") if name == "embed" else axes))
    for name, axes in _norm([
        ("batch", ("pod", "data")),
        ("act_seq", None), ("act_embed", None), ("act_heads", "model"),
        ("act_mlp", "model"), ("act_ssm", "model"), ("act_vocab", "model"),
        ("embed", "data"), ("vocab", "model"), ("vocab_in", None),
        ("qkv", "model"), ("kv", None), ("mlp", "model"),
        ("expert", "model"), ("expert_mlp", None),
        ("ssm_inner", "model"), ("ssm_heads", "model"), ("ssm_state", None),
        ("conv_dim", "model"), ("layers", None), ("codebook", None),
        ("cache_batch", ("pod", "data")), ("cache_seq", None),
        ("cache_kv", None), ("cache_head_dim", None),
    ])
)

# Beyond-paper perf variant (§Perf iteration 1 for dense-train cells):
# pure ZeRO/FSDP — the batch shards over EVERY mesh axis (256-way DP on a
# pod) and weights shard over ('data','model') on their d_model rows with
# NO tensor parallelism.  Per-device FLOPs are identical to FSDP+TP, but
# the per-layer collectives drop from 4-6 activation all-reduces
# (O(B_loc*S*d) each) + weight gathers to ONE weight all-gather + one grad
# reduce-scatter (O(params_layer)); at train_4k sizes that is ~10x less
# wire.  Requires global_batch % chips == 0 (256 on the single pod).
LOGICAL_RULES_TRAIN_FSDP: Rules = _norm([
    ("batch", ("pod", "data", "model")),
    ("act_seq", None), ("act_embed", None), ("act_heads", None),
    ("act_mlp", None), ("act_ssm", None), ("act_vocab", None),
    ("embed", ("data", "model")),
    ("vocab", None), ("vocab_in", None),
    ("qkv", None), ("kv", None), ("mlp", None),
    ("expert", "model"),            # MoE keeps EP over 'model'
    ("expert_mlp", None),
    ("ssm_inner", None), ("ssm_heads", None), ("ssm_state", None),
    ("conv_dim", None), ("layers", None), ("codebook", None),
    ("cache_batch", ("pod", "data", "model")),
    ("cache_seq", None), ("cache_kv", None), ("cache_head_dim", None),
])

# Sequence-parallel prefill (§Perf cell E): the residual stream shards its
# SEQUENCE over 'model' — no tensor parallelism.  FFNs/norms become purely
# local; attention (models/attention.sp_prefill_attention) all-gathers the
# small GQA K/V heads per layer (O(S*KV*Dh)) instead of TP's O(B*S*d)
# all-reduces.  Weights shard over ('data','model') rows for storage and
# are gathered per layer.  Bonus: emitted KV caches are already in the
# decode layout (cache_seq='model') — no prefill->decode reshard.
LOGICAL_RULES_PREFILL_SP: Rules = _norm([
    ("batch", ("pod", "data")),
    ("act_seq", "model"),
    ("act_embed", None), ("act_heads", None), ("act_mlp", None),
    ("act_ssm", None), ("act_vocab", None),
    ("embed", ("data", "model")),
    ("vocab", None), ("vocab_in", None),
    ("qkv", None), ("kv", None), ("mlp", None),
    ("expert", "model"), ("expert_mlp", None),
    ("ssm_inner", None), ("ssm_heads", None), ("ssm_state", None),
    ("conv_dim", None), ("layers", None), ("codebook", None),
    ("cache_batch", ("pod", "data")),
    ("cache_seq", "model"),
    ("cache_kv", None), ("cache_head_dim", None),
])

# CAPSim predictor: ~2M params -> weights replicate everywhere; the clip
# batch is i.i.d. and shards over EVERY mesh axis (the paper's clip-level
# parallelism).  Gradient all-reduce of ~8 MB fp32 over 512 chips is noise.
LOGICAL_RULES_PREDICTOR: Rules = _norm([
    ("batch", ("pod", "data", "model")),
    ("act_seq", None), ("act_embed", None), ("act_heads", None),
    ("act_mlp", None), ("act_vocab", None),
    ("embed", None), ("vocab", None), ("vocab_in", None),
    ("qkv", None), ("kv", None), ("mlp", None),
    ("layers", None),
])


# --------------------------------------------------------------------------- #
# Context: active mesh + rules
# --------------------------------------------------------------------------- #

class _Ctx(threading.local):
    mesh: Optional[Mesh] = None
    rules: Optional[Rules] = None


_CTX = _Ctx()


@contextlib.contextmanager
def use_mesh_and_rules(mesh: Optional[Mesh], rules: Optional[Rules]):
    """Activate (mesh, rules) for logical-axis constraint resolution."""
    prev = (_CTX.mesh, _CTX.rules)
    _CTX.mesh, _CTX.rules = mesh, _norm(rules) if rules else None
    try:
        yield
    finally:
        _CTX.mesh, _CTX.rules = prev


def current_mesh() -> Optional[Mesh]:
    return _CTX.mesh


def current_rules() -> Optional[Rules]:
    return _CTX.rules


def axis_rules(logical_axes: Sequence[Optional[str]],
               rules: Optional[Rules] = None,
               mesh: Optional[Mesh] = None) -> P:
    """Map a tuple of logical axis names to a PartitionSpec.

    A mesh axis is consumed at most once per spec (first logical axis wins);
    mesh axes absent from the mesh (e.g. 'pod' on a single-pod mesh) are
    dropped; axes whose size does not divide the dimension are dropped by
    XLA later, so no check here.
    """
    rules = rules if rules is not None else (_CTX.rules or ())
    mesh = mesh if mesh is not None else _CTX.mesh
    table = dict(rules)
    mesh_axis_names = set(mesh.axis_names) if mesh is not None else None
    used = set()
    spec = []
    for name in logical_axes:
        if name is None:
            spec.append(None)
            continue
        axes = table.get(name)
        if axes is None:
            spec.append(None)
            continue
        picked = []
        for ax in axes:
            if mesh_axis_names is not None and ax not in mesh_axis_names:
                continue
            if ax in used:
                continue
            picked.append(ax)
            used.add(ax)
        spec.append(tuple(picked) if len(picked) > 1 else (picked[0] if picked else None))
    return P(*spec)


def compat_shard_map(f, *, mesh, in_specs, out_specs,
                     check_vma: bool = False):
    """``jax.shard_map`` across jax versions: top-level with ``check_vma``
    on >= 0.6, ``jax.experimental.shard_map`` with the older ``check_rep``
    spelling before that."""
    if hasattr(jax, "shard_map"):
        return jax.shard_map(f, mesh=mesh, in_specs=in_specs,
                             out_specs=out_specs, check_vma=check_vma)
    from jax.experimental.shard_map import shard_map as _shard_map
    return _shard_map(f, mesh=mesh, in_specs=in_specs,
                      out_specs=out_specs, check_rep=check_vma)


def logical_sharding(logical_axes: Sequence[Optional[str]],
                     mesh: Optional[Mesh] = None,
                     rules: Optional[Rules] = None) -> NamedSharding:
    mesh = mesh if mesh is not None else _CTX.mesh
    assert mesh is not None, "no active mesh; wrap in use_mesh_and_rules(...)"
    return NamedSharding(mesh, axis_rules(logical_axes, rules=rules, mesh=mesh))


def shard_logical(x, *logical_axes):
    """with_sharding_constraint by logical axis names (no-op without a mesh)."""
    if _CTX.mesh is None or _CTX.rules is None:
        return x
    spec = axis_rules(logical_axes)
    # Drop constraints that do not divide the dimension (tiny smoke meshes).
    sizes = dict(zip(_CTX.mesh.axis_names, _CTX.mesh.devices.shape))
    fixed = []
    for dim, entry in zip(x.shape, spec):
        if entry is None:
            fixed.append(None)
            continue
        axes = entry if isinstance(entry, tuple) else (entry,)
        total = 1
        for ax in axes:
            total *= sizes[ax]
        fixed.append(entry if (total and dim % total == 0) else None)
    return jax.lax.with_sharding_constraint(
        x, NamedSharding(_CTX.mesh, P(*fixed)))
