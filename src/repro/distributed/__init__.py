from repro.distributed.sharding import (  # noqa: F401
    LOGICAL_RULES_TRAIN,
    LOGICAL_RULES_DECODE,
    LOGICAL_RULES_DECODE_LONG,
    axis_rules,
    current_mesh,
    current_rules,
    logical_sharding,
    shard_logical,
    use_mesh_and_rules,
)
