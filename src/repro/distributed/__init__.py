from repro.distributed.sharding import (  # noqa: F401
    LOGICAL_RULES_DECODE,
    LOGICAL_RULES_DECODE_LONG,
    LOGICAL_RULES_TRAIN,
    axis_rules,
    current_mesh,
    current_rules,
    logical_sharding,
    shard_logical,
    use_mesh_and_rules,
)
