"""Gradient compression with error feedback.

At 1000+ node scale the inter-pod (DCN) gradient all-reduce dominates step
time for DP-heavy meshes.  The standard mitigation is lossy gradient
compression with an error-feedback buffer (1-bit Adam / PowerSGD lineage).
We implement int8 per-tensor-scaled quantization:

    q = round(g / s),  s = max|g| / 127        (int8 wire format)
    e' = g - s*q                               (residual fed back next step)

On a real multi-pod deployment the int8 payload is what crosses the DCN
boundary (the all-reduce runs on the quantized tensor + fp32 scale); in this
framework the quantize->dequantize pair is applied to the gradients right
before the optimizer, so convergence behavior (the part that needs testing)
is exactly what production would see, and the wire-format saving is 4x
(fp32->int8) / 2x (bf16->int8) recorded in the roofline collective term.
"""
from __future__ import annotations

from typing import Any, Tuple

import jax
import jax.numpy as jnp


def init_error_feedback(params) -> Any:
    return jax.tree_util.tree_map(
        lambda p: jnp.zeros(p.shape, jnp.float32), params)


def compress_decompress(grads, error_fb) -> Tuple[Any, Any]:
    """Returns (effective_grads, new_error_fb)."""

    def one(g, e):
        g = g.astype(jnp.float32) + e
        scale = jnp.max(jnp.abs(g)) / 127.0
        scale = jnp.maximum(scale, 1e-12)
        q = jnp.clip(jnp.round(g / scale), -127, 127).astype(jnp.int8)
        deq = q.astype(jnp.float32) * scale
        return deq, g - deq

    out = jax.tree_util.tree_map(one, grads, error_fb)
    eff = jax.tree_util.tree_map(lambda o: o[0], out,
                                 is_leaf=lambda x: isinstance(x, tuple))
    new_e = jax.tree_util.tree_map(lambda o: o[1], out,
                                   is_leaf=lambda x: isinstance(x, tuple))
    return eff, new_e
