"""CAPSim attention performance predictor — the paper's own model (§V, Fig 4).

E=128 embeddings, 4-head MHA, 4 instruction-encoder layers + 4 block-encoder
layers, MLP head with arithmetic mean (paper §VI-B).  "seq_len" in its shapes
is the clip length L_clip; batch is clips per step.  Context matrix: Table I
register file -> (name token + byte-pair value tokens) rows.
"""
from repro.configs import CAPSIM_SHAPES, ArchConfig
from repro.core.context import CONTEXT_LEN


def config() -> ArchConfig:
    return ArchConfig(
        name="capsim",
        family="predictor",
        num_layers=8,                 # 4 instruction-encoder + 4 block-encoder
        d_model=128,                  # E
        num_heads=4,
        num_kv_heads=4,
        head_dim=32,
        d_ff=512,
        vocab_size=512,               # standardized-token vocab is 383 (incl.
                                      # the <CORE> channel token); padded to
                                      # 512 for clean TPU lane tiling
        clip_tokens=16,               # L_token: max standardized length is 14
        context_tokens=CONTEXT_LEN,   # M = 40 registers x (1 name + 8 value
                                      # tokens); multicore layouts widen M
                                      # at the data level (context.py)
        shape_names=tuple(CAPSIM_SHAPES),
        skipped_shapes=(),
        skip_reason="",
        dtype="bfloat16",
        param_dtype="float32",
    )


def smoke_config() -> ArchConfig:
    return config().replace(
        d_model=32, num_heads=2, head_dim=16, d_ff=64, vocab_size=256,
        clip_tokens=16, context_tokens=36,
        dtype="float32", param_dtype="float32", remat=False,
    )
