"""Nemotron-4-15B — dense, GQA kv=8, squared-ReLU MLP.

[arXiv:2402.16819; unverified].  32L, d_model=6144, 48 heads (head_dim 128),
d_ff=24576 with squared-ReLU (2-matrix MLP, no gate), vocab 256000.
"""
from repro.configs import ArchConfig


def config() -> ArchConfig:
    return ArchConfig(
        name="nemotron-4-15b",
        family="dense",
        num_layers=32,
        d_model=6144,
        num_heads=48,
        num_kv_heads=8,
        head_dim=128,
        d_ff=24576,
        vocab_size=256000,
        activation="squared_relu",
        rope_theta=10_000.0,
    )


def smoke_config() -> ArchConfig:
    return config().replace(
        num_layers=2, d_model=64, num_heads=4, num_kv_heads=2, head_dim=16,
        d_ff=128, vocab_size=256,
        dtype="float32", param_dtype="float32", remat=False, attn_chunk=32,
    )
