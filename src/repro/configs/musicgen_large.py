"""MusicGen-Large — decoder-only over EnCodec tokens, 4 codebooks.

[arXiv:2306.05284; hf].  48L, d_model=2048, 32 heads MHA (head_dim 64),
d_ff=8192 GELU, vocab 2048 per codebook, 4 parallel codebooks (delay pattern).
Token input is (B, S, 4); codebook embeddings are summed, and 4 output heads
predict the next token of each codebook.  The text/melody conditioning
frontend is a STUB: ``input_specs()`` supplies precomputed conditioning
frames (frontend_len=64) prepended to the sequence.
"""
from repro.configs import ArchConfig


def config() -> ArchConfig:
    return ArchConfig(
        name="musicgen-large",
        family="audio",
        num_layers=48,
        d_model=2048,
        num_heads=32,
        num_kv_heads=32,
        head_dim=64,
        d_ff=8192,
        vocab_size=2048,
        activation="gelu",
        rope_theta=10_000.0,
        frontend="audio",
        frontend_len=64,
        num_codebooks=4,
    )


def smoke_config() -> ArchConfig:
    return config().replace(
        num_layers=2, d_model=64, num_heads=4, num_kv_heads=4, head_dim=16,
        d_ff=128, vocab_size=128, frontend_len=8, num_codebooks=4,
        dtype="float32", param_dtype="float32", remat=False, attn_chunk=32,
    )
