"""Llama-4 Maverick (400B total / 17B active) — MoE 128 experts top-1, early fusion.

[hf:meta-llama/Llama-4-Scout-17B-16E; unverified].  48L, d_model=5120,
40 heads (head_dim 128), GQA kv=8, d_ff=8192, vocab 202048.  MoE interleaved
every other layer (interleave_moe_layer_step=2), top-1 routing.
"""
from repro.configs import ArchConfig


def config() -> ArchConfig:
    return ArchConfig(
        name="llama4-maverick-400b-a17b",
        family="moe",
        num_layers=48,
        d_model=5120,
        num_heads=40,
        num_kv_heads=8,
        head_dim=128,
        d_ff=8192,
        vocab_size=202048,
        num_experts=128,
        experts_per_token=1,
        moe_every=2,
        moe_offset=1,
        pattern_len=2,
        activation="swiglu",
        rope_theta=500_000.0,
    )


def smoke_config() -> ArchConfig:
    return config().replace(
        num_layers=2, d_model=64, num_heads=4, num_kv_heads=2, head_dim=16,
        d_ff=64, vocab_size=256, num_experts=4, experts_per_token=1,
        pattern_len=2,
        dtype="float32", param_dtype="float32", remat=False, attn_chunk=32,
    )
