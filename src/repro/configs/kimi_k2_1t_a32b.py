"""Kimi-K2 (1T total / 32B active) — trillion-parameter MoE, 384 experts top-8.

[arXiv:2501.kimi2; unverified, paper-table].  61L, d_model=7168, 64 heads
(head_dim 112), GQA kv=8, per-expert d_ff=2048, vocab 163840.  Every layer is
MoE (384 routed experts, top-8).
"""
from repro.configs import ArchConfig


def config() -> ArchConfig:
    return ArchConfig(
        name="kimi-k2-1t-a32b",
        family="moe",
        num_layers=61,
        d_model=7168,
        num_heads=64,
        num_kv_heads=8,
        head_dim=112,
        d_ff=2048,
        vocab_size=163840,
        num_experts=384,
        experts_per_token=8,
        moe_every=1,
        activation="swiglu",
        rope_theta=50_000.0,
    )


def smoke_config() -> ArchConfig:
    return config().replace(
        num_layers=2, d_model=64, num_heads=4, num_kv_heads=2, head_dim=16,
        d_ff=32, vocab_size=256, num_experts=8, experts_per_token=2,
        dtype="float32", param_dtype="float32", remat=False, attn_chunk=32,
    )
