"""Jamba-1.5-Large (398B) — hybrid Mamba+attention 1:7 interleave, MoE 16e top-2.

[arXiv:2403.19887; hf].  72 layers in 9 super-blocks of 8: attention at
in-block index 4, Mamba elsewhere; MoE FFN on every other layer.  Runs
``long_500k`` (sub-quadratic SSM majority; the few attention layers decode
against a KV cache, which is O(S) per emitted token).
"""
from repro.configs import ArchConfig


def config() -> ArchConfig:
    return ArchConfig(
        name="jamba-1.5-large-398b",
        family="hybrid",
        num_layers=72,
        d_model=8192,
        num_heads=64,
        num_kv_heads=8,
        head_dim=128,
        d_ff=24576,
        vocab_size=65536,
        num_experts=16,
        experts_per_token=2,
        moe_every=2,
        moe_offset=1,
        ssm_state=128,
        ssm_head_dim=64,
        ssm_expand=2,
        attn_every=8,
        attn_offset=4,
        pattern_len=8,
        activation="swiglu",
        shape_names=("train_4k", "prefill_32k", "decode_32k", "long_500k"),
        skipped_shapes=(),
        skip_reason="",
    )


def smoke_config() -> ArchConfig:
    return config().replace(
        num_layers=8, d_model=64, num_heads=4, num_kv_heads=2, head_dim=16,
        d_ff=128, vocab_size=256, num_experts=4, experts_per_token=2,
        ssm_state=16, ssm_head_dim=16, ssm_chunk=16, pattern_len=8,
        dtype="float32", param_dtype="float32", remat=False, attn_chunk=32,
    )
