"""Qwen3-4B — dense, GQA kv=8, QK-norm.

[hf:Qwen/Qwen3-8B; hf].  36L, d_model=2560, 32 heads with explicit
head_dim=128 (q proj dim 4096 != d_model, as in Qwen3), d_ff=9728 SwiGLU,
vocab 151936, RMS qk_norm on per-head q/k.
"""
from repro.configs import ArchConfig


def config() -> ArchConfig:
    return ArchConfig(
        name="qwen3-4b",
        family="dense",
        num_layers=36,
        d_model=2560,
        num_heads=32,
        num_kv_heads=8,
        head_dim=128,
        d_ff=9728,
        vocab_size=151936,
        qk_norm=True,
        activation="swiglu",
        rope_theta=1_000_000.0,
    )


def smoke_config() -> ArchConfig:
    return config().replace(
        num_layers=2, d_model=64, num_heads=4, num_kv_heads=2, head_dim=16,
        d_ff=128, vocab_size=256,
        dtype="float32", param_dtype="float32", remat=False, attn_chunk=32,
    )
