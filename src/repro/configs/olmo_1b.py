"""OLMo-1B — dense, MHA (kv=16), non-parametric LayerNorm.

[arXiv:2402.00838; hf].  16L, d_model=2048, 16 heads (head_dim 128),
d_ff=8192 SwiGLU, vocab 50304, LayerNorm without learnable affine params,
tied input/output embeddings.
"""
from repro.configs import ArchConfig


def config() -> ArchConfig:
    return ArchConfig(
        name="olmo-1b",
        family="dense",
        num_layers=16,
        d_model=2048,
        num_heads=16,
        num_kv_heads=16,
        head_dim=128,
        d_ff=8192,
        vocab_size=50304,
        nonparametric_norm=True,
        tie_embeddings=True,
        activation="swiglu",
        rope_theta=10_000.0,
    )


def smoke_config() -> ArchConfig:
    return config().replace(
        num_layers=2, d_model=64, num_heads=4, num_kv_heads=4, head_dim=16,
        d_ff=128, vocab_size=256,
        dtype="float32", param_dtype="float32", remat=False, attn_chunk=32,
    )
