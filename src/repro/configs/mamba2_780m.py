"""Mamba2-780m — pure SSM (attention-free), SSD state-space duality.

[arXiv:2405.21060; unverified].  48 layers, d_model=1536, d_inner=2*d_model,
head_dim=64 -> 48 SSD heads, d_state=128, no FFN (the Mamba block is the whole
layer).  Runs all four shapes including ``long_500k``.
"""
from repro.configs import ArchConfig


def config() -> ArchConfig:
    return ArchConfig(
        name="mamba2-780m",
        family="ssm",
        num_layers=48,
        d_model=1536,
        num_heads=0,
        num_kv_heads=0,
        head_dim=0,
        d_ff=0,
        vocab_size=50280,
        ssm_state=128,
        ssm_head_dim=64,
        ssm_expand=2,
        ssm_conv_width=4,
        ssm_chunk=256,
        shape_names=("train_4k", "prefill_32k", "decode_32k", "long_500k"),
        skipped_shapes=(),
        skip_reason="",
    )


def smoke_config() -> ArchConfig:
    return config().replace(
        num_layers=4, d_model=64, vocab_size=256, ssm_state=16,
        ssm_head_dim=16, ssm_chunk=16,
        dtype="float32", param_dtype="float32", remat=False,
    )
