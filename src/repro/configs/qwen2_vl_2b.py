"""Qwen2-VL-2B — VLM backbone, M-RoPE, GQA kv=2.

[arXiv:2409.12191; hf].  28L, d_model=1536, 12 heads (head_dim 128),
d_ff=8960 SwiGLU, vocab 151936.  The vision frontend is a STUB:
``input_specs()`` supplies precomputed patch embeddings (frontend_len=256)
that are prepended to token embeddings; M-RoPE uses 3 position streams
(temporal/height/width) with sections (16, 24, 24) over head_dim 128 halves.
"""
from repro.configs import ArchConfig


def config() -> ArchConfig:
    return ArchConfig(
        name="qwen2-vl-2b",
        family="vlm",
        num_layers=28,
        d_model=1536,
        num_heads=12,
        num_kv_heads=2,
        head_dim=128,
        d_ff=8960,
        vocab_size=151936,
        mrope_sections=(16, 24, 24),
        activation="swiglu",
        rope_theta=1_000_000.0,
        frontend="vision",
        frontend_len=256,
    )


def smoke_config() -> ArchConfig:
    return config().replace(
        num_layers=2, d_model=64, num_heads=4, num_kv_heads=2, head_dim=16,
        mrope_sections=(4, 2, 2), frontend_len=8,
        d_ff=128, vocab_size=256,
        dtype="float32", param_dtype="float32", remat=False, attn_chunk=32,
    )
