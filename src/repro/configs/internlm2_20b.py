"""InternLM2-20B — dense, GQA kv=8.

[arXiv:2403.17297; hf].  48L, d_model=6144, 48 heads (head_dim 128),
d_ff=16384 SwiGLU, vocab 92544.
"""
from repro.configs import ArchConfig


def config() -> ArchConfig:
    return ArchConfig(
        name="internlm2-20b",
        family="dense",
        num_layers=48,
        d_model=6144,
        num_heads=48,
        num_kv_heads=8,
        head_dim=128,
        d_ff=16384,
        vocab_size=92544,
        activation="swiglu",
        rope_theta=1_000_000.0,
    )


def smoke_config() -> ArchConfig:
    return config().replace(
        num_layers=2, d_model=64, num_heads=4, num_kv_heads=2, head_dim=16,
        d_ff=128, vocab_size=256,
        dtype="float32", param_dtype="float32", remat=False, attn_chunk=32,
    )
