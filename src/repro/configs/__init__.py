"""Architecture / shape config system.

Every runnable model in the framework — the 10 assigned LM-family architectures
plus the paper's own CAPSim predictor — is described by an ``ArchConfig``.
Configs are plain frozen dataclasses so they hash, compare, and print cleanly;
the registry maps the public ``--arch <id>`` names to builder functions.

Shapes follow the assignment:
    train_4k      seq_len=4096,   global_batch=256   (training)
    prefill_32k   seq_len=32768,  global_batch=32    (inference prefill)
    decode_32k    seq_len=32768,  global_batch=128   (one-token decode w/ KV cache)
    long_500k     seq_len=524288, global_batch=1     (long-context decode)

``long_500k`` is only runnable for sub-quadratic archs (ssm / hybrid); pure
full-attention archs mark it skipped (see DESIGN.md §Arch-applicability).
"""
from __future__ import annotations

import dataclasses
import importlib
from dataclasses import dataclass
from typing import Tuple


# --------------------------------------------------------------------------- #
# Shape configs
# --------------------------------------------------------------------------- #

@dataclass(frozen=True)
class ShapeConfig:
    name: str
    seq_len: int
    global_batch: int
    kind: str  # "train" | "prefill" | "decode"


LM_SHAPES = {
    "train_4k": ShapeConfig("train_4k", 4_096, 256, "train"),
    "prefill_32k": ShapeConfig("prefill_32k", 32_768, 32, "prefill"),
    "decode_32k": ShapeConfig("decode_32k", 32_768, 128, "decode"),
    "long_500k": ShapeConfig("long_500k", 524_288, 1, "decode"),
}

# CAPSim predictor shapes: "seq_len" is the clip length (instructions per clip),
# batch is clips per step.  Kinds map onto the same train/serve entry points.
CAPSIM_SHAPES = {
    "train_clips": ShapeConfig("train_clips", 128, 4_096, "train"),
    "serve_clips": ShapeConfig("serve_clips", 128, 16_384, "prefill"),
}


# --------------------------------------------------------------------------- #
# Architecture config
# --------------------------------------------------------------------------- #

@dataclass(frozen=True)
class ArchConfig:
    name: str
    family: str                      # dense | moe | ssm | hybrid | vlm | audio | predictor
    num_layers: int
    d_model: int
    num_heads: int                   # query heads (0 for attention-free)
    num_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int = 0                # 0 -> d_model // num_heads

    # --- MoE ---
    num_experts: int = 0
    experts_per_token: int = 0
    moe_every: int = 1               # MoE FFN on layers with (i % moe_every == moe_offset)
    moe_offset: int = 0
    capacity_factor: float = 1.25

    # --- SSM / hybrid ---
    ssm_state: int = 0               # Mamba2 d_state (0 -> no ssm layers)
    ssm_head_dim: int = 64
    ssm_expand: int = 2
    ssm_conv_width: int = 4
    ssm_chunk: int = 256             # SSD chunk size
    attn_every: int = 0              # hybrid: attention on layers with (i % attn_every == attn_offset)
    attn_offset: int = 0

    # --- attention features ---
    qk_norm: bool = False
    rope_theta: float = 10_000.0
    mrope_sections: Tuple[int, ...] = ()   # qwen2-vl M-RoPE (temporal, h, w) dims
    attn_window: int = 0                   # >0: sliding-window attention
    attn_logit_softcap: float = 0.0

    # --- FFN / norm features ---
    activation: str = "swiglu"       # swiglu | squared_relu | gelu
    nonparametric_norm: bool = False # olmo: LN without learnable params
    tie_embeddings: bool = False

    # --- modality frontend stubs ---
    frontend: str = "none"           # none | vision | audio
    frontend_len: int = 0            # number of precomputed frontend embeddings
    num_codebooks: int = 1           # musicgen: parallel EnCodec streams

    # --- CAPSim predictor extras (family == "predictor") ---
    clip_tokens: int = 32            # L_token: padded tokens per instruction
    context_tokens: int = 360        # M: context-matrix rows (register state)

    # --- numerics / execution ---
    dtype: str = "bfloat16"
    param_dtype: str = "bfloat16"
    remat: bool = True
    attn_impl: str = "chunked"       # chunked (XLA) | pallas (TPU flash kernel)
    ssm_impl: str = "chunked"        # chunked (XLA) | pallas (TPU SSD kernel)
    attn_chunk: int = 1024           # q-chunk for memory-bounded XLA attention
    scan_layers: bool = True
    pattern_len: int = 1             # layers per scanned super-block (jamba: 8)

    # --- which assigned shape names apply ---
    shape_names: Tuple[str, ...] = ("train_4k", "prefill_32k", "decode_32k")
    skipped_shapes: Tuple[str, ...] = ("long_500k",)
    skip_reason: str = "pure full-attention arch: 500k decode needs sub-quadratic mixer"

    # ------------------------------------------------------------------ #
    def __post_init__(self):
        if self.head_dim == 0 and self.num_heads:
            object.__setattr__(self, "head_dim", self.d_model // self.num_heads)
        if self.num_layers % self.pattern_len != 0:
            raise ValueError(
                f"{self.name}: num_layers={self.num_layers} not divisible by "
                f"pattern_len={self.pattern_len}")

    # --- layer-schedule helpers (which mixer / ffn at layer i) --------- #
    def mixer_at(self, i: int) -> str:
        """'attn' | 'ssm' for layer i."""
        if self.ssm_state == 0:
            return "attn"
        if self.attn_every == 0:
            return "ssm"
        return "attn" if (i % self.attn_every) == self.attn_offset else "ssm"

    def ffn_at(self, i: int) -> str:
        """'dense' | 'moe' | 'none' for layer i."""
        if self.d_ff == 0 and self.num_experts == 0:
            return "none"
        if self.num_experts and (i % self.moe_every) == self.moe_offset:
            return "moe"
        return "dense" if self.d_ff else "none"

    def pattern(self) -> Tuple[Tuple[str, str], ...]:
        """The (mixer, ffn) schedule of one scanned super-block."""
        return tuple((self.mixer_at(i), self.ffn_at(i))
                     for i in range(self.pattern_len))

    @property
    def num_repeats(self) -> int:
        return self.num_layers // self.pattern_len

    def shapes(self):
        table = CAPSIM_SHAPES if self.family == "predictor" else LM_SHAPES
        return {n: table[n] for n in self.shape_names}

    def replace(self, **kw) -> "ArchConfig":
        return dataclasses.replace(self, **kw)


# --------------------------------------------------------------------------- #
# Registry
# --------------------------------------------------------------------------- #

_ARCH_MODULES = {
    "jamba-1.5-large-398b": "jamba_1_5_large_398b",
    "mamba2-780m": "mamba2_780m",
    "nemotron-4-15b": "nemotron_4_15b",
    "qwen3-4b": "qwen3_4b",
    "internlm2-20b": "internlm2_20b",
    "olmo-1b": "olmo_1b",
    "kimi-k2-1t-a32b": "kimi_k2_1t_a32b",
    "llama4-maverick-400b-a17b": "llama4_maverick_400b_a17b",
    "qwen2-vl-2b": "qwen2_vl_2b",
    "musicgen-large": "musicgen_large",
    "capsim": "capsim",
}

ARCH_NAMES = tuple(_ARCH_MODULES)
ASSIGNED_ARCH_NAMES = tuple(n for n in ARCH_NAMES if n != "capsim")


def get_config(name: str) -> ArchConfig:
    """Load the full (paper-exact) config for ``--arch <name>``."""
    if name not in _ARCH_MODULES:
        raise KeyError(f"unknown arch {name!r}; known: {list(_ARCH_MODULES)}")
    mod = importlib.import_module(f"repro.configs.{_ARCH_MODULES[name]}")
    return mod.config()


def get_smoke_config(name: str) -> ArchConfig:
    """Reduced same-family config for CPU smoke tests."""
    mod = importlib.import_module(f"repro.configs.{_ARCH_MODULES[name]}")
    return mod.smoke_config()
