"""LR schedules (pure functions of step)."""
from __future__ import annotations

import jax.numpy as jnp


def warmup_cosine(base_lr: float, warmup_steps: int, total_steps: int,
                  min_ratio: float = 0.1):
    def lr(step):
        step = jnp.asarray(step, jnp.float32)
        warm = base_lr * step / jnp.maximum(1.0, warmup_steps)
        prog = (step - warmup_steps) / jnp.maximum(
            1.0, total_steps - warmup_steps)
        prog = jnp.clip(prog, 0.0, 1.0)
        cos = base_lr * (min_ratio + (1 - min_ratio) *
                         0.5 * (1 + jnp.cos(jnp.pi * prog)))
        return jnp.where(step < warmup_steps, warm, cos)
    return lr


def constant(base_lr: float):
    def lr(step):
        return jnp.full((), base_lr, jnp.float32)
    return lr
