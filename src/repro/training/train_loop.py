"""Train-step factory: grad accumulation, clipping, compression, schedules.

``make_train_step`` returns a pure function suitable for jax.jit / AOT
lowering:

    state = {"params", "opt", "step", "err_fb"?}
    new_state, metrics = train_step(state, batch)

Microbatching runs as a lax.scan over gradient accumulation slices, so the
HLO stays small and activation memory is bounded by one microbatch.
"""
from __future__ import annotations

import dataclasses
from typing import Callable

import jax
import jax.numpy as jnp

from repro.distributed.compression import compress_decompress, init_error_feedback
from repro.training.optimizer import Optimizer, get_optimizer
from repro.training.schedule import constant, warmup_cosine


@dataclasses.dataclass(frozen=True)
class TrainConfig:
    optimizer: str = "sgdm"          # paper §VI-B: SGD momentum 0.9
    base_lr: float = 1e-3
    warmup_steps: int = 0
    total_steps: int = 1000
    grad_clip: float = 1.0
    microbatches: int = 1
    compress_grads: bool = False
    momentum: float = 0.9
    weight_decay: float = 0.0
    accum_dtype: str = "float32"     # microbatch grad accumulator (bf16 =
                                     # half the accumulator HBM, §Perf B2)
    opt_state_dtype: str = "float32"  # sgdm momentum dtype (§Perf B4)

    def make_optimizer(self) -> Optimizer:
        if self.optimizer == "sgdm":
            return get_optimizer("sgdm", momentum=self.momentum,
                                 weight_decay=self.weight_decay,
                                 state_dtype=self.opt_state_dtype)
        if self.optimizer == "adamw":
            return get_optimizer("adamw", weight_decay=self.weight_decay)
        return get_optimizer(self.optimizer)

    def make_schedule(self) -> Callable:
        if self.warmup_steps or self.total_steps:
            return warmup_cosine(self.base_lr, self.warmup_steps,
                                 self.total_steps)
        return constant(self.base_lr)


def init_train_state(params, tcfg: TrainConfig) -> dict:
    opt = tcfg.make_optimizer()
    state = {"params": params, "opt": opt.init(params),
             "step": jnp.zeros((), jnp.int32)}
    if tcfg.compress_grads:
        state["err_fb"] = init_error_feedback(params)
    return state


def abstract_train_state(param_abs, tcfg: TrainConfig) -> dict:
    opt = tcfg.make_optimizer()
    state = {"params": param_abs, "opt": opt.abstract_state(param_abs),
             "step": jax.ShapeDtypeStruct((), jnp.int32)}
    if tcfg.compress_grads:
        state["err_fb"] = jax.tree_util.tree_map(
            lambda p: jax.ShapeDtypeStruct(p.shape, jnp.float32), param_abs)
    return state


def _global_norm(tree):
    leaves = jax.tree_util.tree_leaves(tree)
    return jnp.sqrt(sum(jnp.sum(jnp.square(l.astype(jnp.float32)))
                        for l in leaves))


def _clip_by_global_norm(grads, max_norm):
    gn = _global_norm(grads)
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(gn, 1e-12))
    # scale in the gradient's own dtype: materializing an f32 copy here
    # forces XLA to run the cross-replica gradient reduction in f32 —
    # measured 2x the all-reduce wire on the dry-run (§Perf iteration A2)
    return jax.tree_util.tree_map(
        lambda g: g * scale.astype(g.dtype), grads), gn


def make_train_step(loss_fn: Callable, tcfg: TrainConfig):
    """loss_fn(params, batch) -> (scalar, aux dict)."""
    opt = tcfg.make_optimizer()
    sched = tcfg.make_schedule()
    grad_fn = jax.value_and_grad(loss_fn, has_aux=True)

    def microbatched_grads(params, batch):
        if tcfg.microbatches <= 1:
            (loss, aux), grads = grad_fn(params, batch)
            return loss, aux, grads
        n = tcfg.microbatches

        def reshape(x):
            b = x.shape[0]
            assert b % n == 0, f"batch {b} not divisible by microbatches {n}"
            return x.reshape(n, b // n, *x.shape[1:])

        mb = jax.tree_util.tree_map(reshape, batch)

        acc_dt = jnp.dtype(tcfg.accum_dtype)

        def body(carry, mbatch):
            loss_sum, aux_sum, gsum = carry
            (loss, aux), grads = grad_fn(params, mbatch)
            gsum = jax.tree_util.tree_map(
                lambda a, g: a + g.astype(acc_dt), gsum, grads)
            aux_sum = jax.tree_util.tree_map(lambda a, b_: a + b_,
                                             aux_sum, aux)
            return (loss_sum + loss, aux_sum, gsum), None

        zero_g = jax.tree_util.tree_map(
            lambda p: jnp.zeros(p.shape, acc_dt), params)
        zero_aux = {"ce": 0.0, "lb": 0.0, "z": 0.0}
        zero_aux = jax.tree_util.tree_map(jnp.float32, zero_aux)
        (loss, aux, gsum), _ = jax.lax.scan(body, (0.0, zero_aux, zero_g), mb)
        inv = 1.0 / n
        grads = jax.tree_util.tree_map(lambda g: g * inv, gsum)
        aux = jax.tree_util.tree_map(lambda a: a * inv, aux)
        return loss * inv, aux, grads

    def train_step(state, batch):
        params = state["params"]
        loss, aux, grads = microbatched_grads(params, batch)
        grads, gnorm = _clip_by_global_norm(grads, tcfg.grad_clip)
        new_state = dict(state)
        if tcfg.compress_grads:
            grads, new_state["err_fb"] = compress_decompress(
                grads, state["err_fb"])
        lr = sched(state["step"])
        new_params, new_opt = opt.update(grads, state["opt"], params, lr)
        new_state["params"] = new_params
        new_state["opt"] = new_opt
        new_state["step"] = state["step"] + 1
        metrics = {"loss": loss, "grad_norm": gnorm, "lr": lr, **aux}
        return new_state, metrics

    return train_step
