from repro.training.optimizer import get_optimizer  # noqa: F401
from repro.training.train_loop import TrainConfig, make_train_step  # noqa: F401
