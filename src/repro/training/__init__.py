from repro.training.optimizer import get_optimizer  # noqa: F401
from repro.training.train_loop import make_train_step, TrainConfig  # noqa: F401
