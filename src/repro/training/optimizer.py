"""Pure-JAX optimizers (no optax dependency).

Each optimizer is an (init, update) pair over arbitrary param pytrees:

    state = opt.init(params)
    new_params, new_state = opt.update(grads, state, params, lr)

SGD+momentum is the paper's trainer (§VI-B: momentum 0.9, lr 1e-3) and is the
memory-light default for the trillion-parameter dry-run cells; AdamW for the
small-model experiments; Adafactor for memory-constrained large training.
Optimizer states inherit the param sharding (same tree structure), so FSDP
sharding of params automatically ZeRO-shards the states.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class Optimizer:
    name: str
    init: Callable[[Any], Any]
    update: Callable[[Any, Any, Any, jax.Array], Any]
    # pytree-structure factory for the state given param *specs* (for AOT)
    abstract_state: Callable[[Any], Any]


def _tmap(fn, *trees, **kw):
    return jax.tree_util.tree_map(fn, *trees, **kw)


# ----------------------------- SGD + momentum ------------------------------ #

def sgd_momentum(momentum: float = 0.9, weight_decay: float = 0.0,
                 state_dtype: str = "float32") -> Optimizer:
    dt = jnp.dtype(state_dtype)

    def init(params):
        return {"mu": _tmap(lambda p: jnp.zeros(p.shape, dt), params)}

    def update(grads, state, params, lr):
        mu = _tmap(lambda m, g: momentum * m + g.astype(dt), state["mu"], grads)
        def step(p, m):
            upd = m
            if weight_decay:
                upd = upd + weight_decay * p.astype(dt)
            return (p.astype(jnp.float32) - lr * upd.astype(jnp.float32)
                    ).astype(p.dtype)
        return _tmap(step, params, mu), {"mu": mu}

    def abstract_state(param_abs):
        return {"mu": _tmap(lambda p: jax.ShapeDtypeStruct(p.shape, dt),
                            param_abs)}

    return Optimizer("sgdm", init, update, abstract_state)


# --------------------------------- AdamW ----------------------------------- #

def adamw(b1: float = 0.9, b2: float = 0.95, eps: float = 1e-8,
          weight_decay: float = 0.1) -> Optimizer:

    def init(params):
        z = lambda p: jnp.zeros(p.shape, jnp.float32)
        return {"mu": _tmap(z, params), "nu": _tmap(z, params),
                "count": jnp.zeros((), jnp.int32)}

    def update(grads, state, params, lr):
        c = state["count"] + 1
        mu = _tmap(lambda m, g: b1 * m + (1 - b1) * g.astype(jnp.float32),
                   state["mu"], grads)
        nu = _tmap(lambda v, g: b2 * v + (1 - b2) *
                   jnp.square(g.astype(jnp.float32)), state["nu"], grads)
        bc1 = 1 - b1 ** c.astype(jnp.float32)
        bc2 = 1 - b2 ** c.astype(jnp.float32)

        def step(p, m, v):
            upd = (m / bc1) / (jnp.sqrt(v / bc2) + eps)
            upd = upd + weight_decay * p.astype(jnp.float32)
            return (p.astype(jnp.float32) - lr * upd).astype(p.dtype)

        return _tmap(step, params, mu, nu), {"mu": mu, "nu": nu, "count": c}

    def abstract_state(param_abs):
        z = lambda p: jax.ShapeDtypeStruct(p.shape, jnp.float32)
        return {"mu": _tmap(z, param_abs), "nu": _tmap(z, param_abs),
                "count": jax.ShapeDtypeStruct((), jnp.int32)}

    return Optimizer("adamw", init, update, abstract_state)


# ------------------------------- Adafactor --------------------------------- #

def adafactor(decay: float = 0.99, eps: float = 1e-30,
              clip_threshold: float = 1.0) -> Optimizer:
    """Factored second moment for >=2D params (row/col statistics)."""

    def _factored(p):
        return p.ndim >= 2

    def init(params):
        def make(p):
            if _factored(p):
                return {"vr": jnp.zeros(p.shape[:-1], jnp.float32),
                        "vc": jnp.zeros(p.shape[:-2] + p.shape[-1:],
                                        jnp.float32)}
            return {"v": jnp.zeros(p.shape, jnp.float32)}
        return {"v": _tmap(make, params,), "count": jnp.zeros((), jnp.int32)}

    def update(grads, state, params, lr):
        c = state["count"] + 1

        def step(p, g, s):
            g = g.astype(jnp.float32)
            g2 = jnp.square(g) + eps
            if _factored(p):
                vr = decay * s["vr"] + (1 - decay) * jnp.mean(g2, axis=-1)
                vc = decay * s["vc"] + (1 - decay) * jnp.mean(g2, axis=-2)
                denom = jnp.maximum(jnp.mean(vr, axis=-1, keepdims=True),
                                    eps)
                vhat = (vr[..., None] * vc[..., None, :]) / denom[..., None]
                upd = g * jax.lax.rsqrt(vhat + eps)
                new_s = {"vr": vr, "vc": vc}
            else:
                v = decay * s["v"] + (1 - decay) * g2
                upd = g * jax.lax.rsqrt(v + eps)
                new_s = {"v": v}
            rms = jnp.sqrt(jnp.mean(jnp.square(upd)) + 1e-12)
            upd = upd / jnp.maximum(1.0, rms / clip_threshold)
            return (p.astype(jnp.float32) - lr * upd).astype(p.dtype), new_s

        out = _tmap(lambda p, g, s: step(p, g, s), params, grads, state["v"],
                    )
        # out leaves are tuples; unzip
        new_params = _tmap(lambda o: o[0], out,
                           is_leaf=lambda x: isinstance(x, tuple))
        new_v = _tmap(lambda o: o[1], out,
                      is_leaf=lambda x: isinstance(x, tuple))
        return new_params, {"v": new_v, "count": c}

    def abstract_state(param_abs):
        def make(p):
            if _factored(p):
                return {"vr": jax.ShapeDtypeStruct(p.shape[:-1], jnp.float32),
                        "vc": jax.ShapeDtypeStruct(
                            p.shape[:-2] + p.shape[-1:], jnp.float32)}
            return {"v": jax.ShapeDtypeStruct(p.shape, jnp.float32)}
        return {"v": _tmap(make, param_abs),
                "count": jax.ShapeDtypeStruct((), jnp.int32)}

    return Optimizer("adafactor", init, update, abstract_state)


def get_optimizer(name: str, **kw) -> Optimizer:
    if name == "sgdm":
        return sgd_momentum(**kw)
    if name == "adamw":
        return adamw(**kw)
    if name == "adafactor":
        return adafactor(**kw)
    raise ValueError(name)
